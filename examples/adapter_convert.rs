//! Adapter lifecycle demo — two parts.
//!
//! **Part 1 (always runs, no artifacts needed):** the multi-adapter
//! engine. Two named adapters (PiSSA r=8 on q/v, LoRA r=4 on all seven
//! linears) over ONE frozen base; hot-swap between them, merge/unmerge
//! the LoRA adapter (deployment path, §3), and export the PiSSA adapter
//! as an Appendix-C LoRA delta (ΔA = [A'|A], ΔB = [B';−B]) — every
//! invariant checked at runtime.
//!
//! **Part 2 (needs `artifacts/`):** the original end-to-end protocol —
//! fine-tune a PiSSA adapter through the PJRT train artifact, convert it,
//! and verify that applying ΔA·ΔB to the ORIGINAL dense weights
//! reproduces the fine-tuned weights exactly — no SVD at share time.
//!
//! Run: cargo run --release --example adapter_convert

use anyhow::Result;
use pissa::adapter::convert::pissa_to_lora;
use pissa::adapter::{AdapterEngine, AdapterSpec};
use pissa::coordinator::{self, RunConfig};
use pissa::linalg::{matmul, Mat};
use pissa::model::{apply_spec, BaseModel, Tensor};
use pissa::runtime::{ConfigInfo, Manifest, Runtime};
use pissa::util::rng::Rng;
use std::path::PathBuf;

fn main() -> Result<()> {
    engine_demo()?;

    let art = PathBuf::from("artifacts");
    if !art.join("manifest.json").exists() {
        println!("\n[convert] artifacts/ absent — skipping the PJRT fine-tune flow");
        println!("[convert] (run `make artifacts` and link the real xla crate to enable it)");
        return Ok(());
    }
    pjrt_convert_flow(&art)
}

/// Part 1: AdapterEngine — registry ops over one frozen base.
fn engine_demo() -> Result<()> {
    println!("== AdapterEngine demo: two adapters, one frozen base ==");
    let cfg = ConfigInfo {
        name: "demo".into(),
        kind: "decoder".into(),
        vocab: 320,
        d_model: 48,
        n_layers: 2,
        n_heads: 2,
        d_ff: 96,
        seq_len: 32,
        batch: 4,
        eval_batch: 2,
        n_classes: 0,
        ranks: vec![4, 8],
    };
    let mut rng = Rng::new(42);
    let base = BaseModel::random(&cfg, &mut rng);
    let w_q0 = base.linears["base_q"].layer(0); // original dense weight
    let mut engine = AdapterEngine::new(base);

    // Two named adapters from declarative specs; attach validates the
    // base + A·B == W invariant for every targeted layer.
    engine.attach("math-pissa", AdapterSpec::pissa(8).niter(4).targets(&["q", "v"]), &mut rng)?;
    engine.attach("chat-lora", AdapterSpec::lora(4), &mut rng)?;
    println!("[engine] attached: {:?} (active: {:?})", engine.names(), engine.active());

    // Both serve W exactly at init — that's the paper's point.
    for name in ["math-pissa", "chat-lora"] {
        let eff = engine.effective_weight_of(name, "q", 0)?;
        let rel = eff.sub(&w_q0).fro() / w_q0.fro();
        println!("[engine] {name:10}: ‖W − effective‖/‖W‖ = {rel:.2e}");
        assert!(rel < 1e-5, "{name} must preserve W at init");
    }
    // PiSSA targets only q/v: untargeted modules serve the frozen base.
    let gate = engine.effective_weight_of("math-pissa", "gate", 0)?;
    assert_eq!(gate.data, engine.base_weight("gate", 0).data);

    // Hot-swap: O(1), base untouched.
    let prev = engine.swap("chat-lora")?;
    println!("[engine] hot-swapped {:?} -> {:?}", prev, engine.active());

    // Simulate training drift on both adapters.
    for name in ["math-pissa", "chat-lora"] {
        let modules: Vec<String> =
            engine.get(name)?.spec.target_modules().iter().map(|s| s.to_string()).collect();
        for module in modules {
            for li in 0..2 {
                let (mut a, mut b) = {
                    let ad = engine.get(name)?;
                    (
                        ad.factors[&format!("a_{module}")].layer(li),
                        ad.factors[&format!("b_{module}")].layer(li),
                    )
                };
                for x in a.data.iter_mut() {
                    *x += 0.05 * rng.normal_f32(0.0, 1.0);
                }
                for x in b.data.iter_mut() {
                    *x += 0.05 * rng.normal_f32(0.0, 1.0);
                }
                engine.set_factors(name, &module, li, &a, &b)?;
            }
        }
    }

    // Merge/unmerge the LoRA adapter (deployment path). The merged dense
    // weights equal base + A·B; unmerge verifies the round-trip and the
    // factors come back bit-identical (they were never destroyed).
    let factors_before = engine.get("chat-lora")?.factors.clone();
    let eff_before = engine.effective_weight_of("chat-lora", "down", 1)?;
    engine.merge("chat-lora")?;
    let eff_merged = engine.effective_weight_of("chat-lora", "down", 1)?;
    assert_eq!(eff_merged.data, eff_before.data, "merged dense == base + A·B");
    engine.unmerge("chat-lora")?;
    for (k, t) in &factors_before {
        assert_eq!(t.data, engine.get("chat-lora")?.factors[k].data, "factor {k} changed");
    }
    println!("[engine] merge/unmerge(chat-lora): dense == base + A·B, factors restored ✓");

    // Export the (drifted) PiSSA adapter as an Appendix-C LoRA delta;
    // every layer is validated against the ORIGINAL dense W inside
    // to_lora_delta.
    let deltas = engine.to_lora_delta("math-pissa")?;
    let d = &deltas["q"][0];
    let via = w_q0.add(&d.delta());
    let direct = engine.effective_weight_of("math-pissa", "q", 0)?;
    let rel = via.sub(&direct).fro() / direct.fro();
    println!(
        "[engine] to_lora_delta(math-pissa): {} modules, ΔA is {}x{}, W+ΔAΔB rel err {rel:.2e} ✓",
        deltas.len(),
        d.da.rows,
        d.da.cols
    );
    assert!(rel < 1e-4);
    println!("[engine] OK — hot-swap, merge/unmerge, and LoRA export all hold ✓");
    Ok(())
}

/// Part 2: the original PJRT-backed fine-tune + conversion protocol.
fn pjrt_convert_flow(art: &PathBuf) -> Result<()> {
    let manifest = Manifest::load(art)?;
    let rt = Runtime::cpu(art)?;

    println!("\n[convert] pre-train + PiSSA fine-tune on tiny…");
    let (base, _) = coordinator::pretrain(&rt, &manifest, "tiny", 100, 2e-3, 42)?;
    // Snapshot the INITIAL PiSSA factors (the conversion needs them).
    let spec = AdapterSpec::pissa(4);
    let mut rng = Rng::new(42 /* same seed the finetune below uses */);
    let init_state = apply_spec(&base, &spec, &mut rng)?;

    let run = RunConfig { steps: 60, ..RunConfig::quick("tiny", spec) };
    let result = coordinator::finetune(&rt, &manifest, &base, &run)?;
    let trained = &result.final_state;

    println!("[convert] building ΔA/ΔB per layer/linear (Eq. 9–10)…");
    let mut max_err = 0.0f64;
    let mut n_adapters = 0;
    for name in pissa::model::LINEARS {
        let w_orig_t: &Tensor = &base.linears[&format!("base_{name}")];
        let layers = w_orig_t.shape[0];
        for l in 0..layers {
            let w_orig: Mat = w_orig_t.layer(l);
            let a0 = init_state.trainable[&format!("a_{name}")].layer(l);
            let b0 = init_state.trainable[&format!("b_{name}")].layer(l);
            let a1 = trained.trainable[&format!("a_{name}")].layer(l);
            let b1 = trained.trainable[&format!("b_{name}")].layer(l);
            let res = trained.frozen[&format!("base_{name}")].layer(l);

            // Fine-tuned effective weight: W_res + A'B'.
            let w_ft = res.add(&matmul(&a1, &b1));
            // Via conversion: W_orig + ΔA·ΔB.
            let delta = pissa_to_lora(&a0, &b0, &a1, &b1);
            let w_via = w_orig.add(&delta.delta());
            let err = w_ft.sub(&w_via).fro() / w_ft.fro().max(1e-30);
            max_err = max_err.max(err);
            n_adapters += 1;
        }
    }
    println!("[convert] {n_adapters} adapters converted; max relative error {max_err:.2e}");
    assert!(max_err < 1e-4, "conversion must be exact (got {max_err})");

    // Storage accounting (the paper's sharing argument).
    let cfg = manifest.config("tiny")?;
    let dense = cfg.d_model * cfg.d_model;
    let lora_delta = 2 * (cfg.d_model * 2 * 4 + 2 * 4 * cfg.d_model) / 2;
    println!(
        "[convert] per q_proj layer: dense ΔW = {dense} floats vs ΔA/ΔB = {lora_delta} floats ({}x smaller)",
        dense / lora_delta.max(1)
    );
    println!("[convert] OK — trained PiSSA shares as a plain LoRA adapter ✓");
    Ok(())
}
