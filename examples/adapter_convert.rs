//! Appendix C end-to-end: fine-tune a PiSSA adapter, convert it to an
//! equivalent LoRA delta (ΔA = [A'|A], ΔB = [B';−B]) and verify that
//! applying ΔA·ΔB to the ORIGINAL dense weights reproduces the
//! fine-tuned model's logits exactly — no SVD needed at share time.
//!
//! Run: cargo run --release --example adapter_convert

use anyhow::Result;
use pissa::adapter::convert::pissa_to_lora;
use pissa::adapter::init::Strategy;
use pissa::coordinator::{self, RunConfig};
use pissa::linalg::Mat;
use pissa::model::{apply_strategy, Tensor};
use pissa::runtime::{Manifest, Runtime};
use pissa::util::rng::Rng;
use std::path::PathBuf;

fn main() -> Result<()> {
    let art = PathBuf::from("artifacts");
    let manifest = Manifest::load(&art)?;
    let rt = Runtime::cpu(&art)?;

    println!("[convert] pre-train + PiSSA fine-tune on tiny…");
    let (base, _) = coordinator::pretrain(&rt, &manifest, "tiny", 100, 2e-3, 42)?;
    // Snapshot the INITIAL PiSSA factors (the conversion needs them).
    let mut rng = Rng::new(42 /* same seed the finetune below uses */);
    let init_state = apply_strategy(&base, Strategy::Pissa, 4, 5, &mut rng)?;

    let run = RunConfig { steps: 60, ..RunConfig::quick("tiny", Strategy::Pissa, 4) };
    let result = coordinator::finetune(&rt, &manifest, &base, &run)?;
    let trained = &result.final_state;

    println!("[convert] building ΔA/ΔB per layer/linear (Eq. 9–10)…");
    let mut max_err = 0.0f64;
    let mut n_adapters = 0;
    for name in pissa::model::LINEARS {
        let w_orig_t: &Tensor = &base.linears[&format!("base_{name}")];
        let layers = w_orig_t.shape[0];
        for l in 0..layers {
            let w_orig: Mat = w_orig_t.layer(l);
            let a0 = init_state.trainable[&format!("a_{name}")].layer(l);
            let b0 = init_state.trainable[&format!("b_{name}")].layer(l);
            let a1 = trained.trainable[&format!("a_{name}")].layer(l);
            let b1 = trained.trainable[&format!("b_{name}")].layer(l);
            let res = trained.frozen[&format!("base_{name}")].layer(l);

            // Fine-tuned effective weight: W_res + A'B'.
            let w_ft = res.add(&pissa::linalg::matmul(&a1, &b1));
            // Via conversion: W_orig + ΔA·ΔB.
            let delta = pissa_to_lora(&a0, &b0, &a1, &b1);
            let w_via = w_orig.add(&delta.delta());
            let err = w_ft.sub(&w_via).fro() / w_ft.fro().max(1e-30);
            max_err = max_err.max(err);
            n_adapters += 1;
        }
    }
    println!("[convert] {n_adapters} adapters converted; max relative error {max_err:.2e}");
    assert!(max_err < 1e-4, "conversion must be exact (got {max_err})");

    // Storage accounting (the paper's sharing argument).
    let cfg = manifest.config("tiny")?;
    let dense = cfg.d_model * cfg.d_model;
    let lora_delta = 2 * (cfg.d_model * 2 * 4 + 2 * 4 * cfg.d_model) / 2;
    println!(
        "[convert] per q_proj layer: dense ΔW = {dense} floats vs ΔA/ΔB = {lora_delta} floats ({}x smaller)",
        dense / lora_delta.max(1)
    );
    println!("[convert] OK — trained PiSSA shares as a plain LoRA adapter ✓");
    Ok(())
}
