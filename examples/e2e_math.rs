//! END-TO-END DRIVER — the repo's headline validation run.
//!
//! Exercises every layer on a real (small) workload:
//!   * pre-trains the `e2e` decoder (d=256, L=6, ~7.4M dense params) on
//!     the synthetic corpus via the full-FT HLO artifact (L2 compute,
//!     L3 loop),
//!   * fine-tunes it on the synthetic GSM8K-analog under PiSSA, LoRA and
//!     full fine-tuning with identical budgets,
//!   * logs all three loss curves to results/e2e_math/*.jsonl,
//!   * greedy-decodes the held-out eval set and reports exact-match
//!     accuracy (the paper's Table 1 protocol at reproduction scale).
//!
//! Run: cargo run --release --example e2e_math [-- --config small --steps 300]
//! Recorded in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use pissa::adapter::AdapterSpec;
use pissa::coordinator::{self, RunConfig, TaskFamily};
use pissa::metrics::JsonlSink;
use pissa::runtime::{Manifest, Runtime};
use pissa::util::cli::Args;
use pissa::util::timer::Timer;
use std::path::PathBuf;

fn main() -> Result<()> {
    let args = Args::from_env();
    let config = args.str_or("config", "e2e");
    let pre_steps = args.usize_or("pretrain-steps", 300)?;
    let ft_steps = args.usize_or("steps", 200)?;
    let rank = args.usize_or("rank", 8)?;
    let n_eval = args.usize_or("n-eval", 64)?;
    let seed = args.u64_or("seed", 42)?;

    let art = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let manifest = Manifest::load(&art)?;
    let rt = Runtime::cpu(&art)?;
    let out_dir = PathBuf::from("results/e2e_math");
    std::fs::create_dir_all(&out_dir)?;

    let cfg = manifest.config(&config)?;
    println!(
        "[e2e] model {config}: d={} L={} T={} — {} dense / {} adapter(r={rank}) trainable params",
        cfg.d_model,
        cfg.n_layers,
        cfg.seq_len,
        fmt_count(dense_params(cfg)),
        fmt_count(adapter_params(cfg, rank)),
    );

    // ---- 1. pre-train -----------------------------------------------------
    let t = Timer::start();
    println!("[e2e] pre-training for {pre_steps} steps…");
    let (base, pre_hist) = coordinator::pretrain(&rt, &manifest, &config, pre_steps, 2e-3, seed)?;
    println!(
        "[e2e] pretrain loss {:.3} -> {:.3} in {:.1}s",
        pre_hist[0].loss,
        pre_hist.last().unwrap().loss,
        t.secs()
    );
    let mut sink = JsonlSink::create(&out_dir.join("pretrain.jsonl"))?;
    for m in &pre_hist {
        sink.write_step(m)?;
    }

    // ---- 2. fine-tune under three strategies ------------------------------
    let specs = [AdapterSpec::pissa(rank), AdapterSpec::lora(rank), AdapterSpec::full_ft()];
    let mut summaries = Vec::new();
    for spec in specs {
        let run = RunConfig {
            config: config.clone(),
            spec: spec.clone(),
            steps: ft_steps,
            peak_lr: if spec.is_full_ft() { 5e-4 } else { 2e-3 },
            corpus_size: 2048,
            seed,
            task: TaskFamily::Math,
        };
        let t = Timer::start();
        let result = coordinator::finetune(&rt, &manifest, &base, &run)?;
        let mut sink = JsonlSink::create(&out_dir.join(format!("{}.jsonl", spec.name())))?;
        for m in &result.history {
            sink.write_step(m)?;
        }
        let acc = coordinator::evaluate(&rt, &manifest, &run, &result.final_state, n_eval, 56)?;
        println!(
            "[e2e] {:8} params={:>9}  loss {:.4} -> {:.4}  acc {:>6.2}%  ({:.1}s, overhead {:.1}%)",
            spec.name(),
            fmt_count(result.trainable_params),
            result.history[0].loss,
            result.final_loss(10),
            acc,
            t.secs(),
            100.0 * result.overhead_s / result.total_s.max(1e-9),
        );
        summaries.push((spec.name(), result.final_loss(10), acc));
    }

    // ---- 3. verdict --------------------------------------------------------
    let get = |s: &str| summaries.iter().find(|x| x.0 == s).unwrap();
    let (p, l) = (get("pissa"), get("lora"));
    println!("\n[e2e] paper claims at reproduction scale:");
    println!(
        "  PiSSA loss {:.4} < LoRA loss {:.4} : {}",
        p.1,
        l.1,
        if p.1 < l.1 { "✓" } else { "✗" }
    );
    println!(
        "  PiSSA acc  {:.2}% ≥ LoRA acc {:.2}% : {}",
        p.2,
        l.2,
        if p.2 >= l.2 { "✓" } else { "✗" }
    );
    println!("  curves: results/e2e_math/*.jsonl");
    Ok(())
}

fn dense_params(cfg: &pissa::runtime::ConfigInfo) -> usize {
    let (d, f, l) = (cfg.d_model, cfg.d_ff, cfg.n_layers);
    l * (4 * d * d + 3 * d * f) + 2 * cfg.vocab * d
}

fn adapter_params(cfg: &pissa::runtime::ConfigInfo, r: usize) -> usize {
    let (d, f, l) = (cfg.d_model, cfg.d_ff, cfg.n_layers);
    l * (4 * (d + d) * r + 2 * (d + f) * r + (f + d) * r)
}

fn fmt_count(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}
