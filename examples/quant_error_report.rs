//! Quantization-error report (Table 3 / Table 6 protocol) on a
//! pre-trained base model: for every linear-layer type, compare the
//! nuclear-norm error of QLoRA (= plain NF4), LoftQ-T-iter and
//! QPiSSA-T-iter, and print the reduction ratios.
//!
//! Run: cargo run --release --example quant_error_report [-- --config small --ranks 2,4,8 --iters 1,5]

use anyhow::Result;
use pissa::adapter::init;
use pissa::coordinator;
use pissa::linalg::{matmul, nuclear_norm};
use pissa::quant;
use pissa::runtime::{Manifest, Runtime};
use pissa::util::cli::Args;
use pissa::util::rng::Rng;
use std::path::PathBuf;

fn main() -> Result<()> {
    let args = Args::from_env();
    let config = args.str_or("config", "tiny");
    let ranks = args.usize_list_or("ranks", &[2, 4, 8])?;
    let iters_list = args.usize_list_or("iters", &[1, 5])?;

    let art = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let manifest = Manifest::load(&art)?;
    let rt = Runtime::cpu(&art)?;
    println!("[quant] pre-training {config} so weights have a realistic spectrum…");
    let (base, _) = coordinator::pretrain(&rt, &manifest, &config, 150, 2e-3, 42)?;
    let mut rng = Rng::new(9);

    println!("\nquantization-error reduction ratio vs QLoRA (%), layer 0 of each type");
    println!(
        "{:6} {:>5} {:>5} | {:>7} {:>7} | {:>8}",
        "layer", "rank", "T", "LoftQ", "QPiSSA", "QLoRA ‖·‖*"
    );
    for name in pissa::model::LINEARS {
        let w = base.linears[&format!("base_{name}")].layer(0);
        let baseline = quant::qlora_error(&w);
        for &r in &ranks {
            for &t in &iters_list {
                let lq = init::loftq(&w, r, t, &mut rng);
                let e_lq = nuclear_norm(&w.sub(&lq.base.add(&matmul(&lq.a, &lq.b))));
                let qp = init::qpissa(&w, r, t, &mut rng);
                let e_qp = nuclear_norm(&w.sub(&qp.base.add(&matmul(&qp.a, &qp.b))));
                println!(
                    "{:6} {:>5} {:>5} | {:>7.1} {:>7.1} | {:>8.3}",
                    name,
                    r,
                    t,
                    (1.0 - e_lq / baseline) * 100.0,
                    (1.0 - e_qp / baseline) * 100.0,
                    baseline,
                );
            }
        }
    }
    println!("\n(QLoRA's own ratio is 0 by construction — Eq. 6. Expect QPiSSA > LoftQ > 0,\n larger at higher rank and more iterations: Tables 3 & 6.)");
    Ok(())
}
