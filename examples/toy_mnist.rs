//! Figure 2a's toy experiment: pre-train a 2-layer MLP on odd synthetic
//! digits, fine-tune on even digits, compare LoRA vs PiSSA vs full-FT
//! convergence. Entirely rust-native (linalg substrate), seconds to run.
//!
//! Run: cargo run --release --example toy_mnist [-- --rank 4 --steps 80]

use pissa::coordinator::toy;
use pissa::metrics::write_csv;
use pissa::util::cli::Args;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rank = args.usize_or("rank", 4)?;
    let steps = args.usize_or("steps", 80)?;
    let seed = args.u64_or("seed", 7)?;

    println!("Figure 2a analog: odd-digit pretrain -> even-digit transfer (rank {rank})");
    let (lora, pissa, full) = toy::fig2a_protocol(32, rank, 120, steps, 0.5, seed);

    println!("{:>6} {:>10} {:>10} {:>10}", "step", "lora", "pissa", "full-ft");
    for i in (0..steps).step_by((steps / 16).max(1)) {
        println!("{:>6} {:>10.4} {:>10.4} {:>10.4}", i + 1, lora[i], pissa[i], full[i]);
    }
    let out = PathBuf::from("results/fig2a_toy.csv");
    let rows: Vec<Vec<f64>> = (0..steps)
        .map(|i| vec![(i + 1) as f64, lora[i], pissa[i], full[i]])
        .collect();
    write_csv(&out, &["step", "lora_loss", "pissa_loss", "full_ft_loss"], &rows)?;
    println!("\nwrote {}", out.display());
    println!(
        "final: lora {:.4}, pissa {:.4}, full {:.4} — pissa finds the descent direction sooner ✓",
        lora[steps - 1],
        pissa[steps - 1],
        full[steps - 1]
    );
    Ok(())
}
