//! Quickstart: the whole PiSSA story in one minute on the `tiny` model.
//!
//!   1. pre-train a base model (so weights have a realistic spectrum)
//!   2. initialize PiSSA vs LoRA adapters (Eq. 2–4) — both preserve the
//!      model exactly at step 0
//!   3. fine-tune both on synthetic math under identical budgets
//!   4. show PiSSA's faster convergence + the QPiSSA quantization-error win
//!
//! Run: cargo run --release --example quickstart

use anyhow::Result;
use pissa::adapter::{init, AdapterSpec};
use pissa::coordinator::{self, RunConfig};
use pissa::linalg::matmul;
use pissa::quant;
use pissa::runtime::{Manifest, Runtime};
use pissa::util::rng::Rng;
use std::path::PathBuf;

fn main() -> Result<()> {
    let art = PathBuf::from("artifacts");
    let manifest = Manifest::load(&art)?;
    let rt = Runtime::cpu(&art)?;

    println!("== 1. pre-train a tiny base model (full-FT artifact) ==");
    let (base, hist) = coordinator::pretrain(&rt, &manifest, "tiny", 120, 2e-3, 42)?;
    println!(
        "   loss {:.3} -> {:.3} over {} steps\n",
        hist[0].loss,
        hist.last().unwrap().loss,
        hist.len()
    );

    println!("== 2. initialize adapters on layer-0 q_proj ==");
    let w = base.linears["base_q"].layer(0);
    let mut rng = Rng::new(7);
    let p = init::pissa(&w, 4, None, &mut rng);
    let l = init::lora(&w, 4, &mut rng);
    println!("   ‖W‖F = {:.3}", w.fro());
    println!(
        "   PiSSA:  ‖AB‖F = {:.3} (principal mass), ‖W−(res+AB)‖F = {:.2e}",
        matmul(&p.a, &p.b).fro(),
        p.effective().sub(&w).fro()
    );
    println!(
        "   LoRA:   ‖AB‖F = {:.3} (zero init),      ‖W−(W+AB)‖F = {:.2e}\n",
        matmul(&l.a, &l.b).fro(),
        l.effective().sub(&w).fro()
    );

    println!("== 3. fine-tune on synthetic math (identical budgets) ==");
    let mut results = Vec::new();
    for spec in [AdapterSpec::pissa(4), AdapterSpec::lora(4)] {
        let run = RunConfig { steps: 80, ..RunConfig::quick("tiny", spec.clone()) };
        let r = coordinator::finetune(&rt, &manifest, &base, &run)?;
        println!(
            "   {:8} params={}  loss {:.4} -> {:.4}",
            spec.name(),
            r.trainable_params,
            r.history[0].loss,
            r.final_loss(8)
        );
        results.push((spec.name(), r.final_loss(8)));
    }
    println!(
        "   => PiSSA converges {} (paper Fig. 2a/4)\n",
        if results[0].1 < results[1].1 { "faster ✓" } else { "slower ✗ (tiny-scale noise)" }
    );

    println!("== 4. QPiSSA quantization-error reduction (Eq. 6–8) ==");
    let baseline = quant::qlora_error(&w);
    let qp = init::qpissa(&w, 4, 5, &mut rng);
    let e_qp = pissa::linalg::nuclear_norm(&w.sub(&qp.base.add(&matmul(&qp.a, &qp.b))));
    println!("   QLoRA error  ‖W−nf4(W)‖* = {baseline:.3}");
    println!(
        "   QPiSSA error ‖W−(nf4(Wres)+AB)‖* = {e_qp:.3}  (−{:.1}%)",
        (1.0 - e_qp / baseline) * 100.0
    );
    Ok(())
}
