//! FIGURE 5 — quantized-training convergence: (Q)LoRA vs (Q)PiSSA vs
//! LoftQ vs full-FT loss/grad-norm/accuracy. Paper: LLaMA-3-8B on
//! MetaMathQA-395K. Here: pre-trained base + all six strategies under
//! identical budgets.
//!
//! Expected shape: QPiSSA ≈ PiSSA ≫ {LoRA, QLoRA, LoftQ} in early loss
//! drop; QPiSSA's accuracy ≥ full-precision LoRA.

mod common;

use pissa::adapter::AdapterSpec;
use pissa::coordinator::{self, RunConfig, TaskFamily};
use pissa::metrics::write_labeled_csv;

fn main() -> anyhow::Result<()> {
    common::banner("Figure 5", "(Q)LoRA vs (Q)PiSSA vs LoftQ convergence");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();
    let config = if full { "small" } else { "tiny" };
    let steps = if full { 300 } else { 120 };

    let (base, _) =
        coordinator::pretrain(&rt, &manifest, config, if full { 300 } else { 150 }, 2e-3, 42)?;

    let specs = [
        AdapterSpec::lora(4),
        AdapterSpec::qlora(4),
        AdapterSpec::pissa(4),
        AdapterSpec::qpissa(4).iters(5),
        AdapterSpec::loftq(4).iters(5),
        AdapterSpec::full_ft(),
    ];
    let mut rows = Vec::new();
    let mut summary = Vec::new();
    for spec in specs {
        let run = RunConfig {
            config: config.to_string(),
            spec: spec.clone(),
            steps,
            peak_lr: if spec.is_full_ft() { 5e-4 } else { 2e-3 },
            corpus_size: 1024,
            seed: 42,
            task: TaskFamily::Math,
        };
        let r = coordinator::finetune(&rt, &manifest, &base, &run)?;
        let acc = coordinator::evaluate(&rt, &manifest, &run, &r.final_state, 32, 40)?;
        let early = r.history[steps / 10].loss;
        let gnorm = r.history.iter().map(|m| m.grad_norm as f64).sum::<f64>() / steps as f64;
        println!(
            "{:8}: loss@10% {early:.4}, final {:.4}, mean gnorm {gnorm:.4}, acc {acc:>6.2}%",
            spec.name(),
            r.final_loss(10)
        );
        for m in r.history.iter().step_by((steps / 40).max(1)) {
            rows.push((format!("{}/{}", spec.name(), m.step), vec![m.loss as f64, m.grad_norm as f64]));
        }
        summary.push((spec.name(), early, r.final_loss(10), acc));
    }

    let get = |s: &str| summary.iter().find(|x| x.0 == s).unwrap();
    println!("\nshape checks (paper Fig 5):");
    println!(
        "  QPiSSA early-loss < QLoRA early-loss: {} ({:.4} vs {:.4})",
        get("qpissa").1 < get("qlora").1,
        get("qpissa").1,
        get("qlora").1
    );
    println!(
        "  QPiSSA final < LoftQ final:           {} ({:.4} vs {:.4})",
        get("qpissa").2 < get("loftq").2,
        get("qpissa").2,
        get("loftq").2
    );
    println!(
        "  LoftQ ≈ QLoRA convergence (not faster): Δ = {:+.4}",
        get("loftq").2 - get("qlora").2
    );
    write_labeled_csv(
        &common::results_dir().join("fig5_quant_curves.csv"),
        &["strategy_step", "loss", "grad_norm"],
        &rows,
    )?;
    println!("wrote results/fig5_quant_curves.csv");
    Ok(())
}
