//! TABLES 3 & 6 + FIGURE 2b + FIGURE 13 — quantization-error reduction
//! ratio of {QLoRA, LoftQ, QPiSSA} per linear-layer type, across ranks
//! and alternation counts T ∈ {1, 5}. Paper scale: LLaMA-2-7B/3-8B/3-70B
//! at ranks 64/128; here: a pre-trained `small` base (d=128) at scaled
//! ranks, same r/dim ratios.
//!
//! Expected shape: QLoRA ≡ 0; QPiSSA > LoftQ at every (layer, rank, T);
//! both grow with rank and with T (Table 6); ratios biggest for the
//! most anisotropic projections (paper: K/Q).

mod common;

use pissa::adapter::init::{loftq, qpissa};
use pissa::coordinator;
use pissa::linalg::{matmul, nuclear_norm};
use pissa::metrics::write_labeled_csv;
use pissa::quant::qlora_error;
use pissa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    common::banner("Tables 3/6 + Fig 2b/13", "quantization-error reduction ratios");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();
    let config = if full { "small" } else { "tiny" };
    let ranks: &[usize] = if full { &[2, 4, 8, 16, 32] } else { &[2, 4, 8] };
    let iters: &[usize] = &[1, 5];

    println!("[t3] pre-training {config} base…");
    let (base, _) = coordinator::pretrain(&rt, &manifest, config, if full { 300 } else { 150 }, 2e-3, 42)?;
    let mut rng = Rng::new(13);

    println!(
        "\n{:6} {:>4} {:>3} | {:>6} {:>7} {:>7}",
        "layer", "rank", "T", "QLoRA", "LoftQ", "QPiSSA"
    );
    let mut rows = Vec::new();
    let mut qpissa_beats_loftq = 0usize;
    let mut cells = 0usize;
    for name in pissa::model::LINEARS {
        let w = base.linears[&format!("base_{name}")].layer(0);
        let baseline = qlora_error(&w);
        for &r in ranks {
            for &t in iters {
                let lq = loftq(&w, r, t, &mut rng);
                let e_lq = nuclear_norm(&w.sub(&lq.base.add(&matmul(&lq.a, &lq.b))));
                let qp = qpissa(&w, r, t, &mut rng);
                let e_qp = nuclear_norm(&w.sub(&qp.base.add(&matmul(&qp.a, &qp.b))));
                let ratio_lq = (1.0 - e_lq / baseline) * 100.0;
                let ratio_qp = (1.0 - e_qp / baseline) * 100.0;
                println!(
                    "{name:6} {r:>4} {t:>3} | {:>6.1} {ratio_lq:>7.1} {ratio_qp:>7.1}",
                    0.0
                );
                rows.push((format!("{name}/r{r}/T{t}"), vec![0.0, ratio_lq, ratio_qp]));
                cells += 1;
                if ratio_qp >= ratio_lq - 1e-9 {
                    qpissa_beats_loftq += 1;
                }
            }
        }
    }
    write_labeled_csv(
        &common::results_dir().join("table3_quant_error.csv"),
        &["layer_rank_T", "qlora_ratio", "loftq_ratio", "qpissa_ratio"],
        &rows,
    )?;

    println!("\nshape check: QPiSSA ≥ LoftQ on {qpissa_beats_loftq}/{cells} cells (paper: all)");
    // Figure 2b: per-layer absolute errors at the largest rank, T=5.
    println!("\nFig 2b — absolute nuclear-norm error per layer (rank {}, T=5):", ranks.last().unwrap());
    let mut bar_rows = Vec::new();
    for name in pissa::model::LINEARS {
        let w = base.linears[&format!("base_{name}")].layer(0);
        let baseline = qlora_error(&w);
        let r = *ranks.last().unwrap();
        let lq = loftq(&w, r, 5, &mut rng);
        let e_lq = nuclear_norm(&w.sub(&lq.base.add(&matmul(&lq.a, &lq.b))));
        let qp = qpissa(&w, r, 5, &mut rng);
        let e_qp = nuclear_norm(&w.sub(&qp.base.add(&matmul(&qp.a, &qp.b))));
        println!("  {name:6}: qlora {baseline:>7.3}  loftq {e_lq:>7.3}  qpissa {e_qp:>7.3}");
        bar_rows.push((name.to_string(), vec![baseline, e_lq, e_qp]));
    }
    write_labeled_csv(
        &common::results_dir().join("fig2b_error_bars.csv"),
        &["layer", "qlora_err", "loftq_err", "qpissa_err"],
        &bar_rows,
    )?;
    println!("\nwrote results/table3_quant_error.csv, results/fig2b_error_bars.csv");
    Ok(())
}
