//! FIGURE 2a — the toy convergence comparison: 2-layer MLP pre-trained on
//! odd digits, fine-tuned on even digits; LoRA vs PiSSA vs full-FT loss
//! curves. Expected shape: PiSSA drops fast immediately (like full-FT);
//! LoRA idles near its init for many steps (B = 0 ⇒ dL/dA = 0 at start).

mod common;

use pissa::coordinator::toy::fig2a_protocol;
use pissa::metrics::write_csv;

fn main() -> anyhow::Result<()> {
    common::banner("Figure 2a", "toy MNIST-analog: LoRA vs PiSSA convergence");
    let full = common::full_mode();
    let steps = if full { 200 } else { 80 };
    let seeds = if full { vec![7u64, 17, 27] } else { vec![7u64] };

    let mut agg: Vec<Vec<f64>> = Vec::new();
    for &seed in &seeds {
        let (lora, pissa, fullft) = fig2a_protocol(32, 4, 120, steps, 0.5, seed);
        if agg.is_empty() {
            agg = (0..steps).map(|i| vec![(i + 1) as f64, 0.0, 0.0, 0.0]).collect();
        }
        for i in 0..steps {
            agg[i][1] += lora[i] / seeds.len() as f64;
            agg[i][2] += pissa[i] / seeds.len() as f64;
            agg[i][3] += fullft[i] / seeds.len() as f64;
        }
    }

    println!("{:>6} {:>10} {:>10} {:>10}", "step", "lora", "pissa", "full-ft");
    for row in agg.iter().step_by((steps / 16).max(1)) {
        println!("{:>6} {:>10.4} {:>10.4} {:>10.4}", row[0], row[1], row[2], row[3]);
    }
    let (l_end, p_end, f_end) = (agg[steps - 1][1], agg[steps - 1][2], agg[steps - 1][3]);
    println!("\nshape checks:");
    println!("  PiSSA final < LoRA final: {} ({p_end:.4} vs {l_end:.4})", p_end < l_end);
    // "finds the right direction more quickly": loss at 25% of budget
    let q = steps / 4;
    println!(
        "  PiSSA@{q} < LoRA@{q}:        {} ({:.4} vs {:.4})",
        agg[q][2] < agg[q][1],
        agg[q][2],
        agg[q][1]
    );
    println!("  full-FT ≲ PiSSA ≤ LoRA:   {f_end:.4} ≲ {p_end:.4} ≤ {l_end:.4}");
    write_csv(
        &common::results_dir().join("fig2a_curves.csv"),
        &["step", "lora_loss", "pissa_loss", "full_ft_loss"],
        &agg,
    )?;
    println!("wrote results/fig2a_curves.csv");
    Ok(())
}
