//! FIGURES 3, 9, 10 — spectrum/distribution analysis of W vs W_res:
//!   3a/3b: singular values of W and W_res (descending)
//!   3c/3f: value histograms + Gaussian fits (std shrinks for W_res)
//!   3d/3e (+9): singular values of the error matrices W−nf4(W) vs
//!               W_res−nf4(W_res)
//!   10:    Student-t fits — W_res fits a higher-ν (more Gaussian) t
//!
//! Expected shape: removing the top-r components narrows the value
//! distribution and lowers the quantization error spectrum — §4's whole
//! argument for QPiSSA.

mod common;

use pissa::adapter::init::pissa;
use pissa::coordinator;
use pissa::linalg::norms::{fit_student_t, value_histogram};
use pissa::linalg::singular_values;
use pissa::metrics::write_csv;
use pissa::quant::nf4_roundtrip;
use pissa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    common::banner("Figures 3/9/10", "singular spectra + value distributions of W vs W_res");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();
    let config = if full { "small" } else { "tiny" };
    let rank = if full { 16 } else { 8 };

    let (base, _) = coordinator::pretrain(&rt, &manifest, config, if full { 300 } else { 150 }, 2e-3, 42)?;
    let w = base.linears["base_q"].layer(0); // the paper's layers[0].self_attn.q_proj
    let mut rng = Rng::new(3);
    let init = pissa(&w, rank, None, &mut rng);
    let w_res = &init.base;

    // (a)/(b) singular values
    let s_w = singular_values(&w);
    let s_res = singular_values(w_res);
    println!("\n(3a/3b) singular values (top 12):");
    println!("  W    : {:?}", &s_w[..12.min(s_w.len())].iter().map(|x| (x * 1000.0).round() / 1000.0).collect::<Vec<_>>());
    println!("  W_res: {:?}", &s_res[..12.min(s_res.len())].iter().map(|x| (x * 1000.0).round() / 1000.0).collect::<Vec<_>>());
    println!(
        "  shape check: σ₁(W_res) ≈ σ_{{r+1}}(W): {:.4} vs {:.4} {}",
        s_res[0],
        s_w[rank],
        if (s_res[0] - s_w[rank]).abs() < 0.05 * s_w[rank] { "✓" } else { "✗" }
    );

    // (d)/(e) error-matrix singular values
    let err_w = w.sub(&nf4_roundtrip(&w));
    let err_res = w_res.sub(&nf4_roundtrip(w_res));
    let s_err_w = singular_values(&err_w);
    let s_err_res = singular_values(&err_res);
    let nuc = |s: &[f32]| s.iter().map(|&x| x as f64).sum::<f64>();
    println!("\n(3d/3e) quantization-error nuclear norms:");
    println!("  ‖W − nf4(W)‖*         = {:.4}", nuc(&s_err_w));
    println!(
        "  ‖W_res − nf4(W_res)‖* = {:.4}  ({:.1}% lower) {}",
        nuc(&s_err_res),
        (1.0 - nuc(&s_err_res) / nuc(&s_err_w)) * 100.0,
        if nuc(&s_err_res) < nuc(&s_err_w) { "✓" } else { "✗" }
    );

    // (c)/(f) value distributions
    let (_, stdw) = w.mean_std();
    let (_, stdr) = w_res.mean_std();
    println!("\n(3c/3f) value distributions:");
    println!("  std(W) = {stdw:.5}, std(W_res) = {stdr:.5}  (narrower: {})", stdr < stdw);
    let lim = 3.0 * stdw as f32;
    let (centers, hw) = value_histogram(&w, -lim, lim, 41);
    let (_, hr) = value_histogram(w_res, -lim, lim, 41);
    let rows: Vec<Vec<f64>> = centers
        .iter()
        .zip(hw.iter().zip(&hr))
        .map(|(c, (a, b))| vec![*c as f64, *a as f64, *b as f64])
        .collect();
    write_csv(&common::results_dir().join("fig3_value_hist.csv"), &["center", "W_count", "Wres_count"], &rows)?;

    // Fig 10: Student-t fits
    let (nu_w, sc_w) = fit_student_t(&w);
    let (nu_r, sc_r) = fit_student_t(w_res);
    println!("\n(Fig 10) Student-t fits:");
    println!("  W    : ν = {nu_w:.1}, scale = {sc_w:.5}");
    println!("  W_res: ν = {nu_r:.1}, scale = {sc_r:.5}");
    println!(
        "  shape check — W_res more Gaussian-like (higher ν): {}",
        if nu_r >= nu_w { "✓" } else { "✗ (scale-dependent at tiny dims)" }
    );

    // spectra CSV
    let n = s_w.len();
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|i| {
            vec![
                i as f64,
                s_w[i] as f64,
                s_res.get(i).copied().unwrap_or(0.0) as f64,
                s_err_w.get(i).copied().unwrap_or(0.0) as f64,
                s_err_res.get(i).copied().unwrap_or(0.0) as f64,
            ]
        })
        .collect();
    write_csv(
        &common::results_dir().join("fig3_spectra.csv"),
        &["i", "sigma_W", "sigma_Wres", "sigma_err_W", "sigma_err_Wres"],
        &rows,
    )?;
    println!("\nwrote results/fig3_spectra.csv, results/fig3_value_hist.csv");
    Ok(())
}
