//! FIGURE 8 / APPENDIX A — component-choice ablation: initialize adapters
//! from the PRINCIPAL vs MEDIUM vs MINOR singular-triplet windows and
//! compare training loss + accuracy. Paper: LLaMA-2/Mistral/Gemma on
//! MetaMathQA; here: pre-trained bases, same protocol.
//!
//! Expected shape: principal < medium < minor in loss; principal wins
//! accuracy on every model.

mod common;

use pissa::adapter::init::Window;
use pissa::adapter::AdapterSpec;
use pissa::coordinator::{self, LrSchedule, RunConfig, TaskFamily, Trainer};
use pissa::metrics::write_labeled_csv;
use pissa::model::apply_spec;
use pissa::runtime::Manifest;
use pissa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    common::banner("Figure 8 / App. A", "principal vs medium vs minor component init");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();
    let config = if full { "small" } else { "tiny" };
    let steps = if full { 200 } else { 100 };
    let rank = 4;
    let models: &[(&str, u64)] =
        if full { &[("m1", 42), ("m2", 1337), ("m3", 2024)] } else { &[("m1", 42)] };

    let mut rows = Vec::new();
    for (mname, seed) in models {
        let (base, _) =
            coordinator::pretrain(&rt, &manifest, config, if full { 300 } else { 150 }, 2e-3, *seed)?;
        let cfg = manifest.config(config)?.clone();
        let mut results = Vec::new();
        for (wname, window) in
            [("principal", Window::Principal), ("medium", Window::Medium), ("minor", Window::Minor)]
        {
            // One declarative spec per window — no manual state patching:
            // exact SVD (the ablation's protocol) over the chosen window.
            let spec = AdapterSpec::pissa(rank).exact_svd().window(window).iters(1);
            let mut rng = Rng::new(*seed);
            let state = apply_spec(&base, &spec, &mut rng)?;
            let art = Manifest::train_name(config, rank, false);
            let mut trainer =
                Trainer::new(&rt, &manifest, &art, state, LrSchedule::alpaca(2e-3, steps))?;
            let level = coordinator::experiment::level_for_seq(cfg.seq_len);
            let corpus = TaskFamily::Math.corpus(1024, seed ^ 0xDA7A, level);
            let mut batcher =
                pissa::data::Batcher::new(corpus, cfg.batch, cfg.seq_len, seed ^ 0x5EED);
            for _ in 0..steps {
                trainer.step(&batcher.next_batch())?;
            }
            let fl = trainer.recent_loss(10);
            // score
            let run = RunConfig {
                config: config.to_string(),
                spec: spec.clone(),
                steps,
                peak_lr: 2e-3,
                corpus_size: 1024,
                seed: *seed,
                task: TaskFamily::Math,
            };
            let acc = coordinator::evaluate(&rt, &manifest, &run, &trainer.state, 32, 40)?;
            println!("{mname} {wname:9}: final loss {fl:.4}, acc {acc:>6.2}%");
            results.push((wname, fl, acc));
            rows.push((format!("{mname}/{wname}"), vec![fl as f64, acc]));
        }
        let by = |w: &str| results.iter().find(|x| x.0 == w).unwrap();
        println!(
            "  shape: principal ≤ medium ≤ minor in loss: {}",
            by("principal").1 <= by("medium").1 && by("medium").1 <= by("minor").1 * 1.05
        );
    }
    write_labeled_csv(
        &common::results_dir().join("fig8_components.csv"),
        &["model_window", "final_loss", "accuracy"],
        &rows,
    )?;
    println!("wrote results/fig8_components.csv");
    Ok(())
}
