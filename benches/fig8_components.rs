//! FIGURE 8 / APPENDIX A — component-choice ablation: initialize adapters
//! from the PRINCIPAL vs MEDIUM vs MINOR singular-triplet windows and
//! compare training loss + accuracy. Paper: LLaMA-2/Mistral/Gemma on
//! MetaMathQA; here: pre-trained bases, same protocol.
//!
//! Expected shape: principal < medium < minor in loss; principal wins
//! accuracy on every model.

mod common;

use pissa::adapter::init::{pissa_window, Strategy, Window};
use pissa::coordinator::{self, LrSchedule, RunConfig, TaskFamily, Trainer};
use pissa::metrics::write_labeled_csv;
use pissa::model::{apply_strategy, Tensor};
use pissa::runtime::Manifest;
use pissa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    common::banner("Figure 8 / App. A", "principal vs medium vs minor component init");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();
    let config = if full { "small" } else { "tiny" };
    let steps = if full { 200 } else { 100 };
    let rank = 4;
    let models: &[(&str, u64)] =
        if full { &[("m1", 42), ("m2", 1337), ("m3", 2024)] } else { &[("m1", 42)] };

    let mut rows = Vec::new();
    for (mname, seed) in models {
        let (base, _) =
            coordinator::pretrain(&rt, &manifest, config, if full { 300 } else { 150 }, 2e-3, *seed)?;
        let cfg = manifest.config(config)?.clone();
        let mut results = Vec::new();
        for (wname, window) in
            [("principal", Window::Principal), ("medium", Window::Medium), ("minor", Window::Minor)]
        {
            // Build the state with the window init.
            let mut rng = Rng::new(*seed);
            let mut state = apply_strategy(&base, Strategy::Pissa, rank, 1, &mut rng)?;
            for name in pissa::model::LINEARS {
                let stacked = &base.linears[&format!("base_{name}")];
                let mut bases = Vec::new();
                let mut aas = Vec::new();
                let mut bbs = Vec::new();
                for l in 0..stacked.shape[0] {
                    let init = pissa_window(&stacked.layer(l), rank, window);
                    bases.push(init.base);
                    aas.push(init.a);
                    bbs.push(init.b);
                }
                state.frozen.insert(format!("base_{name}"), Tensor::stack(&bases));
                state.trainable.insert(format!("a_{name}"), Tensor::stack(&aas));
                state.trainable.insert(format!("b_{name}"), Tensor::stack(&bbs));
            }
            let art = Manifest::train_name(config, rank, false);
            let mut trainer =
                Trainer::new(&rt, &manifest, &art, state, LrSchedule::alpaca(2e-3, steps))?;
            let level = coordinator::experiment::level_for_seq(cfg.seq_len);
            let corpus = TaskFamily::Math.corpus(1024, seed ^ 0xDA7A, level);
            let mut batcher =
                pissa::data::Batcher::new(corpus, cfg.batch, cfg.seq_len, seed ^ 0x5EED);
            for _ in 0..steps {
                trainer.step(&batcher.next_batch())?;
            }
            let fl = trainer.recent_loss(10);
            // score
            let run = RunConfig {
                config: config.to_string(),
                strategy: Strategy::Pissa,
                rank,
                iters: 1,
                steps,
                peak_lr: 2e-3,
                corpus_size: 1024,
                seed: *seed,
                task: TaskFamily::Math,
            };
            let acc = coordinator::evaluate(&rt, &manifest, &run, &trainer.state, 32, 40)?;
            println!("{mname} {wname:9}: final loss {fl:.4}, acc {acc:>6.2}%");
            results.push((wname, fl, acc));
            rows.push((format!("{mname}/{wname}"), vec![fl as f64, acc]));
        }
        let by = |w: &str| results.iter().find(|x| x.0 == w).unwrap();
        println!(
            "  shape: principal ≤ medium ≤ minor in loss: {}",
            by("principal").1 <= by("medium").1 && by("medium").1 <= by("minor").1 * 1.05
        );
    }
    write_labeled_csv(
        &common::results_dir().join("fig8_components.csv"),
        &["model_window", "final_loss", "accuracy"],
        &rows,
    )?;
    println!("wrote results/fig8_components.csv");
    Ok(())
}
