//! TABLE 4 — Fast SVD (Halko) vs exact SVD for PiSSA initialization:
//! init time, init error, and final training loss across rank × niter.
//! Paper scale: 4096-dim LLaMA matrices, niter ∈ {1,2,4,8,16,∞};
//! here: the pre-trained base's matrices (same niter grid, scaled ranks).
//!
//! Expected shape: Fast SVD is 10-100× faster; error falls with niter;
//! training loss of Fast-SVD init approaches exact-SVD init as niter grows.

mod common;

use pissa::adapter::init::pissa;
use pissa::adapter::AdapterSpec;
use pissa::coordinator::{self, RunConfig};
use pissa::linalg::matmul;
use pissa::metrics::write_labeled_csv;
use pissa::util::rng::Rng;
use pissa::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    common::banner("Table 4", "Fast SVD vs exact SVD: init time / error / final loss");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();
    let config = if full { "small" } else { "tiny" };
    let ranks: &[usize] = if full { &[1, 2, 4, 8, 16, 32] } else { &[2, 4, 8] };
    let niters: &[Option<usize>] =
        &[Some(1), Some(2), Some(4), Some(8), Some(16), None]; // None = exact ("∞")

    let (base, _) = coordinator::pretrain(&rt, &manifest, config, if full { 250 } else { 120 }, 2e-3, 42)?;
    let w = base.linears["base_q"].layer(0);

    println!("\ninit time (ms) and |SVD − FastSVD| factor error on q_proj:");
    println!("{:>6} {:>8} {:>12} {:>12}", "rank", "niter", "time_ms", "err");
    let mut rows = Vec::new();
    for &r in ranks {
        // exact reference factors
        let mut rng = Rng::new(5);
        let t_exact = Timer::start();
        let exact = pissa(&w, r, None, &mut rng);
        let exact_ms = t_exact.ms();
        let exact_ab = matmul(&exact.a, &exact.b);
        for &niter in niters {
            let mut rng = Rng::new(5);
            let t = Timer::start();
            let init = pissa(&w, r, niter, &mut rng);
            let ms = if niter.is_none() { exact_ms } else { t.ms() };
            // error = ‖AB_fast − AB_exact‖F (factor-product comparison is
            // basis-invariant, unlike the paper's raw |ΔA|+|ΔB| sum)
            let err = matmul(&init.a, &init.b).sub(&exact_ab).fro();
            let label = niter.map(|n| n.to_string()).unwrap_or_else(|| "∞".into());
            println!("{r:>6} {label:>8} {ms:>12.2} {err:>12.3e}");
            rows.push((format!("r{r}/niter{label}"), vec![ms, err]));
        }
    }

    // Final-loss comparison at one rank: train with each init quality.
    let r = ranks[ranks.len() / 2];
    println!("\nfinal fine-tune loss by init niter (rank {r}):");
    let mut loss_rows = Vec::new();
    for &niter in niters {
        // The niter knob is now first-class on the spec — no manual
        // state patching needed to control the init quality.
        let spec = match niter {
            Some(n) => AdapterSpec::pissa(r).niter(n),
            None => AdapterSpec::pissa(r).exact_svd(),
        };
        let run = RunConfig {
            steps: if full { 120 } else { 60 },
            ..RunConfig::quick(config, spec.clone())
        };
        let mut rng = Rng::new(run.seed);
        let state = pissa::model::apply_spec(&base, &spec, &mut rng)?;
        let cfg = manifest.config(config)?.clone();
        let sched = pissa::coordinator::LrSchedule::alpaca(run.peak_lr, run.steps);
        let art = pissa::runtime::Manifest::train_name(config, r, false);
        let mut trainer = pissa::coordinator::Trainer::new(&rt, &manifest, &art, state, sched)?;
        let corpus = run.task.corpus(
            run.corpus_size,
            run.seed ^ 0xDA7A,
            coordinator::experiment::level_for_seq(cfg.seq_len),
        );
        let mut batcher =
            pissa::data::Batcher::new(corpus, cfg.batch, cfg.seq_len, run.seed ^ 0x5EED);
        for _ in 0..run.steps {
            trainer.step(&batcher.next_batch())?;
        }
        let label = niter.map(|n| n.to_string()).unwrap_or_else(|| "∞".into());
        let fl = trainer.recent_loss(8);
        println!("  niter {label:>3}: final loss {fl:.4}");
        loss_rows.push((format!("niter{label}"), vec![fl as f64]));
    }
    write_labeled_csv(
        &common::results_dir().join("table4_fast_svd.csv"),
        &["rank_niter", "time_ms", "factor_err"],
        &rows,
    )?;
    write_labeled_csv(
        &common::results_dir().join("table4_final_loss.csv"),
        &["niter", "final_loss"],
        &loss_rows,
    )?;
    println!("\nwrote results/table4_fast_svd.csv, results/table4_final_loss.csv");
    Ok(())
}
