//! TABLE 5 — BF16 vs FP32 full fine-tuning. Paper: four 7-8B models on
//! MetaMathQA-395K; finding: precision matters but neither dominates.
//! Here: full-FT on the synthetic corpus in f32 vs simulated-bf16
//! (weights rounded to bf16 after every optimizer step — the storage
//! effect of bf16 training, while XLA computes in f32 like fused bf16
//! matmuls with f32 accumulation on real hardware).

mod common;

use pissa::adapter::AdapterSpec;
use pissa::coordinator::{LrSchedule, Trainer};
use pissa::data::Batcher;
use pissa::metrics::write_labeled_csv;
use pissa::model::{apply_spec, BaseModel};
use pissa::quant::bf16::bf16_round_inplace;
use pissa::runtime::Manifest;
use pissa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    common::banner("Table 5", "BF16 vs FP32 full fine-tuning");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();
    let config = "tiny";
    let steps = if full { 200 } else { 80 };

    // Two "models" (seeds); per model: f32 vs bf16-rounded training.
    let mut rows = Vec::new();
    for (mname, seed) in [("model-A", 42u64), ("model-B", 1337)] {
        let cfg = manifest.config(config)?.clone();
        let mut results = Vec::new();
        for bf16 in [false, true] {
            let mut rng = Rng::new(seed);
            let base = BaseModel::random(&cfg, &mut rng);
            let state = apply_spec(&base, &AdapterSpec::full_ft(), &mut rng)?;
            let art = Manifest::train_name(config, 0, true);
            let mut trainer =
                Trainer::new(&rt, &manifest, &art, state, LrSchedule::alpaca(1e-3, steps))?;
            let corpus = pissa::data::corpus::gen_corpus(1024, seed ^ 0xBA5E);
            let mut batcher = Batcher::new(corpus, cfg.batch, cfg.seq_len, seed ^ 0xF00D);
            for _ in 0..steps {
                trainer.step(&batcher.next_batch())?;
                if bf16 {
                    // simulate bf16 weight storage
                    for (_, t) in trainer.state.trainable.iter_mut() {
                        bf16_round_inplace(&mut t.data);
                    }
                }
            }
            let fl = trainer.recent_loss(8);
            println!("{mname} {}: final loss {fl:.4}", if bf16 { "bf16" } else { "fp32" });
            results.push(fl as f64);
        }
        println!(
            "  Δ(bf16−fp32) = {:+.4}  (paper: sign varies by model — no clear winner)",
            results[1] - results[0]
        );
        rows.push((mname.to_string(), results));
    }
    write_labeled_csv(
        &common::results_dir().join("table5_precision.csv"),
        &["model", "fp32_loss", "bf16_loss"],
        &rows,
    )?;
    println!("\nwrote results/table5_precision.csv");
    Ok(())
}
