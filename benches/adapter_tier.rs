//! §Adapter tiering — serving more registered tenants than fit in RAM.
//!
//! The multi-tenant shape the PiSSA serving story implies at fleet
//! scale: far more registered adapters than the host can keep resident.
//! The residency tiers ([`TierManager`]) keep a byte-budgeted LRU hot
//! set in f32 (+ prepared Appendix-C deltas), spill evictees losslessly
//! to disk, and attach cold tenants on their first request at a step
//! boundary. This bench measures what that costs:
//!
//!   setup        N_TENANTS cold tenants registered over N_TEMPLATES
//!                saved adapter checkpoints; a budget admitting HOT_CAP
//!                hot adapters (HOT_CAP << N_TENANTS)
//!   steady       a WORKING_SET-tenant resident working set served
//!                closed-loop, once with the per-step residency hook and
//!                once without (the all-hot baseline). Target: the hook
//!                costs ≤ 5% decode throughput (ratio ≥ 0.95).
//!   churn        open-loop Zipf(ZIPF_S) traffic over ALL tenants: cold
//!                attaches on miss, LRU eviction past the budget.
//!                Reported: churn tokens/s vs steady-state, the
//!                attach-on-miss p95 (absolute, and normalized by the
//!                steady per-token time), and the max resident bytes
//!                seen at any step-boundary sample (must stay ≤ budget —
//!                hard-asserted at EVERY sample, not just the max).
//!
//! Two correctness probes guard the comparison: a demote→promote round
//! trip must serve trajectories bitwise identical to the same checkpoint
//! attached hot from the start (the Exact-policy eviction-invariance
//! contract), and a churn slice must be bit-identical under
//! PISSA_THREADS 1 vs 8. Quick mode (default) trims request counts,
//! never the tenant registry; PISSA_BENCH_FULL=1 for the full protocol.

mod common;

use pissa::adapter::{AdapterEngine, AdapterSpec, Tier, TierManager};
use pissa::metrics::write_labeled_csv;
use pissa::model::{BaseModel, LINEARS};
use pissa::runtime::ConfigInfo;
use pissa::serve::{
    argmax, drift_factors, DecodeRequest, DecodeScheduler, FinishedSeq, KvCache, ModelServer,
    SeqRequest, ServeConfig, ServeStrategy,
};
use pissa::util::json::{jnum, Json};
use pissa::util::par::with_parallelism;
use pissa::util::rng::Rng;
use pissa::util::timer::Timer;
use std::path::{Path, PathBuf};

const DIM: usize = 32;
const D_FF: usize = 48;
const VOCAB: usize = 48;
const LAYERS: usize = 2;
const RANK: usize = 4;
/// Registered tenants — the whole point is N_TENANTS >> HOT_CAP.
const N_TENANTS: usize = 1024;
/// Distinct saved checkpoints the tenants alias (fleet tenants are
/// near-duplicates; the tier machinery neither knows nor cares).
const N_TEMPLATES: usize = 8;
/// Hot adapters the byte budget admits.
const HOT_CAP: usize = 32;
const SLOTS: usize = 4;
const PROMPT_LEN: usize = 6;
const MAX_NEW: usize = 8;
const MAX_SEQ: usize = PROMPT_LEN + MAX_NEW;
/// Zipf exponent of the churn traffic (mild skew: a long miss tail).
const ZIPF_S: f64 = 1.1;
/// Steady-state resident working set (hot throughout that section).
const WORKING_SET: usize = 8;

fn serve_cfg() -> ServeConfig {
    ServeConfig::full_model()
        .strategy(ServeStrategy::Fused)
        .max_seq(MAX_SEQ)
        .slots(SLOTS)
}

/// Engine plus `N_TEMPLATES` saved (drifted) adapter checkpoints under
/// `dir/templates/`. The templates are detached after saving — tenants
/// reference the files, not engine state.
fn build_engine_and_templates(
    rng: &mut Rng,
    dir: &Path,
) -> anyhow::Result<(AdapterEngine, Vec<PathBuf>)> {
    let cfg = ConfigInfo {
        name: "adapter-tier-bench".into(),
        kind: "decoder".into(),
        vocab: VOCAB,
        d_model: DIM,
        n_layers: LAYERS,
        n_heads: 2,
        d_ff: D_FF,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![RANK],
    };
    let base = BaseModel::random(&cfg, rng);
    let mut engine = AdapterEngine::new(base);
    let mut paths = Vec::with_capacity(N_TEMPLATES);
    for t in 0..N_TEMPLATES {
        let name = format!("tmpl{t}");
        engine.attach(&name, AdapterSpec::pissa(RANK), rng)?;
        for module in LINEARS {
            drift_factors(&mut engine, &name, module, 0.05, rng)?;
        }
        let path = dir.join("templates").join(format!("{name}.ckpt"));
        engine.save(&name, &path)?;
        engine.detach(&name)?;
        paths.push(path);
    }
    Ok((engine, paths))
}

/// Cumulative-weight Zipf sampler over ranks 0..n (rank 0 hottest).
struct Zipf {
    cum: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Zipf {
        let mut cum = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 0..n {
            total += 1.0 / ((k + 1) as f64).powf(s);
            cum.push(total);
        }
        Zipf { cum }
    }

    fn sample(&self, u: f64) -> usize {
        let target = u * self.cum.last().copied().unwrap_or(1.0);
        match self.cum.binary_search_by(|c| c.partial_cmp(&target).expect("finite")) {
            Ok(i) | Err(i) => i.min(self.cum.len() - 1),
        }
    }
}

fn zipf_workload(names: &[String], n: usize, seed: u64) -> Vec<SeqRequest> {
    let zipf = Zipf::new(names.len(), ZIPF_S);
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let tenant = zipf.sample(rng.uniform());
            let plen = 3 + (rng.uniform() * (PROMPT_LEN - 3) as f64) as usize;
            let prompt: Vec<usize> =
                (0..plen).map(|_| (rng.uniform() * VOCAB as f64) as usize % VOCAB).collect();
            SeqRequest::new(&names[tenant], prompt, MAX_NEW)
        })
        .collect()
}

/// Round-robin traffic over the resident working set.
fn steady_workload(ws: &[String], n: usize) -> Vec<SeqRequest> {
    let mut rng = Rng::new(99);
    (0..n)
        .map(|i| {
            let prompt: Vec<usize> = (0..PROMPT_LEN)
                .map(|_| (rng.uniform() * VOCAB as f64) as usize % VOCAB)
                .collect();
            SeqRequest::new(&ws[i % ws.len()], prompt, MAX_NEW)
        })
        .collect()
}

/// One probe trajectory: prefill + MAX_NEW-1 decode steps, tokens and
/// every step's logits row (compared bitwise by the callers).
fn traj(
    server: &mut ModelServer,
    cache: &mut KvCache,
    adapter: &str,
    prompt: &[usize],
) -> anyhow::Result<(Vec<usize>, Vec<Vec<f32>>)> {
    let slot = cache.try_claim(prompt.len() + MAX_NEW)?.expect("probe slot is free");
    let mut tokens = prompt.to_vec();
    let mut logits_all = Vec::new();
    let l0 = server.prefill(cache, slot, Some(adapter), prompt)?;
    let mut next = argmax(&l0);
    tokens.push(next);
    logits_all.push(l0);
    for _ in 1..MAX_NEW {
        let req = DecodeRequest { slot, token: next, adapter: Some(adapter.to_string()) };
        let lm = server.decode_step(cache, &[req])?;
        let row = lm.row(0).to_vec();
        next = argmax(&row);
        tokens.push(next);
        logits_all.push(row);
    }
    cache.release(slot);
    Ok((tokens, logits_all))
}

/// Closed-loop serving of a RESIDENT working set: everything submitted
/// up front (the wanted set fits the budget), the per-step residency
/// hook optional — `hook = false` is the all-hot baseline leg.
fn run_steady(
    engine: &mut AdapterEngine,
    tiers: &mut TierManager,
    server: &mut ModelServer,
    cache: &mut KvCache,
    reqs: &[SeqRequest],
    hook: bool,
) -> anyhow::Result<(Vec<FinishedSeq>, f64)> {
    let mut sched = DecodeScheduler::new();
    for r in reqs {
        sched.submit(r.clone());
    }
    let t = Timer::start();
    let mut fin = Vec::new();
    while !sched.idle() {
        if hook {
            let wanted = sched.active_adapters();
            let failed = tiers.ensure_resident(engine, server, &wanted);
            anyhow::ensure!(failed.is_empty(), "steady promotion failed: {failed:?}");
            anyhow::ensure!(
                tiers.resident_bytes() <= tiers.budget_bytes(),
                "resident bytes over budget in steady state"
            );
        }
        fin.extend(sched.step(server, cache)?);
    }
    let wall = t.secs();
    fin.sort_by_key(|f| f.id);
    Ok((fin, wall))
}

/// Open-loop churn over the WHOLE tenant registry: arrivals throttled by
/// scheduler backpressure (so the wanted set tracks the live working
/// set, not the backlog), the residency hook before every step,
/// resident ≤ budget hard-asserted at every sample.
fn run_churn(
    engine: &mut AdapterEngine,
    tiers: &mut TierManager,
    server: &mut ModelServer,
    cache: &mut KvCache,
    reqs: &[SeqRequest],
) -> anyhow::Result<(Vec<FinishedSeq>, f64, usize)> {
    let mut sched = DecodeScheduler::new();
    let t = Timer::start();
    let mut fin = Vec::new();
    let mut max_resident = 0usize;
    let mut next = 0usize;
    while next < reqs.len() || !sched.idle() {
        while next < reqs.len() && sched.pending() < SLOTS {
            sched.submit(reqs[next].clone());
            next += 1;
        }
        let wanted = sched.active_adapters();
        let failed = tiers.ensure_resident(engine, server, &wanted);
        anyhow::ensure!(failed.is_empty(), "attach-on-miss failed: {failed:?}");
        anyhow::ensure!(
            tiers.resident_bytes() <= tiers.budget_bytes(),
            "resident bytes over budget mid-churn"
        );
        max_resident = max_resident.max(tiers.resident_bytes());
        fin.extend(sched.step(server, cache)?);
    }
    let wall = t.secs();
    fin.sort_by_key(|f| f.id);
    Ok((fin, wall, max_resident))
}

fn main() -> anyhow::Result<()> {
    common::banner(
        "§Adapter tiering",
        &format!(
            "{N_TENANTS} tenants over {N_TEMPLATES} checkpoints, budget = {HOT_CAP} hot — \
             d={DIM}, f={D_FF}, L={LAYERS}, rank {RANK}, {SLOTS} slots"
        ),
    );
    let n_steady = if common::full_mode() { 96 } else { 32 };
    let n_churn = if common::full_mode() { 384 } else { 96 };
    let dir = std::env::temp_dir().join(format!("pissa_bench_adapter_tier_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    let mut rng = Rng::new(41);
    eprintln!("[setup] engine + {N_TEMPLATES} saved template adapters…");
    let (mut engine, tmpl_paths) = build_engine_and_templates(&mut rng, &dir)?;

    // Per-tenant hot bytes (engine f32 tensors + prepared serve deltas),
    // measured on a throwaway attach — the budget unit.
    engine.attach("meas", AdapterSpec::pissa(RANK), &mut rng)?;
    let mut server = ModelServer::new(&engine, serve_cfg())?;
    let per_hot = engine.adapter_bytes("meas")? + server.adapter_delta_bytes("meas");
    server.remove_adapter("meas")?;
    engine.detach("meas")?;
    let mut cache = server.new_cache()?;

    let budget = HOT_CAP * per_hot;
    let mut tiers = TierManager::new(budget, dir.join("spill"));
    let names: Vec<String> = (0..N_TENANTS).map(|i| format!("t{i:04}")).collect();
    for (i, n) in names.iter().enumerate() {
        tiers.register_cold(n, &tmpl_paths[i % N_TEMPLATES])?;
    }
    eprintln!(
        "[setup] {N_TENANTS} cold tenants registered; budget {budget} B admits {HOT_CAP} hot \
         ({per_hot} B each)"
    );

    // Probe: Exact-policy eviction invariance through the serving path.
    // The same checkpoint attached hot from the start ("ref-hot") and as
    // a tiered tenant must serve bitwise-identical trajectories — before
    // AND after a forced demote→promote round trip.
    let prompt = vec![3usize, 17, 41, 8];
    engine.attach_saved("ref-hot", &tmpl_paths[0])?;
    server.add_adapter(&engine, "ref-hot")?;
    let want = traj(&mut server, &mut cache, "ref-hot", &prompt)?;
    let wanted = vec![names[0].clone()];
    let failed = tiers.ensure_resident(&mut engine, &mut server, &wanted);
    anyhow::ensure!(failed.is_empty(), "probe attach failed: {failed:?}");
    let before = traj(&mut server, &mut cache, &names[0], &prompt)?;
    tiers.demote(&mut engine, &mut server, &names[0])?;
    anyhow::ensure!(tiers.tier(&names[0]) == Some(Tier::Cold), "Exact demote spills to cold");
    let failed = tiers.ensure_resident(&mut engine, &mut server, &wanted);
    anyhow::ensure!(failed.is_empty(), "probe re-promotion failed: {failed:?}");
    let after = traj(&mut server, &mut cache, &names[0], &prompt)?;
    anyhow::ensure!(
        before == want && after == want,
        "demote→promote trajectory diverged from the all-hot reference"
    );
    server.remove_adapter("ref-hot")?;
    engine.detach("ref-hot")?;
    eprintln!("[probe] demote→promote trajectories bitwise == all-hot ✓");

    // Probe: a churn slice must be bit-identical under 1 vs 8 threads
    // (tier transitions happen at step boundaries; nothing about the
    // worker count may change what gets attached or decoded).
    let invariant = |threads: usize| -> anyhow::Result<Vec<Vec<usize>>> {
        with_parallelism(threads, || -> anyhow::Result<Vec<Vec<usize>>> {
            let tdir = dir.join(format!("tinv{threads}"));
            let mut rng = Rng::new(53);
            let (mut engine, paths) = build_engine_and_templates(&mut rng, &tdir)?;
            let mut server = ModelServer::new(&engine, serve_cfg())?;
            let mut cache = server.new_cache()?;
            // 12 resident adapters: comfortably above the worst-case live
            // wanted set (pending + running ≤ 2·SLOTS tenants — the hook
            // never evicts the wanted set, so the budget must admit it)
            // while still forcing evictions across the 32-tenant slice.
            let mut tiers = TierManager::new(12 * per_hot, tdir.join("spill"));
            let names: Vec<String> = (0..32).map(|i| format!("p{i:02}")).collect();
            for (i, n) in names.iter().enumerate() {
                tiers.register_cold(n, &paths[i % N_TEMPLATES])?;
            }
            let reqs = zipf_workload(&names, 24, 7);
            let (fin, _, _) = run_churn(&mut engine, &mut tiers, &mut server, &mut cache, &reqs)?;
            Ok(fin.into_iter().map(|f| f.tokens).collect())
        })
    };
    let (inv1, inv8) = (invariant(1)?, invariant(8)?);
    anyhow::ensure!(inv1 == inv8, "churn trajectories changed with thread count");
    eprintln!("[probe] churn trajectories identical under 1 vs 8 threads ✓");

    // §steady state: a resident working set, with and without the hook.
    let ws: Vec<String> = names[..WORKING_SET].to_vec();
    let failed = tiers.ensure_resident(&mut engine, &mut server, &ws);
    anyhow::ensure!(failed.is_empty(), "working-set promotion failed: {failed:?}");
    let steady = steady_workload(&ws, n_steady);
    eprintln!("[steady] {n_steady} requests over {WORKING_SET} resident tenants x {{all-hot, tiered}}…");
    let (fin_hot, wall_hot) =
        run_steady(&mut engine, &mut tiers, &mut server, &mut cache, &steady, false)?;
    let (fin_tiered, wall_tiered) =
        run_steady(&mut engine, &mut tiers, &mut server, &mut cache, &steady, true)?;
    for (a, b) in fin_hot.iter().zip(&fin_tiered) {
        anyhow::ensure!(
            a.tokens == b.tokens,
            "the residency hook changed a steady-state trajectory (seq {:?})",
            a.id
        );
    }
    let tokens_steady: usize = fin_tiered.iter().map(|f| f.generated().len()).sum();
    let rate_hot = tokens_steady as f64 / wall_hot.max(1e-12);
    let rate_tiered = tokens_steady as f64 / wall_tiered.max(1e-12);
    let resident_ratio = rate_tiered / rate_hot.max(1e-12);
    let resident_ok = resident_ratio >= 0.95;
    let token_s = wall_tiered / tokens_steady.max(1) as f64;
    println!(
        "\nsteady state: tiered {rate_tiered:.0} tok/s vs all-hot {rate_hot:.0} tok/s -> \
         {resident_ratio:.3}x (target >= 0.95x: {}); trajectories identical ✓",
        if resident_ok { "PASS" } else { "FAIL" },
    );

    // §churn: Zipf traffic over the whole registry under the budget.
    let churn = zipf_workload(&names, n_churn, 11);
    let distinct = {
        let mut t: Vec<&str> = churn.iter().filter_map(|r| r.adapter.as_deref()).collect();
        t.sort();
        t.dedup();
        t.len()
    };
    eprintln!("[churn] {n_churn} open-loop Zipf(s={ZIPF_S}) requests over {distinct} distinct tenants…");
    let (fin_churn, wall_churn, max_resident) =
        run_churn(&mut engine, &mut tiers, &mut server, &mut cache, &churn)?;
    anyhow::ensure!(fin_churn.len() == n_churn, "churn lost sequences");
    let tokens_churn: usize = fin_churn.iter().map(|f| f.generated().len()).sum();
    let rate_churn = tokens_churn as f64 / wall_churn.max(1e-12);
    let churn_ratio = rate_churn / rate_tiered.max(1e-12);
    let attach_p95 = tiers.attach_p95_s();
    let attach_x_token = attach_p95 / token_s.max(1e-12);
    let resident_x_budget = max_resident as f64 / budget.max(1) as f64;
    let c = tiers.counters();
    anyhow::ensure!(c.cold_attaches > 0 && c.demotions > 0, "churn never churned: {c:?}");
    anyhow::ensure!(max_resident <= budget, "max resident over budget");
    println!(
        "churn: {rate_churn:.0} tok/s ({churn_ratio:.2}x steady), attach-on-miss p95 \
         {:.3} ms ({attach_x_token:.1}x a decoded token), max resident {max_resident} B \
         ({resident_x_budget:.3}x budget), {} attaches / {} demotions",
        attach_p95 * 1e3,
        c.cold_attaches,
        c.demotions,
    );

    let mut j = Json::obj();
    j.set("bench", Json::Str("adapter_tier".into()));
    j.set("tenants", jnum(N_TENANTS as f64));
    j.set("templates", jnum(N_TEMPLATES as f64));
    j.set("hot_cap", jnum(HOT_CAP as f64));
    j.set("budget_bytes", jnum(budget as f64));
    j.set("per_adapter_bytes", jnum(per_hot as f64));
    j.set("steady_requests", jnum(n_steady as f64));
    j.set("churn_requests", jnum(n_churn as f64));
    j.set("steady_tok_per_s_allhot", jnum(rate_hot));
    j.set("steady_tok_per_s_tiered", jnum(rate_tiered));
    j.set("resident_tok_s_x_allhot", jnum(resident_ratio));
    j.set("churn_tok_per_s", jnum(rate_churn));
    j.set("attach_miss_p95_s", jnum(attach_p95));
    j.set("attach_p95_x_token", jnum(attach_x_token));
    j.set("max_resident_bytes", jnum(max_resident as f64));
    j.set("max_resident_x_budget", jnum(resident_x_budget));
    j.set("cold_attaches", jnum(c.cold_attaches as f64));
    j.set("demotions", jnum(c.demotions as f64));
    j.set("promotions", jnum(c.promotions as f64));
    j.set("pass", Json::Bool(resident_ok));
    println!("BENCH {j}");

    common::write_bench_summary(
        "adapter_tier",
        &[
            ("resident_tok_s_x_allhot", resident_ratio),
            ("churn_tok_s_x_resident", churn_ratio),
            ("attach_p95_x_token", attach_x_token),
            ("max_resident_x_budget", resident_x_budget),
        ],
    )?;
    println!("overall: {}", if resident_ok { "PASS" } else { "FAIL" });

    let out = common::results_dir().join("adapter_tier.csv");
    write_labeled_csv(
        &out,
        &["section", "tok_per_s", "ratio", "attach_p95_ms", "resident_x_budget"],
        &[
            ("allhot".to_string(), vec![rate_hot, 1.0, 0.0, 0.0]),
            ("tiered".to_string(), vec![rate_tiered, resident_ratio, 0.0, 0.0]),
            (
                "churn".to_string(),
                vec![rate_churn, churn_ratio, attach_p95 * 1e3, resident_x_budget],
            ),
        ],
    )?;
    println!("(rows -> {}; methodology in EXPERIMENTS.md §Adapter tiering)", out.display());
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
