//! §Serving — batched multi-adapter serving throughput.
//!
//! The acceptance workload of the serving runtime (EXPERIMENTS.md
//! §Serving): a 768×768 base linear, 16 PiSSA rank-16 adapters drifted to
//! simulate training, mixed 64-request batches. Three execution
//! strategies over the SAME prepared `(W, ΔA, ΔB)` snapshot:
//!
//!   fused              shared X·W once + two skinny GEMMs per adapter
//!                      group (ΔW never materialized)
//!   dense-per-adapter  merge once per group, dense GEMM per group
//!   merge-per-request  merge for every request (the naive baseline)
//!
//! Emits one `BENCH {json}` line per strategy plus a speedup summary and
//! a CSV under results/. Target: fused ≥ 3× merge-per-request.
//!
//! Quick mode (default) trims batch count, not the workload shape; set
//! PISSA_BENCH_FULL=1 for more timed batches.

mod common;

use pissa::adapter::{AdapterEngine, AdapterSpec};
use pissa::metrics::write_labeled_csv;
use pissa::model::BaseModel;
use pissa::runtime::ConfigInfo;
use pissa::serve::{drift_factors, Request, ServeConfig, ServeStrategy, Server};
use pissa::util::json::{jnum, Json};
use pissa::util::rng::Rng;

const DIM: usize = 768;
const N_ADAPTERS: usize = 16;
const RANK: usize = 16;
const BATCH: usize = 64;
const MODULE: &str = "q";
const BASE_FRAC: f64 = 0.125;

fn workload(names: &[String], batches: usize, rng: &mut Rng) -> Vec<Vec<Request>> {
    (0..batches)
        .map(|_| {
            (0..BATCH)
                .map(|_| {
                    let mut x = vec![0.0f32; DIM];
                    rng.fill_normal(&mut x, 0.0, 1.0);
                    if rng.uniform() < BASE_FRAC {
                        Request::base(x)
                    } else {
                        Request::new(rng.choice(names), x)
                    }
                })
                .collect()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    common::banner(
        "§Serving",
        &format!(
            "fused low-rank vs merged serving — {DIM}x{DIM} base, {N_ADAPTERS} adapters, \
             rank {RANK}, batch {BATCH}"
        ),
    );
    let full = common::full_mode();
    let mut rng = Rng::new(11);

    let cfg = ConfigInfo {
        name: "serve-bench".into(),
        kind: "decoder".into(),
        vocab: 64,
        d_model: DIM,
        n_layers: 1,
        n_heads: 2,
        d_ff: 64,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![RANK],
    };
    eprintln!("[setup] base model + {N_ADAPTERS} pissa:rank={RANK} adapters (SVD init)…");
    let base = BaseModel::random(&cfg, &mut rng);
    let mut engine = AdapterEngine::new(base);
    let names: Vec<String> = (0..N_ADAPTERS).map(|i| format!("tenant{i:02}")).collect();
    for name in &names {
        engine.attach(name, AdapterSpec::pissa(RANK).targets(&[MODULE]), &mut rng)?;
        drift_factors(&mut engine, name, MODULE, 0.05, &mut rng)?;
    }

    println!(
        "\n{:20} {:>10} {:>10} {:>10} {:>12}",
        "strategy", "p50 ms", "p95 ms", "req/s", "vs merge"
    );
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    let mut req_per_s = std::collections::BTreeMap::new();
    // Baseline first so the speedup column fills as strategies complete.
    let order =
        [ServeStrategy::MergePerRequest, ServeStrategy::DensePerAdapter, ServeStrategy::Fused];
    for strategy in order {
        // merge-per-request does a dense merge per request — keep its
        // batch count low; the timed quantity is per-request throughput.
        let timed = match (strategy, full) {
            (ServeStrategy::MergePerRequest, true) => 6,
            (ServeStrategy::MergePerRequest, false) => 2,
            (_, true) => 40,
            (_, false) => 12,
        };
        let serve_cfg = ServeConfig::new(MODULE).strategy(strategy).max_batch(BATCH);
        let mut server = Server::new(&engine, serve_cfg)?;
        let mut wl_rng = Rng::new(77); // identical request stream per strategy
        let all = workload(&names, timed + 1, &mut wl_rng);
        server.forward(&all[0])?; // warmup (page in the snapshot)
        server.reset_stats();
        for batch in &all[1..] {
            server.forward(batch)?;
        }
        let s = server.stats().summary();
        req_per_s.insert(strategy.name(), s.req_per_s);
        let baseline = req_per_s.get("merge-per-request").copied();
        println!(
            "{:20} {:>10.3} {:>10.3} {:>10.0} {:>12}",
            strategy.name(),
            s.p50_s * 1e3,
            s.p95_s * 1e3,
            s.req_per_s,
            match (strategy, baseline) {
                (ServeStrategy::MergePerRequest, _) => "1.0x".to_string(),
                (_, Some(b)) if b > 0.0 => format!("{:.1}x", s.req_per_s / b),
                _ => "-".to_string(),
            },
        );
        let mut j = Json::obj();
        j.set("bench", Json::Str("serve_throughput".into()));
        j.set("strategy", Json::Str(strategy.name().into()));
        j.set("dim", jnum(DIM as f64));
        j.set("adapters", jnum(N_ADAPTERS as f64));
        j.set("rank", jnum(RANK as f64));
        j.set("batch", jnum(BATCH as f64));
        j.set("batches", jnum(s.batches as f64));
        j.set("p50_ms", jnum(s.p50_s * 1e3));
        j.set("p95_ms", jnum(s.p95_s * 1e3));
        j.set("req_per_s", jnum(s.req_per_s));
        j.set("mean_occupancy", jnum(s.mean_occupancy));
        j.set("mean_groups", jnum(s.mean_groups));
        println!("BENCH {j}");
        rows.push((
            strategy.name().to_string(),
            vec![s.p50_s * 1e3, s.p95_s * 1e3, s.req_per_s],
        ));
    }

    let fused = req_per_s["fused"];
    let merge = req_per_s["merge-per-request"];
    let speedup = if merge > 0.0 { fused / merge } else { f64::INFINITY };
    println!(
        "\nfused vs merge-per-request: {speedup:.1}x  (target >= 3x: {})",
        if speedup >= 3.0 { "PASS" } else { "FAIL" }
    );
    let mut j = Json::obj();
    j.set("bench", Json::Str("serve_throughput_summary".into()));
    j.set("fused_vs_merge_speedup", jnum(speedup));
    j.set("target", jnum(3.0));
    j.set("pass", Json::Bool(speedup >= 3.0));
    println!("BENCH {j}");

    let out = common::results_dir().join("serve_throughput.csv");
    write_labeled_csv(&out, &["strategy", "p50_ms", "p95_ms", "req_per_s"], &rows)?;
    println!("(rows -> {}; methodology in EXPERIMENTS.md §Serving)", out.display());
    Ok(())
}
