//! §HTTP serving — loopback load test over the streaming front-end.
//!
//! Sustains mixed-tenant concurrent traffic against a real `NetServer`
//! on a loopback port and asserts the three properties the front-end is
//! specified by:
//!
//!   1. fidelity — every streamed token trajectory is BIT-IDENTICAL to
//!      an in-process greedy decode of the same request on an
//!      identically seeded engine (HTTP adds transport, not arithmetic),
//!   2. admission — a throttled tenant draws typed 429s with Retry-After
//!      while in-budget tenants meet the TTFT p95 SLO,
//!   3. drain — a graceful drain finishes every running sequence with
//!      zero lost or truncated streams, then refuses new work with 503.
//!
//! Emits one `BENCH {json}` line per wave plus `http_serve_summary`, and
//! writes results/http_serve.json. Quick mode (default) trims client
//! count and generation length, not the shape; PISSA_BENCH_FULL=1 for
//! the full protocol.

mod common;

use pissa::adapter::{AdapterEngine, AdapterSpec};
use pissa::model::{BaseModel, LINEARS};
use pissa::net::{http, NetConfig, NetServer, StreamingClient, TenantPolicy};
use pissa::runtime::ConfigInfo;
use pissa::serve::{drift_factors, DecodeScheduler, ModelServer, SeqRequest, ServeConfig};
use pissa::util::json::{jarr, jnum, jstr, Json};
use pissa::util::rng::Rng;
use pissa::util::timer::{BenchStats, Timer};

const DIM: usize = 48;
const D_FF: usize = 96;
const LAYERS: usize = 2;
const VOCAB: usize = 48;
const N_ADAPTERS: usize = 5;
const RANK: usize = 4;
const SLOTS: usize = 8;
const MAX_SEQ: usize = 96;
const SEED: u64 = 4242;
/// The tenant pinned to a near-empty token bucket.
const THROTTLED: &str = "tenant04";
/// TTFT p95 SLO for in-budget tenants (generous: loopback CI boxes).
const TTFT_SLO_MS: f64 = 2000.0;

fn build_engine(seed: u64) -> anyhow::Result<AdapterEngine> {
    let cfg = ConfigInfo {
        name: "http-serve-bench".into(),
        kind: "decoder".into(),
        vocab: VOCAB,
        d_model: DIM,
        n_layers: LAYERS,
        n_heads: 2,
        d_ff: D_FF,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![RANK],
    };
    let mut rng = Rng::new(seed);
    let base = BaseModel::random(&cfg, &mut rng);
    let mut engine = AdapterEngine::new(base);
    for i in 0..N_ADAPTERS {
        let name = format!("tenant{i:02}");
        engine.attach(&name, AdapterSpec::pissa(RANK), &mut rng)?;
        for module in LINEARS {
            drift_factors(&mut engine, &name, module, 0.05, &mut rng)?;
        }
    }
    Ok(engine)
}

fn serve_cfg() -> ServeConfig {
    ServeConfig::full_model().max_seq(MAX_SEQ).slots(SLOTS)
}

/// Deterministic per-client request: tenant assignment rotates over four
/// in-budget adapters plus the base (five tenants of wire traffic).
fn client_request(i: usize) -> (Option<String>, Vec<usize>) {
    let adapter = match i % 5 {
        0 => Some("tenant00".to_string()),
        1 => Some("tenant01".to_string()),
        2 => Some("tenant02".to_string()),
        3 => Some("tenant03".to_string()),
        _ => None,
    };
    let prompt = vec![(i * 7 + 1) % VOCAB, (i * 3 + 2) % VOCAB, (i + 5) % VOCAB];
    (adapter, prompt)
}

fn gen_body(adapter: Option<&str>, prompt: &[usize], max_new: usize, stream: bool) -> Json {
    let mut o = Json::obj();
    o.set("adapter", adapter.map(jstr).unwrap_or(Json::Null));
    o.set("prompt", jarr(prompt.iter().map(|&t| jnum(t as f64))));
    o.set("max_new", jnum(max_new as f64));
    o.set("stream", Json::Bool(stream));
    o
}

struct ClientResult {
    idx: usize,
    ttft_s: f64,
    wall_s: f64,
    tokens: Vec<usize>,
    truncated: bool,
}

/// One streaming client: POST, time the first token line, collect the
/// whole trajectory, flag truncation (no done line).
fn run_stream_client(addr: &str, idx: usize, max_new: usize) -> anyhow::Result<ClientResult> {
    let (adapter, prompt) = client_request(idx);
    let body = gen_body(adapter.as_deref(), &prompt, max_new, true);
    let t = Timer::start();
    let mut c = StreamingClient::post(addr, "/v1/generate", &body)?;
    anyhow::ensure!(c.status == 200, "client {idx}: status {}", c.status);
    let mut ttft_s = f64::NAN;
    let mut tokens = Vec::new();
    let mut done = false;
    while let Some(chunk) = c.next_chunk()? {
        for line in String::from_utf8(chunk)?.lines().filter(|l| !l.is_empty()) {
            let j = Json::parse(line)?;
            if let Some(tok) = j.get("token").and_then(|v| v.as_f64()) {
                if tokens.is_empty() {
                    ttft_s = t.secs();
                }
                tokens.push(tok as usize);
            } else if j.get("done").is_some() {
                done = true;
            }
        }
    }
    Ok(ClientResult { idx, ttft_s, wall_s: t.secs(), tokens, truncated: !done })
}

fn main() -> anyhow::Result<()> {
    let full = common::full_mode();
    let n_clients: usize = if full { 64 } else { 32 };
    let n_throttled: usize = 8;
    let max_new: usize = if full { 16 } else { 8 };
    common::banner(
        "§HTTP serving",
        &format!(
            "loopback load test — {n_clients} concurrent clients over 5 tenants \
             (+{n_throttled} against a throttled one), d={DIM}, L={LAYERS}, \
             {SLOTS} slots, max_new {max_new}"
        ),
    );

    eprintln!("[setup] building {N_ADAPTERS}-tenant engine and starting the front-end…");
    let engine = build_engine(SEED)?;
    let net_cfg = NetConfig {
        workers: n_clients + n_throttled,
        accept_backlog: 2 * (n_clients + n_throttled),
        tenant_policies: vec![(
            THROTTLED.to_string(),
            TenantPolicy { rate_per_s: 1e-6, burst: 2.0, max_inflight: 64 },
        )],
        ..NetConfig::default()
    };
    let server = NetServer::start(&engine, serve_cfg(), net_cfg)?;
    let addr = server.addr().to_string();

    // In-process oracle: same seed, same engine, one sequential greedy
    // decode per request — the ground truth every stream must match.
    let oracle_engine = build_engine(SEED)?;
    let mut oracle_server = ModelServer::new(&oracle_engine, serve_cfg())?;
    let mut oracle_cache = oracle_server.new_cache()?;
    let mut oracle = |adapter: Option<String>, prompt: Vec<usize>| -> anyhow::Result<Vec<usize>> {
        let mut sched = DecodeScheduler::new();
        sched.submit(SeqRequest { adapter, prompt, max_new, stop_token: None });
        let fin = sched.run(&mut oracle_server, &mut oracle_cache)?;
        Ok(fin[0].generated().to_vec())
    };

    // ---- wave 1: mixed-tenant concurrent streaming + throttled burst --
    eprintln!("[wave 1] {n_clients} streaming + {n_throttled} throttled clients…");
    let wave = Timer::start();
    let mut stream_handles = Vec::new();
    for i in 0..n_clients {
        let addr = addr.clone();
        stream_handles.push(std::thread::spawn(move || run_stream_client(&addr, i, max_new)));
    }
    let mut throttle_handles = Vec::new();
    for i in 0..n_throttled {
        let addr = addr.clone();
        throttle_handles.push(std::thread::spawn(move || -> anyhow::Result<(u16, bool)> {
            let prompt = vec![(i + 1) % VOCAB, 2];
            let body = gen_body(Some(THROTTLED), &prompt, 2, false);
            let resp = http::request(&addr, "POST", "/v1/generate", Some(&body))?;
            let code = resp
                .json()
                .ok()
                .and_then(|j| {
                    let c = j.get("error").and_then(|e| e.get("code"))?;
                    c.as_str().map(|s| s.to_string())
                })
                .unwrap_or_default();
            let typed_429 = resp.status == 429
                && code == "rate_limited"
                && resp.header("retry-after").is_some();
            Ok((resp.status, typed_429))
        }));
    }

    let mut results = Vec::new();
    for h in stream_handles {
        results.push(h.join().expect("stream client thread")?);
    }
    let mut throttled_429 = 0usize;
    let mut throttled_ok = 0usize;
    for h in throttle_handles {
        let (status, typed) = h.join().expect("throttled client thread")?;
        match status {
            200 => throttled_ok += 1,
            429 => {
                anyhow::ensure!(typed, "429 without rate_limited code + Retry-After");
                throttled_429 += 1;
            }
            other => anyhow::bail!("throttled client: unexpected status {other}"),
        }
    }
    let wave_s = wave.secs();

    // Fidelity: every in-budget stream matches the oracle bit for bit.
    let mut trajectories_ok = true;
    for r in &results {
        let (adapter, prompt) = client_request(r.idx);
        let want = oracle(adapter, prompt)?;
        if r.tokens != want || r.truncated {
            trajectories_ok = false;
            let got = &r.tokens;
            eprintln!("[FAIL] client {}: stream {got:?} != oracle {want:?}", r.idx);
        }
    }
    let ttft = BenchStats::from_samples(results.iter().map(|r| r.ttft_s).collect());
    let wall = BenchStats::from_samples(results.iter().map(|r| r.wall_s).collect());
    let tokens_total: usize = results.iter().map(|r| r.tokens.len()).sum();
    let slo_ok = ttft.p95 * 1e3 <= TTFT_SLO_MS;
    // Burst is 2.0 and refill is negligible, so exactly two requests of
    // the throttled burst are admitted no matter how threads interleave.
    let throttling_ok = throttled_429 >= 1 && throttled_ok >= 1 && throttled_ok <= 2;
    println!(
        "\nmixed wave: {n_clients} clients, {tokens_total} tokens in {wave_s:.3}s \
         ({:.0} tok/s aggregate)",
        tokens_total as f64 / wave_s.max(1e-12)
    );
    println!(
        "TTFT p50 {:.1} ms  p95 {:.1} ms (SLO {TTFT_SLO_MS:.0} ms: {})  |  \
         stream wall p95 {:.1} ms",
        ttft.p50 * 1e3,
        ttft.p95 * 1e3,
        if slo_ok { "PASS" } else { "FAIL" },
        wall.p95 * 1e3
    );
    println!(
        "throttled tenant: {throttled_ok} admitted (burst 2), {throttled_429} typed 429s \
         ({})  |  trajectories vs oracle: {}",
        if throttling_ok { "PASS" } else { "FAIL" },
        if trajectories_ok { "PASS" } else { "FAIL" }
    );
    let mut j = Json::obj();
    j.set("bench", jstr("http_serve"));
    j.set("wave", jstr("mixed"));
    j.set("clients", jnum(n_clients as f64));
    j.set("tenants", jnum(5.0));
    j.set("generated_tokens", jnum(tokens_total as f64));
    j.set("wall_s", jnum(wave_s));
    j.set("agg_tok_per_s", jnum(tokens_total as f64 / wave_s.max(1e-12)));
    j.set("ttft_p50_ms", jnum(ttft.p50 * 1e3));
    j.set("ttft_p95_ms", jnum(ttft.p95 * 1e3));
    j.set("ttft_slo_ms", jnum(TTFT_SLO_MS));
    j.set("throttled_clients", jnum(n_throttled as f64));
    j.set("throttled_429", jnum(throttled_429 as f64));
    j.set("trajectories_ok", Json::Bool(trajectories_ok));
    println!("BENCH {j}");
    let mixed_json = j;

    // ---- wave 2: graceful drain under load ----------------------------
    let drain_clients: usize = 6;
    let drain_max_new = 2 * max_new;
    eprintln!("[wave 2] drain with {drain_clients} streams in flight…");
    // Each client signals readiness only after its 200 response head,
    // which the server writes after the first decode event — so by the
    // time the drain is requested every sequence is provably running.
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
    let mut handles = Vec::new();
    for i in 0..drain_clients {
        let addr = addr.clone();
        let ready = ready_tx.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<(usize, bool)> {
            let (adapter, prompt) = client_request(i);
            let body = gen_body(adapter.as_deref(), &prompt, drain_max_new, true);
            let mut c = StreamingClient::post(&addr, "/v1/generate", &body)?;
            anyhow::ensure!(c.status == 200, "drain client {i}: status {}", c.status);
            let _ = ready.send(());
            let mut n_tokens = 0usize;
            let mut done = false;
            while let Some(chunk) = c.next_chunk()? {
                for line in String::from_utf8(chunk)?.lines().filter(|l| !l.is_empty()) {
                    let j = Json::parse(line)?;
                    if j.get("token").is_some() {
                        n_tokens += 1;
                    } else if j.get("done").is_some() {
                        done = true;
                    }
                }
            }
            Ok((n_tokens, done))
        }));
    }
    drop(ready_tx);
    for _ in 0..drain_clients {
        ready_rx.recv()?;
    }
    let d = http::request(&addr, "POST", "/admin/drain", None)?;
    anyhow::ensure!(d.status == 200, "drain endpoint: status {}", d.status);
    let probe = gen_body(None, &[1, 2], 2, false);
    let refused = http::request(&addr, "POST", "/v1/generate", Some(&probe))?;
    let post_drain_503 = refused.status == 503;
    let mut drain_ok = true;
    let mut drained_tokens = 0usize;
    for (i, h) in handles.into_iter().enumerate() {
        let (n_tokens, done) = h.join().expect("drain client thread")?;
        drained_tokens += n_tokens;
        if !done || n_tokens != drain_max_new {
            drain_ok = false;
            eprintln!("[FAIL] drain client {i}: {n_tokens} tokens, done={done}");
        }
    }
    server.wait_engine_stopped();
    println!(
        "drain: {drain_clients} in-flight streams finished with {drained_tokens} tokens, \
         zero truncation: {}  |  new work refused with 503: {}",
        if drain_ok { "PASS" } else { "FAIL" },
        if post_drain_503 { "PASS" } else { "FAIL" }
    );
    let mut j = Json::obj();
    j.set("bench", jstr("http_serve"));
    j.set("wave", jstr("drain"));
    j.set("inflight_streams", jnum(drain_clients as f64));
    j.set("drained_tokens", jnum(drained_tokens as f64));
    j.set("zero_truncation", Json::Bool(drain_ok));
    j.set("post_drain_503", Json::Bool(post_drain_503));
    println!("BENCH {j}");
    let drain_json = j;
    server.shutdown()?;

    // ---- summary ------------------------------------------------------
    let pass = trajectories_ok && slo_ok && throttling_ok && drain_ok && post_drain_503;
    let mut s = Json::obj();
    s.set("bench", jstr("http_serve_summary"));
    s.set("clients", jnum((n_clients + n_throttled) as f64));
    s.set("trajectories_ok", Json::Bool(trajectories_ok));
    s.set("ttft_slo_ok", Json::Bool(slo_ok));
    s.set("throttling_ok", Json::Bool(throttling_ok));
    s.set("drain_zero_truncation", Json::Bool(drain_ok));
    s.set("post_drain_503", Json::Bool(post_drain_503));
    s.set("pass", Json::Bool(pass));
    println!("BENCH {s}");
    println!("overall: {}", if pass { "PASS" } else { "FAIL" });

    let mut out = Json::obj();
    out.set("mixed", mixed_json);
    out.set("drain", drain_json);
    out.set("summary", s);
    let path = common::results_dir().join("http_serve.json");
    pissa::metrics::write_json(&path, &out)?;
    println!("(json -> {}; methodology in EXPERIMENTS.md §HTTP serving)", path.display());
    anyhow::ensure!(pass, "http_serve SLO/fidelity assertions failed");
    Ok(())
}
