//! §Perf — microbenchmarks of every hot path, feeding EXPERIMENTS.md §Perf:
//!   L3: GEMM GFLOP/s vs naive + vs practical peak, exact vs fast SVD,
//!       NF4 quant/dequant throughput, PiSSA init end-to-end
//!   runtime: train-step latency breakdown (marshal vs execute) for each
//!       artifact, logits-fn latency (jnp vs pallas variant)

mod common;

use pissa::adapter::AdapterSpec;
use pissa::coordinator::{LrSchedule, Trainer};
use pissa::linalg::{matmul, rsvd, svd, Mat};
use pissa::model::{apply_spec, BaseModel};
use pissa::quant::nf4::{dequantize, quantize};
use pissa::runtime::Manifest;
use pissa::util::rng::Rng;
use pissa::util::timer::{bench, Timer};

fn main() -> anyhow::Result<()> {
    common::banner("§Perf", "hot-path microbenchmarks");
    let full = common::full_mode();
    let mut rng = Rng::new(1);

    // ---- GEMM ---------------------------------------------------------
    println!("\n[gemm] C=A·B f32, {} threads:", pissa::util::par::num_threads());
    for &n in if full { &[256usize, 512, 1024][..] } else { &[256usize, 512][..] } {
        let a = Mat::randn(n, n, 0.0, 1.0, &mut rng);
        let b = Mat::randn(n, n, 0.0, 1.0, &mut rng);
        let stats = bench(2, if full { 10 } else { 5 }, || {
            std::hint::black_box(matmul(&a, &b));
        });
        let gflops = 2.0 * (n as f64).powi(3) / stats.min / 1e9;
        println!("  {n:4}³: {} -> {gflops:.2} GFLOP/s (best)", stats.human());
    }

    // ---- SVD ------------------------------------------------------------
    println!("\n[svd] exact Jacobi vs randomized (rank 16, niter 4):");
    for &(m, n) in &[(128usize, 128usize), (256, 128)] {
        let a = Mat::randn(m, n, 0.0, 1.0, &mut rng);
        let t_exact = {
            let t = Timer::start();
            std::hint::black_box(svd(&a));
            t.ms()
        };
        let t_fast = {
            let t = Timer::start();
            std::hint::black_box(rsvd(&a, 16, 4, &mut rng));
            t.ms()
        };
        println!("  {m}x{n}: exact {t_exact:.1} ms, fast {t_fast:.1} ms ({:.1}x speedup)", t_exact / t_fast);
    }

    // ---- NF4 -------------------------------------------------------------
    println!("\n[nf4] quantize/dequantize throughput:");
    let m = Mat::randn(1024, 1024, 0.0, 0.05, &mut rng);
    let bytes = m.data.len() * 4;
    let sq = bench(2, 8, || {
        std::hint::black_box(quantize(&m));
    });
    let q = quantize(&m);
    let sd = bench(2, 8, || {
        std::hint::black_box(dequantize(&q));
    });
    println!(
        "  quant:   {}  ({:.2} GB/s)",
        sq.human(),
        bytes as f64 / sq.min / 1e9
    );
    println!(
        "  dequant: {}  ({:.2} GB/s)",
        sd.human(),
        bytes as f64 / sd.min / 1e9
    );

    // ---- PiSSA init end-to-end -------------------------------------------
    println!("\n[init] full-model PiSSA init (fast SVD, niter 4):");
    let (rt, manifest) = common::load()?;
    for config in ["tiny", "small"] {
        let cfg = manifest.config(config)?.clone();
        let base = BaseModel::random(&cfg, &mut rng);
        let t = Timer::start();
        let _ = apply_spec(&base, &AdapterSpec::pissa(8.min(cfg.ranks[cfg.ranks.len() - 1])), &mut rng)?;
        println!("  {config:6}: {:.0} ms (paper target: seconds — ✓)", t.ms());
    }

    // ---- train-step latency breakdown --------------------------------------
    println!("\n[step] train-step latency (marshal+unmarshal = rust overhead):");
    for config in ["tiny", "small"] {
        let cfg = manifest.config(config)?.clone();
        let mut rng2 = Rng::new(3);
        let base = BaseModel::random(&cfg, &mut rng2);
        let rank = 4.min(cfg.ranks[cfg.ranks.len() - 1]);
        let state = apply_spec(&base, &AdapterSpec::pissa(rank), &mut rng2)?;
        let art = Manifest::train_name(config, rank, false);
        let mut trainer =
            Trainer::new(&rt, &manifest, &art, state, LrSchedule::alpaca(1e-3, 100))?;
        let corpus = pissa::data::corpus::gen_corpus(128, 4);
        let mut batcher = pissa::data::Batcher::new(corpus, cfg.batch, cfg.seq_len, 5);
        let warm = batcher.next_batch();
        trainer.step(&warm)?; // compile+warm
        let n = if full { 30 } else { 10 };
        let t0_total = trainer.total_s;
        let t0_over = trainer.overhead_s;
        for _ in 0..n {
            trainer.step(&batcher.next_batch())?;
        }
        let step_ms = (trainer.total_s - t0_total) / n as f64 * 1e3;
        let over_ms = (trainer.overhead_s - t0_over) / n as f64 * 1e3;
        println!(
            "  {config:6}: {step_ms:.2} ms/step, rust overhead {over_ms:.3} ms ({:.1}%)",
            100.0 * over_ms / step_ms
        );
    }

    // ---- logits: jnp vs pallas artifact -------------------------------------
    if manifest.artifacts.contains_key("logits_tiny_r4_pallas") {
        println!("\n[logits] jnp-path vs pallas-kernel-path artifact (tiny, r4):");
        let cfg = manifest.config("tiny")?.clone();
        let mut rng3 = Rng::new(6);
        let base = BaseModel::random(&cfg, &mut rng3);
        let state = apply_spec(&base, &AdapterSpec::pissa(4), &mut rng3)?;
        let tokens: Vec<i32> = (0..cfg.eval_batch * cfg.seq_len).map(|i| (i % 250) as i32 + 8).collect();
        for name in ["logits_tiny_r4", "logits_tiny_r4_pallas"] {
            let g = pissa::eval::Generator::new(&rt, &manifest, name, &state)?;
            g.logits(&tokens)?; // warm
            let s = bench(1, 8, || {
                std::hint::black_box(g.logits(&tokens).unwrap());
            });
            println!("  {name:24}: {}", s.human());
        }
    }
    println!("\n(record these in EXPERIMENTS.md §Perf)");
    Ok(())
}
