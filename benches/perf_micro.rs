//! §Perf — microbenchmarks of every hot path, feeding EXPERIMENTS.md §Perf:
//!   L3: GEMM GFLOP/s vs naive + vs practical peak, exact vs fast SVD,
//!       NF4 quant/dequant throughput, PiSSA init end-to-end
//!   trajectory: same-run speedups of the register-tiled kernels vs the
//!       pre-PR reference kernels, written to results/BENCH_perf_micro.json
//!       (normalized ratios only — see README §Perf trajectory)
//!   runtime: train-step latency breakdown (marshal vs execute) for each
//!       artifact, logits-fn latency (jnp vs pallas variant)

mod common;

use pissa::adapter::AdapterSpec;
use pissa::coordinator::{LrSchedule, Trainer};
use pissa::linalg::{dequant_matmul_into, matmul, matmul_into, rsvd, svd, vecmat_into, Mat};
use pissa::model::{apply_spec, BaseModel};
use pissa::quant::nf4::{dequantize, quantize};
use pissa::runtime::Manifest;
use pissa::util::rng::Rng;
use pissa::util::timer::{bench, Timer};

/// The seed's pre-PR GEMM kernels, kept verbatim so the
/// `packed_gemm_x_ref_*` / `row_kernel_x_ref_*` trajectory metrics are
/// same-run, same-machine speedup RATIOS against the exact code this PR
/// replaced — never absolute times. Both old and new kernels perform one
/// multiply-add per C element in ascending k order, so the bit-identity
/// probes below hold exactly.
mod refkernel {
    use pissa::linalg::Mat;
    use pissa::util::par::par_rows_mut;

    const MC: usize = 64; // rows of A per macro-block
    const KC: usize = 256; // depth per macro-block
    const NR: usize = 8; // register tile width

    #[inline]
    fn axpy_row(crow: &mut [f32], av: f32, brow: &[f32]) {
        let n = crow.len();
        let strips = n / NR;
        for s in 0..strips {
            let j0 = s * NR;
            let cdst = &mut crow[j0..j0 + NR];
            let bsrc = &brow[j0..j0 + NR];
            for q in 0..NR {
                cdst[q] += av * bsrc[q];
            }
        }
        for j in strips * NR..n {
            crow[j] += av * brow[j];
        }
    }

    /// The seed's blocked-AXPY `matmul_into` (MC/KC macro-blocks, 8-wide
    /// strip-mined inner AXPY, parallel over row blocks).
    pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
        let (m, k, n) = (a.rows, a.cols, b.cols);
        assert_eq!((c.rows, c.cols), (m, n));
        c.data.iter_mut().for_each(|x| *x = 0.0);
        par_rows_mut(&mut c.data, m, n, MC.min(16), |lo, hi, cchunk| {
            for kb in (0..k).step_by(KC) {
                let ke = (kb + KC).min(k);
                for ib in (lo..hi).step_by(MC) {
                    let ie = (ib + MC).min(hi);
                    for i in ib..ie {
                        let arow = &a.data[i * k..(i + 1) * k];
                        let crow = &mut cchunk[(i - lo) * n..(i - lo + 1) * n];
                        for p in kb..ke {
                            axpy_row(crow, arow[p], &b.data[p * n..(p + 1) * n]);
                        }
                    }
                }
            }
        });
    }

    /// The seed's sequential single-row sweep (`vecmat_into` before the
    /// 4-row-blocked decode kernel).
    pub fn vecmat_into(x: &[f32], a: &Mat, y: &mut [f32]) {
        assert_eq!(x.len(), a.rows);
        assert_eq!(y.len(), a.cols);
        y.iter_mut().for_each(|v| *v = 0.0);
        for (p, &xv) in x.iter().enumerate() {
            axpy_row(y, xv, a.row(p));
        }
    }
}

fn main() -> anyhow::Result<()> {
    common::banner("§Perf", "hot-path microbenchmarks");
    let full = common::full_mode();
    let mut rng = Rng::new(1);

    // ---- GEMM ---------------------------------------------------------
    println!("\n[gemm] C=A·B f32, {} threads:", pissa::util::par::num_threads());
    for &n in if full { &[256usize, 512, 1024][..] } else { &[256usize, 512][..] } {
        let a = Mat::randn(n, n, 0.0, 1.0, &mut rng);
        let b = Mat::randn(n, n, 0.0, 1.0, &mut rng);
        let stats = bench(2, if full { 10 } else { 5 }, || {
            std::hint::black_box(matmul(&a, &b));
        });
        let gflops = 2.0 * (n as f64).powi(3) / stats.min / 1e9;
        println!("  {n:4}³: {} -> {gflops:.2} GFLOP/s (best)", stats.human());
    }

    // ---- trajectory: packed kernels vs pre-PR reference -----------------
    // Same-run ratios (reference best / packed best); machine-independent
    // by construction. These feed results/BENCH_perf_micro.json, which
    // `pissa-bench-check` diffs against benches/baselines/ in CI.
    println!("\n[trajectory] register-tiled kernels vs pre-PR reference kernels:");
    let mut metrics: Vec<(&str, f64)> = Vec::new();
    for &n in &[256usize, 512, 1024] {
        let a = Mat::randn(n, n, 0.0, 1.0, &mut rng);
        let b = Mat::randn(n, n, 0.0, 1.0, &mut rng);
        let mut c_new = Mat::zeros(n, n);
        let mut c_ref = Mat::zeros(n, n);
        if n == 256 {
            // Bit-identity probe: the register-tiled kernel must produce
            // the exact bits of the pre-PR kernel (one multiply-add per
            // element, ascending k — the determinism contract).
            matmul_into(&a, &b, &mut c_new);
            refkernel::matmul_into(&a, &b, &mut c_ref);
            assert_eq!(
                c_new.data, c_ref.data,
                "packed kernel diverged bitwise from the pre-PR kernel"
            );
            println!("  bit-identity probe at 256³: ok");
        }
        let iters = if full {
            8
        } else if n >= 1024 {
            3
        } else {
            5
        };
        let s_new = bench(1, iters, || {
            matmul_into(&a, &b, &mut c_new);
            std::hint::black_box(&c_new);
        });
        let s_ref = bench(1, iters, || {
            refkernel::matmul_into(&a, &b, &mut c_ref);
            std::hint::black_box(&c_ref);
        });
        let ratio = s_ref.min / s_new.min;
        let name = match n {
            256 => "packed_gemm_x_ref_256",
            512 => "packed_gemm_x_ref_512",
            _ => "packed_gemm_x_ref_1024",
        };
        println!(
            "  {n:4}³: packed {ratio:.2}x reference (ref {}, packed {})",
            s_ref.human(),
            s_new.human()
        );
        metrics.push((name, ratio));
    }

    // Single-row decode kernel vs the seed's sequential sweep, k = n = 1024.
    {
        let k = 1024usize;
        let n = 1024usize;
        let a = Mat::randn(k, n, 0.0, 1.0, &mut rng);
        let x: Vec<f32> = (0..k).map(|i| (i % 17) as f32 * 0.1 - 0.8).collect();
        let mut y_new = vec![0.0f32; n];
        let mut y_ref = vec![0.0f32; n];
        vecmat_into(&x, &a, &mut y_new);
        refkernel::vecmat_into(&x, &a, &mut y_ref);
        assert_eq!(y_new, y_ref, "row kernel diverged bitwise from the pre-PR sweep");
        let iters = if full { 30 } else { 12 };
        let s_new = bench(2, iters, || {
            vecmat_into(&x, &a, &mut y_new);
            std::hint::black_box(&y_new);
        });
        let s_ref = bench(2, iters, || {
            refkernel::vecmat_into(&x, &a, &mut y_ref);
            std::hint::black_box(&y_ref);
        });
        let ratio = s_ref.min / s_new.min;
        println!("  row k=1024: blocked {ratio:.2}x sequential sweep");
        metrics.push(("row_kernel_x_ref_k1024", ratio));
    }

    // Fused LUT dequant-GEMM vs materialize-then-multiply, m=8 decode batch.
    {
        let k = 1024usize;
        let n = 1024usize;
        let x = Mat::randn(8, k, 0.0, 1.0, &mut rng);
        let w = quantize(&Mat::randn(k, n, 0.0, 0.05, &mut rng));
        let mut c_fused = Mat::zeros(8, n);
        let mut c_mat = Mat::zeros(8, n);
        let iters = if full { 10 } else { 4 };
        let s_fused = bench(1, iters, || {
            dequant_matmul_into(&x, &w, &mut c_fused);
            std::hint::black_box(&c_fused);
        });
        let s_mat = bench(1, iters, || {
            let dense = dequantize(&w);
            matmul_into(&x, &dense, &mut c_mat);
            std::hint::black_box(&c_mat);
        });
        assert_eq!(c_fused.data, c_mat.data, "fused dequant diverged from materialized product");
        let ratio = s_mat.min / s_fused.min;
        println!("  fused dequant m=8, 1024²: {ratio:.2}x vs materialize+matmul");
        metrics.push(("fused_dequant_x_materialize_1024", ratio));
    }
    common::write_bench_summary("perf_micro", &metrics)?;

    // ---- SVD ------------------------------------------------------------
    println!("\n[svd] exact Jacobi vs randomized (rank 16, niter 4):");
    for &(m, n) in &[(128usize, 128usize), (256, 128)] {
        let a = Mat::randn(m, n, 0.0, 1.0, &mut rng);
        let t_exact = {
            let t = Timer::start();
            std::hint::black_box(svd(&a));
            t.ms()
        };
        let t_fast = {
            let t = Timer::start();
            std::hint::black_box(rsvd(&a, 16, 4, &mut rng));
            t.ms()
        };
        println!("  {m}x{n}: exact {t_exact:.1} ms, fast {t_fast:.1} ms ({:.1}x speedup)", t_exact / t_fast);
    }

    // ---- NF4 -------------------------------------------------------------
    println!("\n[nf4] quantize/dequantize throughput:");
    let m = Mat::randn(1024, 1024, 0.0, 0.05, &mut rng);
    let bytes = m.data.len() * 4;
    let sq = bench(2, 8, || {
        std::hint::black_box(quantize(&m));
    });
    let q = quantize(&m);
    let sd = bench(2, 8, || {
        std::hint::black_box(dequantize(&q));
    });
    println!(
        "  quant:   {}  ({:.2} GB/s)",
        sq.human(),
        bytes as f64 / sq.min / 1e9
    );
    println!(
        "  dequant: {}  ({:.2} GB/s)",
        sd.human(),
        bytes as f64 / sd.min / 1e9
    );

    // ---- artifact-backed sections (skipped when artifacts/ is absent,
    // e.g. the CI perf-trajectory job, which only needs the BENCH summary
    // written above) ------------------------------------------------------
    let (rt, manifest) = match common::load() {
        Ok(v) => v,
        Err(e) => {
            println!("\n[init/step/logits] skipped — no artifacts ({e})");
            println!("\n(record these in EXPERIMENTS.md §Perf)");
            return Ok(());
        }
    };

    // ---- PiSSA init end-to-end -------------------------------------------
    println!("\n[init] full-model PiSSA init (fast SVD, niter 4):");
    for config in ["tiny", "small"] {
        let cfg = manifest.config(config)?.clone();
        let base = BaseModel::random(&cfg, &mut rng);
        let t = Timer::start();
        let _ = apply_spec(&base, &AdapterSpec::pissa(8.min(cfg.ranks[cfg.ranks.len() - 1])), &mut rng)?;
        println!("  {config:6}: {:.0} ms (paper target: seconds — ✓)", t.ms());
    }

    // ---- train-step latency breakdown --------------------------------------
    println!("\n[step] train-step latency (marshal+unmarshal = rust overhead):");
    for config in ["tiny", "small"] {
        let cfg = manifest.config(config)?.clone();
        let mut rng2 = Rng::new(3);
        let base = BaseModel::random(&cfg, &mut rng2);
        let rank = 4.min(cfg.ranks[cfg.ranks.len() - 1]);
        let state = apply_spec(&base, &AdapterSpec::pissa(rank), &mut rng2)?;
        let art = Manifest::train_name(config, rank, false);
        let mut trainer =
            Trainer::new(&rt, &manifest, &art, state, LrSchedule::alpaca(1e-3, 100))?;
        let corpus = pissa::data::corpus::gen_corpus(128, 4);
        let mut batcher = pissa::data::Batcher::new(corpus, cfg.batch, cfg.seq_len, 5);
        let warm = batcher.next_batch();
        trainer.step(&warm)?; // compile+warm
        let n = if full { 30 } else { 10 };
        let t0_total = trainer.total_s;
        let t0_over = trainer.overhead_s;
        for _ in 0..n {
            trainer.step(&batcher.next_batch())?;
        }
        let step_ms = (trainer.total_s - t0_total) / n as f64 * 1e3;
        let over_ms = (trainer.overhead_s - t0_over) / n as f64 * 1e3;
        println!(
            "  {config:6}: {step_ms:.2} ms/step, rust overhead {over_ms:.3} ms ({:.1}%)",
            100.0 * over_ms / step_ms
        );
    }

    // ---- logits: jnp vs pallas artifact -------------------------------------
    if manifest.artifacts.contains_key("logits_tiny_r4_pallas") {
        println!("\n[logits] jnp-path vs pallas-kernel-path artifact (tiny, r4):");
        let cfg = manifest.config("tiny")?.clone();
        let mut rng3 = Rng::new(6);
        let base = BaseModel::random(&cfg, &mut rng3);
        let state = apply_spec(&base, &AdapterSpec::pissa(4), &mut rng3)?;
        let tokens: Vec<i32> = (0..cfg.eval_batch * cfg.seq_len).map(|i| (i % 250) as i32 + 8).collect();
        for name in ["logits_tiny_r4", "logits_tiny_r4_pallas"] {
            let g = pissa::eval::Generator::new(&rt, &manifest, name, &state)?;
            g.logits(&tokens)?; // warm
            let s = bench(1, 8, || {
                std::hint::black_box(g.logits(&tokens).unwrap());
            });
            println!("  {name:24}: {}", s.human());
        }
    }
    println!("\n(record these in EXPERIMENTS.md §Perf)");
    Ok(())
}
