//! FIGURE 6 — (Q)PiSSA vs (Q)LoRA across model sizes/types. Paper: 9
//! models, 7B→70B incl. MoE, on GSM8K + HumanEval. Here: the decoder
//! config grid (tiny/small/e2e = increasing d_model & depth) with
//! plain strategies on the smaller configs and Q-strategies on the
//! largest (mirroring the paper's use of quantization for its largest
//! models), each scored on math + code.
//!
//! Expected shape: (Q)PiSSA beats (Q)LoRA in every bar pair.

mod common;

use pissa::adapter::AdapterSpec;
use pissa::coordinator::{self, RunConfig, TaskFamily};
use pissa::metrics::write_labeled_csv;

fn main() -> anyhow::Result<()> {
    common::banner("Figure 6", "(Q)PiSSA vs (Q)LoRA across model scale grid");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();

    // (config, use quantized variants, pretrain steps, ft steps)
    let grid: &[(&str, bool, usize, usize)] = if full {
        &[("tiny", false, 200, 120), ("small", false, 300, 160), ("e2e", true, 300, 160)]
    } else {
        &[("tiny", false, 120, 80), ("small", true, 150, 80)]
    };

    let mut rows = Vec::new();
    let mut pairs_won = 0;
    let mut pairs = 0;
    for &(config, quantized, pre, ft) in grid {
        let (base, _) = coordinator::pretrain(&rt, &manifest, config, pre, 2e-3, 42)?;
        let cfg = manifest.config(config)?;
        let rank = *cfg.ranks.iter().find(|&&r| r >= 4).unwrap_or(&cfg.ranks[cfg.ranks.len() - 1]);
        let (s_lora, s_pissa) = if quantized {
            (AdapterSpec::qlora(rank), AdapterSpec::qpissa(rank).iters(5))
        } else {
            (AdapterSpec::lora(rank), AdapterSpec::pissa(rank))
        };
        for task in [TaskFamily::Math, TaskFamily::Code] {
            let mut accs = Vec::new();
            for spec in [s_lora.clone(), s_pissa.clone()] {
                let run = RunConfig {
                    config: config.to_string(),
                    spec: spec.clone(),
                    steps: ft,
                    peak_lr: 2e-3,
                    corpus_size: 1024,
                    seed: 42,
                    task,
                };
                let r = coordinator::finetune(&rt, &manifest, &base, &run)?;
                let acc = coordinator::evaluate(&rt, &manifest, &run, &r.final_state, 32, 40)?;
                println!(
                    "{config:6} d={:<4} {:7} {:6}: acc {acc:>6.2}%  (final loss {:.4})",
                    cfg.d_model,
                    spec.name(),
                    task.name(),
                    r.final_loss(8)
                );
                accs.push(acc);
            }
            pairs += 1;
            if accs[1] >= accs[0] {
                pairs_won += 1;
            }
            rows.push((format!("{config}/{}", task.name()), accs));
        }
    }
    println!("\nshape check: (Q)PiSSA ≥ (Q)LoRA on {pairs_won}/{pairs} (model, task) pairs");
    write_labeled_csv(
        &common::results_dir().join("fig6_model_grid.csv"),
        &["model_task", "lora_acc", "pissa_acc"],
        &rows,
    )?;
    println!("wrote results/fig6_model_grid.csv");
    Ok(())
}
