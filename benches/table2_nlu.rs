//! TABLE 2 — NLU (GLUE analog): {Full-FT, LoRA, PiSSA} × 2 encoders × 8
//! tasks. Paper scale: RoBERTa-large + DeBERTa-v3-base on GLUE; here: two
//! pre-sized encoder configs (enc_tiny, enc_small) on the synthetic task
//! suite, scored with the real GLUE metrics (accuracy / Matthews / Pearson).
//!
//! Expected shape: PiSSA ≥ LoRA on most of the 16 cells (paper: 14/16).

mod common;

use pissa::adapter::AdapterSpec;
use pissa::coordinator::{LrSchedule, Trainer};
use pissa::data::nlu::{gen_dataset, ALL_TASKS};
use pissa::eval::nlu_eval::{score, NluScorer};
use pissa::metrics::write_labeled_csv;
use pissa::model::{apply_spec, BaseModel};
use pissa::runtime::Manifest;
use pissa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    common::banner("Table 2", "PiSSA vs LoRA vs Full-FT on 8 NLU tasks × 2 encoders");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();
    let encoders: &[&str] = if full { &["enc_tiny", "enc_small"] } else { &["enc_tiny"] };
    let epochs_scale = if full { 2 } else { 1 };

    let mut rows = Vec::new();
    for enc in encoders {
        let cfg = manifest.config(enc)?.clone();
        let rank = cfg.ranks[0];
        // NLU starts from a generic pre-trained encoder; here random-init
        // + the task's own training provides the signal (the synthetic
        // tasks are lexical, so even a fresh encoder separates them —
        // what matters is the LoRA-vs-PiSSA delta under equal budgets).
        let mut rng = Rng::new(77);
        let base = BaseModel::random(&cfg, &mut rng);

        let specs =
            [AdapterSpec::full_ft(), AdapterSpec::lora(rank).iters(1), AdapterSpec::pissa(rank).iters(1)];
        for spec in &specs {
            let mut vals = Vec::new();
            for task in ALL_TASKS {
                let train = gen_dataset(task, task.train_size() / (2 - epochs_scale.min(1)), 100 + task as u64);
                let eval = gen_dataset(task, 200, 900 + task as u64);
                let steps = (train.len() / cfg.batch) * epochs_scale;

                let mut rng2 = Rng::new(7 ^ task as u64);
                let state = apply_spec(&base, spec, &mut rng2)?;
                let art = Manifest::enc_train_name(
                    enc,
                    rank,
                    spec.is_full_ft(),
                    task.regression(),
                );
                let mut trainer = Trainer::new(
                    &rt,
                    &manifest,
                    &art,
                    state,
                    LrSchedule::alpaca(if spec.is_full_ft() { 1e-3 } else { 3e-3 }, steps),
                )?;
                let (b, t) = (cfg.batch, cfg.seq_len);
                for step in 0..steps {
                    let lo = (step * b) % (train.len().saturating_sub(b).max(1));
                    let mut tokens = vec![0i32; b * t];
                    let mut amask = vec![0.0f32; b * t];
                    let mut labels = vec![0i32; b];
                    for row in 0..b {
                        let ex = &train[(lo + row) % train.len()];
                        let n = ex.tokens.len().min(t);
                        tokens[row * t..row * t + n].copy_from_slice(&ex.tokens[..n]);
                        for i in 0..n {
                            amask[row * t + i] = 1.0;
                        }
                        labels[row] = if task.regression() {
                            // The artifact takes i32 labels and casts to
                            // f32 for the MSE loss; STS-B's {0, 2.5, 5}
                            // similarities are doubled to stay integral.
                            // Pearson scoring is invariant to the scale.
                            (ex.label_f * 2.0) as i32
                        } else {
                            ex.label
                        };
                    }
                    trainer.step_encoder(&tokens, &amask, &labels)?;
                }

                let eval_art = format!(
                    "logits_{enc}_{}",
                    if spec.is_full_ft() { "full".to_string() } else { format!("r{rank}") }
                );
                let scorer =
                    NluScorer::new(&rt, &manifest, &eval_art, &trainer.state, task.n_classes())?;
                let (preds, scores) = scorer.predict(&eval)?;
                let metric = score(task, &preds, &scores, &eval);
                vals.push(metric);
                println!("{enc:10} {:8} {:6}: {metric:>6.2}", spec.name(), task.name());
            }
            rows.push((format!("{enc}/{}", spec.name()), vals));
        }
    }
    write_labeled_csv(
        &common::results_dir().join("table2_nlu.csv"),
        &["encoder_strategy", "MNLI", "SST-2", "MRPC", "CoLA", "QNLI", "QQP", "RTE", "STS-B"],
        &rows,
    )?;

    // Shape check: count cells where PiSSA >= LoRA.
    let mut wins = 0;
    let mut cells = 0;
    for enc in encoders {
        let get = |s: &str| {
            rows.iter().find(|(k, _)| k == &format!("{enc}/{s}")).map(|(_, v)| v.clone()).unwrap()
        };
        let (p, l) = (get("pissa"), get("lora"));
        for i in 0..p.len() {
            cells += 1;
            if p[i] >= l[i] - 1e-9 {
                wins += 1;
            }
        }
    }
    println!("\nshape check: PiSSA ≥ LoRA on {wins}/{cells} cells (paper: 14/16 + 1 tie)");
    println!("wrote results/table2_nlu.csv");
    Ok(())
}
