//! §QPiSSA Serving — quantized-base serving: fused NF4 dequant-GEMM vs
//! dequantize-once-dense vs the fp32 fused path.
//!
//! The paper's deployment claim (§4): the frozen base can stay resident
//! in blockwise NF4 with the adapters in fp32. This bench quantifies the
//! serving-side trade on the standard mixed-tenant workload of
//! `benches/serve_throughput.rs` (768×768 base, 16 rank-16 adapters,
//! 64-request mixed batches), three strategies over the SAME engine:
//!
//!   fused          PR-2 fp32 path: dense base resident (m·n·4 bytes)
//!   dequant-dense  quantize → dequantize ONCE at construction, then
//!                  serve dense (fp32 residency, NF4-valued base)
//!   fused-quant    NF4 base resident, streamed through the dequant-GEMM
//!                  panel kernel — the dense base never exists
//!
//! Emits one `BENCH {json}` line per strategy (throughput + resident
//! base bytes) plus a summary line. Targets: fused-quant resident bytes
//! ≤ 0.35× the fp32 fused path while staying within 2× its latency; and
//! fused-quant ≡ dequant-dense bit-for-bit (asserted on a probe batch).
//!
//! Quick mode (default) trims batch count, not the workload shape; set
//! PISSA_BENCH_FULL=1 for more timed batches.

mod common;

use pissa::adapter::{AdapterEngine, AdapterSpec};
use pissa::metrics::write_labeled_csv;
use pissa::model::BaseModel;
use pissa::runtime::ConfigInfo;
use pissa::serve::{drift_factors, Request, ServeConfig, ServeStrategy, Server};
use pissa::util::json::{jnum, Json};
use pissa::util::rng::Rng;

const DIM: usize = 768;
const N_ADAPTERS: usize = 16;
const RANK: usize = 16;
const BATCH: usize = 64;
const MODULE: &str = "q";
const BASE_FRAC: f64 = 0.125;

fn workload(names: &[String], batches: usize, rng: &mut Rng) -> Vec<Vec<Request>> {
    (0..batches)
        .map(|_| {
            (0..BATCH)
                .map(|_| {
                    let mut x = vec![0.0f32; DIM];
                    rng.fill_normal(&mut x, 0.0, 1.0);
                    if rng.uniform() < BASE_FRAC {
                        Request::base(x)
                    } else {
                        Request::new(rng.choice(names), x)
                    }
                })
                .collect()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    common::banner(
        "§QPiSSA Serving",
        &format!(
            "fused NF4 dequant-GEMM vs dequant-once vs fp32 fused — {DIM}x{DIM} base, \
             {N_ADAPTERS} adapters, rank {RANK}, batch {BATCH}"
        ),
    );
    let full = common::full_mode();
    let mut rng = Rng::new(11);

    let cfg = ConfigInfo {
        name: "quant-serve-bench".into(),
        kind: "decoder".into(),
        vocab: 64,
        d_model: DIM,
        n_layers: 1,
        n_heads: 2,
        d_ff: 64,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![RANK],
    };
    eprintln!("[setup] base model + {N_ADAPTERS} pissa:rank={RANK} adapters (SVD init)…");
    let base = BaseModel::random(&cfg, &mut rng);
    let mut engine = AdapterEngine::new(base);
    let names: Vec<String> = (0..N_ADAPTERS).map(|i| format!("tenant{i:02}")).collect();
    for name in &names {
        engine.attach(name, AdapterSpec::pissa(RANK).targets(&[MODULE]), &mut rng)?;
        drift_factors(&mut engine, name, MODULE, 0.05, &mut rng)?;
    }

    // Probe batch: fused-quant must equal dequant-once-dense bit for bit
    // (same NF4 snapshot, same correction path, same accumulation order —
    // the DequantGemm contract).
    {
        let mut probe_rng = Rng::new(99);
        let probe_batches = workload(&names, 1, &mut probe_rng);
        let probe = &probe_batches[0];
        let mut fq = Server::new(
            &engine,
            ServeConfig::new(MODULE).strategy(ServeStrategy::FusedQuant).max_batch(BATCH),
        )?;
        let mut dd = Server::new(
            &engine,
            ServeConfig::new(MODULE).strategy(ServeStrategy::DequantDense).max_batch(BATCH),
        )?;
        let (yq, yd) = (fq.forward(probe)?, dd.forward(probe)?);
        anyhow::ensure!(
            yq.data == yd.data,
            "fused-quant and dequant-dense diverged on the probe batch"
        );
        eprintln!("[probe] fused-quant == dequant-dense bit-for-bit on a {BATCH}-batch ✓");
    }

    println!(
        "\n{:16} {:>10} {:>10} {:>10} {:>14} {:>10}",
        "strategy", "p50 ms", "p95 ms", "req/s", "base bytes", "bytes x"
    );
    let timed = if full { 40 } else { 8 };
    let order = [ServeStrategy::Fused, ServeStrategy::DequantDense, ServeStrategy::FusedQuant];
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    let mut req_per_s = std::collections::BTreeMap::new();
    let mut p50_ms = std::collections::BTreeMap::new();
    let mut resident = std::collections::BTreeMap::new();
    for strategy in order {
        let serve_cfg = ServeConfig::new(MODULE).strategy(strategy).max_batch(BATCH);
        let mut server = Server::new(&engine, serve_cfg)?;
        let bytes = server.base_resident_bytes();
        let mut wl_rng = Rng::new(77); // identical request stream per strategy
        let all = workload(&names, timed + 1, &mut wl_rng);
        server.forward(&all[0])?; // warmup (page in the snapshot)
        server.reset_stats();
        for batch in &all[1..] {
            server.forward(batch)?;
        }
        let s = server.stats().summary();
        req_per_s.insert(strategy.name(), s.req_per_s);
        p50_ms.insert(strategy.name(), s.p50_s * 1e3);
        resident.insert(strategy.name(), bytes);
        let dense_bytes = DIM * DIM * 4;
        println!(
            "{:16} {:>10.3} {:>10.3} {:>10.0} {:>14} {:>10.3}",
            strategy.name(),
            s.p50_s * 1e3,
            s.p95_s * 1e3,
            s.req_per_s,
            bytes,
            bytes as f64 / dense_bytes as f64,
        );
        let mut j = Json::obj();
        j.set("bench", Json::Str("quant_serve".into()));
        j.set("strategy", Json::Str(strategy.name().into()));
        j.set("dim", jnum(DIM as f64));
        j.set("adapters", jnum(N_ADAPTERS as f64));
        j.set("rank", jnum(RANK as f64));
        j.set("batch", jnum(BATCH as f64));
        j.set("batches", jnum(s.batches as f64));
        j.set("p50_ms", jnum(s.p50_s * 1e3));
        j.set("p95_ms", jnum(s.p95_s * 1e3));
        j.set("req_per_s", jnum(s.req_per_s));
        j.set("resident_base_bytes", jnum(bytes as f64));
        println!("BENCH {j}");
        rows.push((
            strategy.name().to_string(),
            vec![s.p50_s * 1e3, s.p95_s * 1e3, s.req_per_s, bytes as f64],
        ));
    }

    // Acceptance: fused-quant keeps ≤ 0.35× the fp32 fused base bytes
    // while staying within 2× its latency (p50).
    let bytes_ratio = resident["fused-quant"] as f64 / resident["fused"] as f64;
    let latency_ratio = if p50_ms["fused"] > 0.0 {
        p50_ms["fused-quant"] / p50_ms["fused"]
    } else {
        f64::INFINITY
    };
    let bytes_ok = bytes_ratio <= 0.35;
    let latency_ok = latency_ratio <= 2.0;
    println!(
        "\nfused-quant vs fused: {bytes_ratio:.3}x base bytes (target <= 0.35x: {}), \
         {latency_ratio:.2}x p50 latency (target <= 2x: {})",
        if bytes_ok { "PASS" } else { "FAIL" },
        if latency_ok { "PASS" } else { "FAIL" },
    );
    let mut j = Json::obj();
    j.set("bench", Json::Str("quant_serve_summary".into()));
    j.set("bytes_ratio", jnum(bytes_ratio));
    j.set("bytes_target", jnum(0.35));
    j.set("latency_ratio", jnum(latency_ratio));
    j.set("latency_target", jnum(2.0));
    j.set("pass", Json::Bool(bytes_ok && latency_ok));
    println!("BENCH {j}");
    common::write_bench_summary(
        "quant_serve",
        &[("bytes_ratio", bytes_ratio), ("latency_ratio", latency_ratio)],
    )?;

    let out = common::results_dir().join("quant_serve.csv");
    write_labeled_csv(
        &out,
        &["strategy", "p50_ms", "p95_ms", "req_per_s", "resident_base_bytes"],
        &rows,
    )?;
    println!("(rows -> {}; methodology in EXPERIMENTS.md §QPiSSA Serving)", out.display());
    Ok(())
}
