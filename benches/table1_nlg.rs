//! TABLE 1 — NLG comparison: {Full-FT, LoRA, PiSSA} × 3 base models ×
//! {math, code, chat} task families, reporting final training loss and
//! exact-match accuracy. Paper scale: LLaMA-2-7B/Mistral-7B/Gemma-7B on
//! GSM8K/MATH/HumanEval/MBPP/MT-Bench; here: three differently-seeded
//! pre-trained `tiny` bases on the synthetic analogs (DESIGN.md §3/§5 T1).
//!
//! Expected shape (paper): PiSSA > LoRA on every (model, task) cell.

mod common;

use pissa::adapter::AdapterSpec;
use pissa::coordinator::{self, RunConfig, TaskFamily};
use pissa::metrics::write_labeled_csv;

fn main() -> anyhow::Result<()> {
    common::banner("Table 1", "PiSSA vs LoRA vs Full-FT on NLG task families");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();
    let config = "tiny";
    let (pre_steps, ft_steps, n_eval) = if full { (240, 160, 64) } else { (100, 60, 16) };

    // Three "base models" — independently pre-trained seeds, standing in
    // for the paper's three architectures (two in quick mode).
    let model_seeds: &[(&str, u64)] = if full {
        &[("model-A", 42u64), ("model-B", 1337), ("model-C", 2024)]
    } else {
        &[("model-A", 42u64), ("model-B", 1337)]
    };
    let tasks = [TaskFamily::Math, TaskFamily::Code, TaskFamily::Chat];
    let specs = [AdapterSpec::full_ft(), AdapterSpec::lora(4), AdapterSpec::pissa(4)];

    println!(
        "{:8} {:9} {:>6} | {:>10} {:>8} | task columns: loss/acc%",
        "model", "strategy", "params", "task", "metric"
    );
    let mut rows = Vec::new();
    for &(mname, seed) in model_seeds {
        let (base, _) = coordinator::pretrain(&rt, &manifest, config, pre_steps, 2e-3, seed)?;
        for spec in &specs {
            let mut vals = Vec::new();
            let mut params = 0;
            let _ = params;
            for task in tasks {
                let run = RunConfig {
                    steps: ft_steps,
                    task,
                    seed,
                    peak_lr: if spec.is_full_ft() { 5e-4 } else { 2e-3 },
                    ..RunConfig::quick(config, spec.clone())
                };
                let r = coordinator::finetune(&rt, &manifest, &base, &run)?;
                let acc =
                    coordinator::evaluate(&rt, &manifest, &run, &r.final_state, n_eval, 40)?;
                params = r.trainable_params;
                vals.push(r.final_loss(8) as f64);
                vals.push(acc);
                println!(
                    "{:8} {:9} {:>6} | {:>10} | loss {:.4}  acc {:>6.2}%",
                    mname,
                    spec.name(),
                    params,
                    task.name(),
                    r.final_loss(8),
                    acc
                );
            }
            rows.push((format!("{mname}/{}", spec.name()), vals));
        }
    }
    write_labeled_csv(
        &common::results_dir().join("table1_nlg.csv"),
        &["model_strategy", "math_loss", "math_acc", "code_loss", "code_acc", "chat_loss", "chat_acc"],
        &rows,
    )?;

    // Shape check mirroring the paper's claim.
    println!("\nshape check (PiSSA beats LoRA per model on math loss):");
    for &(mname, _) in model_seeds {
        let loss = |s: &str| {
            rows.iter()
                .find(|(k, _)| k == &format!("{mname}/{s}"))
                .map(|(_, v)| v[0])
                .unwrap_or(f64::NAN)
        };
        println!(
            "  {mname}: pissa {:.4} vs lora {:.4} -> {}",
            loss("pissa"),
            loss("lora"),
            if loss("pissa") < loss("lora") { "✓" } else { "✗" }
        );
    }
    println!("\nwrote results/table1_nlg.csv");
    Ok(())
}
