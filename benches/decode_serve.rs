//! §Decode serving — continuous batching vs the naive baselines.
//!
//! The generation shape the paper's GSM8K/HumanEval evaluation implies:
//! a stream of sequence requests (prompt + generation budget) over mixed
//! adapters, decoded autoregressively. Three ways to serve the SAME
//! request set over the SAME engine:
//!
//!   continuous   DecodeScheduler at 8 slots: per-step admission into
//!                the slot-paged KV cache, one decode step per token for
//!                every running sequence (adapter-bucketed), retirement
//!                mid-flight
//!   sequential   the same KV-cached prefill/decode path, one sequence
//!                at a time (slots = 1) — isolates the batching win from
//!                the caching win
//!   naive        recompute-per-token: every emitted token re-prefills
//!                the whole prefix from scratch into a throwaway slot —
//!                the O(T²) cost `eval/generate.rs` used to pay
//!
//! The three produce BIT-IDENTICAL token trajectories (probe-asserted:
//! greedy decode is deterministic and incremental ≡ recompute), so the
//! comparison is pure scheduling/caching. Emits one `BENCH {json}` line
//! per contender plus a `decode_serve_summary`. Target: continuous ≥ 3×
//! the naive tokens/s at 8 slots (the continuous-vs-sequential ratio is
//! reported alongside).
//!
//! A fourth section measures CHUNKED PREFILL: open-loop mixed traffic
//! (one arrival per scheduler step, a long prompt every ~22 requests)
//! served with `prefill_chunk = 8` vs one-shot prefill. Chunking bounds
//! how long a freshly-admitted long prompt can stall everyone else's
//! first token, so the TTFT p95 of the mixed stream must drop to
//! ≤ 0.7× the one-shot value — with bit-identical trajectories
//! (probe-asserted: chunked prefill is a scheduler change, not a model
//! change).
//!
//! A fifth section is ATTENTION-BOUND: a fixed decode batch of
//! `ATTN_BATCH` sequences prefilled to ctx ∈ {64, 256, 1024} positions,
//! decoded for a fixed step count. The new page-streaming kernel
//! (`attn_streamed_into` + head×sequence `par_items` dispatch) is
//! measured end to end through `decode_step_into`, with the
//! attn/linear split read from `ServeStats`; the PRE-page-streaming
//! kernel (a faithful in-bench copy: one `k_row`/`v_row` page lookup
//! per position per head, `par_rows_mut` over sequences only) is
//! re-timed over the identical (step, layer, n_ctx) schedule, and the
//! pre-PR throughput estimate reuses the measured linear time (the
//! linear path is untouched by the streaming change). Both layouts of
//! CI's head matrix are exercised in-process regardless of the env
//! override. Emits `decode_tok_per_s_ctx*_x_prepr_*` ratios plus
//! `attn_share_ctx1024_gqa` into the ratio-only trajectory summary
//! gated by `pissa-bench-check` (target: ≥ 2× at ctx 1024 under GQA).
//! Two bitwise probes guard the comparison: the streamed kernel must
//! equal the reference bit for bit on the live cache, and decode
//! trajectories must be identical under `PISSA_THREADS` 1 vs 8.
//!
//! Quick mode (default) trims the request count, not the shape; set
//! PISSA_BENCH_FULL=1 for more sequences. PISSA_SERVE_HEADS /
//! PISSA_SERVE_KV_HEADS switch every section onto a multi-head (+RoPE)
//! attention layout — CI's head-config matrix runs single-head and
//! 4-head/2-KV-head GQA.

mod common;

use pissa::adapter::{AdapterEngine, AdapterSpec};
use pissa::linalg::Mat;
use pissa::metrics::write_labeled_csv;
use pissa::model::{BaseModel, LINEARS};
use pissa::runtime::ConfigInfo;
use pissa::serve::{
    argmax, attn_streamed_into, drift_factors, DecodeRequest, DecodeScheduler, FinishedSeq,
    KvCache, ModelServer, SeqId, SeqRequest, ServeConfig, ServeStrategy, SlotId, StepObserver,
};
use pissa::util::par::{par_rows_mut, with_parallelism};
use pissa::util::timer::Timer;
use pissa::util::rng::Rng;
use pissa::util::json::{jnum, Json};

const DIM: usize = 96;
const D_FF: usize = 192;
const VOCAB: usize = 64;
const LAYERS: usize = 2;
const N_ADAPTERS: usize = 6;
const RANK: usize = 8;
const SLOTS: usize = 8;
const PROMPT_LEN: usize = 12;
const MAX_NEW: usize = 24;
const MAX_SEQ: usize = PROMPT_LEN + MAX_NEW;
const BASE_FRAC: f64 = 0.125;
/// Long-prompt length for the chunked-prefill TTFT section.
const LONG_LEN: usize = 48;
/// One long prompt per this many mixed-traffic requests — few enough
/// that the p95 rank always lands on a SHORT request (the longs' own
/// first tokens legitimately arrive later under chunking).
const LONG_EVERY: usize = 22;
/// Prefill chunk size for the chunked contender.
const CHUNK: usize = 8;
/// Decode batch of the attention-bound section (small enough that the
/// pre-PR sequence-only dispatch cannot fill the worker pool — exactly
/// the regime the head×sequence partitioning targets).
const ATTN_BATCH: usize = 2;
/// Context lengths swept by the attention-bound section.
const ATTN_CTXS: [usize; 3] = [64, 256, 1024];

fn build_engine(rng: &mut Rng) -> anyhow::Result<(AdapterEngine, Vec<String>)> {
    let cfg = ConfigInfo {
        name: "decode-serve-bench".into(),
        kind: "decoder".into(),
        vocab: VOCAB,
        d_model: DIM,
        n_layers: LAYERS,
        n_heads: 2,
        d_ff: D_FF,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![RANK],
    };
    let base = BaseModel::random(&cfg, rng);
    let mut engine = AdapterEngine::new(base);
    let names: Vec<String> = (0..N_ADAPTERS).map(|i| format!("tenant{i:02}")).collect();
    for name in &names {
        engine.attach(name, AdapterSpec::pissa(RANK), rng)?;
        for module in LINEARS {
            drift_factors(&mut engine, name, module, 0.05, rng)?;
        }
    }
    Ok((engine, names))
}

/// The shared request set: every contender serves exactly these.
fn workload(names: &[String], n: usize) -> Vec<SeqRequest> {
    let mut rng = Rng::new(77);
    (0..n)
        .map(|_| {
            let plen = 4 + (rng.uniform() * (PROMPT_LEN - 4) as f64) as usize;
            let prompt: Vec<usize> =
                (0..plen).map(|_| (rng.uniform() * VOCAB as f64) as usize % VOCAB).collect();
            if names.is_empty() || rng.uniform() < BASE_FRAC {
                SeqRequest::base(prompt, MAX_NEW)
            } else {
                SeqRequest::new(rng.choice(names), prompt, MAX_NEW)
            }
        })
        .collect()
}

/// CI head-config matrix hook: PISSA_SERVE_HEADS / PISSA_SERVE_KV_HEADS
/// switch the whole bench onto a multi-head (+RoPE) attention layout;
/// unset keeps the legacy single-head default.
fn head_overrides(cfg: ServeConfig) -> ServeConfig {
    let var = |k: &str| std::env::var(k).ok().and_then(|s| s.parse::<usize>().ok());
    match var("PISSA_SERVE_HEADS") {
        Some(n) if n > 1 => {
            let kv = var("PISSA_SERVE_KV_HEADS").unwrap_or(n);
            cfg.heads(n, kv).rope_theta(10000.0)
        }
        _ => cfg,
    }
}

fn serve_cfg(slots: usize) -> ServeConfig {
    head_overrides(
        ServeConfig::full_model()
            .strategy(ServeStrategy::Fused)
            .max_seq(MAX_SEQ)
            .slots(slots),
    )
}

/// KV-cached continuous batching at `slots`.
fn run_scheduled(
    engine: &AdapterEngine,
    reqs: &[SeqRequest],
    slots: usize,
) -> anyhow::Result<(Vec<FinishedSeq>, ModelServer, f64, usize)> {
    let mut server = ModelServer::new(engine, serve_cfg(slots))?;
    let mut cache = server.new_cache()?;
    let mut sched = DecodeScheduler::new();
    for r in reqs {
        sched.submit(r.clone());
    }
    let t = Timer::start();
    let fin = sched.run_sorted(&mut server, &mut cache)?;
    let wall = t.secs();
    Ok((fin, server, wall, cache.resident_bytes()))
}

/// Naive recompute-per-token: for every emitted token, prefill the WHOLE
/// prefix from scratch (fresh slot, no reuse) — the quadratic baseline.
fn run_naive(
    engine: &AdapterEngine,
    reqs: &[SeqRequest],
) -> anyhow::Result<(Vec<Vec<usize>>, ModelServer, f64)> {
    let mut server = ModelServer::new(engine, serve_cfg(1))?;
    let mut cache = server.new_cache()?;
    let t = Timer::start();
    let mut outs = Vec::with_capacity(reqs.len());
    for r in reqs {
        let mut tokens = r.prompt.clone();
        for _ in 0..r.max_new {
            let slot = cache
                .try_claim(tokens.len())?
                .expect("slots=1 cache is free between recomputes");
            let logits = server.prefill(&mut cache, slot, r.adapter.as_deref(), &tokens)?;
            cache.release(slot);
            let tok = argmax(&logits);
            tokens.push(tok);
            if r.stop_token == Some(tok) {
                break;
            }
        }
        outs.push(tokens);
    }
    Ok((outs, server, t.secs()))
}

/// Mixed traffic for the chunked-prefill section: mostly interactive
/// prompts, with a LONG_LEN-token prompt every LONG_EVERY requests.
fn mixed_workload(names: &[String], n: usize) -> Vec<SeqRequest> {
    let mut rng = Rng::new(177);
    (0..n)
        .map(|i| {
            let long = i % LONG_EVERY == LONG_EVERY / 2;
            let plen = if long { LONG_LEN } else { 4 + (rng.uniform() * 4.0) as usize };
            let prompt: Vec<usize> =
                (0..plen).map(|_| (rng.uniform() * VOCAB as f64) as usize % VOCAB).collect();
            if names.is_empty() || rng.uniform() < BASE_FRAC {
                SeqRequest::base(prompt, 4)
            } else {
                SeqRequest::new(rng.choice(names), prompt, 4)
            }
        })
        .collect()
}

/// Wall-clock first-token times, recorded the moment the scheduler
/// emits them.
struct TtftProbe {
    clock: Timer,
    firsts: Vec<(SeqId, f64)>,
}

impl StepObserver for TtftProbe {
    fn on_token(&mut self, id: SeqId, _token: usize, first: bool) {
        if first {
            self.firsts.push((id, self.clock.secs()));
        }
    }
}

/// Open-loop mixed traffic: ONE request arrives per scheduler step (so
/// TTFT measures in-step head-of-line blocking, not closed-batch queue
/// depth), served with `prefill_chunk = chunk`. Returns the finished
/// trajectories (id order) and per-request arrival→first-token TTFTs in
/// submission order.
fn run_mixed_traffic(
    engine: &AdapterEngine,
    reqs: &[SeqRequest],
    chunk: usize,
) -> anyhow::Result<(Vec<FinishedSeq>, Vec<f64>)> {
    let cfg = head_overrides(
        ServeConfig::full_model()
            .strategy(ServeStrategy::Fused)
            .max_seq(LONG_LEN + 8)
            .slots(SLOTS)
            .prefill_chunk(chunk),
    );
    let mut server = ModelServer::new(engine, cfg)?;
    let mut cache = server.new_cache()?;
    let mut sched = DecodeScheduler::new();
    let mut probe = TtftProbe { clock: Timer::start(), firsts: Vec::new() };
    let mut arrivals: Vec<(SeqId, f64)> = Vec::new();
    let mut finished = Vec::new();
    let mut next = 0usize;
    while next < reqs.len() || !sched.idle() {
        if next < reqs.len() {
            let id = sched.submit(reqs[next].clone());
            arrivals.push((id, probe.clock.secs()));
            next += 1;
        }
        finished.extend(sched.step_observed(&mut server, &mut cache, &mut probe)?);
    }
    let ttfts = arrivals
        .iter()
        .map(|(id, t0)| {
            let first = probe
                .firsts
                .iter()
                .find(|(fid, _)| fid == id)
                .expect("every sequence emits a first token");
            first.1 - t0
        })
        .collect();
    finished.sort_by_key(|f| f.id);
    Ok((finished, ttfts))
}

/// Faithful copy of the PRE-page-streaming attention kernel: per head,
/// one `k_row`/`v_row` page-table lookup per position, running max,
/// exp/sum, V accumulation, final normalize. Kept verbatim in the bench
/// as the measured baseline of the attention-bound section AND as the
/// bitwise reference the streamed kernel is probe-asserted against —
/// the arithmetic (one mul-add per element, ascending position order)
/// is identical, only the memory traversal differs.
#[allow(clippy::too_many_arguments)]
fn ref_attn_into(
    cache: &KvCache,
    slot: SlotId,
    layer: usize,
    q: &[f32],
    n_ctx: usize,
    n_heads: usize,
    n_kv_heads: usize,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    let hd = q.len() / n_heads;
    let group = n_heads / n_kv_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    for h in 0..n_heads {
        let kv_off = (h / group) * hd;
        let qh = &q[h * hd..(h + 1) * hd];
        let oh = &mut out[h * hd..(h + 1) * hd];
        scores.clear();
        let mut max = f32::NEG_INFINITY;
        for j in 0..n_ctx {
            let k = &cache.k_row(slot, layer, j)[kv_off..kv_off + hd];
            let mut dot = 0.0f32;
            for (qv, kv) in qh.iter().zip(k) {
                dot += qv * kv;
            }
            let s = dot * scale;
            if s > max {
                max = s;
            }
            scores.push(s);
        }
        let mut sum = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - max).exp();
            sum += *s;
        }
        oh.iter_mut().for_each(|v| *v = 0.0);
        for (j, &w) in scores.iter().enumerate() {
            let v = &cache.v_row(slot, layer, j)[kv_off..kv_off + hd];
            for (ov, vv) in oh.iter_mut().zip(v) {
                *ov += w * vv;
            }
        }
        let inv = 1.0 / sum;
        for ov in oh.iter_mut() {
            *ov *= inv;
        }
    }
}

/// One attention-bound measurement at a fixed head layout and context.
struct AttnBound {
    /// End-to-end decode tokens/s through the streamed path.
    tok_s_new: f64,
    /// Estimated pre-PR tokens/s: measured linear time + re-timed
    /// pre-PR kernel over the identical schedule.
    tok_s_ref: f64,
    /// attn_secs / (attn_secs + linear_secs) of the streamed path.
    attn_share: f64,
}

/// Prefill `ATTN_BATCH` sequences to `ctx` positions, decode `steps`
/// tokens through the streamed path (attn/linear split from
/// `ServeStats`), then re-time the pre-PR kernel with the pre-PR
/// dispatch shape (`par_rows_mut` over sequences only, per-chunk score
/// scratch) over the SAME (step, layer, n_ctx) schedule. The pre-PR
/// throughput estimate charges the old path the measured linear time —
/// the linear projections are untouched by the streaming change, so
/// the ratio isolates the attention overhaul. Before timing, the
/// streamed kernel is probe-asserted bit-identical to the reference on
/// the live cache.
fn run_attn_bound(
    engine: &AdapterEngine,
    nh: usize,
    nkv: usize,
    rope: f64,
    ctx: usize,
    steps: usize,
) -> anyhow::Result<AttnBound> {
    let cfg = ServeConfig::full_model()
        .strategy(ServeStrategy::Fused)
        .max_seq(ctx + steps + 1)
        .slots(ATTN_BATCH)
        .kv_budget_bytes(64 << 20)
        .heads(nh, nkv)
        .rope_theta(rope);
    let mut server = ModelServer::new(engine, cfg)?;
    let mut cache = server.new_cache()?;
    let mut rng = Rng::new(31 + ctx as u64);
    let mut reqs = Vec::new();
    for _ in 0..ATTN_BATCH {
        let slot = cache.try_claim(ctx + steps + 1)?.expect("attn-bound slots are free");
        let prompt: Vec<usize> =
            (0..ctx).map(|_| (rng.uniform() * VOCAB as f64) as usize % VOCAB).collect();
        let logits = server.prefill(&mut cache, slot, None, &prompt)?;
        reqs.push(DecodeRequest { slot, token: argmax(&logits), adapter: None });
    }

    // Bitwise probe: streamed kernel == pre-PR kernel on the live cache
    // (every layer; ctx covers whole-page and straddling cases as the
    // sweep varies).
    let q0: Vec<f32> = (0..DIM).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let (mut r_out, mut s_out) = (vec![0.0f32; DIM], vec![0.0f32; DIM]);
    let (mut r_sc, mut s_sc) = (Vec::new(), Vec::new());
    for l in 0..LAYERS {
        ref_attn_into(&cache, reqs[0].slot, l, &q0, ctx, nh, nkv, &mut r_sc, &mut r_out);
        attn_streamed_into(&cache, reqs[0].slot, l, &q0, ctx, nh, nkv, &mut s_sc, &mut s_out);
        anyhow::ensure!(
            r_out.iter().zip(&s_out).all(|(a, b)| a.to_bits() == b.to_bits()),
            "streamed attention diverged from the pre-PR kernel (ctx {ctx}, layer {l})"
        );
    }

    server.reset_stats();
    let mut logits = Mat::zeros(0, 0);
    for _ in 0..steps {
        server.decode_step_into(&mut cache, &reqs, &mut logits)?;
        for (i, r) in reqs.iter_mut().enumerate() {
            r.token = argmax(logits.row(i));
        }
    }
    let s = server.stats().summary();
    let decode_s = s.attn_secs + s.linear_secs;

    // Pre-PR kernel over the identical schedule: decode step `i` of the
    // loop above attended over `ctx + i + 1` positions of every layer.
    let slots: Vec<SlotId> = reqs.iter().map(|r| r.slot).collect();
    let q: Vec<f32> = (0..ATTN_BATCH * DIM).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut ao = vec![0.0f32; ATTN_BATCH * DIM];
    let t = Timer::start();
    for step in 0..steps {
        let n_ctx = ctx + step + 1;
        for l in 0..LAYERS {
            let (cache, slots, q) = (&cache, &slots, &q);
            par_rows_mut(&mut ao, ATTN_BATCH, DIM, 1, |lo, hi, chunk| {
                let mut scores = Vec::new();
                for i in lo..hi {
                    let out = &mut chunk[(i - lo) * DIM..(i - lo + 1) * DIM];
                    let qi = &q[i * DIM..(i + 1) * DIM];
                    ref_attn_into(cache, slots[i], l, qi, n_ctx, nh, nkv, &mut scores, out);
                }
            });
        }
    }
    let t_ref = t.secs();
    let tokens = (steps * ATTN_BATCH) as f64;
    Ok(AttnBound {
        tok_s_new: tokens / decode_s.max(1e-12),
        tok_s_ref: tokens / (s.linear_secs + t_ref).max(1e-12),
        attn_share: s.attn_secs / decode_s.max(1e-12),
    })
}

/// Decode trajectories must be BIT-IDENTICAL across thread counts: the
/// head×sequence partitioning writes disjoint output slices and keeps
/// one mul-add per element in ascending position order, so the worker
/// count can never change a reduction order. A page-straddling context
/// (33 = 2 pages + 1) exercises run boundaries under the GQA layout.
fn assert_thread_invariance(engine: &AdapterEngine) -> anyhow::Result<()> {
    let run = |threads: usize| -> anyhow::Result<(Vec<usize>, Vec<u32>)> {
        with_parallelism(threads, || {
            let cfg = ServeConfig::full_model()
                .strategy(ServeStrategy::Fused)
                .max_seq(48)
                .slots(ATTN_BATCH)
                .kv_budget_bytes(16 << 20)
                .heads(4, 2)
                .rope_theta(10000.0);
            let mut server = ModelServer::new(engine, cfg)?;
            let mut cache = server.new_cache()?;
            let mut rng = Rng::new(91);
            let mut reqs = Vec::new();
            for _ in 0..ATTN_BATCH {
                let slot = cache.try_claim(48)?.expect("thread-probe slots are free");
                let prompt: Vec<usize> =
                    (0..33).map(|_| (rng.uniform() * VOCAB as f64) as usize % VOCAB).collect();
                let logits = server.prefill(&mut cache, slot, None, &prompt)?;
                reqs.push(DecodeRequest { slot, token: argmax(&logits), adapter: None });
            }
            let mut toks = Vec::new();
            let mut logits = Mat::zeros(0, 0);
            for _ in 0..8 {
                server.decode_step_into(&mut cache, &reqs, &mut logits)?;
                for (i, r) in reqs.iter_mut().enumerate() {
                    r.token = argmax(logits.row(i));
                    toks.push(r.token);
                }
            }
            Ok((toks, logits.data.iter().map(|v| v.to_bits()).collect()))
        })
    };
    let (t1, l1) = run(1)?;
    let (t8, l8) = run(8)?;
    anyhow::ensure!(t1 == t8 && l1 == l8, "decode trajectory changed with thread count");
    Ok(())
}

/// Nearest-rank 95th percentile.
fn p95(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() as f64 * 0.95).ceil() as usize).max(1) - 1;
    v[idx.min(v.len() - 1)]
}

fn main() -> anyhow::Result<()> {
    common::banner(
        "§Decode serving",
        &format!(
            "continuous batching vs recompute-per-token — d={DIM}, f={D_FF}, L={LAYERS}, \
             {N_ADAPTERS} adapters, rank {RANK}, {SLOTS} slots, prompts ≤{PROMPT_LEN}, \
             max_new {MAX_NEW}"
        ),
    );
    let n_requests = if common::full_mode() { 48 } else { 16 };
    let mut rng = Rng::new(13);
    eprintln!("[setup] {LAYERS}-layer engine + {N_ADAPTERS} pissa:rank={RANK} adapters…");
    let (engine, names) = build_engine(&mut rng)?;
    let reqs = workload(&names, n_requests);

    // Probe: all three contenders must emit IDENTICAL token trajectories
    // (greedy decode is deterministic; incremental ≡ recompute bit for
    // bit), on a small slice of the workload.
    {
        let probe = &reqs[..4.min(reqs.len())];
        let (cont, _, _, _) = run_scheduled(&engine, probe, SLOTS)?;
        let (seq, _, _, _) = run_scheduled(&engine, probe, 1)?;
        let (naive, _, _) = run_naive(&engine, probe)?;
        for (i, f) in cont.iter().enumerate() {
            anyhow::ensure!(
                f.tokens == seq[i].tokens && f.tokens == naive[i],
                "request {i}: trajectories diverged across contenders"
            );
        }
        eprintln!("[probe] continuous == sequential == naive trajectories ✓");
    }

    println!(
        "\n{:12} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "contender", "tokens", "wall s", "tok/s", "ttft p50 ms", "ttft p95 ms"
    );
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    let mut tok_per_s = std::collections::BTreeMap::new();
    let mut emit = |name: &str,
                    tokens: usize,
                    wall: f64,
                    ttft: Option<(f64, f64)>,
                    kv_bytes: usize,
                    rows: &mut Vec<(String, Vec<f64>)>|
     -> f64 {
        let rate = tokens as f64 / wall.max(1e-12);
        let (p50, p95) = ttft.unwrap_or((0.0, 0.0));
        println!(
            "{name:12} {tokens:>10} {wall:>12.3} {rate:>12.0} {:>12.3} {:>12.3}",
            p50 * 1e3,
            p95 * 1e3
        );
        let mut j = Json::obj();
        j.set("bench", Json::Str("decode_serve".into()));
        j.set("contender", Json::Str(name.into()));
        j.set("requests", jnum(n_requests as f64));
        j.set("slots", jnum(SLOTS as f64));
        j.set("dim", jnum(DIM as f64));
        j.set("layers", jnum(LAYERS as f64));
        j.set("generated_tokens", jnum(tokens as f64));
        j.set("wall_s", jnum(wall));
        j.set("tok_per_s", jnum(rate));
        j.set("ttft_p50_ms", jnum(p50 * 1e3));
        j.set("ttft_p95_ms", jnum(p95 * 1e3));
        j.set("kv_cache_bytes", jnum(kv_bytes as f64));
        println!("BENCH {j}");
        rows.push((
            name.to_string(),
            vec![tokens as f64, wall, rate, p50 * 1e3, p95 * 1e3, kv_bytes as f64],
        ));
        rate
    };

    // continuous batching (8 slots)
    let (fin, server, wall, kv_bytes) = run_scheduled(&engine, &reqs, SLOTS)?;
    let tokens: usize = fin.iter().map(|f| f.generated().len()).sum();
    let s = server.stats().summary();
    let rate = emit(
        "continuous",
        tokens,
        wall,
        Some((s.ttft_p50_s, s.ttft_p95_s)),
        kv_bytes,
        &mut rows,
    );
    tok_per_s.insert("continuous", rate);

    // sequential (KV-cached, one sequence at a time)
    let (fin, server, wall, kv_bytes) = run_scheduled(&engine, &reqs, 1)?;
    let tokens_seq: usize = fin.iter().map(|f| f.generated().len()).sum();
    let s = server.stats().summary();
    let rate = emit(
        "sequential",
        tokens_seq,
        wall,
        Some((s.ttft_p50_s, s.ttft_p95_s)),
        kv_bytes,
        &mut rows,
    );
    tok_per_s.insert("sequential", rate);

    // naive recompute-per-token
    let (outs, _, wall) = run_naive(&engine, &reqs)?;
    let tokens_naive: usize =
        outs.iter().zip(&reqs).map(|(o, r)| o.len() - r.prompt.len()).sum();
    let rate = emit("naive", tokens_naive, wall, None, 0, &mut rows);
    tok_per_s.insert("naive", rate);

    anyhow::ensure!(
        tokens == tokens_seq && tokens == tokens_naive,
        "contenders generated different token counts ({tokens} / {tokens_seq} / {tokens_naive})"
    );

    // §chunked prefill: open-loop mixed long/short traffic, TTFT p95
    // with prefill_chunk=CHUNK vs one-shot admission-time prefill.
    let n_mixed = if common::full_mode() { 64 } else { 32 };
    let mixed = mixed_workload(&names, n_mixed);
    let n_long = mixed.iter().filter(|r| r.prompt.len() == LONG_LEN).count();
    eprintln!("[mixed] {n_mixed} open-loop requests ({n_long} long) x {{one-shot, chunked}}…");
    let (fin_one, ttft_one) = run_mixed_traffic(&engine, &mixed, 0)?;
    let (fin_chunk, ttft_chunk) = run_mixed_traffic(&engine, &mixed, CHUNK)?;
    anyhow::ensure!(fin_one.len() == fin_chunk.len() && fin_one.len() == n_mixed);
    for (a, b) in fin_one.iter().zip(&fin_chunk) {
        anyhow::ensure!(
            a.id == b.id && a.tokens == b.tokens,
            "chunked prefill changed a trajectory (seq {:?})",
            a.id
        );
    }
    let (p95_one, p95_chunk) = (p95(&ttft_one), p95(&ttft_chunk));
    let ttft_ratio = p95_chunk / p95_one.max(1e-12);
    let ttft_ok = ttft_ratio <= 0.7;
    println!(
        "\nchunked prefill (chunk {CHUNK}): mixed-traffic ttft p95 {:.3} ms vs one-shot \
         {:.3} ms -> {ttft_ratio:.2}x (target <= 0.7x: {}); trajectories identical ✓",
        p95_chunk * 1e3,
        p95_one * 1e3,
        if ttft_ok { "PASS" } else { "FAIL" },
    );

    // §attention-bound decode: fixed batch, context length swept, both
    // layouts of CI's head matrix run in-process. Ratio vs the pre-PR
    // position-at-a-time kernel over the identical schedule (gated in
    // CI via the benches/baselines ratio trajectory, target ≥ 2× at
    // ctx 1024 under GQA); attn/linear split from ServeStats.
    let attn_steps = if common::full_mode() { 48 } else { 24 };
    assert_thread_invariance(&engine)?;
    eprintln!("[attn] trajectories identical under 1 vs 8 threads ✓; ctx sweep…");
    println!(
        "\n{:16} {:>6} {:>13} {:>13} {:>8} {:>11}",
        "attention-bound", "ctx", "tok/s new", "tok/s pre-PR", "ratio", "attn share"
    );
    let mut attn_rows: Vec<(String, Vec<f64>)> = Vec::new();
    let mut ratio_gqa = std::collections::BTreeMap::new();
    let (mut ratio_single_1024, mut share_1024) = (0.0f64, 0.0f64);
    for &(label, nh, nkv, rope) in &[("single", 1usize, 1usize, 0.0f64), ("gqa", 4, 2, 10000.0)] {
        for &ctx in &ATTN_CTXS {
            // The full sweep runs on the GQA layout; the single-head
            // layout is measured at the longest context only (the
            // regime the ≥ 2× acceptance bar names).
            if label == "single" && ctx != 1024 {
                continue;
            }
            let m = run_attn_bound(&engine, nh, nkv, rope, ctx, attn_steps)?;
            let ratio = m.tok_s_new / m.tok_s_ref.max(1e-12);
            println!(
                "{label:16} {ctx:>6} {:>13.0} {:>13.0} {ratio:>7.2}x {:>10.2}",
                m.tok_s_new, m.tok_s_ref, m.attn_share
            );
            let mut j = Json::obj();
            j.set("bench", Json::Str("decode_serve_attn".into()));
            j.set("layout", Json::Str(label.into()));
            j.set("ctx", jnum(ctx as f64));
            j.set("batch", jnum(ATTN_BATCH as f64));
            j.set("steps", jnum(attn_steps as f64));
            j.set("tok_per_s", jnum(m.tok_s_new));
            j.set("tok_per_s_prepr", jnum(m.tok_s_ref));
            j.set("ratio_x_prepr", jnum(ratio));
            j.set("attn_share", jnum(m.attn_share));
            println!("BENCH {j}");
            attn_rows.push((
                format!("{label}_ctx{ctx}"),
                vec![ctx as f64, m.tok_s_new, m.tok_s_ref, ratio, m.attn_share],
            ));
            match label {
                "gqa" => {
                    ratio_gqa.insert(ctx, ratio);
                    if ctx == 1024 {
                        share_1024 = m.attn_share;
                    }
                }
                _ => ratio_single_1024 = ratio,
            }
        }
    }
    let attn_csv = common::results_dir().join("decode_serve_attn.csv");
    write_labeled_csv(
        &attn_csv,
        &["layout", "ctx", "tok_per_s", "tok_per_s_prepr", "ratio_x_prepr", "attn_share"],
        &attn_rows,
    )?;

    let speedup_naive = tok_per_s["continuous"] / tok_per_s["naive"].max(1e-12);
    let speedup_seq = tok_per_s["continuous"] / tok_per_s["sequential"].max(1e-12);
    let naive_ok = speedup_naive >= 3.0;
    println!(
        "\ncontinuous {speedup_naive:.1}x naive recompute-per-token (target >= 3x: {}); \
         {speedup_seq:.2}x sequential KV-cached (reported)",
        if naive_ok { "PASS" } else { "FAIL" },
    );
    let mut j = Json::obj();
    j.set("bench", Json::Str("decode_serve_summary".into()));
    j.set("slots", jnum(SLOTS as f64));
    j.set("continuous_speedup_vs_naive", jnum(speedup_naive));
    j.set("naive_target", jnum(3.0));
    j.set("continuous_speedup_vs_sequential", jnum(speedup_seq));
    j.set("prefill_chunk", jnum(CHUNK as f64));
    j.set("mixed_requests", jnum(n_mixed as f64));
    j.set("ttft_p95_ms_chunked", jnum(p95_chunk * 1e3));
    j.set("ttft_p95_ms_one_shot", jnum(p95_one * 1e3));
    j.set("chunked_ttft_p95_x_unchunked", jnum(ttft_ratio));
    j.set("ttft_target", jnum(0.7));
    j.set("pass", Json::Bool(naive_ok && ttft_ok));
    println!("BENCH {j}");
    common::write_bench_summary(
        "decode_serve",
        &[
            ("continuous_tok_s_x_naive", speedup_naive),
            ("continuous_tok_s_x_sequential", speedup_seq),
            ("chunked_ttft_p95_x_unchunked", ttft_ratio),
            ("decode_tok_per_s_ctx64_x_prepr_gqa", ratio_gqa[&64]),
            ("decode_tok_per_s_ctx256_x_prepr_gqa", ratio_gqa[&256]),
            ("decode_tok_per_s_ctx1024_x_prepr_gqa", ratio_gqa[&1024]),
            ("decode_tok_per_s_ctx1024_x_prepr_single", ratio_single_1024),
            ("attn_share_ctx1024_gqa", share_1024),
        ],
    )?;
    println!("overall: {}", if naive_ok && ttft_ok { "PASS" } else { "FAIL" });

    let out = common::results_dir().join("decode_serve.csv");
    write_labeled_csv(
        &out,
        &["contender", "generated_tokens", "wall_s", "tok_per_s", "ttft_p50_ms", "ttft_p95_ms", "kv_cache_bytes"],
        &rows,
    )?;
    println!(
        "(rows -> {}; attention sweep -> {}; methodology in EXPERIMENTS.md §Decode serving)",
        out.display(),
        attn_csv.display()
    );
    Ok(())
}
