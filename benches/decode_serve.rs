//! §Decode serving — continuous batching vs the naive baselines.
//!
//! The generation shape the paper's GSM8K/HumanEval evaluation implies:
//! a stream of sequence requests (prompt + generation budget) over mixed
//! adapters, decoded autoregressively. Three ways to serve the SAME
//! request set over the SAME engine:
//!
//!   continuous   DecodeScheduler at 8 slots: per-step admission into
//!                the slot-paged KV cache, one decode step per token for
//!                every running sequence (adapter-bucketed), retirement
//!                mid-flight
//!   sequential   the same KV-cached prefill/decode path, one sequence
//!                at a time (slots = 1) — isolates the batching win from
//!                the caching win
//!   naive        recompute-per-token: every emitted token re-prefills
//!                the whole prefix from scratch into a throwaway slot —
//!                the O(T²) cost `eval/generate.rs` used to pay
//!
//! The three produce BIT-IDENTICAL token trajectories (probe-asserted:
//! greedy decode is deterministic and incremental ≡ recompute), so the
//! comparison is pure scheduling/caching. Emits one `BENCH {json}` line
//! per contender plus a `decode_serve_summary`. Target: continuous ≥ 3×
//! the naive tokens/s at 8 slots (the continuous-vs-sequential ratio is
//! reported alongside).
//!
//! A fourth section measures CHUNKED PREFILL: open-loop mixed traffic
//! (one arrival per scheduler step, a long prompt every ~22 requests)
//! served with `prefill_chunk = 8` vs one-shot prefill. Chunking bounds
//! how long a freshly-admitted long prompt can stall everyone else's
//! first token, so the TTFT p95 of the mixed stream must drop to
//! ≤ 0.7× the one-shot value — with bit-identical trajectories
//! (probe-asserted: chunked prefill is a scheduler change, not a model
//! change).
//!
//! Quick mode (default) trims the request count, not the shape; set
//! PISSA_BENCH_FULL=1 for more sequences. PISSA_SERVE_HEADS /
//! PISSA_SERVE_KV_HEADS switch every section onto a multi-head (+RoPE)
//! attention layout — CI's head-config matrix runs single-head and
//! 4-head/2-KV-head GQA.

mod common;

use pissa::adapter::{AdapterEngine, AdapterSpec};
use pissa::metrics::write_labeled_csv;
use pissa::model::{BaseModel, LINEARS};
use pissa::runtime::ConfigInfo;
use pissa::serve::{
    argmax, drift_factors, DecodeScheduler, FinishedSeq, ModelServer, SeqId, SeqRequest,
    ServeConfig, ServeStrategy, StepObserver,
};
use pissa::util::timer::Timer;
use pissa::util::rng::Rng;
use pissa::util::json::{jnum, Json};

const DIM: usize = 96;
const D_FF: usize = 192;
const VOCAB: usize = 64;
const LAYERS: usize = 2;
const N_ADAPTERS: usize = 6;
const RANK: usize = 8;
const SLOTS: usize = 8;
const PROMPT_LEN: usize = 12;
const MAX_NEW: usize = 24;
const MAX_SEQ: usize = PROMPT_LEN + MAX_NEW;
const BASE_FRAC: f64 = 0.125;
/// Long-prompt length for the chunked-prefill TTFT section.
const LONG_LEN: usize = 48;
/// One long prompt per this many mixed-traffic requests — few enough
/// that the p95 rank always lands on a SHORT request (the longs' own
/// first tokens legitimately arrive later under chunking).
const LONG_EVERY: usize = 22;
/// Prefill chunk size for the chunked contender.
const CHUNK: usize = 8;

fn build_engine(rng: &mut Rng) -> anyhow::Result<(AdapterEngine, Vec<String>)> {
    let cfg = ConfigInfo {
        name: "decode-serve-bench".into(),
        kind: "decoder".into(),
        vocab: VOCAB,
        d_model: DIM,
        n_layers: LAYERS,
        n_heads: 2,
        d_ff: D_FF,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![RANK],
    };
    let base = BaseModel::random(&cfg, rng);
    let mut engine = AdapterEngine::new(base);
    let names: Vec<String> = (0..N_ADAPTERS).map(|i| format!("tenant{i:02}")).collect();
    for name in &names {
        engine.attach(name, AdapterSpec::pissa(RANK), rng)?;
        for module in LINEARS {
            drift_factors(&mut engine, name, module, 0.05, rng)?;
        }
    }
    Ok((engine, names))
}

/// The shared request set: every contender serves exactly these.
fn workload(names: &[String], n: usize) -> Vec<SeqRequest> {
    let mut rng = Rng::new(77);
    (0..n)
        .map(|_| {
            let plen = 4 + (rng.uniform() * (PROMPT_LEN - 4) as f64) as usize;
            let prompt: Vec<usize> =
                (0..plen).map(|_| (rng.uniform() * VOCAB as f64) as usize % VOCAB).collect();
            if names.is_empty() || rng.uniform() < BASE_FRAC {
                SeqRequest::base(prompt, MAX_NEW)
            } else {
                SeqRequest::new(rng.choice(names), prompt, MAX_NEW)
            }
        })
        .collect()
}

/// CI head-config matrix hook: PISSA_SERVE_HEADS / PISSA_SERVE_KV_HEADS
/// switch the whole bench onto a multi-head (+RoPE) attention layout;
/// unset keeps the legacy single-head default.
fn head_overrides(cfg: ServeConfig) -> ServeConfig {
    let var = |k: &str| std::env::var(k).ok().and_then(|s| s.parse::<usize>().ok());
    match var("PISSA_SERVE_HEADS") {
        Some(n) if n > 1 => {
            let kv = var("PISSA_SERVE_KV_HEADS").unwrap_or(n);
            cfg.heads(n, kv).rope_theta(10000.0)
        }
        _ => cfg,
    }
}

fn serve_cfg(slots: usize) -> ServeConfig {
    head_overrides(
        ServeConfig::full_model()
            .strategy(ServeStrategy::Fused)
            .max_seq(MAX_SEQ)
            .slots(slots),
    )
}

/// KV-cached continuous batching at `slots`.
fn run_scheduled(
    engine: &AdapterEngine,
    reqs: &[SeqRequest],
    slots: usize,
) -> anyhow::Result<(Vec<FinishedSeq>, ModelServer, f64, usize)> {
    let mut server = ModelServer::new(engine, serve_cfg(slots))?;
    let mut cache = server.new_cache()?;
    let mut sched = DecodeScheduler::new();
    for r in reqs {
        sched.submit(r.clone());
    }
    let t = Timer::start();
    let fin = sched.run_sorted(&mut server, &mut cache)?;
    let wall = t.secs();
    Ok((fin, server, wall, cache.resident_bytes()))
}

/// Naive recompute-per-token: for every emitted token, prefill the WHOLE
/// prefix from scratch (fresh slot, no reuse) — the quadratic baseline.
fn run_naive(
    engine: &AdapterEngine,
    reqs: &[SeqRequest],
) -> anyhow::Result<(Vec<Vec<usize>>, ModelServer, f64)> {
    let mut server = ModelServer::new(engine, serve_cfg(1))?;
    let mut cache = server.new_cache()?;
    let t = Timer::start();
    let mut outs = Vec::with_capacity(reqs.len());
    for r in reqs {
        let mut tokens = r.prompt.clone();
        for _ in 0..r.max_new {
            let slot = cache
                .try_claim(tokens.len())?
                .expect("slots=1 cache is free between recomputes");
            let logits = server.prefill(&mut cache, slot, r.adapter.as_deref(), &tokens)?;
            cache.release(slot);
            let tok = argmax(&logits);
            tokens.push(tok);
            if r.stop_token == Some(tok) {
                break;
            }
        }
        outs.push(tokens);
    }
    Ok((outs, server, t.secs()))
}

/// Mixed traffic for the chunked-prefill section: mostly interactive
/// prompts, with a LONG_LEN-token prompt every LONG_EVERY requests.
fn mixed_workload(names: &[String], n: usize) -> Vec<SeqRequest> {
    let mut rng = Rng::new(177);
    (0..n)
        .map(|i| {
            let long = i % LONG_EVERY == LONG_EVERY / 2;
            let plen = if long { LONG_LEN } else { 4 + (rng.uniform() * 4.0) as usize };
            let prompt: Vec<usize> =
                (0..plen).map(|_| (rng.uniform() * VOCAB as f64) as usize % VOCAB).collect();
            if names.is_empty() || rng.uniform() < BASE_FRAC {
                SeqRequest::base(prompt, 4)
            } else {
                SeqRequest::new(rng.choice(names), prompt, 4)
            }
        })
        .collect()
}

/// Wall-clock first-token times, recorded the moment the scheduler
/// emits them.
struct TtftProbe {
    clock: Timer,
    firsts: Vec<(SeqId, f64)>,
}

impl StepObserver for TtftProbe {
    fn on_token(&mut self, id: SeqId, _token: usize, first: bool) {
        if first {
            self.firsts.push((id, self.clock.secs()));
        }
    }
}

/// Open-loop mixed traffic: ONE request arrives per scheduler step (so
/// TTFT measures in-step head-of-line blocking, not closed-batch queue
/// depth), served with `prefill_chunk = chunk`. Returns the finished
/// trajectories (id order) and per-request arrival→first-token TTFTs in
/// submission order.
fn run_mixed_traffic(
    engine: &AdapterEngine,
    reqs: &[SeqRequest],
    chunk: usize,
) -> anyhow::Result<(Vec<FinishedSeq>, Vec<f64>)> {
    let cfg = head_overrides(
        ServeConfig::full_model()
            .strategy(ServeStrategy::Fused)
            .max_seq(LONG_LEN + 8)
            .slots(SLOTS)
            .prefill_chunk(chunk),
    );
    let mut server = ModelServer::new(engine, cfg)?;
    let mut cache = server.new_cache()?;
    let mut sched = DecodeScheduler::new();
    let mut probe = TtftProbe { clock: Timer::start(), firsts: Vec::new() };
    let mut arrivals: Vec<(SeqId, f64)> = Vec::new();
    let mut finished = Vec::new();
    let mut next = 0usize;
    while next < reqs.len() || !sched.idle() {
        if next < reqs.len() {
            let id = sched.submit(reqs[next].clone());
            arrivals.push((id, probe.clock.secs()));
            next += 1;
        }
        finished.extend(sched.step_observed(&mut server, &mut cache, &mut probe)?);
    }
    let ttfts = arrivals
        .iter()
        .map(|(id, t0)| {
            let first = probe
                .firsts
                .iter()
                .find(|(fid, _)| fid == id)
                .expect("every sequence emits a first token");
            first.1 - t0
        })
        .collect();
    finished.sort_by_key(|f| f.id);
    Ok((finished, ttfts))
}

/// Nearest-rank 95th percentile.
fn p95(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() as f64 * 0.95).ceil() as usize).max(1) - 1;
    v[idx.min(v.len() - 1)]
}

fn main() -> anyhow::Result<()> {
    common::banner(
        "§Decode serving",
        &format!(
            "continuous batching vs recompute-per-token — d={DIM}, f={D_FF}, L={LAYERS}, \
             {N_ADAPTERS} adapters, rank {RANK}, {SLOTS} slots, prompts ≤{PROMPT_LEN}, \
             max_new {MAX_NEW}"
        ),
    );
    let n_requests = if common::full_mode() { 48 } else { 16 };
    let mut rng = Rng::new(13);
    eprintln!("[setup] {LAYERS}-layer engine + {N_ADAPTERS} pissa:rank={RANK} adapters…");
    let (engine, names) = build_engine(&mut rng)?;
    let reqs = workload(&names, n_requests);

    // Probe: all three contenders must emit IDENTICAL token trajectories
    // (greedy decode is deterministic; incremental ≡ recompute bit for
    // bit), on a small slice of the workload.
    {
        let probe = &reqs[..4.min(reqs.len())];
        let (cont, _, _, _) = run_scheduled(&engine, probe, SLOTS)?;
        let (seq, _, _, _) = run_scheduled(&engine, probe, 1)?;
        let (naive, _, _) = run_naive(&engine, probe)?;
        for (i, f) in cont.iter().enumerate() {
            anyhow::ensure!(
                f.tokens == seq[i].tokens && f.tokens == naive[i],
                "request {i}: trajectories diverged across contenders"
            );
        }
        eprintln!("[probe] continuous == sequential == naive trajectories ✓");
    }

    println!(
        "\n{:12} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "contender", "tokens", "wall s", "tok/s", "ttft p50 ms", "ttft p95 ms"
    );
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    let mut tok_per_s = std::collections::BTreeMap::new();
    let mut emit = |name: &str,
                    tokens: usize,
                    wall: f64,
                    ttft: Option<(f64, f64)>,
                    kv_bytes: usize,
                    rows: &mut Vec<(String, Vec<f64>)>|
     -> f64 {
        let rate = tokens as f64 / wall.max(1e-12);
        let (p50, p95) = ttft.unwrap_or((0.0, 0.0));
        println!(
            "{name:12} {tokens:>10} {wall:>12.3} {rate:>12.0} {:>12.3} {:>12.3}",
            p50 * 1e3,
            p95 * 1e3
        );
        let mut j = Json::obj();
        j.set("bench", Json::Str("decode_serve".into()));
        j.set("contender", Json::Str(name.into()));
        j.set("requests", jnum(n_requests as f64));
        j.set("slots", jnum(SLOTS as f64));
        j.set("dim", jnum(DIM as f64));
        j.set("layers", jnum(LAYERS as f64));
        j.set("generated_tokens", jnum(tokens as f64));
        j.set("wall_s", jnum(wall));
        j.set("tok_per_s", jnum(rate));
        j.set("ttft_p50_ms", jnum(p50 * 1e3));
        j.set("ttft_p95_ms", jnum(p95 * 1e3));
        j.set("kv_cache_bytes", jnum(kv_bytes as f64));
        println!("BENCH {j}");
        rows.push((
            name.to_string(),
            vec![tokens as f64, wall, rate, p50 * 1e3, p95 * 1e3, kv_bytes as f64],
        ));
        rate
    };

    // continuous batching (8 slots)
    let (fin, server, wall, kv_bytes) = run_scheduled(&engine, &reqs, SLOTS)?;
    let tokens: usize = fin.iter().map(|f| f.generated().len()).sum();
    let s = server.stats().summary();
    let rate = emit(
        "continuous",
        tokens,
        wall,
        Some((s.ttft_p50_s, s.ttft_p95_s)),
        kv_bytes,
        &mut rows,
    );
    tok_per_s.insert("continuous", rate);

    // sequential (KV-cached, one sequence at a time)
    let (fin, server, wall, kv_bytes) = run_scheduled(&engine, &reqs, 1)?;
    let tokens_seq: usize = fin.iter().map(|f| f.generated().len()).sum();
    let s = server.stats().summary();
    let rate = emit(
        "sequential",
        tokens_seq,
        wall,
        Some((s.ttft_p50_s, s.ttft_p95_s)),
        kv_bytes,
        &mut rows,
    );
    tok_per_s.insert("sequential", rate);

    // naive recompute-per-token
    let (outs, _, wall) = run_naive(&engine, &reqs)?;
    let tokens_naive: usize =
        outs.iter().zip(&reqs).map(|(o, r)| o.len() - r.prompt.len()).sum();
    let rate = emit("naive", tokens_naive, wall, None, 0, &mut rows);
    tok_per_s.insert("naive", rate);

    anyhow::ensure!(
        tokens == tokens_seq && tokens == tokens_naive,
        "contenders generated different token counts ({tokens} / {tokens_seq} / {tokens_naive})"
    );

    // §chunked prefill: open-loop mixed long/short traffic, TTFT p95
    // with prefill_chunk=CHUNK vs one-shot admission-time prefill.
    let n_mixed = if common::full_mode() { 64 } else { 32 };
    let mixed = mixed_workload(&names, n_mixed);
    let n_long = mixed.iter().filter(|r| r.prompt.len() == LONG_LEN).count();
    eprintln!("[mixed] {n_mixed} open-loop requests ({n_long} long) x {{one-shot, chunked}}…");
    let (fin_one, ttft_one) = run_mixed_traffic(&engine, &mixed, 0)?;
    let (fin_chunk, ttft_chunk) = run_mixed_traffic(&engine, &mixed, CHUNK)?;
    anyhow::ensure!(fin_one.len() == fin_chunk.len() && fin_one.len() == n_mixed);
    for (a, b) in fin_one.iter().zip(&fin_chunk) {
        anyhow::ensure!(
            a.id == b.id && a.tokens == b.tokens,
            "chunked prefill changed a trajectory (seq {:?})",
            a.id
        );
    }
    let (p95_one, p95_chunk) = (p95(&ttft_one), p95(&ttft_chunk));
    let ttft_ratio = p95_chunk / p95_one.max(1e-12);
    let ttft_ok = ttft_ratio <= 0.7;
    println!(
        "\nchunked prefill (chunk {CHUNK}): mixed-traffic ttft p95 {:.3} ms vs one-shot \
         {:.3} ms -> {ttft_ratio:.2}x (target <= 0.7x: {}); trajectories identical ✓",
        p95_chunk * 1e3,
        p95_one * 1e3,
        if ttft_ok { "PASS" } else { "FAIL" },
    );

    let speedup_naive = tok_per_s["continuous"] / tok_per_s["naive"].max(1e-12);
    let speedup_seq = tok_per_s["continuous"] / tok_per_s["sequential"].max(1e-12);
    let naive_ok = speedup_naive >= 3.0;
    println!(
        "\ncontinuous {speedup_naive:.1}x naive recompute-per-token (target >= 3x: {}); \
         {speedup_seq:.2}x sequential KV-cached (reported)",
        if naive_ok { "PASS" } else { "FAIL" },
    );
    let mut j = Json::obj();
    j.set("bench", Json::Str("decode_serve_summary".into()));
    j.set("slots", jnum(SLOTS as f64));
    j.set("continuous_speedup_vs_naive", jnum(speedup_naive));
    j.set("naive_target", jnum(3.0));
    j.set("continuous_speedup_vs_sequential", jnum(speedup_seq));
    j.set("prefill_chunk", jnum(CHUNK as f64));
    j.set("mixed_requests", jnum(n_mixed as f64));
    j.set("ttft_p95_ms_chunked", jnum(p95_chunk * 1e3));
    j.set("ttft_p95_ms_one_shot", jnum(p95_one * 1e3));
    j.set("chunked_ttft_p95_x_unchunked", jnum(ttft_ratio));
    j.set("ttft_target", jnum(0.7));
    j.set("pass", Json::Bool(naive_ok && ttft_ok));
    println!("BENCH {j}");
    common::write_bench_summary(
        "decode_serve",
        &[
            ("continuous_tok_s_x_naive", speedup_naive),
            ("continuous_tok_s_x_sequential", speedup_seq),
            ("chunked_ttft_p95_x_unchunked", ttft_ratio),
        ],
    )?;
    println!("overall: {}", if naive_ok && ttft_ok { "PASS" } else { "FAIL" });

    let out = common::results_dir().join("decode_serve.csv");
    write_labeled_csv(
        &out,
        &["contender", "generated_tokens", "wall_s", "tok_per_s", "ttft_p50_ms", "ttft_p95_ms", "kv_cache_bytes"],
        &rows,
    )?;
    println!("(rows -> {}; methodology in EXPERIMENTS.md §Decode serving)", out.display());
    Ok(())
}
