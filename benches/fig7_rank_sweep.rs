//! FIGURE 7 (+ Figs 14-16 / App. H) — the rank sweep: quantization-error
//! reduction ratio (7a), final training loss (7b), and eval accuracy
//! (7c/7d) for (Q)LoRA / (Q)PiSSA / LoftQ across ranks; full-FT as the
//! horizontal reference line. Paper: ranks 1..128 on 4096-dim models;
//! here: ranks 1..32 on the `small` config (same r/min(m,n) ratio grid).
//!
//! Expected shape: PiSSA < LoRA in loss at EVERY rank (gap largest at
//! small rank); QPiSSA > LoftQ in error reduction at every rank; PiSSA's
//! accuracy approaches/crosses full-FT as rank grows.

mod common;

use pissa::adapter::init::{loftq, qpissa};
use pissa::adapter::AdapterSpec;
use pissa::coordinator::{self, RunConfig, TaskFamily};
use pissa::linalg::{matmul, nuclear_norm};
use pissa::metrics::write_labeled_csv;
use pissa::quant::qlora_error;
use pissa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    common::banner("Figure 7 (+14-16)", "rank sweep: error ratio, loss, accuracy");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();
    let config = if full { "small" } else { "tiny" };
    let cfg = manifest.config(config)?.clone();
    let ranks: Vec<usize> = cfg.ranks.clone();
    let steps = if full { 200 } else { 80 };

    let (base, _) =
        coordinator::pretrain(&rt, &manifest, config, if full { 300 } else { 150 }, 2e-3, 42)?;

    // --- 7a: quantization-error reduction ratio vs rank (q_proj) --------
    println!("\n(7a) error-reduction ratio vs rank (q_proj, T=1):");
    let w = base.linears["base_q"].layer(0);
    let baseline = qlora_error(&w);
    let mut rng = Rng::new(5);
    let mut rows_a = Vec::new();
    for &r in &ranks {
        let lq = loftq(&w, r, 1, &mut rng);
        let e_lq = nuclear_norm(&w.sub(&lq.base.add(&matmul(&lq.a, &lq.b))));
        let qp = qpissa(&w, r, 1, &mut rng);
        let e_qp = nuclear_norm(&w.sub(&qp.base.add(&matmul(&qp.a, &qp.b))));
        let (rl, rq) = ((1.0 - e_lq / baseline) * 100.0, (1.0 - e_qp / baseline) * 100.0);
        println!("  r={r:<3}: qlora 0.0  loftq {rl:>6.1}  qpissa {rq:>6.1}  {}", if rq >= rl { "✓" } else { "✗" });
        rows_a.push((format!("r{r}"), vec![0.0, rl, rq]));
    }
    write_labeled_csv(
        &common::results_dir().join("fig7a_error_vs_rank.csv"),
        &["rank", "qlora", "loftq", "qpissa"],
        &rows_a,
    )?;

    // --- 7b/7c: final loss + accuracy vs rank ----------------------------
    println!("\n(7b/7c) final loss and accuracy vs rank:");
    // full-FT reference
    let full_run = RunConfig {
        config: config.to_string(),
        spec: AdapterSpec::full_ft(),
        steps,
        peak_lr: 5e-4,
        corpus_size: 1024,
        seed: 42,
        task: TaskFamily::Math,
    };
    let full_r = coordinator::finetune(&rt, &manifest, &base, &full_run)?;
    let full_acc = coordinator::evaluate(&rt, &manifest, &full_run, &full_r.final_state, 32, 40)?;
    println!("  full-FT reference: loss {:.4}, acc {full_acc:.2}%", full_r.final_loss(8));

    let mut rows_b = Vec::new();
    let mut pissa_wins = 0;
    for &r in &ranks {
        let mut cells = Vec::new();
        for spec in [
            AdapterSpec::lora(r),
            AdapterSpec::pissa(r),
            AdapterSpec::qpissa(r).iters(1),
            AdapterSpec::loftq(r).iters(1),
        ] {
            let run = RunConfig {
                config: config.to_string(),
                spec,
                steps,
                peak_lr: 2e-3,
                corpus_size: 1024,
                seed: 42,
                task: TaskFamily::Math,
            };
            let res = coordinator::finetune(&rt, &manifest, &base, &run)?;
            let acc = coordinator::evaluate(&rt, &manifest, &run, &res.final_state, 32, 40)?;
            cells.push(res.final_loss(8) as f64);
            cells.push(acc);
        }
        let (lora_loss, pissa_loss) = (cells[0], cells[2]);
        if pissa_loss <= lora_loss {
            pissa_wins += 1;
        }
        println!(
            "  r={r:<3}: lora loss {lora_loss:.4}/acc {:5.1}%  pissa {pissa_loss:.4}/{:5.1}%  qpissa {:.4}/{:5.1}%  loftq {:.4}/{:5.1}%",
            cells[1], cells[3], cells[4], cells[5], cells[6], cells[7]
        );
        rows_b.push((format!("r{r}"), cells));
    }
    println!(
        "\nshape check: PiSSA loss ≤ LoRA loss at {pissa_wins}/{} ranks (paper: all)",
        ranks.len()
    );
    rows_b.push(("full_ft".to_string(), vec![full_r.final_loss(8) as f64, full_acc, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]));
    write_labeled_csv(
        &common::results_dir().join("fig7bc_rank_sweep.csv"),
        &[
            "rank",
            "lora_loss",
            "lora_acc",
            "pissa_loss",
            "pissa_acc",
            "qpissa_loss",
            "qpissa_acc",
            "loftq_loss",
            "loftq_acc",
        ],
        &rows_b,
    )?;
    println!("wrote results/fig7a_error_vs_rank.csv, results/fig7bc_rank_sweep.csv");
    Ok(())
}
