//! Shared helpers for the bench harnesses (criterion is not in the
//! offline vendor set; every bench is a `harness = false` binary that
//! regenerates one of the paper's tables/figures and prints the rows).

// Each bench binary compiles its own copy of this module and uses a
// different subset of the helpers; unused ones are not dead code.
#![allow(dead_code)]

use pissa::runtime::{Manifest, Runtime};
use pissa::util::json::{jnum, Json};
use std::path::PathBuf;

pub fn art_dir() -> PathBuf {
    std::env::var("PISSA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

pub fn results_dir() -> PathBuf {
    let d = PathBuf::from("results");
    std::fs::create_dir_all(&d).ok();
    d
}

pub fn load() -> anyhow::Result<(Runtime, Manifest)> {
    let dir = art_dir();
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu(&dir)?;
    Ok((rt, manifest))
}

/// Quick-mode guard: `cargo bench` runs everything at reduced scale by
/// default; set PISSA_BENCH_FULL=1 for the full protocol.
pub fn full_mode() -> bool {
    std::env::var("PISSA_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

pub fn banner(id: &str, title: &str) {
    println!("\n================================================================");
    println!("  {id} — {title}");
    println!("================================================================");
}

/// Write a bench's normalized perf summary to `results/BENCH_<name>.json`
/// (and echo it as a `BENCH {json}` stdout line).
///
/// The trajectory contract (see README §Perf trajectory): every metric is
/// a same-run RATIO (speedup vs a baseline measured in the same process,
/// or a resident-bytes fraction) — never an absolute time, so summaries
/// are comparable across machines. `pissa-bench-check` diffs these fresh
/// files against the committed `benches/baselines/BENCH_<name>.json`
/// trajectory and fails CI outside tolerance.
pub fn write_bench_summary(name: &str, metrics: &[(&str, f64)]) -> anyhow::Result<PathBuf> {
    let mut m = Json::obj();
    for (key, val) in metrics {
        m.set(key, jnum(*val));
    }
    let mut j = Json::obj();
    j.set("bench", Json::Str(name.into()));
    j.set("schema", Json::Str("ratio-trajectory-v1".into()));
    j.set("metrics", m);
    let path = results_dir().join(format!("BENCH_{name}.json"));
    pissa::metrics::write_json(&path, &j)?;
    println!("BENCH {j}");
    println!("(normalized summary -> {})", path.display());
    Ok(path)
}
