//! §Full-model serving — the whole-model pipeline vs merged-per-request,
//! across layer counts.
//!
//! PR 2/3 measured one linear; this bench measures the deployment shape
//! the paper actually fine-tunes: every tenant adapts ALL seven linears
//! of EVERY layer, and a mixed batch of token requests runs embed →
//! L blocks → head in one `ModelServer::forward` call. Three strategies
//! over the SAME engine, at each layer count:
//!
//!   fused              shared base GEMM per linear + per-group low-rank
//!                      corrections (ΔW never materialized)
//!   merge-per-request  the naive baseline: materialize every merged
//!                      dense weight for every request at every linear
//!   fused-quant        the QPiSSA shape: all L×7 bases NF4-resident
//!                      (shared per-module Nf4Stack snapshots), streamed
//!                      through the dequant-GEMM
//!
//! Emits one `BENCH {json}` line per (layers, strategy) with throughput
//! and aggregate resident base bytes, plus a summary line per layer
//! count. Targets: fused ≥ 3× merge-per-request throughput, and
//! fused-quant aggregate residency ≤ 0.35× dense while matching the
//! dense pipeline's outputs (probe-asserted against dequant-dense bit
//! for bit).
//!
//! Quick mode (default) trims batch count, not the workload shape; set
//! PISSA_BENCH_FULL=1 for more timed batches.

mod common;

use pissa::adapter::{AdapterEngine, AdapterSpec};
use pissa::metrics::write_labeled_csv;
use pissa::model::{BaseModel, LINEARS};
use pissa::runtime::ConfigInfo;
use pissa::serve::{drift_factors, ModelRequest, ModelServer, ServeConfig, ServeStrategy};
use pissa::util::json::{jnum, Json};
use pissa::util::rng::Rng;

const DIM: usize = 128;
const D_FF: usize = 256;
const VOCAB: usize = 64;
const N_ADAPTERS: usize = 8;
const RANK: usize = 8;
const BATCH: usize = 32;
const BASE_FRAC: f64 = 0.125;
const LAYER_COUNTS: [usize; 3] = [1, 2, 4];

fn workload(names: &[String], batches: usize, rng: &mut Rng) -> Vec<Vec<ModelRequest>> {
    (0..batches)
        .map(|_| {
            (0..BATCH)
                .map(|_| {
                    let token = (rng.uniform() * VOCAB as f64) as usize % VOCAB;
                    if rng.uniform() < BASE_FRAC {
                        ModelRequest::base(token)
                    } else {
                        ModelRequest::new(rng.choice(names), token)
                    }
                })
                .collect()
        })
        .collect()
}

fn build_engine(layers: usize, rng: &mut Rng) -> anyhow::Result<(AdapterEngine, Vec<String>)> {
    let cfg = ConfigInfo {
        name: "model-serve-bench".into(),
        kind: "decoder".into(),
        vocab: VOCAB,
        d_model: DIM,
        n_layers: layers,
        n_heads: 2,
        d_ff: D_FF,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![RANK],
    };
    let base = BaseModel::random(&cfg, rng);
    let mut engine = AdapterEngine::new(base);
    let names: Vec<String> = (0..N_ADAPTERS).map(|i| format!("tenant{i:02}")).collect();
    for name in &names {
        engine.attach(name, AdapterSpec::pissa(RANK), rng)?;
        for module in LINEARS {
            drift_factors(&mut engine, name, module, 0.05, rng)?;
        }
    }
    Ok((engine, names))
}

fn main() -> anyhow::Result<()> {
    common::banner(
        "§Full-model serving",
        &format!(
            "whole-model pipeline (L×7 adapted linears) — d={DIM}, f={D_FF}, \
             {N_ADAPTERS} adapters, rank {RANK}, batch {BATCH}, layers {LAYER_COUNTS:?}"
        ),
    );
    let full = common::full_mode();
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    let mut all_pass = true;

    for layers in LAYER_COUNTS {
        let mut rng = Rng::new(11 + layers as u64);
        eprintln!("[setup] {layers}-layer engine + {N_ADAPTERS} pissa:rank={RANK} adapters…");
        let (engine, names) = build_engine(layers, &mut rng)?;

        // Probe: fused-quant must equal dequant-dense bit for bit through
        // the WHOLE pipeline (same shared NF4 snapshots, same correction
        // path, same accumulation order at every one of the L×7 linears).
        {
            let mut probe_rng = Rng::new(99);
            let probe = &workload(&names, 1, &mut probe_rng)[0];
            let mut fq = ModelServer::new(
                &engine,
                ServeConfig::full_model().strategy(ServeStrategy::FusedQuant).max_batch(BATCH),
            )?;
            let mut dd = ModelServer::new(
                &engine,
                ServeConfig::full_model().strategy(ServeStrategy::DequantDense).max_batch(BATCH),
            )?;
            anyhow::ensure!(
                fq.forward(probe)?.data == dd.forward(probe)?.data,
                "layers={layers}: fused-quant diverged from dequant-dense on the probe batch"
            );
            eprintln!("[probe] L={layers}: fused-quant == dequant-dense bit-for-bit ✓");
        }

        println!(
            "\nlayers={layers}\n{:18} {:>10} {:>10} {:>10} {:>14} {:>8}",
            "strategy", "p50 ms", "p95 ms", "req/s", "base bytes", "bytes x"
        );
        let mut req_per_s = std::collections::BTreeMap::new();
        let mut resident = std::collections::BTreeMap::new();
        let mut dense_bytes = 0usize;
        let order =
            [ServeStrategy::MergePerRequest, ServeStrategy::Fused, ServeStrategy::FusedQuant];
        for strategy in order {
            let timed = match (strategy, full) {
                (ServeStrategy::MergePerRequest, true) => 4,
                (ServeStrategy::MergePerRequest, false) => 2,
                (_, true) => 20,
                (_, false) => 6,
            };
            let mut server = ModelServer::new(
                &engine,
                ServeConfig::full_model().strategy(strategy).max_batch(BATCH),
            )?;
            dense_bytes = server.dense_base_bytes();
            let bytes = server.base_resident_bytes();
            let mut wl_rng = Rng::new(77); // identical request stream per strategy
            let all = workload(&names, timed + 1, &mut wl_rng);
            server.forward(&all[0])?; // warmup (page in the snapshot)
            server.reset_stats();
            for batch in &all[1..] {
                server.forward(batch)?;
            }
            let s = server.stats().summary();
            req_per_s.insert(strategy.name(), s.req_per_s);
            resident.insert(strategy.name(), bytes);
            println!(
                "{:18} {:>10.3} {:>10.3} {:>10.0} {:>14} {:>8.3}",
                strategy.name(),
                s.p50_s * 1e3,
                s.p95_s * 1e3,
                s.req_per_s,
                bytes,
                bytes as f64 / dense_bytes as f64,
            );
            let mut j = Json::obj();
            j.set("bench", Json::Str("model_serve".into()));
            j.set("strategy", Json::Str(strategy.name().into()));
            j.set("layers", jnum(layers as f64));
            j.set("dim", jnum(DIM as f64));
            j.set("d_ff", jnum(D_FF as f64));
            j.set("adapters", jnum(N_ADAPTERS as f64));
            j.set("rank", jnum(RANK as f64));
            j.set("batch", jnum(BATCH as f64));
            j.set("batches", jnum(s.batches as f64));
            j.set("p50_ms", jnum(s.p50_s * 1e3));
            j.set("p95_ms", jnum(s.p95_s * 1e3));
            j.set("req_per_s", jnum(s.req_per_s));
            j.set("resident_base_bytes", jnum(bytes as f64));
            j.set("resident", server.resident_breakdown().to_json());
            println!("BENCH {j}");
            rows.push((
                format!("L{layers}-{}", strategy.name()),
                vec![layers as f64, s.p50_s * 1e3, s.p95_s * 1e3, s.req_per_s, bytes as f64],
            ));
        }

        // Per-layer-count acceptance: fused ≥ 3× the merged baseline,
        // fused-quant ≤ 0.35× the dense resident bytes.
        let speedup = req_per_s["fused"] / req_per_s["merge-per-request"].max(1e-12);
        let bytes_ratio = resident["fused-quant"] as f64 / dense_bytes as f64;
        let speed_ok = speedup >= 3.0;
        let bytes_ok = bytes_ratio <= 0.35;
        all_pass &= speed_ok && bytes_ok;
        println!(
            "layers={layers}: fused {speedup:.1}x merge-per-request (target >= 3x: {}), \
             fused-quant {bytes_ratio:.3}x dense bytes (target <= 0.35x: {})",
            if speed_ok { "PASS" } else { "FAIL" },
            if bytes_ok { "PASS" } else { "FAIL" },
        );
        let mut j = Json::obj();
        j.set("bench", Json::Str("model_serve_summary".into()));
        j.set("layers", jnum(layers as f64));
        j.set("fused_speedup_vs_merge", jnum(speedup));
        j.set("speedup_target", jnum(3.0));
        j.set("quant_bytes_ratio", jnum(bytes_ratio));
        j.set("bytes_target", jnum(0.35));
        j.set("pass", Json::Bool(speed_ok && bytes_ok));
        println!("BENCH {j}");
    }

    println!("\noverall: {}", if all_pass { "PASS" } else { "FAIL" });
    let out = common::results_dir().join("model_serve.csv");
    write_labeled_csv(
        &out,
        &["point", "layers", "p50_ms", "p95_ms", "req_per_s", "resident_base_bytes"],
        &rows,
    )?;
    println!(
        "(rows -> {}; methodology in EXPERIMENTS.md §Full-model serving)",
        out.display()
    );
    Ok(())
}
