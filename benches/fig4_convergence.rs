//! FIGURES 4, 11, 12 — loss / grad-norm / eval-accuracy over training
//! steps for LoRA vs PiSSA vs full-FT ("full data, more epochs").
//! Paper: LLaMA-2-7B (+Mistral, Gemma in App. G) on MetaMathQA-395K,
//! 3 epochs. Here: pre-trained bases on the synthetic corpus, multiple
//! epochs over the analog dataset, eval every K steps.
//!
//! Expected shape: PiSSA's loss drops fastest in the first ~100 steps;
//! its grad norm stays above LoRA's; accuracy dominates LoRA throughout.

mod common;

use pissa::adapter::AdapterSpec;
use pissa::coordinator::{self, RunConfig, TaskFamily};
use pissa::metrics::write_labeled_csv;

fn main() -> anyhow::Result<()> {
    common::banner("Figures 4/11/12", "loss, grad norm & accuracy vs steps");
    let (rt, manifest) = common::load()?;
    let full = common::full_mode();
    let config = if full { "small" } else { "tiny" };
    let steps = if full { 400 } else { 150 };
    let eval_every = steps / 5;
    // model seeds stand in for LLaMA/Mistral/Gemma (Figs 4, 11, 12)
    let models: &[(&str, u64)] =
        if full { &[("llama-an", 42), ("mistral-an", 1337), ("gemma-an", 2024)] } else { &[("llama-an", 42)] };

    for (mname, seed) in models {
        println!("\n--- base model {mname} ---");
        let (base, _) =
            coordinator::pretrain(&rt, &manifest, config, if full { 300 } else { 150 }, 2e-3, *seed)?;
        let mut rows = Vec::new();
        for spec in [AdapterSpec::lora(4), AdapterSpec::pissa(4), AdapterSpec::full_ft()] {
            let run = RunConfig {
                config: config.to_string(),
                spec: spec.clone(),
                steps,
                peak_lr: if spec.is_full_ft() { 5e-4 } else { 2e-3 },
                corpus_size: 1024,
                seed: *seed,
                task: TaskFamily::Math,
            };
            let r = coordinator::finetune(&rt, &manifest, &base, &run)?;
            // log curves
            for m in r.history.iter().step_by((steps / 40).max(1)) {
                rows.push((
                    format!("{}/{}", spec.name(), m.step),
                    vec![m.loss as f64, m.grad_norm as f64],
                ));
            }
            // periodic eval (re-using final state at checkpoints would need
            // snapshots; we report final accuracy + loss curve, and
            // checkpoint-accuracies in full mode via multiple runs)
            let acc = coordinator::evaluate(&rt, &manifest, &run, &r.final_state, 32, 40)?;
            let early = &r.history[steps / 10];
            println!(
                "{:8}: loss@10% {:.4}, final loss {:.4}, mean gnorm {:.4}, acc {:>6.2}%",
                spec.name(),
                early.loss,
                r.final_loss(10),
                r.history.iter().map(|m| m.grad_norm as f64).sum::<f64>() / steps as f64,
                acc
            );
            if eval_every > 0 && full {
                // accuracy-vs-steps series: run shorter budgets
                for frac in [1, 2, 3, 4] {
                    let sub = RunConfig { steps: steps * frac / 5, ..run.clone() };
                    let rr = coordinator::finetune(&rt, &manifest, &base, &sub)?;
                    let a = coordinator::evaluate(&rt, &manifest, &sub, &rr.final_state, 32, 40)?;
                    rows.push((format!("{}/acc@{}", spec.name(), sub.steps), vec![a, 0.0]));
                }
            }
        }
        write_labeled_csv(
            &common::results_dir().join(format!("fig4_curves_{mname}.csv")),
            &["strategy_step", "loss", "grad_norm"],
            &rows,
        )?;
        println!("wrote results/fig4_curves_{mname}.csv");
    }
    Ok(())
}
