//! Integration tests over the full three-layer stack: manifest ↔ state
//! agreement, PJRT training, generation, NLU, checkpoint resume, and
//! cross-language goldens (rust NF4/SVD vs jnp references).
//!
//! These tests need `make artifacts` to have run; they skip (not fail)
//! when artifacts are absent so `cargo test` stays green pre-AOT.

use pissa::adapter::AdapterSpec;
use pissa::coordinator::{self, LrSchedule, RunConfig, Trainer};
use pissa::data::batcher::Batcher;
use pissa::model::{apply_spec, BaseModel};
use pissa::runtime::{Manifest, Runtime};
use pissa::util::json::Json;
use pissa::util::rng::Rng;
use std::path::PathBuf;

fn art_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    art_dir().join("manifest.json").exists()
}

/// One PJRT client per test (PjRtClient is Rc-based and !Send, so it
/// cannot be shared across the test harness's threads).
fn runtime() -> Runtime {
    Runtime::cpu(&art_dir()).expect("PJRT CPU client")
}

fn manifest() -> Manifest {
    Manifest::load(&art_dir()).expect("manifest")
}

#[test]
fn train_step_decreases_loss_for_all_strategies() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let rt = &runtime();
    let manifest = manifest();
    let cfg = manifest.config("tiny").unwrap().clone();
    let mut rng = Rng::new(1);
    let base = BaseModel::random(&cfg, &mut rng);

    for spec in [
        AdapterSpec::pissa(4),
        AdapterSpec::lora(4),
        AdapterSpec::qpissa(4).iters(1),
        AdapterSpec::full_ft(),
    ] {
        let state = apply_spec(&base, &spec, &mut rng).unwrap();
        let art = Manifest::train_name("tiny", 4, spec.is_full_ft());
        let sched = LrSchedule::alpaca(3e-3, 30);
        let mut trainer = Trainer::new(rt, &manifest, &art, state, sched).unwrap();
        let corpus = pissa::data::corpus::gen_corpus(256, 2);
        let mut batcher = Batcher::new(corpus, cfg.batch, cfg.seq_len, 3);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for i in 0..30 {
            let m = trainer.step(&batcher.next_batch()).unwrap();
            assert!(m.loss.is_finite(), "{spec} loss not finite at step {i}");
            if i == 0 {
                first = m.loss;
            }
            last = m.loss;
        }
        assert!(
            last < first,
            "{spec}: loss did not decrease ({first} -> {last})"
        );
    }
}

#[test]
fn pissa_and_lora_start_from_identical_loss() {
    // Both inits preserve the base model exactly (Eq. 5), so step-1 loss
    // on the same batch must match to fp tolerance.
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let rt = &runtime();
    let manifest = manifest();
    let cfg = manifest.config("tiny").unwrap().clone();
    let mut rng = Rng::new(5);
    let base = BaseModel::random(&cfg, &mut rng);
    let mut first_losses = Vec::new();
    for spec in [AdapterSpec::pissa(4), AdapterSpec::lora(4)] {
        let state = apply_spec(&base, &spec, &mut rng).unwrap();
        let mut trainer = Trainer::new(
            rt,
            &manifest,
            &Manifest::train_name("tiny", 4, false),
            state,
            LrSchedule::alpaca(1e-3, 10),
        )
        .unwrap();
        let corpus = pissa::data::corpus::gen_corpus(64, 6);
        let mut batcher = Batcher::new(corpus, cfg.batch, cfg.seq_len, 7);
        first_losses.push(trainer.step(&batcher.next_batch()).unwrap().loss);
    }
    let diff = (first_losses[0] - first_losses[1]).abs();
    assert!(diff < 2e-3, "first-step losses differ: {first_losses:?}");
}

#[test]
fn generator_emits_text_and_eval_runs() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let rt = &runtime();
    let manifest = manifest();
    let run = RunConfig {
        steps: 25,
        corpus_size: 256,
        ..RunConfig::quick("tiny", AdapterSpec::pissa(4))
    };
    let (base, _) = coordinator::pretrain(rt, &manifest, "tiny", 40, 2e-3, 11).unwrap();
    let result = coordinator::finetune(rt, &manifest, &base, &run).unwrap();
    let acc = coordinator::evaluate(rt, &manifest, &run, &result.final_state, 8, 40).unwrap();
    assert!((0.0..=100.0).contains(&acc), "accuracy {acc} out of range");
    // direct generation sanity
    let gen = pissa::eval::Generator::new(
        rt,
        &manifest,
        &Manifest::logits_name("tiny", 4, false),
        &result.final_state,
    )
    .unwrap();
    let outs = gen.generate(&["Tom: 3 apples, +5. Total?".to_string()], 24).unwrap();
    assert_eq!(outs.len(), 1);
}

#[test]
fn encoder_training_works() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let rt = &runtime();
    let manifest = manifest();
    let cfg = manifest.config("enc_tiny").unwrap().clone();
    let mut rng = Rng::new(21);
    let base = BaseModel::random(&cfg, &mut rng);
    let state = apply_spec(&base, &AdapterSpec::pissa(4), &mut rng).unwrap();
    let art = Manifest::enc_train_name("enc_tiny", 4, false, false);
    let mut trainer =
        Trainer::new(rt, &manifest, &art, state, LrSchedule::alpaca(5e-3, 40)).unwrap();

    let task = pissa::data::nlu::NluTask::Sst2;
    let ds = pissa::data::nlu::gen_dataset(task, 256, 22);
    let (b, t) = (cfg.batch, cfg.seq_len);
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 0..40 {
        let lo = (step * b) % (ds.len() - b);
        let mut tokens = vec![0i32; b * t];
        let mut amask = vec![0.0f32; b * t];
        let mut labels = vec![0i32; b];
        for row in 0..b {
            let ex = &ds[lo + row];
            let n = ex.tokens.len().min(t);
            tokens[row * t..row * t + n].copy_from_slice(&ex.tokens[..n]);
            for i in 0..n {
                amask[row * t + i] = 1.0;
            }
            labels[row] = ex.label;
        }
        let m = trainer.step_encoder(&tokens, &amask, &labels).unwrap();
        if step == 0 {
            first = m.loss;
        }
        last = m.loss;
    }
    assert!(last < first, "encoder loss {first} -> {last}");
}

#[test]
fn golden_nf4_matches_python() {
    // Cross-language check: rust NF4 quantizer vs the jnp reference.
    let path = art_dir().join("goldens.json");
    if !path.exists() {
        eprintln!("skipping: no goldens");
        return;
    }
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let input: Vec<f32> = j.req_arr("nf4_input").unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
    let want_codes: Vec<u8> =
        j.req_arr("nf4_codes").unwrap().iter().map(|v| v.as_f64().unwrap() as u8).collect();
    let want_rt: Vec<f32> =
        j.req_arr("nf4_roundtrip").unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();

    let m = pissa::linalg::Mat::from_vec(1, input.len(), input.clone());
    let q = pissa::quant::quantize(&m);
    // unpack rust codes (2 per byte, low nibble first)
    let got_codes: Vec<u8> = (0..input.len())
        .map(|i| {
            let byte = q.codes[i / 2];
            if i % 2 == 0 {
                byte & 0x0F
            } else {
                byte >> 4
            }
        })
        .collect();
    assert_eq!(got_codes, want_codes, "NF4 codes diverge from python");
    let rt = pissa::quant::dequantize(&q);
    for (a, b) in rt.data.iter().zip(&want_rt) {
        assert!((a - b).abs() < 1e-6, "roundtrip {a} vs {b}");
    }
}

#[test]
fn golden_svd_matches_python() {
    let path = art_dir().join("goldens.json");
    if !path.exists() {
        eprintln!("skipping: no goldens");
        return;
    }
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let rows = j.req_usize("svd_rows").unwrap();
    let cols = j.req_usize("svd_cols").unwrap();
    let input: Vec<f32> =
        j.req_arr("svd_input").unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
    let want_s: Vec<f32> = j
        .req_arr("svd_singular_values")
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    let m = pissa::linalg::Mat::from_vec(rows, cols, input);
    let got = pissa::linalg::singular_values(&m);
    for (i, (a, b)) in got.iter().zip(&want_s).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 * (1.0 + b.abs()),
            "σ{i}: rust {a} vs numpy {b}"
        );
    }
}

#[test]
fn checkpoint_resume_reproduces_training() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let rt = &runtime();
    let manifest = manifest();
    let cfg = manifest.config("tiny").unwrap().clone();
    let mut rng = Rng::new(31);
    let base = BaseModel::random(&cfg, &mut rng);

    // Run A: 20 straight steps.
    let corpus = pissa::data::corpus::gen_corpus(256, 32);
    let run_steps = |state: pissa::model::TrainState, start: usize, n: usize| {
        let mut trainer = Trainer::new(
            rt,
            &manifest,
            &Manifest::train_name("tiny", 4, false),
            state,
            LrSchedule::alpaca(2e-3, 20),
        )
        .unwrap();
        // Recreate the same batch stream and skip to `start`.
        let mut batcher = Batcher::new(corpus.clone(), cfg.batch, cfg.seq_len, 33);
        for _ in 0..start {
            let _ = batcher.next_batch();
        }
        for _ in 0..n {
            trainer.step(&batcher.next_batch()).unwrap();
        }
        trainer.state
    };

    let mut rng2 = Rng::new(34);
    let s0 = apply_spec(&base, &AdapterSpec::pissa(4), &mut rng2).unwrap();
    let full = run_steps(s0.clone(), 0, 20);

    // Run B: 10 steps, save/load through the checkpoint container, 10 more.
    let mid = run_steps(s0, 0, 10);
    let dir = std::env::temp_dir().join("pissa_resume_test");
    let path = dir.join("mid.ckpt");
    let mut ckp = pissa::adapter::Checkpoint::new();
    // Save trainable + opt state with distinct prefixes.
    for (k, t) in &mid.trainable {
        ckp.put(&format!("t.{k}"), pissa::linalg::Mat::from_vec(t.numel(), 1, t.data.clone()));
    }
    for (k, t) in &mid.m {
        ckp.put(&format!("m.{k}"), pissa::linalg::Mat::from_vec(t.numel(), 1, t.data.clone()));
    }
    for (k, t) in &mid.v {
        ckp.put(&format!("v.{k}"), pissa::linalg::Mat::from_vec(t.numel(), 1, t.data.clone()));
    }
    ckp.save(&path).unwrap();
    let loaded = pissa::adapter::Checkpoint::load(&path).unwrap();
    let mut resumed = mid.clone();
    for (k, t) in resumed.trainable.iter_mut() {
        t.data = loaded.get(&format!("t.{k}")).unwrap().data.clone();
    }
    for (k, t) in resumed.m.iter_mut() {
        t.data = loaded.get(&format!("m.{k}")).unwrap().data.clone();
    }
    for (k, t) in resumed.v.iter_mut() {
        t.data = loaded.get(&format!("v.{k}")).unwrap().data.clone();
    }
    let resumed_final = run_steps(resumed, 10, 10);

    // Identical final trainable state bit-for-bit (same batches, same lr).
    for (k, t) in &full.trainable {
        assert_eq!(t.data, resumed_final.trainable[k].data, "divergence in {k}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pallas_logits_artifact_matches_jnp_artifact() {
    // The kernel-path artifact and the jnp-path artifact must agree.
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let rt = &runtime();
    let manifest = manifest();
    if !manifest.artifacts.contains_key("logits_tiny_r4_pallas") {
        eprintln!("skipping: pallas artifact absent");
        return;
    }
    let cfg = manifest.config("tiny").unwrap().clone();
    let mut rng = Rng::new(41);
    let base = BaseModel::random(&cfg, &mut rng);
    let state = apply_spec(&base, &AdapterSpec::pissa(4), &mut rng).unwrap();

    let gen_jnp =
        pissa::eval::Generator::new(rt, &manifest, "logits_tiny_r4", &state).unwrap();
    let gen_pal =
        pissa::eval::Generator::new(rt, &manifest, "logits_tiny_r4_pallas", &state).unwrap();
    let b = gen_jnp.batch();
    let t = gen_jnp.seq_len();
    let mut tokens = vec![0i32; b * t];
    for (i, tok) in tokens.iter_mut().enumerate() {
        *tok = (i % 250) as i32 + 8;
    }
    let l1 = gen_jnp.logits(&tokens).unwrap();
    let l2 = gen_pal.logits(&tokens).unwrap();
    assert_eq!(l1.len(), l2.len());
    let mut max_err = 0.0f32;
    for (a, b) in l1.iter().zip(&l2) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 5e-3, "pallas vs jnp logits max err {max_err}");
}
