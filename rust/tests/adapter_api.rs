//! API-migration and round-trip tests for the declarative adapter stack:
//!
//! * the legacy `initialize`/`apply_strategy` path and the new
//!   `AdapterSpec` path produce BIT-IDENTICAL initializations for
//!   equivalent configs (the refactor's no-regression guarantee),
//! * `base + A·B == W` holds (to 1e-5, or the quantized bound) for every
//!   strategy/spec combination,
//! * engine `merge` → `unmerge` restores the original factors,
//! * a `Checkpoint` save/load round-trips an `AdapterSpec` + NF4 blob
//!   pair losslessly.

#![allow(deprecated)] // the migration tests exercise the legacy shims on purpose

use pissa::adapter::init::{self, Strategy, Window};
use pissa::adapter::{AdapterEngine, AdapterError, AdapterSpec, Checkpoint};
use std::path::PathBuf;
use pissa::linalg::{matmul, Mat};
use pissa::model::{apply_spec, apply_strategy, BaseModel};
use pissa::quant::{dequantize, nf4_roundtrip, quantize, Nf4Tensor};
use pissa::runtime::ConfigInfo;
use pissa::util::rng::Rng;

/// A matrix with a decaying (pre-trained-like) spectrum.
fn spectral_mat(m: usize, n: usize, decay: f32, rng: &mut Rng) -> Mat {
    let k = m.min(n);
    let u = pissa::linalg::qr::orthonormalize(&Mat::randn(m, k, 0.0, 1.0, rng));
    let v = pissa::linalg::qr::orthonormalize(&Mat::randn(n, k, 0.0, 1.0, rng));
    let s: Vec<f32> = (0..k).map(|i| (1.0 + i as f32).powf(-decay)).collect();
    let mut us = u;
    us.scale_cols(&s);
    matmul(&us, &v.t())
}

fn tiny_cfg() -> ConfigInfo {
    ConfigInfo {
        name: "api-test".into(),
        kind: "decoder".into(),
        vocab: 128,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        seq_len: 32,
        batch: 4,
        eval_batch: 2,
        n_classes: 0,
        ranks: vec![2, 4],
    }
}

const ALL_STRATEGIES: [Strategy; 6] = [
    Strategy::FullFt,
    Strategy::Lora,
    Strategy::Pissa,
    Strategy::QLora,
    Strategy::QPissa,
    Strategy::LoftQ,
];

// ---------------------------------------------------------------------------
// Migration: old path == new path, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn spec_init_bit_identical_to_legacy_initialize() {
    // Same seed -> same rng stream -> identical matrices, for every
    // strategy at several (rank, iters) points.
    for (si, &strategy) in ALL_STRATEGIES.iter().enumerate() {
        for (ri, &(rank, iters)) in [(2usize, 1usize), (4, 3), (6, 5)].iter().enumerate() {
            let seed = 1000 + (si * 10 + ri) as u64;
            let mut wgen = Rng::new(seed);
            let w = spectral_mat(24, 20, 0.7, &mut wgen);

            let mut rng_old = Rng::new(seed ^ 0xA5A5);
            let old = init::initialize(strategy, &w, rank, iters, &mut rng_old);

            let spec = AdapterSpec::from_strategy(strategy, rank, iters);
            let mut rng_new = Rng::new(seed ^ 0xA5A5);
            let new = spec.init_matrix(&w, rank, &mut rng_new);

            assert_eq!(old.base.data, new.base.data, "{strategy:?} r={rank} T={iters}: base");
            assert_eq!(old.a.data, new.a.data, "{strategy:?} r={rank} T={iters}: A");
            assert_eq!(old.b.data, new.b.data, "{strategy:?} r={rank} T={iters}: B");
        }
    }
}

#[test]
fn apply_spec_bit_identical_to_legacy_apply_strategy() {
    // Whole-model check: identical rng stream order across all seven
    // linears and layers.
    let cfg = tiny_cfg();
    for &(strategy, rank, iters) in &[
        (Strategy::Pissa, 4usize, 1usize),
        (Strategy::Lora, 2, 1),
        (Strategy::QPissa, 2, 2),
        (Strategy::FullFt, 0, 1),
    ] {
        let mut rng_base = Rng::new(7);
        let base = BaseModel::random(&cfg, &mut rng_base);

        let mut rng_old = Rng::new(99);
        let old = apply_strategy(&base, strategy, rank, iters, &mut rng_old).unwrap();
        let mut rng_new = Rng::new(99);
        let new =
            apply_spec(&base, &AdapterSpec::from_strategy(strategy, rank, iters), &mut rng_new)
                .unwrap();

        assert_eq!(
            old.trainable.keys().collect::<Vec<_>>(),
            new.trainable.keys().collect::<Vec<_>>()
        );
        for (k, t) in &old.trainable {
            assert_eq!(t.data, new.trainable[k].data, "{strategy:?}: trainable {k}");
        }
        for (k, t) in &old.frozen {
            assert_eq!(t.data, new.frozen[k].data, "{strategy:?}: frozen {k}");
        }
    }
}

// ---------------------------------------------------------------------------
// (a) base + A·B == W for every strategy/spec combination
// ---------------------------------------------------------------------------

#[test]
fn prop_exactness_holds_for_every_spec_combination() {
    let variants: Vec<Box<dyn Fn(AdapterSpec) -> AdapterSpec>> = vec![
        Box::new(|s| s),
        Box::new(|s| s.iters(1)),
        Box::new(|s| s.alpha(32.0)),
        Box::new(|s| s.targets(&["q", "v", "down"])),
        Box::new(|s| s.target_rank("q", 6)),
    ];
    for seed in 0..4u64 {
        let mut rng = Rng::new(2000 + seed);
        let w = spectral_mat(32, 28, 0.6, &mut rng);
        let quant_bound = w.sub(&nf4_roundtrip(&w)).fro() * 1.05 + 1e-9;
        for &strategy in &ALL_STRATEGIES {
            if strategy == Strategy::FullFt {
                continue; // no factor decomposition to check
            }
            for make in &variants {
                let spec = make(AdapterSpec::new(strategy, 4));
                let rank = spec.module_rank("q");
                let init = spec.init_matrix(&w, rank, &mut rng);
                let err = init.effective().sub(&w).fro();
                if spec.quantized() {
                    // Structural invariant: the frozen base is an NF4
                    // fixed point…
                    let refix = init.base.sub(&nf4_roundtrip(&init.base)).fro();
                    assert!(refix < 1e-5 * (1.0 + init.base.fro()), "seed={seed} {spec}: base not NF4-fixed");
                    // …and at standard scaling the paper's claim holds:
                    // error bounded by the plain QLoRA round-trip.
                    if spec.scaling() == 1.0 {
                        assert!(
                            err <= quant_bound,
                            "seed={seed} {spec}: err {err:.3e} > quantized bound {quant_bound:.3e}"
                        );
                    }
                } else {
                    let rel = err / w.fro();
                    assert!(rel < 1e-5, "seed={seed} {spec}: rel err {rel:.3e}");
                }
            }
        }
        // Window ablation variants (exact SVD) preserve W too.
        for window in [Window::Principal, Window::Medium, Window::Minor] {
            let spec = AdapterSpec::pissa(4).exact_svd().window(window);
            let init = spec.init_matrix(&w, 4, &mut rng);
            let rel = init.effective().sub(&w).fro() / w.fro();
            assert!(rel < 1e-5, "seed={seed} window={window:?}: rel err {rel:.3e}");
        }
    }
}

// ---------------------------------------------------------------------------
// (b) merge → unmerge restores the original factors
// ---------------------------------------------------------------------------

#[test]
fn prop_merge_unmerge_restores_factors() {
    let cfg = tiny_cfg();
    for (seed, spec) in [
        (0u64, AdapterSpec::pissa(4)),
        (1, AdapterSpec::lora(2).alpha(8.0)),
        (2, AdapterSpec::pissa(3).targets(&["q", "v"]).target_rank("q", 5)),
        (3, AdapterSpec::qpissa(2).iters(2)),
    ] {
        let mut rng = Rng::new(3000 + seed);
        let base = BaseModel::random(&cfg, &mut rng);
        let mut engine = AdapterEngine::new(base);
        engine.attach("ad", spec.clone(), &mut rng).unwrap();

        // drift the factors a little (merge must work on trained adapters)
        let modules: Vec<String> =
            engine.get("ad").unwrap().spec.target_modules().iter().map(|s| s.to_string()).collect();
        for module in &modules {
            let (mut a, mut b) = {
                let ad = engine.get("ad").unwrap();
                (ad.factors[&format!("a_{module}")].layer(0), ad.factors[&format!("b_{module}")].layer(0))
            };
            for x in a.data.iter_mut() {
                *x += 0.02 * rng.normal_f32(0.0, 1.0);
            }
            for x in b.data.iter_mut() {
                *x += 0.02 * rng.normal_f32(0.0, 1.0);
            }
            engine.set_factors("ad", module, 0, &a, &b).unwrap();
        }

        let factors_before = engine.get("ad").unwrap().factors.clone();
        let frozen_before = engine.get("ad").unwrap().frozen.clone();
        let eff_before = engine.effective_weight_of("ad", modules[0].as_str(), 0).unwrap();

        engine.merge("ad").unwrap();
        let eff_merged = engine.effective_weight_of("ad", modules[0].as_str(), 0).unwrap();
        assert_eq!(eff_merged.data, eff_before.data, "{spec}: merged == base + A·B");
        engine.unmerge("ad").unwrap();

        let ad = engine.get("ad").unwrap();
        for (k, t) in &factors_before {
            assert_eq!(t.data, ad.factors[k].data, "{spec}: factor {k} not restored");
        }
        for (k, t) in &frozen_before {
            assert_eq!(t.data, ad.frozen[k].data, "{spec}: frozen {k} changed");
        }
        let eff_after = engine.effective_weight_of("ad", modules[0].as_str(), 0).unwrap();
        assert_eq!(eff_after.data, eff_before.data);
    }
}

// ---------------------------------------------------------------------------
// (c) Checkpoint round-trips an AdapterSpec + NF4 blob pair losslessly
// ---------------------------------------------------------------------------

#[test]
fn prop_checkpoint_spec_and_nf4_pair_roundtrip() {
    for seed in 0..4u64 {
        let mut rng = Rng::new(4000 + seed);
        let w = Mat::randn(16 + seed as usize * 8, 24, 0.0, 0.5, &mut rng);
        let q = quantize(&w);

        // the spec + the <name>.codes / <name>.scales entry pair
        let spec = AdapterSpec::qpissa(4).iters(3).targets(&["q", "up"]).target_rank("up", 2);
        let mut ckp = Checkpoint::new();
        ckp.spec = Some(spec.clone());
        ckp.put_blob("base_q.codes", q.codes.clone());
        let scale_bytes: Vec<u8> = q.scales.iter().flat_map(|s| s.to_le_bytes()).collect();
        ckp.put_blob("base_q.scales", scale_bytes);
        ckp.put_blob(
            "base_q.dims",
            [q.rows as u64, q.cols as u64].iter().flat_map(|d| d.to_le_bytes()).collect(),
        );

        let dir = std::env::temp_dir().join(format!("pissa_api_nf4_{seed}"));
        let path = dir.join("nf4.ckpt");
        ckp.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();

        // spec survives, byte for byte of meaning
        assert_eq!(back.spec, Some(spec));
        // codes + scales are lossless
        assert_eq!(back.blobs["base_q.codes"], q.codes);
        let scales_back: Vec<f32> = back.blobs["base_q.scales"]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(scales_back, q.scales);
        let dims: Vec<u64> = back.blobs["base_q.dims"]
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();

        // reassemble and dequantize: identical to the original round trip
        let q2 = Nf4Tensor {
            rows: dims[0] as usize,
            cols: dims[1] as usize,
            codes: back.blobs["base_q.codes"].clone(),
            scales: scales_back,
        };
        assert_eq!(dequantize(&q2).data, dequantize(&q).data);
    }
}

// ---------------------------------------------------------------------------
// End-to-end: registry semantics over one frozen base
// ---------------------------------------------------------------------------

#[test]
fn engine_serves_multiple_adapters_over_one_base() {
    let cfg = tiny_cfg();
    let mut rng = Rng::new(5000);
    let base = BaseModel::random(&cfg, &mut rng);
    let w_q = base.linears["base_q"].layer(0);
    let mut engine = AdapterEngine::new(base);

    engine.attach("pissa-qv", AdapterSpec::pissa(8).targets(&["q", "v"]), &mut rng).unwrap();
    engine.attach("lora-all", AdapterSpec::lora(4), &mut rng).unwrap();

    // both preserve W at init; hot-swap flips which one serves
    for name in ["pissa-qv", "lora-all"] {
        engine.swap(name).unwrap();
        let eff = engine.effective_weight("q", 0).unwrap();
        assert!(eff.sub(&w_q).fro() / w_q.fro() < 1e-5, "{name} must preserve W");
    }
    // the two adapters hold DIFFERENT factorizations of the same W
    let a_p = engine.get("pissa-qv").unwrap().factors["a_q"].clone();
    let a_l = engine.get("lora-all").unwrap().factors["a_q"].clone();
    assert_ne!(a_p.shape, a_l.shape); // r=8 vs r=4
    // PiSSA's adapter carries principal mass; LoRA's B is zero
    assert!(engine.get("lora-all").unwrap().factors["b_q"].fro() == 0.0);
    assert!(engine.get("pissa-qv").unwrap().factors["b_q"].fro() > 0.0);

    // export the PiSSA adapter as an Appendix-C delta (validated inside)
    let deltas = engine.to_lora_delta("pissa-qv").unwrap();
    let keys: Vec<&str> = deltas.keys().map(|s| s.as_str()).collect();
    assert_eq!(keys, vec!["q", "v"]);
}

// ---------------------------------------------------------------------------
// Attach atomicity: a failing attach_saved leaves the engine unchanged
// ---------------------------------------------------------------------------

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures").join(name)
}

/// Full engine-state fingerprint: attached names, active selection, and
/// every byte of every resident tensor.
fn fingerprint(engine: &AdapterEngine) -> (Vec<String>, Option<String>, Vec<(String, Vec<f32>)>) {
    let names: Vec<String> = engine.names().iter().map(|s| s.to_string()).collect();
    let mut tensors = Vec::new();
    for name in &names {
        let ad = engine.get(name).unwrap();
        for (prefix, store) in
            [("frozen", &ad.frozen), ("factors", &ad.factors), ("init", &ad.init_factors)]
        {
            for (k, t) in store.iter() {
                tensors.push((format!("{name}/{prefix}.{k}"), t.data.clone()));
            }
        }
    }
    (names, engine.active().map(|s| s.to_string()), tensors)
}

#[test]
fn attach_saved_failure_leaves_engine_unchanged() {
    let cfg = tiny_cfg();
    let mut rng = Rng::new(6000);
    let base = BaseModel::random(&cfg, &mut rng);
    let mut engine = AdapterEngine::new(base);
    engine.attach("keep", AdapterSpec::pissa(4), &mut rng).unwrap();
    let before = fingerprint(&engine);

    // Committed corrupt fixtures: wrong magic, a mat entry whose header
    // claims more payload than the file holds, and a well-formed v1
    // container (no spec entry → not attachable as an adapter).
    for fx in ["bad_magic.ckpt", "truncated.ckpt", "v1_no_spec.ckpt"] {
        let err = engine.attach_saved("incoming", &fixture(fx)).unwrap_err();
        assert!(
            engine.get("incoming").is_err(),
            "{fx}: failed attach must not leave a partial adapter ({err:#})"
        );
        assert_eq!(fingerprint(&engine), before, "{fx}: engine changed by a failed attach");
    }
    // The v1 fixture parses fine — it fails with the TYPED missing-spec
    // error, naming the file.
    let err = engine.attach_saved("incoming", &fixture("v1_no_spec.ckpt")).unwrap_err();
    let ae = err.downcast_ref::<AdapterError>().expect("typed error");
    assert!(matches!(ae, AdapterError::NoSpec { path } if path.contains("v1_no_spec")));

    // Deepest validation failure: a checkpoint whose shapes all match but
    // which was saved against a DIFFERENT base model, so the attach-time
    // decomposition check rejects it mid-validation.
    let mut other_rng = Rng::new(6001);
    let other_base = BaseModel::random(&cfg, &mut other_rng);
    let mut other = AdapterEngine::new(other_base);
    other.attach("alien", AdapterSpec::pissa(4), &mut other_rng).unwrap();
    let dir = std::env::temp_dir().join("pissa_api_atomicity");
    let path = dir.join("alien.ckpt");
    other.save("alien", &path).unwrap();

    let err = engine.attach_saved("incoming", &path).unwrap_err();
    assert!(
        format!("{err:#}").contains("does not decompose"),
        "expected the decomposition check to fire, got: {err:#}"
    );
    assert!(engine.get("incoming").is_err());
    assert_eq!(fingerprint(&engine), before, "mid-validation failure mutated the engine");

    // And the happy path still works after all those failures.
    engine.save("keep", &dir.join("keep.ckpt")).unwrap();
    engine.attach_saved("copy", &dir.join("keep.ckpt")).unwrap();
    assert!(engine.get("copy").is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Typed adapter errors: enum variants carry context + wire mapping
// ---------------------------------------------------------------------------

#[test]
fn adapter_errors_are_typed_with_context() {
    let cfg = tiny_cfg();
    let mut rng = Rng::new(6100);
    let base = BaseModel::random(&cfg, &mut rng);
    let mut engine = AdapterEngine::new(base);
    engine.attach("a", AdapterSpec::pissa(2), &mut rng).unwrap();
    engine.attach("b", AdapterSpec::lora(2), &mut rng).unwrap();

    // Unknown: names both the request and the available set.
    let err = engine.swap("ghost").unwrap_err();
    match err.downcast_ref::<AdapterError>() {
        Some(AdapterError::Unknown { name, have }) => {
            assert_eq!(name, "ghost");
            assert_eq!(have, &vec!["a".to_string(), "b".to_string()]);
        }
        other => panic!("expected Unknown, got {other:?}"),
    }
    assert_eq!(err.downcast_ref::<AdapterError>().unwrap().http_status(), 404);

    // AlreadyAttached: duplicate names conflict (409).
    let err = engine.attach("a", AdapterSpec::pissa(2), &mut rng).unwrap_err();
    let ae = err.downcast_ref::<AdapterError>().unwrap();
    assert!(matches!(ae, AdapterError::AlreadyAttached { name } if name == "a"));
    assert_eq!(ae.http_status(), 409);

    // EmptyName / FullFt: unprocessable requests (422).
    let err = engine.attach("", AdapterSpec::pissa(2), &mut rng).unwrap_err();
    assert!(matches!(err.downcast_ref::<AdapterError>(), Some(AdapterError::EmptyName)));
    let err = engine.attach("ft", AdapterSpec::full_ft(), &mut rng).unwrap_err();
    assert!(matches!(err.downcast_ref::<AdapterError>(), Some(AdapterError::FullFtNotAnAdapter)));

    // Merged: detaching a merged adapter conflicts until unmerged.
    engine.merge("a").unwrap();
    let err = engine.detach("a").unwrap_err();
    let ae = err.downcast_ref::<AdapterError>().unwrap();
    assert!(matches!(ae, AdapterError::Merged { name } if name == "a"));
    assert_eq!(ae.http_status(), 409);
    engine.unmerge("a").unwrap();
    engine.detach("a").unwrap();

    // Every variant exposes a stable machine-readable code.
    assert_eq!(AdapterError::EmptyName.code(), "empty_adapter_name");
    assert_eq!(
        AdapterError::Unknown { name: "x".into(), have: vec![] }.code(),
        "unknown_adapter"
    );
}
