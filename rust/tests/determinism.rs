//! Thread-count determinism: GEMM and serving results must be
//! BIT-IDENTICAL under `PISSA_THREADS=1` and `PISSA_THREADS=8`.
//!
//! This locks in the fixed-order reduction contract of `util::par`:
//! parallelism only ever partitions independent output regions (rows,
//! column panels, adapter groups); every accumulated element is summed in
//! the same k-order regardless of how the partitions land on threads. CI
//! additionally runs the whole suite under both thread counts.
//!
//! The tests in this binary pin the process-global parallelism degree
//! (via `util::par::with_parallelism` — the cached `PISSA_THREADS` parse
//! is process-wide), so they serialize on a shared lock (cargo runs
//! `#[test]`s concurrently).

use pissa::adapter::{AdapterEngine, AdapterSpec};
use pissa::linalg::{
    dequant_matmul, dequant_matmul_panel, matmul, matmul_nt, matmul_tn, vecmat, Mat,
};
use pissa::model::{BaseModel, LINEARS};
use pissa::quant::{dequantize, quantize};
use pissa::runtime::ConfigInfo;
use pissa::serve::{
    attn_streamed_into, drift_factors, DecodeRequest, DecodeScheduler, KvCache, ModelRequest,
    ModelServer, Request, SeqRequest, ServeConfig, ServeStrategy, Server, KV_PAGE,
};
use pissa::util::rng::Rng;
use std::sync::Mutex;

static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` under a pinned parallelism degree, restoring the previous
/// setting afterwards. Callers must hold ENV_LOCK (the override is
/// process-global). Uses the scoped in-process override rather than the
/// `PISSA_THREADS` env var: the env parse is cached once per process, so
/// mutating the environment mid-run would silently pin every comparison
/// to the first value seen.
fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    pissa::util::par::with_parallelism(n, f)
}

#[test]
fn gemm_kernels_bit_identical_across_thread_counts() {
    let _guard = ENV_LOCK.lock().unwrap();
    let mut rng = Rng::new(1);
    // Shapes chosen to actually hit the parallel paths: the blocked
    // micro-kernel (rows ≥ 2·16), the nt row sweep, and the tn panel
    // kernel (multiple column panels).
    let a = Mat::randn(129, 70, 0.0, 1.0, &mut rng);
    let b = Mat::randn(70, 300, 0.0, 1.0, &mut rng);
    let at = a.t();
    let bt = b.t();
    let skinny = Mat::randn(70, 24, 0.0, 1.0, &mut rng); // k=70 panel operand

    let run = || {
        (
            matmul(&a, &b),
            matmul_nt(&a, &bt),
            matmul_tn(&at, &b),     // m=129 > cap: wide fallback path
            matmul_tn(&skinny, &b), // 24×300: panel kernel, 3 column panels
        )
    };
    let t1 = with_threads(1, run);
    let t8 = with_threads(8, run);
    assert_eq!(t1.0.data, t8.0.data, "matmul drifted across thread counts");
    assert_eq!(t1.1.data, t8.1.data, "matmul_nt drifted across thread counts");
    assert_eq!(t1.2.data, t8.2.data, "matmul_tn (wide fallback) drifted");
    assert_eq!(t1.3.data, t8.3.data, "matmul_tn (panel kernel) drifted");
}

#[test]
fn dequant_gemm_bit_identical_across_threads_and_panel_sizes() {
    let _guard = ENV_LOCK.lock().unwrap();
    let mut rng = Rng::new(3);
    // 70 rows × 300 cols of NF4: each row is 300 values, so every panel
    // boundary lands mid-block (300 % 64 != 0) — the ragged case the
    // streaming decode must handle identically to a full dequantize.
    let x_big = Mat::randn(40, 70, 0.0, 1.0, &mut rng); // parallel row path
    let x_one = Mat::randn(1, 70, 0.0, 1.0, &mut rng); // inline path
    let w = quantize(&Mat::randn(70, 300, 0.0, 0.5, &mut rng));

    // Reference: dequantize once, dense GEMM (single-threaded so the
    // reference itself is pinned).
    let want_big = with_threads(1, || matmul(&x_big, &dequantize(&w)));
    let want_one = with_threads(1, || matmul(&x_one, &dequantize(&w)));

    // Panel heights that don't divide the NF4 block size (and one that
    // exceeds k): the ascending-p accumulation makes both the panel
    // split and the thread split invisible.
    for panel in [1usize, 3, 37, 63, 64, 100] {
        let run = || {
            (dequant_matmul_panel(&x_big, &w, panel), dequant_matmul_panel(&x_one, &w, panel))
        };
        let t1 = with_threads(1, run);
        let t8 = with_threads(8, run);
        assert_eq!(t1.0.data, t8.0.data, "panel={panel}: thread drift (parallel path)");
        assert_eq!(t1.1.data, t8.1.data, "panel={panel}: thread drift (inline path)");
        assert_eq!(t1.0.data, want_big.data, "panel={panel}: diverged from dequant-once");
        assert_eq!(t1.1.data, want_one.data, "panel={panel}: diverged from dequant-once");
    }
    let d1 = with_threads(1, || dequant_matmul(&x_big, &w));
    let d8 = with_threads(8, || dequant_matmul(&x_big, &w));
    assert_eq!(d1.data, d8.data, "default-panel dequant_matmul drifted");
    assert_eq!(d1.data, want_big.data);
}

#[test]
fn packed_kernel_edge_shapes_bit_identical() {
    // The register-tiled packed kernel has partial tiles in every
    // dimension (m % MR, n % NR, k % KC) plus small/skinny dispatch
    // cutoffs; each edge shape must be bit-identical across thread
    // counts AND to the single-row kernel swept row by row (the decode
    // fast path's structural contract).
    let _guard = ENV_LOCK.lock().unwrap();
    let mut rng = Rng::new(31);
    for &(m, k, n) in &[
        (3usize, 64usize, 64usize), // threads > rows (skinny sweep)
        (40, 300, 48),              // k spans two KC panels, ragged tail
        (33, 70, 5),                // n < NR: one partial strip
        (2, 80, 300),               // m < MR above the small cutoff
        (64, 257, 96),              // k = KC + 1, several row chunks
    ] {
        let a = Mat::randn(m, k, 0.0, 1.0, &mut rng);
        let b = Mat::randn(k, n, 0.0, 1.0, &mut rng);
        let t1 = with_threads(1, || matmul(&a, &b));
        let t8 = with_threads(8, || matmul(&a, &b));
        assert_eq!(t1.data, t8.data, "{m}x{k}x{n}: thread drift");
        for i in 0..m {
            let y = with_threads(8, || vecmat(a.row(i), &b));
            assert_eq!(
                y.as_slice(),
                t1.row(i),
                "{m}x{k}x{n} row {i}: row kernel diverged from packed kernel"
            );
        }
    }
}

#[test]
fn packed_nf4_kernel_block_straddling_panels_bit_identical() {
    // NF4 scales are per-64-value-block over the FLATTENED buffer, so
    // packed panels and register strips routinely straddle block
    // boundaries mid-row (n % 64 != 0). Every (shape × panel × threads)
    // combination must reproduce the dequantize-then-matmul reference
    // bit for bit.
    let _guard = ENV_LOCK.lock().unwrap();
    let mut rng = Rng::new(33);
    for &(m, k, n) in &[
        (3usize, 70usize, 37usize), // skinny sweep, ragged blocks
        (9, 130, 5),                // packed path, n < NR
        (40, 70, 300),              // packed path, parallel row chunks
    ] {
        let x = Mat::randn(m, k, 0.0, 1.0, &mut rng);
        let w = quantize(&Mat::randn(k, n, 0.0, 0.5, &mut rng));
        let want = with_threads(1, || matmul(&x, &dequantize(&w)));
        for panel in [1usize, 63, 64, 65, 100] {
            let p1 = with_threads(1, || dequant_matmul_panel(&x, &w, panel));
            let p8 = with_threads(8, || dequant_matmul_panel(&x, &w, panel));
            assert_eq!(p1.data, p8.data, "{m}x{k}x{n} panel={panel}: thread drift");
            assert_eq!(
                p1.data, want.data,
                "{m}x{k}x{n} panel={panel}: diverged from dequant-once reference"
            );
        }
    }
}

#[test]
fn serving_bit_identical_across_thread_counts() {
    let _guard = ENV_LOCK.lock().unwrap();
    let cfg = ConfigInfo {
        name: "determinism".into(),
        kind: "decoder".into(),
        vocab: 64,
        d_model: 48,
        n_layers: 1,
        n_heads: 2,
        d_ff: 64,
        seq_len: 8,
        batch: 4,
        eval_batch: 2,
        n_classes: 0,
        ranks: vec![4],
    };
    // Build the engine once (under a pinned thread count, though attach
    // determinism is not what's under test here).
    let (engine, requests) = with_threads(1, || {
        let mut rng = Rng::new(5);
        let base = BaseModel::random(&cfg, &mut rng);
        let mut engine = AdapterEngine::new(base);
        for name in ["t0", "t1", "t2", "t3"] {
            engine.attach(name, AdapterSpec::pissa(4).targets(&["q"]), &mut rng).unwrap();
            drift_factors(&mut engine, name, "q", 0.05, &mut rng).unwrap();
        }
        let requests: Vec<Request> = (0..64)
            .map(|i| {
                let mut x = vec![0.0f32; 48];
                rng.fill_normal(&mut x, 0.0, 1.0);
                if i % 5 == 4 {
                    Request::base(x)
                } else {
                    Request::new(["t0", "t1", "t2", "t3"][i % 4], x)
                }
            })
            .collect();
        (engine, requests)
    });

    for strategy in ServeStrategy::all() {
        let run = || {
            let mut server = Server::new(
                &engine,
                ServeConfig::new("q").strategy(strategy).max_batch(64),
            )
            .unwrap();
            server.forward(&requests).unwrap()
        };
        let y1 = with_threads(1, run);
        let y8 = with_threads(8, run);
        assert_eq!(
            y1.data,
            y8.data,
            "strategy {} drifted across thread counts",
            strategy.name()
        );
    }
}

#[test]
fn full_model_serving_bit_identical_across_thread_counts() {
    // The whole-model pipeline is a long chain of parallel GEMMs (L×7
    // per batch) interleaved with fixed-order elementwise math; one
    // nondeterministic reduction anywhere in it would show up here.
    let _guard = ENV_LOCK.lock().unwrap();
    let cfg = ConfigInfo {
        name: "model-determinism".into(),
        kind: "decoder".into(),
        vocab: 32,
        d_model: 48,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        seq_len: 8,
        batch: 4,
        eval_batch: 2,
        n_classes: 0,
        ranks: vec![4],
    };
    let (engine, requests) = with_threads(1, || {
        let mut rng = Rng::new(9);
        let base = BaseModel::random(&cfg, &mut rng);
        let mut engine = AdapterEngine::new(base);
        for name in ["t0", "t1", "t2"] {
            engine.attach(name, AdapterSpec::pissa(4), &mut rng).unwrap();
            for module in LINEARS {
                drift_factors(&mut engine, name, module, 0.05, &mut rng).unwrap();
            }
        }
        let requests: Vec<ModelRequest> = (0..32)
            .map(|i| {
                if i % 5 == 4 {
                    ModelRequest::base(i % 32)
                } else {
                    ModelRequest::new(["t0", "t1", "t2"][i % 3], (i * 7) % 32)
                }
            })
            .collect();
        (engine, requests)
    });

    for strategy in ServeStrategy::all() {
        let run = || {
            let mut server = ModelServer::new(
                &engine,
                ServeConfig::full_model().strategy(strategy).max_batch(64),
            )
            .unwrap();
            server.forward(&requests).unwrap()
        };
        let y1 = with_threads(1, run);
        let y8 = with_threads(8, run);
        assert_eq!(
            y1.data,
            y8.data,
            "full-model strategy {} drifted across thread counts",
            strategy.name()
        );
    }
}

#[test]
fn full_decode_trajectories_bit_identical_across_thread_counts() {
    // The decode pipeline adds three parallel surfaces on top of the
    // forward — per-position attention (par_rows_mut over the batch),
    // K/V cache writes, and the continuous-batching step loop. A whole
    // workload's every sampled token (and the prefill logits that chose
    // it) must be bit-identical under PISSA_THREADS=1 and 8, for every
    // serving strategy.
    let _guard = ENV_LOCK.lock().unwrap();
    let cfg = ConfigInfo {
        name: "decode-determinism".into(),
        kind: "decoder".into(),
        vocab: 32,
        d_model: 48,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        seq_len: 8,
        batch: 4,
        eval_batch: 2,
        n_classes: 0,
        ranks: vec![4],
    };
    let (engine, workload) = with_threads(1, || {
        let mut rng = Rng::new(21);
        let base = BaseModel::random(&cfg, &mut rng);
        let mut engine = AdapterEngine::new(base);
        for name in ["t0", "t1", "t2"] {
            engine.attach(name, AdapterSpec::pissa(4), &mut rng).unwrap();
            for module in LINEARS {
                drift_factors(&mut engine, name, module, 0.05, &mut rng).unwrap();
            }
        }
        let workload: Vec<SeqRequest> = (0..10)
            .map(|i| {
                let prompt: Vec<usize> = (0..(2 + i % 3)).map(|j| (i * 11 + j * 3) % 32).collect();
                if i % 4 == 3 {
                    SeqRequest::base(prompt, 6)
                } else {
                    SeqRequest::new(["t0", "t1", "t2"][i % 3], prompt, 6)
                }
            })
            .collect();
        (engine, workload)
    });

    for strategy in ServeStrategy::all() {
        let run = || {
            let mut server = ModelServer::new(
                &engine,
                ServeConfig::full_model().strategy(strategy).max_seq(16).slots(4),
            )
            .unwrap();
            let mut cache = server.new_cache().unwrap();
            let mut sched = DecodeScheduler::new();
            for r in &workload {
                sched.submit(r.clone());
            }
            let fin = sched.run_sorted(&mut server, &mut cache).unwrap();
            fin.into_iter().map(|f| f.tokens).collect::<Vec<_>>()
        };
        let t1 = with_threads(1, run);
        let t8 = with_threads(8, run);
        assert_eq!(
            t1,
            t8,
            "decode trajectories drifted across thread counts (strategy {})",
            strategy.name()
        );

        // Trajectories compare post-argmax; also pin the RAW logits of a
        // prefill and a mixed-adapter decode step.
        let probe = || {
            let mut server = ModelServer::new(
                &engine,
                ServeConfig::full_model().strategy(strategy).max_seq(16).slots(4),
            )
            .unwrap();
            let mut cache = server.new_cache().unwrap();
            let s0 = cache.try_claim(8).unwrap().unwrap();
            let l0 = server.prefill(&mut cache, s0, Some("t0"), &[1, 2, 3]).unwrap();
            let s1 = cache.try_claim(8).unwrap().unwrap();
            server.prefill(&mut cache, s1, None, &[4, 5]).unwrap();
            let reqs = vec![
                DecodeRequest { slot: s0, token: 7, adapter: Some("t0".into()) },
                DecodeRequest { slot: s1, token: 9, adapter: None },
            ];
            let lm = server.decode_step(&mut cache, &reqs).unwrap();
            (l0, lm.data)
        };
        let p1 = with_threads(1, probe);
        let p8 = with_threads(8, probe);
        assert_eq!(p1, p8, "decode logits drifted across thread counts ({})", strategy.name());
    }
}

#[test]
fn streamed_attention_bit_identical_to_reference_across_pages_and_threads() {
    // The page-streaming kernel walks K/V as contiguous page runs and
    // computes a whole GQA group per hot span, but its arithmetic must
    // be EXACTLY the position-at-a-time reference: one mul-add per
    // element, ascending position order, per-head running max in the
    // same order. Pin bit-identity at contexts around the page
    // boundary (KV_PAGE − 1, KV_PAGE, KV_PAGE + 1, 2·KV_PAGE + 1) for
    // every group shape, under both thread counts — the kernel itself
    // is sequential, so the thread sweep pins that no parallelism
    // leaked inside it.
    let _guard = ENV_LOCK.lock().unwrap();
    let (n_heads, hd) = (4usize, 8usize);
    let ctxs = [KV_PAGE - 1, KV_PAGE, KV_PAGE + 1, 2 * KV_PAGE + 1];
    let fill = 2 * KV_PAGE + 1;
    for n_kv in [1usize, 2, 4] {
        let kv_dim = n_kv * hd;
        let mut rng = Rng::new(1000 + n_kv as u64);
        let mut cache = KvCache::new(1, kv_dim, 64, 1, 1 << 20).unwrap();
        let slot = cache.try_claim(fill).unwrap().unwrap();
        for _ in 0..fill {
            let k: Vec<f32> = (0..kv_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..kv_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            cache.append(slot, 0, &k, &v);
            cache.advance(slot, 1);
        }
        let q: Vec<f32> = (0..n_heads * hd).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for n_ctx in ctxs {
            // Position-at-a-time reference: the pre-streaming kernel.
            let group = n_heads / n_kv;
            let scale = 1.0 / (hd as f32).sqrt();
            let mut want = vec![0.0f32; n_heads * hd];
            for h in 0..n_heads {
                let kv_off = (h / group) * hd;
                let qh = &q[h * hd..(h + 1) * hd];
                let mut scores = Vec::new();
                let mut max = f32::NEG_INFINITY;
                for j in 0..n_ctx {
                    let k = &cache.k_row(slot, 0, j)[kv_off..kv_off + hd];
                    let mut dot = 0.0f32;
                    for (qv, kv) in qh.iter().zip(k) {
                        dot += qv * kv;
                    }
                    let s = dot * scale;
                    if s > max {
                        max = s;
                    }
                    scores.push(s);
                }
                let mut sum = 0.0f32;
                for s in scores.iter_mut() {
                    *s = (*s - max).exp();
                    sum += *s;
                }
                let oh = &mut want[h * hd..(h + 1) * hd];
                for (j, &w) in scores.iter().enumerate() {
                    let v = &cache.v_row(slot, 0, j)[kv_off..kv_off + hd];
                    for (ov, vv) in oh.iter_mut().zip(v) {
                        *ov += w * vv;
                    }
                }
                let inv = 1.0 / sum;
                for ov in oh.iter_mut() {
                    *ov *= inv;
                }
            }
            for threads in [1usize, 8] {
                let got = with_threads(threads, || {
                    let mut scratch = Vec::new();
                    let mut out = vec![0.0f32; n_heads * hd];
                    attn_streamed_into(
                        &cache, slot, 0, &q, n_ctx, n_heads, n_kv, &mut scratch, &mut out,
                    );
                    out
                });
                let bits_equal =
                    got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(
                    bits_equal,
                    "streamed kernel diverged from reference (n_kv {n_kv}, n_ctx {n_ctx}, \
                     threads {threads})"
                );
            }
        }
        cache.release(slot);
    }
}

#[test]
fn page_straddling_decode_trajectories_bit_identical_across_thread_counts() {
    // Whole-path twin of the kernel test above: prompts LONGER than a
    // KV page, decoded past the second page boundary, so the
    // head×sequence `par_items` dispatch and the streamed kernel both
    // cross page runs mid-trajectory. Every group shape of the serving
    // config (MHA, GQA, MQA-like 4:1) must emit bit-identical token
    // trajectories under 1 and 8 threads. Attention is
    // strategy-independent, so `fused` alone covers the surface (the
    // strategy sweep lives in the short-context tests).
    let _guard = ENV_LOCK.lock().unwrap();
    let cfg = ConfigInfo {
        name: "page-straddle-determinism".into(),
        kind: "decoder".into(),
        vocab: 32,
        d_model: 48, // 4 heads -> head_dim 12 (even, RoPE-able)
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 8,
        batch: 4,
        eval_batch: 2,
        n_classes: 0,
        ranks: vec![4],
    };
    let (engine, workload) = with_threads(1, || {
        let mut rng = Rng::new(41);
        let base = BaseModel::random(&cfg, &mut rng);
        let mut engine = AdapterEngine::new(base);
        for name in ["t0", "t1"] {
            engine.attach(name, AdapterSpec::pissa(4), &mut rng).unwrap();
            for module in LINEARS {
                drift_factors(&mut engine, name, module, 0.05, &mut rng).unwrap();
            }
        }
        // Prompts of KV_PAGE + {2..5} tokens, 15 generated: trajectories
        // start past one page boundary and decode across the next.
        let workload: Vec<SeqRequest> = (0..4)
            .map(|i| {
                let plen = KV_PAGE + 2 + i;
                let prompt: Vec<usize> = (0..plen).map(|j| (i * 13 + j * 5) % 32).collect();
                if i % 2 == 0 {
                    SeqRequest::base(prompt, 15)
                } else {
                    SeqRequest::new(["t0", "t1"][i % 2], prompt, 15)
                }
            })
            .collect();
        (engine, workload)
    });

    for n_kv in [1usize, 2, 4] {
        let run = || {
            let mut server = ModelServer::new(
                &engine,
                ServeConfig::full_model()
                    .strategy(ServeStrategy::Fused)
                    .max_seq(3 * KV_PAGE)
                    .slots(4)
                    .heads(4, n_kv)
                    .rope_theta(10000.0),
            )
            .unwrap();
            let mut cache = server.new_cache().unwrap();
            let mut sched = DecodeScheduler::new();
            for r in &workload {
                sched.submit(r.clone());
            }
            let fin = sched.run_sorted(&mut server, &mut cache).unwrap();
            fin.into_iter().map(|f| f.tokens).collect::<Vec<_>>()
        };
        let t1 = with_threads(1, run);
        let t8 = with_threads(8, run);
        assert_eq!(
            t1, t8,
            "page-straddling decode trajectories drifted across thread counts (n_kv {n_kv})"
        );
    }
}

#[test]
fn gqa_rope_chunked_decode_trajectories_bit_identical_across_thread_counts() {
    // Same bar as above, with every new attention surface switched on at
    // once: 4 query heads sharing 2 KV heads, RoPE rotations at both
    // prefill and decode, and chunked prefill interleaving with decode
    // steps. None of it may introduce a thread-count dependence — the
    // per-head softmax and rotations are fixed-order scalar f32, and the
    // chunk schedule is a pure function of the workload.
    let _guard = ENV_LOCK.lock().unwrap();
    let cfg = ConfigInfo {
        name: "gqa-determinism".into(),
        kind: "decoder".into(),
        vocab: 32,
        d_model: 48, // 4 heads -> head_dim 12 (even, RoPE-able)
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 8,
        batch: 4,
        eval_batch: 2,
        n_classes: 0,
        ranks: vec![4],
    };
    let (engine, workload) = with_threads(1, || {
        let mut rng = Rng::new(31);
        let base = BaseModel::random(&cfg, &mut rng);
        let mut engine = AdapterEngine::new(base);
        for name in ["t0", "t1"] {
            engine.attach(name, AdapterSpec::pissa(4), &mut rng).unwrap();
            for module in LINEARS {
                drift_factors(&mut engine, name, module, 0.05, &mut rng).unwrap();
            }
        }
        let workload: Vec<SeqRequest> = (0..8)
            .map(|i| {
                // Prompts up to 10 tokens so chunk=3 splits most of them.
                let prompt: Vec<usize> =
                    (0..(3 + i % 8)).map(|j| (i * 13 + j * 3) % 32).collect();
                if i % 4 == 3 {
                    SeqRequest::base(prompt, 5)
                } else {
                    SeqRequest::new(["t0", "t1"][i % 2], prompt, 5)
                }
            })
            .collect();
        (engine, workload)
    });

    for strategy in ServeStrategy::all() {
        for chunk in [0usize, 3] {
            let run = || {
                let mut server = ModelServer::new(
                    &engine,
                    ServeConfig::full_model()
                        .strategy(strategy)
                        .max_seq(16)
                        .slots(4)
                        .heads(4, 2)
                        .rope_theta(10000.0)
                        .prefill_chunk(chunk),
                )
                .unwrap();
                let mut cache = server.new_cache().unwrap();
                let mut sched = DecodeScheduler::new();
                for r in &workload {
                    sched.submit(r.clone());
                }
                let fin = sched.run_sorted(&mut server, &mut cache).unwrap();
                fin.into_iter().map(|f| f.tokens).collect::<Vec<_>>()
            };
            let t1 = with_threads(1, run);
            let t8 = with_threads(8, run);
            assert_eq!(
                t1,
                t8,
                "GQA+RoPE decode trajectories drifted across thread counts \
                 (strategy {} chunk {chunk})",
                strategy.name()
            );
        }
    }
}
