//! End-to-end tests for the streaming HTTP front-end (`pissa::net`).
//!
//! Every test starts a real `NetServer` on a loopback port and talks to
//! it over TCP with the crate's own minimal HTTP client — no mocks. The
//! load-bearing property is trajectory equivalence: tokens streamed over
//! the wire must be BIT-IDENTICAL to an in-process decode of the same
//! request on an identically seeded engine (greedy decode is
//! deterministic, and continuous ≡ sequential batching is pinned by the
//! serve test suite, so the oracle is independent of HTTP interleaving).

use pissa::adapter::{AdapterEngine, AdapterSpec};
use pissa::model::{BaseModel, LINEARS};
use pissa::net::{http, NetConfig, NetServer, StreamingClient, TenantPolicy};
use pissa::runtime::ConfigInfo;
use pissa::serve::{
    drift_factors, DecodeScheduler, ModelServer, SeqId, SeqRequest, ServeConfig, StepObserver,
};
use pissa::util::json::{jarr, jnum, jstr, Json};
use pissa::util::rng::Rng;

const DIM: usize = 32;
const D_FF: usize = 64;
const LAYERS: usize = 2;
const VOCAB: usize = 32;
const N_ADAPTERS: usize = 3;
const RANK: usize = 4;
const SLOTS: usize = 4;
const MAX_SEQ: usize = 96;
const SEED: u64 = 2024;

/// Deterministic engine build: same seed -> bit-identical weights, so a
/// second build is a valid in-process oracle for the served one.
fn build_engine(seed: u64) -> anyhow::Result<(AdapterEngine, Vec<String>)> {
    let cfg = ConfigInfo {
        name: "http-serve-test".into(),
        kind: "decoder".into(),
        vocab: VOCAB,
        d_model: DIM,
        n_layers: LAYERS,
        n_heads: 2,
        d_ff: D_FF,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![RANK],
    };
    let mut rng = Rng::new(seed);
    let base = BaseModel::random(&cfg, &mut rng);
    let mut engine = AdapterEngine::new(base);
    let names: Vec<String> = (0..N_ADAPTERS).map(|i| format!("tenant{i:02}")).collect();
    for name in &names {
        engine.attach(name, AdapterSpec::pissa(RANK), &mut rng)?;
        for module in LINEARS {
            drift_factors(&mut engine, name, module, 0.05, &mut rng)?;
        }
    }
    Ok((engine, names))
}

fn serve_cfg() -> ServeConfig {
    ServeConfig::full_model().max_seq(MAX_SEQ).slots(SLOTS)
}

fn start_server(net_cfg: NetConfig) -> anyhow::Result<NetServer> {
    let (engine, _) = build_engine(SEED)?;
    NetServer::start(&engine, serve_cfg(), net_cfg)
}

/// In-process greedy decode of one request on a fresh identical engine.
fn oracle_tokens(
    adapter: Option<&str>,
    prompt: &[usize],
    max_new: usize,
) -> anyhow::Result<Vec<usize>> {
    let (engine, _) = build_engine(SEED)?;
    let mut server = ModelServer::new(&engine, serve_cfg())?;
    let mut cache = server.new_cache()?;
    let mut sched = DecodeScheduler::new();
    sched.submit(SeqRequest {
        adapter: adapter.map(|s| s.to_string()),
        prompt: prompt.to_vec(),
        max_new,
        stop_token: None,
    });
    let fin = sched.run(&mut server, &mut cache)?;
    Ok(fin[0].generated().to_vec())
}

fn gen_body(adapter: Option<&str>, prompt: &[usize], max_new: usize, stream: bool) -> Json {
    let mut o = Json::obj();
    o.set("adapter", adapter.map(jstr).unwrap_or(Json::Null));
    o.set("prompt", jarr(prompt.iter().map(|&t| jnum(t as f64))));
    o.set("max_new", jnum(max_new as f64));
    o.set("stream", Json::Bool(stream));
    o
}

fn tokens_of(j: &Json) -> Vec<usize> {
    j.get("tokens")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|t| t.as_f64()).map(|f| f as usize).collect())
        .unwrap_or_default()
}

#[test]
fn non_streaming_generate_matches_in_process_decode() -> anyhow::Result<()> {
    let server = start_server(NetConfig::default())?;
    let addr = server.addr().to_string();
    for (adapter, prompt, max_new) in [
        (Some("tenant00"), vec![1usize, 5, 9], 6usize),
        (Some("tenant02"), vec![3, 3, 7, 11], 8),
        (None, vec![2, 4], 5),
    ] {
        let body = gen_body(adapter, &prompt, max_new, false);
        let resp = http::request(&addr, "POST", "/v1/generate", Some(&body))?;
        assert_eq!(resp.status, 200, "body={}", resp.body_str());
        let j = resp.json()?;
        assert_eq!(j.get("done"), Some(&Json::Bool(true)));
        assert_eq!(j.get("prompt_len").and_then(|v| v.as_f64()), Some(prompt.len() as f64));
        let want = oracle_tokens(adapter, &prompt, max_new)?;
        assert_eq!(tokens_of(&j), want, "adapter={adapter:?} prompt={prompt:?}");
    }
    server.shutdown()
}

#[test]
fn streaming_frames_meta_then_tokens_then_done_bit_identical() -> anyhow::Result<()> {
    let server = start_server(NetConfig::default())?;
    let addr = server.addr().to_string();
    let prompt = [4usize, 8, 15];
    let max_new = 7;
    let body = gen_body(Some("tenant01"), &prompt, max_new, true);
    let mut client = StreamingClient::post(&addr, "/v1/generate", &body)?;
    assert_eq!(client.status, 200);
    assert_eq!(
        client.headers.get("transfer-encoding").map(|s| s.as_str()),
        Some("chunked"),
        "streaming must use chunked transfer-encoding"
    );
    let text = String::from_utf8(client.read_rest()?)?;
    let lines: Vec<Json> =
        text.lines().filter(|l| !l.is_empty()).map(Json::parse).collect::<Result<_, _>>()?;
    // Frame order: meta, then token lines, then the terminal done line.
    assert!(lines.len() >= 3, "got {} lines: {text}");
    let meta = &lines[0];
    assert_eq!(meta.get("adapter").and_then(|v| v.as_str()), Some("tenant01"));
    assert!(meta.get("seq").is_some());
    let done = lines.last().unwrap();
    assert_eq!(done.get("done"), Some(&Json::Bool(true)));
    assert_eq!(done.get("reason").and_then(|v| v.as_str()), Some("max_new"));
    let mut streamed = Vec::new();
    for (i, line) in lines[1..lines.len() - 1].iter().enumerate() {
        let tok = line.get("token").and_then(|v| v.as_f64()).expect("token line") as usize;
        let first = line.get("first").and_then(|v| v.as_bool()).unwrap();
        assert_eq!(first, i == 0, "only the first token line carries first=true");
        streamed.push(tok);
    }
    let want = oracle_tokens(Some("tenant01"), &prompt, max_new)?;
    assert_eq!(streamed, want, "streamed tokens must be bit-identical to in-process decode");
    assert_eq!(tokens_of(done), want, "done line repeats the full trajectory");
    server.shutdown()
}

#[test]
fn healthz_and_metrics_expose_engine_state() -> anyhow::Result<()> {
    let server = start_server(NetConfig::default())?;
    let addr = server.addr().to_string();
    let h = http::request(&addr, "GET", "/healthz", None)?;
    assert_eq!(h.status, 200, "body={}", h.body_str());
    let hj = h.json()?;
    assert_eq!(hj.get("ready"), Some(&Json::Bool(true)));
    assert_eq!(hj.get("phase").and_then(|v| v.as_str()), Some("running"));
    assert_eq!(hj.get("slots").and_then(|v| v.as_f64()), Some(SLOTS as f64));
    assert!(hj.get("kv_budget_bytes").and_then(|v| v.as_f64()).unwrap() > 0.0);

    // Serve one request so the counters move, then snapshot metrics.
    let body = gen_body(Some("tenant00"), &[1, 2], 3, false);
    let resp = http::request(&addr, "POST", "/v1/generate", Some(&body))?;
    assert_eq!(resp.status, 200);
    let m = http::request(&addr, "GET", "/metrics", None)?;
    assert_eq!(m.status, 200);
    let mj = m.json()?;
    for field in ["requests", "rejections", "resident", "tenants", "phase", "hits"] {
        assert!(mj.get(field).is_some(), "metrics missing '{field}': {mj}");
    }
    let tenants = mj.get("tenants").unwrap();
    let t0 = tenants.get("tenant00").expect("tenant00 admission counters");
    assert_eq!(t0.get("admitted").and_then(|v| v.as_f64()), Some(1.0));
    server.shutdown()
}

#[test]
fn rate_limited_tenant_gets_typed_429_while_open_tenant_proceeds() -> anyhow::Result<()> {
    let cfg = NetConfig {
        tenant_policies: vec![(
            "tenant00".to_string(),
            TenantPolicy { rate_per_s: 1e-6, burst: 1.0, max_inflight: 8 },
        )],
        ..NetConfig::default()
    };
    let server = start_server(cfg)?;
    let addr = server.addr().to_string();
    let body = gen_body(Some("tenant00"), &[1, 2], 2, false);
    // The single bucket token admits the first request…
    let ok = http::request(&addr, "POST", "/v1/generate", Some(&body))?;
    assert_eq!(ok.status, 200, "body={}", ok.body_str());
    // …and the second is a typed 429 with retry hints.
    let limited = http::request(&addr, "POST", "/v1/generate", Some(&body))?;
    assert_eq!(limited.status, 429);
    assert!(limited.header("retry-after").is_some(), "429 must carry Retry-After");
    assert!(limited.header("x-ratelimit-remaining").is_some());
    let err = limited.json()?;
    assert_eq!(
        err.get("error").and_then(|e| e.get("code")).and_then(|v| v.as_str()),
        Some("rate_limited")
    );
    // An unthrottled tenant is unaffected.
    let open = gen_body(Some("tenant01"), &[1, 2], 2, false);
    let resp = http::request(&addr, "POST", "/v1/generate", Some(&open))?;
    assert_eq!(resp.status, 200, "body={}", resp.body_str());
    // The rejection shows up in the admission counters.
    let mj = http::request(&addr, "GET", "/metrics", None)?.json()?;
    let t0 = mj.get("tenants").and_then(|t| t.get("tenant00")).unwrap();
    assert_eq!(t0.get("rejected_rate_limited").and_then(|v| v.as_f64()), Some(1.0));
    server.shutdown()
}

#[test]
fn wire_errors_are_typed_status_codes() -> anyhow::Result<()> {
    let server = start_server(NetConfig::default())?;
    let addr = server.addr().to_string();
    // (body, want_status, want_code)
    let cases: Vec<(Json, u16, &str)> = vec![
        (gen_body(Some("ghost"), &[1], 2, false), 404, "unknown_adapter"),
        (gen_body(None, &[], 2, false), 422, "empty_prompt"),
        (gen_body(None, &[VOCAB + 5], 2, false), 422, "token_out_of_range"),
        (gen_body(None, &[1], MAX_SEQ + 1, false), 422, "seq_too_long"),
    ];
    for (body, status, code) in cases {
        let resp = http::request(&addr, "POST", "/v1/generate", Some(&body))?;
        assert_eq!(resp.status, status, "body={}", resp.body_str());
        let got = resp.json()?;
        assert_eq!(
            got.get("error").and_then(|e| e.get("code")).and_then(|v| v.as_str()),
            Some(code)
        );
    }
    // Malformed JSON body.
    let mut raw = Json::obj();
    raw.set("not", jstr("a valid generate request"));
    let resp = http::request(&addr, "POST", "/v1/generate", Some(&raw))?;
    assert_eq!(resp.status, 400);
    // Wrong method and unknown route.
    assert_eq!(http::request(&addr, "GET", "/v1/generate", None)?.status, 405);
    assert_eq!(http::request(&addr, "POST", "/healthz", None)?.status, 405);
    assert_eq!(http::request(&addr, "GET", "/nope", None)?.status, 404);
    server.shutdown()
}

#[test]
fn drain_finishes_inflight_streams_and_rejects_new_requests() -> anyhow::Result<()> {
    let server = start_server(NetConfig::default())?;
    let addr = server.addr().to_string();
    let max_new = 48;
    // Long-running streamed generation in a background thread.
    let body = gen_body(Some("tenant00"), &[7, 7, 7], max_new, true);
    let stream_addr = addr.clone();
    let inflight = std::thread::spawn(move || -> anyhow::Result<Vec<Json>> {
        let mut c = StreamingClient::post(&stream_addr, "/v1/generate", &body)?;
        anyhow::ensure!(c.status == 200, "stream status {}", c.status);
        let text = String::from_utf8(c.read_rest()?)?;
        text.lines().filter(|l| !l.is_empty()).map(|l| Ok(Json::parse(l)?)).collect()
    });
    // Begin the drain over the wire while the stream is (likely) running.
    let d = http::request(&addr, "POST", "/admin/drain", None)?;
    assert_eq!(d.status, 200);
    // New work is refused with a typed 503 once draining.
    let refused =
        http::request(&addr, "POST", "/v1/generate", Some(&gen_body(None, &[1], 2, false)))?;
    assert_eq!(refused.status, 503, "body={}", refused.body_str());
    let code = refused.json()?;
    assert_eq!(
        code.get("error").and_then(|e| e.get("code")).and_then(|v| v.as_str()),
        Some("draining")
    );
    // The in-flight stream still completes with zero truncation: meta +
    // every token + the done line.
    let lines = inflight.join().expect("stream thread")?;
    let done = lines.last().expect("nonempty stream");
    assert_eq!(done.get("done"), Some(&Json::Bool(true)), "stream truncated: {lines:?}");
    assert_eq!(tokens_of(done).len(), max_new, "drained stream lost tokens");
    assert_eq!(lines.len(), max_new + 2, "meta + tokens + done");
    // Drain completes and the whole thread ensemble joins cleanly.
    server.wait_engine_stopped();
    server.shutdown()
}

#[test]
fn concurrent_mixed_tenant_clients_all_complete_with_oracle_trajectories() -> anyhow::Result<()> {
    let server = start_server(NetConfig::default())?;
    let addr = server.addr().to_string();
    let mut handles = Vec::new();
    for i in 0..8usize {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<(usize, Vec<usize>)> {
            let adapter = match i % 4 {
                0 => Some("tenant00"),
                1 => Some("tenant01"),
                2 => Some("tenant02"),
                _ => None,
            };
            let prompt = vec![(i % VOCAB), (i * 3 % VOCAB), 1];
            let body = gen_body(adapter, &prompt, 5, false);
            let resp = http::request(&addr, "POST", "/v1/generate", Some(&body))?;
            anyhow::ensure!(resp.status == 200, "status {} body {}", resp.status, resp.body_str());
            Ok((i, tokens_of(&resp.json()?)))
        }));
    }
    for h in handles {
        let (i, tokens) = h.join().expect("client thread")?;
        let adapter = match i % 4 {
            0 => Some("tenant00"),
            1 => Some("tenant01"),
            2 => Some("tenant02"),
            _ => None,
        };
        let prompt = vec![(i % VOCAB), (i * 3 % VOCAB), 1];
        let want = oracle_tokens(adapter, &prompt, 5)?;
        assert_eq!(tokens, want, "client {i}: concurrent trajectory diverged from oracle");
    }
    server.shutdown()
}

/// The observer hook the engine thread streams through: every token is
/// reported exactly once, with `first` set only on the prefill token.
#[test]
fn step_observed_reports_every_token_with_first_flags() -> anyhow::Result<()> {
    struct Recorder {
        events: Vec<(SeqId, usize, bool)>,
    }
    impl StepObserver for Recorder {
        fn on_token(&mut self, id: SeqId, token: usize, first: bool) {
            self.events.push((id, token, first));
        }
    }
    let (engine, _) = build_engine(SEED)?;
    let mut server = ModelServer::new(&engine, serve_cfg())?;
    let mut cache = server.new_cache()?;
    let mut sched = DecodeScheduler::new();
    let a = sched.submit(SeqRequest::new("tenant00", vec![1, 2, 3], 4));
    let b = sched.submit(SeqRequest::base(vec![9, 9], 3));
    let mut rec = Recorder { events: Vec::new() };
    let mut finished = Vec::new();
    while !sched.idle() {
        finished.extend(sched.step_observed(&mut server, &mut cache, &mut rec)?);
    }
    assert_eq!(finished.len(), 2);
    for (id, want_n) in [(a, 4usize), (b, 3)] {
        let seq: Vec<_> = rec.events.iter().filter(|(i, _, _)| *i == id).collect();
        assert_eq!(seq.len(), want_n, "one on_token per generated token");
        assert!(seq[0].2, "prefill token carries first=true");
        assert!(seq[1..].iter().all(|(_, _, f)| !f));
        let fin = finished.iter().find(|f| f.id == id).unwrap();
        let observed: Vec<usize> = seq.iter().map(|(_, t, _)| *t).collect();
        assert_eq!(observed, fin.generated(), "observer saw the retired trajectory");
    }
    Ok(())
}
