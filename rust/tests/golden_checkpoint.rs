//! Golden-file checkpoint tests: the `PISSACKP` loader must keep reading
//! STABLE on-disk artifacts, not just files it wrote itself in-process.
//!
//! `rust/tests/fixtures/golden_v1.ckpt` is a hand-crafted v1 container
//! (mats + blobs, no spec entry); `golden_v2.ckpt` is a v2 container with
//! a spec entry plus two forward-compat probes (an unknown reserved
//! `__future__` entry and an unknown kind) that the loader must skip.
//! Both byte streams are checked in — any format regression breaks here
//! first, before it breaks someone's saved adapter.

use pissa::adapter::{AdapterSpec, Checkpoint};
use std::path::PathBuf;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures").join(name)
}

#[test]
fn golden_v1_loads_with_expected_contents() {
    let ckp = Checkpoint::load(&fixture("golden_v1.ckpt")).unwrap();
    assert_eq!(ckp.spec, None, "v1 files carry no spec");
    assert_eq!(ckp.mats.len(), 2);
    let a = ckp.get("a_q").unwrap();
    assert_eq!((a.rows, a.cols), (2, 3));
    assert_eq!(a.data, vec![1.0, 2.0, 3.0, -0.5, 0.25, 8.0]);
    let b = ckp.get("b_q").unwrap();
    assert_eq!((b.rows, b.cols), (3, 2));
    assert_eq!(b.data, vec![0.5, -1.5, 2.5, 4.0, -8.25, 0.125]);
    assert_eq!(ckp.blobs["meta"], b"{\"rank\":4}".to_vec());
}

#[test]
fn golden_v2_loads_spec_and_skips_unknown_entries() {
    let ckp = Checkpoint::load(&fixture("golden_v2.ckpt")).unwrap();
    assert_eq!(
        ckp.spec,
        Some(AdapterSpec::pissa(2).targets(&["q", "v"])),
        "v2 spec entry must parse to the recorded AdapterSpec"
    );
    // the unknown-kind entry and the reserved __future__ blob are skipped
    assert_eq!(ckp.mats.len(), 1, "unknown kinds must be skipped, not loaded");
    assert_eq!(ckp.blobs.len(), 1, "reserved entries must be skipped");
    let m = ckp.get("factors.a").unwrap();
    assert_eq!((m.rows, m.cols), (2, 2));
    assert_eq!(m.data, vec![0.5, -1.5, 2.5, 4.0]);
    assert_eq!(ckp.blobs["note"], b"golden".to_vec());
}

#[test]
fn golden_files_roundtrip_through_save_and_load() {
    let dir = std::env::temp_dir().join("pissa_golden_roundtrip");
    for name in ["golden_v1.ckpt", "golden_v2.ckpt"] {
        let ckp = Checkpoint::load(&fixture(name)).unwrap();
        let out = dir.join(name);
        ckp.save(&out).unwrap();
        let back = Checkpoint::load(&out).unwrap();
        assert_eq!(back.spec, ckp.spec, "{name}: spec changed across a round-trip");
        assert_eq!(
            back.mats.keys().collect::<Vec<_>>(),
            ckp.mats.keys().collect::<Vec<_>>()
        );
        for (k, m) in &ckp.mats {
            assert_eq!(back.mats[k].data, m.data, "{name}: mat '{k}' changed");
            assert_eq!((back.mats[k].rows, back.mats[k].cols), (m.rows, m.cols));
        }
        assert_eq!(back.blobs, ckp.blobs, "{name}: blobs changed across a round-trip");
    }
    std::fs::remove_dir_all(&dir).ok();
}
