//! Serving equivalence property tests.
//!
//! Contract: for random bases, every full-precision serving strategy,
//! rank ∈ {1, 4, 16}, and batch ∈ {1, 7, 64}, the batched server output
//! equals the merged-dense forward (`engine.effective_weight_of` row by
//! row) within 1e-4 relative Frobenius error — including mixed-adapter
//! batches and the no-adapter (base-only) path. The quantized-base pair
//! has its own contract over the same rank × batch grid: `fused-quant`
//! equals the dequantize-once dense reference bit for bit, and matches
//! the fp32 fused forward within a tolerance derived from
//! `quant::error::fro_error` of the NF4 base round trip. Plus the
//! edge-case hardening set: empty batches, unknown adapters, over-rank
//! configs, and quantized adapters under full-precision strategies are
//! typed errors, never panics.
//!
//! The full-model section holds the `ModelServer` pipeline to the same
//! bars end-to-end: over the identical strategy × rank × batch grid, one
//! `forward` call through ALL `n_layers × 7` adapted linears must match
//! an independent per-request dense reference (every linear materialized
//! via `effective_weight_of`, the block math re-derived here) within
//! 1e-4; `fused-quant` must equal `dequant-dense` bit for bit while
//! keeping the aggregate base ≤ 0.35× dense-resident; and quantized
//! adapters route through the quantized-base strategies only.

use pissa::adapter::{
    AdapterEngine, AdapterSpec, DemotePolicy, Tier, TierManager, WARM_NF4_REL_TOL,
};
use pissa::linalg::{matmul, vecmat, Mat};
use pissa::model::{BaseModel, LINEARS};
use pissa::quant::error::fro_error;
use pissa::quant::nf4_roundtrip;
use pissa::runtime::ConfigInfo;
use pissa::serve::{
    argmax, drift_factors, DecodeRequest, DecodeScheduler, KvCache, ModelRequest, ModelServer,
    Request, SeqId, SeqRequest, ServeConfig, ServeError, ServeStrategy, Server, StepObserver,
};
use pissa::util::par::with_parallelism;
use pissa::util::rng::Rng;

const MODULE: &str = "q";

fn cfg(d_model: usize) -> ConfigInfo {
    ConfigInfo {
        name: "serve-equiv".into(),
        kind: "decoder".into(),
        vocab: 64,
        d_model,
        n_layers: 2,
        n_heads: 2,
        d_ff: d_model + 8,
        seq_len: 8,
        batch: 4,
        eval_batch: 2,
        n_classes: 0,
        ranks: vec![4],
    }
}

/// Engine with one drifted PiSSA adapter and one drifted LoRA adapter at
/// `rank`, plus an un-drifted PiSSA adapter (its delta must be ~zero).
fn build_engine(rank: usize, seed: u64) -> (AdapterEngine, Vec<String>, Rng) {
    let mut rng = Rng::new(seed);
    let base = BaseModel::random(&cfg(32), &mut rng);
    let mut eng = AdapterEngine::new(base);
    eng.attach("pissa-t", AdapterSpec::pissa(rank).targets(&[MODULE, "v"]), &mut rng)
        .unwrap();
    drift_factors(&mut eng, "pissa-t", MODULE, 0.05, &mut rng).unwrap();
    eng.attach("lora-t", AdapterSpec::lora(rank), &mut rng).unwrap();
    drift_factors(&mut eng, "lora-t", MODULE, 0.05, &mut rng).unwrap();
    eng.attach("pissa-init", AdapterSpec::pissa(rank).targets(&[MODULE]), &mut rng)
        .unwrap();
    let names = vec!["pissa-t".to_string(), "lora-t".to_string(), "pissa-init".to_string()];
    (eng, names, rng)
}

/// Ground truth: per request, materialize the adapter's effective dense
/// weight from the engine and apply it to the input row.
fn reference(engine: &AdapterEngine, layer: usize, requests: &[Request]) -> Mat {
    let mut y = Mat::zeros(requests.len(), 32);
    for (i, r) in requests.iter().enumerate() {
        let w = match &r.adapter {
            Some(name) => engine.effective_weight_of(name, MODULE, layer).unwrap(),
            None => engine.base_weight(MODULE, layer),
        };
        y.row_mut(i).copy_from_slice(&vecmat(&r.x, &w));
    }
    y
}

fn mixed_batch(names: &[String], size: usize, rng: &mut Rng) -> Vec<Request> {
    (0..size)
        .map(|i| {
            let mut x = vec![0.0f32; 32];
            rng.fill_normal(&mut x, 0.0, 1.0);
            // Deterministic mix: every 4th request is base-only, the rest
            // cycle through the adapters.
            if i % 4 == 3 {
                Request::base(x)
            } else {
                Request::new(&names[i % names.len()], x)
            }
        })
        .collect()
}

fn rel_fro(a: &Mat, b: &Mat) -> f64 {
    a.sub(b).fro() / b.fro().max(1e-30)
}

#[test]
fn all_exact_strategies_match_merged_dense_forward() {
    for &rank in &[1usize, 4, 16] {
        let (engine, names, mut rng) = build_engine(rank, 100 + rank as u64);
        for layer in [0usize, 1] {
            for &batch in &[1usize, 7, 64] {
                let requests = mixed_batch(&names, batch, &mut rng);
                let want = reference(&engine, layer, &requests);
                for strategy in ServeStrategy::exact() {
                    let mut server = Server::new(
                        &engine,
                        ServeConfig::new(MODULE).layer(layer).strategy(strategy).max_batch(64),
                    )
                    .unwrap();
                    let got = server.forward(&requests).unwrap();
                    assert_eq!((got.rows, got.cols), (batch, 32));
                    let err = rel_fro(&got, &want);
                    assert!(
                        err < 1e-4,
                        "rank={rank} layer={layer} batch={batch} strategy={}: rel fro \
                         err {err:.3e}",
                        strategy.name()
                    );
                }
            }
        }
    }
}

#[test]
fn base_only_batch_matches_dense_base() {
    let (engine, _, mut rng) = build_engine(4, 7);
    let requests: Vec<Request> = (0..9)
        .map(|_| {
            let mut x = vec![0.0f32; 32];
            rng.fill_normal(&mut x, 0.0, 1.0);
            Request::base(x)
        })
        .collect();
    let want = reference(&engine, 0, &requests);
    for strategy in ServeStrategy::exact() {
        let mut server =
            Server::new(&engine, ServeConfig::new(MODULE).strategy(strategy)).unwrap();
        let got = server.forward(&requests).unwrap();
        let err = rel_fro(&got, &want);
        assert!(err < 1e-5, "{}: base-only err {err:.3e}", strategy.name());
    }
}

// ---- quantized-base serving (fused NF4 dequant-GEMM) ------------------

/// Frobenius norm of a batch of request inputs (for the ‖X·E‖_F ≤
/// ‖X‖_F·‖E‖_F tolerance bound).
fn requests_fro(requests: &[Request]) -> f64 {
    requests
        .iter()
        .flat_map(|r| r.x.iter())
        .map(|&v| (v as f64) * (v as f64))
        .sum::<f64>()
        .sqrt()
}

#[test]
fn fused_quant_matches_dequant_once_dense_bit_for_bit() {
    // The DequantGemm contract: streaming NF4 panels through the fused
    // forward is the SAME arithmetic as dequantizing once into a dense
    // base — for every rank × batch point, mixed batches included.
    for &rank in &[1usize, 4, 16] {
        let (engine, names, mut rng) = build_engine(rank, 300 + rank as u64);
        for layer in [0usize, 1] {
            for &batch in &[1usize, 7, 64] {
                let requests = mixed_batch(&names, batch, &mut rng);
                let mut fq = Server::new(
                    &engine,
                    ServeConfig::new(MODULE)
                        .layer(layer)
                        .strategy(ServeStrategy::FusedQuant)
                        .max_batch(64),
                )
                .unwrap();
                let mut dd = Server::new(
                    &engine,
                    ServeConfig::new(MODULE)
                        .layer(layer)
                        .strategy(ServeStrategy::DequantDense)
                        .max_batch(64),
                )
                .unwrap();
                let yq = fq.forward(&requests).unwrap();
                let yd = dd.forward(&requests).unwrap();
                assert_eq!(
                    yq.data,
                    yd.data,
                    "rank={rank} layer={layer} batch={batch}: fused-quant diverged from \
                     the dequantize-once dense reference"
                );
                // And the NF4 store really is smaller than the dense one.
                assert!(fq.base_resident_bytes() * 2 < dd.base_resident_bytes());
            }
        }
    }
}

#[test]
fn fused_quant_matches_fp32_fused_within_nf4_tolerance() {
    // fused-quant differs from the fp32 fused forward ONLY in the base:
    // Y_q − Y = X·(deq(nf4(W)) − W), so ‖Y_q − Y‖_F is bounded by
    // ‖X‖_F times the NF4 round-trip error fro_error(W, nf4(W)).
    for &rank in &[1usize, 4, 16] {
        let (engine, names, mut rng) = build_engine(rank, 400 + rank as u64);
        for layer in [0usize, 1] {
            let w = engine.base_weight(MODULE, layer);
            let nf4_err = fro_error(&w, &nf4_roundtrip(&w));
            assert!(nf4_err > 0.0, "NF4 must actually perturb a random base");
            for &batch in &[1usize, 7, 64] {
                let requests = mixed_batch(&names, batch, &mut rng);
                let mut fused = Server::new(
                    &engine,
                    ServeConfig::new(MODULE)
                        .layer(layer)
                        .strategy(ServeStrategy::Fused)
                        .max_batch(64),
                )
                .unwrap();
                let mut fq = Server::new(
                    &engine,
                    ServeConfig::new(MODULE)
                        .layer(layer)
                        .strategy(ServeStrategy::FusedQuant)
                        .max_batch(64),
                )
                .unwrap();
                let y = fused.forward(&requests).unwrap();
                let yq = fq.forward(&requests).unwrap();
                let diff = yq.sub(&y).fro();
                let bound = requests_fro(&requests) * nf4_err * 1.001 + 1e-5;
                assert!(
                    diff <= bound,
                    "rank={rank} layer={layer} batch={batch}: |Yq - Y|_F = {diff:.4e} \
                     exceeds the NF4-derived bound {bound:.4e}"
                );
                // The quantization is visible (guards a silently-dense base).
                assert!(diff > 0.0, "rank={rank} layer={layer} batch={batch}");
            }
        }
    }
}

#[test]
fn quantized_adapters_route_through_fused_quant() {
    // QLoRA and QPiSSA adapters — the configuration the paper says is
    // cheapest to deploy — are a typed error under every full-precision
    // strategy (message naming the escape hatch) and served end-to-end
    // by fused-quant.
    let mut rng = Rng::new(13);
    let base = BaseModel::random(&cfg(32), &mut rng);
    let mut eng = AdapterEngine::new(base);
    eng.attach("ql", AdapterSpec::qlora(4).targets(&[MODULE]), &mut rng).unwrap();
    drift_factors(&mut eng, "ql", MODULE, 0.05, &mut rng).unwrap();
    eng.attach("qp", AdapterSpec::qpissa(4).iters(2).targets(&[MODULE]), &mut rng).unwrap();
    drift_factors(&mut eng, "qp", MODULE, 0.05, &mut rng).unwrap();

    for strategy in ServeStrategy::exact() {
        let err =
            Server::new(&eng, ServeConfig::new(MODULE).strategy(strategy)).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::QuantizedAdapter { .. })),
            "{}: got {err:?}",
            strategy.name()
        );
        assert!(err.to_string().contains("fused-quant"), "escape hatch missing: {err}");
    }

    let mut server = Server::new(
        &eng,
        ServeConfig::new(MODULE).strategy(ServeStrategy::FusedQuant).max_batch(8),
    )
    .unwrap();
    let requests: Vec<Request> = (0..6)
        .map(|i| {
            let mut x = vec![0.0f32; 32];
            rng.fill_normal(&mut x, 0.0, 1.0);
            Request::new(["ql", "qp"][i % 2], x)
        })
        .collect();
    let got = server.forward(&requests).unwrap();

    let w = eng.base_weight(MODULE, 0);
    for (i, r) in requests.iter().enumerate() {
        let name = r.adapter.as_deref().unwrap();
        let ad = eng.get(name).unwrap();
        let w_eff = eng.effective_weight_of(name, MODULE, 0).unwrap();
        let want = vecmat(&r.x, &w_eff);
        // served_W − true_W = nf4(W) − A₀·B₀ − frozen, exactly (the
        // drifted factors cancel); bound the row error by ‖x‖·‖E‖_F.
        let a0 = ad.init_factors[&format!("a_{MODULE}")].layer(0);
        let b0 = ad.init_factors[&format!("b_{MODULE}")].layer(0);
        let frozen = ad.frozen[&format!("base_{MODULE}")].layer(0);
        let e = nf4_roundtrip(&w).sub(&matmul(&a0, &b0)).sub(&frozen);
        let x_norm = r.x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
        let bound = x_norm * e.fro() * 1.001 + 1e-4;
        let row_err: f64 = got
            .row(i)
            .iter()
            .zip(&want)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(
            row_err <= bound,
            "request {i} ({name}): err {row_err:.4e} > bound {bound:.4e}"
        );
    }
}

#[test]
fn single_adapter_batch_matches_merged_weight() {
    // One group, whole batch under one drifted adapter: the fused
    // correction path must agree with engine merge (effective weight).
    let (engine, _, mut rng) = build_engine(4, 8);
    let requests: Vec<Request> = (0..16)
        .map(|_| {
            let mut x = vec![0.0f32; 32];
            rng.fill_normal(&mut x, 0.0, 1.0);
            Request::new("pissa-t", x)
        })
        .collect();
    let want = reference(&engine, 1, &requests);
    let mut server = Server::new(&engine, ServeConfig::new(MODULE).layer(1)).unwrap();
    let got = server.forward(&requests).unwrap();
    assert!(rel_fro(&got, &want) < 1e-4);
}

#[test]
fn undrifted_pissa_adapter_serves_the_original_weight() {
    // At init the exactness invariant pins effective == W, so serving the
    // un-drifted adapter must equal serving the base.
    let (engine, _, mut rng) = build_engine(4, 9);
    let mut x = vec![0.0f32; 32];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let mut server = Server::new(&engine, ServeConfig::new(MODULE)).unwrap();
    let via_adapter = server.forward(&[Request::new("pissa-init", x.clone())]).unwrap();
    let via_base = server.forward(&[Request::base(x)]).unwrap();
    assert!(rel_fro(&via_adapter, &via_base) < 1e-4);
}

// ---- full-model serving (ModelServer pipeline) ------------------------

const MODEL_D: usize = 32;
const MODEL_FF: usize = 40;
const MODEL_LAYERS: usize = 2;
const MODEL_VOCAB: usize = 48;

fn model_cfg() -> ConfigInfo {
    ConfigInfo {
        name: "model-serve-equiv".into(),
        kind: "decoder".into(),
        vocab: MODEL_VOCAB,
        d_model: MODEL_D,
        n_layers: MODEL_LAYERS,
        n_heads: 2,
        d_ff: MODEL_FF,
        seq_len: 8,
        batch: 4,
        eval_batch: 2,
        n_classes: 0,
        ranks: vec![4],
    }
}

/// Engine with a drifted full-coverage PiSSA adapter, a drifted LoRA
/// adapter, a PARTIAL adapter (v/up only — the other five linears serve
/// the base weight), and an un-drifted PiSSA adapter (delta ~ 0).
fn build_model_engine(rank: usize, seed: u64) -> (AdapterEngine, Vec<String>, Rng) {
    let mut rng = Rng::new(seed);
    let base = BaseModel::random(&model_cfg(), &mut rng);
    let mut eng = AdapterEngine::new(base);
    eng.attach("pissa-t", AdapterSpec::pissa(rank), &mut rng).unwrap();
    for module in LINEARS {
        drift_factors(&mut eng, "pissa-t", module, 0.05, &mut rng).unwrap();
    }
    eng.attach("lora-t", AdapterSpec::lora(rank), &mut rng).unwrap();
    drift_factors(&mut eng, "lora-t", "q", 0.05, &mut rng).unwrap();
    drift_factors(&mut eng, "lora-t", "down", 0.05, &mut rng).unwrap();
    eng.attach("partial", AdapterSpec::pissa(rank).targets(&["v", "up"]), &mut rng).unwrap();
    drift_factors(&mut eng, "partial", "v", 0.05, &mut rng).unwrap();
    eng.attach("pissa-init", AdapterSpec::pissa(rank), &mut rng).unwrap();
    let names: Vec<String> =
        ["pissa-t", "lora-t", "partial", "pissa-init"].iter().map(|s| s.to_string()).collect();
    (eng, names, rng)
}

fn model_batch(names: &[String], size: usize, rng: &mut Rng) -> Vec<ModelRequest> {
    (0..size)
        .map(|i| {
            let token = (rng.uniform() * MODEL_VOCAB as f64) as usize % MODEL_VOCAB;
            // Deterministic mix: every 4th request is base-only, the rest
            // cycle through the adapters.
            if i % 4 == 3 {
                ModelRequest::base(token)
            } else {
                ModelRequest::new(&names[i % names.len()], token)
            }
        })
        .collect()
}

fn rms_ref(x: &[f32], gain: &[f32]) -> Vec<f32> {
    let mut ms = 0.0f32;
    for &v in x {
        ms += v * v;
    }
    let inv = 1.0 / (ms / x.len() as f32 + 1e-6).sqrt();
    x.iter().zip(gain).map(|(&v, &g)| v * inv * g).collect()
}

fn sigmoid_ref(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Independent ground truth for the whole pipeline: per request,
/// materialize EVERY layer's seven effective dense weights from the
/// engine and re-derive the block math (rms-norm → q/k/v with the
/// σ(⟨q,k⟩/√d) single-position gate → o → residual → rms-norm → SwiGLU →
/// residual → final norm → head), one row at a time.
fn model_reference(engine: &AdapterEngine, requests: &[ModelRequest]) -> Mat {
    let base = engine.base();
    let embed = base.scaffold["embed"].as_mat();
    let head = base.scaffold["lm_head"].as_mat();
    let attn_gains = base.scaffold["attn_norm"].as_mat();
    let mlp_gains = base.scaffold["mlp_norm"].as_mat();
    let final_gain = &base.scaffold["final_norm"].data;
    let scale = 1.0 / (MODEL_D as f32).sqrt();
    let mut out = Mat::zeros(requests.len(), head.cols);
    for (i, r) in requests.iter().enumerate() {
        let w = |module: &str, layer: usize| -> Mat {
            match &r.adapter {
                Some(name) => engine.effective_weight_of(name, module, layer).unwrap(),
                None => engine.base_weight(module, layer),
            }
        };
        let mut x: Vec<f32> = embed.row(r.token).to_vec();
        for li in 0..MODEL_LAYERS {
            let h = rms_ref(&x, attn_gains.row(li));
            let q = vecmat(&h, &w("q", li));
            let k = vecmat(&h, &w("k", li));
            let mut v = vecmat(&h, &w("v", li));
            let dot: f32 = q.iter().zip(&k).map(|(a, b)| a * b).sum();
            let gate = sigmoid_ref(dot * scale);
            for vv in v.iter_mut() {
                *vv *= gate;
            }
            let o = vecmat(&v, &w("o", li));
            for (xv, ov) in x.iter_mut().zip(&o) {
                *xv += ov;
            }
            let h2 = rms_ref(&x, mlp_gains.row(li));
            let g = vecmat(&h2, &w("gate", li));
            let u = vecmat(&h2, &w("up", li));
            let act: Vec<f32> =
                g.iter().zip(&u).map(|(&gv, &uv)| gv * sigmoid_ref(gv) * uv).collect();
            let dn = vecmat(&act, &w("down", li));
            for (xv, dv) in x.iter_mut().zip(&dn) {
                *xv += dv;
            }
        }
        let hf = rms_ref(&x, final_gain);
        out.row_mut(i).copy_from_slice(&vecmat(&hf, &head));
    }
    out
}

#[test]
fn full_model_exact_strategies_match_dense_reference() {
    // The tentpole contract: one ModelServer::forward call routes a mixed
    // batch through all n_layers × 7 adapted linears and agrees with the
    // per-request merged-dense full forward within 1e-4, over the same
    // strategy × rank × batch grid as the single-linear suite.
    for &rank in &[1usize, 4, 16] {
        let (engine, names, mut rng) = build_model_engine(rank, 500 + rank as u64);
        for &batch in &[1usize, 7, 64] {
            let requests = model_batch(&names, batch, &mut rng);
            let want = model_reference(&engine, &requests);
            for strategy in ServeStrategy::exact() {
                let mut server = ModelServer::new(
                    &engine,
                    ServeConfig::full_model().strategy(strategy).max_batch(64),
                )
                .unwrap();
                let got = server.forward(&requests).unwrap();
                assert_eq!((got.rows, got.cols), (batch, MODEL_VOCAB));
                let err = rel_fro(&got, &want);
                assert!(
                    err < 1e-4,
                    "rank={rank} batch={batch} strategy={}: rel fro err {err:.3e}",
                    strategy.name()
                );
            }
        }
    }
}

#[test]
fn full_model_fused_quant_matches_dequant_dense_bit_for_bit() {
    // The DequantGemm contract survives the pipeline: streaming NF4
    // panels at every one of the L×7 linears is the same arithmetic as
    // dequantizing each base once — and the NF4-resident pipeline keeps
    // the aggregate base within the 0.35× dense budget.
    for &rank in &[1usize, 4, 16] {
        let (engine, names, mut rng) = build_model_engine(rank, 700 + rank as u64);
        for &batch in &[1usize, 7, 64] {
            let requests = model_batch(&names, batch, &mut rng);
            let mut fq = ModelServer::new(
                &engine,
                ServeConfig::full_model().strategy(ServeStrategy::FusedQuant).max_batch(64),
            )
            .unwrap();
            let mut dd = ModelServer::new(
                &engine,
                ServeConfig::full_model().strategy(ServeStrategy::DequantDense).max_batch(64),
            )
            .unwrap();
            let yq = fq.forward(&requests).unwrap();
            let yd = dd.forward(&requests).unwrap();
            assert_eq!(
                yq.data, yd.data,
                "rank={rank} batch={batch}: fused-quant diverged from dequant-dense"
            );
            // Aggregate residency: NF4 across ALL L×7 linears vs dense.
            assert!(
                fq.base_resident_bytes() * 100 <= fq.dense_base_bytes() * 35,
                "rank={rank}: aggregate NF4 residency {} exceeds 0.35x dense {}",
                fq.base_resident_bytes(),
                fq.dense_base_bytes()
            );
            assert_eq!(dd.base_resident_bytes(), dd.dense_base_bytes());
            // Quantization is visible end-to-end (guards a silently-dense
            // base): the fp32 pipeline must differ.
            let mut fused = ModelServer::new(
                &engine,
                ServeConfig::full_model().strategy(ServeStrategy::Fused).max_batch(64),
            )
            .unwrap();
            let y = fused.forward(&requests).unwrap();
            assert!(yq.sub(&y).fro() > 0.0, "rank={rank} batch={batch}");
        }
    }
}

#[test]
fn full_model_quantized_adapters_route_through_fused_quant() {
    let mut rng = Rng::new(42);
    let base = BaseModel::random(&model_cfg(), &mut rng);
    let mut eng = AdapterEngine::new(base);
    eng.attach("qp", AdapterSpec::qpissa(4).iters(2), &mut rng).unwrap();
    for module in LINEARS {
        drift_factors(&mut eng, "qp", module, 0.05, &mut rng).unwrap();
    }
    for strategy in ServeStrategy::exact() {
        let err =
            ModelServer::new(&eng, ServeConfig::full_model().strategy(strategy)).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::QuantizedAdapter { .. })),
            "{}: got {err:?}",
            strategy.name()
        );
    }
    let mut server = ModelServer::new(
        &eng,
        ServeConfig::full_model().strategy(ServeStrategy::FusedQuant).max_batch(8),
    )
    .unwrap();
    let requests =
        vec![ModelRequest::new("qp", 3), ModelRequest::base(3), ModelRequest::new("qp", 11)];
    let y = server.forward(&requests).unwrap();
    assert_eq!((y.rows, y.cols), (3, MODEL_VOCAB));
    assert!(y.data.iter().all(|v| v.is_finite()));
    // The drifted quantized adapter steers the output away from base.
    let diff: f32 = y.row(0).iter().zip(y.row(1)).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1e-3, "adapter row identical to base row (diff {diff:.3e})");
}

#[test]
fn full_model_base_only_batch_matches_dense_base_forward() {
    // A base-only batch takes the pure frozen-base pipeline (no
    // correction GEMMs anywhere) and must reproduce the dense reference
    // essentially exactly — a tighter bar than the mixed-batch 1e-4.
    let (engine, _, mut rng) = build_model_engine(4, 900);
    let requests: Vec<ModelRequest> = (0..9)
        .map(|_| ModelRequest::base((rng.uniform() * MODEL_VOCAB as f64) as usize % MODEL_VOCAB))
        .collect();
    let want = model_reference(&engine, &requests);
    for strategy in ServeStrategy::exact() {
        let mut server =
            ModelServer::new(&engine, ServeConfig::full_model().strategy(strategy)).unwrap();
        let got = server.forward(&requests).unwrap();
        let err = rel_fro(&got, &want);
        assert!(err < 1e-5, "{}: base-only err {err:.3e}", strategy.name());
    }
}

#[test]
fn full_model_over_rank_adapter_names_the_offending_module() {
    // down is 40×32 here, so rank 36 > min(m, n) = 32 must be refused on
    // the fused paths — validation walks every linear in the stack.
    let mut rng = Rng::new(43);
    let base = BaseModel::random(&model_cfg(), &mut rng);
    let mut eng = AdapterEngine::new(base);
    eng.attach("fat", AdapterSpec::lora(36), &mut rng).unwrap();
    let err = ModelServer::new(&eng, ServeConfig::full_model()).unwrap_err();
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::RankTooLarge { rank, module, .. }) => {
            assert_eq!(*rank, 36);
            assert!(LINEARS.contains(&module.as_str()), "module '{module}'");
        }
        other => panic!("expected RankTooLarge, got {other:?}"),
    }
    // The merged/dense strategies accept it, end to end.
    let mut server = ModelServer::new(
        &eng,
        ServeConfig::full_model().strategy(ServeStrategy::DensePerAdapter),
    )
    .unwrap();
    assert!(server.forward(&[ModelRequest::new("fat", 1)]).is_ok());
}

// ---- KV-cached decode (prefill / decode_step / DecodeScheduler) -------

/// The strategy grid of the decode equivalence contract: one exact
/// full-precision path, the streaming-NF4 path, and the naive merged
/// baseline — each must be bit-stable under incremental decode.
fn decode_strategies() -> [ServeStrategy; 3] {
    [ServeStrategy::Fused, ServeStrategy::FusedQuant, ServeStrategy::MergePerRequest]
}

/// Decode `n_new` tokens incrementally (one prefill + single-request
/// decode steps), returning the token trajectory and EVERY step's
/// logits row.
fn incremental_trajectory(
    server: &mut ModelServer,
    cache: &mut KvCache,
    adapter: Option<&str>,
    prompt: &[usize],
    n_new: usize,
) -> (Vec<usize>, Vec<Vec<f32>>) {
    let slot = cache.try_claim(prompt.len() + n_new).unwrap().unwrap();
    let mut tokens = prompt.to_vec();
    let mut logits_all = Vec::new();
    let l0 = server.prefill(cache, slot, adapter, prompt).unwrap();
    let mut next = argmax(&l0);
    tokens.push(next);
    logits_all.push(l0);
    for _ in 1..n_new {
        let req =
            DecodeRequest { slot, token: next, adapter: adapter.map(|s| s.to_string()) };
        let lm = server.decode_step(cache, &[req]).unwrap();
        let row = lm.row(0).to_vec();
        next = argmax(&row);
        tokens.push(next);
        logits_all.push(row);
    }
    cache.release(slot);
    (tokens, logits_all)
}

#[test]
fn incremental_decode_is_bit_identical_to_full_prefill_recompute() {
    // THE tentpole contract: after prefilling a prompt, every decode step
    // must produce EXACTLY the logits a from-scratch prefill of the same
    // prefix would — bit for bit, across strategy × rank, for adapted,
    // partially-adapted, and base sequences.
    for &rank in &[1usize, 4, 16] {
        let (engine, _, _) = build_model_engine(rank, 1100 + rank as u64);
        let fixtures: [(Option<&str>, Vec<usize>); 3] = [
            (Some("pissa-t"), vec![3, 17, 41, 8]),
            (Some("partial"), vec![25, 1]),
            (None, vec![9, 9, 30, 2, 44]),
        ];
        for strategy in decode_strategies() {
            let cfg = ServeConfig::full_model().strategy(strategy).max_seq(32);
            let mut server = ModelServer::new(&engine, cfg).unwrap();
            let mut cache = server.new_cache().unwrap();
            for (adapter, prompt) in &fixtures {
                let n_new = 6;
                let (tokens, logits) =
                    incremental_trajectory(&mut server, &mut cache, *adapter, prompt, n_new);
                assert_eq!(tokens.len(), prompt.len() + n_new);
                // Reference: recompute every prefix from scratch.
                for (step, want) in logits.iter().enumerate() {
                    let prefix = &tokens[..prompt.len() + step];
                    let slot = cache.try_claim(prefix.len()).unwrap().unwrap();
                    let got = server.prefill(&mut cache, slot, *adapter, prefix).unwrap();
                    cache.release(slot);
                    assert_eq!(
                        &got,
                        want,
                        "rank={rank} strategy={} adapter={adapter:?} step={step}: \
                         incremental decode diverged from full recompute",
                        strategy.name()
                    );
                }
            }
        }
    }
}

#[test]
fn page_straddling_incremental_decode_matches_full_recompute() {
    // Long-context twin of the tentpole contract, aimed at the
    // page-streaming kernel: the trajectory starts just under a KV page
    // (KV_PAGE − 2 prompt tokens) and decodes far enough to cross TWO
    // page boundaries, so incremental steps attend over partial pages,
    // exactly-full pages, and fresh pages — every run-clamping case of
    // `k_runs`/`v_runs`. Each step's logits must still equal a
    // from-scratch prefill of the same prefix bit for bit, under the
    // GQA+RoPE layout. Attention is strategy-independent, so the exact
    // `fused` path covers the kernel (the strategy grid is pinned by
    // the short-context test above).
    use pissa::serve::KV_PAGE;
    let (engine, _, _) = build_model_engine(4, 1150);
    let n_new = 2 * KV_PAGE + 4 - (KV_PAGE - 2); // end at 2·KV_PAGE + 4 positions
    let fixtures: [(Option<&str>, usize); 2] = [(Some("pissa-t"), 3), (None, 7)];
    let cfg = ServeConfig::full_model()
        .strategy(ServeStrategy::Fused)
        .max_seq(3 * KV_PAGE)
        .heads(4, 2)
        .rope_theta(10000.0);
    let mut server = ModelServer::new(&engine, cfg).unwrap();
    let mut cache = server.new_cache().unwrap();
    for (adapter, tok0) in &fixtures {
        let prompt: Vec<usize> =
            (0..KV_PAGE - 2).map(|j| (tok0 + j * 5) % MODEL_VOCAB).collect();
        let (tokens, logits) =
            incremental_trajectory(&mut server, &mut cache, *adapter, &prompt, n_new);
        assert_eq!(tokens.len(), 2 * KV_PAGE + 4);
        for (step, want) in logits.iter().enumerate() {
            let prefix = &tokens[..prompt.len() + step];
            let slot = cache.try_claim(prefix.len()).unwrap().unwrap();
            let got = server.prefill(&mut cache, slot, *adapter, prefix).unwrap();
            cache.release(slot);
            assert_eq!(
                &got,
                want,
                "adapter={adapter:?} step={step} (ctx {}): page-straddling incremental \
                 decode diverged from full recompute",
                prefix.len()
            );
        }
    }
}

#[test]
fn batched_decode_steps_match_single_sequence_decode_across_slot_counts() {
    // Continuous batching must not change a single bit of any sequence's
    // trajectory: the same request set decoded at slots {1, 3, 8} (and
    // manually, one sequence at a time) yields identical tokens.
    let (engine, names, _) = build_model_engine(4, 1200);
    let prompts: Vec<(Option<String>, Vec<usize>)> = (0..7)
        .map(|i| {
            let adapter =
                if i % 4 == 3 { None } else { Some(names[i % names.len()].clone()) };
            let prompt: Vec<usize> = (0..(2 + i % 4)).map(|j| (i * 13 + j * 7) % 48).collect();
            (adapter, prompt)
        })
        .collect();
    let max_new = 5;
    for strategy in decode_strategies() {
        let cfg = ServeConfig::full_model().strategy(strategy).max_seq(16);
        // Manual single-sequence reference.
        let mut server = ModelServer::new(&engine, cfg.clone()).unwrap();
        let mut cache = server.new_cache().unwrap();
        let reference: Vec<Vec<usize>> = prompts
            .iter()
            .map(|(a, p)| {
                incremental_trajectory(&mut server, &mut cache, a.as_deref(), p, max_new).0
            })
            .collect();
        for slots in [1usize, 3, 8] {
            let mut server =
                ModelServer::new(&engine, cfg.clone().slots(slots)).unwrap();
            let mut cache = server.new_cache().unwrap();
            let mut sched = DecodeScheduler::new();
            for (a, p) in &prompts {
                let req = SeqRequest {
                    adapter: a.clone(),
                    prompt: p.clone(),
                    max_new,
                    stop_token: None,
                };
                sched.submit(req);
            }
            let fin = sched.run_sorted(&mut server, &mut cache).unwrap();
            assert_eq!(fin.len(), prompts.len());
            for (i, f) in fin.iter().enumerate() {
                assert_eq!(
                    f.tokens,
                    reference[i],
                    "strategy={} slots={slots} seq={i}: continuous batching changed \
                     the trajectory",
                    strategy.name()
                );
                assert_eq!(f.generated().len(), max_new);
            }
            // Every slot was released on retirement.
            assert_eq!(cache.free_slots(), slots);
            assert_eq!(cache.reserved_bytes(), 0);
            let s = server.stats().summary();
            assert_eq!(s.prefills, prompts.len());
            assert_eq!(s.decode_tokens, prompts.len() * (max_new - 1));
            assert!(s.ttft_p95_s >= s.ttft_p50_s);
        }
    }
}

#[test]
fn decode_scheduler_admits_in_strict_arrival_order() {
    // Head-of-line contract (the take_batch starvation/ordering
    // regression, held to on the new scheduler): while an early LONG
    // request is waiting for cache budget, a later SHORT request that
    // WOULD fit must NOT be admitted ahead of it.
    let (engine, _, _) = build_model_engine(4, 1300);
    // Page math (KV_PAGE = 16 positions, 2 layers): a 32-position
    // sequence reserves 8 pages, a 17-position one 8, a 2-position one
    // 4. Budget = 12 pages, so `a` (8) leaves room for `c` (4) but NOT
    // for `b` (8).
    let page_bytes = pissa::serve::KV_PAGE * MODEL_D * 4;
    let probe = KvCache::new(MODEL_LAYERS, MODEL_D, 32, 2, 1 << 30).unwrap();
    assert_eq!(probe.pages_for(32), 8);
    assert_eq!(probe.pages_for(17), 8);
    assert_eq!(probe.pages_for(2), 4);
    let cfg =
        ServeConfig::full_model().max_seq(32).slots(2).kv_budget_bytes(12 * page_bytes);
    let mut server = ModelServer::new(&engine, cfg).unwrap();
    let mut cache = server.new_cache().unwrap();
    let mut sched = DecodeScheduler::new();
    let a = sched.submit(SeqRequest::base(vec![1, 2], 30)); // 32 pos -> 8 pages
    let b = sched.submit(SeqRequest::base(vec![3, 4, 5], 14)); // 17 pos -> 8 pages
    let c = sched.submit(SeqRequest::base(vec![4], 1)); // 2 pos -> 4 pages
    // While `a` is in flight, `b` blocks on budget — and `c`, despite
    // fitting in both a free slot and the remaining budget, must stay
    // queued behind it.
    let mut finished = Vec::new();
    loop {
        let fin = sched.step(&mut server, &mut cache).unwrap();
        let a_done = fin.iter().any(|f| f.id == a);
        finished.extend(fin);
        if a_done {
            break;
        }
        assert_eq!(sched.running(), 1, "only `a` may hold a slot");
        assert_eq!(sched.pending(), 2, "`c` was admitted ahead of the blocked `b`");
    }
    // With `a` retired, b then c admit (in order) and finish.
    while !sched.idle() {
        finished.extend(sched.step(&mut server, &mut cache).unwrap());
    }
    assert_eq!(finished.len(), 3);
    let find = |id| finished.iter().find(|f| f.id == id).unwrap();
    assert_eq!(find(a).generated().len(), 30);
    assert_eq!(find(b).generated().len(), 14);
    assert_eq!(find(c).generated().len(), 1);
    assert_eq!(cache.reserved_bytes(), 0);
}

#[test]
fn gqa_rope_incremental_decode_is_bit_identical_across_kv_head_counts() {
    // The multi-head tentpole contract: with per-head attention, grouped
    // KV sharing, AND rotary embeddings enabled, incremental decode must
    // still equal a from-scratch prefill of every prefix bit for bit —
    // RoPE depends only on the absolute position, so both paths rotate
    // identically. Swept over n_kv_heads ∈ {1, n_heads/2, n_heads} and
    // every decode strategy.
    let (engine, _, _) = build_model_engine(4, 1600);
    let fixtures: [(Option<&str>, Vec<usize>); 3] = [
        (Some("pissa-t"), vec![3, 17, 41, 8]),
        (Some("partial"), vec![25, 1]),
        (None, vec![9, 9, 30, 2, 44]),
    ];
    for &n_kv in &[1usize, 2, 4] {
        for strategy in decode_strategies() {
            // MODEL_D = 32, n_heads = 4 -> head_dim 8 (even, RoPE-able).
            let cfg = ServeConfig::full_model()
                .strategy(strategy)
                .max_seq(32)
                .heads(4, n_kv)
                .rope_theta(10000.0);
            let mut server = ModelServer::new(&engine, cfg).unwrap();
            let mut cache = server.new_cache().unwrap();
            assert_eq!(cache.d(), n_kv * 8, "cache rows must shrink to kv_dim");
            for (adapter, prompt) in &fixtures {
                let n_new = 6;
                let (tokens, logits) =
                    incremental_trajectory(&mut server, &mut cache, *adapter, prompt, n_new);
                for (step, want) in logits.iter().enumerate() {
                    let prefix = &tokens[..prompt.len() + step];
                    let slot = cache.try_claim(prefix.len()).unwrap().unwrap();
                    let got = server.prefill(&mut cache, slot, *adapter, prefix).unwrap();
                    cache.release(slot);
                    assert_eq!(
                        &got,
                        want,
                        "n_kv={n_kv} strategy={} adapter={adapter:?} step={step}: \
                         GQA+RoPE incremental decode diverged from full recompute",
                        strategy.name()
                    );
                }
            }
        }
    }
}

#[test]
fn chunked_prefill_decode_trajectories_match_one_shot_at_every_chunk_size() {
    // Chunked prefill is a SCHEDULER change, not a model change: the
    // same request set — long prompts included — must retire with
    // bit-identical trajectories whether prompts are prefilled in one
    // shot (prefill_chunk = 0) or in chunks of any size, with or without
    // GQA + RoPE in the model underneath.
    let (engine, names, _) = build_model_engine(4, 1700);
    let prompts: Vec<(Option<String>, Vec<usize>)> = (0..6)
        .map(|i| {
            let adapter =
                if i % 3 == 2 { None } else { Some(names[i % names.len()].clone()) };
            // Lengths 2..=22: several prompts span multiple chunks.
            let len = 2 + i * 4;
            let prompt: Vec<usize> = (0..len).map(|j| (i * 11 + j * 5) % 48).collect();
            (adapter, prompt)
        })
        .collect();
    let max_new = 4;
    let head_cfgs: [(usize, usize, f64); 2] = [(1, 1, 0.0), (4, 2, 10000.0)];
    for (n_heads, n_kv, theta) in head_cfgs {
        let base_cfg = ServeConfig::full_model()
            .max_seq(32)
            .slots(3)
            .heads(n_heads, n_kv)
            .rope_theta(theta);
        let run = |chunk: usize| {
            let mut server =
                ModelServer::new(&engine, base_cfg.clone().prefill_chunk(chunk)).unwrap();
            let mut cache = server.new_cache().unwrap();
            let mut sched = DecodeScheduler::new();
            for (a, p) in &prompts {
                sched.submit(SeqRequest {
                    adapter: a.clone(),
                    prompt: p.clone(),
                    max_new,
                    stop_token: None,
                });
            }
            let fin = sched.run_sorted(&mut server, &mut cache).unwrap();
            assert_eq!(cache.free_slots(), 3, "chunk={chunk}: slot leaked");
            assert_eq!(cache.reserved_bytes(), 0, "chunk={chunk}: bytes leaked");
            let s = server.stats().summary();
            assert!(s.ttft_p95_s >= s.ttft_p50_s);
            fin
        };
        let reference = run(0);
        assert_eq!(reference.len(), prompts.len());
        for chunk in [1usize, 2, 3, 5, 7, 16, 64] {
            let fin = run(chunk);
            assert_eq!(fin.len(), reference.len());
            for (f, r) in fin.iter().zip(&reference) {
                assert_eq!(f.id, r.id);
                assert_eq!(
                    f.tokens, r.tokens,
                    "heads=({n_heads},{n_kv}) chunk={chunk} seq={:?}: chunked \
                     prefill changed the trajectory",
                    f.id
                );
                assert_eq!(f.prompt_len, r.prompt_len);
                assert_eq!(f.reason, r.reason);
            }
        }
    }
}

/// Records every sampled token in emission order, tagged with its
/// sequence and whether it was the first (prefill-produced) token.
struct TokenLog {
    events: Vec<(SeqId, usize, bool)>,
}

impl StepObserver for TokenLog {
    fn on_token(&mut self, id: SeqId, token: usize, first: bool) {
        self.events.push((id, token, first));
    }
}

#[test]
fn chunked_prefill_decode_interleaves_with_running_sequences() {
    // The latency point of chunked prefill: while a LONG prompt is being
    // prefilled chunk by chunk, an already-running sequence must keep
    // emitting a token every step instead of stalling behind the full
    // prefill. Observed through the streaming token log.
    let (engine, _, _) = build_model_engine(4, 1800);
    let cfg = ServeConfig::full_model().max_seq(32).slots(2).prefill_chunk(2);
    let mut server = ModelServer::new(&engine, cfg).unwrap();
    let mut cache = server.new_cache().unwrap();
    let mut sched = DecodeScheduler::new();
    let mut log = TokenLog { events: Vec::new() };
    let short = sched.submit(SeqRequest::base(vec![7], 12));
    // Step 1: short admits, prefills (1 token fits one chunk), decodes.
    sched.step_observed(&mut server, &mut cache, &mut log).unwrap();
    let short_before = log.events.iter().filter(|(id, _, _)| *id == short).count();
    assert!(short_before >= 1, "short sequence never started");
    // A 12-token prompt now needs ceil(12 / 2) = 6 chunk steps.
    let long = sched.submit(SeqRequest::base((0..12).map(|j| j % 48).collect(), 2));
    for _ in 0..5 {
        sched.step_observed(&mut server, &mut cache, &mut log).unwrap();
        assert!(
            !log.events.iter().any(|(id, _, _)| *id == long),
            "long prompt produced a token before its prefill completed"
        );
    }
    // The short sequence advanced one token per step throughout.
    let short_during = log.events.iter().filter(|(id, _, _)| *id == short).count();
    assert_eq!(short_during - short_before, 5, "running decode stalled behind prefill");
    // Sixth chunk step completes the prefill: the long seq's FIRST token.
    let mut fin = sched.step_observed(&mut server, &mut cache, &mut log).unwrap();
    let first = log.events.iter().find(|(id, _, _)| *id == long).unwrap();
    assert!(first.2, "long sequence's first token was not flagged as TTFT");
    while !sched.idle() {
        fin.extend(sched.step_observed(&mut server, &mut cache, &mut log).unwrap());
    }
    assert_eq!(fin.len(), 2);
    assert_eq!(cache.reserved_bytes(), 0);
}

#[test]
fn decode_typed_errors_budget_and_max_seq() {
    let (engine, _, _) = build_model_engine(4, 1400);
    // A config whose cache cannot hold even one max_seq sequence is a
    // typed construction error.
    let cfg = ServeConfig::full_model().max_seq(64).slots(2).kv_budget_bytes(256);
    let server = ModelServer::new(&engine, cfg).unwrap();
    let err = server.new_cache().unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::CacheBudgetExhausted { .. })
        ),
        "got {err:?}"
    );
    // An over-max_seq request pops off the queue as a typed error; the
    // scheduler keeps serving what remains.
    let cfg = ServeConfig::full_model().max_seq(8).slots(2);
    let mut server = ModelServer::new(&engine, cfg).unwrap();
    let mut cache = server.new_cache().unwrap();
    let mut sched = DecodeScheduler::new();
    sched.submit(SeqRequest::base(vec![1, 2, 3, 4, 5], 10)); // 15 > 8
    let ok = sched.submit(SeqRequest::base(vec![1, 2], 3));
    let err = sched.step(&mut server, &mut cache).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::SeqTooLong { max_seq: 8, .. })
        ),
        "got {err:?}"
    );
    let fin = sched.run_sorted(&mut server, &mut cache).unwrap();
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].id, ok);
    assert_eq!(fin[0].generated().len(), 3);
}

#[test]
fn decode_error_mid_step_never_drops_finished_sequences() {
    // A sequence that retires in the same step an impossible request
    // errors must survive: the scheduler buffers retirements and hands
    // them back via drain_finished.
    let (engine, _, _) = build_model_engine(4, 1500);
    let cfg = ServeConfig::full_model().max_seq(8).slots(2);
    let mut server = ModelServer::new(&engine, cfg).unwrap();
    let mut cache = server.new_cache().unwrap();
    let mut sched = DecodeScheduler::new();
    // Finishes at admission (one prefill token is the whole budget)…
    let a = sched.submit(SeqRequest::base(vec![1, 2], 1));
    // …then the head-of-queue becomes an impossible request.
    sched.submit(SeqRequest::base(vec![3], 20)); // 21 > max_seq 8
    let err = sched.step(&mut server, &mut cache).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<ServeError>(),
        Some(ServeError::SeqTooLong { .. })
    ));
    let recovered = sched.drain_finished();
    assert_eq!(recovered.len(), 1, "finished sequence was dropped by the error");
    assert_eq!(recovered[0].id, a);
    assert_eq!(recovered[0].generated().len(), 1);
    assert!(sched.idle());
    assert_eq!(cache.reserved_bytes(), 0);
}

#[test]
fn decode_serve_generator_matches_naive_recompute_on_fixture_prompts() {
    // The eval-side satellite: KV-cached generation through the serving
    // stack ≡ recomputing full-sequence logits per emitted token (what
    // `eval/generate.rs` used to do), token for token, on a fixture
    // prompt set.
    use pissa::data::tokenizer::{EOS, VOCAB};
    use pissa::eval::{layout_prompt, extract_response, ServeGenerator};
    let mut rng = Rng::new(4242);
    let mut cfg = model_cfg();
    cfg.vocab = VOCAB; // byte-level tokenizer ids must be embeddable
    let base = BaseModel::random(&cfg, &mut rng);
    let mut eng = AdapterEngine::new(base);
    eng.attach("t", AdapterSpec::pissa(4), &mut rng).unwrap();
    for module in LINEARS {
        drift_factors(&mut eng, "t", module, 0.05, &mut rng).unwrap();
    }
    let serve_cfg = ServeConfig::full_model().max_seq(48).slots(4);
    let fixtures: Vec<String> = ["3 + 4 =", "apples?", "x", "Total: 12 - 5"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let max_new = 12;

    let mut sgen = ServeGenerator::new(&eng, serve_cfg.clone(), Some("t")).unwrap();
    let fast = sgen.generate(&fixtures, max_new).unwrap();

    // Naive reference: per prompt, per token, a from-scratch prefill of
    // the whole prefix (no cache reuse) — the O(T²) path.
    let mut server = ModelServer::new(&eng, serve_cfg).unwrap();
    let mut cache = server.new_cache().unwrap();
    for (p, fast_out) in fixtures.iter().zip(&fast) {
        let toks = layout_prompt(p, cache.max_seq());
        let mut tokens: Vec<usize> = toks.iter().map(|&t| t as usize).collect();
        let budget = max_new.min(cache.max_seq() - tokens.len());
        for _ in 0..budget {
            let slot = cache.try_claim(tokens.len()).unwrap().unwrap();
            let logits = server.prefill(&mut cache, slot, Some("t"), &tokens).unwrap();
            cache.release(slot);
            let tok = argmax(&logits);
            tokens.push(tok);
            if tok == EOS as usize {
                break;
            }
        }
        let toks_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let want = extract_response(&toks_i32);
        assert_eq!(fast_out, &want, "prompt {p:?}: cached decode diverged from recompute");
    }
}

// ---- edge-case hardening ---------------------------------------------

#[test]
fn empty_batch_is_ok_and_empty() {
    let (engine, _, _) = build_engine(4, 10);
    for strategy in ServeStrategy::all() {
        let mut server =
            Server::new(&engine, ServeConfig::new(MODULE).strategy(strategy)).unwrap();
        let y = server.forward(&[]).unwrap();
        assert_eq!((y.rows, y.cols), (0, 32));
    }
}

#[test]
fn unknown_adapter_is_typed_not_a_panic() {
    let (engine, _, _) = build_engine(4, 11);
    let mut server = Server::new(&engine, ServeConfig::new(MODULE)).unwrap();
    let err = server.forward(&[Request::new("nope", vec![0.0; 32])]).unwrap_err();
    let typed = err.downcast_ref::<ServeError>();
    assert!(
        matches!(typed, Some(ServeError::UnknownAdapter { name, .. }) if name == "nope"),
        "got {err:?}"
    );
}

#[test]
fn over_rank_adapter_rejected_with_clear_message() {
    let mut rng = Rng::new(12);
    let base = BaseModel::random(&cfg(32), &mut rng);
    let mut eng = AdapterEngine::new(base);
    // LoRA attaches at any rank (A·B = 0); serving must refuse 48 > 32.
    eng.attach("fat", AdapterSpec::lora(48).targets(&[MODULE]), &mut rng).unwrap();
    let err = Server::new(&eng, ServeConfig::new(MODULE)).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::RankTooLarge { rank: 48, .. })
        ),
        "got {err:?}"
    );
    assert!(err.to_string().contains("min(m, n)"), "message: {err}");

    // The escape hatch the message names: merged/dense serving accepts
    // the over-rank adapter and still matches the engine's weights.
    let mut x = vec![0.0f32; 32];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let w = eng.effective_weight_of("fat", MODULE, 0).unwrap();
    let want = vecmat(&x, &w);
    for strategy in [ServeStrategy::DensePerAdapter, ServeStrategy::MergePerRequest] {
        let mut server =
            Server::new(&eng, ServeConfig::new(MODULE).strategy(strategy)).unwrap();
        let got = server.forward(&[Request::new("fat", x.clone())]).unwrap();
        let err: f64 = got
            .row(0)
            .iter()
            .zip(&want)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(err < 1e-4, "{}: over-rank dense serve err {err:.3e}", strategy.name());
    }
}

// ---- adapter residency tiering (eviction invariance) ------------------

fn tiering_tmp(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("pissa_equiv_tiering_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn tiering_eviction_history_is_bitwise_invariant_for_exact_policy() {
    // THE tiering contract: a budget-starved tiered server — every
    // fixture switch forces a demote of the previous tenant and a cold
    // re-attach of the next, plus a forced demote→promote round trip in
    // the MIDDLE of each trajectory — must serve tokens AND logits
    // bitwise identical to an all-hot server. Eviction history is not
    // allowed to exist, numerically.
    let run = || -> Vec<(Vec<usize>, Vec<Vec<f32>>)> {
        let seed = 1300;
        let fixtures: [(&str, Vec<usize>); 3] = [
            ("pissa-t", vec![3, 17, 41, 8]),
            ("partial", vec![25, 1, 30]),
            ("lora-t", vec![9, 9, 30, 2]),
        ];
        let n_new = 6;
        let cfg = ServeConfig::full_model().strategy(ServeStrategy::Fused).max_seq(32);

        // All-hot reference trajectories.
        let (hot_eng, _, _) = build_model_engine(4, seed);
        let mut hot_srv = ModelServer::new(&hot_eng, cfg.clone()).unwrap();
        let mut hot_cache = hot_srv.new_cache().unwrap();
        let baseline: Vec<_> = fixtures
            .iter()
            .map(|(a, p)| incremental_trajectory(&mut hot_srv, &mut hot_cache, Some(*a), p, n_new))
            .collect();

        // Identically-seeded tiered twin with room for exactly ONE full
        // adapter ("partial" is smaller; the full-coverage pair is the
        // budget unit).
        let (mut eng, names, _) = build_model_engine(4, seed);
        let mut srv = ModelServer::new(&eng, cfg).unwrap();
        let mut cache = srv.new_cache().unwrap();
        let dir = tiering_tmp("exact");
        let budget =
            eng.adapter_bytes("pissa-t").unwrap() + srv.adapter_delta_bytes("pissa-t");
        let mut tiers = TierManager::new(budget, &dir);
        for n in &names {
            tiers.register_hot(n, &eng, &srv).unwrap();
        }

        let mut tiered = Vec::new();
        for (adapter, prompt) in &fixtures {
            let want = vec![adapter.to_string()];
            let failed = tiers.ensure_resident(&mut eng, &mut srv, &want);
            assert!(failed.is_empty(), "promotion failed: {failed:?}");
            assert!(
                tiers.resident_bytes() <= tiers.budget_bytes(),
                "resident {} bytes over the {} byte budget",
                tiers.resident_bytes(),
                tiers.budget_bytes()
            );
            assert_eq!(tiers.tier(adapter), Some(Tier::Hot));

            // The incremental trajectory, with a forced demote→promote
            // round trip after step 3. The KV cache is untouched by tier
            // transitions, so the continuation must not move.
            let slot = cache.try_claim(prompt.len() + n_new).unwrap().unwrap();
            let mut tokens = prompt.clone();
            let mut logits_all = Vec::new();
            let l0 = srv.prefill(&mut cache, slot, Some(*adapter), prompt).unwrap();
            let mut next = argmax(&l0);
            tokens.push(next);
            logits_all.push(l0);
            for step in 1..n_new {
                if step == 3 {
                    tiers.demote(&mut eng, &mut srv, adapter).unwrap();
                    assert_eq!(
                        tiers.tier(adapter),
                        Some(Tier::Cold),
                        "Exact demote spills to disk"
                    );
                    assert!(!srv.serves_adapter(adapter));
                    let failed = tiers.ensure_resident(&mut eng, &mut srv, &want);
                    assert!(failed.is_empty(), "re-promotion failed: {failed:?}");
                    assert!(tiers.resident_bytes() <= tiers.budget_bytes());
                }
                let req =
                    DecodeRequest { slot, token: next, adapter: Some(adapter.to_string()) };
                let lm = srv.decode_step(&mut cache, &[req]).unwrap();
                let row = lm.row(0).to_vec();
                next = argmax(&row);
                tokens.push(next);
                logits_all.push(row);
            }
            cache.release(slot);
            tiered.push((tokens, logits_all));
        }
        assert!(
            tiers.counters().demotions >= fixtures.len(),
            "churn never happened: {:?}",
            tiers.counters()
        );
        for (((bt, bl), (tt, tl)), (adapter, _)) in baseline.iter().zip(&tiered).zip(&fixtures) {
            assert_eq!(bt, tt, "{adapter}: tokens diverged across eviction history");
            assert_eq!(bl, tl, "{adapter}: logits diverged across eviction history");
        }
        std::fs::remove_dir_all(&dir).ok();
        tiered
    };
    // The contract must hold — and agree bitwise — at 1 and 8 threads.
    let t1 = with_parallelism(1, run);
    let t8 = with_parallelism(8, run);
    assert_eq!(t1, t8, "tiered trajectories differ across thread counts");
}

#[test]
fn tiering_warm_nf4_promote_is_the_quantizer_round_trip_and_stable() {
    // The Compressed policy trades the bitwise guarantee for ~7× smaller
    // warm copies. Its contract: every promoted tensor is EXACTLY the
    // per-layer NF4 round trip of the original (deterministic
    // dequantization — nothing else may leak in), each layer obeys the
    // pinned relative-Frobenius bound, and a second demote→promote cycle
    // leaves the SERVED logits bitwise stable (NF4 is a fixed point, all
    // the way through the serving path).
    let seed = 1310;
    let (mut eng, _, _) = build_model_engine(4, seed);
    let cfg = ServeConfig::full_model().strategy(ServeStrategy::Fused).max_seq(32);
    let mut srv = ModelServer::new(&eng, cfg).unwrap();
    let mut cache = srv.new_cache().unwrap();
    let dir = tiering_tmp("warm");
    let mut tiers = TierManager::new(usize::MAX, &dir);
    tiers.register_hot("pissa-t", &eng, &srv).unwrap();
    tiers.set_policy("pissa-t", DemotePolicy::Compressed).unwrap();

    let orig = eng.get("pissa-t").unwrap().clone();
    let want = vec!["pissa-t".to_string()];
    tiers.demote(&mut eng, &mut srv, "pissa-t").unwrap();
    assert_eq!(tiers.tier("pissa-t"), Some(Tier::Warm));
    assert!(!srv.serves_adapter("pissa-t"));
    let failed = tiers.ensure_resident(&mut eng, &mut srv, &want);
    assert!(failed.is_empty(), "warm promotion failed: {failed:?}");
    assert_eq!(tiers.tier("pissa-t"), Some(Tier::Hot));

    let back = eng.get("pissa-t").unwrap().clone();
    for (store_orig, store_back, prefix) in [
        (&orig.frozen, &back.frozen, "frozen"),
        (&orig.factors, &back.factors, "factors"),
        (&orig.init_factors, &back.init_factors, "init"),
    ] {
        for (k, t) in store_orig.iter() {
            let rt = &store_back[k];
            assert_eq!(t.shape, rt.shape, "{prefix}.{k}: shape changed through warm tier");
            for li in 0..t.shape[0] {
                let o = t.layer(li);
                let r = rt.layer(li);
                assert_eq!(
                    nf4_roundtrip(&o).data,
                    r.data,
                    "{prefix}.{k}[{li}]: warm promote is not the NF4 round trip"
                );
                let rel = o.sub(&r).fro() / o.fro().max(1e-30);
                assert!(
                    rel <= WARM_NF4_REL_TOL,
                    "{prefix}.{k}[{li}]: rel err {rel:.3e} over the pinned bound"
                );
            }
        }
    }

    // Served logits after a SECOND round trip: bitwise stable.
    let prompt = vec![3usize, 17, 41, 8];
    let (t1, l1) = incremental_trajectory(&mut srv, &mut cache, Some("pissa-t"), &prompt, 6);
    tiers.demote(&mut eng, &mut srv, "pissa-t").unwrap();
    let failed = tiers.ensure_resident(&mut eng, &mut srv, &want);
    assert!(failed.is_empty(), "second warm promotion failed: {failed:?}");
    let (t2, l2) = incremental_trajectory(&mut srv, &mut cache, Some("pissa-t"), &prompt, 6);
    assert_eq!(t1, t2, "second warm round trip moved the sampled tokens");
    assert_eq!(l1, l2, "second warm round trip moved the served logits");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tiering_cold_tenant_attaches_on_miss_through_the_scheduler() {
    // The serving-path half of attach-on-miss: a tenant registered ONLY
    // as an on-disk checkpoint is routable immediately, becomes resident
    // on its first request via the step-boundary hook (exactly one cold
    // attach), and generates the saved adapter's exact trajectory —
    // through the real continuous-batching scheduler, against a server
    // built before the tenant existed.
    let seed = 1320;
    let cfg = ServeConfig::full_model().strategy(ServeStrategy::Fused).max_seq(32);
    let prompt = vec![3usize, 17, 41, 8];
    let max_new = 6;

    // All-hot reference: "pissa-t" served directly.
    let (hot_eng, _, _) = build_model_engine(4, seed);
    let mut hot_srv = ModelServer::new(&hot_eng, cfg.clone()).unwrap();
    let mut hot_cache = hot_srv.new_cache().unwrap();
    let (want_tokens, _) =
        incremental_trajectory(&mut hot_srv, &mut hot_cache, Some("pissa-t"), &prompt, max_new);

    // Tiered twin: the same adapter saved to disk and registered under a
    // NEW tenant name the ModelServer has never seen.
    let (mut eng, names, _) = build_model_engine(4, seed);
    let dir = tiering_tmp("cold");
    let path = dir.join("templates").join("pissa-t.ckpt");
    eng.save("pissa-t", &path).unwrap();
    let mut srv = ModelServer::new(&eng, cfg).unwrap();
    let mut cache = srv.new_cache().unwrap();
    let mut tiers = TierManager::new(usize::MAX, &dir.join("spill"));
    for n in &names {
        tiers.register_hot(n, &eng, &srv).unwrap();
    }
    tiers.register_cold("tenant-on-disk", &path).unwrap();
    assert_eq!(tiers.tier("tenant-on-disk"), Some(Tier::Cold));
    assert!(!srv.serves_adapter("tenant-on-disk"));

    let mut sched = DecodeScheduler::new();
    sched.submit(SeqRequest::new("tenant-on-disk", prompt, max_new));
    let mut finished = Vec::new();
    while !sched.idle() {
        // The step-boundary hook the HTTP engine thread runs: promote
        // everything the pending/running set needs BEFORE the step.
        let wanted = sched.active_adapters();
        let failed = tiers.ensure_resident(&mut eng, &mut srv, &wanted);
        assert!(failed.is_empty(), "attach-on-miss failed: {failed:?}");
        finished.extend(sched.step(&mut srv, &mut cache).unwrap());
    }
    assert_eq!(tiers.tier("tenant-on-disk"), Some(Tier::Hot), "attached on miss");
    assert_eq!(tiers.counters().cold_attaches, 1, "exactly one cold attach");
    assert_eq!(finished.len(), 1);
    assert_eq!(
        finished[0].tokens, want_tokens,
        "cold-attached tenant must serve the saved adapter's exact trajectory"
    );
    std::fs::remove_dir_all(&dir).ok();
}
