//! Serving equivalence property tests.
//!
//! Contract: for random bases, every full-precision serving strategy,
//! rank ∈ {1, 4, 16}, and batch ∈ {1, 7, 64}, the batched server output
//! equals the merged-dense forward (`engine.effective_weight_of` row by
//! row) within 1e-4 relative Frobenius error — including mixed-adapter
//! batches and the no-adapter (base-only) path. The quantized-base pair
//! has its own contract over the same rank × batch grid: `fused-quant`
//! equals the dequantize-once dense reference bit for bit, and matches
//! the fp32 fused forward within a tolerance derived from
//! `quant::error::fro_error` of the NF4 base round trip. Plus the
//! edge-case hardening set: empty batches, unknown adapters, over-rank
//! configs, and quantized adapters under full-precision strategies are
//! typed errors, never panics.

use pissa::adapter::{AdapterEngine, AdapterSpec};
use pissa::linalg::{matmul, vecmat, Mat};
use pissa::model::BaseModel;
use pissa::quant::error::fro_error;
use pissa::quant::nf4_roundtrip;
use pissa::runtime::ConfigInfo;
use pissa::serve::{drift_factors, Request, ServeConfig, ServeError, ServeStrategy, Server};
use pissa::util::rng::Rng;

const MODULE: &str = "q";

fn cfg(d_model: usize) -> ConfigInfo {
    ConfigInfo {
        name: "serve-equiv".into(),
        kind: "decoder".into(),
        vocab: 64,
        d_model,
        n_layers: 2,
        n_heads: 2,
        d_ff: d_model + 8,
        seq_len: 8,
        batch: 4,
        eval_batch: 2,
        n_classes: 0,
        ranks: vec![4],
    }
}

/// Engine with one drifted PiSSA adapter and one drifted LoRA adapter at
/// `rank`, plus an un-drifted PiSSA adapter (its delta must be ~zero).
fn build_engine(rank: usize, seed: u64) -> (AdapterEngine, Vec<String>, Rng) {
    let mut rng = Rng::new(seed);
    let base = BaseModel::random(&cfg(32), &mut rng);
    let mut eng = AdapterEngine::new(base);
    eng.attach("pissa-t", AdapterSpec::pissa(rank).targets(&[MODULE, "v"]), &mut rng)
        .unwrap();
    drift_factors(&mut eng, "pissa-t", MODULE, 0.05, &mut rng).unwrap();
    eng.attach("lora-t", AdapterSpec::lora(rank), &mut rng).unwrap();
    drift_factors(&mut eng, "lora-t", MODULE, 0.05, &mut rng).unwrap();
    eng.attach("pissa-init", AdapterSpec::pissa(rank).targets(&[MODULE]), &mut rng)
        .unwrap();
    let names = vec!["pissa-t".to_string(), "lora-t".to_string(), "pissa-init".to_string()];
    (eng, names, rng)
}

/// Ground truth: per request, materialize the adapter's effective dense
/// weight from the engine and apply it to the input row.
fn reference(engine: &AdapterEngine, layer: usize, requests: &[Request]) -> Mat {
    let mut y = Mat::zeros(requests.len(), 32);
    for (i, r) in requests.iter().enumerate() {
        let w = match &r.adapter {
            Some(name) => engine.effective_weight_of(name, MODULE, layer).unwrap(),
            None => engine.base_weight(MODULE, layer),
        };
        y.row_mut(i).copy_from_slice(&vecmat(&r.x, &w));
    }
    y
}

fn mixed_batch(names: &[String], size: usize, rng: &mut Rng) -> Vec<Request> {
    (0..size)
        .map(|i| {
            let mut x = vec![0.0f32; 32];
            rng.fill_normal(&mut x, 0.0, 1.0);
            // Deterministic mix: every 4th request is base-only, the rest
            // cycle through the adapters.
            if i % 4 == 3 {
                Request::base(x)
            } else {
                Request::new(&names[i % names.len()], x)
            }
        })
        .collect()
}

fn rel_fro(a: &Mat, b: &Mat) -> f64 {
    a.sub(b).fro() / b.fro().max(1e-30)
}

#[test]
fn all_exact_strategies_match_merged_dense_forward() {
    for &rank in &[1usize, 4, 16] {
        let (engine, names, mut rng) = build_engine(rank, 100 + rank as u64);
        for layer in [0usize, 1] {
            for &batch in &[1usize, 7, 64] {
                let requests = mixed_batch(&names, batch, &mut rng);
                let want = reference(&engine, layer, &requests);
                for strategy in ServeStrategy::exact() {
                    let mut server = Server::new(
                        &engine,
                        ServeConfig::new(MODULE).layer(layer).strategy(strategy).max_batch(64),
                    )
                    .unwrap();
                    let got = server.forward(&requests).unwrap();
                    assert_eq!((got.rows, got.cols), (batch, 32));
                    let err = rel_fro(&got, &want);
                    assert!(
                        err < 1e-4,
                        "rank={rank} layer={layer} batch={batch} strategy={}: rel fro \
                         err {err:.3e}",
                        strategy.name()
                    );
                }
            }
        }
    }
}

#[test]
fn base_only_batch_matches_dense_base() {
    let (engine, _, mut rng) = build_engine(4, 7);
    let requests: Vec<Request> = (0..9)
        .map(|_| {
            let mut x = vec![0.0f32; 32];
            rng.fill_normal(&mut x, 0.0, 1.0);
            Request::base(x)
        })
        .collect();
    let want = reference(&engine, 0, &requests);
    for strategy in ServeStrategy::exact() {
        let mut server =
            Server::new(&engine, ServeConfig::new(MODULE).strategy(strategy)).unwrap();
        let got = server.forward(&requests).unwrap();
        let err = rel_fro(&got, &want);
        assert!(err < 1e-5, "{}: base-only err {err:.3e}", strategy.name());
    }
}

// ---- quantized-base serving (fused NF4 dequant-GEMM) ------------------

/// Frobenius norm of a batch of request inputs (for the ‖X·E‖_F ≤
/// ‖X‖_F·‖E‖_F tolerance bound).
fn requests_fro(requests: &[Request]) -> f64 {
    requests
        .iter()
        .flat_map(|r| r.x.iter())
        .map(|&v| (v as f64) * (v as f64))
        .sum::<f64>()
        .sqrt()
}

#[test]
fn fused_quant_matches_dequant_once_dense_bit_for_bit() {
    // The DequantGemm contract: streaming NF4 panels through the fused
    // forward is the SAME arithmetic as dequantizing once into a dense
    // base — for every rank × batch point, mixed batches included.
    for &rank in &[1usize, 4, 16] {
        let (engine, names, mut rng) = build_engine(rank, 300 + rank as u64);
        for layer in [0usize, 1] {
            for &batch in &[1usize, 7, 64] {
                let requests = mixed_batch(&names, batch, &mut rng);
                let mut fq = Server::new(
                    &engine,
                    ServeConfig::new(MODULE)
                        .layer(layer)
                        .strategy(ServeStrategy::FusedQuant)
                        .max_batch(64),
                )
                .unwrap();
                let mut dd = Server::new(
                    &engine,
                    ServeConfig::new(MODULE)
                        .layer(layer)
                        .strategy(ServeStrategy::DequantDense)
                        .max_batch(64),
                )
                .unwrap();
                let yq = fq.forward(&requests).unwrap();
                let yd = dd.forward(&requests).unwrap();
                assert_eq!(
                    yq.data,
                    yd.data,
                    "rank={rank} layer={layer} batch={batch}: fused-quant diverged from \
                     the dequantize-once dense reference"
                );
                // And the NF4 store really is smaller than the dense one.
                assert!(fq.base_resident_bytes() * 2 < dd.base_resident_bytes());
            }
        }
    }
}

#[test]
fn fused_quant_matches_fp32_fused_within_nf4_tolerance() {
    // fused-quant differs from the fp32 fused forward ONLY in the base:
    // Y_q − Y = X·(deq(nf4(W)) − W), so ‖Y_q − Y‖_F is bounded by
    // ‖X‖_F times the NF4 round-trip error fro_error(W, nf4(W)).
    for &rank in &[1usize, 4, 16] {
        let (engine, names, mut rng) = build_engine(rank, 400 + rank as u64);
        for layer in [0usize, 1] {
            let w = engine.base_weight(MODULE, layer);
            let nf4_err = fro_error(&w, &nf4_roundtrip(&w));
            assert!(nf4_err > 0.0, "NF4 must actually perturb a random base");
            for &batch in &[1usize, 7, 64] {
                let requests = mixed_batch(&names, batch, &mut rng);
                let mut fused = Server::new(
                    &engine,
                    ServeConfig::new(MODULE)
                        .layer(layer)
                        .strategy(ServeStrategy::Fused)
                        .max_batch(64),
                )
                .unwrap();
                let mut fq = Server::new(
                    &engine,
                    ServeConfig::new(MODULE)
                        .layer(layer)
                        .strategy(ServeStrategy::FusedQuant)
                        .max_batch(64),
                )
                .unwrap();
                let y = fused.forward(&requests).unwrap();
                let yq = fq.forward(&requests).unwrap();
                let diff = yq.sub(&y).fro();
                let bound = requests_fro(&requests) * nf4_err * 1.001 + 1e-5;
                assert!(
                    diff <= bound,
                    "rank={rank} layer={layer} batch={batch}: |Yq - Y|_F = {diff:.4e} \
                     exceeds the NF4-derived bound {bound:.4e}"
                );
                // The quantization is visible (guards a silently-dense base).
                assert!(diff > 0.0, "rank={rank} layer={layer} batch={batch}");
            }
        }
    }
}

#[test]
fn quantized_adapters_route_through_fused_quant() {
    // QLoRA and QPiSSA adapters — the configuration the paper says is
    // cheapest to deploy — are a typed error under every full-precision
    // strategy (message naming the escape hatch) and served end-to-end
    // by fused-quant.
    let mut rng = Rng::new(13);
    let base = BaseModel::random(&cfg(32), &mut rng);
    let mut eng = AdapterEngine::new(base);
    eng.attach("ql", AdapterSpec::qlora(4).targets(&[MODULE]), &mut rng).unwrap();
    drift_factors(&mut eng, "ql", MODULE, 0.05, &mut rng).unwrap();
    eng.attach("qp", AdapterSpec::qpissa(4).iters(2).targets(&[MODULE]), &mut rng).unwrap();
    drift_factors(&mut eng, "qp", MODULE, 0.05, &mut rng).unwrap();

    for strategy in ServeStrategy::exact() {
        let err =
            Server::new(&eng, ServeConfig::new(MODULE).strategy(strategy)).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::QuantizedAdapter { .. })),
            "{}: got {err:?}",
            strategy.name()
        );
        assert!(err.to_string().contains("fused-quant"), "escape hatch missing: {err}");
    }

    let mut server = Server::new(
        &eng,
        ServeConfig::new(MODULE).strategy(ServeStrategy::FusedQuant).max_batch(8),
    )
    .unwrap();
    let requests: Vec<Request> = (0..6)
        .map(|i| {
            let mut x = vec![0.0f32; 32];
            rng.fill_normal(&mut x, 0.0, 1.0);
            Request::new(["ql", "qp"][i % 2], x)
        })
        .collect();
    let got = server.forward(&requests).unwrap();

    let w = eng.base_weight(MODULE, 0);
    for (i, r) in requests.iter().enumerate() {
        let name = r.adapter.as_deref().unwrap();
        let ad = eng.get(name).unwrap();
        let w_eff = eng.effective_weight_of(name, MODULE, 0).unwrap();
        let want = vecmat(&r.x, &w_eff);
        // served_W − true_W = nf4(W) − A₀·B₀ − frozen, exactly (the
        // drifted factors cancel); bound the row error by ‖x‖·‖E‖_F.
        let a0 = ad.init_factors[&format!("a_{MODULE}")].layer(0);
        let b0 = ad.init_factors[&format!("b_{MODULE}")].layer(0);
        let frozen = ad.frozen[&format!("base_{MODULE}")].layer(0);
        let e = nf4_roundtrip(&w).sub(&matmul(&a0, &b0)).sub(&frozen);
        let x_norm = r.x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
        let bound = x_norm * e.fro() * 1.001 + 1e-4;
        let row_err: f64 = got
            .row(i)
            .iter()
            .zip(&want)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(
            row_err <= bound,
            "request {i} ({name}): err {row_err:.4e} > bound {bound:.4e}"
        );
    }
}

#[test]
fn single_adapter_batch_matches_merged_weight() {
    // One group, whole batch under one drifted adapter: the fused
    // correction path must agree with engine merge (effective weight).
    let (engine, _, mut rng) = build_engine(4, 8);
    let requests: Vec<Request> = (0..16)
        .map(|_| {
            let mut x = vec![0.0f32; 32];
            rng.fill_normal(&mut x, 0.0, 1.0);
            Request::new("pissa-t", x)
        })
        .collect();
    let want = reference(&engine, 1, &requests);
    let mut server = Server::new(&engine, ServeConfig::new(MODULE).layer(1)).unwrap();
    let got = server.forward(&requests).unwrap();
    assert!(rel_fro(&got, &want) < 1e-4);
}

#[test]
fn undrifted_pissa_adapter_serves_the_original_weight() {
    // At init the exactness invariant pins effective == W, so serving the
    // un-drifted adapter must equal serving the base.
    let (engine, _, mut rng) = build_engine(4, 9);
    let mut x = vec![0.0f32; 32];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let mut server = Server::new(&engine, ServeConfig::new(MODULE)).unwrap();
    let via_adapter = server.forward(&[Request::new("pissa-init", x.clone())]).unwrap();
    let via_base = server.forward(&[Request::base(x)]).unwrap();
    assert!(rel_fro(&via_adapter, &via_base) < 1e-4);
}

// ---- edge-case hardening ---------------------------------------------

#[test]
fn empty_batch_is_ok_and_empty() {
    let (engine, _, _) = build_engine(4, 10);
    for strategy in ServeStrategy::all() {
        let mut server =
            Server::new(&engine, ServeConfig::new(MODULE).strategy(strategy)).unwrap();
        let y = server.forward(&[]).unwrap();
        assert_eq!((y.rows, y.cols), (0, 32));
    }
}

#[test]
fn unknown_adapter_is_typed_not_a_panic() {
    let (engine, _, _) = build_engine(4, 11);
    let mut server = Server::new(&engine, ServeConfig::new(MODULE)).unwrap();
    let err = server.forward(&[Request::new("nope", vec![0.0; 32])]).unwrap_err();
    let typed = err.downcast_ref::<ServeError>();
    assert!(
        matches!(typed, Some(ServeError::UnknownAdapter { name, .. }) if name == "nope"),
        "got {err:?}"
    );
}

#[test]
fn over_rank_adapter_rejected_with_clear_message() {
    let mut rng = Rng::new(12);
    let base = BaseModel::random(&cfg(32), &mut rng);
    let mut eng = AdapterEngine::new(base);
    // LoRA attaches at any rank (A·B = 0); serving must refuse 48 > 32.
    eng.attach("fat", AdapterSpec::lora(48).targets(&[MODULE]), &mut rng).unwrap();
    let err = Server::new(&eng, ServeConfig::new(MODULE)).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::RankTooLarge { rank: 48, .. })
        ),
        "got {err:?}"
    );
    assert!(err.to_string().contains("min(m, n)"), "message: {err}");

    // The escape hatch the message names: merged/dense serving accepts
    // the over-rank adapter and still matches the engine's weights.
    let mut x = vec![0.0f32; 32];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let w = eng.effective_weight_of("fat", MODULE, 0).unwrap();
    let want = vecmat(&x, &w);
    for strategy in [ServeStrategy::DensePerAdapter, ServeStrategy::MergePerRequest] {
        let mut server =
            Server::new(&eng, ServeConfig::new(MODULE).strategy(strategy)).unwrap();
        let got = server.forward(&[Request::new("fat", x.clone())]).unwrap();
        let err: f64 = got
            .row(0)
            .iter()
            .zip(&want)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(err < 1e-4, "{}: over-rank dense serve err {err:.3e}", strategy.name());
    }
}
