//! Property-based tests over the coordinator-side substrates: randomized
//! shape/seed sweeps asserting the algebraic invariants the paper's
//! method relies on. (No proptest crate offline — a seeded-sweep loop
//! over our own PRNG plays the same role, with the failing seed printed.)

use pissa::adapter::convert::pissa_to_lora;
use pissa::adapter::init::{self, Strategy};
use pissa::adapter::AdapterSpec;
use pissa::linalg::{matmul, matmul_nt, matmul_tn, nuclear_norm, rsvd, svd, Mat};
use pissa::quant::{nf4_roundtrip, qlora_error};
use pissa::util::rng::Rng;

fn rand_shape(rng: &mut Rng, lo: usize, hi: usize) -> (usize, usize) {
    (lo + rng.below(hi - lo), lo + rng.below(hi - lo))
}

/// A matrix with a decaying (pre-trained-like) spectrum.
fn spectral_mat(m: usize, n: usize, decay: f32, rng: &mut Rng) -> Mat {
    let k = m.min(n);
    let u = pissa::linalg::qr::orthonormalize(&Mat::randn(m, k, 0.0, 1.0, rng));
    let v = pissa::linalg::qr::orthonormalize(&Mat::randn(n, k, 0.0, 1.0, rng));
    let s: Vec<f32> = (0..k).map(|i| (1.0 + i as f32).powf(-decay)).collect();
    let mut us = u;
    us.scale_cols(&s);
    matmul(&us, &v.t())
}

#[test]
fn prop_pissa_exact_preservation_across_shapes() {
    // base + A·B == W for every shape/rank/niter (Eq. 5).
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed);
        let (m, n) = rand_shape(&mut rng, 8, 48);
        let r = 1 + rng.below(m.min(n).min(8));
        let w = Mat::randn(m, n, 0.0, 0.3, &mut rng);
        let niter = if rng.below(2) == 0 { None } else { Some(1 + rng.below(6)) };
        let init = init::pissa(&w, r, niter, &mut rng);
        let err = init.effective().sub(&w).fro() / w.fro();
        assert!(err < 1e-5, "seed={seed} {m}x{n} r={r} niter={niter:?} err={err}");
    }
}

#[test]
fn prop_svd_reconstruction_and_ordering() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(100 + seed);
        let (m, n) = rand_shape(&mut rng, 4, 40);
        let w = Mat::randn(m, n, 0.0, 1.0, &mut rng);
        let d = svd(&w);
        let err = d.reconstruct().sub(&w).fro() / w.fro();
        assert!(err < 1e-4, "seed={seed} err={err}");
        assert!(d.s.windows(2).all(|p| p[0] >= p[1] - 1e-5), "seed={seed} unsorted");
        assert!(d.s.iter().all(|&x| x >= 0.0));
    }
}

#[test]
fn prop_rsvd_never_beats_optimal_but_close_with_iters() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(200 + seed);
        let w = spectral_mat(40, 32, 0.7, &mut rng);
        let exact = svd(&w);
        let r = 6;
        let opt: f64 = exact.s[r..].iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        let approx = rsvd(&w, r, 5, &mut rng);
        let err = approx.reconstruct().sub(&w).fro();
        assert!(err >= opt - 1e-4, "seed={seed}: rsvd beat the optimum?!");
        assert!(err <= 1.25 * opt + 1e-6, "seed={seed}: err {err} far from optimal {opt}");
    }
}

#[test]
fn prop_qpissa_error_never_exceeds_qlora() {
    // On decaying-spectrum matrices, the paper's Eq. 8 ≤ Eq. 6 must hold.
    for seed in 0..8u64 {
        let mut rng = Rng::new(300 + seed);
        let w = spectral_mat(32 + rng.below(16), 32, 0.6 + rng.uniform() as f32, &mut rng);
        let baseline = qlora_error(&w);
        let r = 2 + rng.below(6);
        let qp = init::qpissa(&w, r, 1 + rng.below(4), &mut rng);
        let err = nuclear_norm(&w.sub(&qp.base.add(&matmul(&qp.a, &qp.b))));
        assert!(
            err <= baseline * 1.001,
            "seed={seed} r={r}: qpissa {err} > qlora {baseline}"
        );
    }
}

#[test]
fn prop_conversion_exact_for_any_drift() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(400 + seed);
        let (m, n) = rand_shape(&mut rng, 8, 32);
        let r = 1 + rng.below(4);
        let w = Mat::randn(m, n, 0.0, 0.5, &mut rng);
        let init = init::pissa(&w, r, None, &mut rng);
        let mut a1 = init.a.clone();
        let mut b1 = init.b.clone();
        let scale = rng.uniform_in(0.0, 2.0);
        for x in a1.data.iter_mut() {
            *x += scale * rng.normal_f32(0.0, 0.1);
        }
        for x in b1.data.iter_mut() {
            *x += scale * rng.normal_f32(0.0, 0.1);
        }
        let delta = pissa_to_lora(&init.a, &init.b, &a1, &b1);
        let via = w.add(&delta.delta());
        let direct = init.base.add(&matmul(&a1, &b1));
        let err = via.sub(&direct).fro() / direct.fro().max(1e-20);
        assert!(err < 1e-5, "seed={seed} err={err}");
    }
}

#[test]
fn prop_nf4_idempotent_and_bounded() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(500 + seed);
        let (m, n) = rand_shape(&mut rng, 4, 64);
        let scale = 10f32.powf(rng.uniform_in(-3.0, 1.0));
        let w = Mat::randn(m, n, 0.0, scale, &mut rng);
        let rt = nf4_roundtrip(&w);
        let rt2 = nf4_roundtrip(&rt);
        for (a, b) in rt.data.iter().zip(&rt2.data) {
            assert!((a - b).abs() <= 1e-6 * scale, "seed={seed} not idempotent");
        }
        // Largest codebook gap is levels[1]−levels[0] ≈ 0.304, so the
        // worst-case elementwise error is half that times the block absmax.
        let err = w.sub(&rt).absmax();
        assert!(err <= 0.153 * w.absmax() + 1e-7, "seed={seed} err {err} vs absmax {}", w.absmax());
    }
}

#[test]
fn prop_gemm_linearity_and_transpose_identities() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(600 + seed);
        let (m, k) = rand_shape(&mut rng, 3, 40);
        let (_, n) = rand_shape(&mut rng, 3, 40);
        let a = Mat::randn(m, k, 0.0, 1.0, &mut rng);
        let b = Mat::randn(k, n, 0.0, 1.0, &mut rng);
        // (A·B)ᵀ == Bᵀ·Aᵀ
        let lhs = matmul(&a, &b).t();
        let rhs = matmul(&b.t(), &a.t());
        assert!(lhs.sub(&rhs).fro() < 1e-3, "seed={seed} transpose identity");
        // A·(B+B) == 2·A·B
        let mut b2 = b.clone();
        b2.add_assign(&b);
        let mut two_ab = matmul(&a, &b);
        two_ab.scale(2.0);
        assert!(matmul(&a, &b2).sub(&two_ab).fro() < 1e-3, "seed={seed} linearity");
        // nt/tn agree with explicit transposes
        assert!(matmul_nt(&a, &b.t()).sub(&matmul(&a, &b)).fro() < 1e-3);
        assert!(matmul_tn(&a.t(), &b).sub(&matmul(&a, &b)).fro() < 1e-3);
    }
}

#[test]
fn prop_strategy_inits_all_preserve_model_or_quantize_base() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(700 + seed);
        let w = spectral_mat(24, 24, 0.8, &mut rng);
        for strategy in [Strategy::Lora, Strategy::Pissa] {
            let i = AdapterSpec::from_strategy(strategy, 4, 1).init_matrix(&w, 4, &mut rng);
            let err = i.effective().sub(&w).fro() / w.fro();
            assert!(err < 1e-4, "seed={seed} {strategy:?} err={err}");
        }
        for strategy in [Strategy::QLora, Strategy::QPissa, Strategy::LoftQ] {
            let i = AdapterSpec::from_strategy(strategy, 4, 2).init_matrix(&w, 4, &mut rng);
            // quantized strategies can't preserve exactly, but must beat
            // (or match) plain QLoRA's error
            let err = i.effective().sub(&w).fro();
            let base_err = w.sub(&nf4_roundtrip(&w)).fro();
            assert!(
                err <= base_err * 1.05,
                "seed={seed} {strategy:?}: {err} vs qlora {base_err}"
            );
        }
    }
}

#[test]
fn prop_lr_schedule_bounded_and_continuous() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(800 + seed);
        let total = 50 + rng.below(500);
        let peak = 10f64.powf(rng.uniform_in(-5.0, -2.0) as f64);
        let s = pissa::coordinator::LrSchedule::alpaca(peak, total);
        let mut prev = 0.0f64;
        for step in 1..=total {
            let lr = s.at(step);
            assert!((0.0..=peak * 1.0001).contains(&lr), "seed={seed} lr out of range");
            // jumps are bounded (continuity at warmup boundary)
            assert!((lr - prev).abs() <= peak / 2.0, "seed={seed} discontinuity at {step}");
            prev = lr;
        }
    }
}

// ---- NF4 quantization properties (quant/nf4.rs contract) ---------------

/// Reference nearest-neighbor over the 16 codebook levels with the
/// tie-break pinned: at an exact midpoint between two levels the LOWER
/// code wins (the boundary-count kernel uses strict `>`).
fn reference_nearest(x: f32) -> u8 {
    let mut best = 0usize;
    for (i, &level) in pissa::quant::nf4::NF4_LEVELS.iter().enumerate() {
        let (d, db) = ((x - level).abs(), (x - pissa::quant::nf4::NF4_LEVELS[best]).abs());
        if d < db {
            best = i;
        }
    }
    best as u8
}

/// Next representable f32 strictly greater than `x` (hand-rolled; avoids
/// depending on the recently stabilized `f32::next_up`).
fn next_up(x: f32) -> f32 {
    if x > 0.0 {
        f32::from_bits(x.to_bits() + 1)
    } else if x < 0.0 {
        f32::from_bits(x.to_bits() - 1)
    } else {
        f32::from_bits(1)
    }
}

#[test]
fn prop_nearest_code_is_true_nearest_neighbor() {
    use pissa::quant::nf4::{nearest_code, NF4_LEVELS};
    // Dense grid over (and beyond) the normalized domain: no grid point
    // lands on an exact midpoint, so true-nearest is unambiguous there
    // and the kernel must agree everywhere (exhaustive over all 16 codes
    // as targets).
    for step in -1500..=1500i32 {
        let x = step as f32 * 1e-3;
        let got = nearest_code(x);
        let want = reference_nearest(x);
        assert_eq!(got, want, "nearest_code({x}) = {got}, true nearest = {want}");
    }
    // Exact levels map to themselves; far outside saturates to the ends.
    for (i, &level) in NF4_LEVELS.iter().enumerate() {
        assert_eq!(nearest_code(level) as usize, i);
    }
    assert_eq!(nearest_code(-1e9), 0);
    assert_eq!(nearest_code(1e9), 15);
    // The 15 midpoints: exhaustive tie-break check, lower code wins at
    // the exact tie, upper code one ulp past it.
    for i in 0..15 {
        let mid = (NF4_LEVELS[i] + NF4_LEVELS[i + 1]) / 2.0;
        assert_eq!(
            nearest_code(mid) as usize,
            i,
            "tie at midpoint {mid} between codes {i} and {} must pin to {i}",
            i + 1
        );
        assert_eq!(nearest_code(next_up(mid)) as usize, i + 1, "just past midpoint {mid}");
    }
}

#[test]
fn prop_quantize_dequantize_is_idempotent() {
    use pissa::quant::{dequantize, quantize};
    for seed in 0..10u64 {
        let mut rng = Rng::new(900 + seed);
        let (m, n) = rand_shape(&mut rng, 1, 40); // incl. tail blocks & tiny mats
        let scale = 10f32.powf(rng.uniform_in(-3.0, 1.0));
        let mut w = Mat::randn(m, n, 0.0, scale, &mut rng);
        if seed % 3 == 0 && !w.data.is_empty() {
            // Force an all-zero block prefix (absmax = 0 edge case).
            for x in w.data.iter_mut().take(64.min(w.data.len())) {
                *x = 0.0;
            }
        }
        let t1 = quantize(&w);
        let d1 = dequantize(&t1);
        let t2 = quantize(&d1);
        // Quantized points are fixed points: codes AND scales identical,
        // not just values-within-tolerance.
        assert_eq!(t1.codes, t2.codes, "seed={seed} {m}x{n} codes drifted");
        assert_eq!(t1.scales, t2.scales, "seed={seed} {m}x{n} scales drifted");
        assert_eq!(d1.data, dequantize(&t2).data, "seed={seed} {m}x{n}");
    }
}

#[test]
fn prop_blockwise_error_bounded_by_half_max_gap_times_absmax() {
    use pissa::quant::nf4::{BLOCK, NF4_LEVELS};
    let max_gap = NF4_LEVELS.windows(2).map(|w| w[1] - w[0]).fold(0.0f32, f32::max);
    for seed in 0..8u64 {
        let mut rng = Rng::new(950 + seed);
        let (m, n) = rand_shape(&mut rng, 3, 50);
        let w = Mat::randn(m, n, 0.0, 0.5, &mut rng);
        let rt = nf4_roundtrip(&w);
        for (b, chunk) in w.data.chunks(BLOCK).enumerate() {
            let absmax = chunk.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            let bound = 0.5 * max_gap * absmax + 1e-6;
            for (i, &x) in chunk.iter().enumerate() {
                let err = (x - rt.data[b * BLOCK + i]).abs();
                assert!(
                    err <= bound,
                    "seed={seed} {m}x{n} block {b}: err {err} > bound {bound} (absmax {absmax})"
                );
            }
        }
    }
}

#[test]
fn prop_storage_bytes_matches_actual_buffers_incl_double_quant() {
    use pissa::quant::double::{double_quantize, quantize_scales, GROUP};
    use pissa::quant::nf4::BLOCK;
    use pissa::quant::{quantize, storage_bytes};
    for seed in 0..8u64 {
        let mut rng = Rng::new(990 + seed);
        let (m, n) = rand_shape(&mut rng, 1, 80);
        let w = Mat::randn(m, n, 0.0, 0.2, &mut rng);
        let t = quantize(&w);
        let vals = m * n;
        // The declared layout: two codes per byte, one f32 scale / block.
        assert_eq!(t.codes.len(), vals.div_ceil(2), "seed={seed} {m}x{n}");
        assert_eq!(t.scales.len(), vals.div_ceil(BLOCK), "seed={seed} {m}x{n}");
        assert_eq!(storage_bytes(&t), t.codes.len() + 4 * t.scales.len());
        assert_eq!(storage_bytes(&t), t.storage_bytes());
        // Double-quant metadata: one u8 code per scale + an (f32, f32)
        // affine pair per group of 256.
        let dq = quantize_scales(&t.scales);
        assert_eq!(dq.codes.len(), t.scales.len());
        assert_eq!(dq.groups.len(), t.scales.len().div_ceil(GROUP));
        assert_eq!(
            pissa::quant::double::storage_bytes(&dq),
            dq.codes.len() + 8 * dq.groups.len()
        );
        // double_quantize's reported saving is the bytes delta.
        let mut t2 = t.clone();
        let saved = double_quantize(&mut t2);
        let before = 4 * t.scales.len();
        let after = pissa::quant::double::storage_bytes(&dq);
        assert_eq!(saved, before.saturating_sub(after), "seed={seed} {m}x{n}");
    }
}

#[test]
fn prop_block_iterator_and_range_decode_agree_with_dequantize() {
    use pissa::quant::{dequantize, quantize};
    for seed in 0..8u64 {
        let mut rng = Rng::new(1030 + seed);
        let (m, n) = rand_shape(&mut rng, 2, 60);
        let t = quantize(&Mat::randn(m, n, 0.0, 0.4, &mut rng));
        let dense = dequantize(&t);
        // Blocks tile the flattened buffer exactly.
        let mut rebuilt = vec![0.0f32; t.len()];
        for blk in t.blocks() {
            blk.dequantize_into(&mut rebuilt[blk.start..blk.start + blk.len]);
        }
        assert_eq!(rebuilt, dense.data, "seed={seed} {m}x{n} blocks() retile");
        // Random unaligned ranges decode identically to slicing.
        for _ in 0..12 {
            let lo = rng.below(t.len() + 1);
            let hi = lo + rng.below(t.len() - lo + 1);
            let mut buf = vec![0.0f32; hi - lo];
            t.dequantize_range(lo, hi, &mut buf);
            assert_eq!(buf, dense.data[lo..hi], "seed={seed} range [{lo}, {hi})");
        }
    }
}
