//! `pissa` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   pretrain     pre-train a base model with the full-FT artifact
//!   train        fine-tune under a strategy (pissa/lora/qpissa/qlora/loftq/full-ft)
//!   eval         score a trained run on the synthetic GSM8K/HumanEval analogs
//!   quant-error  Table 3/6-style quantization-error reduction report
//!   convert      PiSSA→LoRA adapter conversion (Appendix C)
//!   serve        batched multi-adapter serving on a synthetic workload
//!   toy          the Figure-2a MNIST-analog convergence comparison
//!   info         print manifest/artifact inventory

use anyhow::Result;
use pissa::adapter::init::{Strategy, Window};
use pissa::adapter::store::Checkpoint;
use pissa::adapter::AdapterSpec;
use pissa::coordinator::{self, RunConfig, TaskFamily};
use pissa::linalg::matmul;
use pissa::metrics::JsonlSink;
use pissa::runtime::{Manifest, Runtime};
use pissa::util::cli::Args;
use pissa::util::rng::Rng;
use std::path::{Path, PathBuf};

fn art_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "quant-error" => cmd_quant_error(&args),
        "convert" => cmd_convert(&args),
        "serve" => cmd_serve(&args),
        "toy" => cmd_toy(&args),
        "info" => cmd_info(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        // A malformed flag is usage, not a crash: say which flag and how
        // to get help, and exit with a distinct status.
        if let Some(bad) = e.downcast_ref::<pissa::util::cli::ArgError>() {
            eprintln!("pissa: {bad}");
            eprintln!("run `pissa help` for usage");
            std::process::exit(2);
        }
        eprintln!("pissa: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "pissa {} — PiSSA (NeurIPS 2024) full-system reproduction

USAGE: pissa <command> [--flags]

COMMANDS
  pretrain     --config tiny --steps 200 --lr 2e-3 --out runs/base_tiny.ckpt
  train        --config tiny --spec pissa:rank=4:niter=4 --steps 100
               [--base runs/base_tiny.ckpt] [--out runs/run1]
  eval         --config tiny --spec pissa:rank=4
               [--task math|code|chat] [--n 64]
  quant-error  --config tiny [--base runs/base_tiny.ckpt] --ranks 2,4,8
  convert      --run runs/run1 --out runs/run1_lora.ckpt
  serve        --adapters 8 --rank 8 --batch 32 --batches 40
               [--strategy fused|merge|dense|fused-quant|dequant-dense]
               [--quantized]  (QPiSSA adapters + NF4-resident base via
                               the fused-quant dequant-GEMM path)
               [--full-model] (whole-model pipeline: token requests
                               through embed -> every layer's seven
                               adapted linears -> head logits;
                               [--layers 2] [--d-ff 2*d-model]
                               [--vocab 64])
               [--decode]     (autoregressive decode serving: sequence
                               requests through the continuous-batching
                               scheduler over the slot-paged KV cache;
                               [--requests 32] [--prompt-len 12]
                               [--max-new 24] [--slots 8] [--max-seq N]
                               [--kv-budget-mb 64] [--heads 1]
                               [--kv-heads HEADS] [--rope-theta 10000]
                               [--prefill-chunk 0])
               [--http ADDR]  (streaming HTTP front-end over the decode
                               scheduler: POST /v1/generate with chunked
                               NDJSON token streaming, GET /healthz,
                               GET /metrics, graceful drain on SIGTERM;
                               [--workers 16] [--backlog 64] [--rate 64]
                               [--burst 128] [--max-inflight 64];
                               adapter residency tiering:
                               [--adapter-budget-mb N] caps resident
                               adapter bytes (hot f32 + warm NF4), LRU
                               evicting to disk past it, and
                               [--cold-adapters N] registers N extra
                               on-disk tenants attached lazily on their
                               first request)
               [--module q] [--layer 0] [--d-model 128]
               [--base-frac 0.125] [--drift 0.05] [--iters 2]
               [--out results/serve_stats.json]
  toy          [--rank 4] [--steps 60] (Figure 2a)
  info         list artifacts and configs

ADAPTER SPECS (train/eval)
  --spec STR   declarative adapter config, e.g.
                 pissa:rank=8:niter=4:targets=q,v
                 qpissa:rank=4:iters=5 | lora:rank=4:alpha=8 | full-ft
  or the flag form: --strategy pissa --rank 4 [--iters 5] [--niter 4|exact]
                    [--window principal|medium|minor] [--targets q,v]
                    [--alpha 8]

Global: --artifacts DIR (default ./artifacts), --seed N",
        pissa::version()
    );
}

fn cmd_info(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&art_dir(args))?;
    println!("configs:");
    for (name, c) in &manifest.configs {
        println!(
            "  {name:10} {}  d={} L={} T={} B={} ranks={:?}",
            c.kind, c.d_model, c.n_layers, c.seq_len, c.batch, c.ranks
        );
    }
    println!("artifacts ({}):", manifest.artifacts.len());
    for (name, a) in &manifest.artifacts {
        println!("  {name:32} {:14} args={}", a.kind, a.args.len());
    }
    Ok(())
}

/// Save a base model to a checkpoint.
fn save_base(base: &pissa::model::BaseModel, path: &Path) -> Result<()> {
    let mut ckp = Checkpoint::new();
    for (k, t) in base.scaffold.iter().chain(base.linears.iter()) {
        ckp.put_tensor(k, t);
    }
    ckp.put_blob("config", base.config.as_bytes().to_vec());
    ckp.put_blob("encoder", vec![base.encoder as u8]);
    ckp.save(path)
}

/// Load a base model from a checkpoint.
fn load_base(path: &Path) -> Result<pissa::model::BaseModel> {
    let ckp = Checkpoint::load(path)?;
    let config = String::from_utf8(ckp.blobs["config"].clone())?;
    let encoder = ckp.blobs["encoder"][0] != 0;
    let mut scaffold = pissa::model::ParamStore::new();
    let mut linears = pissa::model::ParamStore::new();
    for k in ckp.mats.keys() {
        let t = ckp.get_tensor(k)?;
        if k.starts_with("base_") {
            linears.insert(k.clone(), t);
        } else {
            scaffold.insert(k.clone(), t);
        }
    }
    Ok(pissa::model::BaseModel { config, scaffold, linears, encoder })
}

fn get_or_make_base(
    args: &Args,
    rt: &Runtime,
    manifest: &Manifest,
    config: &str,
) -> Result<pissa::model::BaseModel> {
    if let Some(path) = args.get("base") {
        return load_base(Path::new(path));
    }
    // No checkpoint: quick pre-train so weights have a realistic spectrum.
    let steps = args.usize_or("pretrain-steps", 120)?;
    eprintln!("[pissa] no --base given; pre-training {config} for {steps} steps…");
    let (base, hist) =
        coordinator::pretrain(rt, manifest, config, steps, 2e-3, args.u64_or("seed", 42)?)?;
    eprintln!(
        "[pissa] pretrain loss {:.3} -> {:.3}",
        hist.first().map(|m| m.loss).unwrap_or(f32::NAN),
        hist.last().map(|m| m.loss).unwrap_or(f32::NAN)
    );
    Ok(base)
}

/// Build an `AdapterSpec` from `--spec STR`, or from the individual
/// `--strategy/--rank/--iters/--niter/--window/--targets/--alpha` flags.
fn spec_from(args: &Args) -> Result<AdapterSpec> {
    if let Some(s) = args.get("spec") {
        return AdapterSpec::parse(s);
    }
    let strategy = Strategy::parse(&args.str_or("strategy", "pissa"))?;
    let mut spec = AdapterSpec::new(strategy, args.usize_or("rank", 4)?);
    spec.iters = args.usize_or("iters", 5)?;
    if let Some(n) = args.get("niter") {
        spec.niter = match n {
            "exact" | "inf" => None,
            n => Some(n.parse().map_err(|_| anyhow::anyhow!("--niter: bad value '{n}'"))?),
        };
    }
    if let Some(w) = args.get("window") {
        spec.window = Window::parse(w)?;
    }
    if args.has("targets") {
        let mods = args.str_list_or("targets", &[]);
        let refs: Vec<&str> = mods.iter().map(|s| s.as_str()).collect();
        spec = spec.targets(&refs);
    }
    if let Some(a) = args.get("alpha") {
        spec.alpha = a.parse().map_err(|_| anyhow::anyhow!("--alpha: bad value '{a}'"))?;
    }
    spec.validate()?;
    Ok(spec)
}

fn run_config_from(args: &Args, config: &str) -> Result<RunConfig> {
    Ok(RunConfig {
        config: config.to_string(),
        spec: spec_from(args)?,
        steps: args.usize_or("steps", 100)?,
        peak_lr: args.f64_or("lr", 2e-3)?,
        corpus_size: args.usize_or("corpus", 1024)?,
        seed: args.u64_or("seed", 42)?,
        task: parse_task(&args.str_or("task", "math"))?,
    })
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let dir = art_dir(args);
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu(&dir)?;
    let config = args.str_or("config", "tiny");
    let steps = args.usize_or("steps", 200)?;
    let lr = args.f64_or("lr", 2e-3)?;
    let seed = args.u64_or("seed", 42)?;
    let (base, hist) = coordinator::pretrain(&rt, &manifest, &config, steps, lr, seed)?;
    println!(
        "pretrained {config}: loss {:.4} -> {:.4} over {steps} steps",
        hist.first().unwrap().loss,
        hist.last().unwrap().loss
    );
    let out = PathBuf::from(args.str_or("out", &format!("runs/base_{config}.ckpt")));
    save_base(&base, &out)?;
    println!("saved base model to {}", out.display());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let dir = art_dir(args);
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu(&dir)?;
    let config = args.str_or("config", "tiny");
    let run = run_config_from(args, &config)?;
    let base = get_or_make_base(args, &rt, &manifest, &config)?;
    let result = coordinator::finetune(&rt, &manifest, &base, &run)?;
    println!(
        "{}  params={}  loss {:.4} -> {:.4}  ({} steps, {:.2}s total, {:.1}% rust overhead)",
        run.spec,
        result.trainable_params,
        result.history.first().unwrap().loss,
        result.final_loss(8),
        run.steps,
        result.total_s,
        100.0 * result.overhead_s / result.total_s.max(1e-9),
    );
    if let Some(out) = args.get("out") {
        let mut ckp = Checkpoint::new();
        // v2 container: the spec rides along, so the checkpoint records
        // how the adapter was made.
        ckp.spec = Some(run.spec.clone());
        for (k, t) in result.final_state.trainable.iter().chain(result.final_state.frozen.iter()) {
            ckp.put_tensor(k, t);
        }
        let mut log = JsonlSink::create(&PathBuf::from(format!("{out}.jsonl")))?;
        for m in &result.history {
            log.write_step(m)?;
        }
        ckp.save(Path::new(&format!("{out}.ckpt")))?;
        println!("saved run to {out}.ckpt / {out}.jsonl");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dir = art_dir(args);
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu(&dir)?;
    let config = args.str_or("config", "tiny");
    let run = run_config_from(args, &config)?;
    // Deterministic retrain (tiny models train in seconds) then score.
    let base = get_or_make_base(args, &rt, &manifest, &config)?;
    let result = coordinator::finetune(&rt, &manifest, &base, &run)?;
    let n = args.usize_or("n", 48)?;
    let acc = coordinator::evaluate(
        &rt,
        &manifest,
        &run,
        &result.final_state,
        n,
        args.usize_or("max-new", 48)?,
    )?;
    println!(
        "{} {}: accuracy {acc:.2}% over {n} problems",
        run.spec,
        run.task.name()
    );
    Ok(())
}

fn cmd_quant_error(args: &Args) -> Result<()> {
    use pissa::adapter::init;
    use pissa::quant;
    let dir = art_dir(args);
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu(&dir)?;
    let config = args.str_or("config", "tiny");
    let ranks = args.usize_list_or("ranks", &[2, 4, 8])?;
    let iters = args.usize_or("iters", 5)?;
    let base = get_or_make_base(args, &rt, &manifest, &config)?;
    let mut rng = Rng::new(args.u64_or("seed", 7)?);

    println!("quantization-error reduction ratio (%) vs QLoRA  [config={config}, T={iters}]");
    println!("{:8} {:>6} {:>8} {:>8}", "layer", "rank", "loftq", "qpissa");
    for name in pissa::model::LINEARS {
        let w = base.linears[&format!("base_{name}")].layer(0);
        let baseline = quant::qlora_error(&w);
        for &r in &ranks {
            let lq = init::loftq(&w, r, iters, &mut rng);
            let e_lq =
                pissa::linalg::nuclear_norm(&w.sub(&lq.base.add(&matmul(&lq.a, &lq.b))));
            let qp = init::qpissa(&w, r, iters, &mut rng);
            let e_qp =
                pissa::linalg::nuclear_norm(&w.sub(&qp.base.add(&matmul(&qp.a, &qp.b))));
            println!(
                "{name:8} {r:>6} {:>8.1} {:>8.1}",
                (1.0 - e_lq / baseline) * 100.0,
                (1.0 - e_qp / baseline) * 100.0
            );
        }
    }
    Ok(())
}

fn cmd_convert(args: &Args) -> Result<()> {
    use pissa::adapter::convert::pissa_to_lora;
    let run = args.get("run").ok_or_else(|| anyhow::anyhow!("--run required"))?;
    let ckp = Checkpoint::load(Path::new(&format!("{run}.ckpt")))
        .or_else(|_| Checkpoint::load(Path::new(run)))?;
    match &ckp.spec {
        Some(spec) => println!("converting adapters in {run} (spec: {spec}) to LoRA ΔA/ΔB (Appendix C)…"),
        None => println!("converting adapters in {run} (v1 checkpoint, no spec) to LoRA ΔA/ΔB (Appendix C)…"),
    }
    let mut out = Checkpoint::new();
    out.spec = ckp.spec.clone();
    let mut n = 0;
    for key in ckp.mats.keys() {
        if let Some(name) = key.strip_prefix("a_") {
            let a_t = ckp.get_tensor(key)?;
            let b_t = ckp.get_tensor(&format!("b_{name}"))?;
            let l = a_t.shape[0];
            for li in 0..l {
                let a = a_t.layer(li);
                let b = b_t.layer(li);
                // ΔA/ΔB relative to the stored trained factors vs themselves
                // demonstrates the packing; the init-vs-trained protocol is
                // exercised end-to-end in examples/adapter_convert.rs.
                let delta = pissa_to_lora(&a, &b, &a, &b);
                out.put(&format!("dA_{name}.{li}"), delta.da);
                out.put(&format!("dB_{name}.{li}"), delta.db);
                n += 1;
            }
        }
    }
    let out_path = args.str_or("out", &format!("{run}_lora.ckpt"));
    out.save(Path::new(&out_path))?;
    println!("wrote {n} converted adapter pairs to {out_path}");
    Ok(())
}

/// Resolve the serving strategy from `--strategy` / `--quantized`.
/// `--quantized` pins a strategy that serves an NF4 base; an explicit
/// conflicting `--strategy` is a config error.
fn serve_strategy_from(args: &Args, quantized: bool) -> Result<pissa::serve::ServeStrategy> {
    use pissa::serve::ServeStrategy;
    if quantized {
        if let Some(s) = args.get("strategy") {
            let parsed = ServeStrategy::parse(s)?;
            anyhow::ensure!(
                parsed.quantized_base(),
                "--quantized serves an NF4 base; --strategy {s} is full-precision \
                 (drop it or pick fused-quant/dequant-dense)"
            );
            Ok(parsed)
        } else {
            Ok(ServeStrategy::FusedQuant)
        }
    } else {
        ServeStrategy::parse(&args.str_or("strategy", "fused"))
    }
}

/// Batched multi-adapter serving on a synthetic mixed-tenant workload:
/// one random base model, N adapters (drifted to simulate training), and
/// a request stream routed through the scheduler and the fused low-rank
/// server. `--quantized` switches to the QPiSSA deployment shape: QPiSSA
/// adapters over an NF4-resident shared base served via the fused-quant
/// dequant-GEMM path. `--full-model` promotes the workload from one
/// linear to the whole adapted forward pass (token-id requests through
/// embed → every layer's seven linears → head logits). No artifacts
/// needed.
fn cmd_serve(args: &Args) -> Result<()> {
    use pissa::serve::{drift_factors, Request, Scheduler, ServeConfig, Server};

    if args.has("http") {
        return cmd_serve_http(args);
    }
    if args.bool_or("decode", false) {
        return cmd_serve_decode(args);
    }
    if args.bool_or("full-model", false) {
        return cmd_serve_full_model(args);
    }

    let d_model = args.usize_or("d-model", 128)?;
    let module = args.str_or("module", "q");
    let layer = args.usize_or("layer", 0)?;
    let n_adapters = args.usize_or("adapters", 8)?;
    let rank = args.usize_or("rank", 8)?;
    let batch = args.usize_or("batch", 32)?;
    let batches = args.usize_or("batches", 40)?;
    let base_frac = args.f64_or("base-frac", 0.125)?;
    let drift = args.f64_or("drift", 0.05)? as f32;
    let quantized = args.bool_or("quantized", false);
    let strategy = serve_strategy_from(args, quantized)?;
    let mut rng = Rng::new(args.u64_or("seed", 42)?);

    let cfg = pissa::runtime::ConfigInfo {
        name: "serve-synth".into(),
        kind: "decoder".into(),
        vocab: 64,
        d_model,
        n_layers: layer + 1,
        n_heads: 2,
        d_ff: d_model,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![rank],
    };
    // Under --quantized every tenant is a QPiSSA adapter (frozen NF4
    // residual, Algorithm-1 alternations) — the configuration the paper
    // says is cheapest to deploy.
    let spec = if quantized {
        AdapterSpec::qpissa(rank).iters(args.usize_or("iters", 2)?)
    } else {
        AdapterSpec::pissa(rank)
    };
    eprintln!(
        "[serve] building base ({d_model}x{d_model} {module}) + {n_adapters} \
         {spec} adapters…",
        spec = spec.clone().targets(&[module.as_str()])
    );
    let base = pissa::model::BaseModel::random(&cfg, &mut rng);
    let mut engine = pissa::adapter::AdapterEngine::new(base);
    let names: Vec<String> = (0..n_adapters).map(|i| format!("tenant{i:02}")).collect();
    for name in &names {
        engine.attach(name, spec.clone().targets(&[module.as_str()]), &mut rng)?;
        drift_factors(&mut engine, name, &module, drift, &mut rng)?;
    }

    let serve_cfg = ServeConfig::new(&module).layer(layer).strategy(strategy).max_batch(batch);
    let mut server = Server::new(&engine, serve_cfg)?;
    let n_in = server.n_in();

    let mut scheduler = Scheduler::new(batch);
    let total = batches * batch;
    for _ in 0..total {
        let mut x = vec![0.0f32; n_in];
        rng.fill_normal(&mut x, 0.0, 1.0);
        // --adapters 0 degenerates to a pure base-weight workload.
        let req = if names.is_empty() || rng.uniform() < base_frac {
            Request::base(x)
        } else {
            Request::new(rng.choice(&names), x)
        };
        scheduler.submit(req);
    }
    while let Some(b) = scheduler.take_batch() {
        server.forward(&b)?;
    }

    let s = server.stats().summary();
    println!(
        "served {} requests in {} batches [{}]  ({:.0} req/s)",
        s.requests,
        s.batches,
        server.cfg(),
        s.req_per_s
    );
    let dense_bytes = server.n_in() * server.n_out() * 4;
    println!(
        "resident base: {} bytes ({:.2}x of dense fp32 {})",
        server.base_resident_bytes(),
        server.base_resident_bytes() as f64 / dense_bytes as f64,
        dense_bytes
    );
    println!(
        "latency p50 {:.3} ms  p95 {:.3} ms  |  occupancy {:.0}%  |  {:.1} adapter \
         groups/batch",
        s.p50_s * 1e3,
        s.p95_s * 1e3,
        s.mean_occupancy * 100.0,
        s.mean_groups
    );
    println!("per-adapter hits:");
    for (name, hits) in &server.stats().hits {
        println!("  {name:12} {hits}");
    }
    if let Some(out) = args.get("out") {
        let path = PathBuf::from(out);
        pissa::metrics::write_json(&path, &server.stats().to_json())?;
        println!("wrote stats json to {}", path.display());
    }
    Ok(())
}

/// `pissa serve --decode`: autoregressive decode serving on a synthetic
/// mixed-tenant workload. Sequence requests (random prompts + generation
/// budgets under random adapters) stream through the continuous-batching
/// `DecodeScheduler`: per-step admission into KV-cache slots, one decoded
/// token per running sequence per step, retirement on stop — the serving
/// shape the paper's GSM8K/HumanEval generation implies.
fn cmd_serve_decode(args: &Args) -> Result<()> {
    use pissa::serve::{
        drift_factors, DecodeScheduler, ModelServer, SeqRequest, ServeConfig,
    };

    let d_model = args.usize_or("d-model", 64)?;
    let d_ff = args.usize_or("d-ff", 2 * d_model)?;
    let n_layers = args.usize_or("layers", 2)?;
    let vocab = args.usize_or("vocab", 64)?;
    anyhow::ensure!(vocab >= 2, "--vocab must be >= 2 (need a stop token + content)");
    let n_adapters = args.usize_or("adapters", 4)?;
    let rank = args.usize_or("rank", 4)?;
    let requests = args.usize_or("requests", 32)?;
    let prompt_len = args.usize_or("prompt-len", 12)?;
    let max_new = args.usize_or("max-new", 24)?;
    let slots = args.usize_or("slots", 8)?;
    let max_seq = args.usize_or("max-seq", (prompt_len + max_new).max(32))?;
    anyhow::ensure!(
        max_seq > prompt_len,
        "--max-seq {max_seq} must exceed --prompt-len {prompt_len} (no room to generate)"
    );
    let kv_budget = args.usize_or("kv-budget-mb", 64)? << 20;
    // Attention geometry: legacy single-head unless asked otherwise;
    // RoPE defaults ON for multi-head layouts (0 disables it).
    let n_heads = args.usize_or("heads", 1)?;
    let n_kv_heads = args.usize_or("kv-heads", n_heads)?;
    let rope_theta = args.f64_or("rope-theta", if n_heads > 1 { 10000.0 } else { 0.0 })?;
    let prefill_chunk = args.usize_or("prefill-chunk", 0)?;
    let base_frac = args.f64_or("base-frac", 0.125)?;
    let drift = args.f64_or("drift", 0.05)? as f32;
    let quantized = args.bool_or("quantized", false);
    let strategy = serve_strategy_from(args, quantized)?;
    let mut rng = Rng::new(args.u64_or("seed", 42)?);

    let cfg = pissa::runtime::ConfigInfo {
        name: "serve-decode-synth".into(),
        kind: "decoder".into(),
        vocab,
        d_model,
        n_layers,
        n_heads: 2,
        d_ff,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![rank],
    };
    let spec = if quantized {
        AdapterSpec::qpissa(rank).iters(args.usize_or("iters", 2)?)
    } else {
        AdapterSpec::pissa(rank)
    };
    eprintln!(
        "[serve] building {n_layers}-layer base (d={d_model}, f={d_ff}) + {n_adapters} \
         {spec} adapters for decode serving ({slots} slots, max_seq {max_seq})…"
    );
    let base = pissa::model::BaseModel::random(&cfg, &mut rng);
    let mut engine = pissa::adapter::AdapterEngine::new(base);
    let names: Vec<String> = (0..n_adapters).map(|i| format!("tenant{i:02}")).collect();
    for name in &names {
        engine.attach(name, spec.clone(), &mut rng)?;
        for module in pissa::model::LINEARS {
            drift_factors(&mut engine, name, module, drift, &mut rng)?;
        }
    }

    let serve_cfg = ServeConfig::full_model()
        .strategy(strategy)
        .max_seq(max_seq)
        .slots(slots)
        .kv_budget_bytes(kv_budget)
        .heads(n_heads, n_kv_heads)
        .rope_theta(rope_theta)
        .prefill_chunk(prefill_chunk);
    let mut server = ModelServer::new(&engine, serve_cfg)?;
    let mut cache = server.new_cache()?;

    let mut sched = DecodeScheduler::new();
    for _ in 0..requests {
        let plen = 1 + (rng.uniform() * prompt_len as f64) as usize % prompt_len.max(1);
        let prompt: Vec<usize> =
            (0..plen).map(|_| (rng.uniform() * vocab as f64) as usize % vocab).collect();
        let new = (1 + (rng.uniform() * max_new as f64) as usize % max_new.max(1))
            .min(max_seq - plen);
        let req = if names.is_empty() || rng.uniform() < base_frac {
            SeqRequest::base(prompt, new)
        } else {
            SeqRequest::new(rng.choice(&names), prompt, new)
        };
        sched.submit(req.stop_at(0)); // token 0 doubles as a stop condition
    }
    let timer = pissa::util::timer::Timer::start();
    let finished = sched.run(&mut server, &mut cache)?;
    let wall = timer.secs();

    let s = server.stats().summary();
    let generated: usize = finished.iter().map(|f| f.generated().len()).sum();
    println!(
        "decoded {} sequences ({} prompt tokens prefilled, {generated} tokens generated) \
         in {wall:.3}s [{}]",
        finished.len(),
        s.prefill_tokens,
        server.cfg()
    );
    println!(
        "TTFT p50 {:.3} ms  p95 {:.3} ms  |  decode {:.0} tok/s (steady-state), \
         {:.0} tok/s end-to-end  |  step occupancy {:.0}%  |  {:.1} adapter groups/step",
        s.ttft_p50_s * 1e3,
        s.ttft_p95_s * 1e3,
        s.decode_tok_per_s,
        s.seq_tok_per_s,
        s.mean_occupancy * 100.0,
        s.mean_groups
    );
    let bd = server.resident_breakdown_with_cache(&cache);
    println!(
        "resident: base {} bytes ({:.2}x dense fp32 {}) + KV cache {} bytes = {}",
        bd.total(),
        bd.ratio(),
        bd.dense_bytes,
        bd.kv_bytes,
        bd.total_with_kv()
    );
    println!("per-adapter hits:");
    for (name, hits) in &server.stats().hits {
        println!("  {name:12} {hits}");
    }
    if let Some(out) = args.get("out") {
        let path = PathBuf::from(out);
        let mut j = server.stats().to_json();
        j.set("resident", bd.to_json());
        pissa::metrics::write_json(&path, &j)?;
        println!("wrote stats json to {}", path.display());
    }
    Ok(())
}

/// `pissa serve --http [addr]`: put the decode path on the wire. Builds
/// the same synthetic multi-tenant engine as `--decode`, then serves it
/// over the dependency-free HTTP/1.1 front-end: `POST /v1/generate` with
/// chunked NDJSON token streaming, per-tenant token-bucket admission
/// control, `GET /healthz` + `GET /metrics`, and graceful drain on
/// SIGTERM/SIGINT (stop admitting, finish running sequences, flush
/// streams, exit).
fn cmd_serve_http(args: &Args) -> Result<()> {
    use pissa::net::{NetConfig, NetServer, TenantPolicy};
    use pissa::serve::{drift_factors, ServeConfig};

    let addr = match args.str_or("http", "127.0.0.1:8080").as_str() {
        // Bare `--http` parses as a boolean flag; fall back to the default.
        "true" => "127.0.0.1:8080".to_string(),
        a => a.to_string(),
    };
    let d_model = args.usize_or("d-model", 64)?;
    let d_ff = args.usize_or("d-ff", 2 * d_model)?;
    let n_layers = args.usize_or("layers", 2)?;
    let vocab = args.usize_or("vocab", 64)?;
    anyhow::ensure!(vocab >= 2, "--vocab must be >= 2 (need a stop token + content)");
    let n_adapters = args.usize_or("adapters", 4)?;
    let rank = args.usize_or("rank", 4)?;
    let slots = args.usize_or("slots", 8)?;
    let max_seq = args.usize_or("max-seq", 64)?;
    let kv_budget = args.usize_or("kv-budget-mb", 64)? << 20;
    let n_heads = args.usize_or("heads", 1)?;
    let n_kv_heads = args.usize_or("kv-heads", n_heads)?;
    let rope_theta = args.f64_or("rope-theta", if n_heads > 1 { 10000.0 } else { 0.0 })?;
    let prefill_chunk = args.usize_or("prefill-chunk", 0)?;
    let drift = args.f64_or("drift", 0.05)? as f32;
    let quantized = args.bool_or("quantized", false);
    let strategy = serve_strategy_from(args, quantized)?;
    let mut rng = Rng::new(args.u64_or("seed", 42)?);

    let cfg = pissa::runtime::ConfigInfo {
        name: "serve-http-synth".into(),
        kind: "decoder".into(),
        vocab,
        d_model,
        n_layers,
        n_heads: 2,
        d_ff,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![rank],
    };
    let spec = if quantized {
        AdapterSpec::qpissa(rank).iters(args.usize_or("iters", 2)?)
    } else {
        AdapterSpec::pissa(rank)
    };
    eprintln!(
        "[serve] building {n_layers}-layer base (d={d_model}, f={d_ff}) + {n_adapters} \
         {spec} adapters for HTTP serving ({slots} slots, max_seq {max_seq})…"
    );
    let base = pissa::model::BaseModel::random(&cfg, &mut rng);
    let mut engine = pissa::adapter::AdapterEngine::new(base);
    let names: Vec<String> = (0..n_adapters).map(|i| format!("tenant{i:02}")).collect();
    for name in &names {
        engine.attach(name, spec.clone(), &mut rng)?;
        for module in pissa::model::LINEARS {
            drift_factors(&mut engine, name, module, drift, &mut rng)?;
        }
    }

    let serve_cfg = ServeConfig::full_model()
        .strategy(strategy)
        .max_seq(max_seq)
        .slots(slots)
        .kv_budget_bytes(kv_budget)
        .heads(n_heads, n_kv_heads)
        .rope_theta(rope_theta)
        .prefill_chunk(prefill_chunk);
    let net_cfg = NetConfig {
        addr,
        workers: args.usize_or("workers", 16)?,
        accept_backlog: args.usize_or("backlog", 64)?,
        default_policy: TenantPolicy {
            rate_per_s: args.f64_or("rate", 64.0)?,
            burst: args.f64_or("burst", 128.0)?,
            max_inflight: args.usize_or("max-inflight", 64)?,
        },
        handle_signals: true,
        ..NetConfig::default()
    };

    // Residency tiering: a resident-byte budget and/or lazily-attached
    // cold tenants put the front-end behind a TierManager.
    let budget_mb = args.usize_or("adapter-budget-mb", 0)?;
    let n_cold = args.usize_or("cold-adapters", 0)?;
    let server = if budget_mb > 0 || n_cold > 0 {
        use pissa::adapter::TierManager;
        let budget = if budget_mb > 0 {
            budget_mb << 20
        } else {
            pissa::serve::DEFAULT_ADAPTER_BUDGET_BYTES
        };
        let spill_dir =
            std::env::temp_dir().join(format!("pissa_http_tiers_{}", std::process::id()));
        let mut tiers = TierManager::new(budget, &spill_dir);
        if n_cold > 0 {
            // A few saved templates shared by all cold tenant names:
            // registration costs one map entry, the checkpoint loads on
            // the tenant's first request.
            let n_tmpl = n_cold.min(4);
            let mut paths = Vec::with_capacity(n_tmpl);
            for t in 0..n_tmpl {
                let tmpl = format!("cold-template{t}");
                engine.attach(&tmpl, spec.clone(), &mut rng)?;
                for module in pissa::model::LINEARS {
                    drift_factors(&mut engine, &tmpl, module, drift, &mut rng)?;
                }
                let path = spill_dir.join("templates").join(format!("{tmpl}.ckpt"));
                engine.save(&tmpl, &path)?;
                engine.detach(&tmpl)?;
                paths.push(path);
            }
            for i in 0..n_cold {
                tiers.register_cold(&format!("cold{i:04}"), &paths[i % n_tmpl])?;
            }
            eprintln!("[serve] registered {n_cold} cold tenants over {n_tmpl} saved templates");
        }
        eprintln!(
            "[serve] adapter residency budget {} bytes, spills under {}",
            budget,
            spill_dir.display()
        );
        NetServer::start_tiered(engine, tiers, serve_cfg, net_cfg)?
    } else {
        NetServer::start(&engine, serve_cfg, net_cfg)?
    };
    let bound = server.addr();
    println!("listening on http://{bound} ({n_adapters} tenants: {:?})", names);
    println!("  curl -s http://{bound}/healthz");
    println!(
        "  curl -sN http://{bound}/v1/generate \\\n       \
         -d '{{\"adapter\":\"tenant00\",\"prompt\":[1,2,3],\"max_new\":8}}'"
    );
    println!("  curl -s http://{bound}/metrics");
    println!("SIGTERM/SIGINT drains gracefully: running sequences finish, streams flush.");
    server.wait_engine_stopped();
    eprintln!("[serve] drain complete; shutting down");
    server.shutdown()
}

/// `pissa serve --full-model`: the whole-model pipeline on a synthetic
/// mixed-tenant workload. Every tenant adapts ALL seven linears of every
/// layer (the paper's fine-tuning shape); token-id requests stream
/// through the scheduler into `ModelServer::forward`, which routes each
/// batch through the `layers × 7` adapted linears in one call.
fn cmd_serve_full_model(args: &Args) -> Result<()> {
    use pissa::serve::{drift_factors, ModelRequest, ModelServer, Scheduler, ServeConfig};

    let d_model = args.usize_or("d-model", 64)?;
    let d_ff = args.usize_or("d-ff", 2 * d_model)?;
    let n_layers = args.usize_or("layers", 2)?;
    let vocab = args.usize_or("vocab", 64)?;
    anyhow::ensure!(vocab >= 1, "--vocab must be >= 1 (token ids index the embedding table)");
    let n_adapters = args.usize_or("adapters", 4)?;
    let rank = args.usize_or("rank", 4)?;
    let batch = args.usize_or("batch", 32)?;
    let batches = args.usize_or("batches", 20)?;
    let base_frac = args.f64_or("base-frac", 0.125)?;
    let drift = args.f64_or("drift", 0.05)? as f32;
    let quantized = args.bool_or("quantized", false);
    let strategy = serve_strategy_from(args, quantized)?;
    let mut rng = Rng::new(args.u64_or("seed", 42)?);

    let cfg = pissa::runtime::ConfigInfo {
        name: "serve-full-synth".into(),
        kind: "decoder".into(),
        vocab,
        d_model,
        n_layers,
        n_heads: 2,
        d_ff,
        seq_len: 8,
        batch: 8,
        eval_batch: 4,
        n_classes: 0,
        ranks: vec![rank],
    };
    let spec = if quantized {
        AdapterSpec::qpissa(rank).iters(args.usize_or("iters", 2)?)
    } else {
        AdapterSpec::pissa(rank)
    };
    eprintln!(
        "[serve] building {n_layers}-layer base (d={d_model}, f={d_ff}) + {n_adapters} \
         {spec} adapters on all seven linears…"
    );
    let base = pissa::model::BaseModel::random(&cfg, &mut rng);
    let mut engine = pissa::adapter::AdapterEngine::new(base);
    let names: Vec<String> = (0..n_adapters).map(|i| format!("tenant{i:02}")).collect();
    for name in &names {
        engine.attach(name, spec.clone(), &mut rng)?;
        for module in pissa::model::LINEARS {
            drift_factors(&mut engine, name, module, drift, &mut rng)?;
        }
    }

    let serve_cfg = ServeConfig::full_model().strategy(strategy).max_batch(batch);
    let mut server = ModelServer::new(&engine, serve_cfg)?;

    let mut scheduler: Scheduler<ModelRequest> = Scheduler::new(batch);
    for _ in 0..batches * batch {
        let token = (rng.uniform() * vocab as f64) as usize % vocab;
        let req = if names.is_empty() || rng.uniform() < base_frac {
            ModelRequest::base(token)
        } else {
            ModelRequest::new(rng.choice(&names), token)
        };
        scheduler.submit(req);
    }
    while let Some(b) = scheduler.take_batch() {
        server.forward(&b)?;
    }

    let s = server.stats().summary();
    println!(
        "served {} requests in {} batches [{}] through {}x{} adapted linears  ({:.0} req/s)",
        s.requests,
        s.batches,
        server.cfg(),
        server.n_layers(),
        pissa::model::LINEARS.len(),
        s.req_per_s
    );
    let bd = server.resident_breakdown();
    println!(
        "resident base: {} bytes across all linears ({:.2}x of dense fp32 {})",
        bd.total(),
        bd.ratio(),
        bd.dense_bytes
    );
    println!("per-module resident bytes (summed over {} layers):", server.n_layers());
    for (module, bytes) in &bd.per_module {
        println!("  {module:6} {bytes}");
    }
    println!(
        "latency p50 {:.3} ms  p95 {:.3} ms  |  occupancy {:.0}%  |  {:.1} adapter \
         groups/batch",
        s.p50_s * 1e3,
        s.p95_s * 1e3,
        s.mean_occupancy * 100.0,
        s.mean_groups
    );
    println!("per-adapter hits:");
    for (name, hits) in &server.stats().hits {
        println!("  {name:12} {hits}");
    }
    if let Some(out) = args.get("out") {
        let path = PathBuf::from(out);
        let mut j = server.stats().to_json();
        j.set("resident", bd.to_json());
        pissa::metrics::write_json(&path, &j)?;
        println!("wrote stats json to {}", path.display());
    }
    Ok(())
}

fn cmd_toy(args: &Args) -> Result<()> {
    let rank = args.usize_or("rank", 4)?;
    let steps = args.usize_or("steps", 60)?;
    let seed = args.u64_or("seed", 7)?;
    let (lora_l, pissa_l, full_l) =
        pissa::coordinator::toy::fig2a_protocol(32, rank, 100, steps, 0.5, seed);
    println!("Figure 2a analog — fine-tune loss on even digits (rank {rank})");
    println!("{:>6} {:>10} {:>10} {:>10}", "step", "lora", "pissa", "full-ft");
    for i in (0..steps).step_by((steps / 12).max(1)) {
        println!("{:>6} {:>10.4} {:>10.4} {:>10.4}", i + 1, lora_l[i], pissa_l[i], full_l[i]);
    }
    println!(
        "final: lora {:.4}  pissa {:.4}  full {:.4}  (pissa beats lora: {})",
        lora_l[steps - 1],
        pissa_l[steps - 1],
        full_l[steps - 1],
        pissa_l[steps - 1] < lora_l[steps - 1]
    );
    Ok(())
}

fn parse_task(s: &str) -> Result<TaskFamily> {
    Ok(match s {
        "math" => TaskFamily::Math,
        "code" => TaskFamily::Code,
        "chat" => TaskFamily::Chat,
        other => anyhow::bail!("unknown task '{other}'"),
    })
}
