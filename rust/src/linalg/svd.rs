//! Exact economy SVD via one-sided Jacobi rotations.
//!
//! This is the reference decomposition behind PiSSA init (Eq. 2–4 of the
//! paper), quantization-error nuclear norms, and the singular-spectrum
//! figures. One-sided Jacobi orthogonalizes the columns of A by plane
//! rotations; at convergence the column norms are the singular values,
//! the normalized columns are U, and the accumulated rotations are V.
//! It is O(n²·m) per sweep but extremely accurate (f64 accumulation),
//! which is what we want for an oracle; the *fast* path is `rsvd.rs`.

use super::mat::Mat;

/// Result of an economy SVD: `a = u * diag(s) * vt`,
/// u: m×k, s: k (descending), vt: k×n, with k = min(m, n).
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f32>,
    pub vt: Mat,
}

impl Svd {
    /// Reconstruct `u · diag(s) · vt`.
    pub fn reconstruct(&self) -> Mat {
        let mut us = self.u.clone();
        us.scale_cols(&self.s);
        super::gemm::matmul(&us, &self.vt)
    }

    /// Reconstruct using only singular triplets in [lo, hi).
    pub fn reconstruct_range(&self, lo: usize, hi: usize) -> Mat {
        let mut us = self.u.cols_range(lo, hi);
        us.scale_cols(&self.s[lo..hi]);
        super::gemm::matmul(&us, &self.vt.rows_range(lo, hi))
    }

    /// Nuclear norm = Σ σᵢ.
    pub fn nuclear(&self) -> f64 {
        self.s.iter().map(|&x| x as f64).sum()
    }
}

/// Economy SVD of an arbitrary matrix. Handles m < n by transposing.
pub fn svd(a: &Mat) -> Svd {
    if a.rows >= a.cols {
        svd_tall(a)
    } else {
        let t = svd_tall(&a.t());
        Svd { u: t.vt.t(), s: t.s, vt: t.u.t() }
    }
}

/// One-sided Jacobi on a tall (m ≥ n) matrix, f64 workspace.
fn svd_tall(a: &Mat) -> Svd {
    let (m, n) = (a.rows, a.cols);
    // Column-major f64 workspace: cols[j] is column j of the working matrix.
    let mut cols: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| a[(i, j)] as f64).collect())
        .collect();
    // V accumulated as column-major too.
    let mut v: Vec<Vec<f64>> = (0..n)
        .map(|j| {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            e
        })
        .collect();

    let fro2: f64 = cols.iter().flat_map(|c| c.iter()).map(|x| x * x).sum();
    let tol = 1e-14 * fro2.max(f64::MIN_POSITIVE);
    let max_sweeps = 60;

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // 2x2 Gram entries.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                {
                    let (cp, cq) = (&cols[p], &cols[q]);
                    for i in 0..m {
                        app += cp[i] * cp[i];
                        aqq += cq[i] * cq[i];
                        apq += cp[i] * cq[i];
                    }
                }
                off += apq * apq;
                if apq * apq <= tol * app * aqq {
                    continue;
                }
                // Jacobi rotation that zeroes the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    1.0 / (tau - (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // Rotate columns p and q of the working matrix and of V.
                let (lo, hi) = if p < q { (p, q) } else { (q, p) };
                let (head, tail) = cols.split_at_mut(hi);
                let (cp, cq) = (&mut head[lo], &mut tail[0]);
                for i in 0..m {
                    let (x, y) = (cp[i], cq[i]);
                    cp[i] = c * x - s * y;
                    cq[i] = s * x + c * y;
                }
                let (headv, tailv) = v.split_at_mut(hi);
                let (vp, vq) = (&mut headv[lo], &mut tailv[0]);
                for i in 0..n {
                    let (x, y) = (vp[i], vq[i]);
                    vp[i] = c * x - s * y;
                    vq[i] = s * x + c * y;
                }
            }
        }
        if off <= tol {
            break;
        }
    }

    // Singular values = column norms; sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = cols.iter().map(|c| c.iter().map(|x| x * x).sum::<f64>().sqrt()).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut u = Mat::zeros(m, n);
    let mut vt = Mat::zeros(n, n);
    let mut s = vec![0.0f32; n];
    for (k, &j) in order.iter().enumerate() {
        let nj = norms[j];
        s[k] = nj as f32;
        if nj > 0.0 {
            for i in 0..m {
                u[(i, k)] = (cols[j][i] / nj) as f32;
            }
        } else {
            // Null direction: leave a zero column (callers only use the
            // leading rank anyway).
            u[(k.min(m - 1), k)] = 0.0;
        }
        for i in 0..n {
            vt[(k, i)] = v[j][i] as f32;
        }
    }
    Svd { u, s, vt }
}

/// Truncated reconstruction helpers used by adapter init:
/// principal part `U[:, :r] S[:r] Vt[:r, :]` and residual `U[:, r:] …`.
pub fn split_at_rank(dec: &Svd, r: usize) -> (Mat, Mat) {
    let k = dec.s.len();
    let r = r.min(k);
    (dec.reconstruct_range(0, r), dec.reconstruct_range(r, k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_tn};
    use crate::util::rng::Rng;

    fn check_svd(a: &Mat, tol: f64) {
        let d = svd(a);
        let k = a.rows.min(a.cols);
        assert_eq!(d.s.len(), k);
        // descending
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6, "not descending: {:?}", &d.s);
        }
        // reconstruction
        let err = d.reconstruct().sub(a).fro() / a.fro().max(1e-30);
        assert!(err < tol, "reconstruction err={err}");
        // orthonormal U, V — only over the numerically nonzero singular
        // directions (null-space columns of U are not defined).
        let rank = d.s.iter().take_while(|&&s| s > 1e-5 * d.s[0].max(1e-30)).count();
        let ur = d.u.cols_range(0, rank);
        let vr = d.vt.rows_range(0, rank);
        let utu = matmul_tn(&ur, &ur).sub(&Mat::eye(rank)).fro();
        let vvt = matmul(&vr, &vr.t()).sub(&Mat::eye(rank)).fro();
        assert!(utu < 1e-4, "UᵀU err={utu}");
        assert!(vvt < 1e-4, "VVᵀ err={vvt}");
    }

    #[test]
    fn svd_square_and_rect() {
        let mut rng = Rng::new(20);
        for &(m, n) in &[(8, 8), (24, 10), (10, 24), (40, 40), (64, 17)] {
            let a = Mat::randn(m, n, 0.0, 1.0, &mut rng);
            check_svd(&a, 1e-5);
        }
    }

    #[test]
    fn svd_known_diagonal() {
        let mut a = Mat::zeros(4, 3);
        a[(0, 0)] = 5.0;
        a[(1, 1)] = 3.0;
        a[(2, 2)] = 1.0;
        let d = svd(&a);
        assert!((d.s[0] - 5.0).abs() < 1e-5);
        assert!((d.s[1] - 3.0).abs() < 1e-5);
        assert!((d.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn svd_low_rank() {
        // Rank-2 matrix: trailing singular values ~0.
        let mut rng = Rng::new(21);
        let u = Mat::randn(20, 2, 0.0, 1.0, &mut rng);
        let v = Mat::randn(2, 15, 0.0, 1.0, &mut rng);
        let a = matmul(&u, &v);
        let d = svd(&a);
        assert!(d.s[2] < 1e-4 * d.s[0], "σ₂={} σ₀={}", d.s[2], d.s[0]);
        check_svd(&a, 1e-4);
    }

    #[test]
    fn split_at_rank_sums_to_whole() {
        let mut rng = Rng::new(22);
        let a = Mat::randn(30, 20, 0.0, 1.0, &mut rng);
        let d = svd(&a);
        let (pri, res) = split_at_rank(&d, 5);
        let err = pri.add(&res).sub(&a).fro() / a.fro();
        assert!(err < 1e-5, "split err={err}");
    }

    #[test]
    fn nuclear_norm_diag() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 2.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 0.5;
        assert!((svd(&a).nuclear() - 3.5).abs() < 1e-5);
    }

    #[test]
    fn zero_matrix() {
        let a = Mat::zeros(5, 4);
        let d = svd(&a);
        assert!(d.s.iter().all(|&x| x == 0.0));
        assert!(d.u.data.iter().all(|x| x.is_finite()));
    }
}
