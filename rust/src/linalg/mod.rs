//! Dense linear-algebra substrate built from scratch: the matrix type,
//! blocked multithreaded GEMM, Householder QR, exact one-sided Jacobi SVD,
//! and Halko randomized ("fast") SVD — everything PiSSA initialization and
//! the quantization-error analysis need, with no external BLAS/LAPACK.

pub mod gemm;
pub mod mat;
pub mod norms;
pub mod qr;
pub mod rsvd;
pub mod svd;

pub use gemm::{
    dequant_matmul, dequant_matmul_into, dequant_matmul_panel, dequant_vecmat_into, matmul,
    matmul_acc, matmul_into, matmul_nt, matmul_tn, matvec, vecmat, vecmat_into,
};
pub use mat::Mat;
pub use norms::{nuclear_norm, singular_values};
pub use rsvd::rsvd;
pub use svd::{split_at_rank, svd, Svd};
