//! Dense row-major f32 matrix type — the foundation every substrate
//! (SVD, NF4 quantization, adapter init, the toy MLP, evaluation) builds on.

use crate::util::rng::Rng;
use std::fmt;

/// Row-major dense matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from an existing buffer (must be rows*cols long).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    /// Identity.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// i.i.d. N(mean, std) entries.
    pub fn randn(rows: usize, cols: usize, mean: f32, std: f32, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, mean, std);
        m
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column copy (rows are contiguous; columns are strided).
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.data[i * self.cols + j]).collect()
    }

    /// Transposed copy.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Select column range [lo, hi) as a new matrix.
    pub fn cols_range(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.cols);
        let mut out = Mat::zeros(self.rows, hi - lo);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[lo..hi]);
        }
        out
    }

    /// Select row range [lo, hi) as a new matrix.
    pub fn rows_range(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat::from_vec(hi - lo, self.cols, self.data[lo * self.cols..hi * self.cols].to_vec())
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Max |x|.
    pub fn absmax(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    /// Elementwise in-place ops.
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }
    pub fn sub_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }
    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// `self - other` as a new matrix.
    pub fn sub(&self, other: &Mat) -> Mat {
        let mut out = self.clone();
        out.sub_assign(other);
        out
    }

    /// `self + other` as a new matrix.
    pub fn add(&self, other: &Mat) -> Mat {
        let mut out = self.clone();
        out.add_assign(other);
        out
    }

    /// Scale each column j by s[j] (i.e. `self * diag(s)`).
    pub fn scale_cols(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.cols);
        for i in 0..self.rows {
            let r = self.row_mut(i);
            for (x, &f) in r.iter_mut().zip(s) {
                *x *= f;
            }
        }
    }

    /// Scale each row i by s[i] (i.e. `diag(s) * self`).
    pub fn scale_rows(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.rows);
        for i in 0..self.rows {
            let f = s[i];
            for x in self.row_mut(i) {
                *x *= f;
            }
        }
    }

    /// Mean and (population) std of all entries.
    pub fn mean_std(&self) -> (f64, f64) {
        let n = self.data.len() as f64;
        let mean = self.data.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = self.data.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        (mean, var.sqrt())
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(6) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:+.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_index() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 10 + j) as f32);
        assert_eq!(m[(2, 3)], 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0, 22.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(37, 53, 0.0, 1.0, &mut rng);
        assert_eq!(m.t().t(), m);
        let t = m.t();
        assert_eq!(t[(5, 7)], m[(7, 5)]);
    }

    #[test]
    fn ranges() {
        let m = Mat::from_fn(4, 6, |i, j| (i * 6 + j) as f32);
        let c = m.cols_range(2, 5);
        assert_eq!((c.rows, c.cols), (4, 3));
        assert_eq!(c[(1, 0)], m[(1, 2)]);
        let r = m.rows_range(1, 3);
        assert_eq!((r.rows, r.cols), (2, 6));
        assert_eq!(r[(0, 4)], m[(1, 4)]);
    }

    #[test]
    fn norms_and_scale() {
        let mut m = Mat::from_vec(1, 3, vec![3.0, 4.0, 0.0]);
        assert!((m.fro() - 5.0).abs() < 1e-9);
        assert_eq!(m.absmax(), 4.0);
        m.scale(2.0);
        assert_eq!(m.data, vec![6.0, 8.0, 0.0]);
    }

    #[test]
    fn scale_rows_cols() {
        let mut m = Mat::from_fn(2, 3, |_, _| 1.0);
        m.scale_cols(&[1.0, 2.0, 3.0]);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        m.scale_rows(&[10.0, 100.0]);
        assert_eq!(m.row(1), &[100.0, 200.0, 300.0]);
    }

    #[test]
    fn mean_std() {
        let m = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let (mean, std) = m.mean_std();
        assert!((mean - 2.5).abs() < 1e-12);
        assert!((std - (1.25f64).sqrt()).abs() < 1e-9);
    }
}
