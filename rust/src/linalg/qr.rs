//! Thin Householder QR — used by the randomized-SVD range finder to
//! re-orthonormalize the sketch between power iterations, and as a
//! building block for orthonormal test matrices.

use super::mat::Mat;

/// Thin QR of an m×n matrix with m ≥ n: returns Q (m×n, orthonormal
/// columns) and R (n×n upper triangular) with A = Q·R.
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "qr_thin requires rows >= cols (got {m}x{n})");
    // Work in f64 internally for stability on ill-conditioned sketches.
    let mut r: Vec<f64> = a.data.iter().map(|&x| x as f64).collect(); // m×n, will become R in top block
    let mut vs: Vec<(usize, Vec<f64>)> = Vec::with_capacity(n); // Householder vectors

    for k in 0..n {
        // Householder vector for column k below the diagonal.
        let mut norm2 = 0.0f64;
        for i in k..m {
            let x = r[i * n + k];
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            vs.push((k, vec![0.0; m - k]));
            continue;
        }
        let x0 = r[k * n + k];
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        let mut v: Vec<f64> = (k..m).map(|i| r[i * n + k]).collect();
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 > 0.0 {
            // Apply H = I - 2 v vᵀ / (vᵀv) to R[k.., k..]
            for j in k..n {
                let mut dot = 0.0f64;
                for i in k..m {
                    dot += v[i - k] * r[i * n + j];
                }
                let f = 2.0 * dot / vnorm2;
                for i in k..m {
                    r[i * n + j] -= f * v[i - k];
                }
            }
        }
        vs.push((k, v));
    }

    // Extract R (n×n upper-triangular part).
    let mut rmat = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rmat[(i, j)] = r[i * n + j] as f32;
        }
    }

    // Form thin Q by applying the Householder reflectors to the first n
    // columns of I, in reverse order.
    let mut q: Vec<f64> = vec![0.0; m * n];
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for (k, v) in vs.iter().rev() {
        let k = *k;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0f64;
            for i in k..m {
                dot += v[i - k] * q[i * n + j];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                q[i * n + j] -= f * v[i - k];
            }
        }
    }
    let qmat = Mat::from_vec(m, n, q.into_iter().map(|x| x as f32).collect());
    (qmat, rmat)
}

/// Orthonormalize the columns of A in place (returns Q of the thin QR).
pub fn orthonormalize(a: &Mat) -> Mat {
    qr_thin(a).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_tn};
    use crate::util::rng::Rng;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(10);
        for &(m, n) in &[(5, 5), (20, 7), (64, 16), (33, 32)] {
            let a = Mat::randn(m, n, 0.0, 1.0, &mut rng);
            let (q, r) = qr_thin(&a);
            let qr = matmul(&q, &r);
            let err = qr.sub(&a).fro() / a.fro();
            assert!(err < 1e-5, "{m}x{n} err={err}");
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Rng::new(11);
        let a = Mat::randn(50, 12, 0.0, 1.0, &mut rng);
        let (q, _) = qr_thin(&a);
        let qtq = matmul_tn(&q, &q);
        let err = qtq.sub(&Mat::eye(12)).fro();
        assert!(err < 1e-5, "orthonormality err={err}");
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(12);
        let a = Mat::randn(30, 10, 0.0, 1.0, &mut rng);
        let (_, r) = qr_thin(&a);
        for i in 0..10 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn rank_deficient_is_stable() {
        // Column 2 = column 0 + column 1: QR must not produce NaNs.
        let mut rng = Rng::new(13);
        let mut a = Mat::randn(16, 3, 0.0, 1.0, &mut rng);
        for i in 0..16 {
            a[(i, 2)] = a[(i, 0)] + a[(i, 1)];
        }
        let (q, r) = qr_thin(&a);
        assert!(q.data.iter().all(|x| x.is_finite()));
        assert!(r.data.iter().all(|x| x.is_finite()));
        let err = matmul(&q, &r).sub(&a).fro() / a.fro();
        assert!(err < 1e-4);
    }
}
