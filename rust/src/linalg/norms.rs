//! Matrix norms and spectrum diagnostics used by the quantization-error
//! analysis (nuclear norm, Eq. 6–8) and by the Figure 3 / 9 / 10 spectrum
//! and value-distribution plots.

use super::mat::Mat;
use super::svd::svd;

/// Nuclear norm ‖M‖_* = Σ σᵢ (exact, via Jacobi SVD).
pub fn nuclear_norm(m: &Mat) -> f64 {
    svd(m).nuclear()
}

/// Full singular spectrum, descending.
pub fn singular_values(m: &Mat) -> Vec<f32> {
    svd(m).s
}

/// Spectral norm σ₁ estimated by power iteration (cheap; avoids full SVD).
pub fn spectral_norm_est(m: &Mat, iters: usize, seed: u64) -> f64 {
    use crate::linalg::gemm::matvec;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut x: Vec<f32> = (0..m.cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mt = m.t();
    let mut sigma = 0.0f64;
    for _ in 0..iters {
        let y = matvec(m, &x); // m·x
        let z = matvec(&mt, &y); // mᵀ·m·x
        let nz = z.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
        if nz == 0.0 {
            return 0.0;
        }
        for (xi, zi) in x.iter_mut().zip(&z) {
            *xi = (*zi as f64 / nz) as f32;
        }
        let ny = y.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
        sigma = ny;
    }
    sigma
}

/// Histogram of matrix entries over `bins` equal-width buckets in
/// [lo, hi]; returns (bin_centers, counts). Used for Fig 3c/3f.
pub fn value_histogram(m: &Mat, lo: f32, hi: f32, bins: usize) -> (Vec<f32>, Vec<usize>) {
    assert!(bins > 0 && hi > lo);
    let mut counts = vec![0usize; bins];
    let w = (hi - lo) / bins as f32;
    for &x in &m.data {
        if x < lo || x >= hi {
            continue;
        }
        let b = (((x - lo) / w) as usize).min(bins - 1);
        counts[b] += 1;
    }
    let centers = (0..bins).map(|b| lo + w * (b as f32 + 0.5)).collect();
    (centers, counts)
}

/// Fit a Student-t distribution to the entries of M by matching excess
/// kurtosis (method of moments): for t with ν > 4,
/// kurtosis = 3(ν−2)/(ν−4)  ⇒  ν = (4k−6)/(k−3)  with k the sample
/// kurtosis. Returns (nu, scale). Higher ν ⇒ more Gaussian-like — the
/// paper's Figure 10 shows W_res fits a *higher-ν* t than W.
pub fn fit_student_t(m: &Mat) -> (f64, f64) {
    let n = m.data.len() as f64;
    let mean = m.data.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = m.data.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    let m4 = m.data.iter().map(|&x| (x as f64 - mean).powi(4)).sum::<f64>() / n;
    let kurt = m4 / (var * var);
    let nu = if kurt <= 3.0 + 1e-9 {
        1e6 // effectively Gaussian
    } else {
        ((4.0 * kurt - 6.0) / (kurt - 3.0)).max(4.0 + 1e-6)
    };
    // variance of t_ν(scale) is scale² ν/(ν−2)
    let scale = (var * (nu - 2.0) / nu).sqrt();
    (nu, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn nuclear_of_identity() {
        assert!((nuclear_norm(&Mat::eye(6)) - 6.0).abs() < 1e-4);
    }

    #[test]
    fn spectral_est_close_to_svd() {
        let mut rng = Rng::new(40);
        let a = Mat::randn(30, 20, 0.0, 1.0, &mut rng);
        let s1 = singular_values(&a)[0] as f64;
        let est = spectral_norm_est(&a, 50, 7);
        assert!((est - s1).abs() / s1 < 0.02, "est={est} s1={s1}");
    }

    #[test]
    fn histogram_counts_everything_in_range() {
        let m = Mat::from_vec(1, 6, vec![-1.0, -0.5, 0.0, 0.25, 0.5, 0.99]);
        let (_, counts) = value_histogram(&m, -1.0, 1.0, 4);
        assert_eq!(counts.iter().sum::<usize>(), 6);
        // bins over [-1,1): [-1,-.5) {-1.0}, [-.5,0) {-0.5}, [0,.5) {0, .25},
        // [.5,1) {0.5, 0.99}
        assert_eq!(counts, vec![1, 1, 2, 2]);
    }

    #[test]
    fn t_fit_gaussian_gives_high_nu() {
        let mut rng = Rng::new(41);
        let m = Mat::randn(100, 100, 0.0, 0.02, &mut rng);
        let (nu, scale) = fit_student_t(&m);
        assert!(nu > 20.0, "nu={nu}");
        assert!((scale - 0.02).abs() < 0.005, "scale={scale}");
    }

    #[test]
    fn t_fit_heavy_tail_gives_low_nu() {
        // Mixture: mostly small values + rare large outliers => heavy tails.
        let mut rng = Rng::new(42);
        let mut data = vec![0.0f32; 20_000];
        for x in data.iter_mut() {
            *x = if rng.uniform() < 0.01 { rng.normal_f32(0.0, 0.5) } else { rng.normal_f32(0.0, 0.02) };
        }
        let m = Mat::from_vec(100, 200, data);
        let (nu, _) = fit_student_t(&m);
        assert!(nu < 10.0, "nu={nu}");
    }
}
