//! Register-tiled, packed, multithreaded SGEMM — the rust-side compute
//! hot path.
//!
//! The coordinator uses this for adapter initialization (SVD power
//! iterations are GEMM-bound), quantization-error analysis, the toy-MNIST
//! experiment, evaluation-side math, and — through the serving stack —
//! every prefill/decode forward. The kernel is a classic BLIS-style
//! decomposition without explicit SIMD intrinsics (portable; LLVM
//! vectorizes the constant-bound register tile):
//!
//! * the k dimension is cut into `KC`-deep panels; each panel of B is
//!   **packed** once per worker into strip-major layout (`NR`-wide column
//!   strips, contiguous in k) so the inner loop streams it linearly,
//! * each `MR`-row band of A is packed k-major (`apack[p*MR + r]`) so the
//!   micro-kernel broadcasts A values from consecutive memory,
//! * the micro-kernel accumulates an `MR × NR` register tile over one
//!   packed k-panel, loading the tile from C on entry and storing it back
//!   on exit (C-carry).
//!
//! The C-carry detail is what keeps the **bit-determinism contract**: each
//! C element still receives exactly one multiply-add per k index, in
//! ascending k order, across any panel/tile/thread decomposition — the
//! same arithmetic sequence as the pre-tiled kernel, the naive small-case
//! loop, and the single-row `vecmat_into` path, so all of them agree bit
//! for bit (pinned by `rust/tests/determinism.rs`).
//!
//! The quantized path (`dequant_matmul*`) shares the same driver: the NF4
//! operand's nibbles are expanded **during packing** through a per-block
//! 16-entry scaled LUT (`slut[c] = NF4_LEVELS[c] * scale`, bitwise equal
//! to `Nf4Block::value`), so dequantization costs zero extra passes over
//! what the dense packed kernel already pays — the dense W is never
//! materialized, not even panel-wise outside the packed operand buffer.
//!
//! Benchmarked and tuned in `benches/perf_micro.rs`; see EXPERIMENTS.md
//! §Perf. The per-machine trajectory lives in `benches/baselines/`.

use super::mat::Mat;
use crate::quant::nf4::{Nf4Tensor, BLOCK, NF4_LEVELS};
use crate::util::par::par_rows_mut;

/// Register-tile height: rows of A accumulated at once in the
/// micro-kernel. 6×16 f32 accumulators fit the 16 portable vector
/// registers (12 × 8-lane plus broadcast/load scratch).
const MR: usize = 6;
/// Register-tile width: columns of B per packed strip.
const NR: usize = 16;
/// Depth of a packed k-panel for the dense kernel.
const KC: usize = 256;
/// Below this many MACs the naive ikj loop beats the packing overhead.
const SMALL_ELEMS: usize = 32 * 32 * 32;
/// Strip width of the fallback AXPY kernel ([`axpy_row`]).
const AXPY_W: usize = 8;

/// Strip-mined AXPY: `crow += av * brow`, 8-wide (LLVM vectorizes it).
/// This is the shared row kernel of every non-tiled path — the small /
/// skinny GEMM cases and the single-row serving kernels. One multiply-add
/// per element, left to right, so any composition of these paths keeps
/// the fixed-k-order contract.
#[inline]
fn axpy_row(crow: &mut [f32], av: f32, brow: &[f32]) {
    let n = crow.len();
    let strips = n / AXPY_W;
    for s in 0..strips {
        let j0 = s * AXPY_W;
        let cdst = &mut crow[j0..j0 + AXPY_W];
        let bsrc = &brow[j0..j0 + AXPY_W];
        for q in 0..AXPY_W {
            cdst[q] += av * bsrc[q];
        }
    }
    for j in strips * AXPY_W..n {
        crow[j] += av * brow[j];
    }
}

/// The register micro-kernel: accumulate an `mr × nw` C tile (at rows
/// `row0..row0+mr` of `cchunk`, columns `j0..j0+nw`) over one packed
/// k-panel of depth `kc`. `apack` is k-major MR-wide (zero-padded rows
/// past `mr`), `bstrip` is one k-contiguous NR-wide strip (zero-padded
/// columns past `nw`).
///
/// The accumulator tile is **loaded from C and stored back** rather than
/// starting from zero: per element this appends `kc` multiply-adds, in
/// ascending k, onto whatever earlier k-panels already produced — the
/// exact arithmetic sequence of a flat ascending-k sweep. Padded lanes
/// multiply packed zeros and are never stored.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_tile(
    kc: usize,
    apack: &[f32],
    bstrip: &[f32],
    cchunk: &mut [f32],
    row0: usize,
    mr: usize,
    j0: usize,
    nw: usize,
    n: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for r in 0..mr {
        let base = (row0 + r) * n + j0;
        acc[r][..nw].copy_from_slice(&cchunk[base..base + nw]);
    }
    for p in 0..kc {
        let arow = &apack[p * MR..(p + 1) * MR];
        let brow = &bstrip[p * NR..(p + 1) * NR];
        for r in 0..MR {
            let av = arow[r];
            for q in 0..NR {
                acc[r][q] += av * brow[q];
            }
        }
    }
    for r in 0..mr {
        let base = (row0 + r) * n + j0;
        cchunk[base..base + nw].copy_from_slice(&acc[r][..nw]);
    }
}

/// Pack `mr` rows of A (rows `i0..i0+mr`, k range `kb..ke`) k-major into
/// `apack[p*MR + r]`, scaled by `alpha` (exact for `alpha == 1.0`), with
/// rows past `mr` zero-padded.
fn pack_a(a: &Mat, i0: usize, mr: usize, kb: usize, ke: usize, alpha: f32, apack: &mut [f32]) {
    let k = a.cols;
    if mr < MR {
        apack.fill(0.0);
    }
    for r in 0..mr {
        let arow = &a.data[(i0 + r) * k + kb..(i0 + r) * k + ke];
        for (p, &v) in arow.iter().enumerate() {
            apack[p * MR + r] = alpha * v;
        }
    }
}

/// Pack the dense k-panel `b[kb..ke, :]` strip-major: strip `s` occupies
/// `bpack[s*kc*NR ..][p*NR + q]`, tail columns zero-padded.
fn pack_b_dense(b: &Mat, kb: usize, ke: usize, bpack: &mut [f32]) {
    let n = b.cols;
    let kc = ke - kb;
    let nstrips = n.div_ceil(NR);
    for p in 0..kc {
        let brow = &b.data[(kb + p) * n..(kb + p + 1) * n];
        for s in 0..nstrips {
            let j0 = s * NR;
            let nw = NR.min(n - j0);
            let dst = &mut bpack[s * kc * NR + p * NR..s * kc * NR + (p + 1) * NR];
            dst[..nw].copy_from_slice(&brow[j0..j0 + nw]);
            dst[nw..].iter_mut().for_each(|v| *v = 0.0);
        }
    }
}

/// Pack the NF4 k-panel `w[kb..ke, :]` strip-major, expanding nibbles
/// through a 16-entry **scaled LUT** rebuilt at each 64-value block
/// boundary: `slut[c] = NF4_LEVELS[c] * scale` is bitwise equal to
/// `Nf4Block::value`, so the packed panel is bit-identical to packing the
/// dequantized dense operand — dequantization is fused into the packing
/// pass the dense kernel pays anyway, with no side panel and no second
/// sweep.
fn pack_b_nf4(w: &Nf4Tensor, kb: usize, ke: usize, bpack: &mut [f32]) {
    let n = w.cols;
    let kc = ke - kb;
    let nstrips = n.div_ceil(NR);
    for p in 0..kc {
        let mut flat = (kb + p) * n;
        let mut j = 0usize;
        while j < n {
            // One run per NF4 block: rows may straddle the 64-value
            // blocks, so the scale (and LUT) can change mid-row.
            let scale = w.scales[flat / BLOCK];
            let mut slut = [0.0f32; 16];
            for (t, l) in slut.iter_mut().zip(NF4_LEVELS) {
                *t = l * scale;
            }
            let run = n.min(j + (BLOCK - flat % BLOCK));
            while j < run {
                // Low nibble first (even flat), then high — the
                // `Nf4Block::value` layout, extracted branchlessly.
                let code = (w.codes[flat / 2] >> (4 * (flat % 2))) & 0x0F;
                bpack[(j / NR) * kc * NR + p * NR + (j % NR)] = slut[code as usize];
                flat += 1;
                j += 1;
            }
        }
        let tail = n % NR;
        if tail != 0 {
            let base = (nstrips - 1) * kc * NR + p * NR;
            bpack[base + tail..base + NR].iter_mut().for_each(|v| *v = 0.0);
        }
    }
}

/// Shared packed-kernel driver for both operand kinds: `C (+)= (alpha·A) · P`
/// where `pack_panel(kb, ke, bpack)` materializes the strip-major packed
/// k-panel `P[kb..ke, :]` (dense copy or fused NF4 expansion). Parallel
/// over disjoint row blocks of C; each worker owns its packed buffers and
/// walks every k-panel itself (the duplicated pack is O(k·n) per worker
/// vs the O(rows·k·n) MACs it feeds).
fn packed_gemm_rows<P>(
    a: &Mat,
    n: usize,
    kc_max: usize,
    min_rows: usize,
    alpha: f32,
    c: &mut Mat,
    pack_panel: P,
) where
    P: Fn(usize, usize, &mut [f32]) + Sync,
{
    let (m, k) = (a.rows, a.cols);
    let nstrips = n.div_ceil(NR);
    let kcap = kc_max.min(k);
    par_rows_mut(&mut c.data, m, n, min_rows, |lo, hi, cchunk| {
        let mut bpack = vec![0.0f32; nstrips * kcap * NR];
        let mut apack = vec![0.0f32; kcap * MR];
        for kb in (0..k).step_by(kc_max) {
            let ke = (kb + kc_max).min(k);
            let kc = ke - kb;
            pack_panel(kb, ke, &mut bpack[..nstrips * kc * NR]);
            for i0 in (lo..hi).step_by(MR) {
                let mr = MR.min(hi - i0);
                pack_a(a, i0, mr, kb, ke, alpha, &mut apack[..kc * MR]);
                for s in 0..nstrips {
                    let j0 = s * NR;
                    let nw = NR.min(n - j0);
                    micro_tile(
                        kc,
                        &apack[..kc * MR],
                        &bpack[s * kc * NR..(s + 1) * kc * NR],
                        cchunk,
                        i0 - lo,
                        mr,
                        j0,
                        nw,
                        n,
                    );
                }
            }
        }
    });
}

/// One entry point behind [`matmul_into`] (overwrite) and [`matmul_acc`]
/// (accumulate): `C (+)= alpha · A·B`. The two differ ONLY in whether C
/// is zeroed first — the C-carrying micro-kernel accumulates in place
/// either way, so `matmul_acc` no longer materializes a temporary
/// product.
fn gemm_core(a: &Mat, b: &Mat, alpha: f32, c: &mut Mat, accumulate: bool) {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (m, n), "matmul: output shape");
    if !accumulate {
        c.data.iter_mut().for_each(|x| *x = 0.0);
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if m * n * k < SMALL_ELEMS {
        // Small case: naive triple loop, row-major friendly (ikj order).
        // No zero-skip: every path that can stand in for a row of this
        // product — the packed kernel, `vecmat`, the dequant-GEMM —
        // performs one multiply-add per element in ascending p, and the
        // decode path's bit-identity contract (single-row forward ≡ row
        // of the batched forward) leans on that structural identity.
        for i in 0..m {
            for p in 0..k {
                let av = alpha * a.data[i * k + p];
                let brow = &b.data[p * n..(p + 1) * n];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
        return;
    }
    if m < MR {
        // Skinny batch: a padded register tile would mostly multiply
        // zeros; the flat AXPY row sweep (same per-element sequence) wins.
        for i in 0..m {
            let arow = &a.data[i * k..(i + 1) * k];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for p in 0..k {
                axpy_row(crow, alpha * arow[p], &b.data[p * n..(p + 1) * n]);
            }
        }
        return;
    }
    packed_gemm_rows(a, n, KC, 16, alpha, c, |kb, ke, bpack| pack_b_dense(b, kb, ke, bpack));
}

/// C = A · B. Panics on dimension mismatch.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = A · Bᵀ (B given row-major as the transposed operand).
pub fn matmul_nt(a: &Mat, bt: &Mat) -> Mat {
    assert_eq!(a.cols, bt.cols, "matmul_nt inner dim");
    let (m, n, k) = (a.rows, bt.rows, a.cols);
    let mut c = Mat::zeros(m, n);
    // A·Bᵀ with both row-major means rows of A dot rows of Bᵀ: perfect
    // locality already, no packing needed.
    par_rows_mut(&mut c.data, m, n, 8, |lo, hi, chunk| {
        for i in lo..hi {
            let arow = a.row(i);
            let crow = &mut chunk[(i - lo) * n..(i - lo + 1) * n];
            for j in 0..n {
                let brow = bt.row(j);
                let mut acc = 0.0f32;
                // 4-way unrolled reduction; LLVM vectorizes.
                let mut t0 = 0.0f32;
                let mut t1 = 0.0f32;
                let mut t2 = 0.0f32;
                let mut t3 = 0.0f32;
                let chunks = k / 4;
                for c4 in 0..chunks {
                    let p = c4 * 4;
                    t0 += arow[p] * brow[p];
                    t1 += arow[p + 1] * brow[p + 1];
                    t2 += arow[p + 2] * brow[p + 2];
                    t3 += arow[p + 3] * brow[p + 3];
                }
                for p in chunks * 4..k {
                    acc += arow[p] * brow[p];
                }
                crow[j] = acc + (t0 + t1) + (t2 + t3);
            }
        }
    });
    c
}

/// Column-block width of the `matmul_tn` panel kernel: the per-worker
/// accumulator block is `m × TN_JB` floats (≤ 64 KiB at the m ≤ 128 cap),
/// small enough to stay cache-resident across the whole k sweep.
const TN_JB: usize = 128;

/// Output-row cap under which `matmul_tn` uses the panel kernel; wider
/// outputs fall back to transpose + blocked GEMM.
const TN_SKINNY_M: usize = 128;

/// C = Aᵀ · B, with A given row-major as the transposed operand (k×m).
///
/// For skinny outputs (m ≤ 128) — the rank-k panel shape that dominates
/// adapter work: `Qᵀ·A` in the randomized SVD (m = rank + oversampling)
/// and the low-rank backward products of the toy trainer — the dense
/// micro-kernel is a poor fit (narrow C strips, plus a full transpose
/// copy of `at`). This path instead sweeps k once, accumulating rank-1
/// updates into an m×TN_JB cache-resident block per column panel: both
/// operands are walked row-major with no packing or transpose.
///
/// Each C element is accumulated over p = 0..k in ascending order no
/// matter how panels are distributed, so results are bit-identical for
/// any `PISSA_THREADS` (the determinism contract of `util::par`).
pub fn matmul_tn(at: &Mat, b: &Mat) -> Mat {
    assert_eq!(at.rows, b.rows, "matmul_tn inner dim");
    let (k, m, n) = (at.rows, at.cols, b.cols);
    if m > TN_SKINNY_M {
        // Wide output: the blocked micro-kernel wins; pay the transpose.
        return matmul(&at.t(), b);
    }
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    let npanels = n.div_ceil(TN_JB);
    let panels = crate::util::par::par_map(npanels, 1, |pi| {
        let jlo = pi * TN_JB;
        let jhi = (jlo + TN_JB).min(n);
        let w = jhi - jlo;
        let mut block = vec![0.0f32; m * w];
        for p in 0..k {
            let arow = at.row(p);
            let brow = &b.row(p)[jlo..jhi];
            for (i, &av) in arow.iter().enumerate() {
                let dst = &mut block[i * w..(i + 1) * w];
                for (d, &bv) in dst.iter_mut().zip(brow) {
                    *d += av * bv;
                }
            }
        }
        block
    });
    for (pi, block) in panels.iter().enumerate() {
        let jlo = pi * TN_JB;
        let w = ((jlo + TN_JB).min(n)) - jlo;
        for i in 0..m {
            c.data[i * n + jlo..i * n + jlo + w].copy_from_slice(&block[i * w..(i + 1) * w]);
        }
    }
    c
}

/// Rows of the NF4 operand expanded per packed k-panel of
/// [`dequant_matmul`]. At serving widths (n ≤ a few thousand) a panel is
/// a few hundred KiB — large enough to amortize the LUT setup, small
/// enough to stay cache-resident across the row-band sweep.
pub const DQ_PANEL_ROWS: usize = 64;

/// C = X · deq(W) with W kept in blockwise NF4 — the quantized-base
/// serving kernel ("DequantGemm"). The dense W is NEVER materialized:
/// each worker expands k-panels of `panel_rows` rows of W **directly into
/// its packed operand buffer** through the per-block scaled LUT
/// ([`pack_b_nf4`]), then runs the same register micro-kernel as
/// [`matmul`] over the panel — dequantization rides the packing pass the
/// dense kernel needs anyway.
///
/// Every C element is accumulated in ascending p (k-index) order with one
/// multiply-add per p — the exact arithmetic sequence of `matmul` on the
/// dequantized dense operand — so the result is bit-identical to
/// `matmul(x, &dequantize(w))`, for any `PISSA_THREADS` and any
/// `panel_rows` (locked in by `rust/tests/determinism.rs`).
pub fn dequant_matmul(x: &Mat, w: &Nf4Tensor) -> Mat {
    dequant_matmul_panel(x, w, DQ_PANEL_ROWS)
}

/// [`dequant_matmul`] writing into an existing buffer (overwritten, like
/// [`matmul_into`]) — the quantized-base leg of the serving pipeline's
/// reusable activation buffers: L layers of streamed base GEMMs land in
/// the same ping-pong allocation instead of a fresh matrix per linear.
pub fn dequant_matmul_into(x: &Mat, w: &Nf4Tensor, c: &mut Mat) {
    dequant_matmul_panel_into(x, w, DQ_PANEL_ROWS, c);
}

/// [`dequant_matmul`] with an explicit panel height (rows of W expanded
/// per packed k-panel). Exposed for the determinism/equivalence suites,
/// which sweep panel sizes that don't divide the NF4 block size.
pub fn dequant_matmul_panel(x: &Mat, w: &Nf4Tensor, panel_rows: usize) -> Mat {
    let mut c = Mat::zeros(x.rows, w.cols);
    dequant_matmul_panel_into(x, w, panel_rows, &mut c);
    c
}

/// Core of the dequant-GEMM: C = X · deq(W) overwritten into `c`.
pub fn dequant_matmul_panel_into(x: &Mat, w: &Nf4Tensor, panel_rows: usize, c: &mut Mat) {
    assert!(panel_rows >= 1, "panel_rows must be >= 1");
    assert_eq!(
        x.cols, w.rows,
        "dequant_matmul: {}x{} · {}x{}",
        x.rows, x.cols, w.rows, w.cols
    );
    let (m, k, n) = (x.rows, w.rows, w.cols);
    assert_eq!((c.rows, c.cols), (m, n), "dequant_matmul_into: output shape");
    c.data.iter_mut().for_each(|v| *v = 0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if m < MR {
        // Skinny batch: the fused-LUT row sweep (shared with the decode
        // fast path) beats a mostly-padded register tile.
        for i in 0..m {
            let crow = &mut c.data[i * n..(i + 1) * n];
            dequant_row_axpy(x.row(i), w, crow);
        }
        return;
    }
    packed_gemm_rows(x, n, panel_rows, 8, 1.0, c, |kb, ke, bpack| pack_b_nf4(w, kb, ke, bpack));
}

/// C += alpha * A·B accumulated in place through the C-carrying packed
/// kernel — no intermediate product matrix. Each element still receives
/// its k multiply-adds in ascending order (of `alpha·a[i,p]` against
/// `b[p,j]`), appended onto the existing C value.
pub fn matmul_acc(a: &Mat, b: &Mat, alpha: f32, c: &mut Mat) {
    gemm_core(a, b, alpha, c, true);
}

/// Core: C = A · B, register-tiled + packed, parallel over row blocks.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    gemm_core(a, b, 1.0, c, false);
}

/// y = x·A for a row vector x (length `a.rows`) — the single-request
/// serving path. Sequential sweep in fixed p order (deterministic).
pub fn vecmat(x: &[f32], a: &Mat) -> Vec<f32> {
    let mut y = vec![0.0f32; a.cols];
    vecmat_into(x, a, &mut y);
    y
}

/// [`vecmat`] overwriting a caller-owned buffer — the allocation-free
/// single-row decode path, tuned for the one-token-per-step hot loop:
/// four A rows are swept per pass so each y element is loaded/stored once
/// per four k steps instead of every step. Per element the adds still
/// land one multiply-add at a time in ascending p order — bit-identical
/// to the corresponding row of `matmul(X, a)` (the decode fast path's
/// contract with the batched prefill).
pub fn vecmat_into(x: &[f32], a: &Mat, y: &mut [f32]) {
    assert_eq!(x.len(), a.rows, "vecmat: x len {} vs {} rows", x.len(), a.rows);
    assert_eq!(y.len(), a.cols, "vecmat: y len {} vs {} cols", y.len(), a.cols);
    y.iter_mut().for_each(|v| *v = 0.0);
    let (k, n) = (a.rows, a.cols);
    let mut p = 0usize;
    while p + 4 <= k {
        let (x0, x1, x2, x3) = (x[p], x[p + 1], x[p + 2], x[p + 3]);
        let r0 = a.row(p);
        let r1 = a.row(p + 1);
        let r2 = a.row(p + 2);
        let r3 = a.row(p + 3);
        for j in 0..n {
            let mut t = y[j];
            t += x0 * r0[j];
            t += x1 * r1[j];
            t += x2 * r2[j];
            t += x3 * r3[j];
            y[j] = t;
        }
        p += 4;
    }
    while p < k {
        axpy_row(y, x[p], a.row(p));
        p += 1;
    }
}

/// `y += x · deq(w)` with the NF4 nibbles expanded through the per-block
/// scaled LUT directly in the AXPY loop — no panel buffer at all. The
/// shared row kernel of [`dequant_vecmat_into`] and the skinny-batch case
/// of [`dequant_matmul_panel_into`]; `y` must be pre-zeroed (or hold the
/// values being accumulated onto).
fn dequant_row_axpy(x: &[f32], w: &Nf4Tensor, y: &mut [f32]) {
    let (k, n) = (w.rows, w.cols);
    let mut flat = 0usize;
    for (p, &xv) in x.iter().enumerate().take(k) {
        debug_assert_eq!(flat, p * n);
        let mut j = 0usize;
        while j < n {
            let scale = w.scales[flat / BLOCK];
            let mut slut = [0.0f32; 16];
            for (t, l) in slut.iter_mut().zip(NF4_LEVELS) {
                *t = l * scale;
            }
            let run = n.min(j + (BLOCK - flat % BLOCK));
            while j < run {
                let code = (w.codes[flat / 2] >> (4 * (flat % 2))) & 0x0F;
                y[j] += xv * slut[code as usize];
                flat += 1;
                j += 1;
            }
        }
    }
}

/// y = x·deq(W) for a row vector over a blockwise-NF4 operand — the
/// single-row leg of the streaming dequant-GEMM, fully fused: nibbles are
/// expanded through the 16-entry scaled LUT inside the accumulation loop,
/// with no decode buffer. Accumulates in ascending p order with
/// `slut[code]` bitwise equal to `Nf4Block::value`, so the result is
/// bit-identical both to the corresponding row of [`dequant_matmul`] and
/// to `vecmat(x, &dequantize(w))`.
pub fn dequant_vecmat_into(x: &[f32], w: &Nf4Tensor, y: &mut [f32]) {
    assert_eq!(x.len(), w.rows, "dequant_vecmat: x len {} vs {} rows", x.len(), w.rows);
    assert_eq!(y.len(), w.cols, "dequant_vecmat: y len {} vs {} cols", y.len(), w.cols);
    y.iter_mut().for_each(|v| *v = 0.0);
    if w.rows == 0 || w.cols == 0 {
        return;
    }
    dequant_row_axpy(x, w, y);
}

/// y = A·x for a vector x.
pub fn matvec(a: &Mat, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0f32; a.rows];
    for i in 0..a.rows {
        let row = a.row(i);
        let mut acc = 0.0f32;
        for (av, xv) in row.iter().zip(x) {
            acc += av * xv;
        }
        y[i] = acc;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for p in 0..a.cols {
                    acc += a[(i, p)] as f64 * b[(p, j)] as f64;
                }
                c[(i, j)] = acc as f32;
            }
        }
        c
    }

    fn close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        let mut rng = Rng::new(2);
        // Shapes cover all three dispatches: small naive, skinny (m < MR)
        // AXPY sweep, and the packed register kernel with partial tiles
        // in every dimension (m % MR, n % NR, k % KC all nonzero).
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (17, 9, 33),
            (5, 100, 80),   // skinny: m < MR above the small cutoff
            (7, 40, 130),   // packed: partial row band + partial strip
            (64, 64, 64),
            (100, 257, 65), // packed: k straddles a KC panel
            (129, 70, 200),
        ] {
            let a = Mat::randn(m, k, 0.0, 1.0, &mut rng);
            let b = Mat::randn(k, n, 0.0, 1.0, &mut rng);
            close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
        }
    }

    #[test]
    fn matmul_nt_tn_match() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(23, 41, 0.0, 1.0, &mut rng);
        let b = Mat::randn(41, 19, 0.0, 1.0, &mut rng);
        let bt = b.t();
        close(&matmul_nt(&a, &bt), &matmul(&a, &b), 1e-4);
        let at = a.t();
        close(&matmul_tn(&at, &b), &matmul(&a, &b), 1e-4);
    }

    #[test]
    fn matmul_tn_panel_kernel_shapes() {
        // Exercise both the skinny panel path (m ≤ 128, incl. panel-edge
        // n) and the wide fallback (m > 128).
        let mut rng = Rng::new(7);
        for &(k, m, n) in &[
            (1usize, 1usize, 1usize),
            (64, 8, 300),    // panel path, ragged last panel
            (257, 16, 128),  // panel path, exactly one panel
            (100, 128, 129), // panel path at the m cap
            (50, 200, 40),   // wide fallback
        ] {
            let at = Mat::randn(k, m, 0.0, 1.0, &mut rng);
            let b = Mat::randn(k, n, 0.0, 1.0, &mut rng);
            close(&matmul_tn(&at, &b), &naive(&at.t(), &b), 1e-4);
        }
    }

    #[test]
    fn vecmat_matches_matmul_row() {
        let mut rng = Rng::new(8);
        let a = Mat::randn(9, 14, 0.0, 1.0, &mut rng);
        let x: Vec<f32> = (0..9).map(|i| i as f32 * 0.25 - 1.0).collect();
        let y = vecmat(&x, &a);
        let xm = Mat::from_vec(1, 9, x);
        let ym = matmul(&xm, &a);
        for j in 0..14 {
            assert!((y[j] - ym[(0, j)]).abs() < 1e-5);
        }
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(40, 40, 0.0, 1.0, &mut rng);
        close(&matmul(&a, &Mat::eye(40)), &a, 1e-6);
        close(&matmul(&Mat::eye(40), &a), &a, 1e-6);
    }

    #[test]
    fn acc_accumulates() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(8, 8, 0.0, 1.0, &mut rng);
        let b = Mat::randn(8, 8, 0.0, 1.0, &mut rng);
        let mut c = Mat::zeros(8, 8);
        // In-place accumulation reassociates the cancellation (the second
        // pass subtracts products one by one instead of a materialized
        // prod matrix), so exact zero is no longer guaranteed — only
        // zero to fp accumulation error.
        matmul_acc(&a, &b, 1.0, &mut c);
        matmul_acc(&a, &b, -1.0, &mut c);
        assert!(c.fro() < 1e-4, "fro = {}", c.fro());
    }

    #[test]
    fn acc_matches_reference_through_packed_path() {
        // Accumulate onto a non-zero C through the register kernel
        // (shape above the small cutoff, m ≥ MR) and check against the
        // explicit c0 + alpha·A·B reference.
        let mut rng = Rng::new(12);
        let a = Mat::randn(40, 80, 0.0, 1.0, &mut rng);
        let b = Mat::randn(80, 50, 0.0, 1.0, &mut rng);
        let c0 = Mat::randn(40, 50, 0.0, 1.0, &mut rng);
        let mut c = c0.clone();
        matmul_acc(&a, &b, 0.5, &mut c);
        let prod = naive(&a, &b);
        let mut want = c0.clone();
        for (wi, pi) in want.data.iter_mut().zip(&prod.data) {
            *wi += 0.5 * pi;
        }
        close(&c, &want, 1e-4);
    }

    #[test]
    fn dequant_matmul_matches_dense_on_dequantized_operand() {
        use crate::quant::nf4::{dequantize, quantize, BLOCK};
        let mut rng = Rng::new(9);
        // Shapes straddle the NF4 block size (cols not multiples of 64)
        // and cover all dispatches (small/skinny sweep + packed kernel).
        for &(m, k, n) in &[(1usize, 9usize, 11usize), (7, 70, 37), (33, 64, 300), (64, 48, 96)] {
            let x = Mat::randn(m, k, 0.0, 1.0, &mut rng);
            let w = quantize(&Mat::randn(k, n, 0.0, 0.5, &mut rng));
            let dense = dequantize(&w);
            let want = matmul(&x, &dense);
            assert_eq!(dequant_matmul(&x, &w).data, want.data, "{m}x{k}x{n}");
            // Panel heights that don't divide (or exceed) BLOCK: the
            // ascending-p accumulation makes the panel split invisible.
            for panel in [1usize, 3, BLOCK - 1, BLOCK + 9, 4 * BLOCK] {
                let got = dequant_matmul_panel(&x, &w, panel);
                assert_eq!(got.data, want.data, "{m}x{k}x{n} panel={panel}");
            }
        }
    }

    #[test]
    fn dequant_matmul_into_overwrites_stale_buffers() {
        use crate::quant::nf4::quantize;
        let mut rng = Rng::new(10);
        let x = Mat::randn(5, 70, 0.0, 1.0, &mut rng);
        let w = quantize(&Mat::randn(70, 37, 0.0, 0.5, &mut rng));
        let want = dequant_matmul(&x, &w);
        // A reused (ping-pong) buffer full of garbage must be overwritten.
        let mut c = Mat::from_vec(5, 37, vec![7.5; 5 * 37]);
        dequant_matmul_into(&x, &w, &mut c);
        assert_eq!(c.data, want.data);
    }

    #[test]
    fn dequant_matmul_empty_shapes() {
        use crate::quant::nf4::quantize;
        let x = Mat::zeros(0, 8);
        let w = quantize(&Mat::zeros(8, 4));
        let c = dequant_matmul(&x, &w);
        assert_eq!((c.rows, c.cols), (0, 4));
        let c2 = dequant_matmul(&Mat::zeros(3, 8), &quantize(&Mat::zeros(8, 0)));
        assert_eq!((c2.rows, c2.cols), (3, 0));
    }

    #[test]
    fn row_fast_paths_are_bit_identical_to_batched_rows() {
        use crate::quant::nf4::quantize;
        // The decode fast path's contract: vecmat_into / dequant_vecmat_into
        // reproduce rows of the batched GEMMs BIT for bit, covering the
        // small naive, skinny sweep, and packed register dispatches.
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(3usize, 9usize, 11usize), (5, 100, 80), (40, 70, 300)] {
            let x = Mat::randn(m, k, 0.0, 1.0, &mut rng);
            let b = Mat::randn(k, n, 0.0, 1.0, &mut rng);
            let dense = matmul(&x, &b);
            let w = quantize(&b);
            let dq = dequant_matmul(&x, &w);
            let mut y = vec![-7.0f32; n]; // stale buffer must be overwritten
            let mut yq = vec![-7.0f32; n];
            for i in 0..m {
                vecmat_into(x.row(i), &b, &mut y);
                assert_eq!(y.as_slice(), dense.row(i), "{m}x{k}x{n} row {i}");
                dequant_vecmat_into(x.row(i), &w, &mut yq);
                assert_eq!(yq.as_slice(), dq.row(i), "{m}x{k}x{n} quant row {i}");
            }
        }
    }

    #[test]
    fn matvec_matches() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(12, 7, 0.0, 1.0, &mut rng);
        let x: Vec<f32> = (0..7).map(|i| i as f32 * 0.5 - 1.0).collect();
        let y = matvec(&a, &x);
        let xm = Mat::from_vec(7, 1, x);
        let ym = matmul(&a, &xm);
        for i in 0..12 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-5);
        }
    }
}
