//! Blocked, packed, multithreaded SGEMM — the rust-side compute hot path.
//!
//! The coordinator uses this for adapter initialization (SVD power
//! iterations are GEMM-bound), quantization-error analysis, the toy-MNIST
//! experiment, and evaluation-side math. It is written to be auto-
//! vectorizable: the inner loop is an 8-wide accumulator over a packed
//! panel of B, i.e. a classic (MC×KC)·(KC×NR) micro-kernel layout without
//! explicit SIMD intrinsics (portable, and LLVM vectorizes it well).
//!
//! Benchmarked and tuned in `benches/perf_micro.rs`; see EXPERIMENTS.md §Perf.

use super::mat::Mat;
use crate::quant::nf4::Nf4Tensor;
use crate::util::par::par_rows_mut;

/// Cache-blocking parameters (tuned on the image's CPU; see §Perf).
const MC: usize = 64; // rows of A per macro-block
const KC: usize = 256; // depth per macro-block
const NR: usize = 8; // register tile width

/// The shared inner micro-kernel of [`matmul_into`] and
/// [`dequant_matmul_panel`]: `crow += av * brow` as an 8-wide
/// strip-mined AXPY (LLVM vectorizes it). Both GEMM paths MUST go
/// through this one routine — one multiply-add per element, left to
/// right — so the dequant-GEMM's bit-identical-to-dense contract is
/// pinned structurally, not by two copies staying in sync.
#[inline]
fn axpy_row(crow: &mut [f32], av: f32, brow: &[f32]) {
    let n = crow.len();
    let strips = n / NR;
    for s in 0..strips {
        let j0 = s * NR;
        let cdst = &mut crow[j0..j0 + NR];
        let bsrc = &brow[j0..j0 + NR];
        for q in 0..NR {
            cdst[q] += av * bsrc[q];
        }
    }
    for j in strips * NR..n {
        crow[j] += av * brow[j];
    }
}

/// C = A · B. Panics on dimension mismatch.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = A · Bᵀ (B given row-major as the transposed operand).
pub fn matmul_nt(a: &Mat, bt: &Mat) -> Mat {
    assert_eq!(a.cols, bt.cols, "matmul_nt inner dim");
    let (m, n, k) = (a.rows, bt.rows, a.cols);
    let mut c = Mat::zeros(m, n);
    // A·Bᵀ with both row-major means rows of A dot rows of Bᵀ: perfect
    // locality already, no packing needed.
    par_rows_mut(&mut c.data, m, n, 8, |lo, hi, chunk| {
        for i in lo..hi {
            let arow = a.row(i);
            let crow = &mut chunk[(i - lo) * n..(i - lo + 1) * n];
            for j in 0..n {
                let brow = bt.row(j);
                let mut acc = 0.0f32;
                // 4-way unrolled reduction; LLVM vectorizes.
                let mut t0 = 0.0f32;
                let mut t1 = 0.0f32;
                let mut t2 = 0.0f32;
                let mut t3 = 0.0f32;
                let chunks = k / 4;
                for c4 in 0..chunks {
                    let p = c4 * 4;
                    t0 += arow[p] * brow[p];
                    t1 += arow[p + 1] * brow[p + 1];
                    t2 += arow[p + 2] * brow[p + 2];
                    t3 += arow[p + 3] * brow[p + 3];
                }
                for p in chunks * 4..k {
                    acc += arow[p] * brow[p];
                }
                crow[j] = acc + (t0 + t1) + (t2 + t3);
            }
        }
    });
    c
}

/// Column-block width of the `matmul_tn` panel kernel: the per-worker
/// accumulator block is `m × TN_JB` floats (≤ 64 KiB at the m ≤ 128 cap),
/// small enough to stay cache-resident across the whole k sweep.
const TN_JB: usize = 128;

/// Output-row cap under which `matmul_tn` uses the panel kernel; wider
/// outputs fall back to transpose + blocked GEMM.
const TN_SKINNY_M: usize = 128;

/// C = Aᵀ · B, with A given row-major as the transposed operand (k×m).
///
/// For skinny outputs (m ≤ 128) — the rank-k panel shape that dominates
/// adapter work: `Qᵀ·A` in the randomized SVD (m = rank + oversampling)
/// and the low-rank backward products of the toy trainer — the dense
/// micro-kernel is a poor fit (narrow C strips, plus a full transpose
/// copy of `at`). This path instead sweeps k once, accumulating rank-1
/// updates into an m×TN_JB cache-resident block per column panel: both
/// operands are walked row-major with no packing or transpose.
///
/// Each C element is accumulated over p = 0..k in ascending order no
/// matter how panels are distributed, so results are bit-identical for
/// any `PISSA_THREADS` (the determinism contract of `util::par`).
pub fn matmul_tn(at: &Mat, b: &Mat) -> Mat {
    assert_eq!(at.rows, b.rows, "matmul_tn inner dim");
    let (k, m, n) = (at.rows, at.cols, b.cols);
    if m > TN_SKINNY_M {
        // Wide output: the blocked micro-kernel wins; pay the transpose.
        return matmul(&at.t(), b);
    }
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    let npanels = n.div_ceil(TN_JB);
    let panels = crate::util::par::par_map(npanels, 1, |pi| {
        let jlo = pi * TN_JB;
        let jhi = (jlo + TN_JB).min(n);
        let w = jhi - jlo;
        let mut block = vec![0.0f32; m * w];
        for p in 0..k {
            let arow = at.row(p);
            let brow = &b.row(p)[jlo..jhi];
            for (i, &av) in arow.iter().enumerate() {
                let dst = &mut block[i * w..(i + 1) * w];
                for (d, &bv) in dst.iter_mut().zip(brow) {
                    *d += av * bv;
                }
            }
        }
        block
    });
    for (pi, block) in panels.iter().enumerate() {
        let jlo = pi * TN_JB;
        let w = ((jlo + TN_JB).min(n)) - jlo;
        for i in 0..m {
            c.data[i * n + jlo..i * n + jlo + w].copy_from_slice(&block[i * w..(i + 1) * w]);
        }
    }
    c
}

/// Rows of the NF4 operand decoded per streaming panel of
/// [`dequant_matmul`]. At serving widths (n ≤ a few thousand) a panel is
/// a few hundred KiB — large enough to amortize the decode, small enough
/// to stay cache-resident across the row sweep.
pub const DQ_PANEL_ROWS: usize = 64;

/// C = X · deq(W) with W kept in blockwise NF4 — the quantized-base
/// serving kernel ("DequantGemm"). The dense W is NEVER materialized:
/// each worker streams k-panels of `panel_rows` rows of W, decoding them
/// into one reusable per-thread panel buffer
/// ([`Nf4Tensor::dequantize_range`] handles panels that straddle the
/// 64-value NF4 blocks), then runs the same ikj AXPY micro-kernel as
/// [`matmul`] over the panel.
///
/// Every C element is accumulated in ascending p (k-index) order with one
/// multiply-add per p — the exact arithmetic sequence of `matmul` on the
/// dequantized dense operand — so the result is bit-identical to
/// `matmul(x, &dequantize(w))`, for any `PISSA_THREADS` and any
/// `panel_rows` (locked in by `rust/tests/determinism.rs`).
pub fn dequant_matmul(x: &Mat, w: &Nf4Tensor) -> Mat {
    dequant_matmul_panel(x, w, DQ_PANEL_ROWS)
}

/// [`dequant_matmul`] writing into an existing buffer (overwritten, like
/// [`matmul_into`]) — the quantized-base leg of the serving pipeline's
/// reusable activation buffers: L layers of streamed base GEMMs land in
/// the same ping-pong allocation instead of a fresh matrix per linear.
pub fn dequant_matmul_into(x: &Mat, w: &Nf4Tensor, c: &mut Mat) {
    dequant_matmul_panel_into(x, w, DQ_PANEL_ROWS, c);
}

/// [`dequant_matmul`] with an explicit panel height (rows of W decoded
/// per streaming step). Exposed for the determinism/equivalence suites,
/// which sweep panel sizes that don't divide the NF4 block size.
pub fn dequant_matmul_panel(x: &Mat, w: &Nf4Tensor, panel_rows: usize) -> Mat {
    let mut c = Mat::zeros(x.rows, w.cols);
    dequant_matmul_panel_into(x, w, panel_rows, &mut c);
    c
}

/// Core of the dequant-GEMM: C = X · deq(W) overwritten into `c`.
pub fn dequant_matmul_panel_into(x: &Mat, w: &Nf4Tensor, panel_rows: usize, c: &mut Mat) {
    assert!(panel_rows >= 1, "panel_rows must be >= 1");
    assert_eq!(
        x.cols, w.rows,
        "dequant_matmul: {}x{} · {}x{}",
        x.rows, x.cols, w.rows, w.cols
    );
    let (m, k, n) = (x.rows, w.rows, w.cols);
    assert_eq!((c.rows, c.cols), (m, n), "dequant_matmul_into: output shape");
    c.data.iter_mut().for_each(|v| *v = 0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // Parallel over row blocks of C (disjoint output regions, the
    // determinism contract of util::par). Each worker owns one decode
    // buffer and walks every k-panel itself: the duplicated decode is
    // O(k·n) per worker vs the O(rows·k·n) MACs it feeds.
    par_rows_mut(&mut c.data, m, n, 8, |lo, hi, cchunk| {
        let mut panel = vec![0.0f32; panel_rows.min(k) * n];
        for kb in (0..k).step_by(panel_rows) {
            let ke = (kb + panel_rows).min(k);
            let vals = &mut panel[..(ke - kb) * n];
            w.dequantize_range(kb * n, ke * n, vals);
            for i in lo..hi {
                let xrow = x.row(i);
                let crow = &mut cchunk[(i - lo) * n..(i - lo + 1) * n];
                for p in kb..ke {
                    axpy_row(crow, xrow[p], &vals[(p - kb) * n..(p - kb + 1) * n]);
                }
            }
        }
    });
}

/// C += alpha * A·B accumulated into an existing buffer.
pub fn matmul_acc(a: &Mat, b: &Mat, alpha: f32, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let prod = matmul(a, b);
    for (ci, pi) in c.data.iter_mut().zip(&prod.data) {
        *ci += alpha * pi;
    }
}

/// Core: C = A · B with packing + parallel over row blocks of A.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (m, n));
    c.data.iter_mut().for_each(|x| *x = 0.0);
    if m * n * k < 32 * 32 * 32 {
        // Small case: naive triple loop, row-major friendly (ikj order).
        // No zero-skip: every path that can stand in for a row of this
        // product — the blocked kernel below, `vecmat`, the dequant-GEMM —
        // performs one multiply-add per element in ascending p, and the
        // decode path's bit-identity contract (single-row forward ≡ row
        // of the batched forward) leans on that structural identity.
        for i in 0..m {
            for p in 0..k {
                let av = a.data[i * k + p];
                let brow = &b.data[p * n..(p + 1) * n];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
        return;
    }

    // Parallelize over row-blocks of C; each worker owns disjoint C rows.
    par_rows_mut(&mut c.data, m, n, MC.min(16), |lo, hi, cchunk| {
        for kb in (0..k).step_by(KC) {
            let ke = (kb + KC).min(k);
            for ib in (lo..hi).step_by(MC) {
                let ie = (ib + MC).min(hi);
                // Micro-kernel: for each row i, accumulate over the k-panel
                // into C[i, :] with NR-wide strips (ikj order keeps B row
                // access contiguous; the j-strip fits registers).
                for i in ib..ie {
                    let arow = &a.data[i * k..(i + 1) * k];
                    let crow = &mut cchunk[(i - lo) * n..(i - lo + 1) * n];
                    for p in kb..ke {
                        axpy_row(crow, arow[p], &b.data[p * n..(p + 1) * n]);
                    }
                }
            }
        }
    });
}

/// y = x·A for a row vector x (length `a.rows`) — the single-request
/// serving path. Sequential AXPY sweep in fixed p order (deterministic).
pub fn vecmat(x: &[f32], a: &Mat) -> Vec<f32> {
    let mut y = vec![0.0f32; a.cols];
    vecmat_into(x, a, &mut y);
    y
}

/// [`vecmat`] overwriting a caller-owned buffer — the allocation-free
/// single-row decode path. One multiply-add per element in ascending p
/// order: bit-identical to the corresponding row of `matmul(X, a)` (the
/// decode fast path's contract with the batched prefill).
pub fn vecmat_into(x: &[f32], a: &Mat, y: &mut [f32]) {
    assert_eq!(x.len(), a.rows, "vecmat: x len {} vs {} rows", x.len(), a.rows);
    assert_eq!(y.len(), a.cols, "vecmat: y len {} vs {} cols", y.len(), a.cols);
    y.iter_mut().for_each(|v| *v = 0.0);
    for (p, &xv) in x.iter().enumerate() {
        axpy_row(y, xv, a.row(p));
    }
}

/// y = x·deq(W) for a row vector over a blockwise-NF4 operand — the
/// single-row leg of the streaming dequant-GEMM. Decodes k-panels of
/// [`DQ_PANEL_ROWS`] rows into one stack-local buffer and accumulates in
/// ascending p order, so the result is bit-identical both to the
/// corresponding row of [`dequant_matmul`] and to
/// `vecmat(x, &dequantize(w))`.
pub fn dequant_vecmat_into(x: &[f32], w: &Nf4Tensor, y: &mut [f32]) {
    assert_eq!(x.len(), w.rows, "dequant_vecmat: x len {} vs {} rows", x.len(), w.rows);
    assert_eq!(y.len(), w.cols, "dequant_vecmat: y len {} vs {} cols", y.len(), w.cols);
    y.iter_mut().for_each(|v| *v = 0.0);
    let (k, n) = (w.rows, w.cols);
    if k == 0 || n == 0 {
        return;
    }
    let mut panel = vec![0.0f32; DQ_PANEL_ROWS.min(k) * n];
    for kb in (0..k).step_by(DQ_PANEL_ROWS) {
        let ke = (kb + DQ_PANEL_ROWS).min(k);
        let vals = &mut panel[..(ke - kb) * n];
        w.dequantize_range(kb * n, ke * n, vals);
        for p in kb..ke {
            axpy_row(y, x[p], &vals[(p - kb) * n..(p - kb + 1) * n]);
        }
    }
}

/// y = A·x for a vector x.
pub fn matvec(a: &Mat, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0f32; a.rows];
    for i in 0..a.rows {
        let row = a.row(i);
        let mut acc = 0.0f32;
        for (av, xv) in row.iter().zip(x) {
            acc += av * xv;
        }
        y[i] = acc;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for p in 0..a.cols {
                    acc += a[(i, p)] as f64 * b[(p, j)] as f64;
                }
                c[(i, j)] = acc as f32;
            }
        }
        c
    }

    fn close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 9, 33), (64, 64, 64), (100, 257, 65), (129, 70, 200)] {
            let a = Mat::randn(m, k, 0.0, 1.0, &mut rng);
            let b = Mat::randn(k, n, 0.0, 1.0, &mut rng);
            close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
        }
    }

    #[test]
    fn matmul_nt_tn_match() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(23, 41, 0.0, 1.0, &mut rng);
        let b = Mat::randn(41, 19, 0.0, 1.0, &mut rng);
        let bt = b.t();
        close(&matmul_nt(&a, &bt), &matmul(&a, &b), 1e-4);
        let at = a.t();
        close(&matmul_tn(&at, &b), &matmul(&a, &b), 1e-4);
    }

    #[test]
    fn matmul_tn_panel_kernel_shapes() {
        // Exercise both the skinny panel path (m ≤ 128, incl. panel-edge
        // n) and the wide fallback (m > 128).
        let mut rng = Rng::new(7);
        for &(k, m, n) in &[
            (1usize, 1usize, 1usize),
            (64, 8, 300),    // panel path, ragged last panel
            (257, 16, 128),  // panel path, exactly one panel
            (100, 128, 129), // panel path at the m cap
            (50, 200, 40),   // wide fallback
        ] {
            let at = Mat::randn(k, m, 0.0, 1.0, &mut rng);
            let b = Mat::randn(k, n, 0.0, 1.0, &mut rng);
            close(&matmul_tn(&at, &b), &naive(&at.t(), &b), 1e-4);
        }
    }

    #[test]
    fn vecmat_matches_matmul_row() {
        let mut rng = Rng::new(8);
        let a = Mat::randn(9, 14, 0.0, 1.0, &mut rng);
        let x: Vec<f32> = (0..9).map(|i| i as f32 * 0.25 - 1.0).collect();
        let y = vecmat(&x, &a);
        let xm = Mat::from_vec(1, 9, x);
        let ym = matmul(&xm, &a);
        for j in 0..14 {
            assert!((y[j] - ym[(0, j)]).abs() < 1e-5);
        }
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(40, 40, 0.0, 1.0, &mut rng);
        close(&matmul(&a, &Mat::eye(40)), &a, 1e-6);
        close(&matmul(&Mat::eye(40), &a), &a, 1e-6);
    }

    #[test]
    fn acc_accumulates() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(8, 8, 0.0, 1.0, &mut rng);
        let b = Mat::randn(8, 8, 0.0, 1.0, &mut rng);
        let mut c = Mat::zeros(8, 8);
        matmul_acc(&a, &b, 1.0, &mut c);
        matmul_acc(&a, &b, -1.0, &mut c);
        assert!(c.fro() < 1e-5);
    }

    #[test]
    fn dequant_matmul_matches_dense_on_dequantized_operand() {
        use crate::quant::nf4::{dequantize, quantize, BLOCK};
        let mut rng = Rng::new(9);
        // Shapes straddle the NF4 block size (cols not multiples of 64)
        // and cover both matmul paths (small naive + blocked parallel).
        for &(m, k, n) in &[(1usize, 9usize, 11usize), (7, 70, 37), (33, 64, 300), (64, 48, 96)] {
            let x = Mat::randn(m, k, 0.0, 1.0, &mut rng);
            let w = quantize(&Mat::randn(k, n, 0.0, 0.5, &mut rng));
            let dense = dequantize(&w);
            let want = matmul(&x, &dense);
            assert_eq!(dequant_matmul(&x, &w).data, want.data, "{m}x{k}x{n}");
            // Panel heights that don't divide (or exceed) BLOCK: the
            // ascending-p accumulation makes the panel split invisible.
            for panel in [1usize, 3, BLOCK - 1, BLOCK + 9, 4 * BLOCK] {
                let got = dequant_matmul_panel(&x, &w, panel);
                assert_eq!(got.data, want.data, "{m}x{k}x{n} panel={panel}");
            }
        }
    }

    #[test]
    fn dequant_matmul_into_overwrites_stale_buffers() {
        use crate::quant::nf4::quantize;
        let mut rng = Rng::new(10);
        let x = Mat::randn(5, 70, 0.0, 1.0, &mut rng);
        let w = quantize(&Mat::randn(70, 37, 0.0, 0.5, &mut rng));
        let want = dequant_matmul(&x, &w);
        // A reused (ping-pong) buffer full of garbage must be overwritten.
        let mut c = Mat::from_vec(5, 37, vec![7.5; 5 * 37]);
        dequant_matmul_into(&x, &w, &mut c);
        assert_eq!(c.data, want.data);
    }

    #[test]
    fn dequant_matmul_empty_shapes() {
        use crate::quant::nf4::quantize;
        let x = Mat::zeros(0, 8);
        let w = quantize(&Mat::zeros(8, 4));
        let c = dequant_matmul(&x, &w);
        assert_eq!((c.rows, c.cols), (0, 4));
        let c2 = dequant_matmul(&Mat::zeros(3, 8), &quantize(&Mat::zeros(8, 0)));
        assert_eq!((c2.rows, c2.cols), (3, 0));
    }

    #[test]
    fn row_fast_paths_are_bit_identical_to_batched_rows() {
        use crate::quant::nf4::quantize;
        // The decode fast path's contract: vecmat_into / dequant_vecmat_into
        // reproduce rows of the batched GEMMs BIT for bit, covering both
        // the small naive and the blocked parallel dispatch.
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(3usize, 9usize, 11usize), (40, 70, 300)] {
            let x = Mat::randn(m, k, 0.0, 1.0, &mut rng);
            let b = Mat::randn(k, n, 0.0, 1.0, &mut rng);
            let dense = matmul(&x, &b);
            let w = quantize(&b);
            let dq = dequant_matmul(&x, &w);
            let mut y = vec![-7.0f32; n]; // stale buffer must be overwritten
            let mut yq = vec![-7.0f32; n];
            for i in 0..m {
                vecmat_into(x.row(i), &b, &mut y);
                assert_eq!(y.as_slice(), dense.row(i), "{m}x{k}x{n} row {i}");
                dequant_vecmat_into(x.row(i), &w, &mut yq);
                assert_eq!(yq.as_slice(), dq.row(i), "{m}x{k}x{n} quant row {i}");
            }
        }
    }

    #[test]
    fn matvec_matches() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(12, 7, 0.0, 1.0, &mut rng);
        let x: Vec<f32> = (0..7).map(|i| i as f32 * 0.5 - 1.0).collect();
        let y = matvec(&a, &x);
        let xm = Mat::from_vec(7, 1, x);
        let ym = matmul(&a, &xm);
        for i in 0..12 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-5);
        }
    }
}
