//! Randomized (fast) SVD — Halko, Martinsson & Tropp (2011), the
//! "Fast SVD" the paper uses to make PiSSA initialization take seconds
//! instead of minutes (paper §B, Table 4; reference [50]).
//!
//! Algorithm (rank r, oversampling p, `niter` subspace iterations):
//!   1. Ω ~ N(0,1)^{n×(r+p)};  Y = A·Ω
//!   2. repeat niter times:  Y = A·(Aᵀ·orth(Y))   (power iteration with
//!      re-orthonormalization each half-step for stability)
//!   3. Q = orth(Y);  B = Qᵀ·A  ((r+p)×n, small)
//!   4. SVD(B) = Ũ S Vᵀ (exact Jacobi on the small matrix)
//!   5. U = Q·Ũ; truncate everything to rank r.

use super::gemm::{matmul, matmul_tn};
use super::mat::Mat;
use super::qr::orthonormalize;
use super::svd::{svd, Svd};
use crate::util::rng::Rng;

/// Truncated randomized SVD: returns rank-`r` factors (u: m×r, s: r, vt: r×n).
/// `niter` trades accuracy for time exactly like the paper's Table 4.
pub fn rsvd(a: &Mat, r: usize, niter: usize, rng: &mut Rng) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let k = r.min(m.min(n));
    // Oversampling: +10 columns is the standard Halko recommendation.
    let l = (k + 10).min(m.min(n));

    let omega = Mat::randn(n, l, 0.0, 1.0, rng);
    let mut y = matmul(a, &omega); // m×l

    for _ in 0..niter {
        let q = orthonormalize(&y); // m×l
        let z = matmul_tn(&q, a); // l×n  (= QᵀA)
        let zt = orthonormalize(&z.t()); // n×l
        y = matmul(a, &zt); // m×l
    }

    let q = orthonormalize(&y); // m×l
    let b = matmul_tn(&q, a); // l×n, small
    let small = svd(&b);
    let u = matmul(&q, &small.u); // m×l

    Svd {
        u: u.cols_range(0, k),
        s: small.s[..k].to_vec(),
        vt: small.vt.rows_range(0, k),
    }
}

/// Best rank-r approximation error ‖A − A_r‖_F via rsvd (diagnostics).
pub fn lowrank_error(a: &Mat, r: usize, niter: usize, rng: &mut Rng) -> f64 {
    let d = rsvd(a, r, niter, rng);
    d.reconstruct().sub(a).fro()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_tn as mtn;

    #[test]
    fn recovers_exact_low_rank() {
        let mut rng = Rng::new(30);
        let u = Mat::randn(40, 4, 0.0, 1.0, &mut rng);
        let v = Mat::randn(4, 30, 0.0, 1.0, &mut rng);
        let a = matmul(&u, &v);
        let d = rsvd(&a, 4, 2, &mut rng);
        let err = d.reconstruct().sub(&a).fro() / a.fro();
        assert!(err < 1e-4, "err={err}");
    }

    #[test]
    fn matches_exact_svd_leading_values() {
        let mut rng = Rng::new(31);
        let a = Mat::randn(48, 32, 0.0, 1.0, &mut rng);
        let exact = svd(&a);
        let approx = rsvd(&a, 8, 4, &mut rng);
        for i in 0..8 {
            let rel = (exact.s[i] - approx.s[i]).abs() / exact.s[i];
            assert!(rel < 2e-2, "σ{i}: exact={} approx={}", exact.s[i], approx.s[i]);
        }
    }

    #[test]
    fn more_iters_is_more_accurate() {
        // On a matrix with slowly decaying spectrum, power iterations help.
        let mut rng = Rng::new(32);
        let a = Mat::randn(64, 64, 0.0, 1.0, &mut rng);
        let exact = svd(&a);
        let opt: f64 = exact.s[6..].iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        let e1 = lowrank_error(&a, 6, 0, &mut rng);
        let e3 = lowrank_error(&a, 6, 4, &mut rng);
        assert!(e3 <= e1 + 1e-6, "niter=4 ({e3}) should beat niter=0 ({e1})");
        // and e3 should be close to the optimal truncation error
        assert!(e3 < 1.1 * opt, "e3={e3} opt={opt}");
    }

    #[test]
    fn orthonormal_factors() {
        let mut rng = Rng::new(33);
        let a = Mat::randn(50, 40, 0.0, 1.0, &mut rng);
        let d = rsvd(&a, 10, 2, &mut rng);
        let utu = mtn(&d.u, &d.u).sub(&Mat::eye(10)).fro();
        let vvt = matmul(&d.vt, &d.vt.t()).sub(&Mat::eye(10)).fro();
        assert!(utu < 1e-4 && vvt < 1e-4, "utu={utu} vvt={vvt}");
    }

    #[test]
    fn wide_matrix() {
        let mut rng = Rng::new(34);
        let a = Mat::randn(20, 60, 0.0, 1.0, &mut rng);
        let d = rsvd(&a, 5, 2, &mut rng);
        assert_eq!((d.u.rows, d.u.cols), (20, 5));
        assert_eq!((d.vt.rows, d.vt.cols), (5, 60));
        assert!(d.s.windows(2).all(|w| w[0] >= w[1] - 1e-6));
    }
}
