//! The batched multi-adapter server.
//!
//! PiSSA's deployment promise: many cheap adapters share one frozen dense
//! base, so one host serves many fine-tuned variants at once. The server
//! snapshots, per attached adapter, a low-rank delta `(ΔA, ΔB)` against
//! the ORIGINAL dense weight `W` (the Appendix-C equivalent-LoRA form
//! `ΔA = [A'|A], ΔB = [B';−B]` for drifted PiSSA factors; the raw factors
//! when the frozen residual is `W` itself, e.g. LoRA), and executes a
//! mixed-adapter batch as
//!
//! ```text
//!   Y = X·W  +  Σ_groups scatter( (X_g·ΔA_g)·ΔB_g )
//! ```
//!
//! — one shared dense GEMM amortized across every adapter, plus two
//! skinny GEMMs per adapter group, dispatched in parallel via
//! [`crate::util::par::par_map`]. `ΔW` is never materialized. The
//! merge-per-request and dense-per-adapter strategies execute the same
//! `(W, ΔA, ΔB)` snapshot densely and exist as baselines (and as the
//! reference the equivalence property tests compare against).
//!
//! The quantized-base strategies swap the base storage, not the
//! algebra: `fused-quant` keeps the shared base resident as blockwise
//! NF4 (a [`QuantBase`]) and streams it through
//! [`crate::linalg::dequant_matmul`] — `Y = X·deq(W_nf4) + Σ_g …` —
//! while `dequant-dense` dequantizes the same snapshot once into a
//! dense copy (the bit-for-bit reference at fp32 residency). Both
//! accept QPiSSA/QLoRA/LoftQ adapters, whose frozen NF4 base the
//! full-precision strategies reject with a typed error.
//!
//! Determinism: request bucketing is sorted, group corrections are
//! scattered in group order on the caller thread, and every GEMM in the
//! path accumulates in fixed k-order — so serving output is bit-identical
//! for any `PISSA_THREADS` (locked in by `rust/tests/determinism.rs`).

use super::config::{ServeConfig, ServeError, ServeStrategy};
use super::router::{bucket, Group, Request};
use super::stats::ServeStats;
use crate::adapter::convert::pissa_to_lora;
use crate::adapter::AdapterEngine;
use crate::linalg::{dequant_matmul, matmul, vecmat, Mat};
use crate::quant::{dequantize, Nf4Tensor};
use crate::util::par::par_map;
use crate::util::timer::Timer;
use anyhow::Result;
use std::collections::BTreeMap;

/// Snapshot of one servable adapter: `effective = W + ΔA·ΔB`.
/// `None` when the adapter does not target the served module (it serves
/// the base weight unchanged).
#[derive(Debug, Clone)]
struct Prepared {
    delta: Option<(Mat, Mat)>,
}

/// The NF4-resident shared base of the `fused-quant` strategy: packed
/// codes + blockwise scales, streamed through the dequant-GEMM at
/// request time. The dense matrix is never materialized server-side.
#[derive(Debug, Clone)]
pub struct QuantBase {
    /// Blockwise NF4 snapshot of the served base weight.
    pub nf4: Nf4Tensor,
}

impl QuantBase {
    /// Bytes this base keeps resident (packed codes + f32 scales).
    pub fn resident_bytes(&self) -> usize {
        self.nf4.storage_bytes()
    }
}

/// How the server stores the shared base weight of the served linear —
/// the storage side of the [`ServeStrategy`] choice.
#[derive(Debug)]
enum BaseStore {
    /// Full-precision m×n matrix: the original `W` for the exact
    /// strategies, or the dequantized-once NF4 round trip for
    /// `dequant-dense`.
    Dense(Mat),
    /// NF4-resident base for `fused-quant` — the base GEMM streams the
    /// packed blocks panel-by-panel instead of reading a dense matrix.
    Quant(QuantBase),
}

impl BaseStore {
    /// The shared base GEMM `X·base` of the fused forward.
    fn forward(&self, x: &Mat) -> Mat {
        match self {
            BaseStore::Dense(w) => matmul(x, w),
            BaseStore::Quant(q) => dequant_matmul(x, &q.nf4),
        }
    }

    fn resident_bytes(&self) -> usize {
        match self {
            BaseStore::Dense(w) => w.data.len() * 4,
            BaseStore::Quant(q) => q.resident_bytes(),
        }
    }
}

/// Batched multi-adapter server over a snapshot of an [`AdapterEngine`].
///
/// Construction validates the [`ServeConfig`] against the engine and
/// copies out everything serving needs (shared base weight — dense or
/// NF4 depending on the strategy — plus per-adapter low-rank deltas), so
/// the engine is free to keep training afterwards; rebuild the server to
/// pick up new factors.
#[derive(Debug)]
pub struct Server {
    cfg: ServeConfig,
    /// Shared base of the served linear (m×n), in the representation the
    /// strategy serves from.
    base: BaseStore,
    n_in: usize,
    n_out: usize,
    prepared: BTreeMap<String, Prepared>,
    stats: ServeStats,
}

impl Server {
    /// Snapshot `engine` under `cfg`. Fails with a typed [`ServeError`]
    /// on unknown module, out-of-range layer, quantized adapters under a
    /// full-precision strategy, or rank > min(m, n) on a fused path.
    pub fn new(engine: &AdapterEngine, cfg: ServeConfig) -> Result<Server> {
        cfg.validate(engine)?;
        let base_w = engine.base_weight(&cfg.module, cfg.layer);
        let (n_in, n_out) = (base_w.rows, base_w.cols);
        let base = match cfg.strategy {
            // NF4-resident base, streamed through the dequant-GEMM
            // (same snapshot `AdapterEngine::quant_base_weight` hands
            // external callers, built from the already-copied weight).
            ServeStrategy::FusedQuant => {
                BaseStore::Quant(QuantBase { nf4: crate::quant::quantize(&base_w) })
            }
            // Same quantized snapshot, dequantized once into a dense
            // copy: bit-for-bit the FusedQuant output at fp32 residency.
            ServeStrategy::DequantDense => {
                BaseStore::Dense(dequantize(&crate::quant::quantize(&base_w)))
            }
            _ => BaseStore::Dense(base_w),
        };
        let mut prepared = BTreeMap::new();
        for name in engine.names() {
            let ad = engine.get(name)?;
            let delta = if !ad.spec.targets_module(&cfg.module) {
                None
            } else {
                let a0 = ad.init_factors[&format!("a_{}", cfg.module)].layer(cfg.layer);
                let b0 = ad.init_factors[&format!("b_{}", cfg.module)].layer(cfg.layer);
                let a1 = ad.factors[&format!("a_{}", cfg.module)].layer(cfg.layer);
                let b1 = ad.factors[&format!("b_{}", cfg.module)].layer(cfg.layer);
                if b0.data.iter().all(|&x| x == 0.0) {
                    // Frozen residual is W itself (LoRA-style init):
                    // the current factors ARE the delta, at rank r.
                    Some((a1, b1))
                } else {
                    // Appendix C: ΔA·ΔB = A'·B' − A₀·B₀, rank 2r, plugs
                    // into the original W (exact for full-precision
                    // strategies, whose attach-time invariant pins
                    // base = W − A₀·B₀; for quantized adapters the frozen
                    // base is nf4(W_res), so the identity — and therefore
                    // quantized serving — holds to the NF4 round-trip
                    // error the paper bounds in Table 3).
                    let d = pissa_to_lora(&a0, &b0, &a1, &b1);
                    Some((d.da, d.db))
                }
            };
            prepared.insert(name.to_string(), Prepared { delta });
        }
        Ok(Server { cfg, base, n_in, n_out, prepared, stats: ServeStats::new() })
    }

    pub fn cfg(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Input feature count of the served linear.
    pub fn n_in(&self) -> usize {
        self.n_in
    }

    /// Output feature count of the served linear.
    pub fn n_out(&self) -> usize {
        self.n_out
    }

    /// Bytes the shared base keeps resident under this strategy: m·n·4
    /// for a dense store, packed-codes + scales for the NF4 store (the
    /// ≤ 0.35× acceptance bar of `benches/quant_serve.rs`).
    pub fn base_resident_bytes(&self) -> usize {
        self.base.resident_bytes()
    }

    /// Dense base for the merged/dense execution paths. Those strategies
    /// always build a `Dense` store, so this cannot miss.
    fn dense_base(&self) -> &Mat {
        match &self.base {
            BaseStore::Dense(w) => w,
            BaseStore::Quant(_) => {
                unreachable!("merged/dense strategies always snapshot a dense base")
            }
        }
    }

    /// Names the server can route to (snapshot order).
    pub fn adapter_names(&self) -> Vec<&str> {
        self.prepared.keys().map(|s| s.as_str()).collect()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats.reset();
    }

    /// Serve one batch: row i of the output is the served linear applied
    /// to `requests[i]` under its adapter. An empty batch yields an empty
    /// (0×n_out) output. Unknown adapters, wrong input widths, and
    /// batches above `max_batch` (the occupancy denominator — route
    /// through a [`super::Scheduler`]) are typed errors; nothing panics
    /// on request data.
    pub fn forward(&mut self, requests: &[Request]) -> Result<Mat> {
        if requests.is_empty() {
            return Ok(Mat::zeros(0, self.n_out()));
        }
        if requests.len() > self.cfg.max_batch {
            return Err(ServeError::BatchTooLarge {
                got: requests.len(),
                max_batch: self.cfg.max_batch,
            }
            .into());
        }
        let want = self.n_in();
        for (i, r) in requests.iter().enumerate() {
            if r.x.len() != want {
                return Err(ServeError::DimMismatch { index: i, got: r.x.len(), want }.into());
            }
            if let Some(name) = &r.adapter {
                if !self.prepared.contains_key(name) {
                    return Err(ServeError::UnknownAdapter {
                        name: name.clone(),
                        have: self.prepared.keys().cloned().collect(),
                    }
                    .into());
                }
            }
        }
        let timer = Timer::start();
        let groups = bucket(requests);
        let y = match self.cfg.strategy {
            // The three fused-style strategies share one forward; they
            // differ only in how the BaseStore executes the shared GEMM.
            ServeStrategy::Fused | ServeStrategy::FusedQuant | ServeStrategy::DequantDense => {
                self.forward_fused(requests, &groups)
            }
            ServeStrategy::DensePerAdapter => self.forward_dense(requests, &groups),
            ServeStrategy::MergePerRequest => self.forward_merge(requests),
        };
        let adapters: Vec<Option<&str>> = requests.iter().map(|r| r.adapter.as_deref()).collect();
        self.stats.record_batch(&adapters, groups.len(), self.cfg.max_batch, timer.secs());
        Ok(y)
    }

    /// Shared `X·base` once (dense GEMM, or the streaming dequant-GEMM
    /// for the NF4-resident store), then per-group `(X_g·ΔA)·ΔB`
    /// corrections in parallel, scattered back in deterministic group
    /// order.
    fn forward_fused(&self, requests: &[Request], groups: &[Group]) -> Mat {
        let x = gather_all(requests, self.n_in());
        let mut y = self.base.forward(&x);
        let adapter_groups: Vec<&Group> = groups.iter().filter(|g| g.adapter.is_some()).collect();
        let corrections: Vec<Option<Mat>> = par_map(adapter_groups.len(), 1, |gi| {
            let g = adapter_groups[gi];
            let prep = &self.prepared[g.adapter.as_deref().expect("filtered to Some")];
            let (da, db) = prep.delta.as_ref()?;
            let xg = gather_rows(&x, &g.rows);
            let t = matmul(&xg, da); // |g| × R   (skinny)
            Some(matmul(&t, db)) // |g| × n   (rank-R panel product)
        });
        for (g, c) in adapter_groups.iter().zip(&corrections) {
            if let Some(c) = c {
                for (k, &row) in g.rows.iter().enumerate() {
                    for (yv, cv) in y.row_mut(row).iter_mut().zip(c.row(k)) {
                        *yv += cv;
                    }
                }
            }
        }
        y
    }

    /// Baseline: materialize the merged dense weight once per adapter
    /// group, dense GEMM per group. Amortizes the merge across a group
    /// but shares nothing across adapters.
    fn forward_dense(&self, requests: &[Request], groups: &[Group]) -> Mat {
        let mut y = Mat::zeros(requests.len(), self.n_out());
        let outs: Vec<Mat> = par_map(groups.len(), 1, |gi| {
            let g = &groups[gi];
            let xg = gather_requests(requests, &g.rows, self.n_in());
            match self.group_delta(g) {
                Some((da, db)) => {
                    let merged = self.dense_base().add(&matmul(da, db));
                    matmul(&xg, &merged)
                }
                None => matmul(&xg, self.dense_base()),
            }
        });
        for (g, out) in groups.iter().zip(&outs) {
            for (k, &row) in g.rows.iter().enumerate() {
                y.row_mut(row).copy_from_slice(out.row(k));
            }
        }
        y
    }

    /// Naive baseline: merge (materialize `W + ΔA·ΔB`) for every single
    /// request, then one dense vector-matrix product. Sequential — this
    /// is the cost model the fused path is measured against.
    fn forward_merge(&self, requests: &[Request]) -> Mat {
        let mut y = Mat::zeros(requests.len(), self.n_out());
        for (i, r) in requests.iter().enumerate() {
            let delta = r.adapter.as_deref().and_then(|n| self.prepared[n].delta.as_ref());
            let row = match delta {
                Some((da, db)) => {
                    let merged = self.dense_base().add(&matmul(da, db));
                    vecmat(&r.x, &merged)
                }
                None => vecmat(&r.x, self.dense_base()),
            };
            y.row_mut(i).copy_from_slice(&row);
        }
        y
    }

    fn group_delta(&self, g: &Group) -> Option<&(Mat, Mat)> {
        g.adapter.as_deref().and_then(|n| self.prepared[n].delta.as_ref())
    }
}

/// Pack every request row into a batch×m matrix.
fn gather_all(requests: &[Request], m: usize) -> Mat {
    let mut x = Mat::zeros(requests.len(), m);
    for (i, r) in requests.iter().enumerate() {
        x.row_mut(i).copy_from_slice(&r.x);
    }
    x
}

/// Gather a row subset of a packed batch.
fn gather_rows(x: &Mat, rows: &[usize]) -> Mat {
    let mut out = Mat::zeros(rows.len(), x.cols);
    for (k, &row) in rows.iter().enumerate() {
        out.row_mut(k).copy_from_slice(x.row(row));
    }
    out
}

/// Gather a row subset straight from the request slice.
fn gather_requests(requests: &[Request], rows: &[usize], m: usize) -> Mat {
    let mut out = Mat::zeros(rows.len(), m);
    for (k, &row) in rows.iter().enumerate() {
        out.row_mut(k).copy_from_slice(&requests[row].x);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::AdapterSpec;
    use crate::model::BaseModel;
    use crate::runtime::ConfigInfo;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "serve-test".into(),
            kind: "decoder".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            seq_len: 8,
            batch: 4,
            eval_batch: 2,
            n_classes: 0,
            ranks: vec![2],
        }
    }

    fn engine_with(names: &[(&str, AdapterSpec)], seed: u64) -> (AdapterEngine, Rng) {
        let mut rng = Rng::new(seed);
        let base = BaseModel::random(&tiny_cfg(), &mut rng);
        let mut eng = AdapterEngine::new(base);
        for (name, spec) in names {
            eng.attach(name, spec.clone(), &mut rng).unwrap();
        }
        (eng, rng)
    }

    #[test]
    fn empty_batch_serves_empty_output() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 1);
        let mut srv = Server::new(&eng, ServeConfig::new("q")).unwrap();
        let y = srv.forward(&[]).unwrap();
        assert_eq!((y.rows, y.cols), (0, 16));
        assert_eq!(srv.stats().batches, 0);
    }

    #[test]
    fn unknown_adapter_is_a_typed_error() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 2);
        let mut srv = Server::new(&eng, ServeConfig::new("q")).unwrap();
        let err = srv.forward(&[Request::new("ghost", vec![0.0; 16])]).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::UnknownAdapter { name, have }) => {
                assert_eq!(name, "ghost");
                assert_eq!(have, &vec!["p".to_string()]);
            }
            other => panic!("expected UnknownAdapter, got {other:?}"),
        }
    }

    #[test]
    fn dim_mismatch_is_a_typed_error() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 3);
        let mut srv = Server::new(&eng, ServeConfig::new("q")).unwrap();
        let err = srv
            .forward(&[Request::base(vec![0.0; 16]), Request::base(vec![0.0; 5])])
            .unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::DimMismatch { index, got, want }) => {
                assert_eq!((*index, *got, *want), (1, 5, 16));
            }
            other => panic!("expected DimMismatch, got {other:?}"),
        }
    }

    #[test]
    fn oversized_batch_is_a_typed_error() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 9);
        let mut srv = Server::new(&eng, ServeConfig::new("q").max_batch(2)).unwrap();
        let reqs: Vec<Request> = (0..3).map(|_| Request::base(vec![0.0; 16])).collect();
        let err = srv.forward(&reqs).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::BatchTooLarge { got, max_batch }) => {
                assert_eq!((*got, *max_batch), (3, 2));
            }
            other => panic!("expected BatchTooLarge, got {other:?}"),
        }
        // at the ceiling is fine
        assert!(srv.forward(&reqs[..2]).is_ok());
    }

    #[test]
    fn rank_above_min_dim_rejected_at_config_validation() {
        // LoRA attaches fine at any rank (A·B = 0), but serving it as a
        // "low-rank" update of a 16×16 weight at rank 40 is refused.
        let (eng, _) = engine_with(&[("fat", AdapterSpec::lora(40).targets(&["q"]))], 4);
        let err = Server::new(&eng, ServeConfig::new("q")).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::RankTooLarge { rank, m, n, .. }) => {
                assert_eq!((*rank, *m, *n), (40, 16, 16));
            }
            other => panic!("expected RankTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn quantized_adapters_need_a_quantized_base_strategy() {
        // qlora attaches under the exact NF4-fixed-point invariant (A·B=0),
        // so this test never depends on the Table-3 error bound.
        let (eng, _) = engine_with(&[("qp", AdapterSpec::qlora(2))], 5);
        for strategy in ServeStrategy::exact() {
            let err =
                Server::new(&eng, ServeConfig::new("q").strategy(strategy)).unwrap_err();
            assert!(
                matches!(
                    err.downcast_ref::<ServeError>(),
                    Some(ServeError::QuantizedAdapter { .. })
                ),
                "{}: expected QuantizedAdapter, got {err:?}",
                strategy.name()
            );
            assert!(err.to_string().contains("fused-quant"), "message: {err}");
        }
        for strategy in [ServeStrategy::FusedQuant, ServeStrategy::DequantDense] {
            assert!(
                Server::new(&eng, ServeConfig::new("q").strategy(strategy)).is_ok(),
                "{} must accept the quantized adapter",
                strategy.name()
            );
        }
    }

    #[test]
    fn fused_quant_serves_qlora_exactly_and_reports_nf4_residency() {
        // A QLoRA adapter's frozen base IS nf4(W), so serving it from the
        // shared NF4 snapshot reproduces the engine's effective weight up
        // to GEMM association (no quantization mismatch term at all).
        let (mut eng, mut rng) = engine_with(&[("qt", AdapterSpec::qlora(2))], 11);
        crate::serve::drift_factors(&mut eng, "qt", "q", 0.05, &mut rng).unwrap();
        let mut srv =
            Server::new(&eng, ServeConfig::new("q").strategy(ServeStrategy::FusedQuant))
                .unwrap();
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let got = srv.forward(&[Request::new("qt", x.clone())]).unwrap();
        let w_eff = eng.effective_weight_of("qt", "q", 0).unwrap();
        let want = vecmat(&x, &w_eff);
        for (g, w) in got.row(0).iter().zip(&want) {
            assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
        }
        // NF4 residency: 4 bits/value + one f32 scale per 64 values —
        // exactly the engine's quant_base_weight snapshot.
        let dense_bytes = 16 * 16 * 4;
        let nf4 = eng.quant_base_weight("q", 0);
        assert_eq!(srv.base_resident_bytes(), nf4.storage_bytes());
        assert!(
            srv.base_resident_bytes() * 100 <= dense_bytes * 35,
            "nf4 residency {} should be <= 0.35x dense {}",
            srv.base_resident_bytes(),
            dense_bytes
        );
        // The dense strategies report full fp32 residency.
        let dense_srv =
            Server::new(&eng, ServeConfig::new("q").strategy(ServeStrategy::DequantDense))
                .unwrap();
        assert_eq!(dense_srv.base_resident_bytes(), dense_bytes);
    }

    #[test]
    fn bad_module_and_layer_rejected() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 6);
        assert!(matches!(
            Server::new(&eng, ServeConfig::new("bogus")).unwrap_err().downcast_ref(),
            Some(ServeError::UnknownModule { .. })
        ));
        assert!(matches!(
            Server::new(&eng, ServeConfig::new("q").layer(9)).unwrap_err().downcast_ref(),
            Some(ServeError::LayerOutOfRange { .. })
        ));
    }

    #[test]
    fn untargeted_adapter_serves_the_base_weight() {
        let (eng, mut rng) = engine_with(&[("vonly", AdapterSpec::pissa(2).targets(&["v"]))], 7);
        let mut srv = Server::new(&eng, ServeConfig::new("q")).unwrap();
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let via_adapter = srv.forward(&[Request::new("vonly", x.clone())]).unwrap();
        let via_base = srv.forward(&[Request::base(x)]).unwrap();
        assert_eq!(via_adapter.data, via_base.data);
    }

    #[test]
    fn drift_factors_rejects_untargeted_module() {
        let (mut eng, mut rng) =
            engine_with(&[("vonly", AdapterSpec::pissa(2).targets(&["v"]))], 10);
        let err = crate::serve::drift_factors(&mut eng, "vonly", "q", 0.1, &mut rng).unwrap_err();
        assert!(err.to_string().contains("does not target"), "{err}");
        assert!(crate::serve::drift_factors(&mut eng, "vonly", "v", 0.1, &mut rng).is_ok());
    }

    #[test]
    fn stats_count_hits_and_batches() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 8);
        let mut srv = Server::new(&eng, ServeConfig::new("q").max_batch(4)).unwrap();
        let reqs =
            vec![Request::new("p", vec![0.1; 16]), Request::base(vec![0.2; 16])];
        srv.forward(&reqs).unwrap();
        srv.forward(&reqs).unwrap();
        let s = srv.stats().summary();
        assert_eq!(s.batches, 2);
        assert_eq!(s.requests, 4);
        assert_eq!(srv.stats().hits["p"], 2);
        assert!((s.mean_occupancy - 0.5).abs() < 1e-12);
        srv.reset_stats();
        assert_eq!(srv.stats().batches, 0);
    }
}
