//! The batched multi-adapter single-linear server.
//!
//! PiSSA's deployment promise: many cheap adapters share one frozen dense
//! base, so one host serves many fine-tuned variants at once. The server
//! wraps ONE [`LinearServer`] — the reusable per-linear unit that holds
//! the shared base (dense or NF4, per strategy) and the prepared
//! Appendix-C deltas `(ΔA, ΔB)` against the ORIGINAL dense weight — and
//! adds the request-facing contract: typed validation of every batch,
//! adapter bucketing through the router, and serving stats. A
//! mixed-adapter batch executes as
//!
//! ```text
//!   Y = X·W  +  Σ_groups scatter( (X_g·ΔA_g)·ΔB_g )
//! ```
//!
//! — one shared dense GEMM amortized across every adapter, plus two
//! skinny GEMMs per adapter group, dispatched in parallel via
//! [`crate::util::par::par_map`]. `ΔW` is never materialized. The
//! merge-per-request and dense-per-adapter strategies execute the same
//! `(W, ΔA, ΔB)` snapshot densely and exist as baselines (and as the
//! reference the equivalence property tests compare against); the
//! quantized-base pair swaps the base storage, not the algebra (see
//! [`QuantBase`] and [`LinearServer`]). For the whole adapted forward
//! pass — every layer × all seven linears — see [`super::ModelServer`],
//! which stacks these same units into a pipeline.
//!
//! Determinism: request bucketing is sorted, group corrections are
//! scattered in group order on the caller thread, and every GEMM in the
//! path accumulates in fixed k-order — so serving output is bit-identical
//! for any `PISSA_THREADS` (locked in by `rust/tests/determinism.rs`).

use super::config::{ServeConfig, ServeError, ServeScope};
use super::linear::LinearServer;
pub use super::linear::QuantBase;
use super::router::{bucket, Request};
use super::stats::ServeStats;
use crate::adapter::AdapterEngine;
use crate::linalg::Mat;
use crate::util::timer::Timer;
use anyhow::Result;

/// Batched multi-adapter server over a snapshot of an [`AdapterEngine`].
///
/// Construction validates the [`ServeConfig`] against the engine and
/// copies out everything serving needs (shared base weight — dense or
/// NF4 depending on the strategy — plus per-adapter low-rank deltas), so
/// the engine is free to keep training afterwards; rebuild the server to
/// pick up new factors.
#[derive(Debug)]
pub struct Server {
    cfg: ServeConfig,
    linear: LinearServer,
    stats: ServeStats,
}

impl Server {
    /// Snapshot `engine` under `cfg`. Fails with a typed [`ServeError`]
    /// on a non-single-linear scope, unknown module, out-of-range layer,
    /// quantized adapters under a full-precision strategy, or
    /// rank > min(m, n) on a fused path.
    pub fn new(engine: &AdapterEngine, cfg: ServeConfig) -> Result<Server> {
        if cfg.scope != ServeScope::SingleLinear {
            return Err(ServeError::ScopeMismatch {
                server: "Server",
                scope: cfg.scope.name(),
            }
            .into());
        }
        cfg.validate(engine)?;
        let linear = LinearServer::snapshot(engine, &cfg.module, cfg.layer, cfg.strategy, None)?;
        Ok(Server { cfg, linear, stats: ServeStats::new() })
    }

    pub fn cfg(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Input feature count of the served linear.
    pub fn n_in(&self) -> usize {
        self.linear.n_in()
    }

    /// Output feature count of the served linear.
    pub fn n_out(&self) -> usize {
        self.linear.n_out()
    }

    /// Bytes the shared base keeps resident under this strategy: m·n·4
    /// for a dense store, packed-codes + scales for the NF4 store (the
    /// ≤ 0.35× acceptance bar of `benches/quant_serve.rs`).
    pub fn base_resident_bytes(&self) -> usize {
        self.linear.resident_bytes()
    }

    /// Names the server can route to (snapshot order).
    pub fn adapter_names(&self) -> Vec<&str> {
        self.linear.adapter_names()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats.reset();
    }

    /// Serve one batch: row i of the output is the served linear applied
    /// to `requests[i]` under its adapter. An empty batch yields an empty
    /// (0×n_out) output. Unknown adapters, wrong input widths, and
    /// batches above `max_batch` (the occupancy denominator — route
    /// through a [`super::Scheduler`]) are typed errors; nothing panics
    /// on request data.
    pub fn forward(&mut self, requests: &[Request]) -> Result<Mat> {
        if requests.is_empty() {
            return Ok(Mat::zeros(0, self.n_out()));
        }
        if requests.len() > self.cfg.max_batch {
            return Err(ServeError::BatchTooLarge {
                got: requests.len(),
                max_batch: self.cfg.max_batch,
            }
            .into());
        }
        let want = self.n_in();
        for (i, r) in requests.iter().enumerate() {
            if r.x.len() != want {
                return Err(ServeError::DimMismatch { index: i, got: r.x.len(), want }.into());
            }
            if let Some(name) = &r.adapter {
                if !self.linear.serves(name) {
                    return Err(ServeError::UnknownAdapter {
                        name: name.clone(),
                        have: self.linear.adapter_names().iter().map(|s| s.to_string()).collect(),
                    }
                    .into());
                }
            }
        }
        let timer = Timer::start();
        let groups = bucket(requests);
        let x = gather_all(requests, want);
        let y = self.linear.forward(&x, &groups);
        let adapters: Vec<Option<&str>> = requests.iter().map(|r| r.adapter.as_deref()).collect();
        self.stats.record_batch(&adapters, groups.len(), self.cfg.max_batch, timer.secs());
        Ok(y)
    }
}

/// Pack every request row into a batch×m matrix.
fn gather_all(requests: &[Request], m: usize) -> Mat {
    let mut x = Mat::zeros(requests.len(), m);
    for (i, r) in requests.iter().enumerate() {
        x.row_mut(i).copy_from_slice(&r.x);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::AdapterSpec;
    use crate::linalg::vecmat;
    use crate::model::BaseModel;
    use crate::runtime::ConfigInfo;
    use crate::serve::config::ServeStrategy;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "serve-test".into(),
            kind: "decoder".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            seq_len: 8,
            batch: 4,
            eval_batch: 2,
            n_classes: 0,
            ranks: vec![2],
        }
    }

    fn engine_with(names: &[(&str, AdapterSpec)], seed: u64) -> (AdapterEngine, Rng) {
        let mut rng = Rng::new(seed);
        let base = BaseModel::random(&tiny_cfg(), &mut rng);
        let mut eng = AdapterEngine::new(base);
        for (name, spec) in names {
            eng.attach(name, spec.clone(), &mut rng).unwrap();
        }
        (eng, rng)
    }

    #[test]
    fn empty_batch_serves_empty_output() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 1);
        let mut srv = Server::new(&eng, ServeConfig::new("q")).unwrap();
        let y = srv.forward(&[]).unwrap();
        assert_eq!((y.rows, y.cols), (0, 16));
        assert_eq!(srv.stats().batches, 0);
    }

    #[test]
    fn unknown_adapter_is_a_typed_error() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 2);
        let mut srv = Server::new(&eng, ServeConfig::new("q")).unwrap();
        let err = srv.forward(&[Request::new("ghost", vec![0.0; 16])]).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::UnknownAdapter { name, have }) => {
                assert_eq!(name, "ghost");
                assert_eq!(have, &vec!["p".to_string()]);
            }
            other => panic!("expected UnknownAdapter, got {other:?}"),
        }
    }

    #[test]
    fn dim_mismatch_is_a_typed_error() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 3);
        let mut srv = Server::new(&eng, ServeConfig::new("q")).unwrap();
        let err = srv
            .forward(&[Request::base(vec![0.0; 16]), Request::base(vec![0.0; 5])])
            .unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::DimMismatch { index, got, want }) => {
                assert_eq!((*index, *got, *want), (1, 5, 16));
            }
            other => panic!("expected DimMismatch, got {other:?}"),
        }
    }

    #[test]
    fn oversized_batch_is_a_typed_error() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 9);
        let mut srv = Server::new(&eng, ServeConfig::new("q").max_batch(2)).unwrap();
        let reqs: Vec<Request> = (0..3).map(|_| Request::base(vec![0.0; 16])).collect();
        let err = srv.forward(&reqs).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::BatchTooLarge { got, max_batch }) => {
                assert_eq!((*got, *max_batch), (3, 2));
            }
            other => panic!("expected BatchTooLarge, got {other:?}"),
        }
        // at the ceiling is fine
        assert!(srv.forward(&reqs[..2]).is_ok());
    }

    #[test]
    fn full_model_scope_is_rejected_with_a_typed_error() {
        // The scope invariant is part of the construction contract now:
        // a Server only ever holds a single-linear config.
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 13);
        let err = Server::new(&eng, ServeConfig::full_model()).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::ScopeMismatch { server, scope }) => {
                assert_eq!((*server, *scope), ("Server", "full-model"));
            }
            other => panic!("expected ScopeMismatch, got {other:?}"),
        }
        assert!(err.to_string().contains("ModelServer"), "{err}");
    }

    #[test]
    fn rank_above_min_dim_rejected_at_config_validation() {
        // LoRA attaches fine at any rank (A·B = 0), but serving it as a
        // "low-rank" update of a 16×16 weight at rank 40 is refused.
        let (eng, _) = engine_with(&[("fat", AdapterSpec::lora(40).targets(&["q"]))], 4);
        let err = Server::new(&eng, ServeConfig::new("q")).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::RankTooLarge { rank, m, n, .. }) => {
                assert_eq!((*rank, *m, *n), (40, 16, 16));
            }
            other => panic!("expected RankTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn quantized_adapters_need_a_quantized_base_strategy() {
        // qlora attaches under the exact NF4-fixed-point invariant (A·B=0),
        // so this test never depends on the Table-3 error bound.
        let (eng, _) = engine_with(&[("qp", AdapterSpec::qlora(2))], 5);
        for strategy in ServeStrategy::exact() {
            let err =
                Server::new(&eng, ServeConfig::new("q").strategy(strategy)).unwrap_err();
            assert!(
                matches!(
                    err.downcast_ref::<ServeError>(),
                    Some(ServeError::QuantizedAdapter { .. })
                ),
                "{}: expected QuantizedAdapter, got {err:?}",
                strategy.name()
            );
            assert!(err.to_string().contains("fused-quant"), "message: {err}");
        }
        for strategy in [ServeStrategy::FusedQuant, ServeStrategy::DequantDense] {
            assert!(
                Server::new(&eng, ServeConfig::new("q").strategy(strategy)).is_ok(),
                "{} must accept the quantized adapter",
                strategy.name()
            );
        }
    }

    #[test]
    fn fused_quant_serves_qlora_exactly_and_reports_nf4_residency() {
        // A QLoRA adapter's frozen base IS nf4(W), so serving it from the
        // shared NF4 snapshot reproduces the engine's effective weight up
        // to GEMM association (no quantization mismatch term at all).
        let (mut eng, mut rng) = engine_with(&[("qt", AdapterSpec::qlora(2))], 11);
        crate::serve::drift_factors(&mut eng, "qt", "q", 0.05, &mut rng).unwrap();
        let mut srv =
            Server::new(&eng, ServeConfig::new("q").strategy(ServeStrategy::FusedQuant))
                .unwrap();
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let got = srv.forward(&[Request::new("qt", x.clone())]).unwrap();
        let w_eff = eng.effective_weight_of("qt", "q", 0).unwrap();
        let want = vecmat(&x, &w_eff);
        for (g, w) in got.row(0).iter().zip(&want) {
            assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
        }
        // NF4 residency: 4 bits/value + one f32 scale per 64 values —
        // exactly the engine's quant_base_weight snapshot.
        let dense_bytes = 16 * 16 * 4;
        let nf4 = eng.quant_base_weight("q", 0);
        assert_eq!(srv.base_resident_bytes(), nf4.storage_bytes());
        assert!(
            srv.base_resident_bytes() * 100 <= dense_bytes * 35,
            "nf4 residency {} should be <= 0.35x dense {}",
            srv.base_resident_bytes(),
            dense_bytes
        );
        // The dense strategies report full fp32 residency.
        let dense_srv =
            Server::new(&eng, ServeConfig::new("q").strategy(ServeStrategy::DequantDense))
                .unwrap();
        assert_eq!(dense_srv.base_resident_bytes(), dense_bytes);
    }

    #[test]
    fn bad_module_and_layer_rejected() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 6);
        assert!(matches!(
            Server::new(&eng, ServeConfig::new("bogus")).unwrap_err().downcast_ref(),
            Some(ServeError::UnknownModule { .. })
        ));
        assert!(matches!(
            Server::new(&eng, ServeConfig::new("q").layer(9)).unwrap_err().downcast_ref(),
            Some(ServeError::LayerOutOfRange { .. })
        ));
    }

    #[test]
    fn untargeted_adapter_serves_the_base_weight() {
        let (eng, mut rng) = engine_with(&[("vonly", AdapterSpec::pissa(2).targets(&["v"]))], 7);
        let mut srv = Server::new(&eng, ServeConfig::new("q")).unwrap();
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let via_adapter = srv.forward(&[Request::new("vonly", x.clone())]).unwrap();
        let via_base = srv.forward(&[Request::base(x)]).unwrap();
        assert_eq!(via_adapter.data, via_base.data);
    }

    #[test]
    fn drift_factors_rejects_untargeted_module() {
        let (mut eng, mut rng) =
            engine_with(&[("vonly", AdapterSpec::pissa(2).targets(&["v"]))], 10);
        let err = crate::serve::drift_factors(&mut eng, "vonly", "q", 0.1, &mut rng).unwrap_err();
        assert!(err.to_string().contains("does not target"), "{err}");
        assert!(crate::serve::drift_factors(&mut eng, "vonly", "v", 0.1, &mut rng).is_ok());
    }

    #[test]
    fn stats_count_hits_and_batches() {
        let (eng, _) = engine_with(&[("p", AdapterSpec::pissa(2))], 8);
        let mut srv = Server::new(&eng, ServeConfig::new("q").max_batch(4)).unwrap();
        let reqs =
            vec![Request::new("p", vec![0.1; 16]), Request::base(vec![0.2; 16])];
        srv.forward(&reqs).unwrap();
        srv.forward(&reqs).unwrap();
        let s = srv.stats().summary();
        assert_eq!(s.batches, 2);
        assert_eq!(s.requests, 4);
        assert_eq!(srv.stats().hits["p"], 2);
        assert!((s.mean_occupancy - 0.5).abs() < 1e-12);
        srv.reset_stats();
        assert_eq!(srv.stats().batches, 0);
    }
}
