//! Slot-paged per-layer K/V cache — the state that turns the one-shot
//! `ModelServer` forward into an autoregressive decode engine.
//!
//! One [`KvCache`] serves one `ModelServer`: a fixed number of sequence
//! SLOTS (the continuous-batching concurrency budget) over a shared pool
//! of fixed-size PAGES ([`KV_PAGE`] positions × `d` floats each, where
//! `d` is the cached ROW width — under grouped-query attention that is
//! `n_kv_heads × head_dim`, not `d_model`, so GQA configs shrink every
//! page by the same `n_kv_heads / n_heads` factor).
//! Every `(slot, layer)` pair owns two page lists — keys and values —
//! that grow page-by-page as the sequence extends, so memory tracks the
//! positions actually written, not `slots × max_seq` up front, and pages
//! freed by a retiring sequence are immediately reusable by the next
//! admission (no realloc churn under sustained traffic).
//!
//! Admission is reservation-based: [`KvCache::try_claim`] reserves the
//! WORST-CASE page count for a sequence (its full `prompt + max_new`,
//! exactly as requested — nothing is silently capped) against the byte
//! budget before any token runs, so a sequence that starts decoding can
//! always finish — there is no mid-flight allocation failure. A sequence
//! that could never fit is a typed error: over `max_seq` positions is
//! [`ServeError::SeqTooLong`] (callers that want a shorter generation
//! must clamp `max_new` themselves, as `eval::ServeGenerator` does), and
//! a reservation beyond the whole budget is
//! [`ServeError::CacheBudgetExhausted`]. One that merely has to wait for
//! other sequences to retire is `Ok(None)` (the scheduler keeps it
//! queued, in arrival order).
//!
//! Determinism: the cache is pure storage — rows are written and read as
//! plain `f32` slices in position order, so the attention math over
//! cached rows is the exact arithmetic of attention over freshly
//! computed rows (the bit-identity contract of
//! `rust/tests/serve_equiv.rs`).

use super::config::ServeError;
use anyhow::Result;

/// Positions per cache page. Small enough that short sequences don't
/// over-reserve, large enough that the page table stays tiny.
pub const KV_PAGE: usize = 16;

/// Handle to a claimed sequence slot. Only the [`KvCache`] that issued it
/// can interpret it; it is deliberately NOT `Clone`-proof (plain index)
/// because the scheduler is the single owner of slot lifecycles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotId(pub(crate) usize);

impl SlotId {
    /// Raw slot index (for stats/labels).
    pub fn index(&self) -> usize {
        self.0
    }
}

/// One page list: indices into the shared page pool.
#[derive(Debug, Default, Clone)]
struct PageList {
    pages: Vec<usize>,
    /// Rows written into this list so far.
    rows: usize,
}

/// Per-slot sequence state: a K and a V page list per layer.
#[derive(Debug)]
struct Slot {
    /// Committed positions (advanced once per token, after every layer
    /// has appended its K/V row).
    len: usize,
    /// Worst-case positions this slot reserved pages for.
    reserved_positions: usize,
    k: Vec<PageList>,
    v: Vec<PageList>,
}

/// Slot-paged K/V cache over a shared page pool. See the module docs.
#[derive(Debug)]
pub struct KvCache {
    n_layers: usize,
    d: usize,
    max_seq: usize,
    /// Total pages the byte budget allows across all slots.
    total_pages: usize,
    /// Pages currently reserved by claimed slots (worst case).
    reserved_pages: usize,
    /// All page buffers ever allocated (index = page id). A released
    /// page keeps its buffer; its id moves to `free_ids` for reuse.
    pool: Vec<Vec<f32>>,
    /// Free-list of pool indices.
    free_ids: Vec<usize>,
    slots: Vec<Option<Slot>>,
}

impl KvCache {
    /// Build a cache for `slots` concurrent sequences of up to `max_seq`
    /// positions, `n_layers` layers × `d` floats per cached K/V row,
    /// within `budget_bytes`. `d` is the row width actually cached —
    /// `ServeConfig::kv_dim` (= `n_kv_heads × head_dim`) for a
    /// head-aware server, `d_model` for the legacy single-head layout.
    /// Typed [`ServeError::CacheBudgetExhausted`] if even ONE `max_seq`
    /// sequence cannot fit — such a config could never serve anything.
    pub fn new(
        n_layers: usize,
        d: usize,
        max_seq: usize,
        slots: usize,
        budget_bytes: usize,
    ) -> Result<KvCache> {
        anyhow::ensure!(n_layers >= 1, "KvCache: n_layers must be >= 1");
        anyhow::ensure!(d >= 1, "KvCache: d must be >= 1");
        anyhow::ensure!(max_seq >= 1, "KvCache: max_seq must be >= 1");
        anyhow::ensure!(slots >= 1, "KvCache: slots must be >= 1");
        let page_bytes = KV_PAGE * d * 4;
        let total_pages = budget_bytes / page_bytes;
        let cache = KvCache {
            n_layers,
            d,
            max_seq,
            total_pages,
            reserved_pages: 0,
            pool: Vec::new(),
            free_ids: Vec::new(),
            slots: (0..slots).map(|_| None).collect(),
        };
        let one_seq = cache.pages_for(max_seq);
        if one_seq > total_pages {
            return Err(ServeError::CacheBudgetExhausted {
                needed_bytes: one_seq * page_bytes,
                budget_bytes,
            }
            .into());
        }
        Ok(cache)
    }

    /// Worst-case page reservation for a sequence of `positions`:
    /// K + V lists across every layer.
    pub fn pages_for(&self, positions: usize) -> usize {
        2 * self.n_layers * positions.div_ceil(KV_PAGE)
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Cached K/V row width in floats (`kv_dim` of the serving config).
    pub fn d(&self) -> usize {
        self.d
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Total slot count (the concurrency budget).
    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// Currently unclaimed slots.
    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    /// Bytes held by live page buffers (allocated pages, claimed or
    /// pooled for reuse) — the KV line of the residency breakdown.
    pub fn resident_bytes(&self) -> usize {
        self.pool.iter().map(|p| p.len() * 4).sum()
    }

    /// Bytes the current reservations pin (worst case of every claimed
    /// sequence) — what admission control compares against the budget.
    pub fn reserved_bytes(&self) -> usize {
        self.reserved_pages * KV_PAGE * self.d * 4
    }

    /// Configured byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.total_pages * KV_PAGE * self.d * 4
    }

    /// Try to claim a slot for a sequence of up to `positions` tokens.
    ///
    /// * `Ok(Some(slot))` — claimed, pages reserved.
    /// * `Ok(None)` — nothing wrong with the request, but no free slot
    ///   (or no budget headroom) RIGHT NOW; retry after a retirement.
    /// * `Err(SeqTooLong)` — `positions > max_seq`, can never be served.
    /// * `Err(CacheBudgetExhausted)` — the reservation alone exceeds the
    ///   whole budget, can never be served.
    pub fn try_claim(&mut self, positions: usize) -> Result<Option<SlotId>> {
        if positions > self.max_seq {
            // max_new is unknown at this level; the scheduler re-wraps
            // with the request split. Report the total as prompt.
            return Err(ServeError::SeqTooLong {
                prompt: positions,
                max_new: 0,
                max_seq: self.max_seq,
            }
            .into());
        }
        let need = self.pages_for(positions.max(1));
        if need > self.total_pages {
            return Err(ServeError::CacheBudgetExhausted {
                needed_bytes: need * KV_PAGE * self.d * 4,
                budget_bytes: self.budget_bytes(),
            }
            .into());
        }
        if self.reserved_pages + need > self.total_pages {
            return Ok(None);
        }
        let Some(idx) = self.slots.iter().position(|s| s.is_none()) else {
            return Ok(None);
        };
        self.reserved_pages += need;
        self.slots[idx] = Some(Slot {
            len: 0,
            reserved_positions: positions.max(1),
            k: vec![PageList::default(); self.n_layers],
            v: vec![PageList::default(); self.n_layers],
        });
        Ok(Some(SlotId(idx)))
    }

    /// Release a slot: its pages go back to the pool and its reservation
    /// returns to the budget. Idempotent on unclaimed slots.
    pub fn release(&mut self, slot: SlotId) {
        if let Some(s) = self.slots.get_mut(slot.0).and_then(|s| s.take()) {
            self.reserved_pages -= self.pages_for(s.reserved_positions);
            for list in s.k.into_iter().chain(s.v) {
                self.free_ids.extend(list.pages);
            }
        }
    }

    /// Committed positions of a claimed slot (advanced by
    /// [`KvCache::advance`], i.e. whole tokens, not per-layer rows).
    pub fn len(&self, slot: SlotId) -> usize {
        self.slot_ref(slot).len
    }

    /// True when the slot holds no committed positions yet.
    pub fn is_empty(&self, slot: SlotId) -> bool {
        self.len(slot) == 0
    }

    /// Is this slot currently claimed?
    pub fn is_claimed(&self, slot: SlotId) -> bool {
        self.slots.get(slot.0).map(|s| s.is_some()).unwrap_or(false)
    }

    /// Worst-case positions this claimed slot reserved pages for — the
    /// ceiling the serving layer validates appends against (a typed
    /// [`ServeError::ReservationExceeded`] instead of the append assert).
    pub fn reserved_positions(&self, slot: SlotId) -> usize {
        self.slot_ref(slot).reserved_positions
    }

    /// Rows written to `layer` so far (committed positions plus any rows
    /// appended for the token in flight) — the attention bound during a
    /// prefill/decode layer pass.
    pub fn layer_len(&self, slot: SlotId, layer: usize) -> usize {
        self.slot_ref(slot).k[layer].rows
    }

    fn slot_ref(&self, slot: SlotId) -> &Slot {
        self.slots[slot.0].as_ref().expect("KvCache: slot not claimed")
    }

    /// Append one position's K and V row to `layer`. Panics (debug
    /// contract — the serving layer validates requests) if the slot is
    /// unclaimed or the reservation is exceeded; reservation-based
    /// admission makes the latter unreachable from the scheduler.
    pub fn append(&mut self, slot: SlotId, layer: usize, k_row: &[f32], v_row: &[f32]) {
        assert_eq!(k_row.len(), self.d, "KvCache: k row width");
        assert_eq!(v_row.len(), self.d, "KvCache: v row width");
        let KvCache { d, pool, free_ids, slots, .. } = self;
        let s = slots[slot.0].as_mut().expect("KvCache: slot not claimed");
        for (list, row) in [(&mut s.k[layer], k_row), (&mut s.v[layer], v_row)] {
            assert!(list.rows < s.reserved_positions, "KvCache: append past reservation");
            let page_idx = list.rows / KV_PAGE;
            let within = list.rows % KV_PAGE;
            if page_idx == list.pages.len() {
                // Next page: reuse a freed buffer or grow the pool (the
                // reservation guarantees the budget allows it).
                let id = free_ids.pop().unwrap_or_else(|| {
                    pool.push(vec![0.0f32; KV_PAGE * *d]);
                    pool.len() - 1
                });
                list.pages.push(id);
            }
            let page = &mut pool[list.pages[page_idx]];
            page[within * *d..(within + 1) * *d].copy_from_slice(row);
            list.rows += 1;
        }
    }

    /// Key row at `pos` of `layer` (must be < [`KvCache::layer_len`]).
    #[inline]
    pub fn k_row(&self, slot: SlotId, layer: usize, pos: usize) -> &[f32] {
        self.row(slot, layer, pos, true)
    }

    /// Value row at `pos` of `layer`.
    #[inline]
    pub fn v_row(&self, slot: SlotId, layer: usize, pos: usize) -> &[f32] {
        self.row(slot, layer, pos, false)
    }

    #[inline]
    fn row(&self, slot: SlotId, layer: usize, pos: usize, key: bool) -> &[f32] {
        let s = self.slot_ref(slot);
        let list = if key { &s.k[layer] } else { &s.v[layer] };
        debug_assert!(pos < list.rows, "KvCache: row {pos} past {} written", list.rows);
        let page = &self.pool[list.pages[pos / KV_PAGE]];
        let within = pos % KV_PAGE;
        &page[within * self.d..(within + 1) * self.d]
    }

    /// Iterate the key rows of `(slot, layer)` as contiguous PAGE RUNS:
    /// each yielded span is `rows × d` floats covering up to [`KV_PAGE`]
    /// consecutive positions, in ascending position order, clamped to
    /// the first `n_ctx` positions (a prefill row attends at an `n_ctx`
    /// below what the chunk has already written). The attention kernel
    /// streams these spans instead of calling [`KvCache::k_row`] per
    /// position — one page-table lookup per [`KV_PAGE`] rows, and the
    /// span's rows are physically contiguous, so a whole GQA group can
    /// consume them while they are hot. Reading a run row-by-row yields
    /// the exact `f32` slices the per-position accessors return, so the
    /// streamed arithmetic is the same arithmetic, not merely close.
    #[inline]
    pub fn k_runs(&self, slot: SlotId, layer: usize, n_ctx: usize) -> KvRuns<'_> {
        self.runs(slot, layer, n_ctx, true)
    }

    /// Value-row twin of [`KvCache::k_runs`].
    #[inline]
    pub fn v_runs(&self, slot: SlotId, layer: usize, n_ctx: usize) -> KvRuns<'_> {
        self.runs(slot, layer, n_ctx, false)
    }

    #[inline]
    fn runs(&self, slot: SlotId, layer: usize, n_ctx: usize, key: bool) -> KvRuns<'_> {
        let s = self.slot_ref(slot);
        let list = if key { &s.k[layer] } else { &s.v[layer] };
        debug_assert!(n_ctx <= list.rows, "KvCache: runs over {n_ctx} of {} written", list.rows);
        KvRuns { pool: &self.pool, pages: &list.pages, d: self.d, n_ctx, page_idx: 0 }
    }

    /// Commit `n` positions: every layer must have appended exactly `n`
    /// rows beyond the previous commit (the model's layer loop does).
    pub fn advance(&mut self, slot: SlotId, n: usize) {
        let s = self.slots[slot.0].as_mut().expect("KvCache: slot not claimed");
        for l in 0..self.n_layers {
            debug_assert_eq!(s.k[l].rows, s.len + n, "KvCache: layer {l} K rows out of step");
            debug_assert_eq!(s.v[l].rows, s.len + n, "KvCache: layer {l} V rows out of step");
        }
        s.len += n;
    }
}

/// Iterator over the contiguous page runs of one `(slot, layer)` K or V
/// list (see [`KvCache::k_runs`]). Yields `&[f32]` spans of
/// `run_rows × d` floats, where `run_rows` is [`KV_PAGE`] for every run
/// but the last, which is clamped to the requested `n_ctx`.
#[derive(Debug)]
pub struct KvRuns<'a> {
    pool: &'a [Vec<f32>],
    pages: &'a [usize],
    d: usize,
    n_ctx: usize,
    page_idx: usize,
}

impl<'a> Iterator for KvRuns<'a> {
    type Item = &'a [f32];

    #[inline]
    fn next(&mut self) -> Option<&'a [f32]> {
        let start = self.page_idx * KV_PAGE;
        if start >= self.n_ctx {
            return None;
        }
        let rows = KV_PAGE.min(self.n_ctx - start);
        let page = &self.pool[self.pages[self.page_idx]];
        self.page_idx += 1;
        Some(&page[..rows * self.d])
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.n_ctx.div_ceil(KV_PAGE).saturating_sub(self.page_idx);
        (left, Some(left))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn claim_append_read_roundtrip() {
        let mut c = KvCache::new(2, 4, 32, 2, 1 << 20).unwrap();
        let slot = c.try_claim(5).unwrap().unwrap();
        assert_eq!(c.free_slots(), 1);
        assert!(c.is_empty(slot));
        for pos in 0..3 {
            for l in 0..2 {
                let k: Vec<f32> = (0..4).map(|j| (pos * 10 + l * 100 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                c.append(slot, l, &k, &v);
            }
            c.advance(slot, 1);
        }
        assert_eq!(c.len(slot), 3);
        assert_eq!(c.layer_len(slot, 1), 3);
        assert_eq!(c.k_row(slot, 1, 2), &[120.0, 121.0, 122.0, 123.0]);
        assert_eq!(c.v_row(slot, 0, 0), &[-0.0, -1.0, -2.0, -3.0]);
        c.release(slot);
        assert_eq!(c.free_slots(), 2);
        assert_eq!(c.reserved_bytes(), 0);
    }

    #[test]
    fn pages_are_reused_across_sequences() {
        let mut c = KvCache::new(1, 4, 64, 1, 1 << 20).unwrap();
        let s1 = c.try_claim(40).unwrap().unwrap();
        for _ in 0..40 {
            c.append(s1, 0, &[1.0; 4], &[2.0; 4]);
            c.advance(s1, 1);
        }
        let high_water = c.resident_bytes();
        assert!(high_water > 0);
        c.release(s1);
        // A second, equally long sequence reuses the freed pages: the
        // pool does not grow.
        let s2 = c.try_claim(40).unwrap().unwrap();
        for _ in 0..40 {
            c.append(s2, 0, &[3.0; 4], &[4.0; 4]);
            c.advance(s2, 1);
        }
        assert_eq!(c.resident_bytes(), high_water);
        assert_eq!(c.k_row(s2, 0, 39), &[3.0; 4]);
    }

    #[test]
    fn budget_and_slot_exhaustion_are_wait_states() {
        // Budget fits exactly two 16-position sequences of this shape.
        let page_bytes = KV_PAGE * 4 * 4;
        let mut c = KvCache::new(1, 4, 16, 8, 4 * page_bytes).unwrap();
        let a = c.try_claim(16).unwrap().unwrap();
        let _b = c.try_claim(16).unwrap().unwrap();
        // Third must WAIT (budget), not error.
        assert!(c.try_claim(16).unwrap().is_none());
        c.release(a);
        assert!(c.try_claim(16).unwrap().is_some());
        // No free slot is likewise a wait state.
        let mut tiny = KvCache::new(1, 4, 16, 1, 1 << 20).unwrap();
        let _s = tiny.try_claim(4).unwrap().unwrap();
        assert!(tiny.try_claim(4).unwrap().is_none());
    }

    #[test]
    fn impossible_requests_are_typed_errors() {
        let mut c = KvCache::new(2, 8, 16, 2, 1 << 20).unwrap();
        let err = c.try_claim(17).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::SeqTooLong { max_seq: 16, .. })
        ));
        // A budget below one sequence's reservation can never serve.
        let err = KvCache::new(2, 8, 64, 2, 128).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::CacheBudgetExhausted { .. })
        ));
    }

    #[test]
    fn runs_concatenate_to_rows_at_page_boundaries() {
        // n_ctx straddling KV_PAGE (16): one short run, one exact page,
        // page+1, and two pages + 1 — the shapes the streaming attention
        // kernel must read identically to the per-position accessors.
        let d = 3;
        let mut c = KvCache::new(1, d, 64, 1, 1 << 20).unwrap();
        let slot = c.try_claim(40).unwrap().unwrap();
        for pos in 0..40 {
            let k: Vec<f32> = (0..d).map(|j| (pos * 10 + j) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            c.append(slot, 0, &k, &v);
            c.advance(slot, 1);
        }
        for n_ctx in [1, 15, 16, 17, 33, 40] {
            let mut seen = 0usize;
            for (ri, run) in c.k_runs(slot, 0, n_ctx).enumerate() {
                assert_eq!(run.len() % d, 0);
                let rows = run.len() / d;
                assert!(rows <= KV_PAGE, "run {ri} spans {rows} rows");
                for r in 0..rows {
                    assert_eq!(
                        &run[r * d..(r + 1) * d],
                        c.k_row(slot, 0, seen + r),
                        "n_ctx {n_ctx}: run {ri} row {r} diverged from k_row"
                    );
                }
                seen += rows;
            }
            assert_eq!(seen, n_ctx, "n_ctx {n_ctx}: runs covered {seen} rows");
            let v_total: usize = c.v_runs(slot, 0, n_ctx).map(|run| run.len() / d).sum();
            assert_eq!(v_total, n_ctx);
            // V runs carry the negated rows, confirming K/V lists are
            // independent.
            let first = c.v_runs(slot, 0, n_ctx).next().unwrap();
            assert_eq!(&first[..d], c.v_row(slot, 0, 0));
        }
        // Full pages are exactly KV_PAGE rows; the clamped tail is not.
        let runs: Vec<usize> = c.k_runs(slot, 0, 33).map(|r| r.len() / d).collect();
        assert_eq!(runs, vec![KV_PAGE, KV_PAGE, 1]);
        // n_ctx 0 yields nothing (an empty but claimed slot is legal).
        assert_eq!(c.k_runs(slot, 0, 0).count(), 0);
        assert_eq!(c.k_runs(slot, 0, 33).size_hint(), (3, Some(3)));
    }

    #[test]
    fn reservation_is_worst_case_pages() {
        let c = KvCache::new(3, 4, 64, 2, 1 << 20).unwrap();
        // 17 positions -> 2 pages per list, 2 lists (K, V) x 3 layers.
        assert_eq!(c.pages_for(17), 2 * 3 * 2);
        assert_eq!(c.pages_for(16), 2 * 3);
        assert_eq!(c.pages_for(1), 2 * 3);
    }
}
