//! Request routing: adapter-keyed bucketing and the batching scheduler.
//!
//! A [`Request`] is one inference call against the served linear — an
//! input vector plus the adapter it should run under (`None` = the frozen
//! base). The router groups a batch by adapter in a deterministic
//! (sorted, base-first) order so the server can amortize the shared base
//! GEMM across every group — dense, or the NF4-resident `QuantBase`
//! streamed through the dequant-GEMM — and dispatch the per-adapter
//! low-rank corrections in parallel; the [`Scheduler`] accumulates a
//! request stream into batches of at most `max_batch`.

use std::collections::BTreeMap;
use std::collections::VecDeque;

/// One serving request: an input row for the served linear, tagged with
/// the adapter to run under (`None` = base weights only).
#[derive(Clone, Debug)]
pub struct Request {
    pub adapter: Option<String>,
    pub x: Vec<f32>,
}

impl Request {
    pub fn new(adapter: &str, x: Vec<f32>) -> Request {
        Request { adapter: Some(adapter.to_string()), x }
    }

    /// A request against the frozen base (no adapter correction).
    pub fn base(x: Vec<f32>) -> Request {
        Request { adapter: None, x }
    }
}

/// One adapter bucket of a batch: which rows (original batch positions,
/// in arrival order) run under `adapter`.
#[derive(Clone, Debug, PartialEq)]
pub struct Group {
    pub adapter: Option<String>,
    pub rows: Vec<usize>,
}

/// Bucket a batch by adapter. Deterministic: groups come out base-first
/// then name-sorted, rows within a group in arrival order — so a batch
/// routes identically regardless of thread count or map iteration luck.
pub fn bucket(requests: &[Request]) -> Vec<Group> {
    let mut map: BTreeMap<Option<&str>, Vec<usize>> = BTreeMap::new();
    for (i, r) in requests.iter().enumerate() {
        map.entry(r.adapter.as_deref()).or_default().push(i);
    }
    map.into_iter()
        .map(|(adapter, rows)| Group { adapter: adapter.map(|s| s.to_string()), rows })
        .collect()
}

/// FIFO batching scheduler: submit requests as they arrive, drain them in
/// batches of at most `max_batch` (the occupancy denominator of the
/// serving stats).
#[derive(Debug)]
pub struct Scheduler {
    queue: VecDeque<Request>,
    max_batch: usize,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Scheduler {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        Scheduler { queue: VecDeque::new(), max_batch }
    }

    pub fn submit(&mut self, request: Request) {
        self.queue.push_back(request);
    }

    /// Number of queued requests.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Is a full batch ready?
    pub fn full(&self) -> bool {
        self.queue.len() >= self.max_batch
    }

    /// Pop the next batch (up to `max_batch` requests, FIFO); `None` when
    /// the queue is empty. Callers decide whether to wait for `full()` or
    /// flush a partial batch.
    pub fn take_batch(&mut self) -> Option<Vec<Request>> {
        if self.queue.is_empty() {
            return None;
        }
        let take = self.max_batch.min(self.queue.len());
        Some(self.queue.drain(..take).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_is_sorted_and_order_preserving() {
        let reqs = vec![
            Request::new("b", vec![0.0]),
            Request::base(vec![1.0]),
            Request::new("a", vec![2.0]),
            Request::new("b", vec![3.0]),
            Request::base(vec![4.0]),
        ];
        let groups = bucket(&reqs);
        assert_eq!(groups.len(), 3);
        // base-first, then name-sorted
        assert_eq!(groups[0].adapter, None);
        assert_eq!(groups[0].rows, vec![1, 4]);
        assert_eq!(groups[1].adapter.as_deref(), Some("a"));
        assert_eq!(groups[1].rows, vec![2]);
        assert_eq!(groups[2].adapter.as_deref(), Some("b"));
        assert_eq!(groups[2].rows, vec![0, 3]);
    }

    #[test]
    fn bucket_empty_batch() {
        assert!(bucket(&[]).is_empty());
    }

    #[test]
    fn scheduler_drains_fifo_batches() {
        let mut s = Scheduler::new(3);
        for i in 0..7 {
            s.submit(Request::base(vec![i as f32]));
        }
        assert!(s.full());
        assert_eq!(s.pending(), 7);
        let b1 = s.take_batch().unwrap();
        assert_eq!(b1.len(), 3);
        assert_eq!(b1[0].x, vec![0.0]);
        let b2 = s.take_batch().unwrap();
        assert_eq!(b2.len(), 3);
        let b3 = s.take_batch().unwrap();
        assert_eq!(b3.len(), 1); // partial flush
        assert_eq!(b3[0].x, vec![6.0]);
        assert!(s.take_batch().is_none());
        assert!(!s.full());
    }
}
