//! Request routing: adapter-keyed bucketing and the batching schedulers.
//!
//! Three request shapes flow through the same router. A [`Request`] is
//! one inference call against a served LINEAR — an input vector plus the
//! adapter it should run under (`None` = the frozen base). A
//! [`ModelRequest`] is one call against the whole adapted model — a
//! token id that enters at the embedding and leaves as head logits. A
//! [`SeqRequest`] is one autoregressive GENERATION against the adapted
//! model — prompt tokens plus a generation budget and stop condition —
//! which the [`DecodeScheduler`] turns into a prefill and a stream of
//! per-token [`DecodeRequest`]s. All the step-level shapes implement
//! [`Routable`], so [`bucket`] groups any batch by adapter in a
//! deterministic (sorted, base-first) order — the server amortizes the
//! shared base GEMM(s) across every group and dispatches the per-adapter
//! low-rank corrections in parallel.
//!
//! Two schedulers:
//!
//! * the generic FIFO [`Scheduler`] accumulates a request stream into
//!   batches of at most `max_batch` (the one-shot serving path). Its
//!   ordering contract is strict arrival order: a request submitted
//!   while a batch is in flight drains AFTER everything already queued —
//!   locked in by a regression test below.
//! * the continuous-batching [`DecodeScheduler`] admits queued
//!   [`SeqRequest`]s into KV-cache slots per step, decodes every running
//!   sequence one token per step (adapter-bucketed within the step), and
//!   retires sequences mid-flight the moment they hit their stop
//!   condition — no drain barrier between "batches". Admission is
//!   head-of-line: if the oldest pending request does not fit (slot or
//!   cache budget), nothing behind it is admitted either, so a late
//!   submission can never overtake an earlier one when capacity frees
//!   up.

use super::kvcache::{KvCache, SlotId};
use super::model::ModelServer;
use crate::linalg::Mat;
use crate::util::timer::Timer;
use anyhow::Result;
use std::collections::BTreeMap;
use std::collections::VecDeque;

/// One serving request: an input row for the served linear, tagged with
/// the adapter to run under (`None` = base weights only).
#[derive(Clone, Debug)]
pub struct Request {
    pub adapter: Option<String>,
    pub x: Vec<f32>,
}

impl Request {
    pub fn new(adapter: &str, x: Vec<f32>) -> Request {
        Request { adapter: Some(adapter.to_string()), x }
    }

    /// A request against the frozen base (no adapter correction).
    pub fn base(x: Vec<f32>) -> Request {
        Request { adapter: None, x }
    }
}

/// One whole-model serving request: a token id routed through the full
/// adapted forward pass (embed → every layer's seven linears → head)
/// under `adapter` (`None` = the frozen base model).
#[derive(Clone, Debug)]
pub struct ModelRequest {
    pub adapter: Option<String>,
    pub token: usize,
}

impl ModelRequest {
    pub fn new(adapter: &str, token: usize) -> ModelRequest {
        ModelRequest { adapter: Some(adapter.to_string()), token }
    }

    /// A request against the frozen base model (no adapter corrections).
    pub fn base(token: usize) -> ModelRequest {
        ModelRequest { adapter: None, token }
    }
}

/// Anything the router can bucket: a request that names the adapter it
/// runs under.
pub trait Routable {
    /// Adapter this request runs under (`None` = the frozen base).
    fn adapter(&self) -> Option<&str>;
}

impl Routable for Request {
    fn adapter(&self) -> Option<&str> {
        self.adapter.as_deref()
    }
}

impl Routable for ModelRequest {
    fn adapter(&self) -> Option<&str> {
        self.adapter.as_deref()
    }
}

/// One sequence's contribution to a decode step: the token sampled at
/// the previous step (or by the prefill), the KV-cache slot holding its
/// history, and the adapter it runs under.
#[derive(Clone, Debug)]
pub struct DecodeRequest {
    pub slot: SlotId,
    pub token: usize,
    pub adapter: Option<String>,
}

impl Routable for DecodeRequest {
    fn adapter(&self) -> Option<&str> {
        self.adapter.as_deref()
    }
}

/// One autoregressive generation request: prompt tokens, a cap on
/// generated tokens, and an optional stop token (emitting it ends the
/// sequence; it is included in the output).
#[derive(Clone, Debug)]
pub struct SeqRequest {
    pub adapter: Option<String>,
    pub prompt: Vec<usize>,
    pub max_new: usize,
    pub stop_token: Option<usize>,
}

impl SeqRequest {
    /// A base-model generation (no adapter).
    pub fn base(prompt: Vec<usize>, max_new: usize) -> SeqRequest {
        SeqRequest { adapter: None, prompt, max_new, stop_token: None }
    }

    /// A generation under `adapter`.
    pub fn new(adapter: &str, prompt: Vec<usize>, max_new: usize) -> SeqRequest {
        SeqRequest { adapter: Some(adapter.to_string()), prompt, max_new, stop_token: None }
    }

    /// Stop as soon as `token` is emitted.
    pub fn stop_at(mut self, token: usize) -> SeqRequest {
        self.stop_token = Some(token);
        self
    }
}

/// Identity of a submitted [`SeqRequest`] (monotonic per scheduler).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SeqId(u64);

impl SeqId {
    pub fn raw(&self) -> u64 {
        self.0
    }
}

/// Why a sequence retired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The stop token was emitted (it is the last token of the output).
    StopToken,
    /// The `max_new` generation budget was spent.
    MaxNew,
}

/// A retired sequence: the full token trajectory plus bookkeeping.
#[derive(Clone, Debug)]
pub struct FinishedSeq {
    pub id: SeqId,
    pub adapter: Option<String>,
    /// Prompt length (the first `prompt_len` entries of `tokens`).
    pub prompt_len: usize,
    /// Prompt followed by every generated token, in emission order.
    pub tokens: Vec<usize>,
    pub reason: FinishReason,
}

impl FinishedSeq {
    /// The generated continuation (everything after the prompt).
    pub fn generated(&self) -> &[usize] {
        &self.tokens[self.prompt_len..]
    }
}

/// Deterministic greedy sampling: the first index of the maximum logit
/// (ascending scan, ties break low — identical for any thread count).
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// Observer hooks for [`DecodeScheduler::step_observed`]: token-level
/// progress for streaming front-ends (the HTTP server forwards every
/// `on_token` to the client as a chunk the moment it is sampled) and
/// per-sequence rejection notices. Default impls are no-ops, so an
/// observer only implements what it needs.
pub trait StepObserver {
    /// `token` was sampled for sequence `id`; `first` marks the
    /// prefill-produced token (what TTFT measures).
    fn on_token(&mut self, _id: SeqId, _token: usize, _first: bool) {}
    /// Sequence `id` was removed from the queue as unservable (over
    /// `max_seq`/budget, empty prompt, or a prefill failure such as an
    /// unknown adapter). Only fired by [`DecodeScheduler::step_observed`];
    /// plain [`DecodeScheduler::step`] returns these as errors instead.
    fn on_reject(&mut self, _id: SeqId, _err: &anyhow::Error) {}
}

/// The do-nothing observer behind plain [`DecodeScheduler::step`].
struct NoopObserver;

impl StepObserver for NoopObserver {}

/// What to do with an unservable head-of-queue request.
#[derive(Clone, Copy)]
enum RejectMode {
    /// Return the typed error to the caller (the in-process contract:
    /// queued and running work is untouched, the caller decides).
    Halt,
    /// Notify the observer and keep admitting — one tenant's bad request
    /// must not stall every other connection behind it.
    Notify,
}

struct PendingSeq {
    id: SeqId,
    req: SeqRequest,
    submitted: Timer,
}

struct RunningSeq {
    id: SeqId,
    slot: SlotId,
    adapter: Option<String>,
    tokens: Vec<usize>,
    prompt_len: usize,
    max_new: usize,
    stop_token: Option<usize>,
    /// Last sampled token — the next decode step's input.
    next: usize,
    generated: usize,
    /// Prompt positions committed to the KV cache so far. Equal to
    /// `prompt_len` once prefill is complete; under chunked prefill a
    /// sequence sits in `running` mid-prefill (holding its slot, which
    /// was claimed for the full worst case at admission) and is excluded
    /// from decode until it catches up.
    prefilled: usize,
    /// Submission timer, carried so chunked prefill can record TTFT at
    /// the FINAL chunk (when the first token actually exists), not at
    /// admission.
    submitted: Timer,
}

impl RunningSeq {
    fn finish_reason(&self) -> Option<FinishReason> {
        if self.stop_token == Some(self.next) {
            Some(FinishReason::StopToken)
        } else if self.generated >= self.max_new {
            Some(FinishReason::MaxNew)
        } else {
            None
        }
    }

    fn into_finished(self, reason: FinishReason) -> FinishedSeq {
        FinishedSeq {
            id: self.id,
            adapter: self.adapter,
            prompt_len: self.prompt_len,
            tokens: self.tokens,
            reason,
        }
    }
}

/// Continuous-batching decode scheduler over a `ModelServer` + [`KvCache`].
///
/// Unlike the drain-everything [`Scheduler`], sequences are admitted and
/// retired MID-FLIGHT: every [`DecodeScheduler::step`] first admits as
/// many queued sequences as slots/budget allow (in strict arrival order
/// — head-of-line blocking, never reordering), prefilling each and
/// recording its time-to-first-token, then runs ONE decode step over
/// every running sequence (adapter-bucketed inside the server), greedily
/// samples, and retires whatever finished — freeing slots for the very
/// next step's admissions. The slot budget is the cache's slot count
/// ([`crate::serve::ServeConfig::slots`]).
///
/// With [`crate::serve::ServeConfig::prefill_chunk`] `> 0`, admission
/// only CLAIMS the slot; the prompt is then committed one chunk per
/// step (between admission and decode) while already-running sequences
/// keep decoding — a long prompt no longer stalls the whole batch, and
/// TTFT is recorded when the final chunk produces the first token. The
/// per-sequence token trajectory is bit-identical either way: prefill
/// continuation is exact, so the final chunk's logits equal the
/// one-shot prefill's.
pub struct DecodeScheduler {
    next_id: u64,
    pending: VecDeque<PendingSeq>,
    running: Vec<RunningSeq>,
    /// Sequences that retired but have not been handed to the caller
    /// yet. Retirements are pushed here the moment they happen, so an
    /// error mid-step (or mid-`run`) never drops a finished result —
    /// recover them with [`DecodeScheduler::drain_finished`].
    done: Vec<FinishedSeq>,
    /// Reused next-token logits buffer for the decode hot loop —
    /// [`ModelServer::decode_step_into`] resizes it in place, so the
    /// steady-state step allocates nothing for logits.
    logits: Mat,
}

impl Default for DecodeScheduler {
    fn default() -> Self {
        DecodeScheduler::new()
    }
}

impl DecodeScheduler {
    pub fn new() -> DecodeScheduler {
        DecodeScheduler {
            next_id: 0,
            pending: VecDeque::new(),
            running: Vec::new(),
            done: Vec::new(),
            logits: Mat::zeros(0, 0),
        }
    }

    /// Queue a sequence. Validation against a concrete server/cache
    /// happens at admission (inside [`DecodeScheduler::step`]), where an
    /// impossible request — over `max_seq`, or a KV reservation beyond
    /// the whole cache budget — pops off the queue as a typed error.
    pub fn submit(&mut self, req: SeqRequest) -> SeqId {
        let id = SeqId(self.next_id);
        self.next_id += 1;
        self.pending.push_back(PendingSeq { id, req, submitted: Timer::start() });
        id
    }

    /// Queued (not yet admitted) sequences.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Sequences currently holding a slot.
    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Nothing queued and nothing in flight.
    pub fn idle(&self) -> bool {
        self.pending.is_empty() && self.running.is_empty()
    }

    /// Adapter names the scheduler's current working set references —
    /// every queued AND in-flight sequence's adapter, deduplicated and
    /// sorted. This is the attach-on-miss hook: callers hand it to
    /// `TierManager::ensure_resident` BEFORE each step, so pending
    /// sequences for registered-but-evicted adapters are promoted at the
    /// step boundary (never inside the decode loop) and in-flight
    /// sequences' adapters are pinned against eviction.
    pub fn active_adapters(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .pending
            .iter()
            .filter_map(|p| p.req.adapter.clone())
            .chain(self.running.iter().filter_map(|r| r.adapter.clone()))
            .collect();
        names.sort();
        names.dedup();
        names
    }

    /// Retired sequences not yet returned by [`DecodeScheduler::step`] /
    /// [`DecodeScheduler::run`] — non-empty only after one of them
    /// errored mid-flight (completed work is buffered, never dropped).
    pub fn drain_finished(&mut self) -> Vec<FinishedSeq> {
        std::mem::take(&mut self.done)
    }

    /// One continuous-batching step: admit (strict FIFO) → decode one
    /// token for every running sequence → retire. Returns the sequences
    /// that finished during this step. An impossible head-of-queue
    /// request (over `max_seq` / over the whole cache budget) is removed
    /// from the queue and returned as the typed error; queued and running
    /// work is untouched, the scheduler remains usable, and anything that
    /// retired before the error is preserved for
    /// [`DecodeScheduler::drain_finished`].
    pub fn step(
        &mut self,
        server: &mut ModelServer,
        cache: &mut KvCache,
    ) -> Result<Vec<FinishedSeq>> {
        self.step_impl(server, cache, &mut NoopObserver, RejectMode::Halt)
    }

    /// [`DecodeScheduler::step`] with token-level observation and
    /// non-halting rejection — the serving-front-end variant. Every
    /// sampled token is reported through `obs.on_token` the moment it
    /// exists (streaming), and an unservable head-of-queue request is
    /// reported through `obs.on_reject` and DROPPED, after which
    /// admission continues with the next queued sequence — one tenant's
    /// impossible request never stalls or kills the batch loop. A
    /// returned error therefore means the step itself failed (a decode
    /// error affecting every running sequence), not a bad request.
    pub fn step_observed(
        &mut self,
        server: &mut ModelServer,
        cache: &mut KvCache,
        obs: &mut dyn StepObserver,
    ) -> Result<Vec<FinishedSeq>> {
        self.step_impl(server, cache, obs, RejectMode::Notify)
    }

    fn step_impl(
        &mut self,
        server: &mut ModelServer,
        cache: &mut KvCache,
        obs: &mut dyn StepObserver,
        mode: RejectMode,
    ) -> Result<Vec<FinishedSeq>> {
        let chunk = server.cfg().prefill_chunk;
        // Admission: strict arrival order. If the head does not fit RIGHT
        // NOW, stop — admitting anything younger would reorder.
        while let Some(head) = self.pending.front() {
            let total = head.req.prompt.len() + head.req.max_new;
            let claimed = match cache.try_claim(total.max(1)) {
                Ok(Some(slot)) => slot,
                Ok(None) => break, // wait for a retirement; order preserved
                Err(e) => {
                    let p = self.pending.pop_front().expect("head exists");
                    let err = e.context(format!(
                        "seq {:?} ({} prompt + {} max_new) can never be admitted",
                        p.id,
                        p.req.prompt.len(),
                        p.req.max_new
                    ));
                    match mode {
                        RejectMode::Halt => return Err(err),
                        RejectMode::Notify => {
                            obs.on_reject(p.id, &err);
                            continue;
                        }
                    }
                }
            };
            let p = self.pending.pop_front().expect("head exists");
            if p.req.prompt.is_empty() {
                cache.release(claimed);
                let err = anyhow::anyhow!(
                    "seq {:?}: empty prompt (a generation needs >= 1 token)",
                    p.id
                );
                match mode {
                    RejectMode::Halt => return Err(err),
                    RejectMode::Notify => {
                        obs.on_reject(p.id, &err);
                        continue;
                    }
                }
            }
            if chunk > 0 {
                // Chunked admission: claim the slot (done above, for the
                // FULL worst case) but defer all prefill work to the
                // chunk-advance phase, which interleaves it with decode
                // steps of already-running sequences.
                let prompt_len = p.req.prompt.len();
                self.running.push(RunningSeq {
                    id: p.id,
                    slot: claimed,
                    adapter: p.req.adapter,
                    tokens: p.req.prompt,
                    prompt_len,
                    max_new: p.req.max_new,
                    stop_token: p.req.stop_token,
                    next: 0,
                    generated: 0,
                    prefilled: 0,
                    submitted: p.submitted,
                });
                continue;
            }
            let logits =
                match server.prefill(cache, claimed, p.req.adapter.as_deref(), &p.req.prompt) {
                    Ok(l) => l,
                    Err(e) => {
                        cache.release(claimed);
                        match mode {
                            RejectMode::Halt => return Err(e),
                            RejectMode::Notify => {
                                obs.on_reject(p.id, &e);
                                continue;
                            }
                        }
                    }
                };
            server.record_ttft(p.submitted.secs());
            let mut run = RunningSeq {
                id: p.id,
                slot: claimed,
                adapter: p.req.adapter,
                tokens: p.req.prompt,
                prompt_len: 0,
                max_new: p.req.max_new,
                stop_token: p.req.stop_token,
                next: 0,
                generated: 0,
                prefilled: 0,
                submitted: p.submitted,
            };
            run.prompt_len = run.tokens.len();
            run.prefilled = run.prompt_len;
            if run.max_new == 0 {
                cache.release(claimed);
                self.done.push(run.into_finished(FinishReason::MaxNew));
                continue;
            }
            // The prefill's last-position logits ARE the first generated
            // token (this is what TTFT measures).
            run.next = argmax(&logits);
            run.tokens.push(run.next);
            run.generated = 1;
            obs.on_token(run.id, run.next, true);
            if let Some(reason) = run.finish_reason() {
                cache.release(claimed);
                self.done.push(run.into_finished(reason));
            } else {
                self.running.push(run);
            }
        }

        // Chunk-advance: every mid-prefill sequence commits ONE more
        // chunk of its prompt before this step's decode, so a long
        // prompt's prefill is spread across steps instead of stalling
        // the whole batch at admission.
        if chunk > 0 {
            self.advance_prefills(server, cache, obs, mode, chunk)?;
        }

        // One decode step over every running sequence whose prefill is
        // complete (mid-prefill sequences keep their slot but are not
        // decodable yet — their next token comes from the final chunk).
        let reqs: Vec<DecodeRequest> = self
            .running
            .iter()
            .filter(|r| r.prefilled >= r.prompt_len)
            .map(|r| DecodeRequest {
                slot: r.slot,
                token: r.next,
                adapter: r.adapter.clone(),
            })
            .collect();
        if !reqs.is_empty() {
            server.decode_step_into(cache, &reqs, &mut self.logits)?;
            let mut still = Vec::with_capacity(self.running.len());
            let mut row = 0;
            for mut run in std::mem::take(&mut self.running) {
                if run.prefilled < run.prompt_len {
                    still.push(run);
                    continue;
                }
                run.next = argmax(self.logits.row(row));
                row += 1;
                run.tokens.push(run.next);
                run.generated += 1;
                obs.on_token(run.id, run.next, false);
                if let Some(reason) = run.finish_reason() {
                    cache.release(run.slot);
                    self.done.push(run.into_finished(reason));
                } else {
                    still.push(run);
                }
            }
            self.running = still;
        }
        Ok(std::mem::take(&mut self.done))
    }

    /// Advance every mid-prefill sequence by one `chunk`-sized slice of
    /// its prompt (in admission order). A sequence reaching the end of
    /// its prompt produces its first token here — TTFT is recorded at
    /// that moment, and the prefill's last-position logits are greedily
    /// sampled exactly as one-shot admission would. A chunk that fails
    /// (unknown adapter, cache mismatch) releases the slot and is
    /// handled per `mode`, like an admission-time prefill failure.
    fn advance_prefills(
        &mut self,
        server: &mut ModelServer,
        cache: &mut KvCache,
        obs: &mut dyn StepObserver,
        mode: RejectMode,
        chunk: usize,
    ) -> Result<()> {
        let mut i = 0;
        while i < self.running.len() {
            let run = &self.running[i];
            if run.prefilled >= run.prompt_len {
                i += 1;
                continue;
            }
            let end = (run.prefilled + chunk).min(run.prompt_len);
            let res = server.prefill(
                cache,
                run.slot,
                run.adapter.as_deref(),
                &run.tokens[run.prefilled..end],
            );
            let logits = match res {
                Ok(l) => l,
                Err(e) => {
                    let run = self.running.remove(i);
                    cache.release(run.slot);
                    let err = e.context(format!(
                        "seq {:?}: chunked prefill failed at prompt position {}",
                        run.id, run.prefilled
                    ));
                    match mode {
                        RejectMode::Halt => return Err(err),
                        RejectMode::Notify => {
                            obs.on_reject(run.id, &err);
                            continue;
                        }
                    }
                }
            };
            let run = &mut self.running[i];
            run.prefilled = end;
            if run.prefilled < run.prompt_len {
                i += 1;
                continue;
            }
            // Final chunk: the first generated token exists NOW.
            server.record_ttft(run.submitted.secs());
            if run.max_new == 0 {
                let run = self.running.remove(i);
                cache.release(run.slot);
                self.done.push(run.into_finished(FinishReason::MaxNew));
                continue;
            }
            run.next = argmax(&logits);
            run.tokens.push(run.next);
            run.generated = 1;
            obs.on_token(run.id, run.next, true);
            if let Some(reason) = run.finish_reason() {
                let run = self.running.remove(i);
                cache.release(run.slot);
                self.done.push(run.into_finished(reason));
                continue;
            }
            i += 1;
        }
        Ok(())
    }

    /// Drive [`DecodeScheduler::step`] until every submitted sequence has
    /// retired; finished sequences come back in retirement order (ties
    /// within a step in submission order). If a step errors, everything
    /// that had already retired goes back into the buffer (in order) so
    /// the caller can recover it with [`DecodeScheduler::drain_finished`]
    /// — a mid-run failure never loses completed sequences.
    pub fn run(
        &mut self,
        server: &mut ModelServer,
        cache: &mut KvCache,
    ) -> Result<Vec<FinishedSeq>> {
        let mut all = Vec::new();
        while !self.idle() {
            match self.step(server, cache) {
                Ok(f) => all.extend(f),
                Err(e) => {
                    // `done` holds anything retired during the errored
                    // step; earlier steps' results go back in front.
                    let mut keep = all;
                    keep.append(&mut self.done);
                    self.done = keep;
                    return Err(e);
                }
            }
        }
        Ok(all)
    }

    /// Convenience for callers that want prompt-order results: run to
    /// completion and sort by submission id.
    pub fn run_sorted(
        &mut self,
        server: &mut ModelServer,
        cache: &mut KvCache,
    ) -> Result<Vec<FinishedSeq>> {
        let mut all = self.run(server, cache)?;
        all.sort_by_key(|f| f.id);
        Ok(all)
    }
}

/// One adapter bucket of a batch: which rows (original batch positions,
/// in arrival order) run under `adapter`.
#[derive(Clone, Debug, PartialEq)]
pub struct Group {
    pub adapter: Option<String>,
    pub rows: Vec<usize>,
}

/// Bucket a batch by adapter. Deterministic: groups come out base-first
/// then name-sorted, rows within a group in arrival order — so a batch
/// routes identically regardless of thread count or map iteration luck.
pub fn bucket<R: Routable>(requests: &[R]) -> Vec<Group> {
    let mut map: BTreeMap<Option<&str>, Vec<usize>> = BTreeMap::new();
    for (i, r) in requests.iter().enumerate() {
        map.entry(r.adapter()).or_default().push(i);
    }
    map.into_iter()
        .map(|(adapter, rows)| Group { adapter: adapter.map(|s| s.to_string()), rows })
        .collect()
}

/// FIFO batching scheduler: submit requests as they arrive, drain them in
/// batches of at most `max_batch` (the occupancy denominator of the
/// serving stats). Generic over the request shape — the same scheduler
/// feeds a single-linear `Server` (`Scheduler<Request>`, the default)
/// and a whole-model `ModelServer` (`Scheduler<ModelRequest>`).
#[derive(Debug)]
pub struct Scheduler<R = Request> {
    queue: VecDeque<R>,
    max_batch: usize,
}

impl<R> Scheduler<R> {
    pub fn new(max_batch: usize) -> Scheduler<R> {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        Scheduler { queue: VecDeque::new(), max_batch }
    }

    pub fn submit(&mut self, request: R) {
        self.queue.push_back(request);
    }

    /// Number of queued requests.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Is a full batch ready?
    pub fn full(&self) -> bool {
        self.queue.len() >= self.max_batch
    }

    /// Pop the next batch (up to `max_batch` requests, FIFO); `None` when
    /// the queue is empty. Callers decide whether to wait for `full()` or
    /// flush a partial batch.
    pub fn take_batch(&mut self) -> Option<Vec<R>> {
        if self.queue.is_empty() {
            return None;
        }
        let take = self.max_batch.min(self.queue.len());
        Some(self.queue.drain(..take).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_is_sorted_and_order_preserving() {
        let reqs = vec![
            Request::new("b", vec![0.0]),
            Request::base(vec![1.0]),
            Request::new("a", vec![2.0]),
            Request::new("b", vec![3.0]),
            Request::base(vec![4.0]),
        ];
        let groups = bucket(&reqs);
        assert_eq!(groups.len(), 3);
        // base-first, then name-sorted
        assert_eq!(groups[0].adapter, None);
        assert_eq!(groups[0].rows, vec![1, 4]);
        assert_eq!(groups[1].adapter.as_deref(), Some("a"));
        assert_eq!(groups[1].rows, vec![2]);
        assert_eq!(groups[2].adapter.as_deref(), Some("b"));
        assert_eq!(groups[2].rows, vec![0, 3]);
    }

    #[test]
    fn bucket_empty_batch() {
        assert!(bucket::<Request>(&[]).is_empty());
    }

    #[test]
    fn model_requests_bucket_identically_to_linear_requests() {
        let linear = vec![
            Request::new("b", vec![0.0]),
            Request::base(vec![0.0]),
            Request::new("a", vec![0.0]),
        ];
        let model =
            vec![ModelRequest::new("b", 0), ModelRequest::base(1), ModelRequest::new("a", 2)];
        assert_eq!(bucket(&linear), bucket(&model));
    }

    #[test]
    fn scheduler_drains_fifo_batches() {
        let mut s = Scheduler::new(3);
        for i in 0..7 {
            s.submit(Request::base(vec![i as f32]));
        }
        assert!(s.full());
        assert_eq!(s.pending(), 7);
        let b1 = s.take_batch().unwrap();
        assert_eq!(b1.len(), 3);
        assert_eq!(b1[0].x, vec![0.0]);
        let b2 = s.take_batch().unwrap();
        assert_eq!(b2.len(), 3);
        let b3 = s.take_batch().unwrap();
        assert_eq!(b3.len(), 1); // partial flush
        assert_eq!(b3[0].x, vec![6.0]);
        assert!(s.take_batch().is_none());
        assert!(!s.full());
    }

    #[test]
    fn take_batch_never_reorders_mid_flight_submissions() {
        // Regression for the starvation/ordering edge: requests submitted
        // WHILE earlier batches are in flight must drain strictly after
        // everything already pending — capacity freeing up (a new
        // take_batch) must never let a late arrival overtake.
        let mut s = Scheduler::new(2);
        for i in 0..3 {
            s.submit(Request::base(vec![i as f32]));
        }
        let b1 = s.take_batch().unwrap(); // 0, 1 in flight
        assert_eq!(b1.iter().map(|r| r.x[0] as usize).collect::<Vec<_>>(), vec![0, 1]);
        // Mid-flight submissions land behind the already-pending 2.
        s.submit(Request::base(vec![3.0]));
        s.submit(Request::base(vec![4.0]));
        let b2 = s.take_batch().unwrap();
        assert_eq!(b2.iter().map(|r| r.x[0] as usize).collect::<Vec<_>>(), vec![2, 3]);
        s.submit(Request::base(vec![5.0]));
        let b3 = s.take_batch().unwrap();
        assert_eq!(b3.iter().map(|r| r.x[0] as usize).collect::<Vec<_>>(), vec![4, 5]);
        assert!(s.take_batch().is_none());
    }

    #[test]
    fn seq_request_builders_and_finished_accessors() {
        let r = SeqRequest::new("t", vec![1, 2, 3], 4).stop_at(9);
        assert_eq!(r.adapter.as_deref(), Some("t"));
        assert_eq!(r.stop_token, Some(9));
        let b = SeqRequest::base(vec![5], 2);
        assert_eq!(b.adapter, None);
        let f = FinishedSeq {
            id: SeqId(3),
            adapter: None,
            prompt_len: 2,
            tokens: vec![1, 2, 7, 9],
            reason: FinishReason::StopToken,
        };
        assert_eq!(f.generated(), &[7, 9]);
        assert_eq!(f.id.raw(), 3);
    }

    #[test]
    fn argmax_is_first_max_ascending() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -5.0]), 1);
    }

    #[test]
    fn argmax_is_nan_safe() {
        // NaN comparisons are false, so NaNs never win and never panic:
        // the scan just skips them (the contract toy::Mlp::accuracy now
        // shares).
        assert_eq!(argmax(&[f32::NAN, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[2.0, f32::NAN, 3.0]), 2);
        // All-NaN (or empty) input degrades to index 0 rather than
        // aborting the decode step.
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn scheduler_is_generic_over_model_requests() {
        let mut s: Scheduler<ModelRequest> = Scheduler::new(2);
        s.submit(ModelRequest::new("t", 3));
        s.submit(ModelRequest::base(5));
        s.submit(ModelRequest::base(7));
        let b = s.take_batch().unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].token, 3);
        assert_eq!(b[0].adapter.as_deref(), Some("t"));
        assert_eq!(s.take_batch().unwrap()[0].token, 7);
    }
}
