//! Request routing: adapter-keyed bucketing and the batching scheduler.
//!
//! Two request shapes flow through the same router. A [`Request`] is one
//! inference call against a served LINEAR — an input vector plus the
//! adapter it should run under (`None` = the frozen base). A
//! [`ModelRequest`] is one call against the whole adapted model — a
//! token id that enters at the embedding and leaves as head logits.
//! Both implement [`Routable`], so [`bucket`] groups any batch by
//! adapter in a deterministic (sorted, base-first) order — the server
//! amortizes the shared base GEMM(s) across every group (dense, or the
//! NF4-resident `QuantBase` streamed through the dequant-GEMM) and
//! dispatches the per-adapter low-rank corrections in parallel — and the
//! generic [`Scheduler`] accumulates either request stream into batches
//! of at most `max_batch`.

use std::collections::BTreeMap;
use std::collections::VecDeque;

/// One serving request: an input row for the served linear, tagged with
/// the adapter to run under (`None` = base weights only).
#[derive(Clone, Debug)]
pub struct Request {
    pub adapter: Option<String>,
    pub x: Vec<f32>,
}

impl Request {
    pub fn new(adapter: &str, x: Vec<f32>) -> Request {
        Request { adapter: Some(adapter.to_string()), x }
    }

    /// A request against the frozen base (no adapter correction).
    pub fn base(x: Vec<f32>) -> Request {
        Request { adapter: None, x }
    }
}

/// One whole-model serving request: a token id routed through the full
/// adapted forward pass (embed → every layer's seven linears → head)
/// under `adapter` (`None` = the frozen base model).
#[derive(Clone, Debug)]
pub struct ModelRequest {
    pub adapter: Option<String>,
    pub token: usize,
}

impl ModelRequest {
    pub fn new(adapter: &str, token: usize) -> ModelRequest {
        ModelRequest { adapter: Some(adapter.to_string()), token }
    }

    /// A request against the frozen base model (no adapter corrections).
    pub fn base(token: usize) -> ModelRequest {
        ModelRequest { adapter: None, token }
    }
}

/// Anything the router can bucket: a request that names the adapter it
/// runs under.
pub trait Routable {
    /// Adapter this request runs under (`None` = the frozen base).
    fn adapter(&self) -> Option<&str>;
}

impl Routable for Request {
    fn adapter(&self) -> Option<&str> {
        self.adapter.as_deref()
    }
}

impl Routable for ModelRequest {
    fn adapter(&self) -> Option<&str> {
        self.adapter.as_deref()
    }
}

/// One adapter bucket of a batch: which rows (original batch positions,
/// in arrival order) run under `adapter`.
#[derive(Clone, Debug, PartialEq)]
pub struct Group {
    pub adapter: Option<String>,
    pub rows: Vec<usize>,
}

/// Bucket a batch by adapter. Deterministic: groups come out base-first
/// then name-sorted, rows within a group in arrival order — so a batch
/// routes identically regardless of thread count or map iteration luck.
pub fn bucket<R: Routable>(requests: &[R]) -> Vec<Group> {
    let mut map: BTreeMap<Option<&str>, Vec<usize>> = BTreeMap::new();
    for (i, r) in requests.iter().enumerate() {
        map.entry(r.adapter()).or_default().push(i);
    }
    map.into_iter()
        .map(|(adapter, rows)| Group { adapter: adapter.map(|s| s.to_string()), rows })
        .collect()
}

/// FIFO batching scheduler: submit requests as they arrive, drain them in
/// batches of at most `max_batch` (the occupancy denominator of the
/// serving stats). Generic over the request shape — the same scheduler
/// feeds a single-linear `Server` (`Scheduler<Request>`, the default)
/// and a whole-model `ModelServer` (`Scheduler<ModelRequest>`).
#[derive(Debug)]
pub struct Scheduler<R = Request> {
    queue: VecDeque<R>,
    max_batch: usize,
}

impl<R> Scheduler<R> {
    pub fn new(max_batch: usize) -> Scheduler<R> {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        Scheduler { queue: VecDeque::new(), max_batch }
    }

    pub fn submit(&mut self, request: R) {
        self.queue.push_back(request);
    }

    /// Number of queued requests.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Is a full batch ready?
    pub fn full(&self) -> bool {
        self.queue.len() >= self.max_batch
    }

    /// Pop the next batch (up to `max_batch` requests, FIFO); `None` when
    /// the queue is empty. Callers decide whether to wait for `full()` or
    /// flush a partial batch.
    pub fn take_batch(&mut self) -> Option<Vec<R>> {
        if self.queue.is_empty() {
            return None;
        }
        let take = self.max_batch.min(self.queue.len());
        Some(self.queue.drain(..take).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_is_sorted_and_order_preserving() {
        let reqs = vec![
            Request::new("b", vec![0.0]),
            Request::base(vec![1.0]),
            Request::new("a", vec![2.0]),
            Request::new("b", vec![3.0]),
            Request::base(vec![4.0]),
        ];
        let groups = bucket(&reqs);
        assert_eq!(groups.len(), 3);
        // base-first, then name-sorted
        assert_eq!(groups[0].adapter, None);
        assert_eq!(groups[0].rows, vec![1, 4]);
        assert_eq!(groups[1].adapter.as_deref(), Some("a"));
        assert_eq!(groups[1].rows, vec![2]);
        assert_eq!(groups[2].adapter.as_deref(), Some("b"));
        assert_eq!(groups[2].rows, vec![0, 3]);
    }

    #[test]
    fn bucket_empty_batch() {
        assert!(bucket::<Request>(&[]).is_empty());
    }

    #[test]
    fn model_requests_bucket_identically_to_linear_requests() {
        let linear = vec![
            Request::new("b", vec![0.0]),
            Request::base(vec![0.0]),
            Request::new("a", vec![0.0]),
        ];
        let model =
            vec![ModelRequest::new("b", 0), ModelRequest::base(1), ModelRequest::new("a", 2)];
        assert_eq!(bucket(&linear), bucket(&model));
    }

    #[test]
    fn scheduler_drains_fifo_batches() {
        let mut s = Scheduler::new(3);
        for i in 0..7 {
            s.submit(Request::base(vec![i as f32]));
        }
        assert!(s.full());
        assert_eq!(s.pending(), 7);
        let b1 = s.take_batch().unwrap();
        assert_eq!(b1.len(), 3);
        assert_eq!(b1[0].x, vec![0.0]);
        let b2 = s.take_batch().unwrap();
        assert_eq!(b2.len(), 3);
        let b3 = s.take_batch().unwrap();
        assert_eq!(b3.len(), 1); // partial flush
        assert_eq!(b3[0].x, vec![6.0]);
        assert!(s.take_batch().is_none());
        assert!(!s.full());
    }

    #[test]
    fn scheduler_is_generic_over_model_requests() {
        let mut s: Scheduler<ModelRequest> = Scheduler::new(2);
        s.submit(ModelRequest::new("t", 3));
        s.submit(ModelRequest::base(5));
        s.submit(ModelRequest::base(7));
        let b = s.take_batch().unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].token, 3);
        assert_eq!(b[0].adapter.as_deref(), Some("t"));
        assert_eq!(s.take_batch().unwrap()[0].token, 7);
    }
}
