//! `ModelServer` — the whole-model serving pipeline.
//!
//! PiSSA adapts EVERY targeted linear of EVERY layer (the paper
//! fine-tunes q/k/v/o/gate/up/down across all layers), so serving one
//! linear at a time never exercises the actual deployment shape. The
//! `ModelServer` snapshots the full [`AdapterEngine`] base — embedding
//! table, per-layer norms, and `n_layers × 7` [`LinearServer`] units,
//! head — and runs a mixed-adapter batch of [`ModelRequest`]s end to
//! end:
//!
//! ```text
//!   x   = embed[token]                                  (batch × d)
//!   for each layer l:
//!     h  = rms_norm(x, attn_norm[l])
//!     qb, kb, vb = q(h), k(h), v(h)                      (adapted linears)
//!     x += o( σ(⟨qb_i, kb_i⟩/√d) · vb )                  (adapted linear)
//!     h  = rms_norm(x, mlp_norm[l])
//!     x += down( silu(gate(h)) ⊙ up(h) )                 (adapted linears)
//!   logits = rms_norm(x, final_norm) · head              (batch × vocab)
//! ```
//!
//! Each of the seven per-layer projections is a full mixed-adapter
//! [`LinearServer`] execution — shared base GEMM (dense or the streamed
//! NF4 dequant-GEMM) plus per-adapter low-rank corrections — so one call
//! routes the batch through all `L × 7` adapted linears. The attention
//! mixing is the rust-native single-position analog of the L2 model's
//! block (`python/compile/model.py`): requests are independent rows, so
//! the softmax over one position's score degenerates and is replaced by
//! the deterministic per-row gate `σ(⟨q, k⟩/√d)` — every projection stays
//! load-bearing (a q/k-only adapter still changes the output), and the
//! whole forward is a fixed-order f32 computation, bit-identical for any
//! `PISSA_THREADS`.
//!
//! The KV-cached sequence path ([`ModelServer::prefill`] /
//! [`ModelServer::decode_step`]) runs REAL causal attention over the
//! cached context, head-aware and position-aware: the `d_model`-wide
//! q/k/v projections are split into `n_heads` slices of `head_dim`
//! features, rotary position embeddings rotate q and k in place at each
//! token's absolute position (`rope_theta > 0`, via the inverse-
//! frequency table precomputed once in the head layout), only the first
//! `kv_dim = n_kv_heads × head_dim` features of k/v are cached (grouped-
//! query attention: query head `h` reads cached head `h / (n_heads /
//! n_kv_heads)`), and the page-streaming kernel behind
//! [`attn_streamed_into`] computes a per-head causal softmax
//! `softmax(q_h·K_g^T / √head_dim)·V_g`. Every stage keeps the fixed
//! f32 evaluation order, so incremental decode stays bit-identical to a
//! full-prefill recompute and to any thread count. The legacy default
//! (`n_heads = 1`, `rope_theta = 0`) degenerates to exactly the PR 5
//! arithmetic: one head of width `d_model`, no rotation, same 1/√d scale.
//!
//! The attention hot path is built for memory bandwidth: the cache's
//! [`KvCache::k_runs`]/[`KvCache::v_runs`] iterators hand the kernel
//! whole [`crate::serve::KV_PAGE`]-position pages, and the kernel is
//! group-major — each GQA group's K/V pages are streamed ONCE while all
//! `group = n_heads / n_kv_heads` query heads consume the hot span.
//! Attention work is partitioned over (sequence, kv-group) items via
//! [`crate::util::par::par_items`], so a small batch of long sequences
//! still spreads across the whole pool; every item writes a disjoint
//! `ao` slice and a disjoint scratch stride, so thread count cannot
//! change any reduction order. Per-head arithmetic stays one mul-add
//! per element in ascending position/feature order — the exact chains
//! of the position-at-a-time reference kernel, pinned bit-identical by
//! `rust/tests/determinism.rs`.
//!
//! Activation buffers ping-pong: the hidden state `x`, the norm/attn
//! scratch `h`, the three projection buffers, and the two MLP-width
//! buffers live in a server-owned `DecodeScratch` reused across calls
//! (and across all layers within a call) — `LinearServer::forward_into`
//! overwrites them in place, the flat attention score scratch is reused
//! per layer, and [`ModelServer::decode_step_into`] writes logits into a
//! caller-owned buffer, so a steady decode loop performs ZERO heap
//! allocations per step on the shared path (debug-asserted by
//! fingerprinting every scratch buffer's pointer and capacity).
//!
//! Stats and residency aggregate across the whole pipeline:
//! [`ModelServer::base_resident_bytes`] sums all `L × 7` base stores
//! (under `fused-quant` every linear streams from a shared per-module
//! [`crate::quant::Nf4Stack`], keeping the entire base NF4-resident),
//! and [`ModelServer::resident_breakdown`] reports the per-module table.

use super::config::{ServeConfig, ServeError, ServeScope};
use super::kvcache::{KvCache, SlotId};
use super::linear::LinearServer;
use super::router::{bucket, DecodeRequest, Group, ModelRequest};
use super::stats::{ResidentBreakdown, ServeStats};
use crate::adapter::AdapterEngine;
use crate::linalg::{matmul, matmul_into, vecmat, Mat};
use crate::model::LINEARS;
use crate::util::par::par_items;
use crate::util::timer::Timer;
use anyhow::Result;

/// RMS-norm epsilon (matches the L2 model's `rms_norm`).
pub const RMS_EPS: f32 = 1e-6;

// Indices into the per-layer linear array, in `LINEARS` order.
const Q: usize = 0;
const K: usize = 1;
const V: usize = 2;
const O: usize = 3;
const GATE: usize = 4;
const UP: usize = 5;
const DOWN: usize = 6;

/// Attention head layout of the decode path, precomputed at server
/// construction from the validated config. The RoPE inverse-frequency
/// table is evaluated ONCE here ([`rope_inv_freq`]) — bitwise the same
/// `theta.powf(-2i/head_dim)` values the rotation used to recompute per
/// pair per token, now looked up instead.
#[derive(Debug, Clone)]
struct HeadLayout {
    /// Query heads (d_model = n_heads × head_dim).
    n_heads: usize,
    /// Cached K/V heads; query head `h` reads KV head
    /// `h / (n_heads / n_kv_heads)`.
    n_kv_heads: usize,
    /// Features per head.
    head_dim: usize,
    /// Cached row width: `n_kv_heads × head_dim` (the K/V projections
    /// compute full d_model rows, but only this prefix is cached under
    /// GQA — the grouped heads never read past it).
    kv_dim: usize,
    /// Per-pair RoPE inverse frequencies (`head_dim / 2` entries); empty
    /// when `rope_theta == 0` (rotation disabled, the legacy path).
    inv_freq: Vec<f32>,
}

/// Reusable buffers for the KV-cached serving paths, owned by the server
/// and threaded through [`ModelServer::prefill`] /
/// [`ModelServer::decode_step_into`] via `mem::take`: the ping-ponged
/// activation Mats, the flat attention score scratch (one disjoint
/// stride per (sequence, kv-group) item), the per-request position
/// list, and the final-norm row. `prepare` only reallocates when a call
/// needs MORE capacity than any call before it, so a steady decode loop
/// reaches a fixed point after its first step and performs zero heap
/// allocations per step on the shared path — debug-asserted in
/// `decode_step_into` by fingerprinting every buffer.
#[derive(Debug, Default)]
struct DecodeScratch {
    x: Mat,
    h: Mat,
    qb: Mat,
    kb: Mat,
    vb: Mat,
    ao: Mat,
    gate: Mat,
    up: Mat,
    /// Flat attention scratch: one `stride`-sized span per (sequence,
    /// kv-group) item holding that item's `group × n_ctx` scores plus
    /// `group` inverse softmax sums.
    attn: Vec<f32>,
    /// Per-request absolute positions for the current step.
    pos: Vec<usize>,
    /// Final-norm row for the prefill last-position logits.
    hf: Vec<f32>,
}

impl DecodeScratch {
    fn prepare(&mut self, rows: usize, d: usize, f: usize, attn_len: usize) {
        resize_mat(&mut self.x, rows, d);
        resize_mat(&mut self.h, rows, d);
        resize_mat(&mut self.qb, rows, d);
        resize_mat(&mut self.kb, rows, d);
        resize_mat(&mut self.vb, rows, d);
        resize_mat(&mut self.ao, rows, d);
        resize_mat(&mut self.gate, rows, f);
        resize_mat(&mut self.up, rows, f);
        self.attn.resize(attn_len, 0.0);
        self.hf.resize(d, 0.0);
    }

    /// (pointer, capacity) of every owned buffer — unchanged across a
    /// decode step ⇔ the step allocated nothing on the shared path.
    #[cfg(debug_assertions)]
    fn fingerprint(&self) -> [(usize, usize); 11] {
        [
            (self.x.data.as_ptr() as usize, self.x.data.capacity()),
            (self.h.data.as_ptr() as usize, self.h.data.capacity()),
            (self.qb.data.as_ptr() as usize, self.qb.data.capacity()),
            (self.kb.data.as_ptr() as usize, self.kb.data.capacity()),
            (self.vb.data.as_ptr() as usize, self.vb.data.capacity()),
            (self.ao.data.as_ptr() as usize, self.ao.data.capacity()),
            (self.gate.data.as_ptr() as usize, self.gate.data.capacity()),
            (self.up.data.as_ptr() as usize, self.up.data.capacity()),
            (self.attn.as_ptr() as usize, self.attn.capacity()),
            (self.pos.as_ptr() as usize, self.pos.capacity()),
            (self.hf.as_ptr() as usize, self.hf.capacity()),
        ]
    }
}

/// Resize a [`Mat`] in place without giving up its allocation: the shape
/// fields are rewritten and `data` is length-adjusted (zero-filling
/// growth, truncating shrink — so `.data`-wide iterators stay exactly
/// `rows × cols` long and capacity only ever ratchets up).
fn resize_mat(m: &mut Mat, rows: usize, cols: usize) {
    m.rows = rows;
    m.cols = cols;
    m.data.resize(rows * cols, 0.0);
}

/// A raw `*mut f32` the parallel attention closures may carry across
/// threads. SAFETY contract: every use hands each (sequence, kv-group)
/// item a DISJOINT region of the pointee (enforced by the callers' index
/// arithmetic over fixed strides), and [`par_items`] blocks until every
/// item has run, so no write outlives the buffer the pointer was minted
/// from.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Whole-model batched multi-adapter server over a snapshot of an
/// [`AdapterEngine`]: embed → `n_layers` adapted blocks → head.
///
/// Like [`super::Server`], construction snapshots everything (the engine
/// is free to keep training); unlike it, the snapshot spans every layer
/// and all seven linears, plus the frozen scaffold (embedding, norms,
/// head).
#[derive(Debug)]
pub struct ModelServer {
    cfg: ServeConfig,
    /// `n_layers × 7` per-linear units, layer-major (`layer * 7 + module`).
    linears: Vec<LinearServer>,
    /// Token embedding table (vocab × d).
    embed: Mat,
    /// Output head (d × vocab for decoders, d × n_classes for encoders).
    head: Mat,
    /// Per-layer RMS-norm gains (each of length d).
    attn_norm: Vec<Vec<f32>>,
    mlp_norm: Vec<Vec<f32>>,
    final_norm: Vec<f32>,
    n_layers: usize,
    d_model: usize,
    d_ff: usize,
    heads: HeadLayout,
    stats: ServeStats,
    /// Reused activation/score buffers for the KV-cached paths.
    scratch: DecodeScratch,
}

impl ModelServer {
    /// Snapshot the whole engine under a [`ServeScope::FullModel`]
    /// config. Validation covers every `(module, layer)` linear: a typed
    /// [`ServeError`] on quantized adapters under a full-precision
    /// strategy or rank > min(m, n) anywhere in the stack.
    pub fn new(engine: &AdapterEngine, cfg: ServeConfig) -> Result<ModelServer> {
        if cfg.scope != ServeScope::FullModel {
            return Err(ServeError::ScopeMismatch {
                server: "ModelServer",
                scope: cfg.scope.name(),
            }
            .into());
        }
        cfg.validate(engine)?;
        let base = engine.base();
        let n_layers = base.n_layers();
        let embed = base.scaffold["embed"].as_mat();
        let head = if base.encoder {
            base.scaffold["cls_base"].as_mat()
        } else {
            base.scaffold["lm_head"].as_mat()
        };
        let attn_gains = base.scaffold["attn_norm"].as_mat();
        let mlp_gains = base.scaffold["mlp_norm"].as_mat();
        let attn_norm: Vec<Vec<f32>> = (0..n_layers).map(|l| attn_gains.row(l).to_vec()).collect();
        let mlp_norm: Vec<Vec<f32>> = (0..n_layers).map(|l| mlp_gains.row(l).to_vec()).collect();
        let final_norm = base.scaffold["final_norm"].data.clone();
        // Under the quantized-base strategies every layer of a module
        // streams from ONE shared NF4 snapshot of that module's stack —
        // quantized once here, never duplicated per linear.
        let stacks: Option<Vec<crate::quant::Nf4Stack>> = if cfg.strategy.quantized_base() {
            Some(LINEARS.iter().map(|m| engine.quant_base_stack(m)).collect())
        } else {
            None
        };
        let mut linears = Vec::with_capacity(n_layers * LINEARS.len());
        for layer in 0..n_layers {
            for (mi, module) in LINEARS.iter().enumerate() {
                let shared = stacks.as_ref().map(|s| s[mi].layer(layer));
                linears.push(LinearServer::snapshot(
                    engine,
                    module,
                    layer,
                    cfg.strategy,
                    shared,
                )?);
            }
        }
        let d_model = embed.cols;
        let d_ff = linears[GATE].n_out();
        let head_dim = d_model / cfg.n_heads;
        let heads = HeadLayout {
            n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_kv_heads,
            head_dim,
            kv_dim: cfg.n_kv_heads * head_dim,
            inv_freq: rope_inv_freq(cfg.rope_theta as f32, head_dim),
        };
        Ok(ModelServer {
            cfg,
            linears,
            embed,
            head,
            attn_norm,
            mlp_norm,
            final_norm,
            n_layers,
            d_model,
            d_ff,
            heads,
            stats: ServeStats::new(),
            scratch: DecodeScratch::default(),
        })
    }

    fn linear(&self, layer: usize, module: usize) -> &LinearServer {
        &self.linears[layer * LINEARS.len() + module]
    }

    pub fn cfg(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// Cached K/V row width: `n_kv_heads × head_dim` floats per position
    /// per layer — what [`ModelServer::new_cache`] sizes pages by. Equals
    /// `d_model` under the default single-head layout.
    pub fn kv_dim(&self) -> usize {
        self.heads.kv_dim
    }

    /// Embedding-table size — the valid token-id range of requests.
    pub fn vocab(&self) -> usize {
        self.embed.rows
    }

    /// Output width of the head (vocab for decoders, n_classes for
    /// encoders).
    pub fn n_out(&self) -> usize {
        self.head.cols
    }

    /// Names the server can route to (snapshot order).
    pub fn adapter_names(&self) -> Vec<&str> {
        self.linears[0].adapter_names()
    }

    /// Is `name` routable right now? (Runtime set: promotions add
    /// names, demotions remove them.)
    pub fn serves_adapter(&self, name: &str) -> bool {
        self.linears[0].serves(name)
    }

    /// Register one engine adapter's prepared deltas across all
    /// `n_layers × 7` linears at runtime — the promotion path of the
    /// residency tier manager. Runs the same per-adapter servability
    /// checks construction applies to the whole registry, and computes
    /// every delta before touching any linear, so a failure leaves the
    /// server unchanged. The shared base stores are untouched: promotion
    /// never rebuilds the server.
    pub fn add_adapter(&mut self, engine: &AdapterEngine, name: &str) -> Result<()> {
        anyhow::ensure!(
            !self.serves_adapter(name),
            "adapter '{name}' is already served; remove it first"
        );
        self.cfg.validate_adapter(engine, name)?;
        let mut deltas = Vec::with_capacity(self.linears.len());
        for layer in 0..self.n_layers {
            for module in LINEARS {
                deltas.push(engine.serve_delta(name, module, layer)?);
            }
        }
        for (lin, delta) in self.linears.iter_mut().zip(deltas) {
            lin.add_group(name, delta);
        }
        Ok(())
    }

    /// Drop one adapter's prepared deltas from every linear (the
    /// demotion path). Typed error when the name is not served — the
    /// caller's view is stale.
    pub fn remove_adapter(&mut self, name: &str) -> Result<()> {
        if !self.serves_adapter(name) {
            return Err(ServeError::UnknownAdapter {
                name: name.to_string(),
                have: self.adapter_names().iter().map(|s| s.to_string()).collect(),
            }
            .into());
        }
        for lin in &mut self.linears {
            lin.remove_group(name);
        }
        Ok(())
    }

    /// f32 bytes of one adapter's prepared serving deltas across all
    /// linears — the server-side share of the residency budget.
    pub fn adapter_delta_bytes(&self, name: &str) -> usize {
        self.linears.iter().map(|l| l.delta_bytes(name)).sum()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats.reset();
    }

    /// Aggregate bytes the shared base keeps resident across ALL
    /// `n_layers × 7` served linears (the ≤ 0.35×-of-dense acceptance
    /// bar of `benches/model_serve.rs` under `fused-quant`). The frozen
    /// scaffold (embed/norms/head) is strategy-independent and excluded.
    pub fn base_resident_bytes(&self) -> usize {
        self.linears.iter().map(|l| l.resident_bytes()).sum()
    }

    /// What the same linears would hold resident as dense fp32 — the
    /// denominator of the residency ratio.
    pub fn dense_base_bytes(&self) -> usize {
        self.linears.iter().map(|l| l.n_in() * l.n_out() * 4).sum()
    }

    /// Per-module residency table plus the decode path's live KV-cache
    /// bytes — what a decode server actually pins.
    pub fn resident_breakdown_with_cache(&self, cache: &KvCache) -> ResidentBreakdown {
        self.resident_breakdown().with_kv_bytes(cache.resident_bytes())
    }

    /// Per-module residency table (bytes summed over layers).
    pub fn resident_breakdown(&self) -> ResidentBreakdown {
        let per_module = LINEARS
            .iter()
            .enumerate()
            .map(|(mi, module)| {
                let bytes: usize =
                    (0..self.n_layers).map(|l| self.linear(l, mi).resident_bytes()).sum();
                (module.to_string(), bytes)
            })
            .collect();
        ResidentBreakdown::new(per_module, self.dense_base_bytes())
    }

    /// Serve one batch end to end: row i of the logits is the full
    /// adapted forward of `requests[i]`'s token under its adapter. An
    /// empty batch yields an empty (0×n_out) output. Unknown adapters,
    /// out-of-range tokens, and batches above `max_batch` are typed
    /// errors; nothing panics on request data.
    pub fn forward(&mut self, requests: &[ModelRequest]) -> Result<Mat> {
        if requests.is_empty() {
            return Ok(Mat::zeros(0, self.n_out()));
        }
        if requests.len() > self.cfg.max_batch {
            return Err(ServeError::BatchTooLarge {
                got: requests.len(),
                max_batch: self.cfg.max_batch,
            }
            .into());
        }
        for (i, r) in requests.iter().enumerate() {
            if r.token >= self.vocab() {
                return Err(ServeError::TokenOutOfRange {
                    index: i,
                    token: r.token,
                    vocab: self.vocab(),
                }
                .into());
            }
            if let Some(name) = &r.adapter {
                if !self.linears[0].serves(name) {
                    return Err(ServeError::UnknownAdapter {
                        name: name.clone(),
                        have: self.adapter_names().iter().map(|s| s.to_string()).collect(),
                    }
                    .into());
                }
            }
        }
        let timer = Timer::start();
        let groups = bucket(requests);
        let (b, d, f) = (requests.len(), self.d_model, self.d_ff);

        // Activation buffers, allocated once and ping-ponged across every
        // layer (forward_into / *_into overwrite them in place).
        let mut x = Mat::zeros(b, d); // hidden state (residual stream)
        let mut h = Mat::zeros(b, d); // norm output / attention output
        let mut qb = Mat::zeros(b, d);
        let mut kb = Mat::zeros(b, d);
        let mut vb = Mat::zeros(b, d);
        let mut gate = Mat::zeros(b, f);
        let mut up = Mat::zeros(b, f);

        for (i, r) in requests.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.embed.row(r.token));
        }
        let scale = 1.0 / (d as f32).sqrt();
        for l in 0..self.n_layers {
            // h = rms_norm(x); attention projections of h.
            rms_norm_into(&x, &self.attn_norm[l], &mut h);
            self.linear(l, Q).forward_into(&h, &groups, &mut qb);
            self.linear(l, K).forward_into(&h, &groups, &mut kb);
            self.linear(l, V).forward_into(&h, &groups, &mut vb);
            // Single-position attention: per row, gate v by σ(⟨q,k⟩/√d).
            for i in 0..b {
                let dot: f32 =
                    qb.row(i).iter().zip(kb.row(i)).map(|(qv, kv)| qv * kv).sum();
                let g = sigmoid(dot * scale);
                for v in vb.row_mut(i) {
                    *v *= g;
                }
            }
            self.linear(l, O).forward_into(&vb, &groups, &mut h);
            x.add_assign(&h); // residual

            // SwiGLU MLP on the normed residual.
            rms_norm_into(&x, &self.mlp_norm[l], &mut h);
            self.linear(l, GATE).forward_into(&h, &groups, &mut gate);
            self.linear(l, UP).forward_into(&h, &groups, &mut up);
            for (gv, uv) in gate.data.iter_mut().zip(&up.data) {
                *gv = silu(*gv) * uv;
            }
            self.linear(l, DOWN).forward_into(&gate, &groups, &mut h);
            x.add_assign(&h); // residual
        }
        rms_norm_into(&x, &self.final_norm, &mut h);
        let logits = matmul(&h, &self.head);

        let adapters: Vec<Option<&str>> = requests.iter().map(|r| r.adapter.as_deref()).collect();
        self.stats.record_batch(&adapters, groups.len(), self.cfg.max_batch, timer.secs());
        Ok(logits)
    }

    /// Build a [`KvCache`] sized for this server from the config's decode
    /// knobs (`max_seq` × `decode_slots` within `kv_budget_bytes`). Rows
    /// are [`ModelServer::kv_dim`] floats wide, so a GQA config
    /// (`n_kv_heads < n_heads`) shrinks every cached position by
    /// `n_kv_heads / n_heads` relative to the single-head layout.
    pub fn new_cache(&self) -> Result<KvCache> {
        KvCache::new(
            self.n_layers,
            self.heads.kv_dim,
            self.cfg.max_seq,
            self.cfg.decode_slots,
            self.cfg.kv_budget_bytes,
        )
    }

    /// Record one sequence's time-to-first-token (measured by the
    /// scheduler from submission to its prefill completing).
    pub fn record_ttft(&mut self, secs: f64) {
        self.stats.record_ttft(secs);
    }

    /// Record one sequence rejected at admission (keyed by a short
    /// reason such as `"unknown_adapter"`); surfaces in `/metrics`.
    pub fn record_rejection(&mut self, reason: &str) {
        self.stats.record_rejection(reason);
    }

    /// Prefill: run `tokens` (one sequence, one adapter) through the full
    /// pipeline with REAL causal attention, writing every layer's K/V
    /// rows into `slot` of `cache`, and return the last position's logits
    /// (the distribution over the first generated token).
    ///
    /// Unlike [`ModelServer::forward`]'s degenerate single-position gate,
    /// position `i` here attends over positions `0..=i` with a true
    /// per-head causal softmax (`n_heads` slices of `head_dim`, GQA
    /// sharing of the cached `kv_dim` prefix, RoPE rotation of q/k at
    /// the row's absolute position when `rope_theta > 0` — fixed-order
    /// f32 throughout, matching the decode path exactly). Appending to a
    /// non-empty slot continues the sequence from its committed length —
    /// every rotation and score depends only on absolute position, so a
    /// prefill may itself be split into chunks without changing any bit
    /// of the result.
    ///
    /// All `T` positions run as one single-group batch through each of
    /// the `L × 7` linears (the activation buffers are allocated once and
    /// ping-ponged across layers, exactly like `forward`).
    pub fn prefill(
        &mut self,
        cache: &mut KvCache,
        slot: SlotId,
        adapter: Option<&str>,
        tokens: &[usize],
    ) -> Result<Vec<f32>> {
        self.check_cache(cache)?;
        anyhow::ensure!(!tokens.is_empty(), "prefill: empty token sequence");
        if !cache.is_claimed(slot) {
            return Err(ServeError::BadSlot { slot: slot.index(), detail: "not claimed" }.into());
        }
        let start = cache.len(slot);
        if start + tokens.len() > cache.max_seq() {
            return Err(ServeError::SeqTooLong {
                prompt: start + tokens.len(),
                max_new: 0,
                max_seq: cache.max_seq(),
            }
            .into());
        }
        // Validate against the slot's reservation BEFORE any append: a
        // prompt longer than the claim used to trip the KvCache append
        // assert mid-layer; now it is a typed error and the cache is
        // untouched.
        let reserved = cache.reserved_positions(slot);
        if start + tokens.len() > reserved {
            return Err(ServeError::ReservationExceeded {
                slot: slot.index(),
                reserved,
                needed: start + tokens.len(),
            }
            .into());
        }
        for (i, &t) in tokens.iter().enumerate() {
            if t >= self.vocab() {
                return Err(ServeError::TokenOutOfRange {
                    index: i,
                    token: t,
                    vocab: self.vocab(),
                }
                .into());
            }
        }
        if let Some(name) = adapter {
            if !self.linears[0].serves(name) {
                return Err(ServeError::UnknownAdapter {
                    name: name.to_string(),
                    have: self.adapter_names().iter().map(|s| s.to_string()).collect(),
                }
                .into());
            }
        }
        let timer = Timer::start();
        let (t, d, f) = (tokens.len(), self.d_model, self.d_ff);
        let groups =
            vec![Group { adapter: adapter.map(|s| s.to_string()), rows: (0..t).collect() }];

        let n_kv = self.heads.n_kv_heads;
        let group = self.heads.n_heads / n_kv;
        let ghd = group * self.heads.head_dim;
        // One attention item per (row, kv-group); strides are sized for
        // the chunk's LAST row (`n_ctx = start + t`), so every item's
        // `group × n_ctx + group` span fits its stride.
        let n_items = t * n_kv;
        let stride = group * (start + t) + group;
        let mut s = std::mem::take(&mut self.scratch);
        s.prepare(t, d, f, n_items * stride);

        for (i, &tok) in tokens.iter().enumerate() {
            s.x.row_mut(i).copy_from_slice(self.embed.row(tok));
        }
        for l in 0..self.n_layers {
            rms_norm_into(&s.x, &self.attn_norm[l], &mut s.h);
            self.linear(l, Q).forward_into(&s.h, &groups, &mut s.qb);
            self.linear(l, K).forward_into(&s.h, &groups, &mut s.kb);
            self.linear(l, V).forward_into(&s.h, &groups, &mut s.vb);
            // Rotate Q (every head) and the cached K prefix (the
            // n_kv_heads heads that survive into the cache) at each row's
            // ABSOLUTE position — `start + i` here, `cache.len()` on the
            // decode path — so an incremental continuation computes the
            // exact same rotation a from-scratch prefill would.
            for i in 0..t {
                let pos = start + i;
                let (nh, hd) = (self.heads.n_heads, self.heads.head_dim);
                rope_rotate(s.qb.row_mut(i), nh, hd, pos, &self.heads.inv_freq);
                let k = &mut s.kb.row_mut(i)[..self.heads.kv_dim];
                rope_rotate(k, n_kv, hd, pos, &self.heads.inv_freq);
            }
            // Write this chunk's K/V rows (only the kv_dim prefix is ever
            // read under GQA), then attend reading from the cache — the
            // same loads the decode path performs, so the arithmetic is
            // shared, not merely equivalent.
            let kv_dim = self.heads.kv_dim;
            for i in 0..t {
                cache.append(slot, l, &s.kb.row(i)[..kv_dim], &s.vb.row(i)[..kv_dim]);
            }
            {
                let cache = &*cache;
                let (nh, qb) = (self.heads.n_heads, &s.qb);
                let ao_ptr = SendPtr(s.ao.data.as_mut_ptr());
                let attn_ptr = SendPtr(s.attn.as_mut_ptr());
                par_items(n_items, |item| {
                    let i = item / n_kv;
                    let g = item % n_kv;
                    let n_ctx = start + i + 1;
                    // SAFETY: item (i, g) owns `ao[i*d + g*ghd ..][..ghd]`
                    // and `attn[item*stride ..][..group*n_ctx + group]`
                    // (which fits the stride since `n_ctx <= start + t`);
                    // regions are disjoint across items, and `par_items`
                    // returns only after every item has run.
                    let (out, sc) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(ao_ptr.0.add(i * d + g * ghd), ghd),
                            std::slice::from_raw_parts_mut(
                                attn_ptr.0.add(item * stride),
                                group * n_ctx + group,
                            ),
                        )
                    };
                    attn_group_streamed(cache, slot, l, qb.row(i), n_ctx, nh, n_kv, g, sc, out);
                });
            }
            self.linear(l, O).forward_into(&s.ao, &groups, &mut s.h);
            s.x.add_assign(&s.h);

            rms_norm_into(&s.x, &self.mlp_norm[l], &mut s.h);
            self.linear(l, GATE).forward_into(&s.h, &groups, &mut s.gate);
            self.linear(l, UP).forward_into(&s.h, &groups, &mut s.up);
            for (gv, uv) in s.gate.data.iter_mut().zip(&s.up.data) {
                *gv = silu(*gv) * uv;
            }
            self.linear(l, DOWN).forward_into(&s.gate, &groups, &mut s.h);
            s.x.add_assign(&s.h);
        }
        cache.advance(slot, t);
        // Only the last position's logits matter for generation: one
        // final-norm row + one vecmat instead of a T × vocab head GEMM.
        rms_norm_row_into(s.x.row(t - 1), &self.final_norm, &mut s.hf);
        let logits = vecmat(&s.hf, &self.head);
        self.scratch = s;
        self.stats.record_prefill(adapter, t, timer.secs());
        Ok(logits)
    }

    /// One decode step: each request contributes ONE new token whose
    /// position attends over its slot's cached K/V history (plus itself),
    /// and row `i` of the returned logits is request `i`'s next-token
    /// distribution. Mixed adapters batch together — the step is bucketed
    /// by adapter exactly like `forward`, sharing the base GEMMs across
    /// the whole step — and a single-request step takes the
    /// [`LinearServer::forward_row_into`] fast path (sequential `vecmat`
    /// sweeps, no batch-GEMM setup), which is bit-identical to the
    /// batched path by construction.
    ///
    /// Incremental contract (locked in by `rust/tests/serve_equiv.rs`):
    /// prefill(p) followed by decode steps for tokens `p..n` yields, at
    /// every step, EXACTLY the logits a fresh full prefill of the same
    /// `n` tokens would — bit for bit, for every serving strategy.
    ///
    /// Allocates a fresh logits matrix per call; steady-state decode
    /// loops should prefer [`ModelServer::decode_step_into`], which
    /// writes into a caller-owned buffer (the scheduler's hot loop does).
    pub fn decode_step(&mut self, cache: &mut KvCache, requests: &[DecodeRequest]) -> Result<Mat> {
        let mut logits = Mat::zeros(0, 0);
        self.decode_step_into(cache, requests, &mut logits)?;
        Ok(logits)
    }

    /// [`ModelServer::decode_step`] writing row `i`'s next-token logits
    /// into the caller-owned `logits` matrix (resized in place to
    /// `batch × n_out`, reallocating only when capacity must grow).
    /// Combined with the server-owned scratch this makes the steady
    /// decode loop allocation-free on the shared path.
    pub fn decode_step_into(
        &mut self,
        cache: &mut KvCache,
        requests: &[DecodeRequest],
        logits: &mut Mat,
    ) -> Result<()> {
        self.check_cache(cache)?;
        if requests.is_empty() {
            resize_mat(logits, 0, self.n_out());
            return Ok(());
        }
        for (i, r) in requests.iter().enumerate() {
            if !cache.is_claimed(r.slot) {
                return Err(
                    ServeError::BadSlot { slot: r.slot.index(), detail: "not claimed" }.into()
                );
            }
            if requests[..i].iter().any(|p| p.slot == r.slot) {
                return Err(ServeError::BadSlot {
                    slot: r.slot.index(),
                    detail: "appears twice in one decode step",
                }
                .into());
            }
            if cache.len(r.slot) + 1 > cache.max_seq() {
                return Err(ServeError::SeqTooLong {
                    prompt: cache.len(r.slot) + 1,
                    max_new: 0,
                    max_seq: cache.max_seq(),
                }
                .into());
            }
            let reserved = cache.reserved_positions(r.slot);
            if cache.len(r.slot) + 1 > reserved {
                return Err(ServeError::ReservationExceeded {
                    slot: r.slot.index(),
                    reserved,
                    needed: cache.len(r.slot) + 1,
                }
                .into());
            }
            if r.token >= self.vocab() {
                return Err(ServeError::TokenOutOfRange {
                    index: i,
                    token: r.token,
                    vocab: self.vocab(),
                }
                .into());
            }
            if let Some(name) = &r.adapter {
                if !self.linears[0].serves(name) {
                    return Err(ServeError::UnknownAdapter {
                        name: name.clone(),
                        have: self.adapter_names().iter().map(|s| s.to_string()).collect(),
                    }
                    .into());
                }
            }
        }
        let timer = Timer::start();
        let (b, d, f) = (requests.len(), self.d_model, self.d_ff);
        let groups = bucket(requests);

        let n_kv = self.heads.n_kv_heads;
        let group = self.heads.n_heads / n_kv;
        let ghd = group * self.heads.head_dim;
        let n_items = b * n_kv;
        // STEP-STABLE stride: sized by `max_seq`, not the current context
        // — a ctx-sized stride would grow (i.e. reallocate) every step of
        // a steady decode loop, which is exactly what the zero-allocation
        // fingerprint below forbids.
        let stride = group * (cache.max_seq() + 1);
        let mut s = std::mem::take(&mut self.scratch);
        // Each request's new token sits at its slot's committed position —
        // the same absolute index a from-scratch prefill would rotate at.
        s.pos.clear();
        s.pos.extend(requests.iter().map(|r| cache.len(r.slot)));
        s.prepare(b, d, f, n_items * stride);
        resize_mat(logits, b, self.n_out());
        #[cfg(debug_assertions)]
        let fp = s.fingerprint();

        for (i, r) in requests.iter().enumerate() {
            s.x.row_mut(i).copy_from_slice(self.embed.row(r.token));
        }
        let mut attn_s = 0.0f64;
        for l in 0..self.n_layers {
            rms_norm_into(&s.x, &self.attn_norm[l], &mut s.h);
            self.step_linear(l, Q, &s.h, &groups, requests, &mut s.qb);
            self.step_linear(l, K, &s.h, &groups, requests, &mut s.kb);
            self.step_linear(l, V, &s.h, &groups, requests, &mut s.vb);
            for i in 0..b {
                let (nh, hd) = (self.heads.n_heads, self.heads.head_dim);
                rope_rotate(s.qb.row_mut(i), nh, hd, s.pos[i], &self.heads.inv_freq);
                let k = &mut s.kb.row_mut(i)[..self.heads.kv_dim];
                rope_rotate(k, n_kv, hd, s.pos[i], &self.heads.inv_freq);
            }
            let kv_dim = self.heads.kv_dim;
            for (i, r) in requests.iter().enumerate() {
                cache.append(r.slot, l, &s.kb.row(i)[..kv_dim], &s.vb.row(i)[..kv_dim]);
            }
            {
                let attn_timer = Timer::start();
                let cache = &*cache;
                let (nh, qb, pos) = (self.heads.n_heads, &s.qb, &s.pos);
                let ao_ptr = SendPtr(s.ao.data.as_mut_ptr());
                let attn_ptr = SendPtr(s.attn.as_mut_ptr());
                par_items(n_items, |item| {
                    let i = item / n_kv;
                    let g = item % n_kv;
                    let n_ctx = pos[i] + 1;
                    // SAFETY: item (i, g) owns `ao[i*d + g*ghd ..][..ghd]`
                    // and `attn[item*stride ..][..group*n_ctx + group]`
                    // (which fits the stride since `n_ctx <= max_seq`);
                    // regions are disjoint across items, and `par_items`
                    // returns only after every item has run.
                    let (out, sc) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(ao_ptr.0.add(i * d + g * ghd), ghd),
                            std::slice::from_raw_parts_mut(
                                attn_ptr.0.add(item * stride),
                                group * n_ctx + group,
                            ),
                        )
                    };
                    let slot = requests[i].slot;
                    attn_group_streamed(cache, slot, l, qb.row(i), n_ctx, nh, n_kv, g, sc, out);
                });
                attn_s += attn_timer.secs();
            }
            self.step_linear(l, O, &s.ao, &groups, requests, &mut s.h);
            s.x.add_assign(&s.h);

            rms_norm_into(&s.x, &self.mlp_norm[l], &mut s.h);
            self.step_linear(l, GATE, &s.h, &groups, requests, &mut s.gate);
            self.step_linear(l, UP, &s.h, &groups, requests, &mut s.up);
            for (gv, uv) in s.gate.data.iter_mut().zip(&s.up.data) {
                *gv = silu(*gv) * uv;
            }
            self.step_linear(l, DOWN, &s.gate, &groups, requests, &mut s.h);
            s.x.add_assign(&s.h);
        }
        for r in requests {
            cache.advance(r.slot, 1);
        }
        rms_norm_into(&s.x, &self.final_norm, &mut s.h);
        matmul_into(&s.h, &self.head, logits);
        #[cfg(debug_assertions)]
        debug_assert_eq!(fp, s.fingerprint(), "decode step allocated on the shared path");
        self.scratch = s;
        self.stats.record_decode_step(b, groups.len(), self.cfg.decode_slots, timer.secs(), attn_s);
        Ok(())
    }

    /// Dispatch one linear of a decode step: a single-request step takes
    /// the row fast path, larger steps the bucketed batch path. Both are
    /// bit-identical per row.
    fn step_linear(
        &self,
        layer: usize,
        module: usize,
        x: &Mat,
        groups: &[Group],
        requests: &[DecodeRequest],
        y: &mut Mat,
    ) {
        if requests.len() == 1 {
            self.linear(layer, module).forward_row_into(
                x.row(0),
                requests[0].adapter.as_deref(),
                y.row_mut(0),
            );
        } else {
            self.linear(layer, module).forward_into(x, groups, y);
        }
    }

    /// A cache built for a different model shape is a hard config error.
    fn check_cache(&self, cache: &KvCache) -> Result<()> {
        anyhow::ensure!(
            cache.n_layers() == self.n_layers && cache.d() == self.heads.kv_dim,
            "KvCache shape ({} layers x row={}) does not match the served model \
             ({} layers x kv_dim={})",
            cache.n_layers(),
            cache.d(),
            self.n_layers,
            self.heads.kv_dim
        );
        Ok(())
    }
}

/// Page-streaming causal attention for ONE query row over `n_ctx`
/// cached positions of `(slot, layer)`, all heads: the public probe
/// around [`attn_group_streamed`] used by the bench harness and the
/// determinism suite to exercise the serving kernel directly. `scratch`
/// is resized to the single-group requirement (`group × n_ctx + group`
/// floats) and reused across the `n_kv_heads` groups; `out` must be
/// `n_heads × head_dim` (= `q.len()`) wide.
#[allow(clippy::too_many_arguments)]
pub fn attn_streamed_into(
    cache: &KvCache,
    slot: SlotId,
    layer: usize,
    q: &[f32],
    n_ctx: usize,
    n_heads: usize,
    n_kv_heads: usize,
    scratch: &mut Vec<f32>,
    out: &mut [f32],
) {
    let hd = q.len() / n_heads;
    let group = n_heads / n_kv_heads;
    let need = group * n_ctx + group;
    if scratch.len() < need {
        scratch.resize(need, 0.0);
    }
    for g in 0..n_kv_heads {
        let oh = &mut out[g * group * hd..(g + 1) * group * hd];
        let sc = &mut scratch[..need];
        attn_group_streamed(cache, slot, layer, q, n_ctx, n_heads, n_kv_heads, g, sc, oh);
    }
}

/// Causal attention of ONE query row's kv-group `g` — the `group =
/// n_heads / n_kv_heads` query heads that share cached K/V head `g` —
/// over `n_ctx` cached positions of `(slot, layer)`, streamed by page:
/// [`KvCache::k_runs`]/[`KvCache::v_runs`] hand whole pages, and every
/// hot K/V row is consumed by ALL heads of the group before the next
/// position is touched (group-major — the cached bytes are read once
/// per group instead of once per query head).
///
/// `scratch` must be exactly `group * n_ctx + group` floats (per-head
/// score rows, then per-head inverse softmax sums); `out` is the
/// group's `group * head_dim` output slice (heads `g*group..(g+1)*group`
/// are contiguous in `q`/`out` because query head `h` maps to kv head
/// `h / group`).
///
/// Per head the evaluation order is EXACTLY the position-at-a-time
/// reference: scores in ascending position order (each dot in ascending
/// feature order, one `1/√head_dim` scale), one running-max pass, one
/// exp/sum pass, V accumulated one mul-add per element in ascending
/// position order, then one normalize. Restructuring the loops over
/// pages and heads reorders only WHICH independent chain is advanced
/// next, never the order within a chain — so the kernel is bit-identical
/// to the reference for every page boundary, thread count, and batch
/// shape (pinned by `rust/tests/determinism.rs`).
#[allow(clippy::too_many_arguments)]
fn attn_group_streamed(
    cache: &KvCache,
    slot: SlotId,
    layer: usize,
    q: &[f32],
    n_ctx: usize,
    n_heads: usize,
    n_kv_heads: usize,
    g: usize,
    scratch: &mut [f32],
    out: &mut [f32],
) {
    debug_assert!(n_ctx >= 1);
    let hd = q.len() / n_heads;
    let group = n_heads / n_kv_heads;
    debug_assert_eq!(scratch.len(), group * n_ctx + group);
    debug_assert_eq!(out.len(), group * hd);
    // Same expression the per-head reference evaluated: with one head
    // this equals the legacy 1/√d_model, keeping old configs bit-stable.
    let scale = 1.0 / (hd as f32).sqrt();
    let kv_off = g * hd;
    let d = cache.d();
    let qg = &q[g * group * hd..(g + 1) * group * hd];
    let (scores, invs) = scratch.split_at_mut(group * n_ctx);
    // Pass 1 — scores: stream K pages once; every head of the group
    // consumes the hot row while it sits in cache.
    let mut j = 0;
    for run in cache.k_runs(slot, layer, n_ctx) {
        for row in run.chunks_exact(d) {
            let k = &row[kv_off..kv_off + hd];
            for (hi, qh) in qg.chunks_exact(hd).enumerate() {
                let mut dot = 0.0f32;
                for (qv, kv) in qh.iter().zip(k) {
                    dot += qv * kv;
                }
                scores[hi * n_ctx + j] = dot * scale;
            }
            j += 1;
        }
    }
    // Pass 2 — per-head softmax pre-normalization: running max, then
    // exp/sum, both in ascending position order (the reference's exact
    // reduction chains; the max of a chain is order-insensitive only
    // because the COMPARISONS happen in the same ascending order).
    for (hi, inv) in invs.iter_mut().enumerate() {
        let row = &mut scores[hi * n_ctx..(hi + 1) * n_ctx];
        let mut max = f32::NEG_INFINITY;
        for &sv in row.iter() {
            if sv > max {
                max = sv;
            }
        }
        let mut sum = 0.0f32;
        for sv in row.iter_mut() {
            *sv = (*sv - max).exp();
            sum += *sv;
        }
        *inv = 1.0 / sum;
    }
    // Pass 3 — V accumulate: stream V pages once, all heads consume the
    // hot row; one mul-add per element in ascending position order, then
    // one normalize by the stashed inverse sum.
    out.iter_mut().for_each(|v| *v = 0.0);
    let mut j = 0;
    for run in cache.v_runs(slot, layer, n_ctx) {
        for row in run.chunks_exact(d) {
            let v = &row[kv_off..kv_off + hd];
            for (hi, oh) in out.chunks_exact_mut(hd).enumerate() {
                let w = scores[hi * n_ctx + j];
                for (ov, vv) in oh.iter_mut().zip(v) {
                    *ov += w * vv;
                }
            }
            j += 1;
        }
    }
    for (oh, &inv) in out.chunks_exact_mut(hd).zip(invs.iter()) {
        for ov in oh.iter_mut() {
            *ov *= inv;
        }
    }
}

/// The RoPE per-pair inverse-frequency table for one head width:
/// `theta^(-2i/head_dim)` for `i in 0..head_dim/2` — the EXACT
/// expression [`rope_rotate`] used to recompute per pair per token,
/// evaluated once at server construction and indexed ever after (so the
/// cached values are bitwise the ones the old path produced). A zero
/// `theta` yields an empty table: rotation disabled, the legacy path.
pub fn rope_inv_freq(theta: f32, head_dim: usize) -> Vec<f32> {
    if theta == 0.0 {
        return Vec::new();
    }
    (0..head_dim / 2).map(|i| theta.powf(-((2 * i) as f32) / head_dim as f32)).collect()
}

/// In-place rotary position embedding over a projection row laid out as
/// `n_heads` contiguous `head_dim`-wide head slices. Within each head,
/// feature pairs `(2i, 2i+1)` are rotated by `pos · inv_freq[i]`, where
/// `inv_freq` is the precomputed [`rope_inv_freq`] table (empty table =
/// rotation disabled, the legacy no-RoPE path).
///
/// The rotation depends only on `(pos, inv_freq, head_dim)` — never on
/// how many rows are processed together — so a token rotated during
/// incremental decode at position `p` gets the bit-identical rotation a
/// full-prefill recompute applies at the same position. Each pair is
/// computed in a fixed scalar order (sin_cos once, then the 2×2
/// rotation), keeping the result thread-count independent.
fn rope_rotate(row: &mut [f32], n_heads: usize, head_dim: usize, pos: usize, inv_freq: &[f32]) {
    if inv_freq.is_empty() {
        return;
    }
    let p = pos as f32;
    for h in 0..n_heads {
        let s = &mut row[h * head_dim..(h + 1) * head_dim];
        for (i, &freq) in inv_freq.iter().enumerate() {
            let angle = p * freq;
            let (sin, cos) = angle.sin_cos();
            let a = s[2 * i];
            let b = s[2 * i + 1];
            s[2 * i] = a * cos - b * sin;
            s[2 * i + 1] = a * sin + b * cos;
        }
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// Row-wise RMS norm with a gain vector, overwriting `out`:
/// `out[i] = x[i] / sqrt(mean(x[i]²) + eps) * gain`. Fixed-order f32
/// accumulation per row (thread-count independent).
pub fn rms_norm_into(x: &Mat, gain: &[f32], out: &mut Mat) {
    assert_eq!(x.cols, gain.len(), "rms_norm: gain length");
    assert_eq!((out.rows, out.cols), (x.rows, x.cols), "rms_norm: output shape");
    for i in 0..x.rows {
        rms_norm_row_into(x.row(i), gain, out.row_mut(i));
    }
}

/// One row of [`rms_norm_into`] — the decode/prefill paths norm single
/// rows through the SAME routine the batched forward uses, so the two
/// cannot drift by a bit.
pub fn rms_norm_row_into(row: &[f32], gain: &[f32], out: &mut [f32]) {
    let mut ms = 0.0f32;
    for &v in row {
        ms += v * v;
    }
    let inv = 1.0 / (ms / row.len() as f32 + RMS_EPS).sqrt();
    for (o, (&v, &g)) in out.iter_mut().zip(row.iter().zip(gain)) {
        *o = v * inv * g;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::AdapterSpec;
    use crate::model::BaseModel;
    use crate::runtime::ConfigInfo;
    use crate::serve::config::ServeStrategy;
    use crate::serve::drift_factors;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "model-serve-test".into(),
            kind: "decoder".into(),
            vocab: 48,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            seq_len: 8,
            batch: 4,
            eval_batch: 2,
            n_classes: 0,
            ranks: vec![2],
        }
    }

    fn engine(seed: u64) -> (AdapterEngine, Rng) {
        let mut rng = Rng::new(seed);
        let base = BaseModel::random(&tiny_cfg(), &mut rng);
        let mut eng = AdapterEngine::new(base);
        eng.attach("t", AdapterSpec::pissa(2), &mut rng).unwrap();
        for module in LINEARS {
            drift_factors(&mut eng, "t", module, 0.05, &mut rng).unwrap();
        }
        (eng, rng)
    }

    #[test]
    fn snapshot_covers_all_layers_and_linears() {
        let (eng, _) = engine(1);
        let srv = ModelServer::new(&eng, ServeConfig::full_model()).unwrap();
        assert_eq!(srv.n_layers(), 2);
        assert_eq!(srv.d_model(), 16);
        assert_eq!(srv.vocab(), 48);
        assert_eq!(srv.n_out(), 48);
        assert_eq!(srv.adapter_names(), vec!["t"]);
        // L×7 dense fp32 linears: 4 attn (16×16) + gate/up (16×24) +
        // down (24×16), twice.
        let per_layer = 4 * 16 * 16 + 3 * 16 * 24;
        assert_eq!(srv.dense_base_bytes(), 2 * per_layer * 4);
        assert_eq!(srv.base_resident_bytes(), srv.dense_base_bytes());
        let bd = srv.resident_breakdown();
        assert_eq!(bd.per_module.len(), 7);
        assert_eq!(bd.total(), srv.base_resident_bytes());
    }

    #[test]
    fn zero_layer_engine_is_a_typed_error_not_a_panic() {
        let mut cfg = tiny_cfg();
        cfg.n_layers = 0;
        let mut rng = Rng::new(17);
        let base = BaseModel::random(&cfg, &mut rng);
        let eng = AdapterEngine::new(base);
        let err = ModelServer::new(&eng, ServeConfig::full_model()).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<ServeError>(),
                Some(ServeError::LayerOutOfRange { n_layers: 0, .. })
            ),
            "got {err:?}"
        );
    }

    #[test]
    fn single_linear_scope_is_rejected_with_a_typed_error() {
        let (eng, _) = engine(2);
        let err = ModelServer::new(&eng, ServeConfig::new("q")).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::ScopeMismatch { server, scope }) => {
                assert_eq!((*server, *scope), ("ModelServer", "single-linear"));
            }
            other => panic!("expected ScopeMismatch, got {other:?}"),
        }
    }

    #[test]
    fn empty_batch_and_request_validation() {
        let (eng, _) = engine(3);
        let mut srv =
            ModelServer::new(&eng, ServeConfig::full_model().max_batch(2)).unwrap();
        let y = srv.forward(&[]).unwrap();
        assert_eq!((y.rows, y.cols), (0, 48));
        assert_eq!(srv.stats().batches, 0);
        // token out of range
        let err = srv
            .forward(&[ModelRequest::base(0), ModelRequest::base(48)])
            .unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::TokenOutOfRange { index, token, vocab }) => {
                assert_eq!((*index, *token, *vocab), (1, 48, 48));
            }
            other => panic!("expected TokenOutOfRange, got {other:?}"),
        }
        // unknown adapter
        let err = srv.forward(&[ModelRequest::new("ghost", 0)]).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::UnknownAdapter { .. })
        ));
        // over the batch ceiling
        let reqs: Vec<ModelRequest> = (0..3).map(ModelRequest::base).collect();
        let err = srv.forward(&reqs).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::BatchTooLarge { got: 3, max_batch: 2 })
        ));
    }

    #[test]
    fn adapted_rows_differ_from_base_rows_and_stats_aggregate() {
        // The drifted adapter must actually steer the whole-model output
        // (all seven linears contribute), while base rows match a pure
        // base forward.
        let (eng, _) = engine(4);
        let mut srv = ModelServer::new(&eng, ServeConfig::full_model()).unwrap();
        let mixed = [ModelRequest::new("t", 7), ModelRequest::base(7)];
        let y = srv.forward(&mixed).unwrap();
        let base_only = srv.forward(&[ModelRequest::base(7)]).unwrap();
        assert_eq!(y.row(1), base_only.row(0), "base row must be adapter-independent");
        let diff: f32 =
            y.row(0).iter().zip(y.row(1)).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3, "drifted adapter changed nothing (diff {diff:.3e})");
        let s = srv.stats().summary();
        assert_eq!(s.batches, 2);
        assert_eq!(s.requests, 3);
        assert_eq!(srv.stats().hits["t"], 1);
    }

    #[test]
    fn fused_quant_shares_one_nf4_snapshot_across_the_stack() {
        let (eng, _) = engine(5);
        let srv = ModelServer::new(
            &eng,
            ServeConfig::full_model().strategy(ServeStrategy::FusedQuant),
        )
        .unwrap();
        // Aggregate residency equals the sum of the per-module stacks —
        // and is well under the 0.35× dense bar.
        let want: usize =
            LINEARS.iter().map(|m| eng.quant_base_stack(m).storage_bytes()).sum();
        assert_eq!(srv.base_resident_bytes(), want);
        assert!(
            srv.base_resident_bytes() * 100 <= srv.dense_base_bytes() * 35,
            "{} vs dense {}",
            srv.base_resident_bytes(),
            srv.dense_base_bytes()
        );
    }

    #[test]
    fn prefill_and_decode_step_validate_requests() {
        let (eng, _) = engine(6);
        let mut srv = ModelServer::new(&eng, ServeConfig::full_model().max_seq(8)).unwrap();
        let mut cache = srv.new_cache().unwrap();
        let slot = cache.try_claim(8).unwrap().unwrap();
        // unclaimed slot
        let ghost = crate::serve::kvcache::SlotId(5);
        let err = srv.prefill(&mut cache, ghost, None, &[1, 2]).unwrap_err();
        assert!(matches!(err.downcast_ref::<ServeError>(), Some(ServeError::BadSlot { .. })));
        // token out of range
        let err = srv.prefill(&mut cache, slot, None, &[1, 99]).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::TokenOutOfRange { index: 1, token: 99, .. })
        ));
        // over max_seq
        let err = srv.prefill(&mut cache, slot, None, &[0; 9]).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::SeqTooLong { max_seq: 8, .. })
        ));
        // unknown adapter
        let err = srv.prefill(&mut cache, slot, Some("ghost"), &[1]).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::UnknownAdapter { .. })
        ));
        // a valid prefill, then a duplicate-slot decode step
        srv.prefill(&mut cache, slot, Some("t"), &[1, 2]).unwrap();
        let reqs = vec![
            DecodeRequest { slot, token: 1, adapter: None },
            DecodeRequest { slot, token: 2, adapter: None },
        ];
        let err = srv.decode_step(&mut cache, &reqs).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::BadSlot { detail: "appears twice in one decode step", .. })
        ));
        // empty decode step is a no-op
        let y = srv.decode_step(&mut cache, &[]).unwrap();
        assert_eq!((y.rows, y.cols), (0, 48));
    }

    #[test]
    fn chunked_prefill_is_bit_identical_to_one_shot() {
        // Prefill continues from the slot's committed length, so feeding
        // the prompt in two chunks must give the same final logits as one
        // call — the simplest incremental≡recompute instance.
        let (eng, _) = engine(7);
        let mut srv = ModelServer::new(&eng, ServeConfig::full_model()).unwrap();
        let mut cache = srv.new_cache().unwrap();
        let tokens = [3usize, 11, 7, 29, 5];
        let a = cache.try_claim(tokens.len()).unwrap().unwrap();
        let one = srv.prefill(&mut cache, a, Some("t"), &tokens).unwrap();
        cache.release(a);
        let b = cache.try_claim(tokens.len()).unwrap().unwrap();
        srv.prefill(&mut cache, b, Some("t"), &tokens[..2]).unwrap();
        let two = srv.prefill(&mut cache, b, Some("t"), &tokens[2..]).unwrap();
        cache.release(b);
        assert_eq!(one, two, "chunked prefill drifted from one-shot");
        let s = srv.stats();
        assert_eq!(s.prefills, 3);
        assert_eq!(s.prefill_tokens, 10);
        assert_eq!(s.hits["t"], 3);
    }

    #[test]
    fn reservation_overflow_is_a_typed_error_not_a_panic() {
        // Regression: prefilling a slot claimed for fewer positions than
        // the prompt used to trip the KvCache append assert mid-layer
        // (aborting the engine thread). Now both prefill and decode_step
        // validate against the reservation up front.
        let (eng, _) = engine(21);
        let mut srv = ModelServer::new(&eng, ServeConfig::full_model().max_seq(8)).unwrap();
        let mut cache = srv.new_cache().unwrap();
        let slot = cache.try_claim(4).unwrap().unwrap();
        let err = srv.prefill(&mut cache, slot, Some("t"), &[1, 2, 3, 4, 5]).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::ReservationExceeded { reserved, needed, .. }) => {
                assert_eq!((*reserved, *needed), (4, 5));
            }
            other => panic!("expected ReservationExceeded, got {other:?}"),
        }
        // The failed prefill must not have committed anything.
        assert_eq!(cache.len(slot), 0);
        // Fill the reservation exactly, then one decode step past it.
        srv.prefill(&mut cache, slot, Some("t"), &[1, 2, 3, 4]).unwrap();
        let reqs = vec![DecodeRequest { slot, token: 1, adapter: None }];
        let err = srv.decode_step(&mut cache, &reqs).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<ServeError>(),
                Some(ServeError::ReservationExceeded { reserved: 4, needed: 5, .. })
            ),
            "got {err:?}"
        );
        assert_eq!(cache.len(slot), 4, "failed step must not advance the sequence");
    }

    #[test]
    fn head_layout_validation_is_typed_and_upfront() {
        let (eng, _) = engine(22);
        // 3 heads do not divide d_model = 16.
        assert!(ModelServer::new(&eng, ServeConfig::full_model().heads(3, 1)).is_err());
        // 3 KV heads do not divide 4 query heads.
        assert!(ModelServer::new(&eng, ServeConfig::full_model().heads(4, 3)).is_err());
        // Zero heads.
        assert!(ModelServer::new(&eng, ServeConfig::full_model().heads(0, 1)).is_err());
        // RoPE needs an even head_dim: 16 heads → head_dim 1.
        let cfg = ServeConfig::full_model().heads(16, 16).rope_theta(10000.0);
        assert!(ModelServer::new(&eng, cfg).is_err());
        // Non-finite theta.
        let cfg = ServeConfig::full_model().rope_theta(f64::INFINITY);
        assert!(ModelServer::new(&eng, cfg).is_err());
        // A well-formed GQA+RoPE layout builds, and the cache rows shrink
        // to kv_dim = n_kv_heads × head_dim = 2 × 4.
        let cfg = ServeConfig::full_model().heads(4, 2).rope_theta(10000.0);
        let srv = ModelServer::new(&eng, cfg).unwrap();
        assert_eq!(srv.kv_dim(), 8);
        assert_eq!(srv.new_cache().unwrap().d(), 8);
        // The legacy default keeps full-width rows.
        let srv = ModelServer::new(&eng, ServeConfig::full_model()).unwrap();
        assert_eq!(srv.kv_dim(), 16);
    }

    #[test]
    fn gqa_rope_incremental_decode_matches_recompute_bitwise() {
        // The core attention contract under the new layout: with 4 query
        // heads sharing 2 cached KV heads and RoPE enabled, decode steps
        // over a cached prefix must reproduce a from-scratch prefill of
        // the whole sequence EXACTLY (same rotations, same per-head
        // softmax order).
        for (nh, nkv) in [(4, 1), (4, 2), (4, 4)] {
            let (eng, _) = engine(23);
            let cfg = ServeConfig::full_model().max_seq(8).heads(nh, nkv).rope_theta(10000.0);
            let mut srv = ModelServer::new(&eng, cfg).unwrap();
            let mut cache = srv.new_cache().unwrap();
            let tokens = [3usize, 11, 7, 29, 5, 40];
            // Incremental: prefill 3, then decode the rest step by step.
            let inc = cache.try_claim(tokens.len()).unwrap().unwrap();
            let first = srv.prefill(&mut cache, inc, Some("t"), &tokens[..3]).unwrap();
            let mut inc_logits = vec![first];
            for &t in &tokens[3..] {
                let reqs = vec![DecodeRequest { slot: inc, token: t, adapter: Some("t".into()) }];
                let y = srv.decode_step(&mut cache, &reqs).unwrap();
                inc_logits.push(y.row(0).to_vec());
            }
            // Recompute: a fresh one-shot prefill per prefix.
            for (k, got) in inc_logits.iter().enumerate() {
                let n = 3 + k;
                let slot = cache.try_claim(n).unwrap().unwrap();
                let want = srv.prefill(&mut cache, slot, Some("t"), &tokens[..n]).unwrap();
                cache.release(slot);
                assert_eq!(got, &want, "heads ({nh},{nkv}): prefix {n} drifted");
            }
            cache.release(inc);
        }
    }

    #[test]
    fn rope_rotation_is_positional_and_norm_preserving() {
        let row: Vec<f32> = (0..8).map(|i| (i as f32) * 0.3 - 1.0).collect();
        let table = rope_inv_freq(10000.0, 4);
        // theta = 0 yields an empty table, which disables rotation.
        let mut r0 = row.clone();
        rope_rotate(&mut r0, 2, 4, 5, &rope_inv_freq(0.0, 4));
        assert_eq!(r0, row);
        // Position 0 is the identity rotation.
        let mut p0 = row.clone();
        rope_rotate(&mut p0, 2, 4, 0, &table);
        for (a, b) in p0.iter().zip(&row) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        // A real rotation changes the vector but preserves each pair's
        // norm (it is a 2×2 rotation per feature pair).
        let mut p5 = row.clone();
        rope_rotate(&mut p5, 2, 4, 5, &table);
        assert_ne!(p5, row);
        for i in (0..8).step_by(2) {
            let n0 = row[i] * row[i] + row[i + 1] * row[i + 1];
            let n5 = p5[i] * p5[i] + p5[i + 1] * p5[i + 1];
            assert!((n0 - n5).abs() < 1e-4, "pair {i}: {n0} vs {n5}");
        }
        // Deterministic: same inputs, same bits.
        let mut again = row.clone();
        rope_rotate(&mut again, 2, 4, 5, &table);
        assert_eq!(p5, again);
    }

    #[test]
    fn rope_table_matches_per_pair_recomputation_bitwise() {
        // The precomputed table must hold the EXACT f32s the old path
        // recomputed per pair — same expression, evaluated once.
        for (theta, hd) in [(10000.0f32, 8usize), (500.0, 6), (2.5, 16)] {
            let table = rope_inv_freq(theta, hd);
            assert_eq!(table.len(), hd / 2);
            for (i, &got) in table.iter().enumerate() {
                let want = theta.powf(-((2 * i) as f32) / hd as f32);
                assert_eq!(got.to_bits(), want.to_bits(), "theta {theta} hd {hd} pair {i}");
            }
        }
        assert!(rope_inv_freq(0.0, 8).is_empty());
    }

    #[test]
    fn streamed_attention_matches_reference_at_page_boundaries() {
        // The group-major page-streaming kernel vs a position-at-a-time
        // reference (one head at a time, k_row/v_row per position — the
        // pre-streaming kernel's exact loop structure), across contexts
        // that undershoot / hit / straddle KV_PAGE runs and every GQA
        // grouping. Bit-equality, not tolerance.
        use crate::serve::KV_PAGE;
        let (nh, hd) = (4usize, 4usize);
        let d_q = nh * hd;
        let mut rng = Rng::new(97);
        for &n_kv in &[1usize, 2, 4] {
            let kv_dim = n_kv * hd;
            let mut cache = KvCache::new(1, kv_dim, 64, 1, 1 << 20).unwrap();
            let slot = cache.try_claim(40).unwrap().unwrap();
            for _ in 0..40 {
                let k: Vec<f32> = (0..kv_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> = (0..kv_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                cache.append(slot, 0, &k, &v);
                cache.advance(slot, 1);
            }
            let q: Vec<f32> = (0..d_q).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            for &n_ctx in &[1usize, 15, 16, 17, 33, 40] {
                let mut got = vec![0.0f32; d_q];
                let mut scratch = Vec::new();
                attn_streamed_into(&cache, slot, 0, &q, n_ctx, nh, n_kv, &mut scratch, &mut got);
                // Reference: per head, positions one at a time.
                let group = nh / n_kv;
                let scale = 1.0 / (hd as f32).sqrt();
                let mut want = vec![0.0f32; d_q];
                for h in 0..nh {
                    let kv_off = (h / group) * hd;
                    let qh = &q[h * hd..(h + 1) * hd];
                    let mut scores = Vec::new();
                    let mut max = f32::NEG_INFINITY;
                    for j in 0..n_ctx {
                        let k = &cache.k_row(slot, 0, j)[kv_off..kv_off + hd];
                        let mut dot = 0.0f32;
                        for (qv, kv) in qh.iter().zip(k) {
                            dot += qv * kv;
                        }
                        let sv = dot * scale;
                        if sv > max {
                            max = sv;
                        }
                        scores.push(sv);
                    }
                    let mut sum = 0.0f32;
                    for sv in scores.iter_mut() {
                        *sv = (*sv - max).exp();
                        sum += *sv;
                    }
                    let oh = &mut want[h * hd..(h + 1) * hd];
                    for (j, &w) in scores.iter().enumerate() {
                        let v = &cache.v_row(slot, 0, j)[kv_off..kv_off + hd];
                        for (ov, vv) in oh.iter_mut().zip(v) {
                            *ov += w * vv;
                        }
                    }
                    let inv = 1.0 / sum;
                    for ov in oh.iter_mut() {
                        *ov *= inv;
                    }
                }
                let straddles = n_ctx % KV_PAGE != 0;
                assert_eq!(
                    got, want,
                    "n_kv {n_kv} n_ctx {n_ctx} (straddles page: {straddles}) drifted"
                );
            }
            cache.release(slot);
        }
    }

    #[test]
    fn rms_norm_normalizes_rows() {
        let x = Mat::from_vec(1, 4, vec![3.0, -3.0, 3.0, -3.0]);
        let mut out = Mat::zeros(1, 4);
        rms_norm_into(&x, &[1.0, 1.0, 2.0, 1.0], &mut out);
        // mean square = 9 ⇒ x/3 * gain
        let want = [1.0f32, -1.0, 2.0, -1.0];
        for (o, w) in out.row(0).iter().zip(&want) {
            assert!((o - w).abs() < 1e-5, "{o} vs {w}");
        }
    }
}
