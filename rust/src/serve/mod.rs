//! Batched multi-adapter serving runtime.
//!
//! PiSSA's deployment story (§3 + Appendix C): many low-rank adapters
//! share ONE frozen dense base, so a single host serves many fine-tuned
//! variants. This module is the layer that actually exploits that
//! structure at request time, on top of [`crate::adapter::AdapterEngine`]:
//!
//! * [`Request`] / [`Scheduler`] / [`bucket`] — requests carry an adapter
//!   name; the scheduler batches them and the router buckets a batch by
//!   adapter in deterministic order,
//! * [`ServeConfig`] + [`ServeStrategy`] — which linear/layer is served
//!   and how: `fused` (shared `X·W` + per-group low-rank corrections,
//!   `ΔW` never materialized), `merge-per-request`, `dense-per-adapter`
//!   (the baselines of `benches/serve_throughput.rs`), plus the
//!   quantized-base pair of `benches/quant_serve.rs`: `fused-quant`
//!   (NF4-resident base streamed through the dequant-GEMM — the QPiSSA
//!   deployment mode) and `dequant-dense` (dequantize once, serve dense
//!   — its bit-for-bit fp32-residency reference),
//! * [`Server`] — the batched forward `Y = X·W + Σ_g (X_g·ΔA_g)·ΔB_g`
//!   (`X·deq(W_nf4)` under `fused-quant`, see [`QuantBase`]), with
//!   per-adapter corrections dispatched in parallel via
//!   [`crate::util::par::par_map`],
//! * [`ServeStats`] — per-adapter hit counts, batch occupancy, and
//!   p50/p95 latency, exported as JSON through the `metrics` sinks,
//! * [`ServeError`] — typed request/config errors (unknown adapter,
//!   dimension mismatch, rank > min(m, n), quantized adapter under a
//!   full-precision strategy), never panics.
//!
//! Bit-for-bit thread-count determinism of the whole path is locked in
//! by `rust/tests/determinism.rs`; fused ≡ merged-dense equivalence by
//! `rust/tests/serve_equiv.rs`.

pub mod config;
pub mod router;
pub mod server;
pub mod stats;

pub use config::{ServeConfig, ServeError, ServeStrategy};
pub use router::{bucket, Group, Request, Scheduler};
pub use server::{QuantBase, Server};
pub use stats::{ServeStats, ServeSummary, BASE_KEY};

use crate::adapter::AdapterEngine;
use crate::util::rng::Rng;
use anyhow::Result;

/// Simulate training drift on one adapter's factors for `module` (every
/// layer): adds N(0, scale) noise to A and B. Synthetic-workload helper
/// shared by the `serve` CLI, the throughput bench, and the equivalence
/// tests — a server snapshot of a drifted adapter exercises the real
/// Appendix-C delta path instead of the zero-delta init state.
pub fn drift_factors(
    engine: &mut AdapterEngine,
    name: &str,
    module: &str,
    scale: f32,
    rng: &mut Rng,
) -> Result<()> {
    anyhow::ensure!(
        engine.get(name)?.spec.targets_module(module),
        "adapter '{name}' does not target module '{module}'; nothing to drift"
    );
    let layers = engine.base().n_layers();
    for layer in 0..layers {
        let (mut a, mut b) = {
            let ad = engine.get(name)?;
            (
                ad.factors[&format!("a_{module}")].layer(layer),
                ad.factors[&format!("b_{module}")].layer(layer),
            )
        };
        for x in a.data.iter_mut() {
            *x += scale * rng.normal_f32(0.0, 1.0);
        }
        for x in b.data.iter_mut() {
            *x += scale * rng.normal_f32(0.0, 1.0);
        }
        engine.set_factors(name, module, layer, &a, &b)?;
    }
    Ok(())
}
