//! Batched multi-adapter serving runtime.
//!
//! PiSSA's deployment story (§3 + Appendix C): many low-rank adapters
//! share ONE frozen dense base, so a single host serves many fine-tuned
//! variants. This module is the layer that actually exploits that
//! structure at request time, on top of [`crate::adapter::AdapterEngine`].
//! It is a two-level design — a reusable per-linear unit, and servers
//! built from it:
//!
//! * [`LinearServer`] — batched mixed-adapter execution of ONE
//!   `(module, layer)` linear: the shared base in its strategy's
//!   representation (dense, or the NF4-resident [`QuantBase`] streamed
//!   through the dequant-GEMM) plus prepared Appendix-C deltas, with a
//!   buffer-reusing `forward_into`,
//! * [`Server`] — the single-linear server: request validation,
//!   bucketing, stats around one `LinearServer`; the batched forward
//!   `Y = X·W + Σ_g (X_g·ΔA_g)·ΔB_g` (`X·deq(W_nf4)` under
//!   `fused-quant`) with per-adapter corrections dispatched in parallel
//!   via [`crate::util::par::par_map`],
//! * [`ModelServer`] — the whole-model pipeline: embed → `n_layers`
//!   blocks over all seven linears (norms + nonlinearity) → head, every
//!   projection a full mixed-adapter `LinearServer` execution, with
//!   activation buffers ping-ponged across layers and residency/stats
//!   aggregated over all `L × 7` base stores. Three entry points: the
//!   one-shot `forward` (single-position gate, the PR-4 surface), and
//!   the autoregressive pair `prefill` / `decode_step` — real causal
//!   attention over per-layer K/V rows in a [`KvCache`], with
//!   incremental decode BIT-IDENTICAL to recomputing the whole sequence,
//! * [`KvCache`] — the slot-paged K/V store: fixed sequence slots over a
//!   shared pool of fixed-size pages, reservation-based admission
//!   against a byte budget (typed errors for impossible requests, wait
//!   states for full-but-draining capacity),
//! * [`Request`] / [`ModelRequest`] / [`DecodeRequest`] /
//!   [`SeqRequest`] / [`bucket`] — requests carry an adapter name; the
//!   router buckets a batch by adapter in deterministic order,
//! * [`Scheduler`] / [`DecodeScheduler`] — the generic FIFO batcher for
//!   the one-shot paths, and the continuous-batching decode scheduler:
//!   per-step admission in strict arrival order, one decoded token per
//!   running sequence per step, retirement the moment a stop condition
//!   hits (freed slots are re-admitted the very next step),
//! * [`ServeConfig`] + [`ServeScope`] + [`ServeStrategy`] — WHAT is
//!   served (one linear, or the full model) and HOW: `fused` (shared
//!   base GEMM + per-group low-rank corrections, `ΔW` never
//!   materialized), `merge-per-request`, `dense-per-adapter` (the
//!   baselines of `benches/serve_throughput.rs` and
//!   `benches/model_serve.rs`), plus the quantized-base pair of
//!   `benches/quant_serve.rs`: `fused-quant` (NF4-resident base — the
//!   QPiSSA deployment mode, shared per-module [`crate::quant::Nf4Stack`]
//!   snapshots under the full-model scope) and `dequant-dense`
//!   (dequantize once, serve dense — its bit-for-bit fp32-residency
//!   reference),
//! * [`ServeStats`] / [`ResidentBreakdown`] — per-adapter hit counts,
//!   batch occupancy, p50/p95 latency, and the aggregated per-module
//!   residency table, exported as JSON through the `metrics` sinks,
//! * [`ServeError`] — typed request/config errors (unknown adapter,
//!   dimension mismatch, token out of range, scope mismatch,
//!   rank > min(m, n), quantized adapter under a full-precision
//!   strategy), never panics.
//!
//! Bit-for-bit thread-count determinism of the whole path is locked in
//! by `rust/tests/determinism.rs`; fused ≡ merged-dense equivalence (per
//! linear AND end-to-end through the model pipeline) by
//! `rust/tests/serve_equiv.rs`.

pub mod config;
pub mod kvcache;
pub mod linear;
pub mod model;
pub mod router;
pub mod server;
pub mod stats;

pub use config::{
    ServeConfig, ServeError, ServeScope, ServeStrategy, DEFAULT_ADAPTER_BUDGET_BYTES,
    DEFAULT_KV_BUDGET_BYTES,
};
pub use kvcache::{KvCache, KvRuns, SlotId, KV_PAGE};
pub use linear::{LinearServer, QuantBase};
pub use model::{attn_streamed_into, rope_inv_freq, ModelServer, RMS_EPS};
pub use router::{
    argmax, bucket, DecodeRequest, DecodeScheduler, FinishReason, FinishedSeq, Group,
    ModelRequest, Request, Routable, Scheduler, SeqId, SeqRequest, StepObserver,
};
pub use server::Server;
pub use stats::{ResidentBreakdown, ServeStats, ServeSummary, BASE_KEY};

use crate::adapter::AdapterEngine;
use crate::util::rng::Rng;
use anyhow::Result;

/// Simulate training drift on one adapter's factors for `module` (every
/// layer): adds N(0, scale) noise to A and B. Synthetic-workload helper
/// shared by the `serve` CLI, the throughput bench, and the equivalence
/// tests — a server snapshot of a drifted adapter exercises the real
/// Appendix-C delta path instead of the zero-delta init state.
pub fn drift_factors(
    engine: &mut AdapterEngine,
    name: &str,
    module: &str,
    scale: f32,
    rng: &mut Rng,
) -> Result<()> {
    anyhow::ensure!(
        engine.get(name)?.spec.targets_module(module),
        "adapter '{name}' does not target module '{module}'; nothing to drift"
    );
    let layers = engine.base().n_layers();
    for layer in 0..layers {
        let (mut a, mut b) = {
            let ad = engine.get(name)?;
            (
                ad.factors[&format!("a_{module}")].layer(layer),
                ad.factors[&format!("b_{module}")].layer(layer),
            )
        };
        for x in a.data.iter_mut() {
            *x += scale * rng.normal_f32(0.0, 1.0);
        }
        for x in b.data.iter_mut() {
            *x += scale * rng.normal_f32(0.0, 1.0);
        }
        engine.set_factors(name, module, layer, &a, &b)?;
    }
    Ok(())
}
