//! Serving configuration and the typed serving error set.
//!
//! A [`ServeConfig`] pins WHAT is served — a [`ServeScope`]: one
//! `(module, layer)` linear for a `Server`, or the whole adapted forward
//! pass (every layer × all seven linears, embed to head) for a
//! `ModelServer` — plus the execution [`ServeStrategy`] and the
//! scheduler's batch ceiling. Validation happens against a concrete
//! [`AdapterEngine`]: every registered adapter must be servable under
//! the config (quantized adapters only under a quantized-base strategy,
//! declared rank within `min(m, n)` on the fused paths — checked per
//! served linear, i.e. across all `L×7` of them under the full-model
//! scope), so misconfiguration is a clear error at server construction,
//! not a panic mid-batch.

use crate::adapter::AdapterEngine;
use crate::model::{linear_dims, LINEARS};
use anyhow::Result;
use std::fmt;

/// How a batch is executed (the contenders of
/// `benches/serve_throughput.rs` and `benches/quant_serve.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeStrategy {
    /// The paper-faithful path: one shared dense `X·W` for the whole
    /// batch, then per-adapter-group low-rank corrections
    /// `(X_g·ΔA)·ΔB` — ΔW is never materialized.
    Fused,
    /// Naive baseline: materialize the merged dense weight for EVERY
    /// request, then a dense vector-matrix product.
    MergePerRequest,
    /// Middle ground: materialize the merged dense weight once per
    /// adapter group, then a dense group GEMM (no low-rank exploitation,
    /// no cross-adapter sharing).
    DensePerAdapter,
    /// The QPiSSA deployment path (§4): the shared base stays resident
    /// as blockwise NF4 (~0.14× the dense bytes) and is streamed through
    /// the fused dequant-GEMM `Y = X·deq(W_nf4) + (X_g·ΔA)·ΔB` — the
    /// dense base is never materialized. Output matches the fp32 fused
    /// path up to the NF4 round-trip error of the base (the exact trade
    /// the paper quantifies in Table 3), and is the one strategy that
    /// accepts quantized (QPiSSA/QLoRA/LoftQ) adapters.
    FusedQuant,
    /// Quantized-base baseline: quantize the shared base to NF4, then
    /// dequantize ONCE into a resident dense copy at construction and
    /// serve it through the fp32 fused path. Same output as `FusedQuant`
    /// bit-for-bit, fp32-sized residency — the reference the fused
    /// dequant-GEMM is measured against.
    DequantDense,
}

impl ServeStrategy {
    pub fn parse(s: &str) -> Result<ServeStrategy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fused" => ServeStrategy::Fused,
            "merge" | "merge-per-request" => ServeStrategy::MergePerRequest,
            "dense" | "dense-per-adapter" => ServeStrategy::DensePerAdapter,
            "quant" | "fused-quant" => ServeStrategy::FusedQuant,
            "dequant" | "dequant-dense" => ServeStrategy::DequantDense,
            other => anyhow::bail!(
                "unknown serve strategy '{other}' (fused|merge|dense|fused-quant|dequant-dense)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ServeStrategy::Fused => "fused",
            ServeStrategy::MergePerRequest => "merge-per-request",
            ServeStrategy::DensePerAdapter => "dense-per-adapter",
            ServeStrategy::FusedQuant => "fused-quant",
            ServeStrategy::DequantDense => "dequant-dense",
        }
    }

    /// All strategies, for determinism/edge-case sweeps.
    pub fn all() -> [ServeStrategy; 5] {
        [
            ServeStrategy::Fused,
            ServeStrategy::MergePerRequest,
            ServeStrategy::DensePerAdapter,
            ServeStrategy::FusedQuant,
            ServeStrategy::DequantDense,
        ]
    }

    /// The full-precision strategies that reproduce the merged-dense
    /// reference exactly (to fp tolerance). The quantized-base pair is
    /// excluded: it approximates within the NF4 round-trip error by
    /// design and has its own equivalence contract in
    /// `rust/tests/serve_equiv.rs`.
    pub fn exact() -> [ServeStrategy; 3] {
        [ServeStrategy::Fused, ServeStrategy::MergePerRequest, ServeStrategy::DensePerAdapter]
    }

    /// Does this strategy serve from an NF4-quantized snapshot of the
    /// base (and therefore accept quantized adapters)?
    pub fn quantized_base(&self) -> bool {
        matches!(self, ServeStrategy::FusedQuant | ServeStrategy::DequantDense)
    }

    /// Does this strategy rely on the update being genuinely low-rank
    /// (fused-style correction GEMMs)?
    pub fn fused_low_rank(&self) -> bool {
        matches!(
            self,
            ServeStrategy::Fused | ServeStrategy::FusedQuant | ServeStrategy::DequantDense
        )
    }
}

/// What a serving config covers: one linear, or the whole model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeScope {
    /// One `(module, layer)` linear — the PR-2/PR-3 `Server`. This is the
    /// default, so every pre-scope config keeps its meaning.
    SingleLinear,
    /// The whole adapted forward pass — embed → `n_layers` blocks over
    /// all seven linears (norms + nonlinearity) → head — served by a
    /// `ModelServer`. `module`/`layer` are ignored under this scope.
    FullModel,
}

impl ServeScope {
    pub fn name(&self) -> &'static str {
        match self {
            ServeScope::SingleLinear => "single-linear",
            ServeScope::FullModel => "full-model",
        }
    }
}

/// Typed serving errors — the contract of the edge-case hardening tests:
/// bad requests are reported, never panicked on, and each variant can be
/// matched (`err.downcast_ref::<ServeError>()`).
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// A request named an adapter the engine does not hold.
    UnknownAdapter { name: String, have: Vec<String> },
    /// A request's input vector has the wrong length for the served linear.
    DimMismatch { index: usize, got: usize, want: usize },
    /// A batch exceeded the configured `max_batch` ceiling (the occupancy
    /// denominator); route through a `Scheduler` or raise the ceiling.
    BatchTooLarge { got: usize, max_batch: usize },
    /// An adapter's declared rank exceeds `min(m, n)` of the served
    /// weight — the "low-rank" update would be full-rank or worse, so
    /// the fused strategy refuses it (merged/dense serving still works).
    RankTooLarge { adapter: String, module: String, rank: usize, m: usize, n: usize },
    /// A quantized (QPiSSA/QLoRA/LoftQ) adapter was attached under a
    /// full-precision strategy: its frozen NF4 base is not the shared
    /// full-precision `W`, so only the quantized-base strategies
    /// (`fused-quant`, `dequant-dense`) can serve it.
    QuantizedAdapter { adapter: String, strategy: &'static str },
    /// The config names a module outside the seven served linears.
    UnknownModule { module: String },
    /// The config's layer index is out of range for the engine's base.
    LayerOutOfRange { layer: usize, n_layers: usize },
    /// A full-model request's token id is outside the embedding table.
    TokenOutOfRange { index: usize, token: usize, vocab: usize },
    /// The config's [`ServeScope`] does not match the server type it was
    /// handed to (`Server` is single-linear, `ModelServer` full-model).
    ScopeMismatch { server: &'static str, scope: &'static str },
    /// A sequence request's worst case (`prompt + max_new`) does not fit
    /// in the configured `max_seq` positions.
    SeqTooLong { prompt: usize, max_new: usize, max_seq: usize },
    /// The KV cache cannot reserve enough pages for a sequence within the
    /// configured byte budget — the request can NEVER be admitted (as
    /// opposed to "wait until another sequence retires").
    CacheBudgetExhausted { needed_bytes: usize, budget_bytes: usize },
    /// A decode step named a cache slot that is not currently claimed (or
    /// named the same slot twice in one step).
    BadSlot { slot: usize, detail: &'static str },
    /// A prefill/decode call would append more positions than the slot's
    /// claim reserved pages for. The serving layer checks this BEFORE
    /// touching the cache, so the `KvCache::append` reservation assert
    /// stays unreachable — re-claim with a larger `positions` instead.
    ReservationExceeded { slot: usize, reserved: usize, needed: usize },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnknownAdapter { name, have } => {
                write!(f, "no adapter named '{name}' is attached (have: {have:?})")
            }
            ServeError::DimMismatch { index, got, want } => {
                write!(
                    f,
                    "request[{index}]: input has {got} features, the served linear takes {want}"
                )
            }
            ServeError::BatchTooLarge { got, max_batch } => {
                write!(
                    f,
                    "batch of {got} requests exceeds max_batch = {max_batch}; split it \
                     (e.g. via Scheduler) or raise ServeConfig::max_batch"
                )
            }
            ServeError::RankTooLarge { adapter, module, rank, m, n } => write!(
                f,
                "adapter '{adapter}' declares rank {rank} for module '{module}', but the \
                 weight is {m}x{n}: a rank > min(m, n) = {} update is not low-rank — \
                 lower the rank or serve the adapter merged/dense",
                m.min(n)
            ),
            ServeError::QuantizedAdapter { adapter, strategy } => write!(
                f,
                "adapter '{adapter}' uses quantized strategy '{strategy}': its frozen NF4 \
                 base is not the shared full-precision W, so the full-precision serving \
                 strategies cannot express it; serve it with the fused-quant strategy \
                 (ServeStrategy::FusedQuant streams an NF4 base through the dequant-GEMM \
                 fused forward)"
            ),
            ServeError::UnknownModule { module } => {
                write!(f, "unknown module '{module}' (expected one of {:?})", LINEARS)
            }
            ServeError::LayerOutOfRange { layer, n_layers } => {
                write!(f, "layer {layer} out of range (base model has {n_layers} layers)")
            }
            ServeError::TokenOutOfRange { index, token, vocab } => {
                write!(
                    f,
                    "request[{index}]: token id {token} out of range (embedding table has \
                     {vocab} entries)"
                )
            }
            ServeError::ScopeMismatch { server, scope } => {
                write!(
                    f,
                    "{server} cannot serve a {scope} config; use ServeConfig::new(module) \
                     for a Server and ServeConfig::full_model() for a ModelServer"
                )
            }
            ServeError::SeqTooLong { prompt, max_new, max_seq } => write!(
                f,
                "sequence of {prompt} prompt tokens + up to {max_new} generated exceeds \
                 max_seq = {max_seq}; shorten the request or raise ServeConfig::max_seq"
            ),
            ServeError::CacheBudgetExhausted { needed_bytes, budget_bytes } => write!(
                f,
                "KV cache needs {needed_bytes} bytes for this sequence but the whole \
                 budget is {budget_bytes}; raise ServeConfig::kv_budget_bytes or lower \
                 max_seq/slots"
            ),
            ServeError::BadSlot { slot, detail } => {
                write!(f, "KV-cache slot {slot}: {detail}")
            }
            ServeError::ReservationExceeded { slot, reserved, needed } => write!(
                f,
                "KV-cache slot {slot}: appending would commit {needed} positions but the \
                 claim reserved only {reserved}; claim the slot for the sequence's full \
                 worst case (prompt + max_new) before prefilling"
            ),
        }
    }
}

impl ServeError {
    /// HTTP status the wire API maps this error to. Request-shaped
    /// faults are 4xx (the client can fix them); capacity faults are
    /// 429/503 (retryable); config/operator faults are 500 — a request
    /// should never have been able to trigger them.
    pub fn http_status(&self) -> u16 {
        match self {
            ServeError::UnknownAdapter { .. } => 404,
            ServeError::DimMismatch { .. }
            | ServeError::TokenOutOfRange { .. }
            | ServeError::SeqTooLong { .. }
            | ServeError::ReservationExceeded { .. } => 422,
            ServeError::BatchTooLarge { .. } => 429,
            ServeError::CacheBudgetExhausted { .. } => 503,
            ServeError::RankTooLarge { .. }
            | ServeError::QuantizedAdapter { .. }
            | ServeError::UnknownModule { .. }
            | ServeError::LayerOutOfRange { .. }
            | ServeError::ScopeMismatch { .. }
            | ServeError::BadSlot { .. } => 500,
        }
    }

    /// Short snake_case reason key for metrics and the wire API's typed
    /// error bodies (`{"error": {"code": ...}}`).
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::UnknownAdapter { .. } => "unknown_adapter",
            ServeError::DimMismatch { .. } => "dim_mismatch",
            ServeError::BatchTooLarge { .. } => "batch_too_large",
            ServeError::RankTooLarge { .. } => "rank_too_large",
            ServeError::QuantizedAdapter { .. } => "quantized_adapter",
            ServeError::UnknownModule { .. } => "unknown_module",
            ServeError::LayerOutOfRange { .. } => "layer_out_of_range",
            ServeError::TokenOutOfRange { .. } => "token_out_of_range",
            ServeError::ScopeMismatch { .. } => "scope_mismatch",
            ServeError::SeqTooLong { .. } => "seq_too_long",
            ServeError::CacheBudgetExhausted { .. } => "cache_budget_exhausted",
            ServeError::BadSlot { .. } => "bad_slot",
            ServeError::ReservationExceeded { .. } => "reservation_exceeded",
        }
    }
}

impl std::error::Error for ServeError {}

/// Declarative serving configuration. Build with [`ServeConfig::new`]
/// (single linear) or [`ServeConfig::full_model`] (whole forward pass)
/// and the chained setters, then hand to `Server::new` /
/// `ModelServer::new` (which validate).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// What is served: one linear or the whole model.
    pub scope: ServeScope,
    /// Which of the seven linears is served (single-linear scope only).
    pub module: String,
    /// Which layer of the stacked weight (single-linear scope only).
    pub layer: usize,
    /// Batch execution strategy.
    pub strategy: ServeStrategy,
    /// Scheduler batch ceiling (occupancy is reported against this).
    pub max_batch: usize,
    /// Longest sequence (prompt + generated) the decode path serves; the
    /// per-slot KV-cache reservation ceiling.
    pub max_seq: usize,
    /// Concurrent-sequence budget of the continuous-batching decode
    /// scheduler (and the KV cache's slot count).
    pub decode_slots: usize,
    /// Byte budget for the slot-paged KV cache across ALL slots; page
    /// reservations beyond it are a typed
    /// [`ServeError::CacheBudgetExhausted`].
    pub kv_budget_bytes: usize,
    /// Attention (query) heads of the decode path. `d_model` must divide
    /// evenly into `n_heads` slices of `head_dim = d_model / n_heads`.
    /// The default of 1 reproduces the original single-head-over-d_model
    /// attention bit for bit.
    pub n_heads: usize,
    /// K/V heads for grouped-query attention: query head `h` reads cached
    /// K/V head `h / (n_heads / n_kv_heads)`, and the KV cache stores
    /// only `n_kv_heads × head_dim` floats per position per layer (2×,
    /// for K and V). Must divide `n_heads`; `n_kv_heads == n_heads` is
    /// plain multi-head attention.
    pub n_kv_heads: usize,
    /// Rotary-embedding base frequency (e.g. 10000.0). `0.0` disables
    /// RoPE entirely — the default, which keeps legacy configs
    /// bit-identical to the pre-head-aware decode path. When enabled,
    /// `head_dim` must be even (features rotate in pairs).
    pub rope_theta: f64,
    /// Chunked-prefill granularity of the [`super::DecodeScheduler`]: an
    /// admitted prompt prefills at most this many tokens per scheduler
    /// step, interleaved with decode steps of the running sequences, so a
    /// long prompt no longer stalls every other sequence's next token.
    /// `0` (the default) prefills each prompt in one shot at admission —
    /// the legacy behavior. Chunking never changes any output bit (the
    /// chunked ≡ one-shot prefill contract); it only reorders wall-clock.
    pub prefill_chunk: usize,
    /// Byte budget for RESIDENT adapter state (hot f32 tensors +
    /// prepared deltas + warm NF4 copies), enforced by the
    /// [`crate::adapter::TierManager`] LRU alongside the KV budget.
    /// Adapters beyond it are demoted to warm/cold and re-attached on
    /// miss at step boundaries.
    pub adapter_budget_bytes: usize,
}

/// Default KV-cache byte budget: roomy for the synthetic workloads (the
/// tiny models here keep a full 8-slot × 256-position cache well under
/// it), small enough that a misconfigured giant reservation is caught.
pub const DEFAULT_KV_BUDGET_BYTES: usize = 64 << 20;

/// Default resident-adapter byte budget, in the same spirit: far more
/// than the synthetic multi-tenant fleets need, finite so a runaway
/// registration storm gets demoted instead of growing without bound.
pub const DEFAULT_ADAPTER_BUDGET_BYTES: usize = 256 << 20;

impl ServeConfig {
    pub fn new(module: &str) -> ServeConfig {
        ServeConfig {
            scope: ServeScope::SingleLinear,
            module: module.to_string(),
            layer: 0,
            strategy: ServeStrategy::Fused,
            max_batch: 64,
            max_seq: 128,
            decode_slots: 8,
            kv_budget_bytes: DEFAULT_KV_BUDGET_BYTES,
            n_heads: 1,
            n_kv_heads: 1,
            rope_theta: 0.0,
            prefill_chunk: 0,
            adapter_budget_bytes: DEFAULT_ADAPTER_BUDGET_BYTES,
        }
    }

    /// Whole-model scope: every layer × all seven linears, embed → head.
    /// `module`/`layer` are unused (and left at their defaults).
    pub fn full_model() -> ServeConfig {
        ServeConfig { scope: ServeScope::FullModel, ..ServeConfig::new("q") }
    }

    pub fn layer(mut self, layer: usize) -> ServeConfig {
        self.layer = layer;
        self
    }

    pub fn strategy(mut self, strategy: ServeStrategy) -> ServeConfig {
        self.strategy = strategy;
        self
    }

    pub fn max_batch(mut self, max_batch: usize) -> ServeConfig {
        self.max_batch = max_batch;
        self
    }

    /// Sequence-length ceiling of the decode path (prompt + generated).
    pub fn max_seq(mut self, max_seq: usize) -> ServeConfig {
        self.max_seq = max_seq;
        self
    }

    /// Concurrent-sequence slots of the continuous-batching scheduler.
    pub fn slots(mut self, slots: usize) -> ServeConfig {
        self.decode_slots = slots;
        self
    }

    /// KV-cache byte budget across all slots.
    pub fn kv_budget_bytes(mut self, bytes: usize) -> ServeConfig {
        self.kv_budget_bytes = bytes;
        self
    }

    /// Resident-adapter byte budget for the residency tier manager.
    pub fn adapter_budget_bytes(mut self, bytes: usize) -> ServeConfig {
        self.adapter_budget_bytes = bytes;
        self
    }

    /// Attention head layout: `n_heads` query heads sharing `n_kv_heads`
    /// cached K/V heads (GQA). `heads(n, n)` is plain multi-head
    /// attention; `heads(1, 1)` is the legacy single-head path.
    pub fn heads(mut self, n_heads: usize, n_kv_heads: usize) -> ServeConfig {
        self.n_heads = n_heads;
        self.n_kv_heads = n_kv_heads;
        self
    }

    /// Enable rotary position embeddings with base frequency `theta`
    /// (0.0 disables).
    pub fn rope_theta(mut self, theta: f64) -> ServeConfig {
        self.rope_theta = theta;
        self
    }

    /// Chunked-prefill granularity of the decode scheduler (0 = one-shot
    /// prefill at admission).
    pub fn prefill_chunk(mut self, chunk: usize) -> ServeConfig {
        self.prefill_chunk = chunk;
        self
    }

    /// Per-head feature width under this config for a model of `d_model`.
    pub fn head_dim(&self, d_model: usize) -> usize {
        d_model / self.n_heads
    }

    /// Cached K/V row width per position per layer: `n_kv_heads ×
    /// head_dim` floats. With the default single-head layout this equals
    /// `d_model` — the pre-GQA cache shape.
    pub fn kv_dim(&self, d_model: usize) -> usize {
        self.n_kv_heads * self.head_dim(d_model)
    }

    /// Validate the config against a concrete engine: known module, layer
    /// in range (single-linear scope), and every attached adapter
    /// servable on every linear the scope covers — one `(module, layer)`
    /// for [`ServeScope::SingleLinear`], all `n_layers × 7` for
    /// [`ServeScope::FullModel`]. Quantized adapters need a
    /// quantized-base strategy (`fused-quant`/`dequant-dense`) — under
    /// the full-precision strategies their frozen NF4 base is not the
    /// shared `W`, so the typed error points at the escape hatch. The
    /// fused-style strategies additionally require declared rank ≤
    /// min(m, n) of each served weight (the merged/dense strategies
    /// accept any rank).
    pub fn validate(&self, engine: &AdapterEngine) -> Result<()> {
        anyhow::ensure!(self.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(self.max_seq >= 1, "max_seq must be >= 1");
        anyhow::ensure!(self.decode_slots >= 1, "decode_slots must be >= 1");
        anyhow::ensure!(self.n_heads >= 1, "n_heads must be >= 1");
        anyhow::ensure!(self.n_kv_heads >= 1, "n_kv_heads must be >= 1");
        anyhow::ensure!(
            self.n_heads % self.n_kv_heads == 0,
            "n_kv_heads = {} must divide n_heads = {} (every query head needs exactly one \
             cached K/V head)",
            self.n_kv_heads,
            self.n_heads
        );
        anyhow::ensure!(
            self.rope_theta >= 0.0 && self.rope_theta.is_finite(),
            "rope_theta must be finite and >= 0 (0 disables RoPE), got {}",
            self.rope_theta
        );
        if self.scope == ServeScope::FullModel {
            // The attention head layout slices d_model; read it off the
            // q projection (d_model × d_model) without copying weights.
            let (d_model, _) = engine.base_dims("q");
            anyhow::ensure!(
                d_model % self.n_heads == 0,
                "n_heads = {} must divide d_model = {d_model} evenly",
                self.n_heads
            );
            if self.rope_theta > 0.0 {
                let head_dim = d_model / self.n_heads;
                anyhow::ensure!(
                    head_dim % 2 == 0,
                    "RoPE rotates features in pairs: head_dim = d_model / n_heads = \
                     {head_dim} must be even (d_model {d_model}, n_heads {})",
                    self.n_heads
                );
            }
        }
        match self.scope {
            ServeScope::SingleLinear => {
                if !LINEARS.contains(&self.module.as_str()) {
                    return Err(ServeError::UnknownModule { module: self.module.clone() }.into());
                }
                let n_layers = engine.base().n_layers();
                if self.layer >= n_layers {
                    return Err(
                        ServeError::LayerOutOfRange { layer: self.layer, n_layers }.into()
                    );
                }
                self.validate_module(engine, &self.module)
            }
            ServeScope::FullModel => {
                // Every adapter must be servable on every linear it
                // targets. Nothing in the servability check varies by
                // layer (one module's stacked weights share a shape), so
                // one pass over the seven modules covers all L×7 linears.
                if engine.base().n_layers() == 0 {
                    return Err(
                        ServeError::LayerOutOfRange { layer: 0, n_layers: 0 }.into()
                    );
                }
                for module in LINEARS {
                    self.validate_module(engine, module)?;
                }
                Ok(())
            }
        }
    }

    /// The per-module servability check shared by both scopes. Reads the
    /// weight dims off the stacked tensor — no matrix is copied out.
    fn validate_module(&self, engine: &AdapterEngine, module: &str) -> Result<()> {
        for name in engine.names() {
            self.check_adapter_on_module(engine, name, module)?;
        }
        Ok(())
    }

    /// Servability of ONE adapter on every linear this scope covers —
    /// the same checks construction-time [`ServeConfig::validate`] runs
    /// over the whole registry, scoped to a single name so the residency
    /// layer can vet a promotion without rebuilding the server.
    pub fn validate_adapter(&self, engine: &AdapterEngine, name: &str) -> Result<()> {
        match self.scope {
            ServeScope::SingleLinear => self.check_adapter_on_module(engine, name, &self.module),
            ServeScope::FullModel => {
                for module in LINEARS {
                    self.check_adapter_on_module(engine, name, module)?;
                }
                Ok(())
            }
        }
    }

    fn check_adapter_on_module(
        &self,
        engine: &AdapterEngine,
        name: &str,
        module: &str,
    ) -> Result<()> {
        let (m, n) = engine.base_dims(module);
        let ad = engine.get(name)?;
        if !ad.spec.targets_module(module) {
            return Ok(()); // served straight from the base weight
        }
        if ad.spec.quantized() && !self.strategy.quantized_base() {
            return Err(ServeError::QuantizedAdapter {
                adapter: name.to_string(),
                strategy: ad.spec.name(),
            }
            .into());
        }
        // Only the fused-style paths depend on the update actually
        // being low-rank; the merged/dense strategies serve any rank
        // correctly (the error message points there).
        let rank = ad.spec.module_rank(module);
        if self.strategy.fused_low_rank() && rank > m.min(n) {
            return Err(ServeError::RankTooLarge {
                adapter: name.to_string(),
                module: module.to_string(),
                rank,
                m,
                n,
            }
            .into());
        }
        Ok(())
    }

    /// (in_dim, out_dim) of the served linear under `cfg` for a given
    /// model config — handy for request construction. Errors under the
    /// full-model scope (there is no single served linear).
    pub fn dims_for(&self, cfg: &crate::runtime::ConfigInfo) -> Result<(usize, usize)> {
        anyhow::ensure!(
            self.scope == ServeScope::SingleLinear,
            "dims_for: a {} config serves every linear, not one",
            self.scope.name()
        );
        linear_dims(cfg, &self.module)
    }
}

impl fmt::Display for ServeConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.scope {
            ServeScope::SingleLinear => write!(
                f,
                "{}[{}]:{}:max_batch={}",
                self.module,
                self.layer,
                self.strategy.name(),
                self.max_batch
            ),
            ServeScope::FullModel => write!(
                f,
                "full-model:{}:max_batch={}",
                self.strategy.name(),
                self.max_batch
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrip() {
        for s in ServeStrategy::all() {
            assert_eq!(ServeStrategy::parse(s.name()).unwrap(), s);
        }
        assert_eq!(ServeStrategy::parse("merge").unwrap(), ServeStrategy::MergePerRequest);
        assert_eq!(ServeStrategy::parse("dense").unwrap(), ServeStrategy::DensePerAdapter);
        assert_eq!(ServeStrategy::parse("quant").unwrap(), ServeStrategy::FusedQuant);
        assert_eq!(ServeStrategy::parse("dequant").unwrap(), ServeStrategy::DequantDense);
        assert!(ServeStrategy::parse("bogus").is_err());
    }

    #[test]
    fn strategy_classification_helpers() {
        for s in ServeStrategy::exact() {
            assert!(!s.quantized_base(), "{} should be full-precision", s.name());
        }
        for s in [ServeStrategy::FusedQuant, ServeStrategy::DequantDense] {
            assert!(s.quantized_base() && s.fused_low_rank());
        }
        assert!(ServeStrategy::Fused.fused_low_rank());
        assert!(!ServeStrategy::MergePerRequest.fused_low_rank());
        assert!(!ServeStrategy::DensePerAdapter.fused_low_rank());
    }

    #[test]
    fn builder_and_display() {
        let c =
            ServeConfig::new("q").layer(1).strategy(ServeStrategy::DensePerAdapter).max_batch(8);
        assert_eq!(c.scope, ServeScope::SingleLinear);
        assert_eq!(c.module, "q");
        assert_eq!(c.layer, 1);
        assert_eq!(c.max_batch, 8);
        assert_eq!(c.to_string(), "q[1]:dense-per-adapter:max_batch=8");
    }

    #[test]
    fn decode_knobs_build_and_error_messages_point_at_them() {
        let c = ServeConfig::full_model().max_seq(256).slots(4).kv_budget_bytes(1 << 20);
        assert_eq!(c.max_seq, 256);
        assert_eq!(c.decode_slots, 4);
        assert_eq!(c.kv_budget_bytes, 1 << 20);
        assert_eq!(ServeConfig::new("q").kv_budget_bytes, DEFAULT_KV_BUDGET_BYTES);
        let e = ServeError::SeqTooLong { prompt: 100, max_new: 50, max_seq: 128 };
        let msg = e.to_string();
        assert!(msg.contains("128") && msg.contains("max_seq"), "{msg}");
        let e = ServeError::CacheBudgetExhausted { needed_bytes: 4096, budget_bytes: 1024 };
        assert!(e.to_string().contains("kv_budget_bytes"), "{}", e);
        let e = ServeError::BadSlot { slot: 3, detail: "not claimed" };
        assert!(e.to_string().contains("slot 3"), "{}", e);
    }

    #[test]
    fn head_knobs_build_with_legacy_defaults() {
        // Defaults reproduce the pre-head-aware decode path: one head
        // over all of d_model, no RoPE, one-shot prefill.
        let c = ServeConfig::full_model();
        assert_eq!((c.n_heads, c.n_kv_heads), (1, 1));
        assert_eq!(c.rope_theta, 0.0);
        assert_eq!(c.prefill_chunk, 0);
        assert_eq!(c.head_dim(32), 32);
        assert_eq!(c.kv_dim(32), 32);
        // GQA shrinks the cached row width: 8 heads over d_model 32 →
        // head_dim 4, 2 KV heads → kv_dim 8.
        let c = ServeConfig::full_model().heads(8, 2).rope_theta(10000.0).prefill_chunk(16);
        assert_eq!((c.n_heads, c.n_kv_heads), (8, 2));
        assert_eq!(c.head_dim(32), 4);
        assert_eq!(c.kv_dim(32), 8);
        assert_eq!(c.rope_theta, 10000.0);
        assert_eq!(c.prefill_chunk, 16);
    }

    #[test]
    fn reservation_exceeded_error_shape() {
        let e = ServeError::ReservationExceeded { slot: 2, reserved: 8, needed: 11 };
        let msg = e.to_string();
        assert!(msg.contains("slot 2") && msg.contains('8') && msg.contains("11"), "{msg}");
        assert_eq!(e.http_status(), 422);
        assert_eq!(e.code(), "reservation_exceeded");
    }

    #[test]
    fn full_model_scope_builder_and_display() {
        let c = ServeConfig::full_model().strategy(ServeStrategy::FusedQuant).max_batch(16);
        assert_eq!(c.scope, ServeScope::FullModel);
        assert_eq!(c.to_string(), "full-model:fused-quant:max_batch=16");
        assert_eq!(ServeScope::FullModel.name(), "full-model");
        assert_eq!(ServeScope::SingleLinear.name(), "single-linear");
        // No single served linear under the full-model scope.
        let cfg = crate::runtime::ConfigInfo {
            name: "t".into(),
            kind: "decoder".into(),
            vocab: 8,
            d_model: 4,
            n_layers: 1,
            n_heads: 1,
            d_ff: 8,
            seq_len: 4,
            batch: 1,
            eval_batch: 1,
            n_classes: 0,
            ranks: vec![1],
        };
        assert!(c.dims_for(&cfg).is_err());
        assert_eq!(ServeConfig::new("gate").dims_for(&cfg).unwrap(), (4, 8));
    }

    #[test]
    fn serve_error_http_status_and_code_mapping() {
        // Request-shaped faults → 4xx; capacity → 429/503; config → 500.
        let unknown = ServeError::UnknownAdapter { name: "g".into(), have: vec![] };
        assert_eq!(unknown.http_status(), 404);
        assert_eq!(unknown.code(), "unknown_adapter");
        let too_long = ServeError::SeqTooLong { prompt: 9, max_new: 9, max_seq: 8 };
        assert_eq!(too_long.http_status(), 422);
        assert_eq!(too_long.code(), "seq_too_long");
        let tok = ServeError::TokenOutOfRange { index: 0, token: 99, vocab: 8 };
        assert_eq!(tok.http_status(), 422);
        let budget = ServeError::CacheBudgetExhausted { needed_bytes: 9, budget_bytes: 1 };
        assert_eq!(budget.http_status(), 503);
        assert_eq!(budget.code(), "cache_budget_exhausted");
        assert_eq!(ServeError::BatchTooLarge { got: 9, max_batch: 1 }.http_status(), 429);
        let cfg_fault = ServeError::BadSlot { slot: 3, detail: "free" };
        assert_eq!(cfg_fault.http_status(), 500);
    }

    #[test]
    fn serve_error_messages_name_the_problem() {
        let e = ServeError::RankTooLarge {
            adapter: "t".into(),
            module: "q".into(),
            rank: 40,
            m: 32,
            n: 32,
        };
        let msg = e.to_string();
        assert!(msg.contains("rank 40") && msg.contains("min(m, n) = 32"), "{msg}");
        let u = ServeError::UnknownAdapter { name: "ghost".into(), have: vec!["a".into()] };
        assert!(u.to_string().contains("ghost"));
    }

    #[test]
    fn quantized_adapter_message_names_the_fused_quant_escape_hatch() {
        // The wall became a strategy choice: the error must tell the
        // operator that quantized bases ARE servable, and how.
        let e = ServeError::QuantizedAdapter { adapter: "qp".into(), strategy: "qpissa" };
        let msg = e.to_string();
        assert!(msg.contains("qp") && msg.contains("qpissa"), "{msg}");
        assert!(
            msg.contains("fused-quant") && msg.contains("FusedQuant"),
            "message must name the supported escape hatch: {msg}"
        );
        assert!(
            !msg.contains("cannot be expressed"),
            "stale 'cannot' phrasing survived the reword: {msg}"
        );
    }
}
