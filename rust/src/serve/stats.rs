//! Serving statistics: per-adapter hit counts, batch occupancy, latency
//! percentiles, and (for multi-linear servers) the aggregated residency
//! breakdown — the operational surface of the serving runtime, exported
//! as JSON through the `metrics` sinks.

use crate::util::json::{jnum, Json};
use crate::util::timer::BenchStats;
use std::collections::{BTreeMap, VecDeque};

/// Display key for base-only (adapter-less) requests in the hit table.
pub const BASE_KEY: &str = "<base>";

/// Trailing window for the per-batch samples (latency, occupancy,
/// group fan-out). Totals and hit counts stay exact over the server's
/// lifetime; percentiles are over the last `SAMPLE_WINDOW` batches, so
/// memory and `summary()` cost stay bounded under sustained traffic.
pub const SAMPLE_WINDOW: usize = 4096;

/// Accumulated serving counters. One instance lives inside the server and
/// is updated per executed batch; `summary()`/`to_json()` roll it up.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    /// Executed batches.
    pub batches: usize,
    /// Served requests (sum of batch sizes).
    pub requests: usize,
    /// Requests per adapter name (base-only requests under [`BASE_KEY`]).
    pub hits: BTreeMap<String, usize>,
    /// Adapter groups touched per batch (scheduling fan-out), last
    /// [`SAMPLE_WINDOW`] batches.
    group_counts: VecDeque<usize>,
    /// batch_size / max_batch per batch, last [`SAMPLE_WINDOW`] batches.
    occupancies: VecDeque<f64>,
    /// Wall-clock seconds per batch, last [`SAMPLE_WINDOW`] batches.
    latencies_s: VecDeque<f64>,
    /// Exact lifetime sum of batch latencies (throughput denominator).
    total_s: f64,
    // ---- decode-path counters (prefill / decode_step / TTFT) ----------
    /// Admitted sequences (prefills executed).
    pub prefills: usize,
    /// Prompt tokens prefilled (exact lifetime total).
    pub prefill_tokens: usize,
    /// Exact lifetime seconds spent in prefills.
    prefill_s: f64,
    /// Decode steps executed.
    pub decode_steps: usize,
    /// Tokens decoded (one per sequence per step; exact lifetime total).
    pub decode_tokens: usize,
    /// Exact lifetime seconds spent in decode steps.
    decode_s: f64,
    /// Exact lifetime seconds of decode-step time spent inside the
    /// attention kernel (timed around the parallel attention dispatch of
    /// every layer); the remainder of `decode_s` is the linear path
    /// (projections + MLP + head). Tells a deployment whether it is
    /// attention-bound or GEMM-bound straight from `/metrics`.
    decode_attn_s: f64,
    /// Submit→first-token latency per sequence, last [`SAMPLE_WINDOW`].
    ttft_s: VecDeque<f64>,
    /// Rejected sequences by reason (exact lifetime totals) — requests
    /// dropped at admission by the observed decode path (unknown
    /// adapter, over-budget, empty prompt) rather than served.
    pub rejections: BTreeMap<String, usize>,
}

/// Rolled-up view of [`ServeStats`]. `batches`/`requests`/`total_s`/
/// `req_per_s` are exact over the server's lifetime; means and
/// percentiles are over the trailing [`SAMPLE_WINDOW`] batches.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub batches: usize,
    pub requests: usize,
    pub mean_occupancy: f64,
    pub mean_groups: f64,
    /// Per-batch latency percentiles, in seconds (0 when nothing ran).
    pub p50_s: f64,
    pub p95_s: f64,
    pub total_s: f64,
    /// Requests per second over the measured batches.
    pub req_per_s: f64,
    // ---- decode-path rollup -------------------------------------------
    /// Admitted sequences / prefilled prompt tokens / decoded tokens.
    pub prefills: usize,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    /// Time-to-first-token percentiles over the trailing window
    /// (0 when no sequence ran).
    pub ttft_p50_s: f64,
    pub ttft_p95_s: f64,
    /// Decoded tokens per second of decode-step time (the steady-state
    /// generation rate; 0 when no decode step ran).
    pub decode_tok_per_s: f64,
    /// End-to-end generated tokens per second (prefill + decode time).
    pub seq_tok_per_s: f64,
    /// Lifetime decode-step seconds spent in the attention kernel.
    pub attn_secs: f64,
    /// Lifetime decode-step seconds spent outside attention (projections,
    /// MLP, head — the GEMM-bound remainder): `decode_s - attn_secs`.
    pub linear_secs: f64,
}

/// Bounded push: drop the oldest sample once the window is full.
fn push_windowed<T>(q: &mut VecDeque<T>, v: T) {
    if q.len() == SAMPLE_WINDOW {
        q.pop_front();
    }
    q.push_back(v);
}

fn mean_of(iter: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0, 0usize);
    for v in iter {
        sum += v;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats::default()
    }

    /// Record one executed batch: who was hit, how full the batch was,
    /// how many adapter groups it split into, and how long it took.
    pub fn record_batch(
        &mut self,
        adapters: &[Option<&str>],
        n_groups: usize,
        max_batch: usize,
        secs: f64,
    ) {
        self.batches += 1;
        self.requests += adapters.len();
        for a in adapters {
            let key = a.unwrap_or(BASE_KEY).to_string();
            *self.hits.entry(key).or_insert(0) += 1;
        }
        push_windowed(&mut self.group_counts, n_groups);
        push_windowed(&mut self.occupancies, adapters.len() as f64 / max_batch.max(1) as f64);
        push_windowed(&mut self.latencies_s, secs);
        self.total_s += secs;
    }

    /// Record one executed prefill: the sequence's adapter counts as a
    /// served request (hit table included), its tokens toward the
    /// prefill totals.
    pub fn record_prefill(&mut self, adapter: Option<&str>, tokens: usize, secs: f64) {
        self.requests += 1;
        *self.hits.entry(adapter.unwrap_or(BASE_KEY).to_string()).or_insert(0) += 1;
        self.prefills += 1;
        self.prefill_tokens += tokens;
        self.prefill_s += secs;
        self.total_s += secs;
    }

    /// Record one continuous-batching decode step: `batch` sequences each
    /// produced one token; occupancy is measured against the slot budget;
    /// `attn_secs` is the step time spent inside the attention kernel
    /// (the rest of `secs` is the linear path).
    pub fn record_decode_step(
        &mut self,
        batch: usize,
        n_groups: usize,
        slots: usize,
        secs: f64,
        attn_secs: f64,
    ) {
        self.decode_steps += 1;
        self.decode_tokens += batch;
        self.decode_s += secs;
        self.decode_attn_s += attn_secs;
        self.total_s += secs;
        push_windowed(&mut self.group_counts, n_groups);
        push_windowed(&mut self.occupancies, batch as f64 / slots.max(1) as f64);
        push_windowed(&mut self.latencies_s, secs);
    }

    /// Record one sequence's submit→first-token latency.
    pub fn record_ttft(&mut self, secs: f64) {
        push_windowed(&mut self.ttft_s, secs);
    }

    /// Record one rejected sequence under a short reason key (e.g.
    /// `"unknown_adapter"`, `"cache_budget_exhausted"`).
    pub fn record_rejection(&mut self, reason: &str) {
        *self.rejections.entry(reason.to_string()).or_insert(0) += 1;
    }

    pub fn reset(&mut self) {
        *self = ServeStats::default();
    }

    pub fn summary(&self) -> ServeSummary {
        let (p50_s, p95_s) = if self.latencies_s.is_empty() {
            (0.0, 0.0)
        } else {
            let s = BenchStats::from_samples(self.latencies_s.iter().copied().collect());
            (s.p50, s.p95)
        };
        let (ttft_p50_s, ttft_p95_s) = if self.ttft_s.is_empty() {
            (0.0, 0.0)
        } else {
            let s = BenchStats::from_samples(self.ttft_s.iter().copied().collect());
            (s.p50, s.p95)
        };
        let gen_s = self.prefill_s + self.decode_s;
        ServeSummary {
            batches: self.batches,
            requests: self.requests,
            mean_occupancy: mean_of(self.occupancies.iter().copied()),
            mean_groups: mean_of(self.group_counts.iter().map(|&g| g as f64)),
            p50_s,
            p95_s,
            total_s: self.total_s,
            req_per_s: if self.total_s > 0.0 {
                self.requests as f64 / self.total_s
            } else {
                0.0
            },
            prefills: self.prefills,
            prefill_tokens: self.prefill_tokens,
            decode_tokens: self.decode_tokens,
            ttft_p50_s,
            ttft_p95_s,
            decode_tok_per_s: if self.decode_s > 0.0 {
                self.decode_tokens as f64 / self.decode_s
            } else {
                0.0
            },
            // Every prefill emits the sequence's first token; decode
            // steps emit the rest.
            seq_tok_per_s: if gen_s > 0.0 {
                (self.prefills + self.decode_tokens) as f64 / gen_s
            } else {
                0.0
            },
            attn_secs: self.decode_attn_s,
            linear_secs: self.decode_s - self.decode_attn_s,
        }
    }

    /// JSON export (the `serve` CLI and the throughput bench write this
    /// through the `metrics` sinks).
    pub fn to_json(&self) -> Json {
        let s = self.summary();
        let mut o = Json::obj();
        o.set("batches", jnum(s.batches as f64));
        o.set("requests", jnum(s.requests as f64));
        o.set("mean_occupancy", jnum(s.mean_occupancy));
        o.set("mean_groups", jnum(s.mean_groups));
        o.set("p50_ms", jnum(s.p50_s * 1e3));
        o.set("p95_ms", jnum(s.p95_s * 1e3));
        o.set("total_s", jnum(s.total_s));
        o.set("req_per_s", jnum(s.req_per_s));
        o.set("prefills", jnum(s.prefills as f64));
        o.set("prefill_tokens", jnum(s.prefill_tokens as f64));
        o.set("decode_tokens", jnum(s.decode_tokens as f64));
        o.set("ttft_p50_ms", jnum(s.ttft_p50_s * 1e3));
        o.set("ttft_p95_ms", jnum(s.ttft_p95_s * 1e3));
        o.set("decode_tok_per_s", jnum(s.decode_tok_per_s));
        o.set("seq_tok_per_s", jnum(s.seq_tok_per_s));
        o.set("attn_secs", jnum(s.attn_secs));
        o.set("linear_secs", jnum(s.linear_secs));
        let mut hits = Json::obj();
        for (k, v) in &self.hits {
            hits.set(k, jnum(*v as f64));
        }
        o.set("hits", hits);
        let mut rej = Json::obj();
        for (k, v) in &self.rejections {
            rej.set(k, jnum(*v as f64));
        }
        o.set("rejections", rej);
        o
    }
}

/// Residency accounting for a server that aggregates MANY linears (the
/// whole-model pipeline's `L × 7` base stores): bytes kept resident per
/// module (summed over layers) plus the dense-fp32 denominator, i.e. the
/// §Full-model-serving table of EXPERIMENTS.md.
#[derive(Debug, Clone)]
pub struct ResidentBreakdown {
    /// (module, resident bytes summed over its layers), in module order.
    pub per_module: Vec<(String, usize)>,
    /// What the same linears would hold resident as dense fp32.
    pub dense_bytes: usize,
    /// Live KV-cache pages (0 for one-shot servers without a cache); NOT
    /// part of [`ResidentBreakdown::total`] — the base-residency ratio
    /// stays comparable across PRs — but reported alongside it.
    ///
    /// Each cached position costs `2 × n_layers × kv_dim × 4` bytes (K
    /// and V rows per layer, f32), where `kv_dim = n_kv_heads ×
    /// head_dim` — so a GQA config (`n_kv_heads < n_heads`) shrinks
    /// this by `n_kv_heads / n_heads` versus the single-head layout at
    /// the same `d_model`, before the page-granular rounding of
    /// [`crate::serve::KvCache::pages_for`].
    pub kv_bytes: usize,
    /// Per-residency-tier adapter accounting `(tier, adapter count,
    /// resident bytes)` from the [`crate::adapter::TierManager`] — empty
    /// for untiered servers (every adapter implicitly hot, unbudgeted).
    /// Like `kv_bytes`, NOT part of [`ResidentBreakdown::total`]: the
    /// base-residency ratio stays comparable across PRs.
    pub adapter_tiers: Vec<(String, usize, usize)>,
}

impl ResidentBreakdown {
    pub fn new(per_module: Vec<(String, usize)>, dense_bytes: usize) -> ResidentBreakdown {
        ResidentBreakdown { per_module, dense_bytes, kv_bytes: 0, adapter_tiers: Vec::new() }
    }

    /// Attach the decode path's live KV-cache bytes.
    pub fn with_kv_bytes(mut self, kv_bytes: usize) -> ResidentBreakdown {
        self.kv_bytes = kv_bytes;
        self
    }

    /// Attach the residency tier manager's per-tier adapter table.
    pub fn with_adapter_tiers(
        mut self,
        tiers: Vec<(&'static str, usize, usize)>,
    ) -> ResidentBreakdown {
        self.adapter_tiers =
            tiers.into_iter().map(|(t, c, b)| (t.to_string(), c, b)).collect();
        self
    }

    /// RAM held by tier-managed adapters (hot f32 + warm NF4).
    pub fn adapter_bytes(&self) -> usize {
        self.adapter_tiers.iter().map(|(_, _, b)| b).sum()
    }

    /// Aggregate resident bytes across every module.
    pub fn total(&self) -> usize {
        self.per_module.iter().map(|(_, b)| b).sum()
    }

    /// Base bytes plus the KV cache — what the decode server actually
    /// pins while sequences are in flight.
    pub fn total_with_kv(&self) -> usize {
        self.total() + self.kv_bytes
    }

    /// `total / dense` — the residency ratio the fused-quant strategy is
    /// measured on (≤ 0.35 is the acceptance bar).
    pub fn ratio(&self) -> f64 {
        if self.dense_bytes == 0 {
            0.0
        } else {
            self.total() as f64 / self.dense_bytes as f64
        }
    }

    /// JSON export (nested under the serve CLI / bench BENCH lines).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        let mut per = Json::obj();
        for (module, bytes) in &self.per_module {
            per.set(module, jnum(*bytes as f64));
        }
        o.set("per_module_bytes", per);
        o.set("total_bytes", jnum(self.total() as f64));
        o.set("dense_bytes", jnum(self.dense_bytes as f64));
        o.set("ratio", jnum(self.ratio()));
        o.set("kv_cache_bytes", jnum(self.kv_bytes as f64));
        o.set("total_with_kv_bytes", jnum(self.total_with_kv() as f64));
        if !self.adapter_tiers.is_empty() {
            let mut tiers = Json::obj();
            for (tier, count, bytes) in &self.adapter_tiers {
                let mut row = Json::obj();
                row.set("adapters", jnum(*count as f64));
                row.set("bytes", jnum(*bytes as f64));
                tiers.set(tier, row);
            }
            o.set("adapter_tiers", tiers);
            o.set("adapter_bytes", jnum(self.adapter_bytes() as f64));
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resident_breakdown_totals_and_ratio() {
        let bd = ResidentBreakdown::new(
            vec![("q".into(), 100), ("gate".into(), 60)],
            640,
        );
        assert_eq!(bd.total(), 160);
        assert!((bd.ratio() - 0.25).abs() < 1e-12);
        let text = bd.to_json().to_string();
        assert!(text.contains("\"gate\"") && text.contains("\"ratio\""), "{text}");
        // Degenerate denominator does not divide by zero.
        assert_eq!(ResidentBreakdown::new(vec![], 0).ratio(), 0.0);
    }

    #[test]
    fn resident_breakdown_tier_table_round_trips_to_json() {
        let bd = ResidentBreakdown::new(vec![("q".into(), 100)], 400)
            .with_adapter_tiers(vec![("hot", 2, 4096), ("warm", 1, 600), ("cold", 7, 0)]);
        assert_eq!(bd.adapter_bytes(), 4696);
        assert_eq!(bd.total(), 100, "tier bytes stay out of the base-residency ratio");
        let text = bd.to_json().to_string();
        assert!(text.contains("\"adapter_tiers\"") && text.contains("\"warm\""), "{text}");
        // Untiered servers keep the legacy shape: no adapter_tiers key.
        let plain = ResidentBreakdown::new(vec![], 0).to_json().to_string();
        assert!(!plain.contains("adapter_tiers"), "{plain}");
    }

    #[test]
    fn record_and_summarize() {
        let mut st = ServeStats::new();
        st.record_batch(&[Some("a"), Some("a"), None], 2, 4, 0.010);
        st.record_batch(&[Some("b")], 1, 4, 0.030);
        assert_eq!(st.batches, 2);
        assert_eq!(st.requests, 4);
        assert_eq!(st.hits["a"], 2);
        assert_eq!(st.hits["b"], 1);
        assert_eq!(st.hits[BASE_KEY], 1);
        let s = st.summary();
        assert_eq!(s.requests, 4);
        assert!((s.mean_occupancy - (0.75 + 0.25) / 2.0).abs() < 1e-12);
        assert!((s.mean_groups - 1.5).abs() < 1e-12);
        assert!(s.p50_s > 0.0 && s.p95_s >= s.p50_s);
        assert!((s.total_s - 0.040).abs() < 1e-12);
        assert!(s.req_per_s > 0.0);
    }

    #[test]
    fn empty_stats_do_not_divide_by_zero() {
        let st = ServeStats::new();
        let s = st.summary();
        assert_eq!(s.batches, 0);
        assert_eq!(s.p50_s, 0.0);
        assert_eq!(s.req_per_s, 0.0);
        // JSON renders without panicking
        let j = st.to_json();
        assert!(j.to_string().contains("\"requests\""));
    }

    #[test]
    fn samples_are_windowed_but_totals_stay_exact() {
        let mut st = ServeStats::new();
        for _ in 0..(SAMPLE_WINDOW + 10) {
            st.record_batch(&[Some("a")], 1, 1, 0.001);
        }
        assert_eq!(st.batches, SAMPLE_WINDOW + 10);
        assert_eq!(st.requests, SAMPLE_WINDOW + 10);
        assert_eq!(st.hits["a"], SAMPLE_WINDOW + 10);
        assert_eq!(st.latencies_s.len(), SAMPLE_WINDOW);
        assert_eq!(st.occupancies.len(), SAMPLE_WINDOW);
        assert_eq!(st.group_counts.len(), SAMPLE_WINDOW);
        let s = st.summary();
        assert!((s.total_s - 0.001 * (SAMPLE_WINDOW + 10) as f64).abs() < 1e-9);
        assert!(s.req_per_s > 0.0);
    }

    #[test]
    fn decode_counters_roll_up() {
        let mut st = ServeStats::new();
        st.record_prefill(Some("t"), 6, 0.004);
        st.record_prefill(None, 3, 0.002);
        st.record_ttft(0.005);
        st.record_ttft(0.009);
        st.record_decode_step(2, 2, 8, 0.001, 0.0004);
        st.record_decode_step(1, 1, 8, 0.003, 0.0016);
        assert_eq!(st.prefills, 2);
        assert_eq!(st.prefill_tokens, 9);
        assert_eq!(st.decode_tokens, 3);
        assert_eq!(st.hits["t"], 1);
        assert_eq!(st.hits[BASE_KEY], 1);
        let s = st.summary();
        assert_eq!((s.prefills, s.prefill_tokens, s.decode_tokens), (2, 9, 3));
        assert!(s.ttft_p50_s > 0.0 && s.ttft_p95_s >= s.ttft_p50_s);
        assert!((s.decode_tok_per_s - 3.0 / 0.004).abs() < 1e-6);
        // 2 first tokens (prefills) + 3 decoded over 0.010s total.
        assert!((s.seq_tok_per_s - 5.0 / 0.010).abs() < 1e-6);
        // occupancy measured against the slot budget
        assert!((s.mean_occupancy - (0.25 + 0.125) / 2.0).abs() < 1e-12);
        // decode time splits into attention + linear seconds.
        assert!((s.attn_secs - 0.002).abs() < 1e-12);
        assert!((s.linear_secs - 0.002).abs() < 1e-12);
        let j = st.to_json().to_string();
        assert!(j.contains("\"ttft_p50_ms\"") && j.contains("\"decode_tok_per_s\""), "{j}");
        assert!(j.contains("\"attn_secs\"") && j.contains("\"linear_secs\""), "{j}");
    }

    #[test]
    fn resident_breakdown_carries_kv_bytes() {
        let bd = ResidentBreakdown::new(vec![("q".into(), 100)], 400).with_kv_bytes(64);
        assert_eq!(bd.total(), 100);
        assert_eq!(bd.total_with_kv(), 164);
        let j = bd.to_json().to_string();
        assert!(j.contains("\"kv_cache_bytes\":64"), "{j}");
    }

    #[test]
    fn json_has_latency_and_hits() {
        let mut st = ServeStats::new();
        st.record_batch(&[Some("t0")], 1, 8, 0.002);
        let text = st.to_json().to_string();
        assert!(text.contains("\"p95_ms\"") && text.contains("\"t0\""), "{text}");
    }

    #[test]
    fn rejections_roll_up_by_reason() {
        let mut st = ServeStats::new();
        st.record_rejection("unknown_adapter");
        st.record_rejection("unknown_adapter");
        st.record_rejection("cache_budget_exhausted");
        assert_eq!(st.rejections["unknown_adapter"], 2);
        assert_eq!(st.rejections["cache_budget_exhausted"], 1);
        let j = st.to_json().to_string();
        assert!(j.contains("\"rejections\"") && j.contains("\"unknown_adapter\":2"), "{j}");
    }
}
