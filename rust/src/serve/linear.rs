//! `LinearServer` — the reusable per-linear serving unit.
//!
//! One `LinearServer` owns everything needed to execute a mixed-adapter
//! batch through ONE `(module, layer)` linear: the shared base weight in
//! the representation its strategy serves from, plus the prepared
//! per-adapter low-rank deltas `(ΔA, ΔB)` against the ORIGINAL dense
//! weight (the Appendix-C form, see [`AdapterEngine::serve_delta`]). The
//! single-linear `Server` wraps exactly one of these; the whole-model
//! `ModelServer` stacks `n_layers × 7` of them into a pipeline.
//!
//! The dense-vs-quant storage invariant is carried in the TYPE, not
//! asserted at runtime: each strategy family constructs its own [`Exec`]
//! variant, so the merged/dense execution paths hold a dense `Mat`
//! directly — there is no "this store must be dense here" branch left to
//! get wrong (the `unreachable!` the old monolithic server carried).
//!
//! A `LinearServer` operates on an already-packed batch (`X` plus the
//! router's adapter [`Group`]s); request-level validation, scheduling,
//! and stats live in the callers. `forward_into` overwrites a
//! caller-owned output buffer, so a pipeline can ping-pong two
//! activation buffers across a whole model instead of allocating a
//! fresh matrix per linear.

use super::config::ServeStrategy;
use super::router::Group;
use crate::adapter::AdapterEngine;
use crate::linalg::{
    dequant_matmul_into, dequant_vecmat_into, matmul, matmul_into, vecmat, vecmat_into, Mat,
};
use crate::quant::{dequantize, quantize, Nf4Tensor};
use crate::util::par::par_map;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Snapshot of one servable adapter on this linear:
/// `effective = W + ΔA·ΔB`. `None` when the adapter does not target the
/// served module (it serves the base weight unchanged).
#[derive(Debug, Clone)]
struct Prepared {
    delta: Option<(Mat, Mat)>,
}

/// The NF4-resident shared base of the `fused-quant` strategy: packed
/// codes + blockwise scales, streamed through the dequant-GEMM at
/// request time. The dense matrix is never materialized server-side.
/// Held behind an `Arc` so every consumer of one snapshot — e.g. the L
/// per-layer units of a full-model pipeline fed from one
/// [`crate::quant::Nf4Stack`] — shares the same resident bytes.
#[derive(Debug, Clone)]
pub struct QuantBase {
    /// Blockwise NF4 snapshot of the served base weight (shared).
    pub nf4: Arc<Nf4Tensor>,
}

impl QuantBase {
    /// Bytes this base keeps resident (packed codes + f32 scales).
    pub fn resident_bytes(&self) -> usize {
        self.nf4.storage_bytes()
    }
}

/// How the fused-family strategies store the shared base weight.
#[derive(Debug)]
enum BaseStore {
    /// Full-precision m×n matrix: the original `W` for `fused`, or the
    /// dequantized-once NF4 round trip for `dequant-dense`.
    Dense(Mat),
    /// NF4-resident base for `fused-quant` — the base GEMM streams the
    /// packed blocks panel-by-panel instead of reading a dense matrix.
    Quant(QuantBase),
}

impl BaseStore {
    /// The shared base GEMM `X·base` of the fused forward, overwriting
    /// `y` (a reusable activation buffer).
    fn forward_into(&self, x: &Mat, y: &mut Mat) {
        match self {
            BaseStore::Dense(w) => matmul_into(x, w, y),
            BaseStore::Quant(q) => dequant_matmul_into(x, &q.nf4, y),
        }
    }

    fn resident_bytes(&self) -> usize {
        match self {
            BaseStore::Dense(w) => w.data.len() * 4,
            BaseStore::Quant(q) => q.resident_bytes(),
        }
    }
}

/// Per-strategy execution state. The variant IS the strategy family, so
/// each path statically holds the base representation it needs.
#[derive(Debug)]
enum Exec {
    /// `fused` / `fused-quant` / `dequant-dense`: shared base GEMM (in
    /// whichever storage) + per-group low-rank corrections.
    Fused(BaseStore),
    /// `dense-per-adapter`: dense base, merged once per adapter group.
    GroupMerged(Mat),
    /// `merge-per-request`: dense base, merged for every single request.
    RequestMerged(Mat),
}

/// Batched mixed-adapter execution of ONE `(module, layer)` linear.
///
/// Snapshot semantics: construction copies the base weight (in the
/// strategy's representation) and every adapter's serving delta out of
/// the engine, which is then free to keep training; rebuild to pick up
/// new factors.
#[derive(Debug)]
pub struct LinearServer {
    module: String,
    layer: usize,
    n_in: usize,
    n_out: usize,
    exec: Exec,
    prepared: BTreeMap<String, Prepared>,
}

impl LinearServer {
    /// Snapshot one linear of `engine` under `strategy`. Assumes the
    /// caller has run `ServeConfig::validate` (the `Server` /
    /// `ModelServer` constructors do); engine lookups can still fail.
    ///
    /// `shared_quant` supplies a pre-built NF4 snapshot of this weight
    /// for the quantized-base strategies — the full-model pipeline hands
    /// every layer a handle from one per-module [`crate::quant::Nf4Stack`]
    /// so nothing is quantized (or kept resident) twice. `None` quantizes
    /// locally.
    pub(crate) fn snapshot(
        engine: &AdapterEngine,
        module: &str,
        layer: usize,
        strategy: ServeStrategy,
        shared_quant: Option<Arc<Nf4Tensor>>,
    ) -> Result<LinearServer> {
        // Dims come off the stacked tensor; the dense weight is only
        // copied out in the arms that actually store it (under a shared
        // NF4 snapshot the quantized strategies never touch it).
        let (n_in, n_out) = engine.base_dims(module);
        let nf4 = |sq: Option<Arc<Nf4Tensor>>| {
            sq.unwrap_or_else(|| Arc::new(quantize(&engine.base_weight(module, layer))))
        };
        let exec = match strategy {
            // NF4-resident base, streamed through the dequant-GEMM (the
            // same snapshot `AdapterEngine::quant_base_weight` hands
            // external callers).
            ServeStrategy::FusedQuant => {
                Exec::Fused(BaseStore::Quant(QuantBase { nf4: nf4(shared_quant) }))
            }
            // Same quantized snapshot, dequantized once into a dense
            // copy: bit-for-bit the FusedQuant output at fp32 residency.
            ServeStrategy::DequantDense => {
                Exec::Fused(BaseStore::Dense(dequantize(&nf4(shared_quant))))
            }
            ServeStrategy::Fused => {
                Exec::Fused(BaseStore::Dense(engine.base_weight(module, layer)))
            }
            ServeStrategy::DensePerAdapter => {
                Exec::GroupMerged(engine.base_weight(module, layer))
            }
            ServeStrategy::MergePerRequest => {
                Exec::RequestMerged(engine.base_weight(module, layer))
            }
        };
        let mut prepared = BTreeMap::new();
        for name in engine.names() {
            let delta = engine.serve_delta(name, module, layer)?;
            prepared.insert(name.to_string(), Prepared { delta });
        }
        Ok(LinearServer { module: module.to_string(), layer, n_in, n_out, exec, prepared })
    }

    pub fn module(&self) -> &str {
        &self.module
    }

    pub fn layer(&self) -> usize {
        self.layer
    }

    /// Input feature count of the served linear.
    pub fn n_in(&self) -> usize {
        self.n_in
    }

    /// Output feature count of the served linear.
    pub fn n_out(&self) -> usize {
        self.n_out
    }

    /// Names this unit can route to (snapshot order).
    pub fn adapter_names(&self) -> Vec<&str> {
        self.prepared.keys().map(|s| s.as_str()).collect()
    }

    /// Is `name` in the snapshot?
    pub fn serves(&self, name: &str) -> bool {
        self.prepared.contains_key(name)
    }

    /// Register one adapter's prepared serving delta at runtime — the
    /// residency layer's promotion path. The shared base store is
    /// untouched, so promotion never rebuilds the server; `delta` is
    /// `None` for adapters that do not target this module (exactly what
    /// [`crate::adapter::AdapterEngine::serve_delta`] returns).
    pub fn add_group(&mut self, name: &str, delta: Option<(Mat, Mat)>) {
        self.prepared.insert(name.to_string(), Prepared { delta });
    }

    /// Drop one adapter's prepared delta (demotion). Returns whether it
    /// was present.
    pub fn remove_group(&mut self, name: &str) -> bool {
        self.prepared.remove(name).is_some()
    }

    /// f32 bytes of one adapter's prepared delta on this linear (0 when
    /// absent or untargeted) — the server-side share of the hot tier's
    /// budget accounting.
    pub fn delta_bytes(&self, name: &str) -> usize {
        self.prepared
            .get(name)
            .and_then(|p| p.delta.as_ref())
            .map_or(0, |(da, db)| (da.data.len() + db.data.len()) * 4)
    }

    /// Bytes the shared base keeps resident under this strategy: m·n·4
    /// for every dense store, packed codes + scales for the NF4 store.
    pub fn resident_bytes(&self) -> usize {
        match &self.exec {
            Exec::Fused(base) => base.resident_bytes(),
            Exec::GroupMerged(w) | Exec::RequestMerged(w) => w.data.len() * 4,
        }
    }

    /// Execute one packed batch: `x` is batch × n_in, `groups` the
    /// router's bucketing of it (row indices into `x`). Allocates the
    /// output; see [`LinearServer::forward_into`] for the buffer-reusing
    /// form. Callers guarantee every group adapter is in the snapshot.
    pub fn forward(&self, x: &Mat, groups: &[Group]) -> Mat {
        let mut y = Mat::zeros(x.rows, self.n_out);
        self.forward_into(x, groups, &mut y);
        y
    }

    /// Execute one packed batch into a caller-owned buffer (overwritten).
    /// This is the pipeline building block: a whole-model forward ping-
    /// pongs two activation buffers through every layer's linears with
    /// zero per-linear allocations on the shared path.
    pub fn forward_into(&self, x: &Mat, groups: &[Group], y: &mut Mat) {
        assert_eq!(x.cols, self.n_in, "{}[{}]: input width", self.module, self.layer);
        assert_eq!(
            (y.rows, y.cols),
            (x.rows, self.n_out),
            "{}[{}]: output shape",
            self.module,
            self.layer
        );
        match &self.exec {
            Exec::Fused(base) => self.forward_fused(base, x, groups, y),
            Exec::GroupMerged(w) => self.forward_group_merged(w, x, groups, y),
            Exec::RequestMerged(w) => self.forward_request_merged(w, x, groups, y),
        }
    }

    /// Shared `X·base` once (dense GEMM, or the streaming dequant-GEMM
    /// for the NF4-resident store), then per-group `(X_g·ΔA)·ΔB`
    /// corrections in parallel, scattered back in deterministic group
    /// order.
    fn forward_fused(&self, base: &BaseStore, x: &Mat, groups: &[Group], y: &mut Mat) {
        base.forward_into(x, y);
        let adapter_groups: Vec<&Group> = groups.iter().filter(|g| g.adapter.is_some()).collect();
        let corrections: Vec<Option<Mat>> = par_map(adapter_groups.len(), 1, |gi| {
            let g = adapter_groups[gi];
            let prep = &self.prepared[g.adapter.as_deref().expect("filtered to Some")];
            let (da, db) = prep.delta.as_ref()?;
            let xg = gather_rows(x, &g.rows);
            let t = matmul(&xg, da); // |g| × R   (skinny)
            Some(matmul(&t, db)) // |g| × n   (rank-R panel product)
        });
        for (g, c) in adapter_groups.iter().zip(&corrections) {
            if let Some(c) = c {
                for (k, &row) in g.rows.iter().enumerate() {
                    for (yv, cv) in y.row_mut(row).iter_mut().zip(c.row(k)) {
                        *yv += cv;
                    }
                }
            }
        }
    }

    /// Baseline: materialize the merged dense weight once per adapter
    /// group, dense GEMM per group. Amortizes the merge across a group
    /// but shares nothing across adapters.
    fn forward_group_merged(&self, w: &Mat, x: &Mat, groups: &[Group], y: &mut Mat) {
        y.data.iter_mut().for_each(|v| *v = 0.0);
        let outs: Vec<Mat> = par_map(groups.len(), 1, |gi| {
            let g = &groups[gi];
            let xg = gather_rows(x, &g.rows);
            match self.group_delta(g) {
                Some((da, db)) => {
                    let merged = w.add(&matmul(da, db));
                    matmul(&xg, &merged)
                }
                None => matmul(&xg, w),
            }
        });
        for (g, out) in groups.iter().zip(&outs) {
            for (k, &row) in g.rows.iter().enumerate() {
                y.row_mut(row).copy_from_slice(out.row(k));
            }
        }
    }

    /// Naive baseline: merge (materialize `W + ΔA·ΔB`) for every single
    /// request, then one dense vector-matrix product. Sequential — this
    /// is the cost model the fused path is measured against.
    fn forward_request_merged(&self, w: &Mat, x: &Mat, groups: &[Group], y: &mut Mat) {
        y.data.iter_mut().for_each(|v| *v = 0.0);
        for g in groups {
            let delta = self.group_delta(g);
            for &row in &g.rows {
                let out = match delta {
                    Some((da, db)) => {
                        let merged = w.add(&matmul(da, db));
                        vecmat(x.row(row), &merged)
                    }
                    None => vecmat(x.row(row), w),
                };
                y.row_mut(row).copy_from_slice(&out);
            }
        }
    }

    fn group_delta(&self, g: &Group) -> Option<&(Mat, Mat)> {
        g.adapter.as_deref().and_then(|n| self.prepared[n].delta.as_ref())
    }

    /// Single-row decode fast path: `y = x·W_eff` for ONE request under
    /// `adapter`, overwriting `y` — no batch packing, no group bucketing,
    /// no parallel dispatch, just the sequential `vecmat` sweep (or the
    /// panel-streamed [`crate::linalg::dequant_vecmat_into`] for the
    /// NF4-resident base).
    ///
    /// Bit-identity contract: for every strategy this produces EXACTLY
    /// the row a batched [`LinearServer::forward_into`] would — the base
    /// sweep is one multiply-add per element in ascending k, and the
    /// low-rank correction is materialized into its own rank-R staging
    /// buffer before being added (the same two-step accumulation as the
    /// batched group path), so a decode step taken alone matches the same
    /// position recomputed inside a multi-row prefill bit for bit.
    pub fn forward_row_into(&self, x: &[f32], adapter: Option<&str>, y: &mut [f32]) {
        assert_eq!(x.len(), self.n_in, "{}[{}]: input width", self.module, self.layer);
        assert_eq!(y.len(), self.n_out, "{}[{}]: output width", self.module, self.layer);
        let delta = adapter.and_then(|n| self.prepared[n].delta.as_ref());
        match &self.exec {
            Exec::Fused(base) => {
                match base {
                    BaseStore::Dense(w) => vecmat_into(x, w, y),
                    BaseStore::Quant(q) => dequant_vecmat_into(x, &q.nf4, y),
                }
                if let Some((da, db)) = delta {
                    let t = vecmat(x, da); // 1 × R
                    let c = vecmat(&t, db); // 1 × n, staged like the group path
                    for (yv, cv) in y.iter_mut().zip(&c) {
                        *yv += cv;
                    }
                }
            }
            Exec::GroupMerged(w) | Exec::RequestMerged(w) => {
                let out = match delta {
                    Some((da, db)) => {
                        let merged = w.add(&matmul(da, db));
                        vecmat(x, &merged)
                    }
                    None => vecmat(x, w),
                };
                y.copy_from_slice(&out);
            }
        }
    }
}

/// Gather a row subset of a packed batch.
fn gather_rows(x: &Mat, rows: &[usize]) -> Mat {
    let mut out = Mat::zeros(rows.len(), x.cols);
    for (k, &row) in rows.iter().enumerate() {
        out.row_mut(k).copy_from_slice(x.row(row));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::AdapterSpec;
    use crate::model::BaseModel;
    use crate::runtime::ConfigInfo;
    use crate::serve::router::bucket;
    use crate::serve::{drift_factors, Request};
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "linear-test".into(),
            kind: "decoder".into(),
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            seq_len: 8,
            batch: 4,
            eval_batch: 2,
            n_classes: 0,
            ranks: vec![2],
        }
    }

    fn engine(seed: u64) -> (AdapterEngine, Rng) {
        let mut rng = Rng::new(seed);
        let base = BaseModel::random(&tiny_cfg(), &mut rng);
        let mut eng = AdapterEngine::new(base);
        eng.attach("t", AdapterSpec::pissa(2).targets(&["q"]), &mut rng).unwrap();
        drift_factors(&mut eng, "t", "q", 0.05, &mut rng).unwrap();
        (eng, rng)
    }

    fn batch(n: usize, rng: &mut Rng) -> (Mat, Vec<Request>) {
        let reqs: Vec<Request> = (0..n)
            .map(|i| {
                let mut x = vec![0.0f32; 16];
                rng.fill_normal(&mut x, 0.0, 1.0);
                if i % 3 == 2 {
                    Request::base(x)
                } else {
                    Request::new("t", x)
                }
            })
            .collect();
        let mut x = Mat::zeros(n, 16);
        for (i, r) in reqs.iter().enumerate() {
            x.row_mut(i).copy_from_slice(&r.x);
        }
        (x, reqs)
    }

    #[test]
    fn every_strategy_agrees_on_a_mixed_batch() {
        let (eng, mut rng) = engine(21);
        let (x, reqs) = batch(9, &mut rng);
        let groups = bucket(&reqs);
        let reference = LinearServer::snapshot(&eng, "q", 1, ServeStrategy::Fused, None)
            .unwrap()
            .forward(&x, &groups);
        for strategy in [ServeStrategy::DensePerAdapter, ServeStrategy::MergePerRequest] {
            let srv = LinearServer::snapshot(&eng, "q", 1, strategy, None).unwrap();
            let got = srv.forward(&x, &groups);
            let err = got.sub(&reference).fro() / reference.fro().max(1e-30);
            assert!(err < 1e-4, "{:?}: rel err {err:.3e}", strategy.name());
        }
    }

    #[test]
    fn forward_into_overwrites_a_reused_buffer() {
        let (eng, mut rng) = engine(22);
        let (x, reqs) = batch(5, &mut rng);
        let groups = bucket(&reqs);
        for strategy in ServeStrategy::all() {
            let srv = LinearServer::snapshot(&eng, "q", 0, strategy, None).unwrap();
            let want = srv.forward(&x, &groups);
            let mut y = Mat::from_vec(5, 16, vec![-3.25; 5 * 16]); // stale ping-pong buffer
            srv.forward_into(&x, &groups, &mut y);
            assert_eq!(y.data, want.data, "{}", strategy.name());
        }
    }

    #[test]
    fn shared_quant_snapshot_is_used_verbatim() {
        let (eng, mut rng) = engine(23);
        let shared = Arc::new(crate::quant::quantize(&eng.base_weight("q", 0)));
        let srv = LinearServer::snapshot(
            &eng,
            "q",
            0,
            ServeStrategy::FusedQuant,
            Some(shared.clone()),
        )
        .unwrap();
        // Residency is exactly the shared snapshot's bytes…
        assert_eq!(srv.resident_bytes(), shared.storage_bytes());
        // …and the output matches a locally-quantized server bit for bit.
        let local = LinearServer::snapshot(&eng, "q", 0, ServeStrategy::FusedQuant, None).unwrap();
        let (x, reqs) = batch(4, &mut rng);
        let groups = bucket(&reqs);
        assert_eq!(srv.forward(&x, &groups).data, local.forward(&x, &groups).data);
    }

    #[test]
    fn forward_row_into_is_bit_identical_to_batched_rows() {
        // The decode fast path must reproduce each row of a batched
        // forward EXACTLY — every strategy, adapted and base rows alike.
        let (eng, mut rng) = engine(25);
        let (x, reqs) = batch(6, &mut rng);
        let groups = bucket(&reqs);
        for strategy in ServeStrategy::all() {
            let srv = LinearServer::snapshot(&eng, "q", 0, strategy, None).unwrap();
            let want = srv.forward(&x, &groups);
            let mut y = vec![-9.5f32; srv.n_out()]; // stale buffer
            for (i, r) in reqs.iter().enumerate() {
                srv.forward_row_into(x.row(i), r.adapter.as_deref(), &mut y);
                assert_eq!(y.as_slice(), want.row(i), "{} row {i}", strategy.name());
            }
        }
    }

    #[test]
    fn metadata_accessors() {
        let (eng, _) = engine(24);
        let srv = LinearServer::snapshot(&eng, "gate", 1, ServeStrategy::Fused, None).unwrap();
        assert_eq!(srv.module(), "gate");
        assert_eq!(srv.layer(), 1);
        assert_eq!((srv.n_in(), srv.n_out()), (16, 24));
        assert!(srv.serves("t"));
        assert!(!srv.serves("ghost"));
        assert_eq!(srv.adapter_names(), vec!["t"]);
        assert_eq!(srv.resident_bytes(), 16 * 24 * 4);
    }
}
