//! `pissa-bench-check` — perf-trajectory regression gate.
//!
//! Compares fresh bench summaries (`results/BENCH_<name>.json`, written by
//! the `harness = false` bench binaries via `common::write_bench_summary`)
//! against the committed trajectory in `benches/baselines/BENCH_<name>.json`
//! and exits non-zero if any metric regresses beyond its tolerance.
//!
//! Every metric is a same-run normalized RATIO (e.g. packed-kernel speedup
//! over the pre-PR reference measured in the same process, or a
//! resident-bytes fraction) — never an absolute time — so one committed
//! baseline is meaningful on any machine. Baseline entries look like:
//!
//! ```json
//! {"value": 3.0, "tolerance": 0.33, "direction": "higher", "floor": 2.0}
//! ```
//!
//! direction "higher" (speedups): fresh must be >= max(value*(1-tolerance),
//! floor). direction "lower" (byte/latency ratios): fresh must be <=
//! min(value*(1+tolerance), ceiling). `floor`/`ceiling` are optional hard
//! acceptance bounds that tolerance can never relax past.
//!
//! Usage: `pissa-bench-check [--baselines DIR] [--fresh DIR]`
//! (defaults: benches/baselines, results)

use anyhow::{bail, Context, Result};
use pissa::util::json::Json;
use std::path::{Path, PathBuf};

/// Outcome of one metric comparison.
#[derive(Debug)]
struct Check {
    metric: String,
    pass: bool,
    detail: String,
}

/// Compare one fresh summary against its committed baseline. Returns a
/// check per baseline metric; a metric missing from the fresh summary (or
/// NaN) fails. Extra fresh metrics with no baseline are ignored — adding
/// a metric to a bench before committing its trajectory must not go red.
fn compare_summaries(baseline: &Json, fresh: &Json) -> Result<Vec<Check>> {
    let base_metrics = baseline
        .get("metrics")
        .and_then(|m| m.as_obj())
        .context("baseline missing 'metrics' object")?;
    let fresh_metrics = fresh
        .get("metrics")
        .and_then(|m| m.as_obj())
        .context("fresh summary missing 'metrics' object")?;
    let mut checks = Vec::new();
    for (name, spec) in base_metrics {
        let value = spec.req_f64("value")?;
        let tol = spec.req_f64("tolerance")?;
        let direction = spec.req_str("direction")?;
        let got = fresh_metrics.get(name).and_then(|v| v.as_f64());
        let check = match (direction, got) {
            (_, None) => Check {
                metric: name.clone(),
                pass: false,
                detail: "metric missing from fresh summary".into(),
            },
            ("higher", Some(g)) => {
                let mut bound = value * (1.0 - tol);
                if let Some(floor) = spec.get("floor").and_then(|v| v.as_f64()) {
                    bound = bound.max(floor);
                }
                Check {
                    metric: name.clone(),
                    // NaN compares false -> fails, as it should.
                    pass: g >= bound,
                    detail: format!("{g:.3} (need >= {bound:.3}; trajectory {value:.3})"),
                }
            }
            ("lower", Some(g)) => {
                let mut bound = value * (1.0 + tol);
                if let Some(ceiling) = spec.get("ceiling").and_then(|v| v.as_f64()) {
                    bound = bound.min(ceiling);
                }
                Check {
                    metric: name.clone(),
                    pass: g <= bound,
                    detail: format!("{g:.3} (need <= {bound:.3}; trajectory {value:.3})"),
                }
            }
            (d, _) => bail!("metric '{name}': unknown direction '{d}'"),
        };
        checks.push(check);
    }
    Ok(checks)
}

fn load_json(path: &Path) -> Result<Json> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading bench summary {}", path.display()))?;
    Json::parse(&src).with_context(|| format!("parsing {}", path.display()))
}

fn run(baselines: &Path, fresh_dir: &Path) -> Result<usize> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(baselines)
        .with_context(|| format!("listing baselines dir {}", baselines.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
            name.starts_with("BENCH_") && name.ends_with(".json")
        })
        .collect();
    entries.sort();
    if entries.is_empty() {
        bail!("no BENCH_*.json baselines in {}", baselines.display());
    }
    let mut failures = 0usize;
    for base_path in &entries {
        let fname = base_path.file_name().unwrap().to_str().unwrap();
        let baseline = load_json(base_path)?;
        let bench = baseline.req_str("bench")?.to_string();
        let fresh_path = fresh_dir.join(fname);
        if !fresh_path.exists() {
            println!(
                "FAIL {bench}: fresh summary {} not found (bench not run?)",
                fresh_path.display()
            );
            failures += 1;
            continue;
        }
        let fresh = load_json(&fresh_path)?;
        for c in compare_summaries(&baseline, &fresh)? {
            let tag = if c.pass { "PASS" } else { "FAIL" };
            println!("{tag} {bench}/{}: {}", c.metric, c.detail);
            if !c.pass {
                failures += 1;
            }
        }
    }
    Ok(failures)
}

fn main() -> Result<()> {
    let mut baselines = PathBuf::from("benches/baselines");
    let mut fresh_dir = PathBuf::from("results");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--baselines" if i + 1 < args.len() => {
                baselines = PathBuf::from(&args[i + 1]);
                i += 2;
            }
            "--fresh" if i + 1 < args.len() => {
                fresh_dir = PathBuf::from(&args[i + 1]);
                i += 2;
            }
            a => bail!("unknown arg '{a}' (flags: --baselines DIR, --fresh DIR)"),
        }
    }
    println!(
        "pissa-bench-check: {} vs committed trajectory {}",
        fresh_dir.display(),
        baselines.display()
    );
    let failures = run(&baselines, &fresh_dir)?;
    if failures > 0 {
        bail!("{failures} perf-trajectory check(s) failed");
    }
    println!("perf trajectory OK");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use pissa::util::json::{jnum, jstr, Json};

    fn spec(value: f64, tol: f64, dir: &str, bound: Option<(&str, f64)>) -> Json {
        let mut s = Json::obj();
        s.set("value", jnum(value));
        s.set("tolerance", jnum(tol));
        s.set("direction", jstr(dir));
        if let Some((k, v)) = bound {
            s.set(k, jnum(v));
        }
        s
    }

    fn summary(metrics: &[(&str, Json)]) -> Json {
        let mut m = Json::obj();
        for (k, v) in metrics {
            m.set(k, v.clone());
        }
        let mut j = Json::obj();
        j.set("bench", jstr("t"));
        j.set("metrics", m);
        j
    }

    fn baseline() -> Json {
        summary(&[
            ("gemm_speedup", spec(3.0, 0.33, "higher", Some(("floor", 2.0)))),
            ("bytes_ratio", spec(0.15, 0.2, "lower", Some(("ceiling", 0.35)))),
        ])
    }

    #[test]
    fn matching_trajectory_passes() {
        let fresh = summary(&[("gemm_speedup", jnum(3.1)), ("bytes_ratio", jnum(0.14))]);
        let checks = compare_summaries(&baseline(), &fresh).unwrap();
        assert_eq!(checks.len(), 2);
        assert!(checks.iter().all(|c| c.pass), "{checks:?}");
    }

    #[test]
    fn red_on_slowdown() {
        // The acceptance drill: halve every speedup ratio (and blow up the
        // byte ratio) — the gate must go red, not shrug.
        let fresh = summary(&[("gemm_speedup", jnum(1.5)), ("bytes_ratio", jnum(0.5))]);
        let checks = compare_summaries(&baseline(), &fresh).unwrap();
        let failures = checks.iter().filter(|c| !c.pass).count();
        assert_eq!(failures, 2, "{checks:?}");
    }

    #[test]
    fn floor_binds_tighter_than_tolerance() {
        // value*(1-tol) = 2.01 > floor, so 2.005 fails even though it is
        // above the hard floor of 2.0 ...
        let base = summary(&[("s", spec(3.0, 0.33, "higher", Some(("floor", 2.0))))]);
        let fresh = summary(&[("s", jnum(2.005))]);
        assert!(!compare_summaries(&base, &fresh).unwrap()[0].pass);
        // ... and with a looser tolerance the floor takes over: 1.9 < 2.0
        // fails no matter how generous the tolerance is.
        let base = summary(&[("s", spec(3.0, 0.9, "higher", Some(("floor", 2.0))))]);
        let fresh = summary(&[("s", jnum(1.9))]);
        assert!(!compare_summaries(&base, &fresh).unwrap()[0].pass);
        let fresh = summary(&[("s", jnum(2.1))]);
        assert!(compare_summaries(&base, &fresh).unwrap()[0].pass);
    }

    #[test]
    fn ceiling_caps_lower_direction() {
        let base = summary(&[("r", spec(0.3, 0.5, "lower", Some(("ceiling", 0.35))))]);
        // value*(1+tol) = 0.45 but the ceiling holds the line at 0.35.
        let fresh = summary(&[("r", jnum(0.4))]);
        assert!(!compare_summaries(&base, &fresh).unwrap()[0].pass);
        let fresh = summary(&[("r", jnum(0.34))]);
        assert!(compare_summaries(&base, &fresh).unwrap()[0].pass);
    }

    #[test]
    fn missing_and_nan_metrics_fail() {
        let fresh = summary(&[("gemm_speedup", jnum(f64::NAN))]);
        let checks = compare_summaries(&baseline(), &fresh).unwrap();
        assert!(checks.iter().all(|c| !c.pass), "{checks:?}");
    }

    #[test]
    fn unknown_direction_is_an_error() {
        let base = summary(&[("s", spec(1.0, 0.1, "sideways", None))]);
        let fresh = summary(&[("s", jnum(1.0))]);
        assert!(compare_summaries(&base, &fresh).is_err());
    }
}
