//! Rust-side model assembly: the generic tensor/parameter store and the
//! strategy application that mirrors python/compile/model.py's parameter
//! layout (manifest-order marshalling).

pub mod build;
pub mod params;

pub use build::{apply_strategy, effective_weight, BaseModel, TrainState, LINEARS};
pub use params::{count_params, to_literals, ParamStore, Tensor};
