//! Rust-side model assembly: the generic tensor/parameter store and the
//! spec-driven adapter application that mirrors python/compile/model.py's
//! parameter layout (manifest-order marshalling).

pub mod build;
pub mod params;

pub use build::{apply_spec, effective_weight, linear_dims, BaseModel, TrainState, LINEARS};
#[allow(deprecated)]
pub use build::apply_strategy;
pub use params::{count_params, to_literals, ParamStore, Tensor};
