//! Parameter store: named tensors in manifest order.
//!
//! The L2 model's parameters are stacked per layer ([L, m, n]); rust
//! stores everything as a generic `Tensor` (shape + flat f32 buffer) so a
//! parameter set can be marshalled to literals by walking the manifest's
//! `frozen_names` / `trainable_names` lists, and per-layer matrices can be
//! sliced out for SVD/quantization work.

use crate::linalg::Mat;
use crate::runtime::{lit_f32, vec_f32};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::BTreeMap;

/// N-dimensional f32 tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }
    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 0.0, std);
        t
    }
    pub fn from_mat(m: &Mat) -> Tensor {
        Tensor { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// View the whole tensor as a 2-D Mat (requires ndim ≤ 2).
    pub fn as_mat(&self) -> Mat {
        match self.shape.len() {
            1 => Mat::from_vec(1, self.shape[0], self.data.clone()),
            2 => Mat::from_vec(self.shape[0], self.shape[1], self.data.clone()),
            n => panic!("as_mat on {n}-d tensor"),
        }
    }

    /// Slice layer `l` of a stacked [L, m, n] tensor as a Mat copy.
    pub fn layer(&self, l: usize) -> Mat {
        assert_eq!(self.shape.len(), 3, "layer() needs a 3-d tensor");
        let (nl, m, n) = (self.shape[0], self.shape[1], self.shape[2]);
        assert!(l < nl);
        Mat::from_vec(m, n, self.data[l * m * n..(l + 1) * m * n].to_vec())
    }

    /// Write a Mat back into layer `l` of a stacked tensor.
    pub fn set_layer(&mut self, l: usize, m: &Mat) {
        assert_eq!(self.shape.len(), 3);
        let (nl, rows, cols) = (self.shape[0], self.shape[1], self.shape[2]);
        assert!(l < nl && m.rows == rows && m.cols == cols);
        self.data[l * rows * cols..(l + 1) * rows * cols].copy_from_slice(&m.data);
    }

    /// Build a stacked [L, m, n] tensor from per-layer Mats.
    pub fn stack(layers: &[Mat]) -> Tensor {
        let (m, n) = (layers[0].rows, layers[0].cols);
        let mut t = Tensor::zeros(&[layers.len(), m, n]);
        for (l, mat) in layers.iter().enumerate() {
            t.set_layer(l, mat);
        }
        t
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        lit_f32(&self.data, &dims)
    }

    pub fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
        let data = vec_f32(lit)?;
        anyhow::ensure!(
            data.len() == shape.iter().product::<usize>(),
            "literal size {} vs shape {shape:?}",
            data.len()
        );
        Ok(Tensor { shape: shape.to_vec(), data })
    }
}

/// Named tensors. Iteration order is name-sorted (BTreeMap) but the
/// marshalling path always walks an explicit name list from the manifest.
pub type ParamStore = BTreeMap<String, Tensor>;

/// Gather literals for `names` in order.
pub fn to_literals(store: &ParamStore, names: &[String]) -> Result<Vec<xla::Literal>> {
    names
        .iter()
        .map(|n| {
            store
                .get(n)
                .ok_or_else(|| anyhow::anyhow!("param store missing '{n}'"))
                .and_then(|t| t.to_literal())
        })
        .collect()
}

/// Total parameter count over a name list.
pub fn count_params(store: &ParamStore, names: &[String]) -> usize {
    names.iter().filter_map(|n| store.get(n)).map(|t| t.numel()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_slicing_roundtrip() {
        let mut t = Tensor::zeros(&[3, 4, 5]);
        let mut rng = Rng::new(1);
        let m = Mat::randn(4, 5, 0.0, 1.0, &mut rng);
        t.set_layer(1, &m);
        assert_eq!(t.layer(1).data, m.data);
        assert_eq!(t.layer(0).fro(), 0.0);
    }

    #[test]
    fn stack_matches_set_layer() {
        let mut rng = Rng::new(2);
        let mats: Vec<Mat> = (0..3).map(|_| Mat::randn(2, 6, 0.0, 1.0, &mut rng)).collect();
        let t = Tensor::stack(&mats);
        for (l, m) in mats.iter().enumerate() {
            assert_eq!(t.layer(l).data, m.data);
        }
    }

    #[test]
    fn literal_roundtrip() {
        let mut rng = Rng::new(3);
        let t = Tensor::randn(&[2, 3, 4], 1.0, &mut rng);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit, &[2, 3, 4]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn store_marshalling_order() {
        let mut store = ParamStore::new();
        store.insert("z".into(), Tensor::ones(&[2]));
        store.insert("a".into(), Tensor::zeros(&[3]));
        let names = vec!["z".to_string(), "a".to_string()];
        let lits = to_literals(&store, &names).unwrap();
        assert_eq!(lits.len(), 2);
        assert_eq!(vec_f32(&lits[0]).unwrap(), vec![1.0, 1.0]);
        assert_eq!(count_params(&store, &names), 5);
    }

    #[test]
    fn missing_param_errors() {
        let store = ParamStore::new();
        assert!(to_literals(&store, &["nope".to_string()]).is_err());
    }
}
