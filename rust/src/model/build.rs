//! Model assembly: create base weights, apply an adapter strategy, and
//! produce the (frozen, trainable, opt-state) stores a train artifact
//! expects — the rust-side mirror of python/compile/model.py's
//! `param_specs`, driven by the manifest's ConfigInfo.

use super::params::{ParamStore, Tensor};
use crate::adapter::init::{AdapterInit, Strategy};
use crate::adapter::spec::AdapterSpec;
use crate::linalg::Mat;
use crate::runtime::ConfigInfo;
use crate::util::rng::Rng;
use anyhow::Result;

/// The seven adapter-targeted linear types, canonical order
/// (mirrors model.py LINEARS).
pub const LINEARS: [&str; 7] = ["q", "k", "v", "o", "gate", "up", "down"];

/// (in_dim, out_dim) for each linear type. A name outside [`LINEARS`] is
/// a typed [`crate::serve::ServeError::UnknownModule`] (callers range over
/// user-supplied module names, e.g. serving configs — never a panic).
pub fn linear_dims(cfg: &ConfigInfo, name: &str) -> Result<(usize, usize)> {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    Ok(match name {
        "q" | "k" | "v" | "o" => (d, d),
        "gate" | "up" => (d, f),
        "down" => (f, d),
        other => {
            return Err(crate::serve::ServeError::UnknownModule { module: other.to_string() }
                .into())
        }
    })
}

/// A "base model": the frozen scaffolding plus dense per-layer linears.
/// Produced by random init then (optionally) pre-training via the full-FT
/// artifact; consumed by `apply_spec` (and the `AdapterEngine`).
#[derive(Clone, Debug)]
pub struct BaseModel {
    pub config: String,
    /// embed, lm_head/cls_base, attn_norm, mlp_norm, final_norm
    pub scaffold: ParamStore,
    /// base_q … base_down as stacked [L, m, n] tensors
    pub linears: ParamStore,
    pub encoder: bool,
}

impl BaseModel {
    /// Random init matching python's init_params (embed/linears N(0,0.02),
    /// norms = 1). Real experiments then pre-train this with full-FT.
    pub fn random(cfg: &ConfigInfo, rng: &mut Rng) -> BaseModel {
        let (v, d, l) = (cfg.vocab, cfg.d_model, cfg.n_layers);
        let encoder = cfg.kind == "encoder";
        let mut scaffold = ParamStore::new();
        scaffold.insert("embed".into(), Tensor::randn(&[v, d], 0.02, rng));
        if encoder {
            scaffold.insert("cls_base".into(), Tensor::randn(&[d, cfg.n_classes], 0.02, rng));
        } else {
            scaffold.insert("lm_head".into(), Tensor::randn(&[d, v], 0.02, rng));
        }
        scaffold.insert("attn_norm".into(), Tensor::ones(&[l, d]));
        scaffold.insert("mlp_norm".into(), Tensor::ones(&[l, d]));
        scaffold.insert("final_norm".into(), Tensor::ones(&[d]));

        let mut linears = ParamStore::new();
        for name in LINEARS {
            let (m, n) = linear_dims(cfg, name).expect("LINEARS names are always known");
            linears.insert(format!("base_{name}"), Tensor::randn(&[l, m, n], 0.02, rng));
        }
        BaseModel { config: cfg.name.clone(), scaffold, linears, encoder }
    }

    /// Replace the dense linears (e.g. with pre-trained weights).
    pub fn set_linears(&mut self, linears: ParamStore) {
        self.linears = linears;
    }

    pub fn n_layers(&self) -> usize {
        self.linears["base_q"].shape[0]
    }
}

/// Frozen + trainable + optimizer state, ready for a train artifact.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub spec: AdapterSpec,
    pub frozen: ParamStore,
    pub trainable: ParamStore,
    pub m: ParamStore,
    pub v: ParamStore,
    pub step: usize,
}

impl TrainState {
    /// Assemble a fresh train state from its stores: zeroed Adam moments
    /// matching the trainable shapes, step 0. The single construction
    /// point shared by `apply_spec` and the `AdapterEngine` bridge.
    pub fn new(spec: AdapterSpec, frozen: ParamStore, trainable: ParamStore) -> TrainState {
        let m: ParamStore =
            trainable.iter().map(|(k, t)| (k.clone(), Tensor::zeros(&t.shape))).collect();
        let v = m.clone();
        TrainState { spec, frozen, trainable, m, v, step: 0 }
    }

    pub fn strategy(&self) -> Strategy {
        self.spec.strategy
    }

    pub fn rank(&self) -> usize {
        self.spec.rank
    }
}

/// Apply an [`AdapterSpec`] to every (targeted) linear layer of a base
/// model, producing the stores in the exact name layout the manifest
/// uses. Untargeted modules keep their dense weights frozen (no a/b
/// factors) — note the AOT train artifacts are lowered for adapters on
/// all seven linears, so partially-targeted states are for engine-side
/// use (the `Trainer` rejects them with a clear error).
pub fn apply_spec(base: &BaseModel, spec: &AdapterSpec, rng: &mut Rng) -> Result<TrainState> {
    spec.validate()?;
    let mut frozen = base.scaffold.clone();
    let mut trainable = ParamStore::new();
    let l = base.n_layers();

    if base.encoder {
        // Trainable classification-head delta starts at zero.
        let cls = &base.scaffold["cls_base"];
        trainable.insert("cls_head".into(), Tensor::zeros(&cls.shape));
    }

    if spec.is_full_ft() {
        if !base.encoder {
            // Decoder full-FT (and pre-training) also trains embed + head.
            trainable.insert("embed".into(), frozen.remove("embed").unwrap());
            trainable.insert("lm_head".into(), frozen.remove("lm_head").unwrap());
        }
        for name in LINEARS {
            trainable.insert(format!("base_{name}"), base.linears[&format!("base_{name}")].clone());
        }
    } else {
        for name in LINEARS {
            let stacked = &base.linears[&format!("base_{name}")];
            if !spec.targets_module(name) {
                // Untargeted module: dense weights stay frozen as-is.
                frozen.insert(format!("base_{name}"), stacked.clone());
                continue;
            }
            let rank = spec.module_rank(name);
            let mut bases = Vec::with_capacity(l);
            let mut aas = Vec::with_capacity(l);
            let mut bbs = Vec::with_capacity(l);
            for li in 0..l {
                let w = stacked.layer(li);
                let AdapterInit { base: b0, a, b } = spec.init_matrix(&w, rank, rng);
                bases.push(b0);
                aas.push(a);
                bbs.push(b);
            }
            frozen.insert(format!("base_{name}"), Tensor::stack(&bases));
            trainable.insert(format!("a_{name}"), Tensor::stack(&aas));
            trainable.insert(format!("b_{name}"), Tensor::stack(&bbs));
        }
    }

    Ok(TrainState::new(spec.clone(), frozen, trainable))
}

/// Legacy shim over [`apply_spec`]: bit-identical initializations for
/// equivalent configs (`AdapterSpec::from_strategy` reproduces the old
/// hardcoded niter/window defaults).
#[deprecated(note = "build an AdapterSpec and call apply_spec instead")]
pub fn apply_strategy(
    base: &BaseModel,
    strategy: Strategy,
    rank: usize,
    iters: usize,
    rng: &mut Rng,
) -> Result<TrainState> {
    apply_spec(base, &AdapterSpec::from_strategy(strategy, rank, iters), rng)
}

/// Effective dense weight of one linear layer under a train state
/// (base + A·B for targeted modules, the frozen/trainable dense weight
/// otherwise). Used by diagnostics and the quantization-error reports.
pub fn effective_weight(state: &TrainState, name: &str, layer: usize) -> Mat {
    if state.spec.is_full_ft() {
        return state.trainable[&format!("base_{name}")].layer(layer);
    }
    if !state.spec.targets_module(name) {
        return state.frozen[&format!("base_{name}")].layer(layer);
    }
    let base = state.frozen[&format!("base_{name}")].layer(layer);
    let a = state.trainable[&format!("a_{name}")].layer(layer);
    let b = state.trainable[&format!("b_{name}")].layer(layer);
    base.add(&crate::linalg::matmul(&a, &b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "tiny".into(),
            kind: "decoder".into(),
            vocab: 320,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            d_ff: 128,
            seq_len: 64,
            batch: 8,
            eval_batch: 4,
            n_classes: 0,
            ranks: vec![2, 4],
        }
    }

    #[test]
    fn linear_dims_unknown_name_is_a_typed_error() {
        let cfg = tiny_cfg();
        assert_eq!(linear_dims(&cfg, "gate").unwrap(), (64, 128));
        assert_eq!(linear_dims(&cfg, "down").unwrap(), (128, 64));
        let err = linear_dims(&cfg, "bogus").unwrap_err();
        match err.downcast_ref::<crate::serve::ServeError>() {
            Some(crate::serve::ServeError::UnknownModule { module }) => {
                assert_eq!(module, "bogus");
            }
            other => panic!("expected UnknownModule, got {other:?}"),
        }
    }

    #[test]
    fn base_model_shapes() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        let base = BaseModel::random(&cfg, &mut rng);
        assert_eq!(base.scaffold["embed"].shape, vec![320, 64]);
        assert_eq!(base.linears["base_gate"].shape, vec![2, 64, 128]);
        assert_eq!(base.linears["base_down"].shape, vec![2, 128, 64]);
        assert_eq!(base.n_layers(), 2);
    }

    #[test]
    fn pissa_state_preserves_effective_weights() {
        // Eq. 5 at the whole-model level: effective weight == original W.
        let cfg = tiny_cfg();
        let mut rng = Rng::new(2);
        let base = BaseModel::random(&cfg, &mut rng);
        let state = apply_spec(&base, &AdapterSpec::pissa(4), &mut rng).unwrap();
        for name in LINEARS {
            for l in 0..2 {
                let orig = base.linears[&format!("base_{name}")].layer(l);
                let eff = effective_weight(&state, name, l);
                let err = eff.sub(&orig).fro() / orig.fro();
                assert!(err < 1e-5, "{name}[{l}] err={err}");
            }
        }
    }

    #[test]
    fn lora_state_preserves_effective_weights_exactly() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(3);
        let base = BaseModel::random(&cfg, &mut rng);
        let state = apply_spec(&base, &AdapterSpec::lora(4), &mut rng).unwrap();
        let orig = base.linears["base_q"].layer(0);
        let eff = effective_weight(&state, "q", 0);
        assert_eq!(eff.sub(&orig).fro(), 0.0); // B = 0 ⇒ exact
    }

    #[test]
    fn full_ft_trainables_are_dense() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(4);
        let base = BaseModel::random(&cfg, &mut rng);
        let state = apply_spec(&base, &AdapterSpec::full_ft(), &mut rng).unwrap();
        assert!(state.trainable.contains_key("base_q"));
        assert!(!state.trainable.contains_key("a_q"));
        assert!(!state.frozen.contains_key("base_q"));
    }

    #[test]
    fn qpissa_base_is_quantized() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(5);
        let base = BaseModel::random(&cfg, &mut rng);
        let state = apply_spec(&base, &AdapterSpec::qpissa(4).iters(1), &mut rng).unwrap();
        // The frozen base must be an NF4 fixed point: re-quantizing changes nothing.
        let b0 = state.frozen["base_q"].layer(0);
        let rt = crate::quant::nf4_roundtrip(&b0);
        assert!(b0.sub(&rt).fro() < 1e-5);
    }

    #[test]
    fn trainable_param_counts_match_formula() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(6);
        let base = BaseModel::random(&cfg, &mut rng);
        let r = 4;
        let state = apply_spec(&base, &AdapterSpec::pissa(r), &mut rng).unwrap();
        let names: Vec<String> = state.trainable.keys().cloned().collect();
        let total = super::super::params::count_params(&state.trainable, &names);
        let (d, f, l) = (64, 128, 2);
        let expect = l * (4 * (d + d) * r + 2 * (d + f) * r + (f + d) * r);
        assert_eq!(total, expect);
    }

    #[test]
    fn partial_targeting_keeps_untargeted_modules_dense() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(7);
        let base = BaseModel::random(&cfg, &mut rng);
        let spec = AdapterSpec::pissa(4).targets(&["q", "v"]).target_rank("q", 2);
        let state = apply_spec(&base, &spec, &mut rng).unwrap();
        // targeted: factors exist, with the per-module rank override
        assert_eq!(state.trainable["a_q"].shape, vec![2, 64, 2]);
        assert_eq!(state.trainable["a_v"].shape, vec![2, 64, 4]);
        // untargeted: no factors, dense weights frozen and untouched
        assert!(!state.trainable.contains_key("a_gate"));
        assert_eq!(state.frozen["base_gate"].data, base.linears["base_gate"].data);
        let eff = effective_weight(&state, "gate", 0);
        assert_eq!(eff.data, base.linears["base_gate"].layer(0).data);
        // targeted modules still preserve W
        let orig = base.linears["base_q"].layer(0);
        assert!(effective_weight(&state, "q", 0).sub(&orig).fro() / orig.fro() < 1e-5);
    }
}
