//! Model assembly: create base weights, apply an adapter strategy, and
//! produce the (frozen, trainable, opt-state) stores a train artifact
//! expects — the rust-side mirror of python/compile/model.py's
//! `param_specs`, driven by the manifest's ConfigInfo.

use super::params::{ParamStore, Tensor};
use crate::adapter::init::{initialize, AdapterInit, Strategy};
use crate::linalg::Mat;
use crate::runtime::ConfigInfo;
use crate::util::rng::Rng;
use anyhow::Result;

/// The seven adapter-targeted linear types, canonical order
/// (mirrors model.py LINEARS).
pub const LINEARS: [&str; 7] = ["q", "k", "v", "o", "gate", "up", "down"];

/// (in_dim, out_dim) for each linear type.
pub fn linear_dims(cfg: &ConfigInfo, name: &str) -> (usize, usize) {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    match name {
        "q" | "k" | "v" | "o" => (d, d),
        "gate" | "up" => (d, f),
        "down" => (f, d),
        other => panic!("unknown linear '{other}'"),
    }
}

/// A "base model": the frozen scaffolding plus dense per-layer linears.
/// Produced by random init then (optionally) pre-training via the full-FT
/// artifact; consumed by `apply_strategy`.
#[derive(Clone, Debug)]
pub struct BaseModel {
    pub config: String,
    /// embed, lm_head/cls_base, attn_norm, mlp_norm, final_norm
    pub scaffold: ParamStore,
    /// base_q … base_down as stacked [L, m, n] tensors
    pub linears: ParamStore,
    pub encoder: bool,
}

impl BaseModel {
    /// Random init matching python's init_params (embed/linears N(0,0.02),
    /// norms = 1). Real experiments then pre-train this with full-FT.
    pub fn random(cfg: &ConfigInfo, rng: &mut Rng) -> BaseModel {
        let (v, d, l) = (cfg.vocab, cfg.d_model, cfg.n_layers);
        let encoder = cfg.kind == "encoder";
        let mut scaffold = ParamStore::new();
        scaffold.insert("embed".into(), Tensor::randn(&[v, d], 0.02, rng));
        if encoder {
            scaffold.insert("cls_base".into(), Tensor::randn(&[d, cfg.n_classes], 0.02, rng));
        } else {
            scaffold.insert("lm_head".into(), Tensor::randn(&[d, v], 0.02, rng));
        }
        scaffold.insert("attn_norm".into(), Tensor::ones(&[l, d]));
        scaffold.insert("mlp_norm".into(), Tensor::ones(&[l, d]));
        scaffold.insert("final_norm".into(), Tensor::ones(&[d]));

        let mut linears = ParamStore::new();
        for name in LINEARS {
            let (m, n) = linear_dims(cfg, name);
            linears.insert(format!("base_{name}"), Tensor::randn(&[l, m, n], 0.02, rng));
        }
        BaseModel { config: cfg.name.clone(), scaffold, linears, encoder }
    }

    /// Replace the dense linears (e.g. with pre-trained weights).
    pub fn set_linears(&mut self, linears: ParamStore) {
        self.linears = linears;
    }

    pub fn n_layers(&self) -> usize {
        self.linears["base_q"].shape[0]
    }
}

/// Frozen + trainable + optimizer state, ready for a train artifact.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub strategy: Strategy,
    pub rank: usize,
    pub frozen: ParamStore,
    pub trainable: ParamStore,
    pub m: ParamStore,
    pub v: ParamStore,
    pub step: usize,
}

/// Apply an init strategy to every linear layer of a base model,
/// producing the stores in the exact name layout the manifest uses.
/// `iters` is the QPiSSA/LoftQ alternation count (Algorithm 1's T).
pub fn apply_strategy(
    base: &BaseModel,
    strategy: Strategy,
    rank: usize,
    iters: usize,
    rng: &mut Rng,
) -> Result<TrainState> {
    let mut frozen = base.scaffold.clone();
    let mut trainable = ParamStore::new();
    let l = base.n_layers();

    if base.encoder {
        // Trainable classification-head delta starts at zero.
        let cls = &base.scaffold["cls_base"];
        trainable.insert("cls_head".into(), Tensor::zeros(&cls.shape));
    }

    if strategy == Strategy::FullFt {
        if !base.encoder {
            // Decoder full-FT (and pre-training) also trains embed + head.
            trainable.insert("embed".into(), frozen.remove("embed").unwrap());
            trainable.insert("lm_head".into(), frozen.remove("lm_head").unwrap());
        }
        for name in LINEARS {
            trainable.insert(format!("base_{name}"), base.linears[&format!("base_{name}")].clone());
        }
    } else {
        for name in LINEARS {
            let stacked = &base.linears[&format!("base_{name}")];
            let (m_dim, n_dim) = (stacked.shape[1], stacked.shape[2]);
            let mut bases = Vec::with_capacity(l);
            let mut aas = Vec::with_capacity(l);
            let mut bbs = Vec::with_capacity(l);
            for li in 0..l {
                let w = stacked.layer(li);
                let AdapterInit { base: b0, a, b } = initialize(strategy, &w, rank, iters, rng);
                bases.push(b0);
                aas.push(a);
                bbs.push(b);
            }
            frozen.insert(format!("base_{name}"), Tensor::stack(&bases));
            let _ = (m_dim, n_dim);
            trainable.insert(format!("a_{name}"), Tensor::stack(&aas));
            trainable.insert(format!("b_{name}"), Tensor::stack(&bbs));
        }
    }

    let m: ParamStore = trainable.iter().map(|(k, t)| (k.clone(), Tensor::zeros(&t.shape))).collect();
    let v = m.clone();
    Ok(TrainState { strategy, rank, frozen, trainable, m, v, step: 0 })
}

/// Effective dense weight of one linear layer under a train state
/// (base + A·B, or the trainable dense weight for full-FT). Used by
/// diagnostics and the quantization-error reports.
pub fn effective_weight(state: &TrainState, name: &str, layer: usize) -> Mat {
    if state.strategy == Strategy::FullFt {
        return state.trainable[&format!("base_{name}")].layer(layer);
    }
    let base = state.frozen[&format!("base_{name}")].layer(layer);
    let a = state.trainable[&format!("a_{name}")].layer(layer);
    let b = state.trainable[&format!("b_{name}")].layer(layer);
    base.add(&crate::linalg::matmul(&a, &b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "tiny".into(),
            kind: "decoder".into(),
            vocab: 320,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            d_ff: 128,
            seq_len: 64,
            batch: 8,
            eval_batch: 4,
            n_classes: 0,
            ranks: vec![2, 4],
        }
    }

    #[test]
    fn base_model_shapes() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        let base = BaseModel::random(&cfg, &mut rng);
        assert_eq!(base.scaffold["embed"].shape, vec![320, 64]);
        assert_eq!(base.linears["base_gate"].shape, vec![2, 64, 128]);
        assert_eq!(base.linears["base_down"].shape, vec![2, 128, 64]);
        assert_eq!(base.n_layers(), 2);
    }

    #[test]
    fn pissa_state_preserves_effective_weights() {
        // Eq. 5 at the whole-model level: effective weight == original W.
        let cfg = tiny_cfg();
        let mut rng = Rng::new(2);
        let base = BaseModel::random(&cfg, &mut rng);
        let state = apply_strategy(&base, Strategy::Pissa, 4, 1, &mut rng).unwrap();
        for name in LINEARS {
            for l in 0..2 {
                let orig = base.linears[&format!("base_{name}")].layer(l);
                let eff = effective_weight(&state, name, l);
                let err = eff.sub(&orig).fro() / orig.fro();
                assert!(err < 1e-5, "{name}[{l}] err={err}");
            }
        }
    }

    #[test]
    fn lora_state_preserves_effective_weights_exactly() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(3);
        let base = BaseModel::random(&cfg, &mut rng);
        let state = apply_strategy(&base, Strategy::Lora, 4, 1, &mut rng).unwrap();
        let orig = base.linears["base_q"].layer(0);
        let eff = effective_weight(&state, "q", 0);
        assert_eq!(eff.sub(&orig).fro(), 0.0); // B = 0 ⇒ exact
    }

    #[test]
    fn full_ft_trainables_are_dense() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(4);
        let base = BaseModel::random(&cfg, &mut rng);
        let state = apply_strategy(&base, Strategy::FullFt, 0, 1, &mut rng).unwrap();
        assert!(state.trainable.contains_key("base_q"));
        assert!(!state.trainable.contains_key("a_q"));
        assert!(!state.frozen.contains_key("base_q"));
    }

    #[test]
    fn qpissa_base_is_quantized() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(5);
        let base = BaseModel::random(&cfg, &mut rng);
        let state = apply_strategy(&base, Strategy::QPissa, 4, 1, &mut rng).unwrap();
        // The frozen base must be an NF4 fixed point: re-quantizing changes nothing.
        let b0 = state.frozen["base_q"].layer(0);
        let rt = crate::quant::nf4_roundtrip(&b0);
        assert!(b0.sub(&rt).fro() < 1e-5);
    }

    #[test]
    fn trainable_param_counts_match_formula() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(6);
        let base = BaseModel::random(&cfg, &mut rng);
        let r = 4;
        let state = apply_strategy(&base, Strategy::Pissa, r, 1, &mut rng).unwrap();
        let names: Vec<String> = state.trainable.keys().cloned().collect();
        let total = super::super::params::count_params(&state.trainable, &names);
        let (d, f, l) = (64, 128, 2);
        let expect = l * (4 * (d + d) * r + 2 * (d + f) * r + (f + d) * r);
        assert_eq!(total, expect);
    }
}
