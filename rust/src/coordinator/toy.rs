//! Figure 2a's toy experiment, rust-native: pre-train a two-layer MLP on
//! "odd digits" of a synthetic 8×8 digit dataset, then fine-tune on
//! "even digits" with LoRA vs PiSSA adapters and compare convergence.
//!
//! The digits are deterministic stroke templates + Gaussian pixel noise —
//! the same protocol as the paper's MNIST toy (classify odd, transfer to
//! even) with the dataset substituted per DESIGN.md §3.

use crate::adapter::init::{lora, pissa, AdapterInit};
use crate::linalg::{matmul, matmul_nt, matmul_tn, Mat};
use crate::util::rng::Rng;

pub const IMG: usize = 8;
pub const NPIX: usize = IMG * IMG;
pub const NCLASS: usize = 10;

/// Deterministic stroke templates for digits 0-9 on an 8×8 grid.
fn template(digit: usize) -> [f32; NPIX] {
    let mut img = [0.0f32; NPIX];
    let mut set = |r: usize, c: usize| img[r * IMG + c] = 1.0;
    match digit {
        0 => {
            for r in 1..7 {
                set(r, 2);
                set(r, 5);
            }
            for c in 2..6 {
                set(1, c);
                set(6, c);
            }
        }
        1 => {
            for r in 1..7 {
                set(r, 4);
            }
            set(2, 3);
        }
        2 => {
            for c in 2..6 {
                set(1, c);
                set(4, c);
                set(6, c);
            }
            set(2, 5);
            set(3, 5);
            set(5, 2);
        }
        3 => {
            for c in 2..6 {
                set(1, c);
                set(4, c);
                set(6, c);
            }
            for r in 2..6 {
                set(r, 5);
            }
        }
        4 => {
            for r in 1..5 {
                set(r, 2);
            }
            for c in 2..6 {
                set(4, c);
            }
            for r in 1..7 {
                set(r, 5);
            }
        }
        5 => {
            for c in 2..6 {
                set(1, c);
                set(4, c);
                set(6, c);
            }
            set(2, 2);
            set(3, 2);
            set(5, 5);
        }
        6 => {
            for r in 1..7 {
                set(r, 2);
            }
            for c in 2..6 {
                set(4, c);
                set(6, c);
            }
            set(5, 5);
        }
        7 => {
            for c in 2..6 {
                set(1, c);
            }
            for r in 2..7 {
                set(r, 5);
            }
        }
        8 => {
            for r in 1..7 {
                set(r, 2);
                set(r, 5);
            }
            for c in 2..6 {
                set(1, c);
                set(4, c);
                set(6, c);
            }
        }
        _ => {
            for r in 1..5 {
                set(r, 2);
            }
            for r in 1..7 {
                set(r, 5);
            }
            for c in 2..6 {
                set(1, c);
                set(4, c);
            }
        }
    }
    img
}

/// Generate `n` noisy samples of the given digit classes.
pub fn gen_digits(classes: &[usize], n: usize, noise: f32, rng: &mut Rng) -> (Mat, Vec<usize>) {
    let mut x = Mat::zeros(n, NPIX);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let d = *rng.choice(classes);
        let t = template(d);
        for (j, &v) in t.iter().enumerate() {
            x[(i, j)] = v + rng.normal_f32(0.0, noise);
        }
        y.push(d);
    }
    (x, y)
}

/// Two-layer MLP: logits = relu(X·W1)·W2, ten-way softmax CE.
#[derive(Clone)]
pub struct Mlp {
    pub w1: Mat, // NPIX × H
    pub w2: Mat, // H × NCLASS
}

fn softmax_ce_grad(logits: &Mat, labels: &[usize]) -> (f64, Mat) {
    let n = logits.rows;
    let mut grad = Mat::zeros(n, logits.cols);
    let mut loss = 0.0f64;
    for i in 0..n {
        let row = logits.row(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f64> = row.iter().map(|&v| ((v - mx) as f64).exp()).collect();
        let z: f64 = exps.iter().sum();
        for j in 0..logits.cols {
            let p = exps[j] / z;
            grad[(i, j)] = (p - if j == labels[i] { 1.0 } else { 0.0 }) as f32 / n as f32;
        }
        loss -= (exps[labels[i]] / z).ln();
    }
    (loss / n as f64, grad)
}

impl Mlp {
    pub fn random(hidden: usize, rng: &mut Rng) -> Mlp {
        Mlp {
            w1: Mat::randn(NPIX, hidden, 0.0, (2.0 / NPIX as f32).sqrt(), rng),
            w2: Mat::randn(hidden, NCLASS, 0.0, (2.0 / hidden as f32).sqrt(), rng),
        }
    }

    pub fn forward(&self, x: &Mat) -> (Mat, Mat) {
        let mut h = matmul(x, &self.w1);
        for v in h.data.iter_mut() {
            *v = v.max(0.0); // ReLU
        }
        let logits = matmul(&h, &self.w2);
        (h, logits)
    }

    pub fn loss(&self, x: &Mat, y: &[usize]) -> f64 {
        let (_, logits) = self.forward(x);
        softmax_ce_grad(&logits, y).0
    }

    pub fn accuracy(&self, x: &Mat, y: &[usize]) -> f64 {
        let (_, logits) = self.forward(x);
        let mut correct = 0;
        for i in 0..x.rows {
            // serve::argmax: NaN-safe (NaNs never win; all-NaN rows
            // resolve to class 0 instead of panicking) with the serving
            // stack's first-max tie-break.
            if crate::serve::argmax(logits.row(i)) == y[i] {
                correct += 1;
            }
        }
        correct as f64 / x.rows as f64
    }

    /// One full-parameter SGD step; returns loss.
    pub fn sgd_step(&mut self, x: &Mat, y: &[usize], lr: f32) -> f64 {
        let (h, logits) = self.forward(x);
        let (loss, dlogits) = softmax_ce_grad(&logits, y);
        let dw2 = matmul_tn(&h, &dlogits);
        let mut dh = matmul_nt(&dlogits, &self.w2); // dY·W2ᵀ
        for (dv, hv) in dh.data.iter_mut().zip(&h.data) {
            if *hv <= 0.0 {
                *dv = 0.0;
            }
        }
        let dw1 = matmul_tn(x, &dh);
        for (w, g) in self.w1.data.iter_mut().zip(&dw1.data) {
            *w -= lr * g;
        }
        for (w, g) in self.w2.data.iter_mut().zip(&dw2.data) {
            *w -= lr * g;
        }
        loss
    }
}

/// Adapter-wrapped MLP: both layers get frozen bases + trainable (A, B).
pub struct AdapterMlp {
    pub l1: AdapterInit,
    pub l2: AdapterInit,
}

impl AdapterMlp {
    pub fn from_mlp(mlp: &Mlp, rank: usize, use_pissa: bool, rng: &mut Rng) -> AdapterMlp {
        let init = |w: &Mat, rng: &mut Rng| {
            if use_pissa {
                pissa(w, rank, None, rng)
            } else {
                lora(w, rank, rng)
            }
        };
        AdapterMlp { l1: init(&mlp.w1, rng), l2: init(&mlp.w2, rng) }
    }

    fn weights(&self) -> (Mat, Mat) {
        (self.l1.effective(), self.l2.effective())
    }

    pub fn loss(&self, x: &Mat, y: &[usize]) -> f64 {
        let (w1, w2) = self.weights();
        Mlp { w1, w2 }.loss(x, y)
    }

    pub fn accuracy(&self, x: &Mat, y: &[usize]) -> f64 {
        let (w1, w2) = self.weights();
        Mlp { w1, w2 }.accuracy(x, y)
    }

    /// One SGD step on the adapter factors only (bases frozen):
    /// dA = Xᵀ·dY·Bᵀ, dB = Aᵀ·Xᵀ·dY — the gradients from §3 of the paper.
    pub fn sgd_step(&mut self, x: &Mat, y: &[usize], lr: f32) -> f64 {
        let (w1, w2) = self.weights();
        let mlp = Mlp { w1, w2 };
        let (h, logits) = mlp.forward(x);
        let (loss, dlogits) = softmax_ce_grad(&logits, y);

        // layer 2 grads
        let dw2 = matmul_tn(&h, &dlogits); // H×C
        let da2 = matmul_nt(&dw2, &self.l2.b); // (H×C)·(C×r→ Bᵀ) = H×r
        let db2 = matmul_tn(&self.l2.a, &dw2); // r×C

        // backprop to hidden
        let mut dh = matmul_nt(&dlogits, &mlp.w2); // dY·W2ᵀ
        for (dv, hv) in dh.data.iter_mut().zip(&h.data) {
            if *hv <= 0.0 {
                *dv = 0.0;
            }
        }
        let dw1 = matmul_tn(x, &dh); // NPIX×H
        let da1 = matmul_nt(&dw1, &self.l1.b);
        let db1 = matmul_tn(&self.l1.a, &dw1);

        for (w, g) in self.l1.a.data.iter_mut().zip(&da1.data) {
            *w -= lr * g;
        }
        for (w, g) in self.l1.b.data.iter_mut().zip(&db1.data) {
            *w -= lr * g;
        }
        for (w, g) in self.l2.a.data.iter_mut().zip(&da2.data) {
            *w -= lr * g;
        }
        for (w, g) in self.l2.b.data.iter_mut().zip(&db2.data) {
            *w -= lr * g;
        }
        loss
    }
}

/// The full Figure-2a protocol. Returns (lora_losses, pissa_losses,
/// full_ft_losses) over `steps` fine-tuning steps on even digits.
/// `lr` drives pre-training; fine-tuning uses `lr / 10` for every method
/// (identical across methods, per the paper's equal-setup comparison —
/// adapter gradients scale with the factors, so the same small lr is the
/// stable choice for all three).
pub fn fig2a_protocol(
    hidden: usize,
    rank: usize,
    pretrain_steps: usize,
    steps: usize,
    lr: f32,
    seed: u64,
) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let odd = [1usize, 3, 5, 7, 9];
    let even = [0usize, 2, 4, 6, 8];

    // Pre-train on odd digits.
    let mut mlp = Mlp::random(hidden, &mut rng);
    let (xo, yo) = gen_digits(&odd, 512, 0.15, &mut rng);
    for _ in 0..pretrain_steps {
        mlp.sgd_step(&xo, &yo, lr);
    }

    // Fine-tune on even digits under the three regimes.
    let ft_lr = lr / 10.0;
    let (xe, ye) = gen_digits(&even, 512, 0.15, &mut rng);
    let mut lora_mlp = AdapterMlp::from_mlp(&mlp, rank, false, &mut rng);
    let mut pissa_mlp = AdapterMlp::from_mlp(&mlp, rank, true, &mut rng);
    let mut full = mlp.clone();

    let mut lora_l = Vec::with_capacity(steps);
    let mut pissa_l = Vec::with_capacity(steps);
    let mut full_l = Vec::with_capacity(steps);
    for _ in 0..steps {
        lora_l.push(lora_mlp.sgd_step(&xe, &ye, ft_lr));
        pissa_l.push(pissa_mlp.sgd_step(&xe, &ye, ft_lr));
        full_l.push(full.sgd_step(&xe, &ye, ft_lr));
    }
    (lora_l, pissa_l, full_l)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn templates_are_distinct() {
        for a in 0..10 {
            for b in (a + 1)..10 {
                let (ta, tb) = (template(a), template(b));
                assert_ne!(ta, tb, "digits {a} and {b} share a template");
            }
        }
    }

    #[test]
    fn mlp_learns_digits() {
        let mut rng = Rng::new(1);
        let classes = [0usize, 1, 2, 3, 4];
        let (x, y) = gen_digits(&classes, 256, 0.1, &mut rng);
        let mut mlp = Mlp::random(32, &mut rng);
        let l0 = mlp.loss(&x, &y);
        for _ in 0..60 {
            mlp.sgd_step(&x, &y, 0.5);
        }
        let l1 = mlp.loss(&x, &y);
        assert!(l1 < l0 * 0.5, "loss {l0} -> {l1}");
        assert!(mlp.accuracy(&x, &y) > 0.9);
    }

    #[test]
    fn adapter_mlp_preserves_forward_at_init() {
        let mut rng = Rng::new(2);
        let mlp = Mlp::random(16, &mut rng);
        let (x, y) = gen_digits(&[0, 1], 64, 0.1, &mut rng);
        let base_loss = mlp.loss(&x, &y);
        let lora_m = AdapterMlp::from_mlp(&mlp, 4, false, &mut rng);
        let pissa_m = AdapterMlp::from_mlp(&mlp, 4, true, &mut rng);
        assert!((lora_m.loss(&x, &y) - base_loss).abs() < 1e-5);
        assert!((pissa_m.loss(&x, &y) - base_loss).abs() < 1e-4);
    }

    #[test]
    fn accuracy_survives_nan_logits() {
        // Regression: argmax used partial_cmp(..).unwrap() and panicked
        // on NaN logits (e.g. a diverged fine-tune). NaN rows now resolve
        // to class 0 via serve::argmax instead of aborting the eval.
        let mut rng = Rng::new(3);
        let mut mlp = Mlp::random(4, &mut rng);
        mlp.w2.data.iter_mut().for_each(|v| *v = f32::NAN);
        let x = Mat::from_vec(2, NPIX, vec![1.0; 2 * NPIX]);
        let acc = mlp.accuracy(&x, &[0, 1]);
        // Every row's logits are NaN -> every prediction is class 0.
        assert!((acc - 0.5).abs() < 1e-12, "acc = {acc}");
    }

    #[test]
    fn fig2a_pissa_converges_faster_than_lora() {
        // The paper's Figure 2a claim, at small scale: after the same
        // number of steps, PiSSA's loss is below LoRA's.
        let (lora_l, pissa_l, full_l) = fig2a_protocol(32, 4, 80, 40, 0.5, 7);
        let last = |v: &Vec<f64>| v[v.len() - 1];
        assert!(
            last(&pissa_l) < last(&lora_l),
            "pissa {} should beat lora {}",
            last(&pissa_l),
            last(&lora_l)
        );
        // and full FT is the lower bound on loss here
        assert!(last(&full_l) <= last(&pissa_l) * 1.5);
    }
}
