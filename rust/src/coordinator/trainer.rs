//! The training-loop driver: executes AOT-compiled train-step artifacts
//! through PJRT, owns the (trainable, m, v) state, applies the LR
//! schedule, and streams metrics. Python is never on this path.

use super::sched::LrSchedule;
use crate::data::Batch;
use crate::metrics::StepMetrics;
use crate::model::params::{ParamStore, Tensor};
use crate::model::TrainState;
use crate::runtime::{lit_i32, lit_scalar_f32, scalar_f32, Artifact, Manifest, Runtime};
use crate::util::timer::Timer;
use anyhow::{Context, Result};
use std::sync::Arc;

/// A live decoder fine-tuning session bound to one train artifact.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    exe: Arc<xla::PjRtLoadedExecutable>,
    art: Artifact,
    /// Frozen parameters marshalled once (hot-path optimization: the
    /// frozen block dominates input bytes and never changes).
    frozen_lits: Vec<xla::Literal>,
    pub state: TrainState,
    pub sched: LrSchedule,
    pub history: Vec<StepMetrics>,
    /// Rust-side overhead (marshalling etc.) accumulated for §Perf.
    pub overhead_s: f64,
    /// Total step wall time accumulated.
    pub total_s: f64,
}

impl<'rt> Trainer<'rt> {
    /// Bind a train state to its artifact. Validates that the state's
    /// tensors match the manifest shapes exactly.
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        artifact_name: &str,
        state: TrainState,
        sched: LrSchedule,
    ) -> Result<Trainer<'rt>> {
        let art = manifest.get(artifact_name)?.clone();
        anyhow::ensure!(
            art.kind == "train" || art.kind == "encoder_train",
            "artifact '{artifact_name}' is not a train step (kind={})",
            art.kind
        );
        // AOT train artifacts are lowered for adapters on all seven linears
        // at one uniform rank; reject partially-targeted specs up front
        // with a pointer to the engine (which serves them natively) rather
        // than a confusing missing-tensor error below.
        anyhow::ensure!(
            state.spec.covers_all(),
            "artifact '{artifact_name}' expects adapters on all seven linears, but \
             spec '{}' targets only [{}] — partial targeting is an AdapterEngine \
             feature, not an artifact one",
            state.spec,
            state.spec.target_modules().join(",")
        );
        anyhow::ensure!(
            state.spec.uniform_rank(),
            "artifact '{artifact_name}' was lowered for uniform rank {}, but spec \
             '{}' carries per-module rank overrides",
            state.spec.rank,
            state.spec
        );
        validate_state(&art, &state)?;
        let exe = rt.load(artifact_name, &art.file)?;
        let frozen_lits = marshal(&state.frozen, &art.frozen_names)?;
        Ok(Trainer { rt, exe, art, frozen_lits, state, sched, history: Vec::new(), overhead_s: 0.0, total_s: 0.0 })
    }

    pub fn artifact(&self) -> &Artifact {
        &self.art
    }

    /// Run one optimizer step on a decoder batch.
    pub fn step(&mut self, batch: &Batch) -> Result<StepMetrics> {
        anyhow::ensure!(self.art.kind == "train", "use step_encoder for encoder artifacts");
        let total = Timer::start();
        let t0 = Timer::start();
        let b = self.art.batch as i64;
        let t = self.art.seq_len as i64;
        anyhow::ensure!(
            batch.batch == self.art.batch && batch.seq_len == self.art.seq_len,
            "batch {}x{} vs artifact {}x{}",
            batch.batch,
            batch.seq_len,
            self.art.batch,
            self.art.seq_len
        );
        let step_no = self.state.step + 1;
        let lr = self.sched.at(step_no) as f32;

        let tokens = lit_i32(&batch.tokens, &[b, t])?;
        let mask = crate::runtime::lit_f32(&batch.loss_mask, &[b, t])?;
        let lr_lit = lit_scalar_f32(lr);
        let step_lit = lit_scalar_f32(step_no as f32);

        let train_lits = marshal(&self.state.trainable, &self.art.trainable_names)?;
        let m_lits = marshal(&self.state.m, &self.art.trainable_names)?;
        let v_lits = marshal(&self.state.v, &self.art.trainable_names)?;

        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.art.args.len());
        inputs.extend([&tokens, &mask, &lr_lit, &step_lit]);
        inputs.extend(self.frozen_lits.iter());
        inputs.extend(train_lits.iter());
        inputs.extend(m_lits.iter());
        inputs.extend(v_lits.iter());
        anyhow::ensure!(inputs.len() == self.art.args.len(), "arg count mismatch");
        let marshal_s = t0.secs();

        let outs = self.rt.execute_refs(&self.exe, &inputs)?;

        let t1 = Timer::start();
        let loss = scalar_f32(&outs[0])?;
        let grad_norm = scalar_f32(&outs[1])?;
        self.unmarshal_state(&outs[2..])?;
        self.state.step = step_no;
        let unmarshal_s = t1.secs();

        let metrics = StepMetrics {
            step: step_no,
            loss,
            grad_norm,
            lr,
            step_time_s: total.secs(),
        };
        self.overhead_s += marshal_s + unmarshal_s;
        self.total_s += metrics.step_time_s;
        self.history.push(metrics.clone());
        Ok(metrics)
    }

    /// Run one optimizer step on an encoder (NLU) batch.
    pub fn step_encoder(
        &mut self,
        tokens: &[i32],
        attn_mask: &[f32],
        labels: &[i32],
    ) -> Result<StepMetrics> {
        anyhow::ensure!(self.art.kind == "encoder_train", "not an encoder artifact");
        let total = Timer::start();
        let b = self.art.batch as i64;
        let t = self.art.seq_len as i64;
        let step_no = self.state.step + 1;
        let lr = self.sched.at(step_no) as f32;

        let tokens = lit_i32(tokens, &[b, t])?;
        let amask = crate::runtime::lit_f32(attn_mask, &[b, t])?;
        let labels = lit_i32(labels, &[b])?;
        let lr_lit = lit_scalar_f32(lr);
        let step_lit = lit_scalar_f32(step_no as f32);

        let train_lits = marshal(&self.state.trainable, &self.art.trainable_names)?;
        let m_lits = marshal(&self.state.m, &self.art.trainable_names)?;
        let v_lits = marshal(&self.state.v, &self.art.trainable_names)?;

        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.art.args.len());
        inputs.extend([&tokens, &amask, &labels, &lr_lit, &step_lit]);
        inputs.extend(self.frozen_lits.iter());
        inputs.extend(train_lits.iter());
        inputs.extend(m_lits.iter());
        inputs.extend(v_lits.iter());
        anyhow::ensure!(inputs.len() == self.art.args.len(), "arg count mismatch");

        let outs = self.rt.execute_refs(&self.exe, &inputs)?;
        let loss = scalar_f32(&outs[0])?;
        let grad_norm = scalar_f32(&outs[1])?;
        self.unmarshal_state(&outs[2..])?;
        self.state.step = step_no;

        let metrics = StepMetrics { step: step_no, loss, grad_norm, lr, step_time_s: total.secs() };
        self.total_s += metrics.step_time_s;
        self.history.push(metrics.clone());
        Ok(metrics)
    }

    /// Write updated trainable/m/v tensors back from artifact outputs
    /// (outputs[0..] = trainables, then m, then v, in manifest order).
    fn unmarshal_state(&mut self, outs: &[xla::Literal]) -> Result<()> {
        let names = self.art.trainable_names.clone();
        let nt = names.len();
        anyhow::ensure!(outs.len() == 3 * nt, "expected {} outputs, got {}", 3 * nt, outs.len());
        for (i, name) in names.iter().enumerate() {
            let shape = self.state.trainable[name].shape.clone();
            self.state.trainable.insert(name.clone(), Tensor::from_literal(&outs[i], &shape)?);
            self.state.m.insert(name.clone(), Tensor::from_literal(&outs[nt + i], &shape)?);
            self.state.v.insert(name.clone(), Tensor::from_literal(&outs[2 * nt + i], &shape)?);
        }
        Ok(())
    }

    /// Mean loss over the last `n` recorded steps.
    pub fn recent_loss(&self, n: usize) -> f32 {
        let tail = &self.history[self.history.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|m| m.loss).sum::<f32>() / tail.len() as f32
    }
}

fn marshal(store: &ParamStore, names: &[String]) -> Result<Vec<xla::Literal>> {
    crate::model::params::to_literals(store, names)
}

fn validate_state(art: &Artifact, state: &TrainState) -> Result<()> {
    let by_name: std::collections::BTreeMap<&str, &[usize]> =
        art.args.iter().map(|a| (a.name.as_str(), a.shape.as_slice())).collect();
    for name in &art.frozen_names {
        let t = state
            .frozen
            .get(name)
            .with_context(|| format!("state missing frozen '{name}'"))?;
        anyhow::ensure!(
            by_name[name.as_str()] == t.shape.as_slice(),
            "frozen '{name}': state {:?} vs artifact {:?}",
            t.shape,
            by_name[name.as_str()]
        );
    }
    for name in &art.trainable_names {
        let t = state
            .trainable
            .get(name)
            .with_context(|| format!("state missing trainable '{name}'"))?;
        anyhow::ensure!(
            by_name[name.as_str()] == t.shape.as_slice(),
            "trainable '{name}': state {:?} vs artifact {:?}",
            t.shape,
            by_name[name.as_str()]
        );
    }
    Ok(())
}
