//! L3 coordinator: the training-loop driver over PJRT artifacts, the
//! Alpaca LR schedule, the experiment orchestration verbs
//! (pretrain/finetune/evaluate), and the rust-native Figure-2a toy.

pub mod experiment;
pub mod sched;
pub mod toy;
pub mod trainer;

pub use experiment::{evaluate, finetune, pretrain, RunConfig, RunResult, TaskFamily};
pub use sched::LrSchedule;
pub use trainer::Trainer;
