//! Learning-rate schedule: cosine annealing with linear warmup — the
//! paper's Alpaca recipe (warmup ratio 0.03, cosine decay, no weight
//! decay; weight decay lives in the L2 AdamW which is set to 0).

/// Cosine schedule with linear warmup.
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub peak_lr: f64,
    pub total_steps: usize,
    pub warmup_steps: usize,
    /// Final lr as a fraction of peak (paper decays to ~0).
    pub min_ratio: f64,
}

impl LrSchedule {
    /// The paper's recipe: warmup_ratio 0.03, decay to 0.
    pub fn alpaca(peak_lr: f64, total_steps: usize) -> LrSchedule {
        LrSchedule {
            peak_lr,
            total_steps,
            warmup_steps: ((total_steps as f64) * 0.03).ceil() as usize,
            min_ratio: 0.0,
        }
    }

    /// LR at a 1-based step index.
    pub fn at(&self, step: usize) -> f64 {
        if self.total_steps == 0 {
            return self.peak_lr;
        }
        if step <= self.warmup_steps && self.warmup_steps > 0 {
            return self.peak_lr * step as f64 / self.warmup_steps as f64;
        }
        let progress = (step - self.warmup_steps) as f64
            / (self.total_steps - self.warmup_steps).max(1) as f64;
        let progress = progress.clamp(0.0, 1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * progress).cos());
        self.peak_lr * (self.min_ratio + (1.0 - self.min_ratio) * cos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_rises_then_cosine_falls() {
        let s = LrSchedule::alpaca(1e-3, 100);
        assert_eq!(s.warmup_steps, 3);
        assert!(s.at(1) < s.at(2) && s.at(2) < s.at(3));
        assert!((s.at(3) - 1e-3).abs() < 1e-12);
        assert!(s.at(50) < s.at(3));
        assert!(s.at(100) < 1e-5);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = LrSchedule::alpaca(2e-5, 1000);
        let mut prev = f64::INFINITY;
        for step in (s.warmup_steps..=1000).step_by(50) {
            let lr = s.at(step.max(1));
            assert!(lr <= prev + 1e-15);
            prev = lr;
        }
    }

    #[test]
    fn zero_total_steps_is_constant() {
        let s = LrSchedule { peak_lr: 1e-4, total_steps: 0, warmup_steps: 0, min_ratio: 0.0 };
        assert_eq!(s.at(1), 1e-4);
        assert_eq!(s.at(999), 1e-4);
    }
}
