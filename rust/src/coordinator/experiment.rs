//! High-level experiment orchestration: pre-train a base model, apply a
//! strategy, fine-tune, evaluate — the verbs every bench harness and the
//! CLI compose. All runs are deterministic given their seeds.

use super::sched::LrSchedule;
use super::trainer::Trainer;
use crate::adapter::init::Strategy;
use crate::adapter::spec::AdapterSpec;
use crate::data::batcher::Batcher;
use crate::data::tokenizer::Example;
use crate::data::{codegen, mathqa};
use crate::metrics::StepMetrics;
use crate::model::{apply_spec, BaseModel, TrainState};
use crate::runtime::{Manifest, Runtime};
use crate::util::rng::Rng;
use anyhow::Result;

/// Which fine-tuning corpus to use (the paper's three NLG task families).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskFamily {
    /// MetaMathQA → GSM8K analog.
    Math,
    /// CodeFeedback → HumanEval analog.
    Code,
    /// WizardLM → MT-Bench analog (mixed corpus, scored as math here).
    Chat,
}

impl TaskFamily {
    pub fn name(&self) -> &'static str {
        match self {
            TaskFamily::Math => "math",
            TaskFamily::Code => "code",
            TaskFamily::Chat => "chat",
        }
    }

    /// Build the fine-tuning corpus. `level` applies to the math families.
    pub fn corpus(&self, n: usize, seed: u64, level: mathqa::MathLevel) -> Vec<Example> {
        match self {
            TaskFamily::Math => {
                mathqa::gen_dataset(level, n, seed).into_iter().map(|p| p.example).collect()
            }
            TaskFamily::Code => codegen::gen_dataset(n, seed).into_iter().map(|t| t.example).collect(),
            TaskFamily::Chat => {
                // mixed easy math + code + echo lines (instruction variety)
                let mut out: Vec<Example> = mathqa::gen_dataset(mathqa::MathLevel::Easy, n / 2, seed)
                    .into_iter()
                    .map(|p| p.example)
                    .collect();
                out.extend(codegen::gen_dataset(n - n / 2, seed ^ 0xC0DE).into_iter().map(|t| t.example));
                out
            }
        }
    }
}

/// The hardest math level whose worst-case example fits `seq_len` tokens.
pub fn level_for_seq(seq_len: usize) -> mathqa::MathLevel {
    if seq_len >= mathqa::max_tokens(mathqa::MathLevel::Hard) {
        mathqa::MathLevel::Hard
    } else if seq_len >= mathqa::max_tokens(mathqa::MathLevel::Std) {
        mathqa::MathLevel::Std
    } else {
        mathqa::MathLevel::Easy
    }
}

/// Settings for one fine-tuning run: the adapter spec plus the training
/// budget/data knobs. Everything about HOW the adapter is initialized
/// (strategy, rank, alpha, niter, iters, window, targets) lives in the
/// [`AdapterSpec`].
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub config: String,
    pub spec: AdapterSpec,
    pub steps: usize,
    pub peak_lr: f64,
    pub corpus_size: usize,
    pub seed: u64,
    pub task: TaskFamily,
}

impl RunConfig {
    pub fn quick(config: &str, spec: AdapterSpec) -> RunConfig {
        RunConfig {
            config: config.to_string(),
            spec,
            steps: 60,
            peak_lr: 2e-3,
            corpus_size: 512,
            seed: 42,
            task: TaskFamily::Math,
        }
    }

    /// Legacy shim: the old `(strategy, rank)` entry point (iters = 5),
    /// producing bit-identical initializations for equivalent configs.
    #[deprecated(note = "use RunConfig::quick with an AdapterSpec")]
    pub fn quick_strategy(config: &str, strategy: Strategy, rank: usize) -> RunConfig {
        RunConfig::quick(config, AdapterSpec::from_strategy(strategy, rank, 5))
    }

    pub fn strategy(&self) -> Strategy {
        self.spec.strategy
    }

    pub fn rank(&self) -> usize {
        self.spec.rank
    }

    /// Conventional train-artifact name for this run.
    pub fn train_artifact(&self) -> String {
        Manifest::train_name(&self.config, self.spec.rank, self.spec.is_full_ft())
    }

    /// Conventional logits-artifact name for this run.
    pub fn logits_artifact(&self) -> String {
        Manifest::logits_name(&self.config, self.spec.rank, self.spec.is_full_ft())
    }
}

/// Result of a fine-tuning run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub history: Vec<StepMetrics>,
    pub final_state: TrainState,
    pub trainable_params: usize,
    pub overhead_s: f64,
    pub total_s: f64,
}

impl RunResult {
    pub fn final_loss(&self, window: usize) -> f32 {
        let tail = &self.history[self.history.len().saturating_sub(window)..];
        tail.iter().map(|m| m.loss).sum::<f32>() / tail.len().max(1) as f32
    }
}

/// Pre-train a random-init base model with the full-FT artifact on the
/// synthetic corpus; returns the base model with trained weights.
pub fn pretrain(
    rt: &Runtime,
    manifest: &Manifest,
    config: &str,
    steps: usize,
    peak_lr: f64,
    seed: u64,
) -> Result<(BaseModel, Vec<StepMetrics>)> {
    let cfg = manifest.config(config)?.clone();
    let mut rng = Rng::new(seed);
    let base = BaseModel::random(&cfg, &mut rng);
    let state = apply_spec(&base, &AdapterSpec::full_ft(), &mut rng)?;
    let art_name = Manifest::train_name(config, 0, true);
    let sched = LrSchedule::alpaca(peak_lr, steps);
    let mut trainer = Trainer::new(rt, manifest, &art_name, state, sched)?;

    let corpus: Vec<Example> = crate::data::corpus::gen_corpus(steps.max(64) * cfg.batch, seed ^ 0xBA5E);
    let mut batcher = Batcher::new(corpus, cfg.batch, cfg.seq_len, seed ^ 0xF00D);
    let mut history = Vec::with_capacity(steps);
    for _ in 0..steps {
        history.push(trainer.step(&batcher.next_batch())?);
    }

    // Harvest the trained weights back into a BaseModel.
    let mut trained = base;
    trained.scaffold.insert("embed".into(), trainer.state.trainable["embed"].clone());
    trained.scaffold.insert("lm_head".into(), trainer.state.trainable["lm_head"].clone());
    let mut linears = crate::model::ParamStore::new();
    for name in crate::model::LINEARS {
        let key = format!("base_{name}");
        linears.insert(key.clone(), trainer.state.trainable[&key].clone());
    }
    trained.set_linears(linears);
    Ok((trained, history))
}

/// Fine-tune a base model under a strategy; returns metrics + final state.
pub fn finetune(
    rt: &Runtime,
    manifest: &Manifest,
    base: &BaseModel,
    run: &RunConfig,
) -> Result<RunResult> {
    let cfg = manifest.config(&run.config)?.clone();
    let mut rng = Rng::new(run.seed);
    let state = apply_spec(base, &run.spec, &mut rng)?;
    let trainable_params = crate::model::count_params(
        &state.trainable,
        &state.trainable.keys().cloned().collect::<Vec<_>>(),
    );
    let art_name = run.train_artifact();
    let sched = LrSchedule::alpaca(run.peak_lr, run.steps);
    let mut trainer = Trainer::new(rt, manifest, &art_name, state, sched)?;

    let level = level_for_seq(cfg.seq_len);
    let corpus = run.task.corpus(run.corpus_size, run.seed ^ 0xDA7A, level);
    let mut batcher = Batcher::new(corpus, cfg.batch, cfg.seq_len, run.seed ^ 0x5EED);
    for _ in 0..run.steps {
        trainer.step(&batcher.next_batch())?;
    }
    Ok(RunResult {
        history: trainer.history.clone(),
        overhead_s: trainer.overhead_s,
        total_s: trainer.total_s,
        final_state: trainer.state,
        trainable_params,
    })
}

/// Evaluate a fine-tuned state on the task family's held-out suite.
pub fn evaluate(
    rt: &Runtime,
    manifest: &Manifest,
    run: &RunConfig,
    state: &TrainState,
    n_eval: usize,
    max_new: usize,
) -> Result<f64> {
    let art_name = run.logits_artifact();
    let gen = crate::eval::Generator::new(rt, manifest, &art_name, state)?;
    let cfg = manifest.config(&run.config)?;
    let level = level_for_seq(cfg.seq_len);
    let eval_seed = run.seed ^ 0xE7A1;
    match run.task {
        TaskFamily::Math | TaskFamily::Chat => {
            let problems = mathqa::gen_dataset(level, n_eval, eval_seed);
            crate::eval::eval_math(&gen, &problems, max_new)
        }
        TaskFamily::Code => {
            let tasks = codegen::gen_dataset(n_eval, eval_seed);
            crate::eval::eval_code(&gen, &tasks, max_new)
        }
    }
}
