//! Minimal JSON value model, parser, and writer.
//!
//! serde is not in the offline vendor set, so config files and the AOT
//! `manifest.json` are handled by this hand-rolled implementation. It
//! supports the full JSON grammar we emit from python (`json.dump`):
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap for deterministic iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access: `j.get("key")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Required typed accessors (error messages name the key).
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .map(|n| n as usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid numeric field '{key}'"))
    }
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid numeric field '{key}'"))
    }
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field '{key}'"))
    }
    /// Insert into an object value (panics if not an object).
    pub fn set(&mut self, key: &str, val: Json) {
        match self {
            Json::Obj(o) => {
                o.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Parse a JSON document.
    pub fn parse(src: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            b: src.as_bytes(),
            i: 0,
            depth: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !o.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting depth. Network-facing inputs (the HTTP API)
/// go through this parser, so recursion must be bounded — a document of
/// a few thousand `[` bytes would otherwise overflow the stack.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? != c {
            anyhow::bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.b[self.i] as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'{' | b'[' => {
                if self.depth >= MAX_DEPTH {
                    anyhow::bail!("nesting deeper than {MAX_DEPTH} at byte {}", self.i);
                }
                self.depth += 1;
                let v = if self.peek()? == b'{' { self.object() } else { self.array() };
                self.depth -= 1;
                v
            }
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => anyhow::bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                c => anyhow::bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pairs: a high surrogate MUST be
                            // followed by an escaped low surrogate; both
                            // lone halves are rejected (the HTTP API makes
                            // this user-facing — no U+FFFD smoothing).
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        anyhow::bail!(
                                            "\\u{cp:04x} not followed by a low surrogate"
                                        );
                                    }
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    anyhow::bail!("lone high surrogate \\u{cp:04x}");
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                anyhow::bail!("lone low surrogate \\u{cp:04x}");
                            } else {
                                cp
                            };
                            s.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| anyhow::anyhow!("invalid scalar U+{ch:X}"))?,
                            );
                        }
                        c => anyhow::bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.i - 1;
                    let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = txt
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid number '{txt}' at byte {start}"))?;
        // `"1e999".parse::<f64>()` yields inf without erroring; JSON has
        // no non-finite numbers, so reject rather than propagate them.
        if !n.is_finite() {
            anyhow::bail!("number '{txt}' at byte {start} overflows f64");
        }
        Ok(Json::Num(n))
    }

    /// Four hex digits of a `\uXXXX` escape (cursor just past the `u`).
    fn hex4(&mut self) -> anyhow::Result<u32> {
        let end = self
            .i
            .checked_add(4)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
        let hex = std::str::from_utf8(&self.b[self.i..end])?;
        let cp = u32::from_str_radix(hex, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape '\\u{hex}'"))?;
        self.i = end;
        Ok(cp)
    }
}

/// Convenience constructors.
pub fn jnum(n: f64) -> Json {
    Json::Num(n)
}
pub fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}
pub fn jarr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_and_escapes() {
        let src = r#"{"s": "café 😀 \"q\" \\"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("café 😀 \"q\" \\"));
        let rt = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn raw_utf8_passthrough() {
        let src = "{\"s\": \"héllo→世界\"}";
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("héllo→世界"));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn surrogate_pairs_decode_to_non_bmp_scalars() {
        // 😀 = U+1F600 GRINNING FACE; 𐍈 = U+10348.
        let v = Json::parse("\"\\uD83D\\uDE00 \\uD800\\uDF48\"").unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600} \u{10348}"));
        // Escaped non-BMP round-trips through our writer (raw UTF-8 out).
        let rt = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, rt);
        // BMP escapes still work, including lowercase hex and U+FFFD.
        let esc = Json::parse("\"\\u00e9 \\uFFFD\"").unwrap();
        assert_eq!(esc.as_str(), Some("é \u{FFFD}"));
    }

    #[test]
    fn lone_and_mismatched_surrogates_are_rejected() {
        // Lone high surrogate (end of string).
        assert!(Json::parse(r#""\uD83D""#).is_err());
        // Lone high surrogate followed by a normal escape.
        assert!(Json::parse(r#""\uD83D\n""#).is_err());
        // High surrogate followed by a non-low \u escape.
        assert!(Json::parse(r#""\uD83DA""#).is_err());
        // High surrogate followed by another high surrogate.
        assert!(Json::parse(r#""\uD83D\uD83D""#).is_err());
        // Lone low surrogate.
        assert!(Json::parse(r#""\uDE00""#).is_err());
        // Truncated second escape must error, not panic on a short slice.
        assert!(Json::parse(r#""\uD83D\uDE"#).is_err());
    }

    #[test]
    fn truncated_documents_error_cleanly() {
        for src in [
            "",
            "{\"a\":",
            "{\"a\": 1",
            "[1, 2",
            "\"abc",
            "\"ab\\",
            "\"ab\\u",
            "\"ab\\u00",
            "tru",
            "-",
        ] {
            assert!(Json::parse(src).is_err(), "src={src:?}");
        }
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing_the_stack() {
        // Within the limit: fine.
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&ok).is_ok());
        // Past the limit: typed error, not a stack overflow.
        let arrs = "[".repeat(100_000) + &"]".repeat(100_000);
        assert!(Json::parse(&arrs).is_err());
        let objs = "{\"k\":".repeat(100_000) + "null" + &"}".repeat(100_000);
        assert!(Json::parse(&objs).is_err());
    }

    #[test]
    fn duplicate_keys_last_one_wins() {
        let v = Json::parse(r#"{"a": 1, "a": 2}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.as_obj().unwrap().len(), 1);
    }

    #[test]
    fn oversized_numbers_are_rejected() {
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("-1e999").is_err());
        let long = "9".repeat(400);
        assert!(Json::parse(&long).is_err());
        // Subnormal underflow parses to 0.0 — finite, so accepted.
        assert_eq!(Json::parse("1e-999").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn numbers() {
        for (s, want) in [
            ("0", 0.0),
            ("-0.5", -0.5),
            ("1e-3", 0.001),
            ("123456789", 123456789.0),
            ("3.14159", 3.14159),
        ] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(want), "src={s}");
        }
    }

    #[test]
    fn fuzz_roundtrip_random_trees() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xC0FFEE);
        for _ in 0..50 {
            let tree = random_tree(&mut rng, 0);
            let s = tree.to_string();
            let back = Json::parse(&s).unwrap();
            assert_eq!(tree, back, "src={s}");
        }
    }

    fn random_tree(rng: &mut crate::util::rng::Rng, depth: usize) -> Json {
        let pick = if depth > 3 { rng.below(4) } else { rng.below(6) };
        match pick {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.range_i64(-1000, 1000) as f64) / 8.0),
            3 => {
                let n = rng.below(8);
                Json::Str((0..n).map(|_| *rng.choice(&['a', 'é', '\n', '"', 'z'])).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| random_tree(rng, depth + 1)).collect()),
            _ => {
                let mut o = BTreeMap::new();
                for i in 0..rng.below(4) {
                    o.insert(format!("k{i}"), random_tree(rng, depth + 1));
                }
                Json::Obj(o)
            }
        }
    }
}
