//! Tiny declarative CLI flag parser (clap is not in the offline vendor set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated
//! positional arguments, and generates a usage string. Used by the `pissa`
//! binary, the examples, and the bench harnesses. Malformed flag values
//! surface as a typed [`ArgError`] (never a panic), so the binary can
//! print usage and exit nonzero instead of unwinding.

use std::collections::BTreeMap;
use std::fmt;

/// A malformed flag value: `--rank banana` where an integer was expected.
/// Implements [`std::error::Error`], so it converts into `anyhow::Error`
/// with `?` and can be recovered by downcast at the top level to print
/// usage + exit nonzero.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgError {
    pub flag: String,
    pub message: String,
}

impl ArgError {
    fn new(flag: &str, message: String) -> ArgError {
        ArgError { flag: flag.to_string(), message }
    }
}

impl fmt::Display for ArgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "--{}: {}", self.flag, self.message)
    }
}

impl std::error::Error for ArgError {}

/// Parsed arguments: flags plus positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// Splitting into flags/positionals never fails; value validation
    /// happens in the typed accessors below.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), v);
                } else {
                    // Trailing `--flag` or `--flag --other`: boolean.
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, ArgError> {
        match self.get(name) {
            Some(v) => v
                .parse()
                .map_err(|_| ArgError::new(name, format!("expects an integer, got '{v}'"))),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, ArgError> {
        match self.get(name) {
            Some(v) => v
                .parse()
                .map_err(|_| ArgError::new(name, format!("expects an integer, got '{v}'"))),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, ArgError> {
        match self.get(name) {
            Some(v) => {
                v.parse().map_err(|_| ArgError::new(name, format!("expects a number, got '{v}'")))
            }
            None => Ok(default),
        }
    }

    pub fn bool_or(&self, name: &str, default: bool) -> bool {
        self.get(name).map(|v| matches!(v, "true" | "1" | "yes")).unwrap_or(default)
    }

    /// Comma-separated list of usizes: `--ranks 1,2,4`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, ArgError> {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| ArgError::new(name, format!("bad integer '{}'", s.trim())))
                })
                .collect(),
            None => Ok(default.to_vec()),
        }
    }

    /// Comma-separated list of strings.
    pub fn str_list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').filter(|s| !s.is_empty()).map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn flags_and_positionals() {
        // NOTE: a bare `--flag` followed by a non-flag token consumes it as
        // the value, so positionals must precede bare boolean flags.
        let a = p(&["train", "extra", "--rank", "8", "--strategy=pissa", "--verbose"]);
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.usize_or("rank", 4).unwrap(), 8);
        assert_eq!(a.str_or("strategy", "lora"), "pissa");
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn lists() {
        let a = p(&["--ranks", "1,2,4,8", "--models", "a, b"]);
        assert_eq!(a.usize_list_or("ranks", &[]).unwrap(), vec![1, 2, 4, 8]);
        assert_eq!(a.str_list_or("models", &[]), vec!["a", "b"]);
        assert_eq!(a.usize_list_or("other", &[3]).unwrap(), vec![3]);
    }

    #[test]
    fn negative_number_value() {
        let a = p(&["--lr", "-0.5"]);
        // "-0.5" does not start with "--", so it is consumed as the value.
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), -0.5);
    }

    #[test]
    fn malformed_values_are_typed_errors_not_panics() {
        let a = p(&["--rank", "banana", "--lr", "fast", "--ranks", "1,x,3"]);
        let e = a.usize_or("rank", 4).unwrap_err();
        assert_eq!(e.flag, "rank");
        assert!(e.to_string().contains("banana"), "msg={e}");
        assert!(a.u64_or("rank", 4).is_err());
        assert!(a.f64_or("lr", 0.0).is_err());
        let le = a.usize_list_or("ranks", &[]).unwrap_err();
        assert!(le.to_string().contains("'x'"), "msg={le}");
    }

    #[test]
    fn trailing_valueless_flag_is_boolean_not_a_panic() {
        // Regression: `--quantized` as the LAST token used to hit the
        // value-consuming path; it must parse as a boolean flag.
        let a = p(&["serve", "--quantized"]);
        assert!(a.bool_or("quantized", false));
        let b = p(&["--alpha", "--beta"]);
        assert!(b.bool_or("alpha", false));
        assert!(b.bool_or("beta", false));
    }
}
