//! Tiny declarative CLI flag parser (clap is not in the offline vendor set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated
//! positional arguments, and generates a usage string. Used by the `pissa`
//! binary, the examples, and the bench harnesses.

use std::collections::BTreeMap;

/// Parsed arguments: flags plus positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn bool_or(&self, name: &str, default: bool) -> bool {
        self.get(name)
            .map(|v| matches!(v, "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// Comma-separated list of usizes: `--ranks 1,2,4`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad int '{s}'")))
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated list of strings.
    pub fn str_list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').filter(|s| !s.is_empty()).map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn flags_and_positionals() {
        // NOTE: a bare `--flag` followed by a non-flag token consumes it as
        // the value, so positionals must precede bare boolean flags.
        let a = p(&["train", "extra", "--rank", "8", "--strategy=pissa", "--verbose"]);
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.usize_or("rank", 4), 8);
        assert_eq!(a.str_or("strategy", "lora"), "pissa");
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn lists() {
        let a = p(&["--ranks", "1,2,4,8", "--models", "a, b"]);
        assert_eq!(a.usize_list_or("ranks", &[]), vec![1, 2, 4, 8]);
        assert_eq!(a.str_list_or("models", &[]), vec!["a", "b"]);
        assert_eq!(a.usize_list_or("other", &[3]), vec![3]);
    }

    #[test]
    fn negative_number_value() {
        let a = p(&["--lr", "-0.5"]);
        // "-0.5" does not start with "--", so it is consumed as the value.
        assert_eq!(a.f64_or("lr", 0.0), -0.5);
    }
}
