//! Timing and micro-benchmark statistics (criterion is not available
//! offline; the bench harnesses use this instead).

use std::time::Instant;

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
    pub fn us(&self) -> f64 {
        self.secs() * 1e6
    }
}

/// Summary statistics over a set of timed samples (seconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl BenchStats {
    pub fn from_samples(mut samples: Vec<f64>) -> BenchStats {
        assert!(!samples.is_empty());
        // total_cmp: a NaN sample (e.g. a degenerate latency ratio fed in
        // by a caller) must not panic the stats path — under the IEEE
        // total order NaNs sort to the ends and the finite percentiles
        // stay meaningful.
        samples.sort_by(f64::total_cmp);
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| samples[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        BenchStats {
            n,
            mean,
            std: var.sqrt(),
            min: samples[0],
            p50: pct(0.50),
            p95: pct(0.95),
            max: samples[n - 1],
        }
    }

    /// Render as `mean ± std (min … p95)` with automatic unit scaling.
    pub fn human(&self) -> String {
        fn unit(s: f64) -> String {
            if s >= 1.0 {
                format!("{s:.3} s")
            } else if s >= 1e-3 {
                format!("{:.3} ms", s * 1e3)
            } else {
                format!("{:.1} µs", s * 1e6)
            }
        }
        format!(
            "{} ± {} (min {}, p95 {})",
            unit(self.mean),
            unit(self.std),
            unit(self.min),
            unit(self.p95)
        )
    }
}

/// Run `f` repeatedly: `warmup` discarded iterations then `iters` timed ones.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchStats::from_samples(samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_order() {
        let s = BenchStats::from_samples(vec![3.0, 1.0, 2.0, 10.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 10.0);
        assert_eq!(s.n, 4);
        assert!(s.mean > 1.0 && s.mean < 10.0);
        assert!(s.p50 <= s.p95);
    }

    #[test]
    fn nan_samples_do_not_panic() {
        // Regression: this used to panic in sort_by(partial_cmp().unwrap()).
        let s = BenchStats::from_samples(vec![2.0, f64::NAN, 1.0, 3.0]);
        assert_eq!(s.n, 4);
        // Positive NaN sorts last under total_cmp: the low-end stats stay
        // finite, the NaN surfaces at the max end instead of panicking.
        assert_eq!(s.min, 1.0);
        assert!(s.p50.is_finite());
        assert!(s.max.is_nan());
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }
}
