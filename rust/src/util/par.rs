//! Scoped-thread data parallelism (rayon is not in the offline vendor set).
//!
//! `par_rows_mut` splits a mutable slice into contiguous chunks and runs a
//! closure on each chunk on its own OS thread via `std::thread::scope`;
//! `par_for` distributes an index range; `par_map` is a deterministic
//! parallel map (order-stable output, used by the serving router for
//! per-adapter-group dispatch). Threads are cheap at our scale (a handful
//! of spawns per GEMM call on matrices ≥256²; smaller work runs inline).

/// Number of worker threads to use (cores, overridable with PISSA_THREADS).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("PISSA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(start, end)` over disjoint subranges of `0..n` in parallel.
/// `min_grain` is the smallest range worth a thread; below
/// `2 * min_grain` everything runs inline on the caller thread.
pub fn par_for<F>(n: usize, min_grain: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let workers = num_threads().min(n / min_grain.max(1)).max(1);
    if workers <= 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Parallel `(0..n).map(f)` with a deterministic result order. Each worker
/// fills a disjoint slice of the output, so no locking and no reordering:
/// the result is identical for any `PISSA_THREADS`, provided `f` itself is
/// deterministic per index (the fixed-order reduction contract the serving
/// path relies on). Below `2 * min_grain` items everything runs inline.
pub fn par_map<U, F>(n: usize, min_grain: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let workers = num_threads().min(n / min_grain.max(1)).max(1);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<U>> = std::iter::repeat_with(|| None).take(n).collect();
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rest = out.as_mut_slice();
        let mut lo = 0;
        while lo < n {
            let take = chunk.min(n - lo);
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let f = &f;
            let base = lo;
            s.spawn(move || {
                for (off, slot) in head.iter_mut().enumerate() {
                    *slot = Some(f(base + off));
                }
            });
            lo += take;
        }
    });
    out.into_iter().map(|o| o.expect("par_map worker filled every slot")).collect()
}

/// Parallel iteration over mutable, equally-sized row chunks of a slice.
/// `rows` logical rows of width `width`; each worker gets a contiguous row
/// range `[lo, hi)` plus the matching mutable sub-slice.
pub fn par_rows_mut<T, F>(data: &mut [T], rows: usize, width: usize, min_rows: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    assert_eq!(data.len(), rows * width, "slice/rows/width mismatch");
    let workers = num_threads().min(rows / min_rows.max(1)).max(1);
    if workers <= 1 {
        f(0, rows, data);
        return;
    }
    let chunk_rows = rows.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut row = 0;
        while row < rows {
            let take = chunk_rows.min(rows - row);
            let (head, tail) = rest.split_at_mut(take * width);
            rest = tail;
            let f = &f;
            let lo = row;
            s.spawn(move || f(lo, lo + take, head));
            row += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_for_covers_range() {
        let total = AtomicUsize::new(0);
        par_for(1000, 10, |lo, hi| {
            total.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn par_for_small_runs_inline() {
        let total = AtomicUsize::new(0);
        par_for(3, 100, |lo, hi| {
            total.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn par_map_preserves_order() {
        let v = par_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
        // small inputs run inline and still return every element
        let w = par_map(3, 100, |i| i + 1);
        assert_eq!(w, vec![1, 2, 3]);
        let e: Vec<usize> = par_map(0, 1, |i| i);
        assert!(e.is_empty());
    }

    #[test]
    fn par_rows_mut_writes_all() {
        let rows = 64;
        let width = 16;
        let mut v = vec![0u32; rows * width];
        par_rows_mut(&mut v, rows, width, 4, |lo, _hi, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (lo * width + i) as u32;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i as u32);
        }
    }
}
