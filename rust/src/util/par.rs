//! Persistent-pool data parallelism (rayon is not in the offline vendor
//! set).
//!
//! The three entry points — [`par_rows_mut`] (mutable, equally-sized row
//! chunks of a slice), [`par_for`] (disjoint index subranges) and
//! [`par_map`] (order-stable parallel map) — keep the API and, more
//! importantly, the **determinism contract** of the original scoped-thread
//! implementation: work is partitioned into the same contiguous chunks for
//! a given parallelism degree, every chunk only touches its own disjoint
//! output region, and there are no cross-thread reductions, so results are
//! bit-identical no matter how chunks land on threads.
//!
//! What changed is the execution substrate. The original spawned fresh OS
//! threads on every call (`std::thread::scope`), which put a multi-µs
//! spawn/join tax on every GEMM dispatch — ruinous for the decode serving
//! path, where a single token step issues dozens of small GEMMs. Now a
//! **persistent worker pool** is spawned lazily on first use and parked on
//! a condvar between calls; a parallel call enqueues one type-erased job,
//! participates in draining its own chunks (so progress never depends on a
//! free worker — nested parallel calls from inside a worker cannot
//! deadlock), and blocks until the last chunk completes (so borrowed data
//! stays valid for exactly the call's duration, same as the scoped
//! version).
//!
//! The parallelism *degree* comes from `PISSA_THREADS`, parsed **once**
//! into a `OnceLock` (it used to be re-read and re-parsed from the
//! environment on every dispatch) and falling back to
//! `available_parallelism`. Unparsable values now fail loudly (a typed
//! [`ThreadConfigError`] surfaced as a stderr warning + hardware fallback)
//! instead of being silently ignored. Tests that need to compare degrees
//! in-process use the scoped [`with_parallelism`] override, since the
//! cached env parse is process-wide by design.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A `PISSA_THREADS` value that could not be parsed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ThreadConfigError {
    pub raw: String,
}

impl fmt::Display for ThreadConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PISSA_THREADS={:?} is not a thread count (expected a non-negative integer)",
            self.raw
        )
    }
}

impl std::error::Error for ThreadConfigError {}

/// Parse a `PISSA_THREADS` value. `0` is accepted and clamped to 1 (the
/// historical behavior: "no parallelism"), surrounding whitespace is
/// tolerated; anything else non-numeric is a typed error.
pub fn parse_threads(raw: &str) -> Result<usize, ThreadConfigError> {
    match raw.trim().parse::<usize>() {
        Ok(n) => Ok(n.max(1)),
        Err(_) => Err(ThreadConfigError { raw: raw.to_string() }),
    }
}

fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The env-configured degree, parsed exactly once per process.
static CONFIGURED: OnceLock<usize> = OnceLock::new();

/// Scoped in-process override (0 = none); see [`with_parallelism`].
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Parallelism degree for the next dispatch: the [`with_parallelism`]
/// override if one is active, else the `OnceLock`-cached `PISSA_THREADS`
/// parse (hardware parallelism when unset; stderr warning + hardware
/// fallback when unparsable).
pub fn num_threads() -> usize {
    let o = OVERRIDE.load(Ordering::Relaxed);
    if o != 0 {
        return o;
    }
    *CONFIGURED.get_or_init(|| match std::env::var("PISSA_THREADS") {
        Ok(v) => match parse_threads(&v) {
            Ok(n) => n,
            Err(e) => {
                let fallback = hardware_threads();
                eprintln!("[pissa] warning: {e}; falling back to {fallback} hardware threads");
                fallback
            }
        },
        Err(_) => hardware_threads(),
    })
}

/// Run `f` with the parallelism degree pinned to `n` (clamped to ≥ 1),
/// restoring the previous degree afterwards (panic-safe). This is how the
/// determinism suite compares thread counts **in one process** now that
/// the env parse is cached: the override is global, so callers that need
/// isolation must serialize (the suite already holds a lock to mutate
/// process-wide state).
pub fn with_parallelism<T>(n: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.store(self.0, Ordering::SeqCst);
        }
    }
    let prev = OVERRIDE.swap(n.max(1), Ordering::SeqCst);
    let _restore = Restore(prev);
    f()
}

/// One enqueued parallel call: a type-erased chunk runner plus the
/// claim/completion state. `run` borrows from the submitting caller's
/// stack; the lifetime is erased because the caller blocks until
/// `remaining` hits zero, and a worker that claims an index `>= n_chunks`
/// never touches `run` again — so the borrow is live for every actual
/// invocation.
struct Job {
    run: &'static (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    n_chunks: usize,
    remaining: AtomicUsize,
    panicked: AtomicBool,
    done: Mutex<bool>,
    cv: Condvar,
}

/// Claim and run chunks of `job` until none are left. Shared by pool
/// workers and the submitting caller (which guarantees progress even if
/// every pool worker is busy elsewhere).
fn run_chunks(job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n_chunks {
            return;
        }
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (job.run)(i)));
        if result.is_err() {
            job.panicked.store(true, Ordering::SeqCst);
        }
        if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = job.done.lock().unwrap();
            *done = true;
            job.cv.notify_all();
        }
    }
}

struct Pool {
    queue: Mutex<VecDeque<Arc<Job>>>,
    cv: Condvar,
    spawned: AtomicUsize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        queue: Mutex::new(VecDeque::new()),
        cv: Condvar::new(),
        spawned: AtomicUsize::new(0),
    })
}

impl Pool {
    /// Grow the pool to at least `target` parked workers (never shrinks;
    /// workers live for the process).
    fn ensure_workers(&'static self, target: usize) {
        loop {
            let cur = self.spawned.load(Ordering::Relaxed);
            if cur >= target {
                return;
            }
            if self
                .spawned
                .compare_exchange(cur, cur + 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                std::thread::Builder::new()
                    .name(format!("pissa-par-{cur}"))
                    .spawn(move || self.worker_loop())
                    .expect("failed to spawn pissa worker thread");
            }
        }
    }

    fn worker_loop(&'static self) {
        loop {
            let job = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if let Some(j) = q.pop_front() {
                        break j;
                    }
                    q = self.cv.wait(q).unwrap();
                }
            };
            run_chunks(&job);
        }
    }
}

/// Execute `run(0..n_chunks)` with up to `degree` threads (pool workers +
/// the caller). Blocks until every chunk has completed; propagates worker
/// panics to the caller.
fn run_parallel(n_chunks: usize, degree: usize, run: &(dyn Fn(usize) + Sync)) {
    if n_chunks == 0 {
        return;
    }
    if degree <= 1 || n_chunks == 1 {
        for i in 0..n_chunks {
            run(i);
        }
        return;
    }
    let p = pool();
    let helpers = (degree - 1).min(n_chunks - 1);
    p.ensure_workers(helpers);
    // Erase the borrow: safe because this function does not return until
    // `remaining == 0`, and no chunk index < n_chunks is ever claimed
    // twice (fetch_add), so `run` outlives every dereference.
    let run_static: &'static (dyn Fn(usize) + Sync) = unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(run)
    };
    let job = Arc::new(Job {
        run: run_static,
        next: AtomicUsize::new(0),
        n_chunks,
        remaining: AtomicUsize::new(n_chunks),
        panicked: AtomicBool::new(false),
        done: Mutex::new(false),
        cv: Condvar::new(),
    });
    {
        // One queue entry per helper we want on this job; a worker that
        // pops an already-drained entry claims no chunk and moves on.
        let mut q = p.queue.lock().unwrap();
        for _ in 0..helpers {
            q.push_back(job.clone());
        }
    }
    p.cv.notify_all();
    run_chunks(&job);
    let mut done = job.done.lock().unwrap();
    while !*done {
        done = job.cv.wait(done).unwrap();
    }
    drop(done);
    // Sweep any still-queued handles for this job (pushed for workers
    // that never got to it) so no queue entry outlives the borrow the
    // job's closure reference was transmuted from.
    {
        let mut q = p.queue.lock().unwrap();
        q.retain(|j| !Arc::ptr_eq(j, &job));
    }
    if job.panicked.load(Ordering::SeqCst) {
        panic!("pissa parallel worker panicked");
    }
}

/// Raw-pointer capsule for handing each chunk its disjoint output region.
/// Soundness rests on the chunk ranges being disjoint (they are: chunks
/// partition `0..n`) and on `run_parallel` outliving every access.
struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Run `f(start, end)` over disjoint subranges of `0..n` in parallel.
/// `min_grain` is the smallest range worth a thread; below
/// `2 * min_grain` everything runs inline on the caller thread.
pub fn par_for<F>(n: usize, min_grain: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let workers = num_threads().min(n / min_grain.max(1)).max(1);
    if workers <= 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    let n_chunks = n.div_ceil(chunk);
    run_parallel(n_chunks, workers, &|ci| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(n);
        f(lo, hi);
    });
}

/// Run `f(i)` for every `i in 0..n_items` with persistent-pool
/// work-claiming at ITEM granularity: each pool worker (plus the caller,
/// which always participates — nested calls from inside a worker cannot
/// deadlock) claims one item at a time via an atomic counter, so uneven
/// per-item cost load-balances instead of stalling on the slowest
/// pre-cut chunk. This is the dispatch primitive for head×sequence
/// attention partitioning: the caller enumerates an explicit
/// `(seq, kv_group)` item list and each item writes a DISJOINT output
/// slice, so which thread runs which item can never change any
/// reduction order — results are bit-identical for every
/// `PISSA_THREADS`, provided `f` itself is deterministic per item.
///
/// Degree ≤ 1 (or a single item) runs inline in ascending item order.
pub fn par_items<F>(n_items: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = num_threads().min(n_items).max(1);
    if workers <= 1 {
        for i in 0..n_items {
            f(i);
        }
        return;
    }
    run_parallel(n_items, workers, &|i| f(i));
}

/// Parallel `(0..n).map(f)` with a deterministic result order. Each worker
/// fills a disjoint slice of the output, so no locking and no reordering:
/// the result is identical for any `PISSA_THREADS`, provided `f` itself is
/// deterministic per index (the fixed-order reduction contract the serving
/// path relies on). Below `2 * min_grain` items everything runs inline.
pub fn par_map<U, F>(n: usize, min_grain: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let workers = num_threads().min(n / min_grain.max(1)).max(1);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<U>> = std::iter::repeat_with(|| None).take(n).collect();
    let chunk = n.div_ceil(workers);
    let n_chunks = n.div_ceil(chunk);
    let ptr = SendPtr(out.as_mut_ptr());
    run_parallel(n_chunks, workers, &move |ci| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(n);
        for i in lo..hi {
            // Safety: chunks partition 0..n, so index i is written by
            // exactly one chunk; the Vec outlives run_parallel.
            unsafe {
                *ptr.0.add(i) = Some(f(i));
            }
        }
    });
    out.into_iter().map(|o| o.expect("par_map worker filled every slot")).collect()
}

/// Parallel iteration over mutable, equally-sized row chunks of a slice.
/// `rows` logical rows of width `width`; each worker gets a contiguous row
/// range `[lo, hi)` plus the matching mutable sub-slice.
pub fn par_rows_mut<T, F>(data: &mut [T], rows: usize, width: usize, min_rows: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    assert_eq!(data.len(), rows * width, "slice/rows/width mismatch");
    let workers = num_threads().min(rows / min_rows.max(1)).max(1);
    if workers <= 1 {
        f(0, rows, data);
        return;
    }
    let chunk_rows = rows.div_ceil(workers);
    let n_chunks = rows.div_ceil(chunk_rows);
    let ptr = SendPtr(data.as_mut_ptr());
    run_parallel(n_chunks, workers, &move |ci| {
        let lo = ci * chunk_rows;
        let hi = ((ci + 1) * chunk_rows).min(rows);
        // Safety: row chunks are disjoint, so the sub-slices never alias;
        // the backing slice outlives run_parallel.
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(lo * width), (hi - lo) * width)
        };
        f(lo, hi, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Mutex, MutexGuard, PoisonError};

    /// The [`with_parallelism`] override is process-global; tests that set
    /// it must not interleave or their degree assertions race. (Poison is
    /// expected: the panic-propagation test unwinds while holding this.)
    static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

    fn override_lock() -> MutexGuard<'static, ()> {
        OVERRIDE_LOCK.lock().unwrap_or_else(PoisonError::into_inner)
    }

    #[test]
    fn par_for_covers_range() {
        let total = AtomicUsize::new(0);
        par_for(1000, 10, |lo, hi| {
            total.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn par_for_small_runs_inline() {
        let total = AtomicUsize::new(0);
        par_for(3, 100, |lo, hi| {
            total.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn par_map_preserves_order() {
        let v = par_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
        // small inputs run inline and still return every element
        let w = par_map(3, 100, |i| i + 1);
        assert_eq!(w, vec![1, 2, 3]);
        let e: Vec<usize> = par_map(0, 1, |i| i);
        assert!(e.is_empty());
    }

    #[test]
    fn par_rows_mut_writes_all() {
        let rows = 64;
        let width = 16;
        let mut v = vec![0u32; rows * width];
        par_rows_mut(&mut v, rows, width, 4, |lo, _hi, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (lo * width + i) as u32;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i as u32);
        }
    }

    #[test]
    fn par_items_runs_every_item_exactly_once() {
        let _g = override_lock();
        for degree in [1, 2, 8, 32] {
            let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
            with_parallelism(degree, || {
                par_items(hits.len(), |i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "degree {degree}: item {i}");
            }
        }
        // Zero items is a no-op; one item runs inline.
        par_items(0, |_| panic!("no items to run"));
        let one = AtomicUsize::new(0);
        par_items(1, |i| {
            one.fetch_add(i + 7, Ordering::Relaxed);
        });
        assert_eq!(one.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn par_items_disjoint_writes_match_inline_for_any_degree() {
        // The attention-dispatch shape: each item owns a disjoint slice
        // of one shared output; every degree must produce the identical
        // buffer.
        let _g = override_lock();
        let items = 63;
        let width = 5;
        let want: Vec<usize> = (0..items * width).map(|i| i * 3 + 1).collect();
        for degree in [1, 3, 8] {
            let mut out = vec![0usize; items * width];
            let ptr = SendPtr(out.as_mut_ptr());
            with_parallelism(degree, || {
                par_items(items, |item| {
                    // Safety: items own disjoint [item*width, (item+1)*width).
                    let s = unsafe {
                        std::slice::from_raw_parts_mut(ptr.0.add(item * width), width)
                    };
                    for (j, v) in s.iter_mut().enumerate() {
                        *v = (item * width + j) * 3 + 1;
                    }
                });
            });
            assert_eq!(out, want, "degree {degree}");
        }
    }

    #[test]
    fn parse_threads_cases() {
        assert_eq!(parse_threads("8"), Ok(8));
        assert_eq!(parse_threads(" 4 "), Ok(4));
        // 0 means "no parallelism", clamped to one thread.
        assert_eq!(parse_threads("0"), Ok(1));
        assert!(parse_threads("abc").is_err());
        assert!(parse_threads("").is_err());
        assert!(parse_threads("-2").is_err());
        assert!(parse_threads("1.5").is_err());
        let err = parse_threads("garbage").unwrap_err();
        assert!(err.to_string().contains("garbage"));
    }

    #[test]
    fn with_parallelism_overrides_and_restores() {
        let _g = override_lock();
        let before = num_threads();
        let inside = with_parallelism(3, num_threads);
        assert_eq!(inside, 3);
        assert_eq!(num_threads(), before);
        // Degree is clamped to >= 1.
        assert_eq!(with_parallelism(0, num_threads), 1);
        // Nested overrides restore the outer one.
        with_parallelism(5, || {
            assert_eq!(num_threads(), 5);
            with_parallelism(2, || assert_eq!(num_threads(), 2));
            assert_eq!(num_threads(), 5);
        });
    }

    #[test]
    fn pool_results_match_inline_for_any_degree() {
        let _g = override_lock();
        let want: Vec<usize> = (0..512).map(|i| i * 3 + 1).collect();
        for degree in [1, 2, 8, 32] {
            let got = with_parallelism(degree, || par_map(512, 1, |i| i * 3 + 1));
            assert_eq!(got, want, "degree {degree}");
        }
    }

    #[test]
    fn pool_handles_more_chunks_than_workers_and_reuse() {
        // Repeated dispatches reuse the persistent pool; results stay
        // deterministic across calls.
        let _g = override_lock();
        for round in 0..20 {
            let v = with_parallelism(8, || par_map(100 + round, 1, |i| i + round));
            assert_eq!(v.len(), 100 + round);
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, i + round);
            }
        }
    }

    #[test]
    fn nested_parallel_calls_complete() {
        // A parallel call issued from inside a pool worker must not
        // deadlock: the submitter drains its own chunks.
        let _g = override_lock();
        let out = with_parallelism(4, || {
            par_map(8, 1, |i| {
                let inner = par_map(16, 1, |j| i * 16 + j);
                inner.iter().sum::<usize>()
            })
        });
        let want: Vec<usize> = (0..8).map(|i| (0..16).map(|j| i * 16 + j).sum()).collect();
        assert_eq!(out, want);
    }

    #[test]
    #[should_panic(expected = "pissa parallel worker panicked")]
    fn worker_panic_propagates_to_caller() {
        let _g = override_lock();
        with_parallelism(4, || {
            par_for(64, 1, |lo, _hi| {
                if lo >= 32 {
                    panic!("boom");
                }
            });
        });
    }
}
