//! Deterministic pseudo-random number generation.
//!
//! No `rand` crate is available in the offline vendor set, so we implement
//! the generators we need: SplitMix64 (seeding) and xoshiro256** (bulk
//! generation), plus the distributions used throughout the repro
//! (uniform, standard normal via Box–Muller, integer ranges, shuffles).
//! Everything is deterministic given a seed — experiments are replayable.

/// xoshiro256** by Blackman & Vigna, seeded through SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent child generator (for per-layer / per-worker
    /// streams). Uses the current stream to seed a fresh state.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Standard normal deviate (Box–Muller with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal deviate with the given mean and standard deviation, as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Uniform integer in [0, n). Uses Lemire-style rejection for
    /// unbiasedness.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fill a slice with N(0, std) samples.
    pub fn fill_normal(&mut self, xs: &mut [f32], mean: f32, std: f32) {
        for x in xs.iter_mut() {
            *x = self.normal_f32(mean, std);
        }
    }

    /// Fill a slice with U[lo, hi) samples.
    pub fn fill_uniform(&mut self, xs: &mut [f32], lo: f32, hi: f32) {
        for x in xs.iter_mut() {
            *x = self.uniform_in(lo, hi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 / 10_000.0 - 1.0).abs() < 0.08, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(5);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
