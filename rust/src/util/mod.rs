//! Foundation utilities built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, timing/bench statistics, and thread-based
//! data parallelism.

pub mod cli;
pub mod json;
pub mod par;
pub mod rng;
pub mod timer;
