//! Synthetic MetaMathQA/GSM8K-style math corpus.
//!
//! Substitution for the paper's MetaMathQA-395K training set and GSM8K /
//! MATH eval sets (see DESIGN.md §3): templated grade-school word
//! problems with 1–3 arithmetic steps, a chain-of-thought style solution,
//! and a final "The answer is N" line. Loss is computed only on the
//! response (Alpaca/QLoRA recipe). Eval is exact-match on the extracted
//! final number — the same metric GSM8K uses.

use super::tokenizer::Example;
use crate::util::rng::Rng;

const NAMES: [&str; 8] = ["Tom", "Ana", "Raj", "Mia", "Leo", "Zoe", "Sam", "Ivy"];
const ITEMS: [&str; 8] = ["apples", "books", "coins", "cards", "shells", "pens", "stamps", "marbles"];

/// Difficulty presets: number of reasoning steps and operand ranges.
#[derive(Clone, Copy, Debug)]
pub enum MathLevel {
    /// 1-step add/sub (GSM8K-easy analog).
    Easy,
    /// 2-step with multiplication (GSM8K analog).
    Std,
    /// 3-step incl. division with exact quotients (MATH analog).
    Hard,
}

/// One generated problem with its ground-truth answer.
#[derive(Clone, Debug)]
pub struct Problem {
    pub example: Example,
    pub answer: i64,
}

/// Generate a single problem.
pub fn gen_problem(level: MathLevel, rng: &mut Rng) -> Problem {
    // Templates are deliberately compact: prompt+response must fit the
    // smallest artifact's seq_len (tiny: 64 byte-tokens incl. specials).
    let name = *rng.choice(&NAMES);
    let item = *rng.choice(&ITEMS);
    match level {
        MathLevel::Easy => {
            // Small operand range: the eval split is disjoint by seed, so
            // exact-match requires generalizing over ~19² combinations —
            // learnable by the tiny reproduction-scale models, like GSM8K
            // is learnable by 7B models.
            let a = rng.range_i64(2, 20);
            let b = rng.range_i64(2, 20);
            if rng.below(2) == 0 {
                let ans = a + b;
                Problem {
                    example: Example {
                        prompt: format!("{name}: {a} {item}, +{b}. Total?"),
                        response: format!("{a}+{b}={ans}. The answer is {ans}"),
                    },
                    answer: ans,
                }
            } else {
                let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
                let ans = hi - lo;
                Problem {
                    example: Example {
                        prompt: format!("{name}: {hi} {item}, -{lo}. Left?"),
                        response: format!("{hi}-{lo}={ans}. The answer is {ans}"),
                    },
                    answer: ans,
                }
            }
        }
        MathLevel::Std => {
            let boxes = rng.range_i64(2, 9);
            let per = rng.range_i64(2, 9);
            let extra = rng.range_i64(1, 20);
            let prod = boxes * per;
            let ans = prod + extra;
            Problem {
                example: Example {
                    prompt: format!("{name}: {boxes} boxes of {per} {item}, +{extra}. Total?"),
                    response: format!("{boxes}*{per}={prod}. {prod}+{extra}={ans}. The answer is {ans}"),
                },
                answer: ans,
            }
        }
        MathLevel::Hard => {
            let per = rng.range_i64(2, 9);
            let groups = rng.range_i64(2, 9);
            let total = per * groups;
            let sold = rng.range_i64(1, per - 1);
            let keep = per - sold;
            let ans = keep * groups;
            Problem {
                example: Example {
                    prompt: format!("{name}: {total} {item} in {groups} piles, -{sold} each. Left?"),
                    response: format!("{total}/{groups}={per}. {per}-{sold}={keep}. {keep}*{groups}={ans}. The answer is {ans}"),
                },
                answer: ans,
            }
        }
    }
}

/// Worst-case token length of a problem (prompt + response + specials);
/// tested against every config's seq_len.
pub fn max_tokens(level: MathLevel) -> usize {
    match level {
        MathLevel::Easy => 60,
        MathLevel::Std => 78,
        MathLevel::Hard => 92,
    }
}

/// A deterministic dataset: `n` problems from a seed.
pub fn gen_dataset(level: MathLevel, n: usize, seed: u64) -> Vec<Problem> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| gen_problem(level, &mut rng)).collect()
}

/// Extract the final numeric answer from generated text — the GSM8K
/// protocol ("The answer is N", falling back to the last integer).
pub fn extract_answer(text: &str) -> Option<i64> {
    if let Some(idx) = text.rfind("answer is") {
        let tail = &text[idx + "answer is".len()..];
        if let Some(n) = first_int(tail) {
            return Some(n);
        }
    }
    last_int(text)
}

fn first_int(s: &str) -> Option<i64> {
    let mut cur = String::new();
    for c in s.chars() {
        if c.is_ascii_digit() || (c == '-' && cur.is_empty()) {
            cur.push(c);
        } else if !cur.is_empty() {
            break;
        }
    }
    cur.parse().ok()
}

fn last_int(s: &str) -> Option<i64> {
    let mut best: Option<i64> = None;
    let mut cur = String::new();
    for c in s.chars().chain(std::iter::once(' ')) {
        if c.is_ascii_digit() {
            cur.push(c);
        } else {
            if let Ok(n) = cur.parse() {
                best = Some(n);
            }
            cur.clear();
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problems_are_self_consistent() {
        for level in [MathLevel::Easy, MathLevel::Std, MathLevel::Hard] {
            let probs = gen_dataset(level, 200, 7);
            for p in &probs {
                // the response's stated answer must equal the ground truth
                let got = extract_answer(&p.example.response).unwrap();
                assert_eq!(got, p.answer, "{:?}", p.example);
                assert!(p.answer >= 0);
            }
        }
    }

    #[test]
    fn problems_fit_token_budget() {
        // Truncated responses produce all-zero loss masks; every level's
        // problems must fit its declared max_tokens.
        for level in [MathLevel::Easy, MathLevel::Std, MathLevel::Hard] {
            let budget = max_tokens(level);
            for p in gen_dataset(level, 500, 13) {
                let (toks, split) = p.example.tokenize();
                assert!(
                    toks.len() <= budget,
                    "{level:?} problem has {} tokens > {budget}: {:?}",
                    toks.len(),
                    p.example
                );
                assert!(split < toks.len());
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = gen_dataset(MathLevel::Std, 10, 42);
        let b = gen_dataset(MathLevel::Std, 10, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.example.prompt, y.example.prompt);
        }
        let c = gen_dataset(MathLevel::Std, 10, 43);
        assert!(a.iter().zip(&c).any(|(x, y)| x.example.prompt != y.example.prompt));
    }

    #[test]
    fn extract_answer_variants() {
        assert_eq!(extract_answer("blah The answer is 42"), Some(42));
        assert_eq!(extract_answer("3 + 4 = 7. The answer is 7"), Some(7));
        assert_eq!(extract_answer("result: 13"), Some(13));
        assert_eq!(extract_answer("no numbers here"), None);
        // prefers the "answer is" marker over the last int
        assert_eq!(extract_answer("The answer is 5. (confidence 99)"), Some(5));
    }

    #[test]
    fn hard_problems_divide_exactly() {
        for p in gen_dataset(MathLevel::Hard, 100, 3) {
            // the template guarantees exact division; re-derive from text
            assert!(p.answer >= 0);
            assert!(p.example.response.contains("/"));
        }
    }
}
