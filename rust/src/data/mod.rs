//! Data substrate: byte tokenizer, synthetic corpora standing in for the
//! paper's datasets (MetaMathQA/GSM8K, CodeFeedback/HumanEval, GLUE — see
//! DESIGN.md §3 for the substitution rationale), and the fixed-shape
//! batcher with response-only loss masks.

pub mod batcher;
pub mod codegen;
pub mod corpus;
pub mod mathqa;
pub mod nlu;
pub mod tokenizer;

pub use batcher::{batch_of, Batch, Batcher};
pub use tokenizer::Example;
