//! Synthetic "coding" task family — the CodeFeedback/HumanEval stand-in
//! (DESIGN.md §3): string/sequence transformation programs described in
//! words, answered with the transformed output. Scored by exact
//! functional match, mirroring HumanEval's pass@1-style binary scoring.

use super::tokenizer::Example;
use crate::util::rng::Rng;

/// One code-style task with its expected output.
#[derive(Clone, Debug)]
pub struct CodeTask {
    pub example: Example,
    pub expected: String,
}

const WORDS: [&str; 10] = [
    "cat", "dog", "sun", "map", "key", "box", "jar", "log", "net", "pin",
];

/// Generate a single task.
pub fn gen_task(rng: &mut Rng) -> CodeTask {
    match rng.below(5) {
        0 => {
            let w = *rng.choice(&WORDS);
            let out: String = w.chars().rev().collect();
            CodeTask {
                example: Example {
                    prompt: format!("reverse('{w}')"),
                    response: format!("-> {out}"),
                },
                expected: out,
            }
        }
        1 => {
            let w = *rng.choice(&WORDS);
            let out = w.to_uppercase();
            CodeTask {
                example: Example {
                    prompt: format!("upper('{w}')"),
                    response: format!("-> {out}"),
                },
                expected: out,
            }
        }
        2 => {
            let a = *rng.choice(&WORDS);
            let b = *rng.choice(&WORDS);
            let out = format!("{a}{b}");
            CodeTask {
                example: Example {
                    prompt: format!("concat('{a}','{b}')"),
                    response: format!("-> {out}"),
                },
                expected: out,
            }
        }
        3 => {
            let w = *rng.choice(&WORDS);
            let n = rng.range_i64(2, 3) as usize;
            let out = w.repeat(n);
            CodeTask {
                example: Example {
                    prompt: format!("repeat('{w}',{n})"),
                    response: format!("-> {out}"),
                },
                expected: out,
            }
        }
        _ => {
            let w = *rng.choice(&WORDS);
            let out = w.len().to_string();
            CodeTask {
                example: Example {
                    prompt: format!("len('{w}')"),
                    response: format!("-> {out}"),
                },
                expected: out,
            }
        }
    }
}

pub fn gen_dataset(n: usize, seed: u64) -> Vec<CodeTask> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| gen_task(&mut rng)).collect()
}

/// Extract the model's answer from generated text: the token after "->".
pub fn extract_output(text: &str) -> Option<String> {
    let idx = text.find("->")?;
    let tail = text[idx + 2..].trim();
    let out: String = tail.chars().take_while(|c| !c.is_whitespace()).collect();
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tasks_self_consistent() {
        for t in gen_dataset(100, 5) {
            assert_eq!(extract_output(&t.example.response).unwrap(), t.expected);
        }
    }

    #[test]
    fn covers_all_op_kinds() {
        let ds = gen_dataset(200, 9);
        for op in ["reverse", "upper", "concat", "repeat", "len"] {
            assert!(ds.iter().any(|t| t.example.prompt.starts_with(op)), "missing {op}");
        }
    }

    #[test]
    fn extract_handles_noise() {
        assert_eq!(extract_output("-> tac extra"), Some("tac".into()));
        assert_eq!(extract_output("no arrow"), None);
    }
}
