//! Synthetic pre-training corpus.
//!
//! PiSSA's advantage depends on base weights having a realistic decaying
//! singular spectrum — random Gaussian matrices would hide the effect
//! (flat Marchenko–Pastur spectrum). We therefore *actually pre-train*
//! the base models on this corpus (templated English + counting +
//! arithmetic patterns) using the full-FT artifact, which produces
//! weight matrices with dominant principal directions, like real LLMs.

use super::tokenizer::Example;
use crate::util::rng::Rng;

const SUBJECTS: [&str; 10] =
    ["the cat", "a dog", "the sun", "my friend", "the bird", "a child", "the team", "the river", "the clock", "a farmer"];
const VERBS: [&str; 8] = ["sees", "likes", "finds", "makes", "takes", "keeps", "moves", "holds"];
const OBJECTS: [&str; 10] =
    ["the ball", "a tree", "the road", "a stone", "the light", "a song", "the door", "a boat", "the hill", "a star"];

/// One pre-training line (prompt empty: loss over the whole text).
pub fn gen_line(rng: &mut Rng) -> Example {
    match rng.below(4) {
        0 => {
            // simple SVO sentences, chained
            let n = 1 + rng.below(3);
            let text: Vec<String> = (0..n)
                .map(|_| {
                    format!("{} {} {}", rng.choice(&SUBJECTS), rng.choice(&VERBS), rng.choice(&OBJECTS))
                })
                .collect();
            Example { prompt: String::new(), response: text.join(". ") }
        }
        1 => {
            // counting patterns
            let start = rng.range_i64(0, 20);
            let step = rng.range_i64(1, 5);
            let seq: Vec<String> = (0..6).map(|i| (start + i * step).to_string()).collect();
            Example { prompt: String::new(), response: seq.join(" ") }
        }
        2 => {
            // arithmetic facts
            let a = rng.range_i64(0, 20);
            let b = rng.range_i64(0, 20);
            Example { prompt: String::new(), response: format!("{a} + {b} = {}", a + b) }
        }
        _ => {
            // copy/echo patterns (teaches induction)
            let w = rng.choice(&OBJECTS).to_string();
            Example { prompt: String::new(), response: format!("say {w} again: {w}") }
        }
    }
}

pub fn gen_corpus(n: usize, seed: u64) -> Vec<Example> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| gen_line(&mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_generates_varied_lines() {
        let c = gen_corpus(100, 11);
        assert_eq!(c.len(), 100);
        let unique: std::collections::HashSet<&str> =
            c.iter().map(|e| e.response.as_str()).collect();
        assert!(unique.len() > 50, "too repetitive: {}", unique.len());
    }

    #[test]
    fn arithmetic_lines_correct() {
        for e in gen_corpus(500, 12) {
            if let Some((lhs, rhs)) = e.response.split_once(" = ") {
                if let Some((a, b)) = lhs.split_once(" + ") {
                    let (a, b): (i64, i64) = (a.trim().parse().unwrap(), b.trim().parse().unwrap());
                    assert_eq!(a + b, rhs.trim().parse::<i64>().unwrap());
                }
            }
        }
    }
}
