//! Synthetic GLUE-like NLU suite — 8 tasks mirroring Table 2's structure
//! (2 single-sentence classification, 5 pairwise classification, 1
//! similarity regression), each with a distinct learnable signal so the
//! adapter strategies separate measurably.

use super::tokenizer::encode;
use crate::util::rng::Rng;

/// Task descriptors matching the paper's GLUE columns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NluTask {
    /// 3-class entailment (MNLI analog).
    Mnli,
    /// binary sentiment (SST-2 analog).
    Sst2,
    /// binary paraphrase (MRPC analog).
    Mrpc,
    /// binary acceptability (CoLA analog, scored with Matthews corr).
    Cola,
    /// binary QA-entailment (QNLI analog).
    Qnli,
    /// binary question-pair (QQP analog).
    Qqp,
    /// binary entailment, small data (RTE analog).
    Rte,
    /// similarity regression in [0, 5] (STS-B analog, Pearson-scored).
    Stsb,
}

pub const ALL_TASKS: [NluTask; 8] = [
    NluTask::Mnli,
    NluTask::Sst2,
    NluTask::Mrpc,
    NluTask::Cola,
    NluTask::Qnli,
    NluTask::Qqp,
    NluTask::Rte,
    NluTask::Stsb,
];

impl NluTask {
    pub fn name(&self) -> &'static str {
        match self {
            NluTask::Mnli => "MNLI",
            NluTask::Sst2 => "SST-2",
            NluTask::Mrpc => "MRPC",
            NluTask::Cola => "CoLA",
            NluTask::Qnli => "QNLI",
            NluTask::Qqp => "QQP",
            NluTask::Rte => "RTE",
            NluTask::Stsb => "STS-B",
        }
    }
    pub fn n_classes(&self) -> usize {
        match self {
            NluTask::Mnli => 3,
            NluTask::Stsb => 1, // regression
            _ => 2,
        }
    }
    pub fn regression(&self) -> bool {
        matches!(self, NluTask::Stsb)
    }
    /// Training-set size (RTE is deliberately small, like the real task).
    pub fn train_size(&self) -> usize {
        match self {
            NluTask::Rte => 400,
            NluTask::Mnli | NluTask::Qqp => 2400,
            _ => 1200,
        }
    }
}

/// A tokenized NLU example.
#[derive(Clone, Debug)]
pub struct NluExample {
    pub tokens: Vec<i32>,
    /// class id, or scaled similarity for STS-B (stored as f32 in label_f).
    pub label: i32,
    pub label_f: f32,
}

const POS_WORDS: [&str; 6] = ["great", "happy", "bright", "calm", "fresh", "kind"];
const NEG_WORDS: [&str; 6] = ["awful", "sad", "dark", "angry", "stale", "cruel"];
const NOUNS: [&str; 8] = ["film", "day", "meal", "song", "game", "trip", "book", "talk"];

fn sentence(words: &[&str], rng: &mut Rng) -> String {
    format!("the {} was {}", *rng.choice(&NOUNS), *rng.choice(words))
}

/// Generate one example for a task. The signals are deliberately simple
/// (lexical overlap / sentiment words / length cues) — enough structure
/// for fine-tuning to matter while keeping eval deterministic.
pub fn gen_example(task: NluTask, rng: &mut Rng) -> NluExample {
    match task {
        NluTask::Sst2 => {
            let pos = rng.below(2) == 1;
            let s = sentence(if pos { &POS_WORDS } else { &NEG_WORDS }, rng);
            NluExample { tokens: encode(&s), label: pos as i32, label_f: pos as i32 as f32 }
        }
        NluTask::Cola => {
            // acceptable = subject-verb-object order; unacceptable = scrambled
            let n = *rng.choice(&NOUNS);
            let ok = rng.below(2) == 1;
            let s = if ok { format!("she read the {n} today") } else { format!("the read {n} she today") };
            NluExample { tokens: encode(&s), label: ok as i32, label_f: ok as i32 as f32 }
        }
        NluTask::Mnli => {
            let n = *rng.choice(&NOUNS);
            let label = rng.below(3) as i32; // 0=entail 1=neutral 2=contradict
            let premise = format!("everyone liked the {n}");
            let hypothesis = match label {
                0 => format!("the {n} was liked"),
                1 => format!("the {n} was long"),
                _ => format!("nobody liked the {n}"),
            };
            NluExample {
                tokens: encode(&format!("{premise} | {hypothesis}")),
                label,
                label_f: label as f32,
            }
        }
        NluTask::Mrpc | NluTask::Qqp => {
            let a = sentence(&POS_WORDS, rng);
            let same = rng.below(2) == 1;
            let b = if same { a.clone() } else { sentence(&NEG_WORDS, rng) };
            NluExample {
                tokens: encode(&format!("{a} | {b}")),
                label: same as i32,
                label_f: same as i32 as f32,
            }
        }
        NluTask::Qnli | NluTask::Rte => {
            let n = *rng.choice(&NOUNS);
            let ent = rng.below(2) == 1;
            let q = format!("was the {n} good?");
            let ctx = if ent {
                format!("the {n} was {}", *rng.choice(&POS_WORDS))
            } else {
                format!("the {} was {}", *rng.choice(&NOUNS), *rng.choice(&NEG_WORDS))
            };
            NluExample {
                tokens: encode(&format!("{q} | {ctx}")),
                label: ent as i32,
                label_f: ent as i32 as f32,
            }
        }
        NluTask::Stsb => {
            // similarity = word-overlap fraction scaled to [0,5]
            let a = sentence(&POS_WORDS, rng);
            let overlap = rng.below(3); // 0,1,2 shared clauses
            let b = match overlap {
                2 => a.clone(),
                1 => {
                    let mut parts: Vec<&str> = a.split(' ').collect();
                    let len = parts.len();
                    parts[len - 1] = "fine";
                    parts.join(" ")
                }
                _ => sentence(&NEG_WORDS, rng),
            };
            let sim = overlap as f32 * 2.5;
            NluExample {
                tokens: encode(&format!("{a} | {b}")),
                label: overlap as i32,
                label_f: sim,
            }
        }
    }
}

pub fn gen_dataset(task: NluTask, n: usize, seed: u64) -> Vec<NluExample> {
    let mut rng = Rng::new(seed ^ (task as u64) << 32);
    (0..n).map(|_| gen_example(task, &mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate() {
        for task in ALL_TASKS {
            let ds = gen_dataset(task, 50, 1);
            assert_eq!(ds.len(), 50);
            for ex in &ds {
                assert!(!ex.tokens.is_empty());
                assert!((ex.label as usize) < task.n_classes().max(3));
            }
        }
    }

    #[test]
    fn labels_balanced_roughly() {
        let ds = gen_dataset(NluTask::Sst2, 1000, 2);
        let pos = ds.iter().filter(|e| e.label == 1).count();
        assert!(pos > 350 && pos < 650, "pos={pos}");
    }

    #[test]
    fn stsb_is_regression() {
        assert!(NluTask::Stsb.regression());
        let ds = gen_dataset(NluTask::Stsb, 100, 3);
        assert!(ds.iter().any(|e| e.label_f == 5.0));
        assert!(ds.iter().all(|e| (0.0..=5.0).contains(&e.label_f)));
    }

    #[test]
    fn task_names_match_paper() {
        let names: Vec<&str> = ALL_TASKS.iter().map(|t| t.name()).collect();
        assert_eq!(names, vec!["MNLI", "SST-2", "MRPC", "CoLA", "QNLI", "QQP", "RTE", "STS-B"]);
    }
}
