//! Batching: fixed-shape [B, T] token/mask tensors for the AOT train
//! artifacts, with response-only loss masks (Alpaca/QLoRA recipe) and
//! deterministic shuffled epochs.

use super::tokenizer::{Example, PAD};
use crate::util::rng::Rng;

/// A fixed-shape training batch (row-major [B, T]).
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
    pub loss_mask: Vec<f32>,
}

/// Tokenize + pad/truncate one example into row `row` of a batch.
fn fill_row(batch: &mut Batch, row: usize, ex: &Example) {
    let (toks, split) = ex.tokenize();
    let t = batch.seq_len;
    let base = row * t;
    for i in 0..t {
        if i < toks.len() {
            batch.tokens[base + i] = toks[i];
            // Loss on response tokens only (incl. EOS). For pre-training
            // lines (empty prompt), split is right after `BOS SEP`, so
            // nearly the whole line is supervised.
            batch.loss_mask[base + i] = if i >= split { 1.0 } else { 0.0 };
        } else {
            batch.tokens[base + i] = PAD;
            batch.loss_mask[base + i] = 0.0;
        }
    }
}

/// Deterministic epoch iterator yielding fixed-shape batches. Examples
/// that exceed seq_len are truncated (kept — matches the paper's packing
/// of 100K subsets more closely than dropping).
pub struct Batcher {
    examples: Vec<Example>,
    order: Vec<usize>,
    cursor: usize,
    batch: usize,
    seq_len: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(examples: Vec<Example>, batch: usize, seq_len: usize, seed: u64) -> Batcher {
        assert!(!examples.is_empty());
        let order: Vec<usize> = (0..examples.len()).collect();
        let mut b = Batcher { examples, order, cursor: 0, batch, seq_len, rng: Rng::new(seed) };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.examples.len()
    }
    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    /// Batches per epoch (full batches only).
    pub fn batches_per_epoch(&self) -> usize {
        self.examples.len() / self.batch
    }

    /// Next batch; reshuffles at epoch boundaries (infinite stream).
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch > self.examples.len() {
            self.reshuffle();
        }
        let mut out = Batch {
            batch: self.batch,
            seq_len: self.seq_len,
            tokens: vec![PAD; self.batch * self.seq_len],
            loss_mask: vec![0.0; self.batch * self.seq_len],
        };
        for row in 0..self.batch {
            let idx = self.order[self.cursor + row];
            fill_row(&mut out, row, &self.examples[idx]);
        }
        self.cursor += self.batch;
        out
    }
}

/// Build a single fixed batch from explicit examples (eval path).
pub fn batch_of(examples: &[Example], batch: usize, seq_len: usize) -> Batch {
    let mut out = Batch {
        batch,
        seq_len,
        tokens: vec![PAD; batch * seq_len],
        loss_mask: vec![0.0; batch * seq_len],
    };
    for (row, ex) in examples.iter().take(batch).enumerate() {
        fill_row(&mut out, row, ex);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::{BOS, SEP};

    fn ex(p: &str, r: &str) -> Example {
        Example { prompt: p.into(), response: r.into() }
    }

    #[test]
    fn mask_covers_response_only() {
        let b = batch_of(&[ex("ab", "xyz")], 1, 16);
        // layout: BOS a b SEP x y z EOS PAD…
        assert_eq!(b.tokens[0], BOS);
        assert_eq!(b.tokens[3], SEP);
        assert_eq!(&b.loss_mask[0..4], &[0.0, 0.0, 0.0, 0.0]);
        assert_eq!(&b.loss_mask[4..8], &[1.0, 1.0, 1.0, 1.0]); // x y z EOS
        assert!(b.loss_mask[8..].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn truncation_keeps_shape() {
        let long = "a".repeat(100);
        let b = batch_of(&[ex(&long, &long)], 1, 32);
        assert_eq!(b.tokens.len(), 32);
        // prompt fills everything: no response tokens fit => mask all zero
        assert!(b.loss_mask.iter().all(|&m| m == 0.0));
    }

    #[test]
    fn epochs_cover_all_examples() {
        let examples: Vec<Example> = (0..10).map(|i| ex(&format!("p{i}"), "r")).collect();
        let mut b = Batcher::new(examples, 2, 16, 1);
        assert_eq!(b.batches_per_epoch(), 5);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..5 {
            let batch = b.next_batch();
            // recover the prompt digit from tokens: row starts BOS 'p' <digit>
            for row in 0..2 {
                let d = batch.tokens[row * 16 + 2];
                seen.insert(d);
            }
        }
        assert_eq!(seen.len(), 10, "epoch must cover all examples");
    }

    #[test]
    fn deterministic_given_seed() {
        let examples: Vec<Example> = (0..8).map(|i| ex(&format!("p{i}"), "r")).collect();
        let mut b1 = Batcher::new(examples.clone(), 4, 8, 7);
        let mut b2 = Batcher::new(examples, 4, 8, 7);
        assert_eq!(b1.next_batch().tokens, b2.next_batch().tokens);
    }
}
