//! Byte-level tokenizer with special tokens — vocab 320 matches the AOT
//! model configs (256 bytes + specials, padded for alignment).
//!
//! The paper fine-tunes on instruction-following data with response-only
//! loss; the specials mark the prompt/response boundary so the batcher
//! can build loss masks without re-parsing text.

/// Special token ids.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
/// Separates prompt from response ("### Response:" in Alpaca terms).
pub const SEP: i32 = 3;
/// First byte id; byte b encodes as BYTE_BASE + b.
pub const BYTE_BASE: i32 = 8;
/// Total vocabulary (must match configs.py vocab).
pub const VOCAB: usize = 320;

/// Encode a string as byte tokens (no specials).
pub fn encode(s: &str) -> Vec<i32> {
    s.bytes().map(|b| BYTE_BASE + b as i32).collect()
}

/// Decode token ids back to a string; specials and out-of-range ids are
/// dropped (lossy by design — generation may emit PAD/EOS).
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter_map(|&t| {
            let b = t - BYTE_BASE;
            if (0..256).contains(&b) {
                Some(b as u8)
            } else {
                None
            }
        })
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// One training example: prompt + response with the boundary marked.
#[derive(Clone, Debug)]
pub struct Example {
    pub prompt: String,
    pub response: String,
}

impl Example {
    /// Token sequence `BOS prompt SEP response EOS` and the index of the
    /// first response token (= loss-mask start).
    pub fn tokenize(&self) -> (Vec<i32>, usize) {
        let mut toks = vec![BOS];
        toks.extend(encode(&self.prompt));
        toks.push(SEP);
        let split = toks.len();
        toks.extend(encode(&self.response));
        toks.push(EOS);
        (toks, split)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "Q: 3 + 5 = ? A: 8";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "héllo → 世界";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn specials_dropped_on_decode() {
        let mut toks = vec![BOS, PAD];
        toks.extend(encode("x"));
        toks.push(EOS);
        assert_eq!(decode(&toks), "x");
    }

    #[test]
    fn tokenize_marks_response_start() {
        let ex = Example { prompt: "ab".into(), response: "cd".into() };
        let (toks, split) = ex.tokenize();
        assert_eq!(toks.len(), 1 + 2 + 1 + 2 + 1);
        assert_eq!(toks[0], BOS);
        assert_eq!(toks[3], SEP);
        assert_eq!(split, 4);
        assert_eq!(decode(&toks[split..]), "cd");
    }

    #[test]
    fn all_ids_in_vocab() {
        let (toks, _) = Example { prompt: "þÿ".into(), response: "!".into() }.tokenize();
        assert!(toks.iter().all(|&t| (t as usize) < VOCAB));
    }
}
