//! PJRT client wrapper: loads HLO-text artifacts and compiles them into
//! executables. One `Runtime` per process; executables are cached by
//! artifact name so repeated `load` calls are free.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Wraps the PJRT CPU client plus a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    art_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU runtime rooted at an artifacts directory.
    pub fn cpu(art_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
            art_dir: art_dir.to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.art_dir
    }

    /// Load (and cache) an executable from `<art_dir>/<file>` (HLO text).
    pub fn load(&self, name: &str, file: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(name) {
                return Ok(exe.clone());
            }
        }
        let path = self.art_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute with the given input literals; returns the flattened tuple
    /// elements (the AOT pipeline lowers with return_tuple=True).
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let mut result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let elems = result.decompose_tuple()?;
        Ok(elems)
    }

    /// Execute with borrowed literals (hot path: cached frozen parameters
    /// are passed by reference, avoiding a re-marshal per step).
    pub fn execute_refs(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let mut result = exe.execute::<&xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let elems = result.decompose_tuple()?;
        Ok(elems)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu(&art_dir()).unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn load_caches() {
        let dir = art_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not generated");
            return;
        }
        let rt = Runtime::cpu(&dir).unwrap();
        let a = rt.load("logits_tiny_r4", "logits_tiny_r4.hlo.txt").unwrap();
        let b = rt.load("logits_tiny_r4", "logits_tiny_r4.hlo.txt").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }
}
