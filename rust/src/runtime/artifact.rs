//! Artifact registry: parses `artifacts/manifest.json` (written by
//! python/compile/aot.py) into typed descriptors. The manifest's argument
//! order IS the HLO parameter order — the trainer builds its literal
//! lists from these descriptors and nothing else.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One argument or output of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
    fn from_json(j: &Json) -> Result<ArgSpec> {
        Ok(ArgSpec {
            name: j.req_str("name")?.to_string(),
            shape: j
                .req_arr("shape")?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect(),
            dtype: j.req_str("dtype")?.to_string(),
        })
    }
}

/// Descriptor of a lowered artifact (train step, logits fn, …).
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub config: String,
    pub rank: usize,
    pub full_ft: bool,
    pub regression: bool,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub frozen_names: Vec<String>,
    pub trainable_names: Vec<String>,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

impl Artifact {
    /// Number of leading data arguments (tokens/masks/labels/lr/step)
    /// before the parameter block begins.
    pub fn n_data_args(&self) -> usize {
        self.args.len()
            - self.frozen_names.len()
            - if self.kind.contains("logits") { 1 } else { 3 } * self.trainable_names.len()
    }

    /// Shape of a named argument.
    pub fn arg_shape(&self, name: &str) -> Result<&[usize]> {
        self.args
            .iter()
            .find(|a| a.name == name)
            .map(|a| a.shape.as_slice())
            .ok_or_else(|| anyhow::anyhow!("artifact {} has no arg '{name}'", self.name))
    }
}

/// Model configuration echoed into the manifest.
#[derive(Clone, Debug)]
pub struct ConfigInfo {
    pub name: String,
    pub kind: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub n_classes: usize,
    pub ranks: Vec<usize>,
}

/// The whole manifest.
#[derive(Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, Artifact>,
    pub configs: BTreeMap<String, ConfigInfo>,
}

impl Manifest {
    pub fn load(art_dir: &Path) -> Result<Manifest> {
        let path = art_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut artifacts = BTreeMap::new();
        for (name, entry) in j
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .context("manifest missing 'artifacts'")?
        {
            let args = entry
                .req_arr("args")?
                .iter()
                .map(ArgSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = entry
                .req_arr("outputs")?
                .iter()
                .map(ArgSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                Artifact {
                    name: name.clone(),
                    file: entry.req_str("file")?.to_string(),
                    kind: entry.req_str("kind")?.to_string(),
                    config: entry.req_str("config")?.to_string(),
                    rank: entry.req_usize("rank")?,
                    full_ft: entry.get("full_ft").and_then(|v| v.as_bool()).unwrap_or(false),
                    regression: entry.get("regression").and_then(|v| v.as_bool()).unwrap_or(false),
                    batch: entry.req_usize("batch")?,
                    seq_len: entry.req_usize("seq_len")?,
                    vocab: entry.req_usize("vocab")?,
                    frozen_names: entry
                        .req_arr("frozen_names")?
                        .iter()
                        .filter_map(|v| v.as_str().map(String::from))
                        .collect(),
                    trainable_names: entry
                        .req_arr("trainable_names")?
                        .iter()
                        .filter_map(|v| v.as_str().map(String::from))
                        .collect(),
                    args,
                    outputs,
                },
            );
        }

        let mut configs = BTreeMap::new();
        if let Some(cfgs) = j.get("configs").and_then(|c| c.as_obj()) {
            for (name, c) in cfgs {
                configs.insert(
                    name.clone(),
                    ConfigInfo {
                        name: name.clone(),
                        kind: c.req_str("kind")?.to_string(),
                        vocab: c.req_usize("vocab")?,
                        d_model: c.req_usize("d_model")?,
                        n_layers: c.req_usize("n_layers")?,
                        n_heads: c.req_usize("n_heads")?,
                        d_ff: c.req_usize("d_ff")?,
                        seq_len: c.req_usize("seq_len")?,
                        batch: c.req_usize("batch")?,
                        eval_batch: c.req_usize("eval_batch")?,
                        n_classes: c.req_usize("n_classes")?,
                        ranks: c
                            .req_arr("ranks")?
                            .iter()
                            .filter_map(|v| v.as_usize())
                            .collect(),
                    },
                );
            }
        }
        Ok(Manifest { artifacts, configs })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("manifest has no artifact '{name}' (have: {:?})",
                self.artifacts.keys().take(8).collect::<Vec<_>>()))
    }

    pub fn config(&self, name: &str) -> Result<&ConfigInfo> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("manifest has no config '{name}'"))
    }

    /// Conventional artifact names.
    pub fn train_name(config: &str, rank: usize, full_ft: bool) -> String {
        if full_ft {
            format!("train_{config}_full")
        } else {
            format!("train_{config}_r{rank}")
        }
    }
    pub fn logits_name(config: &str, rank: usize, full_ft: bool) -> String {
        if full_ft {
            format!("logits_{config}_full")
        } else {
            format!("logits_{config}_r{rank}")
        }
    }
    pub fn enc_train_name(config: &str, rank: usize, full_ft: bool, regression: bool) -> String {
        let tag = if full_ft { "full".to_string() } else { format!("r{rank}") };
        let suffix = if regression { "reg" } else { "cls" };
        format!("train_{config}_{tag}_{suffix}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_loads_and_is_consistent() {
        let dir = art_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not generated");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.artifacts.is_empty());
        for (name, art) in &m.artifacts {
            assert!(dir.join(&art.file).exists(), "{name}: file missing");
            assert!(!art.args.is_empty());
            if art.kind == "train" {
                // 4 data args + frozen + 3×trainable
                assert_eq!(
                    art.args.len(),
                    4 + art.frozen_names.len() + 3 * art.trainable_names.len(),
                    "{name} arg count"
                );
                assert_eq!(art.outputs[0].name, "loss");
            }
        }
        // configs echoed
        assert!(m.configs.contains_key("tiny"));
        let tiny = m.config("tiny").unwrap();
        assert_eq!(tiny.kind, "decoder");
        assert!(tiny.ranks.contains(&4));
    }

    #[test]
    fn names() {
        assert_eq!(Manifest::train_name("tiny", 4, false), "train_tiny_r4");
        assert_eq!(Manifest::train_name("tiny", 0, true), "train_tiny_full");
        assert_eq!(Manifest::enc_train_name("enc_tiny", 4, false, true), "train_enc_tiny_r4_reg");
    }
}
