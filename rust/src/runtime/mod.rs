//! L3 runtime: PJRT CPU client wrapper (compile + execute HLO-text
//! artifacts), the manifest-driven artifact registry, and literal
//! marshalling between rust tensors and XLA buffers.

pub mod artifact;
pub mod client;
pub mod exec;

pub use artifact::{ArgSpec, Artifact, ConfigInfo, Manifest};
pub use client::Runtime;
pub use exec::{
    lit_f32, lit_i32, lit_mat, lit_scalar_f32, lit_stacked, lit_vec, mat_from, scalar_f32,
    stacked_from, vec_f32, Stacked,
};
