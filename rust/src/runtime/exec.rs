//! Literal marshalling: `Mat` / vectors / scalars ⇄ `xla::Literal`.
//!
//! The AOT artifacts take flat argument lists in manifest order; these
//! helpers build those lists and unpack the tupled outputs.

use crate::linalg::Mat;
use anyhow::Result;

/// A stacked 3-D tensor [layers, rows, cols] stored as a Vec<Mat> —
/// the layout the L2 model uses for per-layer parameters.
#[derive(Clone, Debug)]
pub struct Stacked {
    pub layers: Vec<Mat>,
}

impl Stacked {
    pub fn new(layers: Vec<Mat>) -> Stacked {
        assert!(!layers.is_empty());
        let (r, c) = (layers[0].rows, layers[0].cols);
        assert!(layers.iter().all(|m| m.rows == r && m.cols == c), "ragged stack");
        Stacked { layers }
    }
    pub fn zeros(l: usize, rows: usize, cols: usize) -> Stacked {
        Stacked { layers: (0..l).map(|_| Mat::zeros(rows, cols)).collect() }
    }
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.layers.len(), self.layers[0].rows, self.layers[0].cols)
    }
    pub fn numel(&self) -> usize {
        let (l, r, c) = self.shape();
        l * r * c
    }
    /// Frobenius norm over the whole stack.
    pub fn fro(&self) -> f64 {
        self.layers.iter().map(|m| m.fro().powi(2)).sum::<f64>().sqrt()
    }
}

/// f32 tensor literal from a flat buffer + dims.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "dims {dims:?} vs len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// i32 tensor literal.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "dims {dims:?} vs len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar literals.
pub fn lit_scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// 2-D matrix literal.
pub fn lit_mat(m: &Mat) -> Result<xla::Literal> {
    lit_f32(&m.data, &[m.rows as i64, m.cols as i64])
}

/// Stacked [L, r, c] literal.
pub fn lit_stacked(s: &Stacked) -> Result<xla::Literal> {
    let (l, r, c) = s.shape();
    let mut flat = Vec::with_capacity(l * r * c);
    for m in &s.layers {
        flat.extend_from_slice(&m.data);
    }
    lit_f32(&flat, &[l as i64, r as i64, c as i64])
}

/// 1-D vector literal.
pub fn lit_vec(v: &[f32]) -> Result<xla::Literal> {
    lit_f32(v, &[v.len() as i64])
}

/// Extract a scalar f32 from a literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Extract a flat f32 vector.
pub fn vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a Mat given its expected dims.
pub fn mat_from(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let v = vec_f32(lit)?;
    anyhow::ensure!(v.len() == rows * cols, "literal has {} elems, want {rows}x{cols}", v.len());
    Ok(Mat::from_vec(rows, cols, v))
}

/// Extract a Stacked tensor given its expected dims.
pub fn stacked_from(lit: &xla::Literal, l: usize, rows: usize, cols: usize) -> Result<Stacked> {
    let v = vec_f32(lit)?;
    anyhow::ensure!(v.len() == l * rows * cols, "literal has {} elems, want {l}x{rows}x{cols}", v.len());
    let layers = (0..l)
        .map(|i| Mat::from_vec(rows, cols, v[i * rows * cols..(i + 1) * rows * cols].to_vec()))
        .collect();
    Ok(Stacked::new(layers))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn stacked_invariants() {
        let s = Stacked::zeros(3, 4, 5);
        assert_eq!(s.shape(), (3, 4, 5));
        assert_eq!(s.numel(), 60);
        assert_eq!(s.fro(), 0.0);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_stack_panics() {
        Stacked::new(vec![Mat::zeros(2, 2), Mat::zeros(3, 2)]);
    }

    #[test]
    fn literal_roundtrip_mat() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(5, 7, 0.0, 1.0, &mut rng);
        let lit = lit_mat(&m).unwrap();
        let back = mat_from(&lit, 5, 7).unwrap();
        assert_eq!(back.data, m.data);
    }

    #[test]
    fn literal_roundtrip_stacked() {
        let mut rng = Rng::new(2);
        let s = Stacked::new(vec![
            Mat::randn(3, 4, 0.0, 1.0, &mut rng),
            Mat::randn(3, 4, 0.0, 1.0, &mut rng),
        ]);
        let lit = lit_stacked(&s).unwrap();
        let back = stacked_from(&lit, 2, 3, 4).unwrap();
        for (a, b) in back.layers.iter().zip(&s.layers) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn literal_scalar() {
        let lit = lit_scalar_f32(3.5);
        assert_eq!(scalar_f32(&lit).unwrap(), 3.5);
    }

    #[test]
    fn dim_mismatch_errors() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
    }
}
