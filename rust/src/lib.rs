//! # PiSSA — Principal Singular values and Singular vectors Adaptation
//!
//! Full-system reproduction of *"PiSSA: Principal Singular Values and
//! Singular Vectors Adaptation of Large Language Models"* (Meng, Wang,
//! Zhang — NeurIPS 2024) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the fine-tuning coordinator: the declarative
//!   adapter API ([`adapter::AdapterSpec`] + [`adapter::AdapterEngine`]),
//!   adapter initialization (PiSSA/LoRA/QLoRA/QPiSSA/LoftQ), the
//!   Appendix-C PiSSA→LoRA conversion, `PISSACKP` checkpoints, NF4
//!   quantization, dense linear algebra (GEMM/QR/SVD/randomized SVD), the
//!   synthetic data pipeline, the PJRT runtime that executes AOT-compiled
//!   train/eval steps, and the experiment harnesses that regenerate every
//!   table and figure of the paper.
//! * **L2 (python/compile/model.py)** — the JAX transformer with
//!   adapter-form linears, lowered once to HLO text by `make artifacts`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   adapter matmul, NF4 quant/dequant, and the randomized-SVD range
//!   finder, verified against pure-jnp oracles.
//!
//! Python never runs at training/serving time: the rust binary loads the
//! HLO artifacts through the PJRT C API (`xla` crate) and owns the loop.
//!
//! ## Adapter API in one minute
//!
//! A single declarative config (mirroring peft's
//! `LoraConfig(init_lora_weights="pissa_niter_4", target_modules=...)`)
//! describes HOW an adapter is made; the engine owns one frozen base and
//! a registry of named adapters built from such specs — hot-swap,
//! merge/unmerge, and Appendix-C export are registry operations, each
//! guarded by the paper's `base + A·B == W` exactness invariant:
//!
//! ```
//! use pissa::adapter::{AdapterEngine, AdapterSpec};
//! use pissa::model::BaseModel;
//! use pissa::runtime::ConfigInfo;
//! use pissa::util::rng::Rng;
//!
//! let cfg = ConfigInfo {
//!     name: "demo".into(), kind: "decoder".into(), vocab: 64, d_model: 16,
//!     n_layers: 1, n_heads: 2, d_ff: 32, seq_len: 16, batch: 2,
//!     eval_batch: 2, n_classes: 0, ranks: vec![2],
//! };
//! let mut rng = Rng::new(0);
//! let base = BaseModel::random(&cfg, &mut rng);
//!
//! let mut engine = AdapterEngine::new(base);
//! engine.attach("math", AdapterSpec::pissa(2).niter(4).targets(&["q", "v"]), &mut rng).unwrap();
//! engine.attach("chat", AdapterSpec::lora(2), &mut rng).unwrap();
//! let w = engine.effective_weight("q", 0).unwrap(); // == original W to 1e-5
//! assert_eq!((w.rows, w.cols), (16, 16));
//! engine.swap("chat").unwrap();                     // O(1) hot-swap
//! engine.merge("chat").unwrap();                    // deployment path (§3)
//! engine.unmerge("chat").unwrap();                  // factors restored exactly
//! ```
//!
//! For artifact-driven training, [`coordinator::RunConfig`] carries the
//! same spec (`RunConfig::quick("tiny", AdapterSpec::pissa(4))`), and
//! specs round-trip through a compact CLI string form
//! (`pissa:rank=8:niter=4:targets=q@16,v`) as well as the v2 `PISSACKP`
//! checkpoint container.
//!
//! At request time, the [`serve`] module turns an engine full of adapters
//! into a batched multi-tenant server: requests carry an adapter name,
//! batches are bucketed per adapter, and the fused forward runs one
//! shared dense `X·W` plus two skinny GEMMs per adapter group — `ΔW` is
//! never materialized (`pissa serve` drives a synthetic mixed-adapter
//! workload; `benches/serve_throughput.rs` measures it against the
//! merge-per-request and dense-per-adapter baselines). Quantized
//! (QPiSSA/QLoRA/LoftQ) adapters serve through the `fused-quant`
//! strategy: the shared base stays resident as blockwise NF4 and is
//! streamed through [`linalg::dequant_matmul`] — `pissa serve
//! --quantized` end-to-end, `benches/quant_serve.rs` for the
//! bytes/latency trade. The same per-linear units stack into the
//! whole-model pipeline [`serve::ModelServer`]: token-id requests run
//! embed → every layer's seven adapted linears → head logits in one
//! call, with residency/stats aggregated across the stack (`pissa serve
//! --full-model`, `benches/model_serve.rs`). The [`net`] module puts
//! the decode path on the wire: a dependency-free threaded HTTP/1.1
//! front-end over the continuous-batching scheduler, with chunked token
//! streaming, per-tenant admission control, `/healthz` + `/metrics`,
//! and graceful drain (`pissa serve --http`, `benches/http_serve.rs`).

pub mod adapter;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod net;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
