//! # PiSSA — Principal Singular values and Singular vectors Adaptation
//!
//! Full-system reproduction of *"PiSSA: Principal Singular Values and
//! Singular Vectors Adaptation of Large Language Models"* (Meng, Wang,
//! Zhang — NeurIPS 2024) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the fine-tuning coordinator: adapter lifecycle
//!   (PiSSA/LoRA/QPiSSA/LoftQ init, conversion, checkpoints), NF4
//!   quantization, dense linear algebra (GEMM/QR/SVD/randomized SVD), the
//!   synthetic data pipeline, the PJRT runtime that executes AOT-compiled
//!   train/eval steps, and the experiment harnesses that regenerate every
//!   table and figure of the paper.
//! * **L2 (python/compile/model.py)** — the JAX transformer with
//!   adapter-form linears, lowered once to HLO text by `make artifacts`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   adapter matmul, NF4 quant/dequant, and the randomized-SVD range
//!   finder, verified against pure-jnp oracles.
//!
//! Python never runs at training/serving time: the rust binary loads the
//! HLO artifacts through the PJRT C API (`xla` crate) and owns the loop.

pub mod adapter;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
