//! Metric sinks: per-step training records, CSV/JSONL writers, and the
//! curve summaries used by the figure benches.

use crate::util::json::{jnum, Json};
use std::io::Write;
use std::path::Path;

/// One training-step record (the paper's Figure 4/5 series: loss, grad
/// norm, plus lr and timing for §Perf).
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub step: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub lr: f32,
    pub step_time_s: f64,
}

impl StepMetrics {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("step", jnum(self.step as f64));
        o.set("loss", jnum(self.loss as f64));
        o.set("grad_norm", jnum(self.grad_norm as f64));
        o.set("lr", jnum(self.lr as f64));
        o.set("step_time_s", jnum(self.step_time_s));
        o
    }
}

/// Append-mode JSONL writer for run logs.
pub struct JsonlSink {
    file: std::fs::File,
}

impl JsonlSink {
    pub fn create(path: &Path) -> anyhow::Result<JsonlSink> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(JsonlSink { file: std::fs::File::create(path)? })
    }
    pub fn write(&mut self, j: &Json) -> anyhow::Result<()> {
        writeln!(self.file, "{}", j.to_string())?;
        Ok(())
    }
    pub fn write_step(&mut self, m: &StepMetrics) -> anyhow::Result<()> {
        self.write(&m.to_json())
    }
}

/// Write one JSON document to a file (the serving runtime exports its
/// [`crate::serve::ServeStats`] snapshot through this).
///
/// Atomic: the document lands in a unique temp file in the target
/// directory and is `rename(2)`d into place, so a concurrent reader (an
/// HTTP `/metrics` scrape, a bench harness tailing results/) observes
/// either the old snapshot or the new one — never a torn half-write.
pub fn write_json(path: &Path, j: &Json) -> anyhow::Result<()> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("out.json");
    let tmp = path.with_file_name(format!(
        ".{name}.{}.{}.tmp",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::write(&tmp, format!("{j}\n"))?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    Ok(())
}

/// Write a simple CSV (header + f64 rows) — the bench harnesses emit the
/// paper's table rows through this.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let line: Vec<String> = row.iter().map(|x| format!("{x}")).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

/// Write a labeled CSV where the first column is a string label.
pub fn write_labeled_csv(
    path: &Path,
    header: &[&str],
    rows: &[(String, Vec<f64>)],
) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for (label, vals) in rows {
        let mut line = vec![label.clone()];
        line.extend(vals.iter().map(|x| format!("{x}")));
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

/// Pearson correlation (STS-B metric).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Matthews correlation coefficient (CoLA metric), binary.
pub fn matthews(preds: &[i32], labels: &[i32]) -> f64 {
    let (mut tp, mut tn, mut fp, mut fne) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &l) in preds.iter().zip(labels) {
        match (p, l) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fne += 1.0,
            _ => {}
        }
    }
    let denom = ((tp + fp) * (tp + fne) * (tn + fp) * (tn + fne)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fne) / denom
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect_and_anti() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y = vec![2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z: Vec<f64> = y.iter().map(|v| -v).collect();
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn matthews_perfect() {
        let p = vec![1, 0, 1, 0];
        assert!((matthews(&p, &p) - 1.0).abs() < 1e-12);
        let inv: Vec<i32> = p.iter().map(|v| 1 - v).collect();
        assert!((matthews(&p, &inv) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn write_json_roundtrips_through_parse() {
        let dir = std::env::temp_dir().join("pissa_write_json_test");
        let path = dir.join("stats.json");
        let mut o = Json::obj();
        o.set("req_per_s", jnum(123.5));
        write_json(&path, &o).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("req_per_s").and_then(|v| v.as_f64()), Some(123.5));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_json_is_atomic_replace_with_no_temp_residue() {
        let dir = std::env::temp_dir().join("pissa_write_json_atomic_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("snap.json");
        let mut a = Json::obj();
        a.set("v", jnum(1.0));
        write_json(&path, &a).unwrap();
        let mut b = Json::obj();
        b.set("v", jnum(2.0));
        // Overwrite via rename; the old content is fully replaced.
        write_json(&path, &b).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("v").and_then(|v| v.as_f64()), Some(2.0));
        // Exactly one entry in the directory: no .tmp files left behind.
        let entries: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(entries.len(), 1, "temp residue: {entries:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("pissa_csv_test");
        let path = dir.join("t.csv");
        write_csv(&path, &["a", "b"], &[vec![1.0, 2.0], vec![3.5, -1.0]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n1,2\n3.5,-1"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_sink_writes_lines() {
        let dir = std::env::temp_dir().join("pissa_jsonl_test");
        let path = dir.join("log.jsonl");
        let mut sink = JsonlSink::create(&path).unwrap();
        let m = StepMetrics { step: 1, loss: 2.0, grad_norm: 0.5, lr: 1e-3, step_time_s: 0.1 };
        sink.write_step(&m).unwrap();
        sink.write_step(&m).unwrap();
        drop(sink);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("\"loss\""));
        std::fs::remove_dir_all(&dir).ok();
    }
}
