//! NLU evaluation: run the encoder logits artifact over an eval set and
//! compute the per-task GLUE metric (accuracy, Matthews for CoLA,
//! Pearson for STS-B) — Table 2's columns.

use crate::data::nlu::{NluExample, NluTask};
use crate::data::tokenizer::PAD;
use crate::metrics::{matthews, pearson};
use crate::model::params::to_literals;
use crate::model::TrainState;
use crate::runtime::{lit_f32, lit_i32, vec_f32, Artifact, Manifest, Runtime};
use anyhow::Result;
use std::sync::Arc;

/// Encoder scoring session.
pub struct NluScorer<'rt> {
    rt: &'rt Runtime,
    exe: Arc<xla::PjRtLoadedExecutable>,
    art: Artifact,
    param_lits: Vec<xla::Literal>,
    n_classes: usize,
}

impl<'rt> NluScorer<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        artifact_name: &str,
        state: &TrainState,
        n_classes: usize,
    ) -> Result<NluScorer<'rt>> {
        let art = manifest.get(artifact_name)?.clone();
        anyhow::ensure!(art.kind == "encoder_logits", "'{artifact_name}' is not an encoder logits fn");
        let exe = rt.load(artifact_name, &art.file)?;
        let mut param_lits = to_literals(&state.frozen, &art.frozen_names)?;
        param_lits.extend(to_literals(&state.trainable, &art.trainable_names)?);
        Ok(NluScorer { rt, exe, art, param_lits, n_classes })
    }

    /// Class logits for a [B, T] batch.
    pub fn logits(&self, tokens: &[i32], attn_mask: &[f32]) -> Result<Vec<f32>> {
        let b = self.art.batch as i64;
        let t = self.art.seq_len as i64;
        let tok = lit_i32(tokens, &[b, t])?;
        let am = lit_f32(attn_mask, &[b, t])?;
        let mut inputs: Vec<&xla::Literal> = vec![&tok, &am];
        inputs.extend(self.param_lits.iter());
        let outs = self.rt.execute_refs(&self.exe, &inputs)?;
        vec_f32(&outs[0])
    }

    /// Pack NLU examples into fixed-shape batches (pad rows repeat the
    /// last example; they are sliced off the predictions).
    pub fn predict(&self, examples: &[NluExample]) -> Result<(Vec<i32>, Vec<f64>)> {
        let b = self.art.batch;
        let t = self.art.seq_len;
        let nc = self.n_classes;
        let mut preds = Vec::with_capacity(examples.len());
        let mut scores = Vec::with_capacity(examples.len());
        for chunk in examples.chunks(b) {
            let mut tokens = vec![PAD; b * t];
            let mut amask = vec![0.0f32; b * t];
            for (row, ex) in chunk.iter().enumerate() {
                let n = ex.tokens.len().min(t);
                tokens[row * t..row * t + n].copy_from_slice(&ex.tokens[..n]);
                for i in 0..n {
                    amask[row * t + i] = 1.0;
                }
            }
            let logits = self.logits(&tokens, &amask)?;
            let out_c = self.art.outputs[0].shape[1];
            for (row, _) in chunk.iter().enumerate() {
                let slice = &logits[row * out_c..row * out_c + nc.max(1)];
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for (i, &x) in slice.iter().enumerate() {
                    if x > best_v {
                        best_v = x;
                        best = i;
                    }
                }
                preds.push(best as i32);
                scores.push(slice[0] as f64); // regression head = index 0
            }
        }
        Ok((preds, scores))
    }
}

/// Score predictions with the task's GLUE metric, in percent.
pub fn score(task: NluTask, preds: &[i32], scores: &[f64], examples: &[NluExample]) -> f64 {
    if task.regression() {
        let labels: Vec<f64> = examples.iter().map(|e| e.label_f as f64).collect();
        return pearson(scores, &labels) * 100.0;
    }
    if task == NluTask::Cola {
        let labels: Vec<i32> = examples.iter().map(|e| e.label).collect();
        return matthews(preds, &labels) * 100.0;
    }
    let correct = preds
        .iter()
        .zip(examples)
        .filter(|(p, e)| **p == e.label)
        .count();
    correct as f64 / examples.len() as f64 * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::nlu;

    #[test]
    fn score_accuracy_path() {
        let ds = nlu::gen_dataset(NluTask::Sst2, 20, 1);
        let preds: Vec<i32> = ds.iter().map(|e| e.label).collect();
        let scores = vec![0.0; 20];
        assert_eq!(score(NluTask::Sst2, &preds, &scores, &ds), 100.0);
    }

    #[test]
    fn score_pearson_path() {
        let ds = nlu::gen_dataset(NluTask::Stsb, 30, 2);
        let scores: Vec<f64> = ds.iter().map(|e| e.label_f as f64).collect();
        let preds = vec![0; 30];
        assert!((score(NluTask::Stsb, &preds, &scores, &ds) - 100.0).abs() < 1e-9);
    }
}
