//! Greedy decoding, plus scored evaluation on the synthetic
//! GSM8K/HumanEval-analog suites.
//!
//! Two decoding backends share one prompt/stop/extraction protocol
//! (`BOS prompt SEP …generation… EOS`, greedy first-max sampling):
//!
//! * [`Generator`] — the artifact path: a fixed-shape `[B, T]` logits
//!   executable. The artifact recomputes every position per call (its
//!   interface is the whole-sequence forward), so each emitted token
//!   costs a full forward — O(T²) per sequence, inherent to the frozen
//!   HLO shape and acceptable only because those models are tiny.
//! * [`ServeGenerator`] — the serving path: the same greedy protocol
//!   routed through `ModelServer::prefill`/`decode_step` over a
//!   slot-paged KV cache via the continuous-batching `DecodeScheduler`.
//!   Each emitted token costs ONE single-position forward over the
//!   cached keys/values — O(T) per sequence — and the incremental
//!   trajectory is bit-identical to recomputing every prefix from
//!   scratch (`rust/tests/serve_equiv.rs` locks the equivalence on a
//!   fixture prompt set).

use crate::adapter::AdapterEngine;
use crate::data::codegen::{extract_output, CodeTask};
use crate::data::mathqa::{extract_answer, Problem};
use crate::data::tokenizer::{decode, encode, BOS, EOS, PAD, SEP};
use crate::model::params::to_literals;
use crate::model::TrainState;
use crate::runtime::{lit_i32, vec_f32, Artifact, Manifest, Runtime};
use crate::serve::{argmax, DecodeScheduler, KvCache, ModelServer, SeqRequest, ServeConfig};
use anyhow::Result;
use std::sync::Arc;

/// Lay a prompt out for generation: `BOS prompt SEP`, with the prompt
/// (not the SEP) truncated so the layout leaves at least one position to
/// generate within `seq_len` — an over-long prompt loses its tail, never
/// its prompt/response separator. Shared by both decoding backends so
/// their protocols cannot drift.
pub fn layout_prompt(prompt: &str, seq_len: usize) -> Vec<i32> {
    let mut toks = vec![BOS];
    toks.extend(encode(prompt));
    toks.truncate(seq_len.saturating_sub(2)); // room for SEP + >=1 generated
    toks.push(SEP);
    toks
}

/// Extract the response from a generated row: everything after the first
/// SEP, detokenized (specials dropped). A row with no SEP has no
/// response (`layout_prompt` guarantees one is always present, so this
/// only triggers on foreign token streams — better empty than echoing
/// the prompt back as the "answer"). Shared by both backends.
pub fn extract_response(tokens: &[i32]) -> String {
    match tokens.iter().position(|&x| x == SEP) {
        Some(sep_pos) => decode(&tokens[sep_pos + 1..]),
        None => String::new(),
    }
}

/// A generation session bound to a logits artifact.
pub struct Generator<'rt> {
    rt: &'rt Runtime,
    exe: Arc<xla::PjRtLoadedExecutable>,
    art: Artifact,
    param_lits: Vec<xla::Literal>,
}

impl<'rt> Generator<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        artifact_name: &str,
        state: &TrainState,
    ) -> Result<Generator<'rt>> {
        let art = manifest.get(artifact_name)?.clone();
        anyhow::ensure!(art.kind == "logits", "artifact '{artifact_name}' is not a logits fn");
        let exe = rt.load(artifact_name, &art.file)?;
        // logits artifacts take frozen then trainable params after tokens.
        let mut param_lits = to_literals(&state.frozen, &art.frozen_names)?;
        param_lits.extend(to_literals(&state.trainable, &art.trainable_names)?);
        Ok(Generator { rt, exe, art, param_lits })
    }

    pub fn batch(&self) -> usize {
        self.art.batch
    }
    pub fn seq_len(&self) -> usize {
        self.art.seq_len
    }

    /// One forward pass: tokens [B, T] -> logits [B, T, V] (flat).
    pub fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let b = self.art.batch as i64;
        let t = self.art.seq_len as i64;
        let tok_lit = lit_i32(tokens, &[b, t])?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(1 + self.param_lits.len());
        inputs.push(&tok_lit);
        inputs.extend(self.param_lits.iter());
        let outs = self.rt.execute_refs(&self.exe, &inputs)?;
        vec_f32(&outs[0])
    }

    /// Greedy-decode continuations for a batch of prompts. Each prompt is
    /// laid out as `BOS prompt SEP`; generation continues until EOS or the
    /// sequence fills. Returns the decoded response strings.
    pub fn generate(&self, prompts: &[String], max_new: usize) -> Result<Vec<String>> {
        let bsz = self.art.batch;
        let t = self.art.seq_len;
        let v = self.art.vocab;
        anyhow::ensure!(prompts.len() <= bsz, "{} prompts > batch {bsz}", prompts.len());

        let mut tokens = vec![PAD; bsz * t];
        let mut lens = vec![0usize; bsz];
        for (row, p) in prompts.iter().enumerate() {
            let toks = layout_prompt(p, t);
            lens[row] = toks.len();
            tokens[row * t..row * t + toks.len()].copy_from_slice(&toks);
        }
        let mut done = vec![false; bsz];
        for row in prompts.len()..bsz {
            done[row] = true; // unused rows
        }

        for _ in 0..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let logits = self.logits(&tokens)?;
            for row in 0..prompts.len() {
                if done[row] || lens[row] >= t {
                    done[row] = true;
                    continue;
                }
                // logits for the last real position predict the next token
                let pos = lens[row] - 1;
                let off = (row * t + pos) * v;
                let tok = argmax(&logits[off..off + v]) as i32;
                tokens[row * t + lens[row]] = tok;
                lens[row] += 1;
                if tok == EOS {
                    done[row] = true;
                }
            }
        }

        let mut out = Vec::with_capacity(prompts.len());
        for (row, _) in prompts.iter().enumerate() {
            out.push(extract_response(&tokens[row * t..row * t + lens[row]]));
        }
        Ok(out)
    }
}

/// KV-cached greedy generation over a [`ModelServer`] snapshot — the
/// serving-stack backend of the shared decode protocol. One prefill per
/// prompt, then one cached single-position decode step per emitted token
/// (continuous batching across the prompt set), instead of recomputing
/// the full sequence per token.
pub struct ServeGenerator {
    server: ModelServer,
    cache: KvCache,
    adapter: Option<String>,
}

impl ServeGenerator {
    /// Snapshot `engine` for generation under `adapter` (`None` = the
    /// frozen base). `cfg` must be a full-model config; its decode knobs
    /// (`max_seq`, `slots`, `kv_budget_bytes`) size the KV cache, and
    /// its attention geometry (`heads`, `rope_theta`, `prefill_chunk`)
    /// flows through unchanged — trajectories are bit-identical for any
    /// `prefill_chunk`, so chunking is safe to leave on for eval runs.
    pub fn new(engine: &AdapterEngine, cfg: ServeConfig, adapter: Option<&str>) -> Result<ServeGenerator> {
        let server = ModelServer::new(engine, cfg)?;
        let cache = server.new_cache()?;
        if let Some(name) = adapter {
            anyhow::ensure!(
                server.adapter_names().contains(&name),
                "ServeGenerator: engine has no adapter '{name}'"
            );
        }
        Ok(ServeGenerator { server, cache, adapter: adapter.map(|s| s.to_string()) })
    }

    /// Longest sequence (prompt + generated) the cache admits.
    pub fn max_seq(&self) -> usize {
        self.cache.max_seq()
    }

    pub fn server(&self) -> &ModelServer {
        &self.server
    }

    /// Greedy-decode continuations for a batch of prompts: the same
    /// `BOS prompt SEP … EOS` protocol as [`Generator::generate`], with
    /// `max_new` clamped so every sequence fits `max_seq`. Results come
    /// back in prompt order.
    pub fn generate(&mut self, prompts: &[String], max_new: usize) -> Result<Vec<String>> {
        let mut sched = DecodeScheduler::new();
        for p in prompts {
            let toks = layout_prompt(p, self.cache.max_seq());
            let budget = max_new.min(self.cache.max_seq() - toks.len());
            let prompt: Vec<usize> = toks.iter().map(|&t| t as usize).collect();
            let req = SeqRequest {
                adapter: self.adapter.clone(),
                prompt,
                max_new: budget,
                stop_token: Some(EOS as usize),
            };
            sched.submit(req);
        }
        let finished = sched.run_sorted(&mut self.server, &mut self.cache)?;
        Ok(finished
            .iter()
            .map(|f| {
                let toks: Vec<i32> = f.tokens.iter().map(|&t| t as i32).collect();
                extract_response(&toks)
            })
            .collect())
    }
}

/// Exact-match accuracy on math problems (GSM8K protocol).
pub fn eval_math(gen: &Generator, problems: &[Problem], max_new: usize) -> Result<f64> {
    let bsz = gen.batch();
    let mut correct = 0usize;
    for chunk in problems.chunks(bsz) {
        let prompts: Vec<String> = chunk.iter().map(|p| p.example.prompt.clone()).collect();
        let outs = gen.generate(&prompts, max_new)?;
        for (p, o) in chunk.iter().zip(&outs) {
            if extract_answer(o) == Some(p.answer) {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / problems.len() as f64 * 100.0)
}

/// Exact functional match on code tasks (HumanEval-analog).
pub fn eval_code(gen: &Generator, tasks: &[CodeTask], max_new: usize) -> Result<f64> {
    let bsz = gen.batch();
    let mut correct = 0usize;
    for chunk in tasks.chunks(bsz) {
        let prompts: Vec<String> = chunk.iter().map(|t| t.example.prompt.clone()).collect();
        let outs = gen.generate(&prompts, max_new)?;
        for (task, o) in chunk.iter().zip(&outs) {
            if extract_output(o).as_deref() == Some(task.expected.as_str()) {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / tasks.len() as f64 * 100.0)
}
