//! Greedy decoding over the logits artifact, plus scored evaluation on
//! the synthetic GSM8K/HumanEval-analog suites.
//!
//! Decoding recomputes the full forward per emitted token (no KV cache —
//! the artifacts are fixed-shape [B, T] and the models are tiny; the
//! O(T²) cost is measured in §Perf and irrelevant at this scale).

use crate::data::mathqa::{extract_answer, Problem};
use crate::data::codegen::{extract_output, CodeTask};
use crate::data::tokenizer::{decode, BOS, EOS, PAD, SEP};
use crate::data::tokenizer::encode;
use crate::model::params::to_literals;
use crate::model::TrainState;
use crate::runtime::{lit_i32, vec_f32, Artifact, Manifest, Runtime};
use anyhow::Result;
use std::sync::Arc;

/// A generation session bound to a logits artifact.
pub struct Generator<'rt> {
    rt: &'rt Runtime,
    exe: Arc<xla::PjRtLoadedExecutable>,
    art: Artifact,
    param_lits: Vec<xla::Literal>,
}

impl<'rt> Generator<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        artifact_name: &str,
        state: &TrainState,
    ) -> Result<Generator<'rt>> {
        let art = manifest.get(artifact_name)?.clone();
        anyhow::ensure!(art.kind == "logits", "artifact '{artifact_name}' is not a logits fn");
        let exe = rt.load(artifact_name, &art.file)?;
        // logits artifacts take frozen then trainable params after tokens.
        let mut param_lits = to_literals(&state.frozen, &art.frozen_names)?;
        param_lits.extend(to_literals(&state.trainable, &art.trainable_names)?);
        Ok(Generator { rt, exe, art, param_lits })
    }

    pub fn batch(&self) -> usize {
        self.art.batch
    }
    pub fn seq_len(&self) -> usize {
        self.art.seq_len
    }

    /// One forward pass: tokens [B, T] -> logits [B, T, V] (flat).
    pub fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let b = self.art.batch as i64;
        let t = self.art.seq_len as i64;
        let tok_lit = lit_i32(tokens, &[b, t])?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(1 + self.param_lits.len());
        inputs.push(&tok_lit);
        inputs.extend(self.param_lits.iter());
        let outs = self.rt.execute_refs(&self.exe, &inputs)?;
        vec_f32(&outs[0])
    }

    /// Greedy-decode continuations for a batch of prompts. Each prompt is
    /// laid out as `BOS prompt SEP`; generation continues until EOS or the
    /// sequence fills. Returns the decoded response strings.
    pub fn generate(&self, prompts: &[String], max_new: usize) -> Result<Vec<String>> {
        let bsz = self.art.batch;
        let t = self.art.seq_len;
        let v = self.art.vocab;
        anyhow::ensure!(prompts.len() <= bsz, "{} prompts > batch {bsz}", prompts.len());

        let mut tokens = vec![PAD; bsz * t];
        let mut lens = vec![0usize; bsz];
        for (row, p) in prompts.iter().enumerate() {
            let mut toks = vec![BOS];
            toks.extend(encode(p));
            toks.push(SEP);
            toks.truncate(t - 1); // leave room to generate
            lens[row] = toks.len();
            tokens[row * t..row * t + toks.len()].copy_from_slice(&toks);
        }
        let mut done = vec![false; bsz];
        for row in prompts.len()..bsz {
            done[row] = true; // unused rows
        }

        for _ in 0..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let logits = self.logits(&tokens)?;
            for row in 0..prompts.len() {
                if done[row] || lens[row] >= t {
                    done[row] = true;
                    continue;
                }
                // logits for the last real position predict the next token
                let pos = lens[row] - 1;
                let off = (row * t + pos) * v;
                let slice = &logits[off..off + v];
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for (i, &x) in slice.iter().enumerate() {
                    if x > best_v {
                        best_v = x;
                        best = i;
                    }
                }
                let tok = best as i32;
                tokens[row * t + lens[row]] = tok;
                lens[row] += 1;
                if tok == EOS {
                    done[row] = true;
                }
            }
        }

        let mut out = Vec::with_capacity(prompts.len());
        for (row, _) in prompts.iter().enumerate() {
            // response = tokens after the SEP
            let row_toks = &tokens[row * t..row * t + lens[row]];
            let sep_pos = row_toks.iter().position(|&x| x == SEP).unwrap_or(0);
            out.push(decode(&row_toks[sep_pos + 1..]));
        }
        Ok(out)
    }
}

/// Exact-match accuracy on math problems (GSM8K protocol).
pub fn eval_math(gen: &Generator, problems: &[Problem], max_new: usize) -> Result<f64> {
    let bsz = gen.batch();
    let mut correct = 0usize;
    for chunk in problems.chunks(bsz) {
        let prompts: Vec<String> = chunk.iter().map(|p| p.example.prompt.clone()).collect();
        let outs = gen.generate(&prompts, max_new)?;
        for (p, o) in chunk.iter().zip(&outs) {
            if extract_answer(o) == Some(p.answer) {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / problems.len() as f64 * 100.0)
}

/// Exact functional match on code tasks (HumanEval-analog).
pub fn eval_code(gen: &Generator, tasks: &[CodeTask], max_new: usize) -> Result<f64> {
    let bsz = gen.batch();
    let mut correct = 0usize;
    for chunk in tasks.chunks(bsz) {
        let prompts: Vec<String> = chunk.iter().map(|t| t.example.prompt.clone()).collect();
        let outs = gen.generate(&prompts, max_new)?;
        for (task, o) in chunk.iter().zip(&outs) {
            if extract_output(o).as_deref() == Some(task.expected.as_str()) {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / tasks.len() as f64 * 100.0)
}
