//! Evaluation: greedy decoding over logits artifacts, GSM8K-style
//! exact-match math scoring, HumanEval-style code scoring, and the GLUE
//! metric suite for the NLU encoder.

pub mod generate;
pub mod nlu_eval;

pub use generate::{eval_code, eval_math, Generator};
pub use nlu_eval::{score, NluScorer};
