//! Evaluation: greedy decoding (artifact-backed, and KV-cached through
//! the serving stack), GSM8K-style exact-match math scoring,
//! HumanEval-style code scoring, and the GLUE metric suite for the NLU
//! encoder.

pub mod generate;
pub mod nlu_eval;

pub use generate::{
    eval_code, eval_math, extract_response, layout_prompt, Generator, ServeGenerator,
};
pub use nlu_eval::{score, NluScorer};
