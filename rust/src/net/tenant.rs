//! Per-tenant admission control: token-bucket rate limiting plus an
//! in-flight request cap.
//!
//! A tenant is an adapter name (base-model traffic files under
//! [`crate::serve::BASE_KEY`]). Each tenant owns a classic token bucket
//! — `rate_per_s` refill, `burst` capacity — and an `max_inflight`
//! ceiling on concurrently admitted requests. Admission is checked at
//! the HTTP layer BEFORE a request reaches the engine thread, so a
//! rate-limited tenant costs one map lookup, not a scheduler round-trip.
//!
//! Time is passed in explicitly (seconds from the server's boot
//! [`crate::util::timer::Timer`]) instead of read from a clock, which
//! keeps the arithmetic testable with synthetic timestamps.

use crate::serve::BASE_KEY;
use crate::util::json::{jnum, Json};
use std::collections::BTreeMap;

/// Rate/concurrency policy for one tenant (or the default for all).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TenantPolicy {
    /// Sustained admissions per second (token-bucket refill rate).
    pub rate_per_s: f64,
    /// Bucket capacity: how many admissions may burst back-to-back.
    pub burst: f64,
    /// Max concurrently admitted (submitted, not yet finished) requests.
    pub max_inflight: usize,
}

impl Default for TenantPolicy {
    fn default() -> Self {
        TenantPolicy { rate_per_s: 64.0, burst: 128.0, max_inflight: 64 }
    }
}

/// Admission verdict for one request.
#[derive(Clone, Debug, PartialEq)]
pub enum Admission {
    Granted,
    /// Token bucket empty → HTTP 429 with a `Retry-After` hint (seconds
    /// until one token has refilled).
    RateLimited { retry_after_s: f64 },
    /// Too many requests already in flight → HTTP 503.
    Saturated { inflight: usize, max_inflight: usize },
}

#[derive(Clone, Debug, Default)]
struct TenantState {
    /// Current bucket level (tokens, fractional between refills).
    tokens: f64,
    /// Timestamp of the last refill, seconds from server boot.
    last_s: f64,
    /// Live bucket? (first sighting seeds a full bucket.)
    seen: bool,
    inflight: usize,
    admitted: usize,
    rejected_rate: usize,
    rejected_inflight: usize,
}

/// Admission controller over every tenant. One instance lives behind a
/// mutex in the HTTP server; all methods are O(log tenants).
#[derive(Clone, Debug)]
pub struct AdmissionControl {
    default_policy: TenantPolicy,
    policies: BTreeMap<String, TenantPolicy>,
    tenants: BTreeMap<String, TenantState>,
}

impl AdmissionControl {
    pub fn new(default_policy: TenantPolicy) -> AdmissionControl {
        AdmissionControl { default_policy, policies: BTreeMap::new(), tenants: BTreeMap::new() }
    }

    /// Override the policy for one tenant (adapter name).
    pub fn set_policy(&mut self, tenant: &str, policy: TenantPolicy) {
        self.policies.insert(tenant.to_string(), policy);
    }

    pub fn policy_for(&self, tenant: &str) -> TenantPolicy {
        self.policies.get(tenant).copied().unwrap_or(self.default_policy)
    }

    fn key(adapter: Option<&str>) -> String {
        adapter.unwrap_or(BASE_KEY).to_string()
    }

    /// Try to admit one request for `adapter` at time `now_s` (seconds
    /// from server boot). On `Granted`, the tenant's in-flight count is
    /// incremented — the caller MUST pair it with [`Self::release`]
    /// when the request finishes (success or failure).
    pub fn admit(&mut self, adapter: Option<&str>, now_s: f64) -> Admission {
        let key = Self::key(adapter);
        let policy = self.policy_for(&key);
        let st = self.tenants.entry(key).or_default();
        if !st.seen {
            st.seen = true;
            st.tokens = policy.burst;
            st.last_s = now_s;
        }
        // Refill first (monotonic clock assumed; clamp regressions).
        let dt = (now_s - st.last_s).max(0.0);
        st.tokens = (st.tokens + dt * policy.rate_per_s).min(policy.burst);
        st.last_s = now_s;
        if st.inflight >= policy.max_inflight {
            st.rejected_inflight += 1;
            return Admission::Saturated { inflight: st.inflight, max_inflight: policy.max_inflight };
        }
        if st.tokens < 1.0 {
            st.rejected_rate += 1;
            let retry_after_s = if policy.rate_per_s > 0.0 {
                (1.0 - st.tokens) / policy.rate_per_s
            } else {
                f64::INFINITY
            };
            return Admission::RateLimited { retry_after_s };
        }
        st.tokens -= 1.0;
        st.inflight += 1;
        st.admitted += 1;
        Admission::Granted
    }

    /// A previously admitted request for `adapter` finished.
    pub fn release(&mut self, adapter: Option<&str>) {
        if let Some(st) = self.tenants.get_mut(&Self::key(adapter)) {
            st.inflight = st.inflight.saturating_sub(1);
        }
    }

    /// Remaining whole tokens for a tenant at `now_s` (the
    /// `X-RateLimit-Remaining` header), without consuming anything.
    pub fn remaining(&self, adapter: Option<&str>, now_s: f64) -> f64 {
        let key = Self::key(adapter);
        let policy = self.policy_for(&key);
        match self.tenants.get(&key) {
            Some(st) if st.seen => {
                let dt = (now_s - st.last_s).max(0.0);
                (st.tokens + dt * policy.rate_per_s).min(policy.burst)
            }
            _ => policy.burst,
        }
    }

    /// Per-tenant admission counters for `/metrics`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        for (name, st) in &self.tenants {
            let mut t = Json::obj();
            t.set("inflight", jnum(st.inflight as f64));
            t.set("admitted", jnum(st.admitted as f64));
            t.set("rejected_rate_limited", jnum(st.rejected_rate as f64));
            t.set("rejected_saturated", jnum(st.rejected_inflight as f64));
            o.set(name, t);
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(rate: f64, burst: f64, inflight: usize) -> TenantPolicy {
        TenantPolicy { rate_per_s: rate, burst, max_inflight: inflight }
    }

    #[test]
    fn burst_then_rate_limit_then_refill() {
        let mut ac = AdmissionControl::new(policy(2.0, 3.0, 100));
        // Full bucket at first sight: three admissions burst through.
        for _ in 0..3 {
            assert_eq!(ac.admit(Some("a"), 0.0), Admission::Granted);
        }
        // Fourth at the same instant is limited, with a refill ETA.
        match ac.admit(Some("a"), 0.0) {
            Admission::RateLimited { retry_after_s } => {
                assert!((retry_after_s - 0.5).abs() < 1e-9, "eta={retry_after_s}");
            }
            other => panic!("expected rate limit, got {other:?}"),
        }
        // Half a second later one token has refilled.
        assert_eq!(ac.admit(Some("a"), 0.5), Admission::Granted);
        assert!(matches!(ac.admit(Some("a"), 0.5), Admission::RateLimited { .. }));
        // Refill caps at burst, not beyond.
        assert!((ac.remaining(Some("a"), 1000.0) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn inflight_cap_and_release() {
        let mut ac = AdmissionControl::new(policy(1000.0, 1000.0, 2));
        assert_eq!(ac.admit(Some("a"), 0.0), Admission::Granted);
        assert_eq!(ac.admit(Some("a"), 0.0), Admission::Granted);
        assert_eq!(
            ac.admit(Some("a"), 0.0),
            Admission::Saturated { inflight: 2, max_inflight: 2 }
        );
        ac.release(Some("a"));
        assert_eq!(ac.admit(Some("a"), 0.0), Admission::Granted);
        // Double release never underflows.
        ac.release(Some("b"));
    }

    #[test]
    fn tenants_are_isolated_and_base_uses_base_key() {
        let mut ac = AdmissionControl::new(policy(0.0, 1.0, 10));
        assert_eq!(ac.admit(Some("a"), 0.0), Admission::Granted);
        // Tenant a is dry (rate 0: never refills) but b has its own bucket.
        assert!(matches!(ac.admit(Some("a"), 9.0), Admission::RateLimited { .. }));
        assert_eq!(ac.admit(Some("b"), 9.0), Admission::Granted);
        assert_eq!(ac.admit(None, 9.0), Admission::Granted);
        let j = ac.to_json().to_string();
        assert!(j.contains(BASE_KEY) && j.contains("\"rejected_rate_limited\":1"), "{j}");
    }

    #[test]
    fn per_tenant_policy_overrides_default() {
        let mut ac = AdmissionControl::new(policy(100.0, 100.0, 100));
        ac.set_policy("throttled", policy(0.5, 1.0, 100));
        assert_eq!(ac.admit(Some("throttled"), 0.0), Admission::Granted);
        match ac.admit(Some("throttled"), 0.0) {
            Admission::RateLimited { retry_after_s } => {
                assert!((retry_after_s - 2.0).abs() < 1e-9);
            }
            other => panic!("expected rate limit, got {other:?}"),
        }
        // Other tenants still ride the generous default.
        for _ in 0..50 {
            assert_eq!(ac.admit(Some("open"), 0.0), Admission::Granted);
        }
    }
}
